# pytest: Pallas kernels vs pure-jnp oracles — the CORE L1 correctness
# signal. Fixed-seed cases for each kernel plus hypothesis sweeps over
# shapes / mask densities / index distributions.

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gat_attn, rgcn_agg, sage_matmul, seg_mean
from compile.kernels import ref
from compile.kernels.gat_attn import gat_attn_pallas
from compile.kernels.rgcn_agg import rgcn_agg_pallas
from compile.kernels.sage_matmul import sage_matmul_pallas
from compile.kernels.seg_mean import seg_mean_pallas

RNG = np.random.default_rng(7)


def _mk_seg(n_src, n_dst, k, f, density=0.7, seed=0):
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.normal(size=(n_src, f)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n_src, size=(n_dst, k)).astype(np.int32))
    mask = jnp.asarray((rng.random((n_dst, k)) < density).astype(np.float32))
    return feats, idx, mask


class TestSegMean:
    def test_matches_ref(self):
        feats, idx, mask = _mk_seg(100, 256, 8, 32)
        np.testing.assert_allclose(
            seg_mean_pallas(feats, idx, mask),
            ref.seg_mean_ref(feats, idx, mask), rtol=1e-5, atol=1e-5)

    def test_all_masked_row_is_zero(self):
        feats, idx, _ = _mk_seg(50, 128, 4, 16)
        mask = jnp.zeros((128, 4), jnp.float32)
        out = seg_mean_pallas(feats, idx, mask)
        assert np.all(np.asarray(out) == 0.0)

    def test_single_neighbor_identity(self):
        # one neighbor with mask 1 -> output == that neighbor's feature
        feats, _, _ = _mk_seg(64, 128, 1, 8)
        idx = jnp.asarray(
            RNG.integers(0, 64, size=(128, 1)).astype(np.int32))
        mask = jnp.ones((128, 1), jnp.float32)
        out = seg_mean_pallas(feats, idx, mask)
        np.testing.assert_allclose(
            out, np.asarray(feats)[np.asarray(idx)[:, 0]], rtol=1e-6)

    def test_oob_indices_are_clamped(self):
        # garbage indices behind mask==0 must not poison the output
        feats, idx, mask = _mk_seg(32, 128, 4, 8)
        bad = np.asarray(idx).copy()
        bad[mask == 0] = 10_000_000
        out = seg_mean_pallas(feats, jnp.asarray(bad), mask)
        assert np.all(np.isfinite(np.asarray(out)))

    def test_grad_matches_ref_grad(self):
        feats, idx, mask = _mk_seg(60, 128, 5, 16)
        g = jax.grad(lambda fe: jnp.sum(seg_mean(fe, idx, mask) ** 2))(feats)
        g_ref = jax.grad(
            lambda fe: jnp.sum(ref.seg_mean_ref(fe, idx, mask) ** 2))(feats)
        np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        n_src=st.integers(1, 300),
        n_dst=st.sampled_from([64, 128, 256, 384]),
        k=st.integers(1, 16),
        f=st.integers(1, 64),
        density=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n_src, n_dst, k, f, density, seed):
        feats, idx, mask = _mk_seg(n_src, n_dst, k, f, density, seed)
        np.testing.assert_allclose(
            seg_mean_pallas(feats, idx, mask),
            ref.seg_mean_ref(feats, idx, mask), rtol=1e-4, atol=1e-5)

    @settings(max_examples=8, deadline=None)
    @given(blk=st.sampled_from([32, 64, 128, 256]))
    def test_block_size_invariance(self, blk):
        feats, idx, mask = _mk_seg(80, 256, 6, 24)
        np.testing.assert_allclose(
            seg_mean_pallas(feats, idx, mask, blk_dst=blk),
            ref.seg_mean_ref(feats, idx, mask), rtol=1e-5, atol=1e-5)


class TestSageMatmul:
    def test_matches_ref(self):
        rng = np.random.default_rng(1)
        hs = jnp.asarray(rng.normal(size=(256, 32)).astype(np.float32))
        ha = jnp.asarray(rng.normal(size=(256, 32)).astype(np.float32))
        ws = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
        wn = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        np.testing.assert_allclose(
            sage_matmul_pallas(hs, ha, ws, wn, b),
            ref.sage_matmul_ref(hs, ha, ws, wn, b), rtol=1e-4, atol=1e-4)

    def test_zero_inputs_give_bias(self):
        hs = jnp.zeros((128, 16)); ha = jnp.zeros((128, 16))
        ws = jnp.ones((16, 8)); wn = jnp.ones((16, 8))
        b = jnp.arange(8, dtype=jnp.float32)
        out = np.asarray(sage_matmul_pallas(hs, ha, ws, wn, b))
        np.testing.assert_allclose(out, np.tile(np.arange(8), (128, 1)))

    def test_grads_all_args(self):
        rng = np.random.default_rng(2)
        args = [
            jnp.asarray(rng.normal(size=s).astype(np.float32))
            for s in [(128, 16), (128, 16), (16, 8), (16, 8), (8,)]
        ]
        def loss_k(*a): return jnp.sum(sage_matmul(*a) ** 2)
        def loss_r(*a): return jnp.sum(ref.sage_matmul_ref(*a) ** 2)
        gk = jax.grad(loss_k, argnums=tuple(range(5)))(*args)
        gr = jax.grad(loss_r, argnums=tuple(range(5)))(*args)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.sampled_from([64, 128, 256]),
        f_in=st.integers(1, 48),
        f_out=st.integers(1, 48),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n, f_in, f_out, seed):
        rng = np.random.default_rng(seed)
        hs = jnp.asarray(rng.normal(size=(n, f_in)).astype(np.float32))
        ha = jnp.asarray(rng.normal(size=(n, f_in)).astype(np.float32))
        ws = jnp.asarray(rng.normal(size=(f_in, f_out)).astype(np.float32))
        wn = jnp.asarray(rng.normal(size=(f_in, f_out)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(f_out,)).astype(np.float32))
        np.testing.assert_allclose(
            sage_matmul_pallas(hs, ha, ws, wn, b),
            ref.sage_matmul_ref(hs, ha, ws, wn, b), rtol=1e-3, atol=1e-3)


class TestGatAttn:
    def _mk(self, n_src, n_dst, k, h, d, density=0.8, seed=3):
        rng = np.random.default_rng(seed)
        feats = jnp.asarray(rng.normal(size=(n_src, h, d)).astype(np.float32))
        ssrc = jnp.asarray(rng.normal(size=(n_src, h)).astype(np.float32))
        sdst = jnp.asarray(rng.normal(size=(n_dst, h)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, n_src, size=(n_dst, k)).astype(np.int32))
        mask = jnp.asarray((rng.random((n_dst, k)) < density).astype(np.float32))
        return feats, ssrc, sdst, idx, mask

    def test_matches_ref(self):
        feats, ssrc, sdst, idx, mask = self._mk(90, 128, 6, 2, 16)
        np.testing.assert_allclose(
            gat_attn_pallas(feats, ssrc, sdst, idx, mask, num_heads=2),
            ref.gat_attn_ref(feats, ssrc, sdst, idx, mask),
            rtol=1e-4, atol=1e-5)

    def test_attention_weights_sum_to_one(self):
        # uniform scores + full mask -> plain mean of neighbors
        n_src, n_dst, k, h, d = 40, 128, 4, 1, 8
        feats, _, _, idx, _ = self._mk(n_src, n_dst, k, h, d)
        ssrc = jnp.zeros((n_src, h)); sdst = jnp.zeros((n_dst, h))
        mask = jnp.ones((n_dst, k), jnp.float32)
        out = gat_attn_pallas(feats, ssrc, sdst, idx, mask, num_heads=1)
        expect = np.mean(
            np.asarray(feats)[np.asarray(idx)], axis=1)  # [n_dst, h, d]
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)

    def test_fully_masked_row_is_zero(self):
        feats, ssrc, sdst, idx, mask = self._mk(40, 128, 4, 2, 8)
        mask = jnp.zeros_like(mask)
        out = np.asarray(
            gat_attn_pallas(feats, ssrc, sdst, idx, mask, num_heads=2))
        assert np.all(out == 0.0)

    def test_grads_match_ref(self):
        feats, ssrc, sdst, idx, mask = self._mk(50, 128, 4, 2, 8)
        def lk(fe, a, b):
            return jnp.sum(gat_attn(fe, a, b, idx, mask, num_heads=2) ** 2)
        def lr(fe, a, b):
            return jnp.sum(ref.gat_attn_ref(fe, a, b, idx, mask) ** 2)
        gk = jax.grad(lk, argnums=(0, 1, 2))(feats, ssrc, sdst)
        gr = jax.grad(lr, argnums=(0, 1, 2))(feats, ssrc, sdst)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        n_src=st.integers(1, 200),
        n_dst=st.sampled_from([64, 128]),
        k=st.integers(1, 10),
        h=st.integers(1, 4),
        d=st.integers(1, 16),
        density=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n_src, n_dst, k, h, d, density, seed):
        feats, ssrc, sdst, idx, mask = self._mk(
            n_src, n_dst, k, h, d, density, seed)
        np.testing.assert_allclose(
            gat_attn_pallas(feats, ssrc, sdst, idx, mask, num_heads=h),
            ref.gat_attn_ref(feats, ssrc, sdst, idx, mask),
            rtol=1e-3, atol=1e-4)


class TestRgcnAgg:
    def _mk(self, n_src, n_dst, k, f, r, density=0.8, seed=4):
        rng = np.random.default_rng(seed)
        feats = jnp.asarray(rng.normal(size=(n_src, f)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, n_src, size=(n_dst, k)).astype(np.int32))
        mask = jnp.asarray((rng.random((n_dst, k)) < density).astype(np.float32))
        rel = jnp.asarray(rng.integers(0, r, size=(n_dst, k)).astype(np.int32))
        return feats, idx, mask, rel

    def test_matches_ref(self):
        feats, idx, mask, rel = self._mk(70, 128, 6, 24, 4)
        np.testing.assert_allclose(
            rgcn_agg_pallas(feats, idx, mask, rel, num_rels=4),
            ref.rgcn_agg_ref(feats, idx, mask, rel, 4), rtol=1e-4, atol=1e-5)

    def test_single_relation_equals_seg_mean(self):
        feats, idx, mask, _ = self._mk(50, 128, 5, 16, 1)
        rel = jnp.zeros((128, 5), jnp.int32)
        out = rgcn_agg_pallas(feats, idx, mask, rel, num_rels=1)
        np.testing.assert_allclose(
            out[:, 0, :], ref.seg_mean_ref(feats, idx, mask),
            rtol=1e-5, atol=1e-5)

    def test_relation_partition_is_disjoint(self):
        # every (masked) edge contributes to exactly one relation slot:
        # summing count-weighted outputs over R == unnormalized total sum
        feats, idx, mask, rel = self._mk(40, 128, 4, 8, 3)
        out = np.asarray(rgcn_agg_pallas(feats, idx, mask, rel, num_rels=3))
        sel = (np.asarray(rel)[..., None] == np.arange(3)) * \
            np.asarray(mask)[..., None]
        cnt = np.maximum(sel.sum(axis=1), 1.0)  # [N, R]
        total = (out * cnt[..., None]).sum(axis=1)
        expect = (np.asarray(feats)[np.asarray(idx)] *
                  np.asarray(mask)[..., None]).sum(axis=1)
        np.testing.assert_allclose(total, expect, rtol=1e-4, atol=1e-4)

    def test_grad_matches_ref(self):
        feats, idx, mask, rel = self._mk(30, 128, 4, 8, 3)
        gk = jax.grad(lambda fe: jnp.sum(
            rgcn_agg(fe, idx, mask, rel, num_rels=3) ** 2))(feats)
        gr = jax.grad(lambda fe: jnp.sum(
            ref.rgcn_agg_ref(fe, idx, mask, rel, 3) ** 2))(feats)
        np.testing.assert_allclose(gk, gr, rtol=1e-3, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        n_src=st.integers(1, 150),
        k=st.integers(1, 8),
        f=st.integers(1, 32),
        r=st.integers(1, 6),
        density=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n_src, k, f, r, density, seed):
        feats, idx, mask, rel = self._mk(n_src, 128, k, f, r, density, seed)
        np.testing.assert_allclose(
            rgcn_agg_pallas(feats, idx, mask, rel, num_rels=r),
            ref.rgcn_agg_ref(feats, idx, mask, rel, r),
            rtol=1e-3, atol=1e-4)
