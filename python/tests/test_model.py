# pytest: L2 model-level checks — shapes, gradient flow, loss decrease on a
# learnable toy problem, and manifest/spec consistency for every variant.

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def _rand_inputs(cfg: M.ShapeConfig, train: bool, seed=0):
    """Random but *valid* block inputs for a variant."""
    rng = np.random.default_rng(seed)
    n = cfg.layer_nodes
    out = []
    for (name, shape, dtype) in cfg.input_specs(train):
        if name == "feats":
            a = rng.normal(size=shape).astype(np.float32)
        elif name.startswith("self_idx_"):
            l = int(name.split("_")[-1])
            a = rng.integers(0, n[l - 1], size=shape).astype(np.int32)
        elif name.startswith("nbr_idx_"):
            l = int(name.split("_")[-1])
            a = rng.integers(0, n[l - 1], size=shape).astype(np.int32)
        elif name.startswith("nbr_mask_"):
            a = (rng.random(shape) < 0.8).astype(np.float32)
        elif name.startswith("rel_"):
            a = rng.integers(0, cfg.num_rels, size=shape).astype(np.int32)
        elif name == "labels":
            a = rng.integers(0, max(cfg.num_classes, 1), size=shape).astype(np.int32)
        elif name == "label_mask":
            a = np.ones(shape, np.float32)
        elif name == "pair_mask":
            a = np.ones(shape, np.float32)
        elif name == "lr":
            a = np.float32(0.1)
        else:
            raise AssertionError(name)
        out.append(jnp.asarray(a))
    return out


DEV = ["sage_nc_dev", "sage_lp_dev", "gat_nc_dev", "rgcn_nc_dev"]


@pytest.mark.parametrize("name", DEV)
def test_train_step_shapes(name):
    cfg = M.VARIANTS[name]
    params = [jnp.asarray(p) for p in M.init_params(cfg)]
    step, n_params = M.make_train_step(cfg)
    ins = _rand_inputs(cfg, train=True)
    outs = step(*params, *ins)
    assert len(outs) == n_params + 1
    for p, o in zip(params, outs[:-1]):
        assert p.shape == o.shape and p.dtype == o.dtype
    loss = outs[-1]
    assert loss.shape == () and np.isfinite(float(loss))


@pytest.mark.parametrize("name", DEV)
def test_eval_step_shapes(name):
    cfg = M.VARIANTS[name]
    params = [jnp.asarray(p) for p in M.init_params(cfg)]
    step, _ = M.make_eval_step(cfg)
    ins = _rand_inputs(cfg, train=False)
    (out,) = step(*params, *ins)
    n_l = cfg.layer_nodes[-1]
    exp_dim = cfg.num_classes if cfg.task == "nc" else cfg.hidden
    assert out.shape == (n_l, exp_dim)


@pytest.mark.parametrize(
    "name,lr",
    [("sage_nc_dev", 0.3), ("gat_nc_dev", 1.0), ("rgcn_nc_dev", 0.3)],
)
def test_loss_decreases_under_sgd(name, lr):
    """Repeated train_step on one fixed batch must fit it."""
    cfg = M.VARIANTS[name]
    params = [jnp.asarray(p) for p in M.init_params(cfg)]
    step, n_params = M.make_train_step(cfg)
    jstep = jax.jit(step)
    ins = _rand_inputs(cfg, train=True, seed=1)
    ins[-1] = jnp.asarray(np.float32(lr))
    first = None
    for _ in range(20):
        outs = jstep(*params, *ins)
        params = list(outs[:-1])
        loss = float(outs[-1])
        if first is None:
            first = loss
    assert loss < 0.85 * first, f"{name}: {first} -> {loss}"


def test_lp_loss_decreases():
    cfg = M.VARIANTS["sage_lp_dev"]
    params = [jnp.asarray(p) for p in M.init_params(cfg)]
    step, _ = M.make_train_step(cfg)
    jstep = jax.jit(step)
    ins = _rand_inputs(cfg, train=True, seed=2)
    losses = []
    for _ in range(8):
        outs = jstep(*params, *ins)
        params = list(outs[:-1])
        losses.append(float(outs[-1]))
    assert losses[-1] < losses[0]


def test_grad_matches_finite_difference():
    """Spot-check one weight entry of sage_nc_dev against finite differences."""
    cfg = M.VARIANTS["sage_nc_dev"]
    params = [jnp.asarray(p) for p in M.init_params(cfg)]
    loss_fn, n_params = M.make_loss_fn(cfg)
    ins = _rand_inputs(cfg, train=True, seed=3)
    feats, blocks, task = ins[0], ins[1:-3], ins[-3:-1]

    def f(w0):
        ps = [w0] + params[1:]
        return loss_fn(ps, feats, list(blocks), tuple(task))

    g = jax.grad(f)(params[0])
    eps = 1e-3
    e = np.zeros(params[0].shape, np.float32); e[0, 0] = eps
    fd = (float(f(params[0] + e)) - float(f(params[0] - e))) / (2 * eps)
    assert abs(float(g[0, 0]) - fd) < 5e-2 * max(1.0, abs(fd))


def test_label_mask_zeroes_padding_contribution():
    """Padded rows (label_mask 0) must not change loss or grads."""
    cfg = M.VARIANTS["sage_nc_dev"]
    params = [jnp.asarray(p) for p in M.init_params(cfg)]
    loss_fn, _ = M.make_loss_fn(cfg)
    ins = _rand_inputs(cfg, train=True, seed=4)
    feats, blocks, (labels, lmask) = ins[0], ins[1:-3], ins[-3:-1]
    lmask_half = np.asarray(lmask).copy()
    lmask_half[64:] = 0.0
    labels_garbage = np.asarray(labels).copy()
    base = float(loss_fn(params, feats, list(blocks),
                         (labels, jnp.asarray(lmask_half))))
    labels_garbage[64:] = (labels_garbage[64:] + 7) % cfg.num_classes
    pert = float(loss_fn(params, feats, list(blocks),
                         (jnp.asarray(labels_garbage), jnp.asarray(lmask_half))))
    assert abs(base - pert) < 1e-6


def test_layer_nodes_monotone_and_padded():
    for cfg in M.VARIANTS.values():
        n = cfg.layer_nodes
        assert all(v % M.BLOCK == 0 for v in n)
        assert all(a >= b for a, b in zip(n, n[1:]))
        base = cfg.batch if cfg.task == "nc" else 3 * cfg.batch
        assert n[-1] >= base


def test_manifest_entry_consistent():
    for name in DEV:
        cfg = M.VARIANTS[name]
        e = M.manifest_entry(cfg)
        assert e["layer_nodes"] == cfg.layer_nodes
        assert len(e["param_shapes"]) == \
            M.params_per_layer(cfg.model) * cfg.num_layers
        # eval inputs are the structural prefix of train inputs (train
        # additionally carries task args + lr, which eval's pruned HLO
        # does not accept)
        tr, ev = e["train_inputs"], e["eval_inputs"]
        assert tr[-1]["name"] == "lr"
        assert [i["name"] for i in tr[: len(ev)]] == [i["name"] for i in ev]
        extra = {i["name"] for i in tr[len(ev):]}
        assert extra <= {"labels", "label_mask", "pair_mask", "lr"}


def test_init_params_deterministic():
    cfg = M.VARIANTS["sage_nc_dev"]
    a = M.init_params(cfg, seed=0)
    b = M.init_params(cfg, seed=0)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
