# L1 Pallas kernel: fused GraphSAGE linear transform.
#
#   out = h_self @ W_self + h_agg @ W_neigh + b
#
# TPU mapping: the two matmuls share the same output tile, so fusing them
# halves the number of HBM round-trips for the accumulator. We tile rows of
# h_self/h_agg into (BLK_N, F_in) VMEM blocks, keep both weight matrices
# resident in VMEM (F_in, F_out are model dims <= 1024 => <= 4 MiB each, fits
# alongside double-buffered row tiles), and accumulate in f32. Both matmuls
# map onto the MXU with 128-aligned tiles.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLK_N = 512


def _pick_block(n: int, blk: int) -> int:
    """Largest block <= blk that divides n (try multiples of 128 first).

    Perf note (§Perf pass): bigger blocks mean fewer grid steps, and in
    interpret lowering every grid step re-materializes the resident input
    blocks — at dev shapes this halved the per-call step count.
    """
    b = min(blk, n)
    while b > 1 and n % b:
        b -= 128 if b > 128 else 1
    return max(b, 1)


def _sage_matmul_kernel(hs_ref, ha_ref, ws_ref, wn_ref, b_ref, out_ref):
    hs = hs_ref[...]                 # [BLK, F_in]
    ha = ha_ref[...]                 # [BLK, F_in]
    ws = ws_ref[...]                 # [F_in, F_out]
    wn = wn_ref[...]                 # [F_in, F_out]
    b = b_ref[...]                   # [1, F_out]
    acc = jnp.dot(hs, ws, preferred_element_type=jnp.float32)
    acc = acc + jnp.dot(ha, wn, preferred_element_type=jnp.float32)
    out_ref[...] = acc + b


@functools.partial(jax.jit, static_argnames=("blk_n",))
def sage_matmul_pallas(h_self, h_agg, w_self, w_neigh, b, *, blk_n: int = DEFAULT_BLK_N):
    """Raw Pallas fused SAGE linear (see `sage_matmul` wrapper below)."""
    n, f_in = h_self.shape
    f_out = w_self.shape[1]
    blk = _pick_block(n, blk_n)
    if n % blk != 0:
        raise ValueError(f"N={n} not a multiple of block {blk}")
    b2 = b.reshape(1, f_out)
    return pl.pallas_call(
        _sage_matmul_kernel,
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk, f_in), lambda i: (i, 0)),
            pl.BlockSpec((blk, f_in), lambda i: (i, 0)),
            pl.BlockSpec((f_in, f_out), lambda i: (0, 0)),
            pl.BlockSpec((f_in, f_out), lambda i: (0, 0)),
            pl.BlockSpec((1, f_out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, f_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, f_out), h_self.dtype),
        interpret=True,
    )(h_self, h_agg, w_self, w_neigh, b2)


# ---------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward, jnp-VJP backward (all five args are
# float and differentiable — the grads are three matmuls XLA fuses).
# ---------------------------------------------------------------------------

from . import ref as _ref  # noqa: E402


@functools.lru_cache(maxsize=None)
def _make_sage_matmul(blk_n: int):
    @jax.custom_vjp
    def f(h_self, h_agg, w_self, w_neigh, b):
        return sage_matmul_pallas(h_self, h_agg, w_self, w_neigh, b,
                                  blk_n=blk_n)

    def fwd(*args):
        return f(*args), args

    def bwd(res, g):
        _, vjp = jax.vjp(_ref.sage_matmul_ref, *res)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def sage_matmul(h_self, h_agg, w_self, w_neigh, b, *, blk_n: int = DEFAULT_BLK_N):
    """Differentiable fused SAGE linear: h_self@W_s + h_agg@W_n + b."""
    return _make_sage_matmul(blk_n)(h_self, h_agg, w_self, w_neigh, b)
