# L1 Pallas kernel: per-relation masked mean aggregation (RGCN).
#
# For heterogeneous graphs the paper trains RGCN; the aggregation hot-spot
# becomes a relation-partitioned segment mean. We fuse the one-hot relation
# selection with the gather so each destination tile produces a
# (BLK, R, F) tensor in one pass instead of R separate gathers.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLK_DST = 512


def _pick_block(n: int, blk: int) -> int:
    """Largest block <= blk that divides n (try multiples of 128 first).

    Perf note (§Perf pass): bigger blocks mean fewer grid steps, and in
    interpret lowering every grid step re-materializes the resident input
    blocks — at dev shapes this halved the per-call step count.
    """
    b = min(blk, n)
    while b > 1 and n % b:
        b -= 128 if b > 128 else 1
    return max(b, 1)


def _rgcn_agg_kernel(feats_ref, idx_ref, mask_ref, rel_ref, out_ref, *, num_rels):
    feats = feats_ref[...]            # [N_src, F]
    idx = idx_ref[...]                # [BLK, K]
    mask = mask_ref[...]              # [BLK, K]
    rel = rel_ref[...]                # [BLK, K]
    n_src, f = feats.shape
    blk = idx.shape[0]

    idx = jnp.clip(idx, 0, n_src - 1)
    gathered = jnp.take(feats, idx, axis=0)          # [BLK, K, F]
    sel = (rel[..., None] == jnp.arange(num_rels)[None, None, :]).astype(
        feats.dtype
    ) * mask[..., None]                              # [BLK, K, R]
    s = jnp.einsum("nkf,nkr->nrf", gathered, sel)
    cnt = jnp.maximum(jnp.sum(sel, axis=1), 1.0)     # [BLK, R]
    out = s / cnt[..., None]
    out_ref[...] = out.reshape(blk, num_rels * f)


@functools.partial(jax.jit, static_argnames=("num_rels", "blk_dst"))
def rgcn_agg_pallas(feats, idx, mask, rel, *, num_rels: int,
                    blk_dst: int = DEFAULT_BLK_DST):
    """Raw Pallas per-relation mean aggregation (see `rgcn_agg` below).

    feats: [N_src, F]; idx/mask/rel: [N_dst, K]
    """
    n_dst, k = idx.shape
    n_src, f = feats.shape
    blk = _pick_block(n_dst, blk_dst)
    if n_dst % blk != 0:
        raise ValueError(f"N_dst={n_dst} not a multiple of block {blk}")
    kern = functools.partial(_rgcn_agg_kernel, num_rels=num_rels)
    out = pl.pallas_call(
        kern,
        grid=(n_dst // blk,),
        in_specs=[
            pl.BlockSpec((n_src, f), lambda i: (0, 0)),
            pl.BlockSpec((blk, k), lambda i: (i, 0)),
            pl.BlockSpec((blk, k), lambda i: (i, 0)),
            pl.BlockSpec((blk, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk, num_rels * f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_dst, num_rels * f), feats.dtype),
        interpret=True,
    )(feats, idx, mask, rel)
    return out.reshape(n_dst, num_rels, f)


# ---------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward, jnp-VJP backward (scatter-add per
# relation); idx/rel are int inputs, mask gets a symbolic zero.
# ---------------------------------------------------------------------------

import numpy as _np  # noqa: E402

from . import ref as _ref  # noqa: E402


@functools.lru_cache(maxsize=None)
def _make_rgcn_agg(num_rels: int, blk_dst: int):
    @jax.custom_vjp
    def f(feats, idx, mask, rel):
        return rgcn_agg_pallas(feats, idx, mask, rel, num_rels=num_rels,
                               blk_dst=blk_dst)

    def fwd(feats, idx, mask, rel):
        return f(feats, idx, mask, rel), (feats, idx, mask, rel)

    def bwd(res, g):
        feats, idx, mask, rel = res
        _, vjp = jax.vjp(
            lambda fe: _ref.rgcn_agg_ref(fe, idx, mask, rel, num_rels), feats)
        (df,) = vjp(g)
        return (df, _np.zeros(idx.shape, dtype=jax.dtypes.float0),
                jnp.zeros_like(mask),
                _np.zeros(rel.shape, dtype=jax.dtypes.float0))

    f.defvjp(fwd, bwd)
    return f


def rgcn_agg(feats, idx, mask, rel, *, num_rels: int,
             blk_dst: int = DEFAULT_BLK_DST):
    """Differentiable per-relation mean aggregation (Pallas fwd, jnp bwd)."""
    return _make_rgcn_agg(num_rels, blk_dst)(feats, idx, mask, rel)
