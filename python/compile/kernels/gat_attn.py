# L1 Pallas kernel: GAT edge-softmax + weighted neighbor aggregation.
#
# Fuses, per destination tile:
#   logits  = leaky_relu(scores_src[idx] + scores_dst[:, None])   [BLK,K,H]
#   alpha   = masked softmax over K
#   out     = sum_k alpha * feats[idx]                            [BLK,H,D]
#
# The paper's GPU implementation does this with one threadblock per
# destination; on TPU we tile destinations into VMEM blocks and express the
# K-axis softmax + weighted sum as vector ops over the (BLK, K, H[, D])
# tile. The gather sources (projected features + source scores) stay
# resident in VMEM across grid steps.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLK_DST = 512


def _pick_block(n: int, blk: int) -> int:
    """Largest block <= blk that divides n (try multiples of 128 first).

    Perf note (§Perf pass): bigger blocks mean fewer grid steps, and in
    interpret lowering every grid step re-materializes the resident input
    blocks — at dev shapes this halved the per-call step count.
    """
    b = min(blk, n)
    while b > 1 and n % b:
        b -= 128 if b > 128 else 1
    return max(b, 1)
NEG_SLOPE = 0.2


def _gat_attn_kernel(feats_ref, ssrc_ref, sdst_ref, idx_ref, mask_ref, out_ref):
    feats = feats_ref[...]            # [N_src, H*D] flattened
    ssrc = ssrc_ref[...]              # [N_src, H]
    sdst = sdst_ref[...]              # [BLK, H]
    idx = idx_ref[...]                # [BLK, K]
    mask = mask_ref[...]              # [BLK, K]
    n_src = feats.shape[0]
    h = ssrc.shape[1]
    d = feats.shape[1] // h

    idx = jnp.clip(idx, 0, n_src - 1)
    g_sc = jnp.take(ssrc, idx, axis=0)              # [BLK, K, H]
    logits = g_sc + sdst[:, None, :]
    logits = jnp.where(logits >= 0, logits, NEG_SLOPE * logits)
    neg_inf = jnp.asarray(-1e30, logits.dtype)
    logits = jnp.where(mask[..., None] > 0, logits, neg_inf)
    logits = logits - jnp.max(logits, axis=1, keepdims=True)
    ex = jnp.exp(logits) * mask[..., None]
    denom = jnp.maximum(jnp.sum(ex, axis=1, keepdims=True), 1e-20)
    alpha = ex / denom                               # [BLK, K, H]

    g_feats = jnp.take(feats, idx, axis=0)           # [BLK, K, H*D]
    g_feats = g_feats.reshape(g_feats.shape[0], g_feats.shape[1], h, d)
    out = jnp.sum(alpha[..., None] * g_feats, axis=1)  # [BLK, H, D]
    out_ref[...] = out.reshape(out.shape[0], h * d)


@functools.partial(jax.jit, static_argnames=("num_heads", "blk_dst"))
def gat_attn_pallas(feats, scores_src, scores_dst, idx, mask, *, num_heads: int,
                    blk_dst: int = DEFAULT_BLK_DST):
    """Raw Pallas GAT attention aggregation (see `gat_attn` wrapper below).

    feats:      [N_src, H, D] float32 (projected)
    scores_src: [N_src, H]
    scores_dst: [N_dst, H]
    idx, mask:  [N_dst, K]
    returns [N_dst, H, D]
    """
    n_src, h, d = feats.shape
    assert h == num_heads
    n_dst, k = idx.shape
    blk = _pick_block(n_dst, blk_dst)
    if n_dst % blk != 0:
        raise ValueError(f"N_dst={n_dst} not a multiple of block {blk}")
    feats2 = feats.reshape(n_src, h * d)
    out = pl.pallas_call(
        _gat_attn_kernel,
        grid=(n_dst // blk,),
        in_specs=[
            pl.BlockSpec((n_src, h * d), lambda i: (0, 0)),
            pl.BlockSpec((n_src, h), lambda i: (0, 0)),
            pl.BlockSpec((blk, h), lambda i: (i, 0)),
            pl.BlockSpec((blk, k), lambda i: (i, 0)),
            pl.BlockSpec((blk, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk, h * d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_dst, h * d), feats.dtype),
        interpret=True,
    )(feats2, scores_src, scores_dst, idx, mask)
    return out.reshape(n_dst, h, d)


# ---------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward; backward rematerializes the
# softmax through the pure-jnp oracle (feats/scores_src/scores_dst are
# differentiable; idx is int, mask gets a symbolic zero).
# ---------------------------------------------------------------------------

import numpy as _np  # noqa: E402

from . import ref as _ref  # noqa: E402


@functools.lru_cache(maxsize=None)
def _make_gat_attn(num_heads: int, blk_dst: int):
    @jax.custom_vjp
    def f(feats, scores_src, scores_dst, idx, mask):
        return gat_attn_pallas(feats, scores_src, scores_dst, idx, mask,
                               num_heads=num_heads, blk_dst=blk_dst)

    def fwd(feats, scores_src, scores_dst, idx, mask):
        return f(feats, scores_src, scores_dst, idx, mask), (
            feats, scores_src, scores_dst, idx, mask)

    def bwd(res, g):
        feats, ssrc, sdst, idx, mask = res
        _, vjp = jax.vjp(
            lambda fe, a, b: _ref.gat_attn_ref(fe, a, b, idx, mask),
            feats, ssrc, sdst)
        df, da, db = vjp(g)
        return (df, da, db, _np.zeros(idx.shape, dtype=jax.dtypes.float0),
                jnp.zeros_like(mask))

    f.defvjp(fwd, bwd)
    return f


def gat_attn(feats, scores_src, scores_dst, idx, mask, *, num_heads: int,
             blk_dst: int = DEFAULT_BLK_DST):
    """Differentiable GAT edge-softmax aggregation (Pallas fwd, jnp bwd)."""
    return _make_gat_attn(num_heads, blk_dst)(
        feats, scores_src, scores_dst, idx, mask)
