# L1 Pallas kernel: gather + masked segment-mean neighbor aggregation.
#
# This is the GraphSAGE/ RGCN hot-spot (the paper's "feature copy +
# aggregation dominates" path). TPU mapping (see DESIGN.md §3): instead of a
# CUDA warp-per-destination gather we tile the padded neighbor-index matrix
# [N_dst, K] along the destination axis with BlockSpec; each grid step pulls
# a (BLK_DST, K) index tile + (BLK_DST, K) mask tile into VMEM, gathers from
# the source-feature window and reduces to a (BLK_DST, F) output tile.
#
# interpret=True is mandatory on this image: real TPU lowering emits a
# Mosaic custom-call the CPU PJRT plugin cannot execute.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLK_DST = 512


def _pick_block(n: int, blk: int) -> int:
    """Largest block <= blk that divides n (try multiples of 128 first).

    Perf note (§Perf pass): bigger blocks mean fewer grid steps, and in
    interpret lowering every grid step re-materializes the resident input
    blocks — at dev shapes this halved the per-call step count.
    """
    b = min(blk, n)
    while b > 1 and n % b:
        b -= 128 if b > 128 else 1
    return max(b, 1)


def _seg_mean_kernel(feats_ref, idx_ref, mask_ref, out_ref):
    """One grid step: aggregate a BLK_DST tile of destinations."""
    idx = idx_ref[...]                          # [BLK, K] i32
    mask = mask_ref[...]                        # [BLK, K] f32
    feats = feats_ref[...]                      # [N_src, F]
    n_src = feats.shape[0]
    # Clamp indices defensively: padding rows must never read OOB even if the
    # caller left garbage behind mask==0.
    idx = jnp.clip(idx, 0, n_src - 1)
    gathered = jnp.take(feats, idx, axis=0)     # [BLK, K, F]
    s = jnp.sum(gathered * mask[..., None], axis=1)
    cnt = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    out_ref[...] = s / cnt


@functools.partial(jax.jit, static_argnames=("blk_dst",))
def seg_mean_pallas(feats, idx, mask, *, blk_dst: int = DEFAULT_BLK_DST):
    """Raw Pallas forward (not differentiable). See `seg_mean` below.

    feats: [N_src, F] float32
    idx:   [N_dst, K] int32 (N_dst must be a multiple of blk_dst or smaller)
    mask:  [N_dst, K] float32
    returns [N_dst, F] float32
    """
    n_dst, k = idx.shape
    n_src, f = feats.shape
    blk = _pick_block(n_dst, blk_dst)
    if n_dst % blk != 0:
        raise ValueError(f"N_dst={n_dst} not a multiple of block {blk}")
    grid = (n_dst // blk,)
    return pl.pallas_call(
        _seg_mean_kernel,
        grid=grid,
        in_specs=[
            # Source features stay resident across grid steps (gather targets
            # are arbitrary): index_map pins the same full block.
            pl.BlockSpec((n_src, f), lambda i: (0, 0)),
            pl.BlockSpec((blk, k), lambda i: (i, 0)),
            pl.BlockSpec((blk, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_dst, f), feats.dtype),
        interpret=True,
    )(feats, idx, mask)


# ---------------------------------------------------------------------------
# Differentiable wrapper. pallas_call (interpret) has no transpose rule, so
# we attach a custom VJP: forward runs the Pallas kernel; backward
# rematerializes through the pure-jnp oracle (a scatter-add — cheap relative
# to the gather-heavy forward, and XLA fuses it).
# ---------------------------------------------------------------------------

import numpy as _np  # noqa: E402

from . import ref as _ref  # noqa: E402


@functools.lru_cache(maxsize=None)
def _make_seg_mean(blk_dst: int):
    @jax.custom_vjp
    def f(feats, idx, mask):
        return seg_mean_pallas(feats, idx, mask, blk_dst=blk_dst)

    def fwd(feats, idx, mask):
        return f(feats, idx, mask), (feats, idx, mask)

    def bwd(res, g):
        feats, idx, mask = res
        _, vjp = jax.vjp(lambda fe: _ref.seg_mean_ref(fe, idx, mask), feats)
        (df,) = vjp(g)
        return (df, _np.zeros(idx.shape, dtype=jax.dtypes.float0),
                jnp.zeros_like(mask))

    f.defvjp(fwd, bwd)
    return f


def seg_mean(feats, idx, mask, *, blk_dst: int = DEFAULT_BLK_DST):
    """Differentiable masked mean aggregation (Pallas fwd, jnp-VJP bwd)."""
    return _make_seg_mean(blk_dst)(feats, idx, mask)
