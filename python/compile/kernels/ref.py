# Pure-jnp correctness oracles for the Pallas kernels.
#
# Every kernel in this package has a reference implementation here written
# with plain jax.numpy ops only. pytest (python/tests/) asserts
# allclose(kernel, ref) across shape/dtype/mask sweeps — this is the CORE
# correctness signal for Layer 1.

import jax.numpy as jnp


def seg_mean_ref(feats, idx, mask):
    """Masked mean-aggregation of gathered neighbor features.

    feats: [N_src, F] float
    idx:   [N_dst, K] int32, positions into feats (padding rows may point
           anywhere valid; they are zeroed by mask)
    mask:  [N_dst, K] float, 1.0 for real neighbors, 0.0 for padding
    returns [N_dst, F]: sum_k mask * feats[idx] / max(1, sum_k mask)
    """
    gathered = jnp.take(feats, idx, axis=0)          # [N_dst, K, F]
    s = jnp.sum(gathered * mask[..., None], axis=1)  # [N_dst, F]
    cnt = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    return s / cnt


def sage_matmul_ref(h_self, h_agg, w_self, w_neigh, b):
    """Fused GraphSAGE linear: h_self @ w_self + h_agg @ w_neigh + b.

    h_self, h_agg: [N, F_in]; w_self, w_neigh: [F_in, F_out]; b: [F_out]
    """
    return h_self @ w_self + h_agg @ w_neigh + b


def gat_attn_ref(feats, scores_src, scores_dst, idx, mask, neg_slope=0.2):
    """GAT edge-softmax + weighted neighbor aggregation (per head).

    feats:      [N_src, H, D]  projected source features
    scores_src: [N_src, H]     a_src . feats  (precomputed in L2)
    scores_dst: [N_dst, H]     a_dst . h_dst
    idx:        [N_dst, K] int32
    mask:       [N_dst, K] float
    returns [N_dst, H, D]: softmax_k(leaky_relu(s_src[idx]+s_dst)) weighted sum
    """
    g_feats = jnp.take(feats, idx, axis=0)        # [N_dst, K, H, D]
    g_sc = jnp.take(scores_src, idx, axis=0)      # [N_dst, K, H]
    logits = g_sc + scores_dst[:, None, :]        # [N_dst, K, H]
    logits = jnp.where(logits >= 0, logits, neg_slope * logits)
    neg_inf = jnp.asarray(-1e30, logits.dtype)
    logits = jnp.where(mask[..., None] > 0, logits, neg_inf)
    logits = logits - jnp.max(logits, axis=1, keepdims=True)
    ex = jnp.exp(logits) * mask[..., None]
    denom = jnp.maximum(jnp.sum(ex, axis=1, keepdims=True), 1e-20)
    alpha = ex / denom                            # [N_dst, K, H]
    return jnp.sum(alpha[..., None] * g_feats, axis=1)


def rgcn_agg_ref(feats, idx, mask, rel, num_rels):
    """Per-relation masked mean aggregation (RGCN).

    feats: [N_src, F]; idx: [N_dst, K] int32; mask: [N_dst, K] float;
    rel:   [N_dst, K] int32 relation id of each edge
    returns [N_dst, R, F]: for each relation r, mean of neighbors via r-edges
    """
    gathered = jnp.take(feats, idx, axis=0)                   # [N_dst, K, F]
    # sel[n, k, r] = mask * 1[rel == r]
    sel = (rel[..., None] == jnp.arange(num_rels)[None, None, :]).astype(
        feats.dtype
    ) * mask[..., None]
    s = jnp.einsum("nkf,nkr->nrf", gathered, sel)
    cnt = jnp.maximum(jnp.sum(sel, axis=1), 1.0)              # [N_dst, R]
    return s / cnt[..., None]
