# L1: Pallas kernels for the paper's compute hot-spots (neighbor
# aggregation variants + fused SAGE linear). Each has a pure-jnp oracle in
# ref.py; pytest asserts allclose across shape sweeps.

from .gat_attn import gat_attn
from .rgcn_agg import rgcn_agg
from .sage_matmul import sage_matmul
from .seg_mean import seg_mean

__all__ = ["seg_mean", "sage_matmul", "gat_attn", "rgcn_agg"]
