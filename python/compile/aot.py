# AOT lowering: trace each model variant once, dump HLO TEXT + initial
# params + manifest under artifacts/.
#
# HLO *text* (NOT lowered.compile()/.serialize()) is the interchange format:
# jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
# crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
# parser on the Rust side reassigns ids, so text round-trips cleanly. See
# /opt/xla-example/README.md.
#
# Usage:  python -m compile.aot --out-dir ../artifacts [--variants a,b,...]
#
# Python runs ONLY here (and in pytest); the Rust binary is self-contained
# once artifacts/ exists.

import argparse
import os
from typing import List

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(
        shape, {"f32": np.float32, "i32": np.int32}[dtype]
    )


def lower_variant(cfg: M.ShapeConfig, out_dir: str) -> dict:
    params = M.init_params(cfg)
    param_specs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]

    train_step, _ = M.make_train_step(cfg)
    train_specs = param_specs + [
        _spec(s, d) for (_, s, d) in cfg.input_specs(train=True)
    ]
    train_hlo = to_hlo_text(jax.jit(train_step).lower(*train_specs))

    eval_step, _ = M.make_eval_step(cfg)
    eval_specs = param_specs + [
        _spec(s, d) for (_, s, d) in cfg.input_specs(train=False)
    ]
    eval_hlo = to_hlo_text(jax.jit(eval_step).lower(*eval_specs))

    entry = M.manifest_entry(cfg)
    with open(os.path.join(out_dir, entry["train_hlo"]), "w") as f:
        f.write(train_hlo)
    with open(os.path.join(out_dir, entry["eval_hlo"]), "w") as f:
        f.write(eval_hlo)
    # params.bin: flat little-endian f32 concatenation in manifest order
    with open(os.path.join(out_dir, entry["params_bin"]), "wb") as f:
        for p in params:
            f.write(np.ascontiguousarray(p, dtype=np.float32).tobytes())
    return entry


def main(argv: List[str] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", default=",".join(M.DEFAULT_VARIANTS),
                    help="comma-separated variant names, or 'all'")
    args = ap.parse_args(argv)

    names = (list(M.VARIANTS) if args.variants == "all"
             else [v for v in args.variants.split(",") if v])
    os.makedirs(args.out_dir, exist_ok=True)
    for name in names:
        cfg = M.VARIANTS[name]
        entry = lower_variant(cfg, args.out_dir)
        print(f"lowered {name}: layer_nodes={entry['layer_nodes']} "
              f"params={len(entry['param_shapes'])}")
    # manifest covers every variant lowered into this directory so far
    existing = set(names)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    if os.path.exists(manifest_path):
        import json
        with open(manifest_path) as f:
            old = json.load(f).get("variants", {})
        for k in old:
            if k in M.VARIANTS and os.path.exists(
                os.path.join(args.out_dir, f"{k}.train.hlo.txt")
            ):
                existing.add(k)
    M.write_manifest(manifest_path, sorted(existing))
    print(f"manifest: {manifest_path} ({len(existing)} variants)")


if __name__ == "__main__":
    main()
