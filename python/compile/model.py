# L2: JAX compute graphs for the paper's GNN workloads.
#
# GraphSAGE / GAT / RGCN forward + backward + fused SGD update, expressed
# over the padded mini-batch block contract shared with the Rust coordinator
# (DESIGN.md §5). All neighbor aggregation goes through the L1 Pallas
# kernels. These functions are traced once by aot.py and lowered to HLO
# text; Python never runs at training time.
#
# Block contract (one mini-batch, L layers):
#   feats          f32[n0, F]      input features for layer-0 nodes
#   per layer l=1..L:
#     self_idx_l   i32[n_l]        position of each dst node in layer-(l-1)
#     nbr_idx_l    i32[n_l, K_l]   neighbor positions into layer-(l-1)
#     nbr_mask_l   f32[n_l, K_l]   1.0 = real neighbor, 0.0 = padding
#     rel_l        i32[n_l, K_l]   (RGCN only) relation id per edge
#   node classification: labels i32[nL], label_mask f32[nL]
#   link prediction: nL = 3*B rows laid out [heads | tails | negatives],
#                    pair_mask f32[B]
#   lr             f32[]           SGD learning rate
#
# train_step returns (*updated_params, loss); eval returns (logits,) or
# (embeddings,).

import dataclasses
import json
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import gat_attn, rgcn_agg, sage_matmul, seg_mean

BLOCK = 128  # padding quantum: every node-array length is a multiple of this


def ceil_block(n: int) -> int:
    return ((n + BLOCK - 1) // BLOCK) * BLOCK


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """Static shape schedule of one model variant (one HLO artifact pair)."""

    name: str
    model: str                 # "sage" | "gat" | "rgcn"
    task: str                  # "nc" (node classification) | "lp" (link pred)
    batch: int                 # target nodes (nc) or edges (lp) per step
    fanouts: List[int]         # K_l, layer 1 (input-side) .. layer L
    feat_dim: int
    hidden: int
    num_classes: int
    num_heads: int = 2         # GAT
    num_rels: int = 3          # RGCN
    dedup: float = 0.6         # expected unique-node shrink factor per hop
                               # (intra-batch locality, paper §5.2)

    @property
    def num_layers(self) -> int:
        return len(self.fanouts)

    @property
    def layer_nodes(self) -> List[int]:
        """[n0, n1, ..., nL] — padded node-array length per layer."""
        l = self.num_layers
        n = [0] * (l + 1)
        base = self.batch if self.task == "nc" else 3 * self.batch
        n[l] = ceil_block(base)
        for i in range(l, 0, -1):
            fan = self.fanouts[i - 1]
            n[i - 1] = ceil_block(int(n[i] * (1 + fan) * self.dedup))
        return n

    def input_specs(self, train: bool):
        """Ordered (name, shape, dtype) for the non-param inputs.

        Eval (train=False) carries only feats + layer arrays: labels/masks
        are unused by the forward pass, and jax.jit prunes unused
        parameters from the lowered HLO — the manifest must match the
        compiled signature exactly.
        """
        n = self.layer_nodes
        specs = [("feats", (n[0], self.feat_dim), "f32")]
        for l in range(1, self.num_layers + 1):
            k = self.fanouts[l - 1]
            specs.append((f"self_idx_{l}", (n[l],), "i32"))
            specs.append((f"nbr_idx_{l}", (n[l], k), "i32"))
            specs.append((f"nbr_mask_{l}", (n[l], k), "f32"))
            if self.model == "rgcn":
                specs.append((f"rel_{l}", (n[l], k), "i32"))
        if train:
            if self.task == "nc":
                specs.append(("labels", (n[-1],), "i32"))
                specs.append(("label_mask", (n[-1],), "f32"))
            else:
                specs.append(("pair_mask", (self.batch,), "f32"))
            specs.append(("lr", (), "f32"))
        return specs


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------

def _glorot(rng, shape):
    fan_in, fan_out = shape[-2], shape[-1]
    scale = np.sqrt(6.0 / (fan_in + fan_out))
    return rng.uniform(-scale, scale, size=shape).astype(np.float32)


def init_params(cfg: ShapeConfig, seed: int = 0) -> List[np.ndarray]:
    """Deterministic parameter init; order must match _forward consumption."""
    rng = np.random.default_rng(seed)
    dims = [cfg.feat_dim] + [cfg.hidden] * (cfg.num_layers - 1)
    out_dims = [cfg.hidden] * (cfg.num_layers - 1) + [
        cfg.num_classes if cfg.task == "nc" else cfg.hidden
    ]
    params: List[np.ndarray] = []
    for f_in, f_out in zip(dims, out_dims):
        if cfg.model == "sage":
            params += [
                _glorot(rng, (f_in, f_out)),            # W_self
                _glorot(rng, (f_in, f_out)),            # W_neigh
                np.zeros((f_out,), np.float32),          # b
            ]
        elif cfg.model == "gat":
            h, d = cfg.num_heads, max(f_out // cfg.num_heads, 1)
            params += [
                _glorot(rng, (f_in, h * d)),             # W proj
                _glorot(rng, (h, d)),                    # a_src
                _glorot(rng, (h, d)),                    # a_dst
                np.zeros((h * d,), np.float32),          # b
                _glorot(rng, (h * d, f_out)),            # W out (head merge)
            ]
        elif cfg.model == "rgcn":
            params += [
                _glorot(rng, (cfg.num_rels, f_in, f_out)),  # W_rel
                _glorot(rng, (f_in, f_out)),                # W_self
                np.zeros((f_out,), np.float32),              # b
            ]
        else:
            raise ValueError(cfg.model)
    return params


def params_per_layer(model: str) -> int:
    return {"sage": 3, "gat": 5, "rgcn": 3}[model]


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _layer_inputs(cfg: ShapeConfig, blocks: List, l: int):
    """Pull (self_idx, nbr_idx, nbr_mask[, rel]) of layer l from flat list."""
    per = 4 if cfg.model == "rgcn" else 3
    base = (l - 1) * per
    return blocks[base:base + per]


def _forward(cfg: ShapeConfig, params: List, feats, blocks: List):
    """Shared multi-layer forward; returns final node array [nL, out_dim]."""
    per = params_per_layer(cfg.model)
    h = feats
    for l in range(1, cfg.num_layers + 1):
        layer_p = params[(l - 1) * per:l * per]
        last = l == cfg.num_layers
        if cfg.model == "sage":
            self_idx, nbr_idx, nbr_mask = _layer_inputs(cfg, blocks, l)
            w_s, w_n, b = layer_p
            h_self = jnp.take(h, self_idx, axis=0)
            h_agg = seg_mean(h, nbr_idx, nbr_mask)
            h = sage_matmul(h_self, h_agg, w_s, w_n, b)
        elif cfg.model == "gat":
            self_idx, nbr_idx, nbr_mask = _layer_inputs(cfg, blocks, l)
            w, a_src, a_dst, b, w_out = layer_p
            hd = a_src.shape[0] * a_src.shape[1]
            proj = (h @ w).reshape(h.shape[0], a_src.shape[0], a_src.shape[1])
            s_src = jnp.einsum("nhd,hd->nh", proj, a_src)
            proj_dst = jnp.take(proj, self_idx, axis=0)
            s_dst = jnp.einsum("nhd,hd->nh", proj_dst, a_dst)
            att = gat_attn(proj, s_src, s_dst, nbr_idx, nbr_mask,
                           num_heads=cfg.num_heads)
            merged = jax.nn.elu(att.reshape(att.shape[0], hd) + b)
            h = merged @ w_out
        else:  # rgcn
            self_idx, nbr_idx, nbr_mask, rel = _layer_inputs(cfg, blocks, l)
            w_rel, w_self, b = layer_p
            h_self = jnp.take(h, self_idx, axis=0)
            agg = rgcn_agg(h, nbr_idx, nbr_mask, rel, num_rels=cfg.num_rels)
            h = jnp.einsum("nrf,rfo->no", agg, w_rel) + h_self @ w_self + b
        if not last:
            h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def _nc_loss(logits, labels, label_mask):
    """Masked softmax cross-entropy, mean over real rows."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logz, labels[:, None], axis=-1)[:, 0]
    denom = jnp.maximum(jnp.sum(label_mask), 1.0)
    return -jnp.sum(ll * label_mask) / denom


def _lp_loss(emb, pair_mask, batch):
    """Dot-product BCE over rows laid out [heads | tails | negatives]."""
    heads = emb[:batch]
    tails = emb[batch:2 * batch]
    negs = emb[2 * batch:3 * batch]
    pos = jnp.sum(heads * tails, axis=-1)
    neg = jnp.sum(heads * negs, axis=-1)
    loss = jax.nn.softplus(-pos) + jax.nn.softplus(neg)
    denom = jnp.maximum(jnp.sum(pair_mask), 1.0)
    return jnp.sum(loss * pair_mask) / denom


# ---------------------------------------------------------------------------
# Steps (the functions that get lowered to HLO)
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: ShapeConfig):
    n_params = params_per_layer(cfg.model) * cfg.num_layers

    def loss_fn(params, feats, blocks, task_args):
        out = _forward(cfg, params, feats, blocks)
        if cfg.task == "nc":
            labels, label_mask = task_args
            return _nc_loss(out, labels, label_mask)
        (pair_mask,) = task_args
        return _lp_loss(out, pair_mask, cfg.batch)

    return loss_fn, n_params


def make_train_step(cfg: ShapeConfig):
    """flat-args train step: (*params, *inputs, lr) -> (*params', loss)."""
    loss_fn, n_params = make_loss_fn(cfg)
    n_task = 2 if cfg.task == "nc" else 1

    def step(*args):
        params = list(args[:n_params])
        rest = args[n_params:]
        feats = rest[0]
        blocks = list(rest[1:len(rest) - n_task - 1])
        task_args = rest[len(rest) - n_task - 1:len(rest) - 1]
        lr = rest[-1]
        loss, grads = jax.value_and_grad(loss_fn)(
            params, feats, blocks, task_args
        )
        new_params = [p - lr * g for p, g in zip(params, grads)]
        return tuple(new_params) + (loss,)

    return step, n_params


def make_eval_step(cfg: ShapeConfig):
    """flat-args eval: (*params, feats, *blocks) -> (out,)."""
    _, n_params = make_loss_fn(cfg)

    def step(*args):
        params = list(args[:n_params])
        rest = args[n_params:]
        feats = rest[0]
        blocks = list(rest[1:])
        return (_forward(cfg, params, feats, blocks),)

    return step, n_params


# ---------------------------------------------------------------------------
# Variant registry — the artifact set Rust knows about (artifacts/manifest.json)
# ---------------------------------------------------------------------------

VARIANTS = {
    # dev profile: fast to lower + execute; used by unit/integration tests
    "sage_nc_dev": ShapeConfig("sage_nc_dev", "sage", "nc", batch=128,
                               fanouts=[5, 5], feat_dim=32, hidden=64,
                               num_classes=16),
    "sage_lp_dev": ShapeConfig("sage_lp_dev", "sage", "lp", batch=64,
                               fanouts=[5, 5], feat_dim=32, hidden=64,
                               num_classes=0),
    "gat_nc_dev": ShapeConfig("gat_nc_dev", "gat", "nc", batch=128,
                              fanouts=[5, 5], feat_dim=32, hidden=64,
                              num_classes=16, num_heads=2),
    "rgcn_nc_dev": ShapeConfig("rgcn_nc_dev", "rgcn", "nc", batch=128,
                               fanouts=[5, 5], feat_dim=32, hidden=64,
                               num_classes=16, num_rels=3),
    # mag-lsc-shaped RGCN: 4 relations matching the typed mag-lsc
    # generator (DatasetSpec::with_mag_types); the dev shape otherwise
    "rgcn_nc_mag": ShapeConfig("rgcn_nc_mag", "rgcn", "nc", batch=128,
                               fanouts=[5, 5], feat_dim=32, hidden=64,
                               num_classes=16, num_rels=4),
    # paper-shaped profile (§6): 3 layers, fanout 15/10/5 — batch scaled so
    # CPU-interpret execution stays tractable on this testbed
    "sage_nc_paper": ShapeConfig("sage_nc_paper", "sage", "nc", batch=128,
                                 fanouts=[15, 10, 5], feat_dim=100,
                                 hidden=256, num_classes=47, dedup=0.25),
    # Fig 2 full-graph baseline: large batch + wide fanout caps so every
    # neighbor fits (the generator takes full neighborhoods, no sampling)
    "sage_nc_full": ShapeConfig("sage_nc_full", "sage", "nc", batch=256,
                                fanouts=[12, 12], feat_dim=32, hidden=64,
                                num_classes=16),
    # Fig 1 hidden-size sweep
    "sage_nc_h16": ShapeConfig("sage_nc_h16", "sage", "nc", batch=128,
                               fanouts=[5, 5], feat_dim=32, hidden=16,
                               num_classes=16),
    "sage_nc_h32": ShapeConfig("sage_nc_h32", "sage", "nc", batch=128,
                               fanouts=[5, 5], feat_dim=32, hidden=32,
                               num_classes=16),
    "sage_nc_h128": ShapeConfig("sage_nc_h128", "sage", "nc", batch=128,
                                fanouts=[5, 5], feat_dim=32, hidden=128,
                                num_classes=16),
    "sage_nc_h256": ShapeConfig("sage_nc_h256", "sage", "nc", batch=128,
                                fanouts=[5, 5], feat_dim=32, hidden=256,
                                num_classes=16),
}

# Artifacts lowered by default (`make artifacts`); benches may request more.
DEFAULT_VARIANTS = [
    "sage_nc_dev", "sage_lp_dev", "gat_nc_dev", "rgcn_nc_dev",
    "rgcn_nc_mag",
]


def manifest_entry(cfg: ShapeConfig) -> dict:
    params = init_params(cfg)
    return {
        "name": cfg.name,
        "model": cfg.model,
        "task": cfg.task,
        "batch": cfg.batch,
        "fanouts": cfg.fanouts,
        "feat_dim": cfg.feat_dim,
        "hidden": cfg.hidden,
        "num_classes": cfg.num_classes,
        "num_heads": cfg.num_heads,
        "num_rels": cfg.num_rels,
        "layer_nodes": cfg.layer_nodes,
        "param_shapes": [list(p.shape) for p in params],
        "train_inputs": [
            {"name": n, "shape": list(s), "dtype": d}
            for (n, s, d) in cfg.input_specs(train=True)
        ],
        "eval_inputs": [
            {"name": n, "shape": list(s), "dtype": d}
            for (n, s, d) in cfg.input_specs(train=False)
        ],
        "train_hlo": f"{cfg.name}.train.hlo.txt",
        "eval_hlo": f"{cfg.name}.eval.hlo.txt",
        "params_bin": f"{cfg.name}.params.bin",
    }


def write_manifest(path: str, names: List[str]) -> None:
    entries = {n: manifest_entry(VARIANTS[n]) for n in names}
    with open(path, "w") as f:
        json.dump({"block": BLOCK, "variants": entries}, f, indent=1)
