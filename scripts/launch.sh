#!/usr/bin/env sh
# Multi-process equivalence smoke (docs/DESIGN.md §11): run the same
# config once over the in-process backend and once as N real OS
# processes talking TCP on localhost, then require byte-identical
# MACHINE_RESULT lines (batch-stream hashes, parameter hash, losses)
# from both runs, and a decreasing loss.
#
# With --chaos (docs/DESIGN.md §12) the run also kills machine 1
# abruptly just before the epoch-0 barrier and restarts it with
# --chaos-resume: the restarted process reclaims its machine id at the
# rendezvous, re-imports its KV shard from the standby's replica
# tables over RPC, replays epoch 0 locally, and finishes the run over
# TCP — and its MACHINE_RESULT lines must STILL match the fault-free
# in-process reference byte for byte.
#
# Usage: scripts/launch.sh [machines] [trainers_per_machine] [--chaos]
set -eu

CHAOS=0
POS1=""
POS2=""
for a in "$@"; do
    if [ "$a" = "--chaos" ]; then
        CHAOS=1
    elif [ -z "$POS1" ]; then
        POS1="$a"
    elif [ -z "$POS2" ]; then
        POS2="$a"
    fi
done
MACHINES="${POS1:-2}"
TRAINERS="${POS2:-1}"
PORT_BASE="${PORT_BASE:-$((20000 + $$ % 20000))}"
VICTIM=1

if [ "$CHAOS" -eq 1 ] && [ "$MACHINES" -lt 2 ]; then
    echo "FAIL: --chaos needs at least 2 machines" >&2
    exit 1
fi

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "SKIP: no cargo toolchain on PATH — multi-process smoke" \
         "not run here (CI's 'multi-process' job runs it)." >&2
    exit 0
fi

# bare checkout: generate the same minimal manifest as verify.sh
if [ ! -f Cargo.toml ]; then
    cat > Cargo.toml <<'EOF'
[package]
name = "distdglv2"
version = "0.1.0"
edition = "2021"

[dependencies]
anyhow = "1"
rustc-hash = "2"
xla = "0.1"

[lib]
path = "src/lib.rs"
EOF
    for b in benches/*.rs; do
        name=$(basename "$b" .rs)
        cat >> Cargo.toml <<EOF

[[bench]]
name = "$name"
harness = false
EOF
    done
    echo "generated rust/Cargo.toml (bare checkout)"
fi
# the launcher lives outside rust/, so cargo needs an explicit entry
if ! grep -q 'name = "launch"' Cargo.toml; then
    cat >> Cargo.toml <<'EOF'

[[example]]
name = "launch"
path = "../examples/launch.rs"
EOF
fi

WORK=$(mktemp -d)
trap 'rm -rf "$WORK"; kill 0 2>/dev/null || true' EXIT INT TERM

# chaos runs replicate the KV shards (the restart re-imports from the
# replica) and train one extra epoch so the restarted process has two
# over-the-wire epochs after its local epoch-0 replay
EPOCHS=2
REPLICATE=0
if [ "$CHAOS" -eq 1 ]; then
    EPOCHS=3
    REPLICATE=1
fi

cat > "$WORK/run.cfg" <<EOF
# launch.sh smoke config — small deterministic RMAT graph
dataset=rmat:4000:16000
machines=$MACHINES
trainers=$TRAINERS
epochs=$EPOCHS
lr=0.3
seed=7
replicate_kv=$REPLICATE
EOF

cargo build --release --example launch

BIN=target/release/examples/launch

echo "== reference: in-process backend =="
"$BIN" "$WORK/run.cfg" --inproc | tee "$WORK/inproc.log"

if [ "$CHAOS" -eq 1 ]; then
    echo "== $MACHINES OS processes over TCP + kill/restart of" \
         "machine $VICTIM (port base $PORT_BASE) =="
else
    echo "== $MACHINES OS processes over TCP (port base $PORT_BASE) =="
fi
m=0
while [ "$m" -lt "$MACHINES" ]; do
    FLAG=""
    if [ "$CHAOS" -eq 1 ]; then
        if [ "$m" -eq "$VICTIM" ]; then
            FLAG="--chaos-exit"
        else
            FLAG="--chaos"
        fi
    fi
    # shellcheck disable=SC2086
    "$BIN" "$WORK/run.cfg" --machine "$m" --port-base "$PORT_BASE" \
        $FLAG > "$WORK/proc$m.log" 2>&1 &
    eval "PID$m=$!"
    m=$((m + 1))
done

if [ "$CHAOS" -eq 1 ]; then
    # first life: the victim exits 0 just before the epoch-0 barrier
    eval "vpid=\$PID$VICTIM"
    if ! wait "$vpid"; then
        echo "FAIL: chaos victim's first life exited nonzero" >&2
        cat "$WORK/proc$VICTIM.log" >&2
        exit 1
    fi
    if ! grep -q "^CHAOS_EXIT m=$VICTIM" "$WORK/proc$VICTIM.log"; then
        echo "FAIL: victim did not reach its chaos exit point" >&2
        cat "$WORK/proc$VICTIM.log" >&2
        exit 1
    fi
    mv "$WORK/proc$VICTIM.log" "$WORK/chaos-exit.log"
    # second life: reclaim the machine id, re-import the shard from
    # the standby's replica, replay epoch 0 locally, finish over TCP
    "$BIN" "$WORK/run.cfg" --machine "$VICTIM" \
        --port-base "$PORT_BASE" --chaos-resume \
        > "$WORK/proc$VICTIM.log" 2>&1 &
    eval "PID$VICTIM=$!"
fi
m=0
while [ "$m" -lt "$MACHINES" ]; do
    eval "pid=\$PID$m"
    if ! wait "$pid"; then
        echo "FAIL: machine process $m exited nonzero" >&2
        cat "$WORK/proc$m.log" >&2
        exit 1
    fi
    m=$((m + 1))
done
cat "$WORK"/proc*.log

# every machine's result line must match the in-process reference
# verbatim: same batch streams, same all-reduced params, same losses
grep '^MACHINE_RESULT' "$WORK/inproc.log" | sort > "$WORK/inproc.res"
grep -h '^MACHINE_RESULT' "$WORK"/proc*.log | sort > "$WORK/tcp.res"
if ! diff -u "$WORK/inproc.res" "$WORK/tcp.res"; then
    echo "FAIL: TCP run diverged from the in-process reference" >&2
    exit 1
fi

# all processes converged on one parameter vector
NHASH=$(sed 's/.*param_hash=\([0-9a-f]*\).*/\1/' "$WORK/tcp.res" \
    | sort -u | wc -l)
if [ "$NHASH" -ne 1 ]; then
    echo "FAIL: processes ended with different params" >&2
    exit 1
fi

# the smoke actually learned something (launch also asserts this)
grep -q '^LAUNCH OK$' "$WORK/inproc.log"
grep -q 'LAUNCH OK' "$WORK"/proc*.log

if [ "$CHAOS" -eq 1 ]; then
    # the restarted victim really took the recovery path: shard
    # re-imported from the standby's replica tables, epoch 0 replayed
    grep -q "^CHAOS_REIMPORT m=$VICTIM" "$WORK/proc$VICTIM.log"
    grep -q "^CHAOS_REPLAY m=$VICTIM" "$WORK/proc$VICTIM.log"
    echo "chaos smoke passed: machine $VICTIM killed after epoch 0," \
         "restarted, and the run still matched the fault-free" \
         "reference byte for byte"
else
    echo "multi-process smoke passed:" \
         "$MACHINES procs x $TRAINERS trainers == in-process run"
fi
