#!/usr/bin/env sh
# Multi-process equivalence smoke (docs/DESIGN.md §11): run the same
# config once over the in-process backend and once as N real OS
# processes talking TCP on localhost, then require byte-identical
# MACHINE_RESULT lines (batch-stream hashes, parameter hash, losses)
# from both runs, and a decreasing loss.
#
# Usage: scripts/launch.sh [machines] [trainers_per_machine]
set -eu

MACHINES="${1:-2}"
TRAINERS="${2:-1}"
PORT_BASE="${PORT_BASE:-$((20000 + $$ % 20000))}"

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "SKIP: no cargo toolchain on PATH — multi-process smoke" \
         "not run here (CI's 'multi-process' job runs it)." >&2
    exit 0
fi

# bare checkout: generate the same minimal manifest as verify.sh
if [ ! -f Cargo.toml ]; then
    cat > Cargo.toml <<'EOF'
[package]
name = "distdglv2"
version = "0.1.0"
edition = "2021"

[dependencies]
anyhow = "1"
rustc-hash = "2"
xla = "0.1"

[lib]
path = "src/lib.rs"
EOF
    for b in benches/*.rs; do
        name=$(basename "$b" .rs)
        cat >> Cargo.toml <<EOF

[[bench]]
name = "$name"
harness = false
EOF
    done
    echo "generated rust/Cargo.toml (bare checkout)"
fi
# the launcher lives outside rust/, so cargo needs an explicit entry
if ! grep -q 'name = "launch"' Cargo.toml; then
    cat >> Cargo.toml <<'EOF'

[[example]]
name = "launch"
path = "../examples/launch.rs"
EOF
fi

WORK=$(mktemp -d)
trap 'rm -rf "$WORK"; kill 0 2>/dev/null || true' EXIT INT TERM

cat > "$WORK/run.cfg" <<EOF
# launch.sh smoke config — small deterministic RMAT graph
dataset=rmat:4000:16000
machines=$MACHINES
trainers=$TRAINERS
epochs=2
lr=0.3
seed=7
EOF

cargo build --release --example launch

BIN=target/release/examples/launch

echo "== reference: in-process backend =="
"$BIN" "$WORK/run.cfg" --inproc | tee "$WORK/inproc.log"

echo "== $MACHINES OS processes over TCP (port base $PORT_BASE) =="
m=0
while [ "$m" -lt "$MACHINES" ]; do
    "$BIN" "$WORK/run.cfg" --machine "$m" --port-base "$PORT_BASE" \
        > "$WORK/proc$m.log" 2>&1 &
    eval "PID$m=$!"
    m=$((m + 1))
done
m=0
while [ "$m" -lt "$MACHINES" ]; do
    eval "pid=\$PID$m"
    if ! wait "$pid"; then
        echo "FAIL: machine process $m exited nonzero" >&2
        cat "$WORK/proc$m.log" >&2
        exit 1
    fi
    m=$((m + 1))
done
cat "$WORK"/proc*.log

# every machine's result line must match the in-process reference
# verbatim: same batch streams, same all-reduced params, same losses
grep '^MACHINE_RESULT' "$WORK/inproc.log" | sort > "$WORK/inproc.res"
grep -h '^MACHINE_RESULT' "$WORK"/proc*.log | sort > "$WORK/tcp.res"
if ! diff -u "$WORK/inproc.res" "$WORK/tcp.res"; then
    echo "FAIL: TCP run diverged from the in-process reference" >&2
    exit 1
fi

# all processes converged on one parameter vector
NHASH=$(sed 's/.*param_hash=\([0-9a-f]*\).*/\1/' "$WORK/tcp.res" \
    | sort -u | wc -l)
if [ "$NHASH" -ne 1 ]; then
    echo "FAIL: processes ended with different params" >&2
    exit 1
fi

# the smoke actually learned something (launch also asserts this)
grep -q '^LAUNCH OK$' "$WORK/inproc.log"
grep -q 'LAUNCH OK' "$WORK"/proc*.log

echo "multi-process smoke passed:" \
     "$MACHINES procs x $TRAINERS trainers == in-process run"
