#!/usr/bin/env sh
# Tier-1 verification gate, runnable anywhere: build the crate in
# release mode and run the full test suite — the same bar CI's `rust`
# job enforces (see .github/workflows/ci.yml). Mirrors CI's manifest
# fallback: the build harness normally supplies Cargo.toml (the xla
# dependency comes from the baked-in rust_pallas toolchain), so a bare
# checkout generates a minimal one.
#
# Environments without a Rust toolchain (e.g. authoring containers)
# skip with a clear message and exit 0 — the gate then runs in CI.
set -eu

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "SKIP: no cargo toolchain on PATH — tier-1 gate" \
         "(cargo build --release && cargo test -q) not run here." >&2
    echo "      CI's 'rust' job runs it on every push/PR;" \
         "locally, install Rust and re-run scripts/verify.sh." >&2
    exit 0
fi

if [ ! -f Cargo.toml ]; then
    cat > Cargo.toml <<'EOF'
[package]
name = "distdglv2"
version = "0.1.0"
edition = "2021"

[dependencies]
anyhow = "1"
rustc-hash = "2"
xla = "0.1"

[lib]
path = "src/lib.rs"
EOF
    # benches are plain main() harnesses (BenchRunner), not libtest
    for b in benches/*.rs; do
        name=$(basename "$b" .rs)
        cat >> Cargo.toml <<EOF

[[bench]]
name = "$name"
harness = false
EOF
    done
    # the multi-process launcher lives at the repo root, outside
    # rust/src — register it explicitly so `cargo build --example
    # launch` (and scripts/launch.sh) work from this manifest too
    cat >> Cargo.toml <<'EOF'

[[example]]
name = "launch"
path = "../examples/launch.rs"
EOF
    echo "generated rust/Cargo.toml (bare checkout)"
fi

echo "tier-1 gate: cargo build --release"
cargo build --release
echo "tier-1 gate: cargo test -q"
cargo test -q
echo "tier-1 gate passed"
