//! Table 2: time breakdown of the training pipeline on the
//! papers100M-shaped workload — partition (our ParMETIS role), load/save,
//! data loading for training, and training to convergence, for both tasks
//! (node classification with its small labeled set vs link prediction
//! with edge-scale training data).

use std::time::Instant;

use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::graph::DatasetSpec;
use distdglv2::graph::io::{load_graph, save_graph};
use distdglv2::runtime::manifest::artifacts_dir;
use distdglv2::trainer::{self, TrainConfig};

fn main() -> anyhow::Result<()> {
    let mut dspec = DatasetSpec::new("papers-s", 55_000, 320_000);
    dspec.feat_dim = 32;
    dspec.num_classes = 16;
    dspec.train_frac = 0.011; // papers100M: ~1% labeled
    let dataset = dspec.generate();

    // load/save: the partition-bundle IO the paper attributes 23 min to
    let dir = std::env::temp_dir().join("ddgl_tab02");
    std::fs::create_dir_all(&dir)?;
    let t = Instant::now();
    save_graph(&dataset.graph, &dir.join("g.bin"))?;
    let _g = load_graph(&dir.join("g.bin"))?;
    let io_secs = t.elapsed().as_secs_f64();
    std::fs::remove_file(dir.join("g.bin")).ok();

    // partition + deploy (partition/build/load timings collected inside)
    let cluster = Cluster::deploy(
        &dataset,
        ClusterSpec::new(4, 2),
        artifacts_dir(),
    )?;
    let s = cluster.stats.clone();

    // node classification training (small labeled set)
    let t = Instant::now();
    let nc = trainer::train(
        &cluster,
        &TrainConfig {
            variant: "sage_nc_dev".into(),
            lr: 0.3,
            epochs: 2,
            ..Default::default()
        },
    )?;
    let nc_secs = t.elapsed().as_secs_f64();

    // link prediction training (edge-scale training set → much longer)
    let cluster_lp = Cluster::deploy(
        &dataset,
        ClusterSpec::new(4, 2),
        artifacts_dir(),
    )?;
    let t = Instant::now();
    let lp = trainer::train(
        &cluster_lp,
        &TrainConfig {
            variant: "sage_lp_dev".into(),
            lr: 0.1,
            epochs: 1,
            max_steps: nc.steps * 4, // edge-scale set: bounded sample here
            ..Default::default()
        },
    )?;
    let lp_secs_sampled = t.elapsed().as_secs_f64();
    // extrapolate to the full edge set (the paper trains on ALL edges)
    let edges_total = dataset.graph.n_edges() / 2;
    let lp_steps_full = edges_total
        .div_ceil(64 * cluster_lp.n_trainers()); // lp batch=64 pairs
    let lp_secs_full =
        lp_secs_sampled / lp.steps.max(1) as f64 * lp_steps_full as f64;

    println!("=== Table 2 — time breakdown (papers100M-shaped, 4 machines) ===\n");
    println!(
        "{:<22} {:>12} {:>12} {:>12} {:>16}",
        "task", "partition", "load/save", "load(train)", "train"
    );
    println!(
        "{:<22} {:>11.2}s {:>11.2}s {:>11.2}s {:>15.2}s",
        "node classification",
        s.partition_secs + s.build_secs,
        io_secs,
        s.load_secs,
        nc_secs
    );
    println!(
        "{:<22} {:>11.2}s {:>11.2}s {:>11.2}s {:>15.2}s (extrapolated)",
        "link prediction",
        s.partition_secs + s.build_secs,
        io_secs,
        s.load_secs,
        lp_secs_full
    );
    println!(
        "\nshape checks (paper Table 2): partition is NOT the dominant \
         cost; nc training is short (tiny labeled set: {} nodes); lp \
         training dominates everything (edge-scale training set: {} \
         positive edges -> {} steps).",
        cluster.train_sets.iter().map(|s| s.len()).sum::<usize>(),
        edges_total,
        lp_steps_full,
    );
    println!(
        "paper: 12min partition / 23min load-save / 8min load / 4min nc \
         train vs 305min lp train."
    );
    println!(
        "\nlocality (nc): {}",
        distdglv2::benchsuite::locality_summary(&nc)
    );
    println!(
        "locality (lp): {}",
        distdglv2::benchsuite::locality_summary(&lp)
    );
    Ok(())
}
