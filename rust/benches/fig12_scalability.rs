//! Figure 12: scaling DistDGLv2 from 8 to 64 GPUs (papers100M-shaped
//! SAGE/GAT, mag-shaped RGCN; fixed per-trainer batch 1000).
//!
//! Method: a real 2-machine × 2-trainer protocol run calibrates unit costs
//! (per-edge sampling, remote-row fraction); the 8→64 GPU curve then comes
//! from the pipeline bound at paper shapes — steps per epoch shrink with
//! the trainer count while the cross-machine fraction and ring size grow.
//!
//! Expected shape (paper): ~20x (SAGE, CPU/network-bound) vs ~36x (GAT,
//! compute-bound) at 64 GPUs; RGCN doubles from 4→8 machines.

use distdglv2::benchsuite::{
    paper_spec, paper_stage_times, FigTable, NET_BYTES_PER_SEC,
    NET_LATENCY_S, SAMPLING_CPU_SCALE,
};
use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::graph::DatasetSpec;
use distdglv2::pipeline::PipelineMode;
use distdglv2::runtime::manifest::{artifacts_dir, Manifest};
use distdglv2::runtime::DeviceCostModel;
use distdglv2::sampler::compact::ModelKind;
use distdglv2::trainer::{self, TrainConfig};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&artifacts_dir())?;
    let mut dspec = DatasetSpec::new("papers-s", 48_000, 280_000);
    dspec.feat_dim = 32;
    dspec.num_classes = 16;
    dspec.train_frac = 0.15;
    let dataset = dspec.generate();
    let t4 = DeviceCostModel::t4();

    // (model label, measured variant, lr, paper model, feat, train items)
    let rows = [
        ("GraphSAGE/papers", "sage_nc_dev", 0.3f32, ModelKind::Sage, 128,
         1_200_000usize),
        ("GAT/papers", "gat_nc_dev", 0.5, ModelKind::Gat, 128, 1_200_000),
        ("RGCN/mag", "rgcn_nc_dev", 0.3, ModelKind::Rgcn, 136, 1_100_000),
    ];

    for (label, variant, lr, model, feat, train_items) in rows {
        let spec = manifest.variant(variant)?.clone();
        let pspec = paper_spec(model, feat);
        // measured protocol run
        let cluster = Cluster::deploy(
            &dataset,
            ClusterSpec::new(2, 2),
            artifacts_dir(),
        )?;
        let tcfg = TrainConfig {
            variant: variant.into(),
            lr,
            epochs: 1,
            max_steps: 6,
            ..Default::default()
        };
        let report = trainer::train(&cluster, &tcfg)?;
        let st0 = paper_stage_times(
            &report, &cluster, &spec, &pspec, &t4, SAMPLING_CPU_SCALE,
        );

        let mut table = FigTable::new(&format!(
            "Fig 12 — {label} (modeled epoch time, batch {} per trainer)",
            pspec.batch
        ));
        let mut t8 = None;
        for n_gpus in [8usize, 16, 32, 64] {
            let machines = (n_gpus / 8).max(1);
            let steps =
                train_items.div_ceil(pspec.batch * n_gpus).max(1);
            let mut s = st0;
            // cross-machine fraction grows with machine count
            let base_remote = 0.5; // calibration run had 2 machines
            s.net *= if machines <= 1 {
                0.15 / base_remote // mostly-local halo pulls
            } else {
                (1.0 - 1.0 / machines as f64) / base_remote
            };
            // ring all-reduce grows with participants
            let n = n_gpus as f64;
            s.allreduce = 2.0 * (n - 1.0) / n
                * (pspec.param_elements() as f64 * 4.0)
                / NET_BYTES_PER_SEC
                + 2.0 * (n - 1.0) * NET_LATENCY_S;
            let epoch =
                s.step(PipelineMode::AsyncNonstop) * steps as f64;
            table.row(
                &format!("{n_gpus} GPUs ({machines} machines)"),
                f64::NAN,
                epoch,
            );
            let t8v = *t8.get_or_insert(epoch);
            println!(
                "    -> {steps} steps/epoch, speedup vs 8 GPUs: {:.1}x \
                 (ideal {:.0}x)",
                t8v / epoch,
                n / 8.0
            );
        }
        println!(
            "  calibration: sample/step {:.2}ms (paper-shape, /{:.0} CPU \
             scale), device/step {:.2}ms, net/step {:.2}ms",
            st0.sample * 1e3,
            SAMPLING_CPU_SCALE,
            st0.device * 1e3,
            st0.net * 1e3,
        );
    }
    println!(
        "\npaper reference: ~20x (SAGE) / ~36x (GAT) at 64 GPUs; RGCN 2x \
         from 4 to 8 machines; SAGE sub-linear from CPU+network saturation."
    );
    Ok(())
}
