//! Figure 13: convergence of DistDGLv2 vs ClusterGCN on the papers-shaped
//! workload (validation accuracy over epochs).
//!
//! ClusterGCN trains on induced subgraphs of sampled clusters and *drops*
//! cross-cluster edges, biasing neighbor aggregation by the partitioning;
//! DistDGLv2 always samples neighbors from the full graph, so its
//! gradient estimate stays unbiased (§6.3).
//!
//! Expected shape (paper): ClusterGCN converges slower and plateaus below
//! DistDGLv2's accuracy.

use std::sync::Arc;

use distdglv2::baselines::ClusterGcnGen;
use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::graph::DatasetSpec;
use distdglv2::runtime::manifest::{artifacts_dir, Manifest};
use distdglv2::trainer::{self, DeviceExecutor, TrainConfig};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&artifacts_dir())?;
    let vspec = manifest.variant("sage_nc_dev")?.clone();

    let mut dspec = DatasetSpec::new("papers-s", 20_000, 120_000);
    dspec.feat_dim = 32;
    dspec.num_classes = 16;
    dspec.train_frac = 0.2;
    let dataset = Arc::new(dspec.generate());

    let rounds = 6usize; // accuracy checkpoints
    let steps_per_round = 10usize;

    // ---- DistDGLv2: full distributed stack -----------------------------
    println!("=== Fig 13 — convergence: DistDGLv2 vs ClusterGCN ===");
    println!("{:<10} {:>18} {:>18}", "steps", "DistDGLv2 acc", "ClusterGCN acc");
    let cluster = Cluster::deploy(
        &dataset,
        ClusterSpec::new(2, 2),
        artifacts_dir(),
    )?;
    let mut v2_acc = Vec::new();
    {
        // run in increments, carrying accuracy per round via eval
        for r in 1..=rounds {
            let cfg = TrainConfig {
                variant: "sage_nc_dev".into(),
                lr: 0.3,
                epochs: 1,
                max_steps: r * steps_per_round,
                eval_each_epoch: true,
                seed: 7, // same stream each time: prefix-equal trajectories
                ..Default::default()
            };
            let report = trainer::train(&cluster, &cfg)?;
            v2_acc.push(report.final_val_acc.unwrap_or(f64::NAN));
        }
    }

    // ---- ClusterGCN: partition-as-minibatch ----------------------------
    // 64 clusters (paper uses 16,384 on the full graph — same ratio of
    // cluster size to batch), 2 clusters per batch.
    let device = DeviceExecutor::spawn(
        artifacts_dir(),
        "sage_nc_dev".into(),
        None,
    )?;
    let mut params = device.initial_params()?;
    let handle = device.handle();
    let mut gen = ClusterGcnGen::new(
        dataset.clone(),
        vspec.shape_spec(),
        64,
        2,
        9,
    );
    println!(
        "(ClusterGCN edge retention: {:.2} — fraction of edges surviving \
         the cluster restriction)",
        gen.edge_retention()
    );
    let mut cg_acc = Vec::new();
    let val = dataset.nodes_with(distdglv2::graph::SplitTag::Val);
    for _r in 1..=rounds {
        for _ in 0..steps_per_round {
            let batch = gen.next();
            handle.train(&mut params, batch, 0.3)?;
        }
        // eval: full-graph neighborhoods via the same generator machinery
        let mut correct = 0usize;
        let mut total = 0usize;
        let c = vspec.num_classes;
        let mut fg = distdglv2::baselines::FullGraphGen::new(
            dataset.clone(),
            vspec.shape_spec(),
        );
        let _ = &mut fg;
        for chunk in val.chunks(vspec.batch).take(4) {
            let hb = eval_batch(&dataset, &vspec, chunk);
            let logits = handle.eval(&params, hb)?;
            for (i, &gid) in chunk.iter().enumerate() {
                let row = &logits[i * c..(i + 1) * c];
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j as u16)
                    .unwrap();
                if argmax == dataset.labels[gid as usize] {
                    correct += 1;
                }
                total += 1;
            }
        }
        cg_acc.push(correct as f64 / total.max(1) as f64);
    }

    for r in 0..rounds {
        println!(
            "{:<10} {:>18.3} {:>18.3}",
            (r + 1) * steps_per_round,
            v2_acc[r],
            cg_acc[r]
        );
    }
    println!(
        "\npaper reference: ClusterGCN converges slower and below \
         DistDGLv2 (dropped cross-partition edges bias aggregation)."
    );
    Ok(())
}

/// Full-neighborhood eval batch for arbitrary target nodes.
fn eval_batch(
    dataset: &Arc<distdglv2::graph::Dataset>,
    vspec: &distdglv2::runtime::manifest::VariantSpec,
    targets: &[distdglv2::graph::NodeId],
) -> distdglv2::runtime::executable::HostBatch {
    use distdglv2::sampler::compact::to_block;
    use distdglv2::sampler::service::SampledNbrs;
    use rustc_hash_shim::FxHashSet;

    mod rustc_hash_shim {
        pub type FxHashSet<T> = std::collections::HashSet<T>;
    }

    let spec = vspec.shape_spec();
    let g = &dataset.graph;
    let l_total = spec.num_layers();
    let mut samples = Vec::with_capacity(l_total);
    let mut seeds: Vec<_> = targets.to_vec();
    for l in (1..=l_total).rev() {
        let k = spec.fanouts[l - 1];
        let cap = spec.layer_nodes[l - 1];
        let mut layer = Vec::with_capacity(seeds.len());
        let mut next = seeds.clone();
        let mut seen: FxHashSet<_> = seeds.iter().copied().collect();
        for &s in &seeds {
            let nbrs: Vec<_> =
                g.neighbors(s).iter().copied().take(k).collect();
            for &v in &nbrs {
                if !seen.contains(&v) && next.len() < cap {
                    seen.insert(v);
                    next.push(v);
                }
            }
            layer.push(SampledNbrs { nbrs, rels: Vec::new() });
        }
        samples.push((seeds, layer));
        seeds = next;
    }
    let block = to_block(&spec, &samples);
    let n0 = spec.layer_nodes[0];
    let f = spec.feat_dim;
    let mut feats = vec![0f32; n0 * f];
    for (i, &v) in block.input_nodes.iter().enumerate().take(n0) {
        feats[i * f..(i + 1) * f].copy_from_slice(dataset.feature(v));
    }
    let n_l = *spec.layer_nodes.last().unwrap();
    distdglv2::runtime::executable::HostBatch {
        feats,
        layers: block.layers,
        labels: vec![0; n_l],
        label_mask: vec![0.0; n_l],
        pair_mask: Vec::new(),
        targets: block.targets,
        input_nodes: block.input_nodes,
        remote_rows: 0,
        dropped_neighbors: block.dropped_neighbors,
    }
}
