//! Recovery-time bench (docs/PERF.md §Recovery): checkpoint cadence ×
//! failure-point grid. For each cadence the run checkpoints every N
//! steps; for each failure point we resume from the latest snapshot at
//! or before the failure and measure restore time, redo (replay) time,
//! and lost steps. Byte-identity of the replayed stream against the
//! no-checkpoint baseline is asserted on every grid cell — the bench
//! doubles as an end-to-end exact-resume check. Emits
//! `BENCH_recovery.json`. Requires `make artifacts`.

use std::time::Instant;

use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::ft::Checkpoint;
use distdglv2::graph::{Dataset, DatasetSpec};
use distdglv2::pipeline::PipelineMode;
use distdglv2::runtime::manifest::artifacts_dir;
use distdglv2::trainer::{self, TrainConfig};

const STEPS: usize = 12;

fn deploy(dataset: &Dataset) -> anyhow::Result<Cluster> {
    Cluster::deploy(dataset, ClusterSpec::new(2, 1), artifacts_dir())
}

fn base_cfg() -> TrainConfig {
    let mut cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        lr: 0.3,
        epochs: 1,
        max_steps: STEPS,
        seed: 29,
        ..Default::default()
    };
    // worst case for exact resume: deepest overlap, worker pool on
    cfg.pipeline.mode = PipelineMode::AsyncNonstop;
    cfg.pipeline.num_workers = 2;
    cfg
}

fn main() -> anyhow::Result<()> {
    let mut dspec = DatasetSpec::new("recovery-bench", 6000, 30_000);
    dspec.seed = 31;
    let dataset = dspec.generate();

    // no-checkpoint baseline: the stream every grid cell must replay
    let t = Instant::now();
    let baseline = trainer::train(&deploy(&dataset)?, &base_cfg())?;
    let base_secs = t.elapsed().as_secs_f64();
    println!(
        "baseline: {STEPS} steps in {base_secs:.3}s (no checkpoints)"
    );

    let dir = std::env::temp_dir().join("ddgl_bench_recovery");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    println!("\n=== recovery grid (cadence x failure point) ===");
    println!(
        "{:<8} {:>6} {:>7} {:>11} {:>9} {:>6}",
        "cadence", "fail@", "resume", "restore(s)", "redo(s)", "lost"
    );
    let mut rows: Vec<String> = Vec::new();
    for cadence in [1usize, 2, 4] {
        let cdir = dir.join(format!("cadence_{cadence}"));
        std::fs::create_dir_all(&cdir)?;
        let mut cfg = base_cfg();
        cfg.checkpoint_every = cadence;
        cfg.checkpoint_dir = cdir.to_string_lossy().into_owned();
        let t = Instant::now();
        let ckpt_run = trainer::train(&deploy(&dataset)?, &cfg)?;
        let ckpt_secs = t.elapsed().as_secs_f64();
        assert_eq!(
            ckpt_run.loss_curve, baseline.loss_curve,
            "checkpointing perturbed the training stream"
        );
        assert_eq!(ckpt_run.ft_checkpoints as usize, STEPS / cadence);
        println!(
            "cadence {cadence}: +{:.1}% wall overhead, {} B written",
            100.0 * (ckpt_secs / base_secs - 1.0),
            ckpt_run.ft_checkpoint_bytes,
        );

        for fail_step in [3usize, 7, 11] {
            // latest snapshot at or before the failure point
            let resume_step = fail_step / cadence * cadence;
            let (restore_secs, redo_secs) = if resume_step == 0 {
                // failed before the first snapshot: full restart
                (0.0, base_secs)
            } else {
                let mut rcfg = base_cfg();
                rcfg.resume_from =
                    Checkpoint::path_for(&cdir, resume_step as u64)
                        .to_string_lossy()
                        .into_owned();
                let t = Instant::now();
                let resumed = trainer::train(&deploy(&dataset)?, &rcfg)?;
                let redo = t.elapsed().as_secs_f64();
                assert_eq!(resumed.resumed_at, resume_step as u64);
                assert_eq!(resumed.steps, STEPS - resume_step);
                assert_eq!(
                    resumed.loss_curve,
                    baseline.loss_curve[resume_step..].to_vec(),
                    "resume from step {resume_step} diverged"
                );
                (resumed.ft_recovery_secs, redo)
            };
            let lost = fail_step - resume_step;
            println!(
                "{:<8} {:>6} {:>7} {:>11.4} {:>9.3} {:>6}",
                cadence, fail_step, resume_step, restore_secs,
                redo_secs, lost,
            );
            rows.push(format!(
                "    {{\"cadence\": {cadence}, \"fail_step\": {fail_step}, \
                 \"resume_step\": {resume_step}, \
                 \"restore_secs\": {restore_secs:.6}, \
                 \"redo_secs\": {redo_secs:.6}, \"lost_steps\": {lost}, \
                 \"ckpt_bytes\": {}, \"ckpt_overhead_secs\": {:.6}, \
                 \"identical\": true}}",
                ckpt_run.ft_checkpoint_bytes,
                (ckpt_secs - base_secs).max(0.0),
            ));
        }
    }

    std::fs::write(
        "BENCH_recovery.json",
        format!(
            "{{\n  \"bench\": \"recovery\",\n  \
             \"steps\": {STEPS},\n  \
             \"machines\": 2,\n  \
             \"pipeline\": \"nonstop\",\n  \
             \"baseline_secs\": {base_secs:.6},\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            rows.join(",\n"),
        ),
    )?;
    println!("\nwrote BENCH_recovery.json");
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
