//! Figure 2: full-graph vs mini-batch training — time (and updates) to
//! reach a target validation accuracy on medium and large workloads.
//!
//! Full-graph training performs one gradient update per pass over the
//! whole training set with full neighborhoods; mini-batch training gets
//! `N/B` updates in the same data volume. Requires `make artifacts-extra`
//! (the `sage_nc_full` variant).
//!
//! Expected shape (paper): mini-batch reaches target accuracy ~an order
//! of magnitude faster; the gap widens with graph size.

use std::sync::Arc;
use std::time::Instant;

use distdglv2::baselines::FullGraphGen;
use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::graph::DatasetSpec;
use distdglv2::runtime::manifest::{artifacts_dir, Manifest};
use distdglv2::trainer::{self, DeviceExecutor, TrainConfig};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&artifacts_dir())?;
    if manifest.variants.get("sage_nc_full").is_none() {
        eprintln!("run `make artifacts-extra` first (sage_nc_full missing)");
        return Ok(());
    }
    let full_spec = manifest.variant("sage_nc_full")?.clone();

    for (label, n, e) in
        [("medium", 8_000usize, 48_000usize), ("large", 24_000, 144_000)]
    {
        let mut dspec = DatasetSpec::new(label, n, e);
        dspec.feat_dim = 32;
        dspec.num_classes = 16;
        dspec.train_frac = 0.2;
        let dataset = Arc::new(dspec.generate());
        println!(
            "\n=== Fig 2 — {label} graph ({} nodes, {} edges) ===",
            dataset.n_nodes(),
            dataset.graph.n_edges()
        );

        // ---- mini-batch: the full distributed system ------------------
        let cluster = Cluster::deploy(
            &dataset,
            ClusterSpec::new(1, 2),
            artifacts_dir(),
        )?;
        let t = Instant::now();
        let cfg = TrainConfig {
            variant: "sage_nc_dev".into(),
            lr: 0.3,
            epochs: 3,
            max_steps: 45,
            eval_each_epoch: true,
            ..Default::default()
        };
        let report = trainer::train(&cluster, &cfg)?;
        let mb_secs = t.elapsed().as_secs_f64();
        let mb_acc = report.final_val_acc.unwrap_or(f64::NAN);
        println!(
            "mini-batch : {:>3} updates, {:.2}s, val acc {:.3}",
            report.steps, mb_secs, mb_acc
        );

        // ---- full-graph: one update per pass ---------------------------
        let device = DeviceExecutor::spawn(
            artifacts_dir(),
            "sage_nc_full".into(),
            None,
        )?;
        let mut params = device.initial_params()?;
        let handle = device.handle();
        let mut gen = FullGraphGen::new(dataset.clone(), full_spec.shape_spec());
        let t = Instant::now();
        let passes = 3;
        let mut updates = 0usize;
        let mut last_loss = f32::NAN;
        for _ in 0..passes {
            for _ in 0..gen.steps_per_pass() {
                let b = gen.next();
                last_loss = handle.train(&mut params, b, 0.05)?;
                updates += 1;
            }
        }
        let fg_secs = t.elapsed().as_secs_f64();
        println!(
            "full-graph : {updates:>3} updates ({passes} passes), {:.2}s, \
             final loss {last_loss:.3}",
            fg_secs
        );
        println!(
            "mini-batch per-update time {:.1}ms vs full-graph {:.1}ms; \
             mini-batch makes {:.0}x more updates per data pass",
            mb_secs * 1e3 / report.steps as f64,
            fg_secs * 1e3 / updates as f64,
            (dataset.nodes_with(distdglv2::graph::SplitTag::Train).len()
                as f64
                / 128.0)
                / gen.steps_per_pass() as f64
                * passes as f64,
        );
    }
    println!(
        "\npaper reference: full-graph an order of magnitude slower to \
         converge on medium graphs, worse on large; may also plateau lower."
    );
    Ok(())
}
