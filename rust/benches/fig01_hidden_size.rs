//! Figure 1: GraphSAGE model accuracy vs hidden size (16 … 256).
//!
//! Motivates the paper's data-parallel (not model-parallel) design: good
//! accuracy needs large hidden sizes, which P3-style model parallelism
//! handles poorly. Requires `make artifacts-extra` (hidden-size variants).
//!
//! Expected shape: accuracy grows with hidden size and saturates.

use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::graph::DatasetSpec;
use distdglv2::runtime::manifest::{artifacts_dir, Manifest};
use distdglv2::trainer::{self, TrainConfig};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&artifacts_dir())?;
    let variants = [
        ("sage_nc_h16", 16usize),
        ("sage_nc_h32", 32),
        ("sage_nc_dev", 64),
        ("sage_nc_h128", 128),
        ("sage_nc_h256", 256),
    ];
    for (v, _) in &variants {
        if manifest.variants.get(*v).is_none() {
            eprintln!(
                "variant {v} missing — run `make artifacts-extra` first"
            );
            return Ok(());
        }
    }

    let mut dspec = DatasetSpec::new("products-s", 24_000, 160_000);
    dspec.feat_dim = 32;
    dspec.num_classes = 16;
    dspec.train_frac = 0.15;
    let dataset = dspec.generate();

    println!("=== Fig 1 — accuracy vs hidden size (GraphSAGE) ===");
    println!("{:<12} {:>10} {:>12}", "hidden", "val acc", "final loss");
    for (variant, hidden) in variants {
        let cluster = Cluster::deploy(
            &dataset,
            ClusterSpec::new(2, 2),
            artifacts_dir(),
        )?;
        let cfg = TrainConfig {
            variant: variant.into(),
            lr: 0.3,
            epochs: 2,
            max_steps: 60,
            eval_each_epoch: true,
            ..Default::default()
        };
        let report = trainer::train(&cluster, &cfg)?;
        println!(
            "{:<12} {:>10.3} {:>12.4}",
            hidden,
            report.final_val_acc.unwrap_or(f64::NAN),
            report.loss_curve.last().copied().unwrap_or(f32::NAN),
        );
    }
    println!(
        "\npaper reference: accuracy increases with hidden size and \
         saturates (Fig 1) — the argument for data parallelism."
    );
    Ok(())
}
