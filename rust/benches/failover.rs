//! Failover bench (docs/PERF.md §Failover): fault scenario ×
//! replication grid. Each scenario injects a KV-server fault through
//! the cluster's `FaultPlan` and measures what replication buys:
//! with `replicate_kv` on, a permanently dead server fails over to its
//! standby replica and the run completes with a loss curve and final
//! params byte-identical to the fault-free baseline; with replication
//! off the same injection surfaces as the typed `ServerDown` drain.
//! The kill+rejoin scenario additionally restarts the dead server,
//! re-imports its shards from the standby, and re-runs to show the
//! primary serves again. t_failover is decomposed into detect (retry
//! budget burned on the dead primary), reroute (standby admission),
//! and re-import (shard copy-back on rejoin) from the `ReplicaSet`
//! timers. Emits `BENCH_failover.json`. Requires `make artifacts`.

use std::sync::Arc;
use std::time::Instant;

use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::ft::{FailWindow, FaultPlan};
use distdglv2::graph::{Dataset, DatasetSpec};
use distdglv2::pipeline::PipelineMode;
use distdglv2::runtime::manifest::artifacts_dir;
use distdglv2::trainer::{self, TrainConfig};

const STEPS: usize = 12;
const MACHINES: usize = 2;
/// Call-counter slot the injected outage opens at: a few healthy
/// remote pulls first, so detection happens mid-run, not at deploy.
const FAIL_AT: u64 = 4;

fn deploy(dataset: &Dataset, replicate: bool) -> anyhow::Result<Cluster> {
    let mut spec = ClusterSpec::new(MACHINES, 1);
    spec.replicate_kv = replicate;
    Cluster::deploy(dataset, spec, artifacts_dir())
}

fn cfg() -> TrainConfig {
    let mut cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        lr: 0.3,
        epochs: 1,
        max_steps: STEPS,
        seed: 41,
        ..Default::default()
    };
    cfg.pipeline.mode = PipelineMode::Sync;
    cfg
}

/// The injected fault, or None for the fault-free scenario.
fn plan_for(scenario: &str) -> Option<FaultPlan> {
    let mut plan = FaultPlan::new();
    plan.backoff = std::time::Duration::ZERO;
    match scenario {
        "no_fault" => return None,
        // two refusals then recovery: the retry budget absorbs it on
        // its own, so replication must NOT fail over
        "transient_outage" => {
            plan.kv_outages.push(FailWindow::transient(0, FAIL_AT, 2))
        }
        // the server never comes back: failover or typed drain
        "permanent_loss" | "kill_and_rejoin" => {
            plan.kv_outages.push(FailWindow::permanent(0, FAIL_AT))
        }
        other => unreachable!("scenario {other}"),
    }
    Some(plan)
}

struct Row {
    scenario: &'static str,
    replicate: bool,
    completed: bool,
    identical: bool,
    error: String,
    wall_secs: f64,
    failovers: u64,
    rejoins: u64,
    replica_bytes: u64,
    detect_secs: f64,
    reroute_secs: f64,
    reimport_secs: f64,
}

impl Row {
    fn json(&self) -> String {
        format!(
            "    {{\"scenario\": \"{}\", \"replicate\": {}, \
             \"completed\": {}, \"identical\": {}, \
             \"error\": \"{}\", \"wall_secs\": {:.6}, \
             \"failovers\": {}, \"rejoins\": {}, \
             \"replica_bytes\": {}, \"detect_secs\": {:.6}, \
             \"reroute_secs\": {:.6}, \"reimport_secs\": {:.6}}}",
            self.scenario,
            self.replicate,
            self.completed,
            self.identical,
            self.error.replace('"', "'"),
            self.wall_secs,
            self.failovers,
            self.rejoins,
            self.replica_bytes,
            self.detect_secs,
            self.reroute_secs,
            self.reimport_secs,
        )
    }
}

fn main() -> anyhow::Result<()> {
    let mut dspec = DatasetSpec::new("failover-bench", 6000, 30_000);
    dspec.seed = 43;
    let dataset = dspec.generate();
    let cfg = cfg();

    // the stream every completed cell must reproduce exactly
    let t = Instant::now();
    let baseline = trainer::train(&deploy(&dataset, false)?, &cfg)?;
    let base_secs = t.elapsed().as_secs_f64();
    println!("baseline: {STEPS} steps in {base_secs:.3}s (no faults)");

    println!("\n=== failover grid (scenario x replication) ===");
    println!(
        "{:<17} {:>5} {:>5} {:>5} {:>9} {:>9} {:>9}",
        "scenario", "repl", "done", "ident", "detect", "reroute",
        "reimport"
    );
    let scenarios =
        ["no_fault", "transient_outage", "permanent_loss",
         "kill_and_rejoin"];
    let mut rows: Vec<Row> = Vec::new();
    for scenario in scenarios {
        for replicate in [false, true] {
            if scenario == "kill_and_rejoin" && !replicate {
                // rejoin needs a replica to re-import from; the
                // unreplicated half of this scenario is
                // permanent_loss, already covered
                continue;
            }
            let cluster = deploy(&dataset, replicate)?;
            if let Some(plan) = plan_for(scenario) {
                cluster.set_fault_plan(Arc::new(plan));
            }
            let t = Instant::now();
            let outcome = trainer::train(&cluster, &cfg);
            let wall_secs = t.elapsed().as_secs_f64();
            let (completed, identical, error) = match &outcome {
                Ok(rep) => {
                    let same = rep.loss_curve == baseline.loss_curve
                        && rep.final_params == baseline.final_params;
                    assert!(
                        same,
                        "{scenario} (replicate={replicate}) completed \
                         but diverged from the fault-free baseline"
                    );
                    (true, same, String::new())
                }
                Err(e) => (false, false, format!("{e:#}")),
            };
            // a permanent loss must complete iff replicated
            if scenario == "permanent_loss"
                || scenario == "kill_and_rejoin"
            {
                assert_eq!(
                    completed, replicate,
                    "{scenario}: completed={completed} with \
                     replicate={replicate}"
                );
            } else {
                assert!(completed, "{scenario} failed: {error}");
            }

            let rs = cluster.kv.replica_set();
            let mut rejoins = 0u64;
            let mut reimport_secs = 0.0f64;
            if scenario == "kill_and_rejoin" && replicate {
                // restart: heal the plan, re-import the dead server's
                // shards from its standby, and prove the primary
                // serves again by re-running the whole stream
                cluster.set_fault_plan(Arc::new(FaultPlan::new()));
                let bytes = cluster.kv.rejoin_server(0);
                assert!(bytes > 0, "rejoin re-imported nothing");
                let again = trainer::train(&cluster, &cfg)?;
                assert_eq!(
                    again.loss_curve, baseline.loss_curve,
                    "post-rejoin run diverged"
                );
                let rs = rs.as_ref().unwrap();
                rejoins = rs.rejoins();
                reimport_secs = rs.reimport_time().as_secs_f64();
            }
            let (failovers, replica_bytes, detect_secs, reroute_secs) =
                match &rs {
                    Some(rs) => (
                        rs.failovers(),
                        rs.replica_bytes(),
                        rs.detect_time().as_secs_f64(),
                        rs.reroute_time().as_secs_f64(),
                    ),
                    None => (0, 0, 0.0, 0.0),
                };
            if replicate {
                let expect = matches!(
                    scenario,
                    "permanent_loss" | "kill_and_rejoin"
                ) as u64;
                assert_eq!(
                    failovers, expect,
                    "{scenario}: failovers={failovers}"
                );
            }
            println!(
                "{:<17} {:>5} {:>5} {:>5} {:>9.6} {:>9.6} {:>9.6}",
                scenario, replicate, completed, identical, detect_secs,
                reroute_secs, reimport_secs,
            );
            rows.push(Row {
                scenario,
                replicate,
                completed,
                identical,
                error,
                wall_secs,
                failovers,
                rejoins,
                replica_bytes,
                detect_secs,
                reroute_secs,
                reimport_secs,
            });
        }
    }

    let json_rows: Vec<String> = rows.iter().map(Row::json).collect();
    std::fs::write(
        "BENCH_failover.json",
        format!(
            "{{\n  \"bench\": \"failover\",\n  \
             \"steps\": {STEPS},\n  \
             \"machines\": {MACHINES},\n  \
             \"fail_at\": {FAIL_AT},\n  \
             \"baseline_secs\": {base_secs:.6},\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            json_rows.join(",\n"),
        ),
    )?;
    println!("\nwrote BENCH_failover.json");
    Ok(())
}
