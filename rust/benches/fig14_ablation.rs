//! Figure 14: ablation of DistDGLv2's optimizations, one added at a time,
//! GraphSAGE on the products-shaped workload (4 machines x 2 trainers):
//!
//!   baseline      random partition, sync pipeline, 1-level split
//!   +metis        multi-constraint min-cut partitioning
//!   +2level       second-level (per-GPU) training-set split
//!   +async        asynchronous mini-batch pipeline
//!   +nonstop      non-stop pipeline across epoch boundaries
//!
//! Expected shape (paper): every bar adds speedup; total ≈ 4.7x.

use distdglv2::benchsuite::{
    measured_epoch_secs, paper_epoch_secs, paper_spec, FigTable,
    PaperWorkload, SAMPLING_CPU_SCALE,
};
use distdglv2::sampler::compact::ModelKind;
use distdglv2::cluster::{Cluster, ClusterSpec, Partitioner};
use distdglv2::graph::DatasetSpec;
use distdglv2::pipeline::{PipelineConfig, PipelineMode};
use distdglv2::runtime::manifest::{artifacts_dir, Manifest};
use distdglv2::runtime::DeviceCostModel;
use distdglv2::trainer::{self, TrainConfig};

struct Step {
    label: &'static str,
    partitioner: Partitioner,
    multi_constraint: bool,
    two_level: bool,
    mode: PipelineMode,
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&artifacts_dir())?;
    let spec = manifest.variant("sage_nc_dev")?.clone();
    let t4 = DeviceCostModel::t4();

    let mut dspec = DatasetSpec::new("products-s", 24_000, 160_000);
    dspec.feat_dim = 32;
    dspec.num_classes = 16;
    dspec.train_frac = 0.082;
    let dataset = dspec.generate();

    let steps = [
        Step {
            label: "baseline (random, sync, 1-level)",
            partitioner: Partitioner::Random,
            multi_constraint: false,
            two_level: false,
            mode: PipelineMode::Sync,
        },
        Step {
            label: "+ multi-constraint METIS",
            partitioner: Partitioner::Metis,
            multi_constraint: true,
            two_level: false,
            mode: PipelineMode::Sync,
        },
        Step {
            label: "+ 2-level partition",
            partitioner: Partitioner::Metis,
            multi_constraint: true,
            two_level: true,
            mode: PipelineMode::Sync,
        },
        Step {
            label: "+ async pipeline",
            partitioner: Partitioner::Metis,
            multi_constraint: true,
            two_level: true,
            mode: PipelineMode::Async,
        },
        Step {
            label: "+ non-stop pipeline",
            partitioner: Partitioner::Metis,
            multi_constraint: true,
            two_level: true,
            mode: PipelineMode::AsyncNonstop,
        },
    ];

    let mut table = FigTable::new(
        "Fig 14 — ablation, GraphSAGE on products (epoch time)",
    );
    let n_steps = 8;
    for s in &steps {
        let mut cspec = ClusterSpec::new(4, 2);
        cspec.partitioner = s.partitioner;
        cspec.multi_constraint = s.multi_constraint;
        cspec.two_level = s.two_level;
        let cluster = Cluster::deploy(&dataset, cspec, artifacts_dir())?;
        let tcfg = TrainConfig {
            variant: "sage_nc_dev".into(),
            lr: 0.3,
            epochs: 1,
            max_steps: n_steps,
            pipeline: PipelineConfig { mode: s.mode, ..Default::default() },
            ..Default::default()
        };
        let report = trainer::train(&cluster, &tcfg)?;
        let workload = PaperWorkload {
            spec: paper_spec(ModelKind::Sage, 100),
            train_items: 197_000,
        };
        table.row(
            s.label,
            measured_epoch_secs(&report, &cluster, &spec),
            paper_epoch_secs(
                &report,
                &cluster,
                &spec,
                &workload,
                &t4,
                s.mode,
                SAMPLING_CPU_SCALE,
                32,
            ),
        );
        println!(
            "    remote feature rows/step: {:.0}, dropped nbrs/step: {:.0}",
            report.remote_feature_rows as f64
                / (report.steps * cluster.n_trainers()) as f64,
            0.0,
        );
    }
    table.speedups("baseline (random, sync, 1-level)");
    println!("\npaper reference: cumulative ≈ 4.7x (Fig 14).");
    Ok(())
}
