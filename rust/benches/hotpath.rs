//! Hot-path microbenchmarks for the §Perf optimization pass: each stage of
//! the mini-batch path in isolation (sampling, compaction, KVStore pull,
//! ring all-reduce, PJRT train step), plus the composed BatchGen. Run
//! before/after every optimization; EXPERIMENTS.md §Perf records the log.

use std::sync::Arc;
use std::time::Instant;

use distdglv2::api::{DistGraph, DistNodeDataLoader};
use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::graph::{DatasetSpec, FanoutPlan};
use distdglv2::kvstore::{KvCluster, RangePolicy, TypedFeatures};
use distdglv2::metrics::Metrics;
use distdglv2::net::CostModel;
use distdglv2::partition::{
    build_partitions, metis_partition, relabel, NodeMap, PartitionConfig,
    VertexWeights,
};
use distdglv2::pipeline::gen::etype_metric_keys;
use distdglv2::pipeline::{
    BatchGen, BatchPool, Pipeline, PipelineConfig, PipelineMode,
};
use distdglv2::runtime::manifest::{artifacts_dir, Manifest, VariantSpec};
use distdglv2::sampler::compact::{to_block, ModelKind, ShapeSpec, TaskKind};
use distdglv2::sampler::{
    BatchScheduler, DistNeighborSampler, SamplerServer,
};
use distdglv2::trainer::{AllReduceGroup, DeviceExecutor};
use distdglv2::util::bench::BenchRunner;
use distdglv2::util::Rng;

/// Per-batch seconds of the legacy trainer-internal path (a raw
/// `BatchGen`, stages 1-4 inline — what `Pipeline` runs per batch) vs.
/// the `api::DistNodeDataLoader` facade over the same generator, both in
/// Sync mode so the facade cost itself is on the measured path.
fn loader_overhead_stage(
    cl: &Cluster,
    vspec: &VariantSpec,
    label: &str,
    r: &mut BenchRunner,
) -> (f64, f64) {
    let mut legacy = cl.batch_gen(0, vspec, &vspec.name, 41);
    let legacy_s = r
        .bench(&format!("legacy BatchGen::next ({label})"), || {
            let b = legacy.next();
            std::hint::black_box(b.targets.len());
            legacy.recycle(b);
        })
        .secs();
    let g = DistGraph::new(cl);
    let mut loader = DistNodeDataLoader::builder(&g, vspec)
        .seed(41)
        .pipeline(PipelineConfig {
            mode: PipelineMode::Sync,
            ..Default::default()
        })
        .build()
        .expect("build loader");
    let loader_s = r
        .bench(&format!("api loader next_batch ({label})"), || {
            let b = loader.next_batch();
            std::hint::black_box(b.targets.len());
            loader.recycle(b);
        })
        .secs();
    (legacy_s, loader_s)
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&artifacts_dir())?;
    let vspec = manifest.variant("sage_nc_dev")?.clone();
    let shape = vspec.shape_spec();
    let plan = FanoutPlan::uniform(&shape.fanouts);

    let mut dspec = DatasetSpec::new("hot", 50_000, 300_000);
    dspec.feat_dim = 32;
    dspec.num_classes = 16;
    dspec.train_frac = 0.2;
    let dataset = dspec.generate();
    let cluster =
        Cluster::deploy(&dataset, ClusterSpec::new(2, 2), artifacts_dir())?;

    let mut r = BenchRunner::new(2, 10);
    let mut rng = Rng::new(17);

    // --- stage 2: distributed neighbor sampling -------------------------
    let mut gen = cluster.batch_gen(0, &vspec, "sage_nc_dev", 3);
    let targets: Vec<u32> = cluster.train_sets[0]
        [..shape.batch.min(cluster.train_sets[0].len())]
        .to_vec();
    let sampler = gen.sampler.clone();
    r.bench("sample_blocks (2 layers, fanout 5)", || {
        let s = sampler
            .sample_blocks(&targets, &plan, &shape.layer_nodes, &mut rng)
            .unwrap();
        std::hint::black_box(s.len());
    });

    // --- stage 4: compaction --------------------------------------------
    let samples =
        sampler
            .sample_blocks(&targets, &plan, &shape.layer_nodes, &mut rng)
            .unwrap();
    r.bench("to_block (compaction)", || {
        let b = to_block(&shape, &samples);
        std::hint::black_box(b.input_nodes.len());
    });

    // --- stage 3: KVStore pull -------------------------------------------
    let block = to_block(&shape, &samples);
    let mut feats = vec![0f32; shape.layer_nodes[0] * shape.feat_dim];
    let n_rows = block.input_nodes.len();
    let mut uncached = cluster.kv.client(0, cluster.policy.clone());
    let cpu_uncached = r.bench(
        &format!("kv pull (uncached, {n_rows} feature rows)"),
        || {
            let n = uncached
                .pull(
                    "feat",
                    &block.input_nodes,
                    &mut feats[..n_rows * shape.feat_dim],
                )
                .unwrap();
            std::hint::black_box(n);
        },
    );
    let mut cached_cpu = cluster.kv.client(0, cluster.policy.clone());
    cached_cpu.attach_cache(cluster.make_feature_cache().unwrap());
    let cpu_cached = r.bench(
        "kv pull (cached, warm, cpu-only)", // warmup iters fill the cache
        || {
            let n = cached_cpu
                .pull(
                    "feat",
                    &block.input_nodes,
                    &mut feats[..n_rows * shape.feat_dim],
                )
                .unwrap();
            std::hint::black_box(n);
        },
    );

    // --- stage 3 under wall-clock network fidelity ------------------------
    // Same pull with modeled link time emulated: this is the regime the
    // cache targets — repeated remote rows stop paying the wire cost.
    let mut em_spec = ClusterSpec::new(2, 2);
    em_spec.emulate_network_time = true;
    let cluster_em =
        Cluster::deploy(&dataset, em_spec, artifacts_dir())?;
    let gen_em = cluster_em.batch_gen(0, &vspec, "sage_nc_dev", 3);
    let mut rng_em = Rng::new(17);
    let samples_em = gen_em
        .sampler
        .sample_blocks(&targets, &plan, &shape.layer_nodes, &mut rng_em)
        .unwrap();
    let block_em = to_block(&shape, &samples_em);
    let n_rows_em = block_em.input_nodes.len();
    let mut un_em = cluster_em.kv.client(0, cluster_em.policy.clone());
    let em_uncached = r.bench("kv pull (uncached)", || {
        let n = un_em
            .pull(
                "feat",
                &block_em.input_nodes,
                &mut feats[..n_rows_em * shape.feat_dim],
            )
            .unwrap();
        std::hint::black_box(n);
    });
    let mut ca_em = cluster_em.kv.client(0, cluster_em.policy.clone());
    ca_em.attach_cache(cluster_em.make_feature_cache().unwrap());
    let em_cached = r.bench("kv pull (cached, warm)", || {
        let n = ca_em
            .pull(
                "feat",
                &block_em.input_nodes,
                &mut feats[..n_rows_em * shape.feat_dim],
            )
            .unwrap();
        std::hint::black_box(n);
    });
    let cstats = ca_em.cache_stats().unwrap();
    println!(
        "cache: {} hits / {} misses ({:.1}% hit rate), {} B saved, \
         {} evicted",
        cstats.hit_rows,
        cstats.miss_rows,
        100.0 * cstats.hit_rate(),
        cstats.remote_bytes_saved,
        cstats.evicted_rows,
    );
    let em_speedup = em_uncached.secs() / em_cached.secs().max(1e-12);
    let cpu_speedup = cpu_uncached.secs() / cpu_cached.secs().max(1e-12);
    println!(
        "warm cached pull speedup: {em_speedup:.2}x (network fidelity), \
         {cpu_speedup:.2}x (cpu-only)"
    );
    std::fs::write(
        "BENCH_cache.json",
        format!(
            "{{\n  \"bench\": \"hotpath.cache\",\n  \
             \"rows\": {n_rows_em},\n  \
             \"feat_dim\": {},\n  \
             \"uncached_s\": {:.9},\n  \
             \"cached_warm_s\": {:.9},\n  \
             \"speedup\": {em_speedup:.3},\n  \
             \"cpu_only\": {{\"uncached_s\": {:.9}, \
             \"cached_warm_s\": {:.9}, \"speedup\": {cpu_speedup:.3}}},\n  \
             \"hit_rows\": {},\n  \
             \"miss_rows\": {},\n  \
             \"hit_rate\": {:.4},\n  \
             \"remote_bytes_saved\": {},\n  \
             \"evicted_rows\": {}\n}}\n",
            shape.feat_dim,
            em_uncached.secs(),
            em_cached.secs(),
            cpu_uncached.secs(),
            cpu_cached.secs(),
            cstats.hit_rows,
            cstats.miss_rows,
            cstats.hit_rate(),
            cstats.remote_bytes_saved,
            cstats.evicted_rows,
        ),
    )?;
    println!("wrote BENCH_cache.json");

    // --- composed BatchGen (stages 1-4) -----------------------------------
    r.bench("BatchGen::next (stages 1-4 composed)", || {
        let b = gen.next();
        std::hint::black_box(b.targets.len());
    });

    // --- api facade: DistNodeDataLoader vs legacy train path ---------------
    // The loader must add no measurable overhead over the pipeline it
    // wraps (ISSUE 4 acceptance): same generator, same recycling, the
    // facade's bookkeeping on the measured path. Reported batches/sec,
    // cpu-only and under emulated network time.
    let (leg_cpu, ldr_cpu) =
        loader_overhead_stage(&cluster, &vspec, "cpu-only", &mut r);
    let (leg_em, ldr_em) =
        loader_overhead_stage(&cluster_em, &vspec, "emulated network", &mut r);
    let cpu_overhead = ldr_cpu / leg_cpu.max(1e-12) - 1.0;
    let em_overhead = ldr_em / leg_em.max(1e-12) - 1.0;
    println!(
        "loader facade: {:.1} vs {:.1} batches/s cpu-only ({:+.1}% \
         overhead), {:.1} vs {:.1} batches/s emulated-network ({:+.1}%)",
        1.0 / ldr_cpu,
        1.0 / leg_cpu,
        100.0 * cpu_overhead,
        1.0 / ldr_em,
        1.0 / leg_em,
        100.0 * em_overhead,
    );
    std::fs::write(
        "BENCH_loader.json",
        format!(
            "{{\n  \"bench\": \"hotpath.loader\",\n  \
             \"cpu_only\": {{\"legacy_s\": {leg_cpu:.9}, \
             \"loader_s\": {ldr_cpu:.9}, \
             \"legacy_batches_per_s\": {:.3}, \
             \"loader_batches_per_s\": {:.3}, \
             \"overhead_frac\": {cpu_overhead:.5}}},\n  \
             \"emulated_network\": {{\"legacy_s\": {leg_em:.9}, \
             \"loader_s\": {ldr_em:.9}, \
             \"legacy_batches_per_s\": {:.3}, \
             \"loader_batches_per_s\": {:.3}, \
             \"overhead_frac\": {em_overhead:.5}}}\n}}\n",
            1.0 / leg_cpu,
            1.0 / ldr_cpu,
            1.0 / leg_em,
            1.0 / ldr_em,
        ),
    )?;
    println!("wrote BENCH_loader.json");

    // --- hetero stage: typed sampling + per-ntype pull ---------------------
    // mag-lsc-shaped typed graph: 3 ntypes (per-ntype feature tables of
    // independent dims), 4 etypes, per-etype fanout split of each layer's
    // K. Needs no AOT artifacts (no device step).
    let mut hspec =
        DatasetSpec::new("hot-hetero", 20_000, 120_000).with_mag_types();
    hspec.feat_dim = 32;
    hspec.train_frac = 0.2;
    let hdata = hspec.generate();
    let hcluster =
        Cluster::deploy(&hdata, ClusterSpec::new(2, 1), artifacts_dir())?;
    let hshape = ShapeSpec {
        name: "hetero-bench".into(),
        model: ModelKind::Rgcn,
        task: TaskKind::NodeClassification,
        batch: 128,
        fanouts: vec![5, 5],
        layer_nodes: vec![3072, 768, 128],
        feat_dim: hspec.feat_dim,
        num_classes: hspec.num_classes,
        num_rels: hspec.num_rels,
    };
    let hplan = hcluster.fanout_plan(&hshape.fanouts);
    let hsampler = DistNeighborSampler::new(
        0,
        hcluster.sampler_servers.clone(),
        hcluster.node_map.clone(),
        hcluster.cost.clone(),
    );
    let htargets: Vec<u32> = hcluster.train_sets[0]
        [..hshape.batch.min(hcluster.train_sets[0].len())]
        .to_vec();
    let mut hrng = Rng::new(23);
    let h_sample = r.bench("hetero sample_blocks (per-etype fanouts)", || {
        let s = hsampler
            .sample_blocks(&htargets, &hplan, &hshape.layer_nodes, &mut hrng)
            .unwrap();
        std::hint::black_box(s.len());
    });
    let hsamples = hsampler
        .sample_blocks(&htargets, &hplan, &hshape.layer_nodes, &mut hrng)
        .unwrap();
    let h_compact = r.bench("hetero to_block (rel-segmented)", || {
        let b = to_block(&hshape, &hsamples);
        std::hint::black_box(b.input_nodes.len());
    });
    let hblock = to_block(&hshape, &hsamples);
    let h_rows = hblock.input_nodes.len();
    // zero once: the pull overwrites every real row's typed prefix each
    // iteration, and the homogeneous pull stages it is compared against
    // do no in-closure zeroing either
    let mut hfeats = vec![0f32; hshape.layer_nodes[0] * hshape.feat_dim];
    let mut hkv = hcluster.kv.client(0, hcluster.policy.clone());
    let h_pull = r.bench(
        &format!("hetero typed kv pull ({h_rows} rows, 3 ntype tables)"),
        || {
            let n = hkv
                .pull_typed(
                    &hcluster.features,
                    &hblock.input_nodes,
                    &mut hfeats[..h_rows * hshape.feat_dim],
                    hshape.feat_dim,
                )
                .unwrap();
            std::hint::black_box(n);
        },
    );
    let etype_json: Vec<String> = hblock
        .etype_edges
        .iter()
        .map(|c| c.to_string())
        .collect();
    println!(
        "hetero: sampled edges per etype {:?}",
        hblock.etype_edges
    );
    std::fs::write(
        "BENCH_hetero.json",
        format!(
            "{{\n  \"bench\": \"hotpath.hetero\",\n  \
             \"ntypes\": 3,\n  \
             \"etypes\": {},\n  \
             \"rows\": {h_rows},\n  \
             \"sample_s\": {:.9},\n  \
             \"compact_s\": {:.9},\n  \
             \"typed_pull_s\": {:.9},\n  \
             \"etype_edges\": [{}]\n}}\n",
            hshape.num_rels,
            h_sample.secs(),
            h_compact.secs(),
            h_pull.secs(),
            etype_json.join(", "),
        ),
    )?;
    println!("wrote BENCH_hetero.json");

    // --- worker scaling: parallel mini-batch generation --------------------
    // Hand-built 3-partition pipeline (trainer on machine 0, remote rows
    // on two other owners) over a deliberately *slow* emulated link
    // (1 GB/s, 200 µs/message) so network time dominates batch
    // generation the way it does at paper scale. Grid: workers ∈ {1,2,4}
    // × serial-vs-concurrent per-owner RPC × cpu-only vs emulated
    // network. Cache off, fixed seed: every config produces the exact
    // same batches, so modeled network bytes must be identical across
    // the whole grid (asserted) while batches/sec scales.
    let vw3 = VertexWeights::uniform(dataset.n_nodes());
    let p3 =
        metis_partition(&dataset.graph, &vw3, &PartitionConfig::new(3));
    let r3 = relabel::relabel(&p3);
    let d3 = relabel::relabel_dataset(&dataset, &r3);
    let parts3 = build_partitions(&d3.graph, &r3.node_map);
    let servers3: Vec<Arc<SamplerServer>> = parts3
        .into_iter()
        .enumerate()
        .map(|(m, pp)| Arc::new(SamplerServer::new(m as u32, Arc::new(pp))))
        .collect();
    let nm3 = Arc::new(NodeMap {
        part_starts: r3.node_map.part_starts.clone(),
    });
    let labels3: Vec<f32> = d3.labels.iter().map(|&l| l as f32).collect();
    // seeds spread over the whole id space → multi-owner fan-out on the
    // hot path; 8 epochs' worth keeps every config run short but steady
    let n_seeds = (8 * shape.batch).min(d3.n_nodes());
    let stride_w = (d3.n_nodes() / n_seeds).max(1);
    let seeds_w: Vec<u32> = (0..n_seeds as u32)
        .map(|i| i * stride_w as u32)
        .collect();
    let mk_gen = |cost: Arc<CostModel>,
                  emulate: bool,
                  concurrent: bool|
     -> BatchGen {
        let kv = KvCluster::with_options(3, cost.clone(), emulate, concurrent);
        let policy = Arc::new(RangePolicy::new(NodeMap {
            part_starts: nm3.part_starts.clone(),
        }));
        let features = TypedFeatures::from_schema(
            "feat",
            &d3.schema,
            Arc::new(d3.graph.node_type.clone()),
        );
        kv.register_typed(&features, &d3.feats, d3.feat_dim, policy.as_ref());
        kv.register_partitioned("label", &labels3, 1, policy.as_ref());
        let mut sampler =
            DistNeighborSampler::new(0, servers3.clone(), nm3.clone(), cost);
        sampler.emulate_network_time = emulate;
        sampler.concurrent_fanout = concurrent;
        let client = kv.client(0, policy);
        BatchGen {
            spec: shape.clone(),
            scheduler: BatchScheduler::for_nodes(
                seeds_w.clone(),
                shape.batch,
                5,
            ),
            sampler: Arc::new(sampler),
            kv: client,
            seed: 7,
            pos: 0,
            eval_pos: 0,
            plan: FanoutPlan::from_schema(&d3.schema, &shape.fanouts),
            features,
            label_name: "label".into(),
            metrics: Arc::new(Metrics::new()),
            etype_keys: etype_metric_keys(shape.num_rels),
            pool: BatchPool::default(),
            label_scratch: Vec::new(),
            frontier_scratch: Vec::new(),
        }
    };
    let mut rows_json: Vec<String> = Vec::new();
    let mut bytes_seen: Option<u64> = None;
    let mut bps_of = std::collections::HashMap::new();
    for emulate in [false, true] {
        for concurrent in [false, true] {
            for workers in [1usize, 2, 4] {
                let cost =
                    Arc::new(CostModel::new(1e9, 200e-6, 12e9));
                let gen = mk_gen(cost.clone(), emulate, concurrent);
                let pool = gen.pool.clone();
                let bpe = gen.batches_per_epoch();
                let cfg = PipelineConfig {
                    mode: PipelineMode::Async, // exact production count
                    cpu_prefetch_depth: 4,
                    gpu_prefetch_depth: 1,
                    num_workers: workers,
                    prefetch_depth: 0,
                };
                let mut pipe =
                    Pipeline::start(gen, &cfg, Arc::new(Metrics::new()));
                let total = 2 * bpe;
                let t = Instant::now();
                for _ in 0..total {
                    let b = pipe.next().unwrap();
                    std::hint::black_box(b.targets.len());
                    pool.put(b);
                }
                let secs = t.elapsed().as_secs_f64();
                drop(pipe);
                let bytes = cost.network_bytes();
                match bytes_seen {
                    None => bytes_seen = Some(bytes),
                    Some(b0) => assert_eq!(
                        bytes, b0,
                        "modeled network bytes changed across the grid"
                    ),
                }
                let bps = total as f64 / secs;
                let net = if emulate { "emulated" } else { "cpu" };
                let rpc = if concurrent { "concurrent" } else { "serial" };
                bps_of.insert((emulate, concurrent, workers), bps);
                println!(
                    "workers stage: {net:>8} net, {rpc:>10} rpc, \
                     {workers} worker(s): {bps:8.1} batches/s \
                     ({total} batches, {bytes} modeled B)"
                );
                rows_json.push(format!(
                    "    {{\"net\": \"{net}\", \"rpc\": \"{rpc}\", \
                     \"workers\": {workers}, \"secs\": {secs:.6}, \
                     \"batches_per_s\": {bps:.3}, \
                     \"net_bytes\": {bytes}}}"
                ));
            }
        }
    }
    let speedup_em =
        bps_of[&(true, true, 4)] / bps_of[&(true, false, 1)].max(1e-12);
    let speedup_cpu =
        bps_of[&(false, true, 4)] / bps_of[&(false, false, 1)].max(1e-12);
    println!(
        "worker scaling: 4 workers + concurrent RPC vs 1 worker serial = \
         {speedup_em:.2}x (emulated network, expect >= 2.0), \
         {speedup_cpu:.2}x (cpu-only)"
    );
    std::fs::write(
        "BENCH_workers.json",
        format!(
            "{{\n  \"bench\": \"hotpath.workers\",\n  \
             \"partitions\": 3,\n  \
             \"batch\": {},\n  \
             \"batches_per_config\": {},\n  \
             \"link\": {{\"bytes_per_sec\": 1e9, \"latency_s\": 2e-4}},\n  \
             \"rows\": [\n{}\n  ],\n  \
             \"speedup_w4_concurrent_vs_w1_serial\": \
             {{\"emulated\": {speedup_em:.3}, \"cpu\": {speedup_cpu:.3}}}\n\
             }}\n",
            shape.batch,
            2 * (n_seeds / shape.batch.max(1)),
            rows_json.join(",\n"),
        ),
    )?;
    println!("wrote BENCH_workers.json");

    // --- all-reduce --------------------------------------------------------
    let param_elems: usize = vspec.param_elements();
    r.bench(
        &format!("ring all-reduce x4 trainers ({param_elems} f32)"),
        || {
            let group = AllReduceGroup::new(
                vec![0, 0, 1, 1],
                Arc::new(CostModel::default()),
            );
            let hs: Vec<_> = (0..4)
                .map(|t| {
                    let p = group.endpoint(t).unwrap();
                    std::thread::spawn(move || {
                        let mut d = vec![t as f32; 14000];
                        p.allreduce_mean(&mut d).unwrap();
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
        },
    );

    // --- PJRT train step ----------------------------------------------------
    let device =
        DeviceExecutor::spawn(artifacts_dir(), "sage_nc_dev".into(), None)?;
    let mut params = device.initial_params()?;
    let handle = device.handle();
    let batch = gen.next();
    r.bench("PJRT train_step (sage_nc_dev)", || {
        let loss = handle.train(&mut params, batch.clone(), 0.1).unwrap();
        std::hint::black_box(loss);
    });
    let batch_eval = gen.materialize_nodes(
        &cluster.val_nodes[..shape.batch.min(cluster.val_nodes.len())],
    );
    r.bench("PJRT eval_step (sage_nc_dev)", || {
        let l = handle.eval(&params, batch_eval.clone()).unwrap();
        std::hint::black_box(l.len());
    });

    println!("\n(record medians in EXPERIMENTS.md §Perf)");
    Ok(())
}
