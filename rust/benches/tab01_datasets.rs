//! Table 1: dataset statistics — the paper's four datasets and the scaled
//! RMAT instantiations this reproduction trains on (DESIGN.md §2 records
//! the substitution). Generates each scaled dataset and reports measured
//! statistics next to the paper's numbers.

use distdglv2::graph::{DatasetSpec, SplitTag};

fn main() {
    println!("=== Table 1 — dataset statistics ===\n");
    println!(
        "{:<18} {:>12} {:>12} {:>8} {:>12} | {:>10} {:>12} {:>10} {:>10}",
        "dataset",
        "paper nodes",
        "paper edges",
        "feat",
        "paper train",
        "our nodes",
        "our edges",
        "train",
        "homophily"
    );
    let paper: [(&str, &str, &str, usize, &str, usize); 4] = [
        ("ogbn-products", "2.4M", "61.9M", 100, "197K", 1000),
        ("amazon", "1.6M", "264M", 200, "1.3M", 1000),
        ("ogbn-papers100M", "111M", "3.2B", 128, "1.2M", 5000),
        ("mag-lsc", "240M", "7B", 756, "1.1M", 10000),
    ];
    for (name, pn, pe, feat, ptrain, scale) in paper {
        let spec = DatasetSpec::paper_table1(name, scale);
        let d = spec.generate();
        let train = d.nodes_with(SplitTag::Train).len();
        // homophily: fraction of edges with same-label endpoints
        let mut same = 0usize;
        let mut total = 0usize;
        for u in 0..d.n_nodes() as u32 {
            for &v in d.graph.neighbors(u) {
                total += 1;
                same += usize::from(
                    d.labels[u as usize] == d.labels[v as usize],
                );
            }
        }
        println!(
            "{:<18} {:>12} {:>12} {:>8} {:>12} | {:>10} {:>12} {:>10} {:>10.3}",
            name,
            pn,
            pe,
            feat,
            ptrain,
            d.n_nodes(),
            d.graph.n_edges(),
            train,
            same as f64 / total.max(1) as f64,
        );
    }
    println!(
        "\n(our columns are 1/scale RMAT instantiations with matching \
         feature dims, class counts and labeled fractions; scale per row: \
         1000/1000/5000/10000. mag-lsc feat scaled 756→136 to fit RAM.)"
    );
}
