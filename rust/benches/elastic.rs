//! Elastic-membership bench (docs/PERF.md §Elastic): reconfiguration
//! cost for {shrink, grow, demote-straggler} scenarios across failure
//! boundaries, decomposed into drain (pipeline teardown) + checkpoint +
//! re-split (loader/all-reduce rebuild) + warmup (pipeline refill).
//! Every shrink cell also asserts the determinism contract end to end:
//! the post-shrink tail of the elastic run is byte-identical (losses
//! and final params) to a fresh deployment of the smaller world resumed
//! from the reconfiguration checkpoint. Emits `BENCH_elastic.json`.
//! Requires `make artifacts`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::coordinator::parse_elastic_schedule;
use distdglv2::ft::{Checkpoint, FaultPlan};
use distdglv2::graph::{Dataset, DatasetSpec};
use distdglv2::pipeline::PipelineMode;
use distdglv2::runtime::manifest::artifacts_dir;
use distdglv2::trainer::{self, TrainConfig};

const EPOCHS: usize = 3;
const SEED: u64 = 29;

fn deploy(dataset: &Dataset, per: usize) -> anyhow::Result<Cluster> {
    Cluster::deploy(dataset, ClusterSpec::new(2, per), artifacts_dir())
}

fn base_cfg() -> TrainConfig {
    let mut cfg = TrainConfig {
        variant: "sage_nc_dev".into(),
        lr: 0.3,
        epochs: 1,
        seed: SEED,
        ..Default::default()
    };
    // worst case for the drain/warmup phases: deepest overlap, worker
    // pool on — the same setup the recovery bench stresses
    cfg.pipeline.mode = PipelineMode::AsyncNonstop;
    cfg.pipeline.num_workers = 2;
    cfg
}

/// Steps per epoch of a topology, probed with a one-epoch classic run.
fn probe_spe(dataset: &Dataset, per: usize) -> anyhow::Result<usize> {
    let mut cfg = base_cfg();
    cfg.epochs = 1;
    Ok(trainer::train(&deploy(dataset, per)?, &cfg)?.steps)
}

fn main() -> anyhow::Result<()> {
    let mut dspec = DatasetSpec::new("elastic-bench", 6000, 30_000);
    dspec.seed = 31;
    let dataset = dspec.generate();

    let dir = std::env::temp_dir().join("ddgl_bench_elastic");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    let spe_big = probe_spe(&dataset, 2)?; // (2 machines, 2 trainers)
    let spe_small = probe_spe(&dataset, 1)?; // (2 machines, 1 trainer)
    println!("steps/epoch: world4 {spe_big}, world2 {spe_small}");

    println!("\n=== elastic reconfiguration grid ===");
    println!(
        "{:<18} {:>5} {:>5}->{:<5} {:>8} {:>9} {:>9} {:>9} {:>9}",
        "scenario", "epoch", "from", "to", "at_step", "drain(s)",
        "ckpt(s)", "resplit(s)", "warmup(s)"
    );
    let mut rows: Vec<String> = Vec::new();

    for boundary in [1u64, 2] {
        for scenario in ["shrink", "grow", "demote"] {
            let cdir = dir.join(format!("{scenario}_{boundary}"));
            std::fs::create_dir_all(&cdir)?;
            let (per, spe) = match scenario {
                "grow" => (1, spe_small),
                _ => (2, spe_big),
            };
            let cluster = deploy(&dataset, per)?;
            let mut cfg = base_cfg();
            cfg.epochs = EPOCHS;
            cfg.max_steps = EPOCHS * spe;
            cfg.checkpoint_dir = cdir.to_string_lossy().into_owned();
            match scenario {
                "shrink" => {
                    cfg.elastic =
                        parse_elastic_schedule(&format!("{boundary}:2"))?;
                }
                "grow" => {
                    cfg.elastic =
                        parse_elastic_schedule(&format!("{boundary}:4"))?;
                }
                "demote" => {
                    // machine 1 computes far slower than the fleet; the
                    // coordinator must notice within `patience` epochs
                    let mut plan = FaultPlan::new();
                    plan.step_slowdowns
                        .push((1, Duration::from_millis(100)));
                    cluster.set_fault_plan(Arc::new(plan));
                    cfg.demote_stragglers = true;
                    cfg.straggler_factor = 2.0;
                    cfg.straggler_patience = boundary as usize;
                }
                _ => unreachable!(),
            }

            let t = Instant::now();
            let report = trainer::train(&cluster, &cfg)?;
            let wall = t.elapsed().as_secs_f64();
            assert_eq!(
                report.ft_reconfigurations, 1,
                "{scenario}@{boundary}: expected exactly one \
                 reconfiguration"
            );
            let rc = &report.reconfigurations[0];
            assert_eq!(rc.boundary, boundary);
            assert_eq!(rc.at_step, boundary as usize * spe);
            if scenario == "demote" {
                assert_eq!(report.ft_demotions, 1);
                assert_eq!(rc.demoted_machines, vec![1]);
            } else {
                assert_eq!(report.ft_demotions, 0);
            }

            // shrink determinism: fresh smaller world resumed from the
            // reconfiguration checkpoint replays the identical tail
            let identical = if scenario == "shrink" {
                let mut rcfg = base_cfg();
                rcfg.epochs = EPOCHS;
                rcfg.max_steps = EPOCHS * spe;
                rcfg.resume_from =
                    Checkpoint::path_for(&cdir, rc.at_step as u64)
                        .to_string_lossy()
                        .into_owned();
                let resumed =
                    trainer::train(&deploy(&dataset, 1)?, &rcfg)?;
                assert_eq!(resumed.resumed_at, rc.at_step as u64);
                assert_eq!(
                    resumed.loss_curve,
                    report.loss_curve[rc.at_step..].to_vec(),
                    "shrink@{boundary}: post-shrink tail diverged from \
                     the fresh smaller-world resume"
                );
                assert_eq!(
                    resumed.final_params, report.final_params,
                    "shrink@{boundary}: final params diverged"
                );
                "true"
            } else {
                "null"
            };

            println!(
                "{:<18} {:>5} {:>5}->{:<5} {:>8} {:>9.4} {:>9.4} \
                 {:>9.4} {:>9.4}",
                scenario,
                boundary,
                rc.from_world,
                rc.to_world,
                rc.at_step,
                rc.drain_secs,
                rc.checkpoint_secs,
                rc.resplit_secs,
                rc.warmup_secs,
            );
            rows.push(format!(
                "    {{\"scenario\": \"{scenario}\", \
                 \"boundary\": {boundary}, \
                 \"from_world\": {}, \"to_world\": {}, \
                 \"at_step\": {}, \"drain_secs\": {:.6}, \
                 \"checkpoint_secs\": {:.6}, \"resplit_secs\": {:.6}, \
                 \"warmup_secs\": {:.6}, \"demotions\": {}, \
                 \"wall_secs\": {wall:.6}, \"identical\": {identical}}}",
                rc.from_world,
                rc.to_world,
                rc.at_step,
                rc.drain_secs,
                rc.checkpoint_secs,
                rc.resplit_secs,
                rc.warmup_secs,
                report.ft_demotions,
            ));
        }
    }

    std::fs::write(
        "BENCH_elastic.json",
        format!(
            "{{\n  \"bench\": \"elastic\",\n  \
             \"epochs\": {EPOCHS},\n  \
             \"machines\": 2,\n  \
             \"steps_per_epoch_world4\": {spe_big},\n  \
             \"steps_per_epoch_world2\": {spe_small},\n  \
             \"pipeline\": \"nonstop\",\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            rows.join(",\n"),
        ),
    )?;
    println!("\nwrote BENCH_elastic.json");
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
