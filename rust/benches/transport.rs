//! Transport bench (docs/DESIGN.md §11): the in-process mailbox fabric
//! vs real TCP loopback sockets across a payload-size grid, plus
//! per-RPC-payload serialize/deserialize micro timings. The round-trip
//! rows measure the full `RpcClient::kv_pull` path — encode, frame,
//! deliver (queue push vs socket write + reader/demux thread), decode —
//! so the in-proc/TCP delta is the real cost of crossing a process
//! boundary. Emits `BENCH_transport.json`. Needs no artifacts.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use distdglv2::net::payload::{
    decode_kv_request, decode_kv_response, decode_sampler_response,
    encode_kv_request, encode_kv_response, encode_sampler_response,
    KvRequest, KvResponse, SamplerResponse,
};
use distdglv2::net::rpc::{serve_kv, RpcClient};
use distdglv2::net::tcp::{free_loopback_ports, tcp_transport, TcpConfig};
use distdglv2::net::{CostModel, Transport};
use distdglv2::kvstore::KvServer;
use distdglv2::sampler::service::SampledNbrs;
use distdglv2::util::bench::BenchRunner;

const DIM: usize = 64;
const ROWS: [usize; 3] = [16, 256, 4096];
const N_LOCAL: usize = 8192;

fn feat_server() -> Arc<KvServer> {
    let server = Arc::new(KvServer::new(1));
    let data: Vec<f32> =
        (0..N_LOCAL * DIM).map(|i| (i % 97) as f32 * 0.25).collect();
    server.register("feat", data, DIM);
    server
}

fn locals(n: usize) -> Vec<u32> {
    (0..n as u32).map(|i| (i * 7) % N_LOCAL as u32).collect()
}

fn main() -> anyhow::Result<()> {
    let mut r = BenchRunner::new(2, 9);
    let mut rows_json: Vec<String> = Vec::new();
    let push = |kind: &str,
                    backend: &str,
                    n_rows: usize,
                    bytes: u64,
                    s: &distdglv2::util::bench::Sample,
                    rows_json: &mut Vec<String>| {
        rows_json.push(format!(
            "    {{\"kind\": \"{kind}\", \"backend\": \"{backend}\", \
             \"rows\": {n_rows}, \"payload_bytes\": {bytes}, \
             \"median_us\": {:.3}, \"min_us\": {:.3}, \
             \"max_us\": {:.3}}}",
            s.median.as_secs_f64() * 1e6,
            s.min.as_secs_f64() * 1e6,
            s.max.as_secs_f64() * 1e6,
        ));
    };

    // --- per-payload serialize / deserialize --------------------------------
    println!("=== RPC payload codecs ===");
    for n in ROWS {
        let req = KvRequest::Pull {
            name: "feat".into(),
            locals: locals(n),
        };
        let req_buf = encode_kv_request(&req);
        let s = r.bench(&format!("ser kv_pull_req {n} rows"), || {
            std::hint::black_box(encode_kv_request(&req));
        });
        push(
            "serialize:kv_pull_req",
            "codec",
            n,
            req_buf.len() as u64,
            &s,
            &mut rows_json,
        );
        let s = r.bench(&format!("de  kv_pull_req {n} rows"), || {
            std::hint::black_box(decode_kv_request(&req_buf).unwrap());
        });
        push(
            "deserialize:kv_pull_req",
            "codec",
            n,
            req_buf.len() as u64,
            &s,
            &mut rows_json,
        );

        let resp = KvResponse::Rows {
            dim: DIM as u32,
            data: vec![1.5f32; n * DIM],
        };
        let resp_buf = encode_kv_response(&resp);
        let s = r.bench(&format!("ser kv_pull_resp {n}x{DIM}"), || {
            std::hint::black_box(encode_kv_response(&resp));
        });
        push(
            "serialize:kv_pull_resp",
            "codec",
            n,
            resp_buf.len() as u64,
            &s,
            &mut rows_json,
        );
        let s = r.bench(&format!("de  kv_pull_resp {n}x{DIM}"), || {
            std::hint::black_box(decode_kv_response(&resp_buf).unwrap());
        });
        push(
            "deserialize:kv_pull_resp",
            "codec",
            n,
            resp_buf.len() as u64,
            &s,
            &mut rows_json,
        );

        let blocks = SamplerResponse::Blocks(
            (0..n)
                .map(|i| SampledNbrs {
                    nbrs: vec![i as u32; 10],
                    rels: vec![0u8; 10],
                })
                .collect(),
        );
        let blk_buf = encode_sampler_response(&blocks);
        let s = r.bench(&format!("ser sampler_resp {n} seeds"), || {
            std::hint::black_box(encode_sampler_response(&blocks));
        });
        push(
            "serialize:sampler_resp",
            "codec",
            n,
            blk_buf.len() as u64,
            &s,
            &mut rows_json,
        );
        let s = r.bench(&format!("de  sampler_resp {n} seeds"), || {
            std::hint::black_box(
                decode_sampler_response(&blk_buf).unwrap(),
            );
        });
        push(
            "deserialize:sampler_resp",
            "codec",
            n,
            blk_buf.len() as u64,
            &s,
            &mut rows_json,
        );
    }

    // --- round trips: in-process fabric -------------------------------------
    println!("\n=== kv_pull round trip: in-process backend ===");
    {
        let t = Transport::new(2, CostModel::default());
        let server = feat_server();
        let running = Arc::new(AtomicBool::new(true));
        let h = serve_kv(t.endpoint(1), server, running.clone());
        let mut client = RpcClient::new(t.endpoint(0));
        for n in ROWS {
            let ids = locals(n);
            let bytes = (n * DIM * 4) as u64;
            let s = r.bench(
                &format!("inproc kv_pull {n}x{DIM} rows"),
                || {
                    let (_, data) =
                        client.kv_pull(1, "feat", &ids).unwrap();
                    std::hint::black_box(data.len());
                },
            );
            push(
                "roundtrip:kv_pull",
                "inproc",
                n,
                bytes,
                &s,
                &mut rows_json,
            );
        }
        running.store(false, Ordering::SeqCst);
        h.join().unwrap();
    }

    // --- round trips: real TCP loopback sockets -----------------------------
    println!("\n=== kv_pull round trip: TCP loopback backend ===");
    {
        let ports = free_loopback_ports(2)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let addrs: Vec<String> =
            ports.iter().map(|p| format!("127.0.0.1:{p}")).collect();
        let mk = |my_proc: usize| {
            let mut cfg = TcpConfig::localhost(my_proc, 2, 0);
            cfg.addrs = addrs.clone();
            tcp_transport(cfg, Arc::new(CostModel::default()))
        };
        let t0 = mk(0).map_err(|e| anyhow::anyhow!("{e}"))?;
        let t1 = mk(1).map_err(|e| anyhow::anyhow!("{e}"))?;
        let server = feat_server();
        let running = Arc::new(AtomicBool::new(true));
        let h = serve_kv(t1.endpoint(1), server, running.clone());
        let mut client = RpcClient::new(t0.endpoint(0));
        for n in ROWS {
            let ids = locals(n);
            let bytes = (n * DIM * 4) as u64;
            let s = r.bench(
                &format!("tcp    kv_pull {n}x{DIM} rows"),
                || {
                    let (_, data) =
                        client.kv_pull(1, "feat", &ids).unwrap();
                    std::hint::black_box(data.len());
                },
            );
            push(
                "roundtrip:kv_pull",
                "tcp",
                n,
                bytes,
                &s,
                &mut rows_json,
            );
        }
        running.store(false, Ordering::SeqCst);
        h.join().unwrap();
    }

    std::fs::write(
        "BENCH_transport.json",
        format!(
            "{{\n  \"bench\": \"transport\",\n  \
             \"dim\": {DIM},\n  \
             \"rows_grid\": [16, 256, 4096],\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            rows_json.join(",\n"),
        ),
    )?;
    println!("\nwrote BENCH_transport.json");
    Ok(())
}
