//! Figure 10: speedup of DistDGLv2 and DistDGL-GPU over DistDGL-CPU for
//! GraphSAGE / GAT / RGCN (node classification) + GraphSAGE (link
//! prediction) on products- and papers-shaped workloads.
//!
//! Systems (all real runs of this codebase, per the paper's framing):
//!   DistDGL-CPU  = METIS partition, sync pipeline, 1-level split, Xeon
//!   DistDGL-GPU  = same, mini-batches moved to the T4
//!   DistDGLv2    = + multi-constraint METIS, 2-level, async non-stop, T4
//!
//! Expected shape (paper): v2 2-3x over DistDGL-GPU; v2 6-30x over
//! DistDGL-CPU, growing with model complexity.

use distdglv2::benchsuite::{
    measured_epoch_secs, paper_epoch_secs, paper_spec, FigTable,
    PaperWorkload, SAMPLING_CPU_SCALE,
};
use distdglv2::sampler::compact::ModelKind;
use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::config::RunConfig;
use distdglv2::graph::DatasetSpec;
use distdglv2::pipeline::PipelineMode;
use distdglv2::runtime::manifest::{artifacts_dir, Manifest};
use distdglv2::runtime::DeviceCostModel;
use distdglv2::trainer::{self, TrainConfig};

struct System {
    label: &'static str,
    preset: fn(RunConfig) -> RunConfig,
    device: DeviceCostModel,
}

fn v2(c: RunConfig) -> RunConfig {
    c
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&artifacts_dir())?;
    let steps = 6usize;

    let systems = [
        System {
            label: "DistDGL-CPU",
            preset: |c| c.preset_distdgl_v1(),
            device: DeviceCostModel::xeon(),
        },
        System {
            label: "DistDGL-GPU",
            preset: |c| c.preset_distdgl_v1(),
            device: DeviceCostModel::t4(),
        },
        System {
            label: "DistDGLv2",
            preset: v2,
            device: DeviceCostModel::t4(),
        },
    ];

    let mut products = DatasetSpec::new("products-s", 24_000, 160_000);
    products.feat_dim = 32;
    products.num_classes = 16;
    products.train_frac = 0.082;
    let mut papers = DatasetSpec::new("papers-s", 40_000, 240_000);
    papers.feat_dim = 32;
    papers.num_classes = 16;
    papers.train_frac = 0.05;
    // (label, measured dataset, variant, lr, paper model kind,
    //  paper feat dim, paper train items)
    let workloads: Vec<(&str, &DatasetSpec, &str, f32, ModelKind, usize, usize)> = vec![
        ("SAGE-nc/products", &products, "sage_nc_dev", 0.3, ModelKind::Sage, 100, 197_000),
        ("GAT-nc/products", &products, "gat_nc_dev", 0.5, ModelKind::Gat, 100, 197_000),
        ("RGCN-nc/products", &products, "rgcn_nc_dev", 0.3, ModelKind::Rgcn, 100, 197_000),
        ("SAGE-lp/products", &products, "sage_lp_dev", 0.1, ModelKind::Sage, 100, 2_000_000),
        ("SAGE-nc/papers", &papers, "sage_nc_dev", 0.3, ModelKind::Sage, 128, 1_200_000),
        ("GAT-nc/papers", &papers, "gat_nc_dev", 0.5, ModelKind::Gat, 128, 1_200_000),
    ];

    println!(
        "Figure 10 reproduction: 4 machines x 2 trainers, {steps} measured \
         steps per cell"
    );
    let n_gpus = 32; // paper Fig 10 cluster: 4 machines x 8 T4
    for (wl, dspec, variant, lr, model, p_feat, p_train) in workloads {
        let dataset = dspec.generate();
        let spec = manifest.variant(variant)?.clone();
        let workload = PaperWorkload {
            spec: paper_spec(model, p_feat),
            train_items: p_train,
        };
        let mut table = FigTable::new(&format!("Fig 10 — {wl}"));
        for sys in &systems {
            let cfg = (sys.preset)(RunConfig::default());
            let mut cspec = ClusterSpec::new(4, 2);
            cspec.partitioner = cfg.cluster.partitioner;
            cspec.multi_constraint = cfg.cluster.multi_constraint;
            cspec.two_level = cfg.cluster.two_level;
            let cluster =
                Cluster::deploy(&dataset, cspec, artifacts_dir())?;
            let tcfg = TrainConfig {
                variant: variant.into(),
                lr,
                epochs: 1,
                max_steps: steps,
                pipeline: cfg.train.pipeline.clone(),
                ..Default::default()
            };
            let report = trainer::train(&cluster, &tcfg)?;
            let mode = if sys.label == "DistDGLv2" {
                PipelineMode::AsyncNonstop
            } else {
                tcfg.pipeline.mode
            };
            table.row(
                sys.label,
                measured_epoch_secs(&report, &cluster, &spec),
                paper_epoch_secs(
                    &report,
                    &cluster,
                    &spec,
                    &workload,
                    &sys.device,
                    mode,
                    SAMPLING_CPU_SCALE,
                    n_gpus,
                ),
            );
        }
        table.speedups("DistDGL-CPU");
    }
    println!(
        "\npaper reference: DistDGLv2 = 2-3x over DistDGL-GPU, up to 30x \
         over DistDGL-CPU (larger for complex models)."
    );
    Ok(())
}
