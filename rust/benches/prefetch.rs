//! Predictive-prefetcher bench (docs/PERF.md §prefetch): loader
//! throughput over the lookahead grid — `prefetch_depth` {0, 2, 8} ×
//! sampling workers {1, 4} × {cpu-only, emulated-network} — through the
//! public `DistNodeDataLoader` API, plus a bounded-staleness ablation
//! tracking a toy embedding-regression loss for
//! `embedding_staleness` {0, 4, 16}. Emits `BENCH_prefetch.json`.
//! Requires `make artifacts`.
//!
//! Expected shape: with network emulation on, depth 8 meets or beats
//! depth 0 in every (workers, net) cell — the lookahead thread absorbs
//! the modeled link sleeps the demand path would otherwise serve — and
//! every depth > 0 cell reports `prefetch_hits > 0`. The staleness
//! curves converge to comparable loss; K = 0 (strict) matches the
//! uncached run bit for bit.

use std::sync::Arc;
use std::time::Instant;

use distdglv2::api::{DistGraph, DistNodeDataLoader};
use distdglv2::cluster::{Cluster, ClusterSpec};
use distdglv2::graph::{DatasetSpec, NodeId};
use distdglv2::kvstore::{
    CacheAdmission, EmbeddingTable, FeatureCache, KvCluster,
    PartitionPolicy, RangePolicy,
};
use distdglv2::net::CostModel;
use distdglv2::partition::NodeMap;
use distdglv2::pipeline::{PipelineConfig, PipelineMode};
use distdglv2::runtime::manifest::{artifacts_dir, Manifest};

/// One toy sparse-embedding training run: rows regress toward a fixed
/// per-row target through gather → grad → `push_grad`, reading through
/// a caching client with a bounded-staleness window of `k` updates
/// (`cached = false` is the wire-truth baseline). Returns the per-step
/// mean-squared loss curve.
fn staleness_run(k: usize, cached: bool) -> Vec<f64> {
    const ROWS: usize = 512;
    const DIM: usize = 8;
    const BATCH: usize = 64;
    const STEPS: usize = 40;
    let nm = NodeMap { part_starts: vec![0, 256, ROWS as u32] };
    let policy: Arc<dyn PartitionPolicy> = Arc::new(RangePolicy::new(nm));
    let cluster = KvCluster::new(2, Arc::new(CostModel::default()));
    let emb = EmbeddingTable::create(
        &cluster,
        policy.as_ref(),
        "emb",
        ROWS,
        DIM,
        0.5,
        11,
    );
    let mut client = cluster.client(0, policy);
    if cached {
        client.attach_cache_sharded(
            FeatureCache::new("emb", 1 << 20, CacheAdmission::All, None),
            2,
        );
        client.set_embedding_staleness(k);
    }
    let lr = 0.2f32;
    let mut buf = vec![0f32; BATCH * DIM];
    let mut grads = vec![0f32; BATCH * DIM];
    let mut losses = Vec::with_capacity(STEPS);
    for step in 0..STEPS {
        // 64 distinct rows per step, sweeping the table (7 is odd, so
        // i*7 mod 512 never collides within a batch)
        let ids: Vec<NodeId> = (0..BATCH)
            .map(|i| ((step * 17 + i * 7) % ROWS) as NodeId)
            .collect();
        emb.gather(&mut client, &ids, &mut buf).unwrap();
        let mut loss = 0f64;
        for (j, &id) in ids.iter().enumerate() {
            let target = (id % 7) as f32 * 0.1;
            for d in 0..DIM {
                let v = buf[j * DIM + d];
                loss += ((v - target) as f64).powi(2);
                grads[j * DIM + d] = 2.0 * (v - target);
            }
        }
        losses.push(loss / (BATCH * DIM) as f64);
        emb.update(&mut client, &ids, &grads, lr).unwrap();
    }
    losses
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&artifacts_dir())?;
    let vspec = manifest.variant("sage_nc_dev")?.clone();

    let mut dspec = DatasetSpec::new("prefetch-b", 9000, 45_000);
    dspec.train_frac = 0.2;
    let dataset = dspec.generate();

    // --- lookahead grid ---------------------------------------------------
    let mut rows_json: Vec<String> = Vec::new();
    let mut bps_of = std::collections::HashMap::new();
    for emulate in [false, true] {
        for workers in [1usize, 4] {
            for depth in [0usize, 2, 8] {
                let mut spec = ClusterSpec::new(3, 1);
                spec.emulate_network_time = emulate;
                spec.prefetch_depth = depth;
                spec.cache_shards = 4;
                let cluster =
                    Cluster::deploy(&dataset, spec, artifacts_dir())?;
                let g = DistGraph::new(&cluster);
                let mut loader = DistNodeDataLoader::builder(&g, &vspec)
                    .seed(11)
                    .pipeline(PipelineConfig {
                        mode: PipelineMode::Async, // exact production count
                        ..Default::default()
                    })
                    .num_workers(workers)
                    .build()?;
                let total = 2 * loader.len();
                let t = Instant::now();
                for _ in 0..total {
                    let b = loader.next_batch();
                    std::hint::black_box(b.targets.len());
                    loader.recycle(b);
                }
                let secs = t.elapsed().as_secs_f64();
                let m = loader.metrics().clone();
                drop(loader);
                let issued = m.counter("cache.prefetch_issued");
                let hits = m.counter("cache.prefetch_hits");
                let wasted = m.counter("cache.prefetch_wasted_bytes");
                let bps = total as f64 / secs;
                let net = if emulate { "emulated" } else { "cpu" };
                bps_of.insert((emulate, workers, depth), bps);
                println!(
                    "prefetch grid: {net:>8} net, {workers} worker(s), \
                     depth {depth}: {bps:8.1} batches/s ({total} batches, \
                     issued {issued}, hits {hits}, wasted {wasted} B)"
                );
                rows_json.push(format!(
                    "    {{\"net\": \"{net}\", \"workers\": {workers}, \
                     \"depth\": {depth}, \"secs\": {secs:.6}, \
                     \"batches_per_s\": {bps:.3}, \
                     \"prefetch_issued\": {issued}, \
                     \"prefetch_hits\": {hits}, \
                     \"prefetch_wasted_bytes\": {wasted}}}"
                ));
            }
        }
    }
    for workers in [1usize, 4] {
        let s = bps_of[&(true, workers, 8)]
            / bps_of[&(true, workers, 0)].max(1e-12);
        println!(
            "emulated net, {workers} worker(s): depth 8 vs 0 = {s:.2}x \
             (expect >= 1.0)"
        );
    }

    // --- bounded-staleness ablation ---------------------------------------
    let mut stale_json: Vec<String> = Vec::new();
    let wire = staleness_run(0, false);
    for k in [0usize, 4, 16] {
        let losses = staleness_run(k, true);
        if k == 0 {
            assert_eq!(
                losses, wire,
                "strict mode must match the uncached run bit for bit"
            );
        }
        let curve: Vec<String> =
            losses.iter().map(|l| format!("{l:.6}")).collect();
        println!(
            "staleness K={k:>2}: first {:.4} -> final {:.4}",
            losses[0],
            losses.last().unwrap()
        );
        stale_json.push(format!(
            "    {{\"staleness\": {k}, \"final_loss\": {:.6}, \
             \"losses\": [{}]}}",
            losses.last().unwrap(),
            curve.join(", ")
        ));
    }

    std::fs::write(
        "BENCH_prefetch.json",
        format!(
            "{{\n  \"bench\": \"prefetch.lookahead\",\n  \
             \"machines\": 3,\n  \
             \"batch\": {},\n  \
             \"rows\": [\n{}\n  ],\n  \
             \"staleness_ablation\": [\n{}\n  ]\n}}\n",
            vspec.batch,
            rows_json.join(",\n"),
            stale_json.join(",\n"),
        ),
    )?;
    println!("wrote BENCH_prefetch.json");
    Ok(())
}
