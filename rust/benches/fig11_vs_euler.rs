//! Figure 11: DistDGLv2 vs Euler (CPU and GPU) training GraphSAGE on the
//! products-shaped workload.
//!
//! Euler (per §6.1): random partitioning, multiprocessing-only parallelism
//! — one trainer process per GPU with *no* sampling thread, so sampling
//! serializes with compute (sync pipeline, sampling-CPU scale 1) and the
//! random partitioning inflates cross-machine feature traffic.
//!
//! Expected shape (paper): Euler-GPU ≈ Euler-CPU (GPU can't help when
//! sampling + data movement dominate); DistDGLv2 ≈ 18x over both.

use distdglv2::benchsuite::{
    measured_epoch_secs, paper_epoch_secs, paper_spec, FigTable,
    PaperWorkload, SAMPLING_CPU_SCALE,
};
use distdglv2::sampler::compact::ModelKind;
use distdglv2::cluster::{Cluster, ClusterSpec, Partitioner};
use distdglv2::graph::DatasetSpec;
use distdglv2::pipeline::{PipelineConfig, PipelineMode};
use distdglv2::runtime::manifest::{artifacts_dir, Manifest};
use distdglv2::runtime::DeviceCostModel;
use distdglv2::trainer::{self, TrainConfig};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&artifacts_dir())?;
    let spec = manifest.variant("sage_nc_dev")?.clone();

    let mut dspec = DatasetSpec::new("products-s", 24_000, 160_000);
    dspec.feat_dim = 32;
    dspec.num_classes = 16;
    dspec.train_frac = 0.082;
    let dataset = dspec.generate();

    let steps = 6;
    let mut table =
        FigTable::new("Fig 11 — GraphSAGE on products: vs Euler");

    // (label, partitioner, pipeline mode, device, sampling scale)
    let cells: [(&str, Partitioner, PipelineMode, DeviceCostModel, f64); 3] = [
        (
            "Euler-CPU",
            Partitioner::Random,
            PipelineMode::Sync,
            DeviceCostModel::xeon(),
            1.0,
        ),
        (
            "Euler-GPU",
            Partitioner::Random,
            PipelineMode::Sync,
            DeviceCostModel::t4(),
            1.0,
        ),
        (
            "DistDGLv2",
            Partitioner::Metis,
            PipelineMode::AsyncNonstop,
            DeviceCostModel::t4(),
            SAMPLING_CPU_SCALE,
        ),
    ];

    for (label, part, mode, device, scale) in cells {
        let mut cspec = ClusterSpec::new(4, 2);
        cspec.partitioner = part;
        cspec.multi_constraint = part == Partitioner::Metis;
        cspec.two_level = part == Partitioner::Metis;
        let cluster = Cluster::deploy(&dataset, cspec, artifacts_dir())?;
        let tcfg = TrainConfig {
            variant: "sage_nc_dev".into(),
            lr: 0.3,
            epochs: 1,
            max_steps: steps,
            pipeline: PipelineConfig { mode, ..Default::default() },
            ..Default::default()
        };
        let report = trainer::train(&cluster, &tcfg)?;
        let workload = PaperWorkload {
            spec: paper_spec(ModelKind::Sage, 100),
            train_items: 197_000,
        };
        table.row(
            label,
            measured_epoch_secs(&report, &cluster, &spec),
            paper_epoch_secs(
                &report, &cluster, &spec, &workload, &device, mode, scale,
                32,
            ),
        );
    }
    table.speedups("Euler-CPU");
    let gpu = table.modeled_of("Euler-GPU").unwrap();
    let cpu = table.modeled_of("Euler-CPU").unwrap();
    println!(
        "\nEuler-GPU / Euler-CPU modeled ratio = {:.2} (paper: ≈1, GPU \
         gives Euler no speedup); paper reference: DistDGLv2 ≈ 18x over \
         both.",
        cpu / gpu
    );
    Ok(())
}
