//! distdglv2 — CLI launcher for the DistDGLv2 reproduction.
//!
//! Subcommands:
//!   partition  key=value...   partition a dataset and report quality
//!   train      key=value...   deploy a simulated cluster and train
//!   info                      list available AOT variants
//!
//! All keys are documented by `config::RunConfig::set` (any invalid key
//! prints the full list). `train` drives `trainer::train`, the thin
//! built-in client of the `api::DistGraph` / `api::DistNodeDataLoader`
//! surface — custom loops use the same API directly
//! (`examples/custom_loop.rs`).

use std::path::PathBuf;

use anyhow::Result;

use distdglv2::cluster::Cluster;
use distdglv2::config::RunConfig;
use distdglv2::runtime::manifest::{artifacts_dir, Manifest};
use distdglv2::trainer;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: Vec<String>) -> Result<()> {
    let Some((cmd, rest)) = args.split_first() else {
        print_usage();
        return Ok(());
    };
    match cmd.as_str() {
        "partition" => cmd_partition(rest.to_vec()),
        "train" => cmd_train(rest.to_vec()),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            anyhow::bail!("unknown command {other:?}")
        }
    }
}

fn print_usage() {
    eprintln!(
        "usage: distdglv2 <command> [key=value ...]\n\
         commands:\n  \
         partition   generate + partition a dataset, report edge cut,\n              \
         balance and timing (Table 2 inputs)\n  \
         train       deploy the simulated cluster and run synchronous\n              \
         data-parallel training\n  \
         info        list AOT model variants available in artifacts/\n\
         examples:\n  \
         distdglv2 train dataset=rmat:20000:120000 machines=2 trainers=2\n  \
         distdglv2 train dataset=ogbn-products@1000 variant=sage_nc_dev\n  \
         distdglv2 partition dataset=ogbn-papers100M@100000 machines=8"
    );
}

fn artifacts() -> PathBuf {
    artifacts_dir()
}

/// Remove and return `key=value` from the arg list, if present.
fn take_kv(args: &mut Vec<String>, key: &str) -> Option<String> {
    let prefix = format!("{key}=");
    let pos = args.iter().position(|a| a.starts_with(&prefix))?;
    Some(args.remove(pos)[prefix.len()..].to_string())
}

fn cmd_partition(mut args: Vec<String>) -> Result<()> {
    // optional out=<path>: persist the generated dataset bundle for reuse
    // ("partition once, train many runs", Table 2)
    let out = take_kv(&mut args, "out");
    let cfg = RunConfig::from_args(args)?;
    println!(
        "generating {} ({} nodes, {} edges target)...",
        cfg.dataset.name, cfg.dataset.n_nodes, cfg.dataset.n_edges
    );
    let d = cfg.dataset.generate();
    println!(
        "generated: {} nodes, {} edges",
        d.n_nodes(),
        d.graph.n_edges()
    );
    if let Some(path) = out {
        distdglv2::graph::bundle::save_dataset(
            &d,
            std::path::Path::new(&path),
        )?;
        println!("saved dataset bundle to {path}");
    }
    let cluster = Cluster::deploy(&d, cfg.cluster.clone(), artifacts())?;
    let s = &cluster.stats;
    println!("partitions           {}", cfg.cluster.n_machines);
    println!("edge cut             {}", s.edge_cut);
    println!("edge cut fraction    {:.4}", cluster.edge_cut_frac());
    println!("imbalance            {:.3}", s.imbalance);
    println!("partition time       {:.3}s", s.partition_secs);
    println!("build (halo/relabel) {:.3}s", s.build_secs);
    println!("kvstore load         {:.3}s", s.load_secs);
    for p in &cluster.partitions {
        println!(
            "  part {}: {} core, {} halo, {} edges",
            p.part_id,
            p.n_core,
            p.n_halo(),
            p.graph.n_edges()
        );
    }
    Ok(())
}

fn cmd_train(mut args: Vec<String>) -> Result<()> {
    // optional from=<path>: load a saved dataset bundle instead of
    // generating (skips the preprocessing cost on reruns)
    let from = take_kv(&mut args, "from");
    let cfg = RunConfig::from_args(args)?;
    println!(
        "dataset {} | {} machines x {} trainers | variant {} | pipeline {:?}",
        cfg.dataset.name,
        cfg.cluster.n_machines,
        cfg.cluster.trainers_per_machine,
        cfg.train.variant,
        cfg.train.pipeline.mode,
    );
    let d = match &from {
        Some(path) => {
            let d = distdglv2::graph::bundle::load_dataset(
                std::path::Path::new(path),
            )?;
            println!("loaded dataset bundle from {path}");
            d
        }
        None => cfg.dataset.generate(),
    };
    let cluster = Cluster::deploy(&d, cfg.cluster.clone(), artifacts())?;
    println!(
        "deployed: edge_cut={} partition={:.2}s train_items/trainer={}",
        cluster.stats.edge_cut,
        cluster.stats.partition_secs,
        cluster.train_sets[0].len()
    );
    let report = trainer::train(&cluster, &cfg.train)?;
    for e in &report.epochs {
        println!(
            "epoch {:>3}  loss {:.4}  {:.2}s",
            e.epoch, e.mean_loss, e.secs
        );
    }
    println!(
        "total {:.2}s | {} steps | {:.1} steps/s | net {} B | pcie {} B | \
         remote rows {}",
        report.total_secs,
        report.steps,
        report.steps as f64 / report.total_secs,
        report.net_bytes,
        report.pcie_bytes,
        report.remote_feature_rows,
    );
    if let Some(acc) = report.final_val_acc {
        println!("val accuracy {acc:.4}");
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let m = Manifest::load(&artifacts())?;
    println!("artifacts: {:?} (block {})", m.dir, m.block);
    for (name, v) in &m.variants {
        println!(
            "  {name}: {:?} {:?} batch={} fanouts={:?} nodes={:?} \
             feat={} classes={} params={}",
            v.model,
            v.task,
            v.batch,
            v.fanouts,
            v.layer_nodes,
            v.feat_dim,
            v.num_classes,
            v.n_params(),
        );
    }
    Ok(())
}
