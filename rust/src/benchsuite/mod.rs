//! Bench-harness support: shared workload builders and the hybrid
//! measurement model used by every `rust/benches/*` binary.
//!
//! Methodology (DESIGN.md §2): this testbed is one CPU core, the paper's
//! is 8×(96 vCPU + 8×T4) with 100 Gbps. Every bench therefore reports two
//! series:
//!
//! 1. **measured** — real wall-clock of the full system at reduced scale
//!    (all protocol work, sampling, compaction, PJRT execution is real);
//! 2. **modeled** — the paper-testbed epoch time from the classic pipeline
//!    bound: per-stage times (sampling CPU, network, PCIe, device) are
//!    derived from the *measured byte counts and stage timings* of (1),
//!    then combined as `sum(stages)` for a synchronous pipeline or
//!    `max(stages)` for the asynchronous one.
//!
//! Speedup *shapes* (who wins, by what factor, where crossovers fall) are
//! the reproduction target, not absolute numbers.

use crate::cluster::Cluster;
use crate::net::CostModel;
use crate::pipeline::PipelineMode;
use crate::runtime::manifest::VariantSpec;
use crate::runtime::DeviceCostModel;
use crate::trainer::TrainReport;

/// Paper-testbed link parameters.
pub const NET_BYTES_PER_SEC: f64 = 11e9; // 100 Gbps effective
pub const NET_LATENCY_S: f64 = 20e-6;
pub const PCIE_BYTES_PER_SEC: f64 = 12e9;

/// How much faster the paper's 96-vCPU machines run the (multithreaded)
/// sampling stages than this testbed's single core. The paper runs
/// several sampler threads per trainer; 8 is a deliberately conservative
/// sustained factor.
pub const SAMPLING_CPU_SCALE: f64 = 8.0;

/// Per-step stage times (seconds) for the pipeline bound.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimes {
    pub sample: f64,
    pub net: f64,
    pub pcie: f64,
    pub device: f64,
    pub allreduce: f64,
}

impl StageTimes {
    /// Synchronous pipeline: stages serialize.
    pub fn sync_step(&self) -> f64 {
        self.sample + self.net + self.pcie + self.device + self.allreduce
    }

    /// Asynchronous pipeline: sampling/transfer overlap device compute;
    /// the all-reduce barrier stays on the critical path.
    pub fn async_step(&self) -> f64 {
        self.sample.max(self.net).max(self.pcie).max(self.device)
            + self.allreduce
    }

    pub fn step(&self, mode: PipelineMode) -> f64 {
        match mode {
            PipelineMode::Sync => self.sync_step(),
            PipelineMode::Async | PipelineMode::AsyncNonstop => {
                self.async_step()
            }
        }
    }
}

/// Derive paper-testbed stage times from a measured run.
///
/// `device` selects the mini-batch compute device (T4 vs Xeon — the
/// paper's GPU/CPU comparison axis); network/PCIe come from measured byte
/// counts; sampling comes from measured CPU time scaled by
/// [`SAMPLING_CPU_SCALE`].
pub fn stage_times(
    report: &TrainReport,
    cluster: &Cluster,
    spec: &VariantSpec,
    device: &DeviceCostModel,
) -> StageTimes {
    stage_times_scaled(report, cluster, spec, device, SAMPLING_CPU_SCALE)
}

/// Like [`stage_times`] with an explicit sampling-CPU scale: systems that
/// cannot multithread sampling within a trainer (Euler, §6.1) get 1.0.
pub fn stage_times_scaled(
    report: &TrainReport,
    cluster: &Cluster,
    spec: &VariantSpec,
    device: &DeviceCostModel,
    sampling_scale: f64,
) -> StageTimes {
    let n_trainers = cluster.n_trainers();
    let steps_total = (report.steps * n_trainers).max(1) as f64;
    // per-trainer-step averages
    let net_bytes = report.net_bytes as f64 / steps_total;
    let net_msgs =
        cluster.cost.network_msgs() as f64 / steps_total; // approx
    let pcie_bytes = report.pcie_bytes as f64 / steps_total;
    let produced = (report.batches_produced as f64).max(steps_total);
    let sample = report.sample_secs / produced / sampling_scale;
    // ring all-reduce: 2(N-1)/N * params across the slowest (network) links
    let param_bytes: f64 = spec.param_elements() as f64 * 4.0;
    let n = n_trainers as f64;
    let ar_bytes = 2.0 * (n - 1.0) / n * param_bytes;
    let allreduce = ar_bytes / NET_BYTES_PER_SEC
        + 2.0 * (n - 1.0) * NET_LATENCY_S;
    StageTimes {
        sample,
        net: net_bytes / NET_BYTES_PER_SEC + net_msgs * NET_LATENCY_S,
        pcie: pcie_bytes / PCIE_BYTES_PER_SEC,
        device: device.step_secs(spec, true),
        allreduce,
    }
}

/// Modeled epoch seconds on the paper testbed for a measured run.
pub fn modeled_epoch_secs(
    report: &TrainReport,
    cluster: &Cluster,
    spec: &VariantSpec,
    device: &DeviceCostModel,
    mode: PipelineMode,
) -> f64 {
    modeled_epoch_secs_scaled(
        report, cluster, spec, device, mode, SAMPLING_CPU_SCALE,
    )
}

/// [`modeled_epoch_secs`] with an explicit sampling-CPU scale.
pub fn modeled_epoch_secs_scaled(
    report: &TrainReport,
    cluster: &Cluster,
    spec: &VariantSpec,
    device: &DeviceCostModel,
    mode: PipelineMode,
    sampling_scale: f64,
) -> f64 {
    let st = stage_times_scaled(report, cluster, spec, device, sampling_scale);
    let steps_per_epoch = cluster.batches_per_epoch(spec.batch, 0);
    let mut t = st.step(mode) * steps_per_epoch as f64;
    if mode == PipelineMode::Async {
        // per-epoch pipeline refill: one full sequential batch latency
        t += st.sync_step();
    }
    t
}

/// Wall-clock seconds per epoch from a measured run.
pub fn measured_epoch_secs(report: &TrainReport, cluster: &Cluster, spec: &VariantSpec) -> f64 {
    let steps_per_epoch = cluster.batches_per_epoch(spec.batch, 0) as f64;
    report.total_secs / report.steps.max(1) as f64 * steps_per_epoch
}

/// Pretty-print one figure row: `label  measured  modeled  speedup-vs-base`.
pub struct FigTable {
    pub title: String,
    rows: Vec<(String, f64, f64)>,
}

impl FigTable {
    pub fn new(title: &str) -> Self {
        println!("\n=== {title} ===");
        println!(
            "{:<44} {:>12} {:>14}",
            "configuration", "measured", "modeled(paper)"
        );
        Self { title: title.to_string(), rows: Vec::new() }
    }

    pub fn row(&mut self, label: &str, measured: f64, modeled: f64) {
        println!(
            "{:<44} {:>11.3}s {:>13.4}s",
            label, measured, modeled
        );
        self.rows.push((label.to_string(), measured, modeled));
    }

    /// Print speedups of every row relative to `base_label`.
    pub fn speedups(&self, base_label: &str) {
        let Some(base) = self.rows.iter().find(|r| r.0 == base_label)
        else {
            return;
        };
        println!("-- speedup over {base_label} --");
        for (label, m, md) in &self.rows {
            println!(
                "{:<44} {:>10.2}x (measured) {:>10.2}x (modeled)",
                label,
                base.1 / m,
                base.2 / md
            );
        }
    }

    pub fn modeled_of(&self, label: &str) -> Option<f64> {
        self.rows.iter().find(|r| r.0 == label).map(|r| r.2)
    }

    pub fn measured_of(&self, label: &str) -> Option<f64> {
        self.rows.iter().find(|r| r.0 == label).map(|r| r.1)
    }
}

/// Fresh cost model with paper link parameters (per-bench isolation).
pub fn paper_cost_model() -> CostModel {
    CostModel::new(NET_BYTES_PER_SEC, NET_LATENCY_S, PCIE_BYTES_PER_SEC)
}

/// FeatureCache hit rate over a measured run's remote feature accesses
/// (hits / (hits + misses)); 0 when the cache was disabled or never
/// consulted.
pub fn cache_hit_rate(report: &TrainReport) -> f64 {
    let total = report.cache_hit_rows + report.cache_miss_rows;
    if total == 0 {
        0.0
    } else {
        report.cache_hit_rows as f64 / total as f64
    }
}

/// One-line locality/cache summary for bench logs: makes partition
/// quality, cache effectiveness, layer-cap pressure, and (on typed runs)
/// the per-etype sampled-edge mix visible next to every figure row
/// instead of buried in per-batch fields.
pub fn locality_summary(report: &TrainReport) -> String {
    let mut s = format!(
        "remote rows fetched {} | cache hits {} ({:.1}% hit rate, \
         {} B saved) | dropped neighbors {}",
        report.remote_feature_rows,
        report.cache_hit_rows,
        100.0 * cache_hit_rate(report),
        report.cache_remote_bytes_saved,
        report.dropped_neighbors,
    );
    if !report.etype_sampled_edges.is_empty() {
        let counts: Vec<String> = report
            .etype_sampled_edges
            .iter()
            .enumerate()
            .map(|(r, c)| format!("r{r}:{c}"))
            .collect();
        s.push_str(&format!(
            " | sampled edges/etype [{}]",
            counts.join(" ")
        ));
    }
    // per-stage CPU attribution (aggregated across sampling workers) and
    // BatchPool effectiveness
    s.push_str(&format!(
        " | stage secs sched:{:.3} sample:{:.3} pull:{:.3} compact:{:.3} \
         | pool hit {} / miss {} / dropped {}",
        report.stage_schedule_secs,
        report.stage_sample_secs,
        report.stage_pull_secs,
        report.stage_compact_secs,
        report.pool_hit,
        report.pool_miss,
        report.pool_dropped,
    ));
    // predictive-prefetcher effectiveness (docs/DESIGN.md §10): only
    // shown when a lookahead actually ran
    if report.cache_prefetch_issued > 0 {
        s.push_str(&format!(
            " | prefetch issued {} hits {} wasted {} B pins {} \
             ({:.3}s lookahead cpu)",
            report.cache_prefetch_issued,
            report.cache_prefetch_hits,
            report.cache_prefetch_wasted_bytes,
            report.cache_pinned_rows,
            report.stage_prefetch_secs,
        ));
    }
    // fault-tolerance counters (docs/DESIGN.md §8-9): only shown when
    // the run checkpointed, resumed, reconfigured, or absorbed injected
    // faults
    if report.ft_checkpoints > 0
        || report.ft_retries > 0
        || report.ft_injected_failures > 0
        || report.resumed_at > 0
        || report.ft_reconfigurations > 0
    {
        s.push_str(&format!(
            " | ft ckpts {} ({} B) retries {} failures {} \
             resumed@{} recovery {:.3}s reconfigs {} demotions {}",
            report.ft_checkpoints,
            report.ft_checkpoint_bytes,
            report.ft_retries,
            report.ft_injected_failures,
            report.resumed_at,
            report.ft_recovery_secs,
            report.ft_reconfigurations,
            report.ft_demotions,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_step_dominates_async_step() {
        let st = StageTimes {
            sample: 2e-3,
            net: 1e-3,
            pcie: 0.5e-3,
            device: 1.5e-3,
            allreduce: 0.2e-3,
        };
        assert!(st.sync_step() > st.async_step());
        // async bound = slowest stage + barrier
        assert!((st.async_step() - (2e-3 + 0.2e-3)).abs() < 1e-9);
    }
}

// ---------------------------------------------------------------------------
// Paper-workload projection: calibrate unit costs from a measured run, then
// re-scale to the paper's workload shapes (batch 1000, fanout 15/10/5,
// feat 100-756). This is what gives the modeled series real stage contrast:
// at dev shapes the device dominates everything; at paper shapes sampling
// and feature movement matter, which is exactly the regime the paper's
// figures live in.
// ---------------------------------------------------------------------------

use crate::sampler::compact::{ModelKind, TaskKind};

/// A paper-scale workload description for one figure row.
#[derive(Clone, Debug)]
pub struct PaperWorkload {
    pub spec: VariantSpec,
    /// Global training items (nodes or edges) — sets steps per epoch.
    pub train_items: usize,
}

/// Representative paper-shape specs (§6 hyper-parameters).
pub fn paper_spec(model: ModelKind, feat_dim: usize) -> VariantSpec {
    let (fanouts, layer_nodes, hidden): (Vec<usize>, Vec<usize>, usize) =
        match model {
            ModelKind::Rgcn => {
                // 2 layers, fanout 15/25, hidden 1024
                (vec![15, 25], vec![50_000, 10_400, 1_000], 1024)
            }
            _ => {
                // 3 layers, fanout 15/10/5, hidden 256
                (vec![15, 10, 5], vec![64_000, 13_000, 3_000, 1_000], 256)
            }
        };
    let n_layers = fanouts.len();
    let mut param_shapes = Vec::new();
    for l in 0..n_layers {
        let f_in = if l == 0 { feat_dim } else { hidden };
        let f_out = if l + 1 == n_layers { 172 } else { hidden };
        param_shapes.push(vec![f_in, f_out]);
        param_shapes.push(vec![f_in, f_out]);
        param_shapes.push(vec![f_out]);
    }
    VariantSpec {
        name: format!("paper-{model:?}"),
        model,
        task: TaskKind::NodeClassification,
        batch: 1000,
        fanouts,
        layer_nodes,
        feat_dim,
        num_classes: 172,
        num_heads: 2,
        num_rels: 4,
        param_shapes,
        train_inputs: Vec::new(),
        eval_inputs: Vec::new(),
        train_hlo: String::new(),
        eval_hlo: String::new(),
        params_bin: String::new(),
    }
}

fn sampled_edges(spec: &VariantSpec) -> f64 {
    (1..=spec.fanouts.len())
        .map(|l| (spec.layer_nodes[l] * spec.fanouts[l - 1]) as f64)
        .sum()
}

/// Project a measured run onto a paper workload: per-step stage times.
///
/// Calibration: sampling cost per sampled edge and the remote-row fraction
/// come from the measured run (they encode partition locality + pipeline
/// behaviour); transfer times are bytes/bandwidth at paper shapes; device
/// time is the roofline at paper shapes.
pub fn paper_stage_times(
    report: &TrainReport,
    cluster: &Cluster,
    our_spec: &VariantSpec,
    paper: &VariantSpec,
    device: &DeviceCostModel,
    sampling_scale: f64,
) -> StageTimes {
    let n_trainers = cluster.n_trainers().max(1);
    let steps_total = (report.steps * n_trainers).max(1) as f64;

    // measured unit costs (normalize by batches actually produced — the
    // non-stop pipeline overproduces a few batches at teardown)
    let produced = (report.batches_produced as f64).max(steps_total);
    let sample_per_edge = report.sample_secs
        / produced
        / sampled_edges(our_spec).max(1.0)
        / sampling_scale;
    let our_rows = our_spec.layer_nodes[0] as f64;
    let remote_frac = (report.remote_feature_rows as f64 / steps_total
        / our_rows)
        .min(1.0);

    // paper-shape per-step quantities
    let p_edges = sampled_edges(paper);
    let p_rows = paper.layer_nodes[0] as f64;
    let feat_bytes = p_rows * paper.feat_dim as f64 * 4.0;
    let idx_bytes: f64 = (1..=paper.fanouts.len())
        .map(|l| {
            (paper.layer_nodes[l] * (1 + 2 * paper.fanouts[l - 1])) as f64
                * 4.0
        })
        .sum();
    let net_bytes = remote_frac * feat_bytes;
    // one batched request per remote machine per layer+feature pull
    let msgs = (cluster.spec.n_machines.saturating_sub(1)
        * (paper.fanouts.len() + 1)) as f64;

    let n = n_trainers as f64;
    let param_bytes: f64 = paper.param_elements() as f64 * 4.0;
    StageTimes {
        sample: sample_per_edge * p_edges,
        net: net_bytes / NET_BYTES_PER_SEC + msgs * NET_LATENCY_S,
        pcie: (feat_bytes + idx_bytes) / PCIE_BYTES_PER_SEC,
        device: device.step_secs(paper, true),
        allreduce: 2.0 * (n - 1.0) / n * param_bytes / NET_BYTES_PER_SEC
            + 2.0 * (n - 1.0) * NET_LATENCY_S,
    }
}

/// Paper-testbed epoch seconds for a figure row.
#[allow(clippy::too_many_arguments)]
pub fn paper_epoch_secs(
    report: &TrainReport,
    cluster: &Cluster,
    our_spec: &VariantSpec,
    workload: &PaperWorkload,
    device: &DeviceCostModel,
    mode: PipelineMode,
    sampling_scale: f64,
    n_gpus: usize,
) -> f64 {
    let st = paper_stage_times(
        report, cluster, our_spec, &workload.spec, device, sampling_scale,
    );
    let steps = workload
        .train_items
        .div_ceil(workload.spec.batch * n_gpus.max(1))
        .max(1);
    let mut t = st.step(mode) * steps as f64;
    if mode == PipelineMode::Async {
        t += st.sync_step(); // per-epoch refill
    }
    t
}
