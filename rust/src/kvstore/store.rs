//! KvServer / KvClient: batched pull & sparse push with locality-aware
//! routing and full byte accounting.

use std::sync::{Arc, RwLock};

use rustc_hash::FxHashMap;

use crate::graph::NodeId;
use crate::net::CostModel;

use super::cache::{CacheStats, FeatureCache};
use super::policy::PartitionPolicy;

/// One named tensor shard on a server: `n_local x dim`, row-major.
struct Shard {
    data: RwLock<Vec<f32>>,
    dim: usize,
}

/// Per-machine KV server: holds the local shard of every registered tensor.
pub struct KvServer {
    pub machine: u32,
    shards: RwLock<FxHashMap<String, Arc<Shard>>>,
}

impl KvServer {
    pub fn new(machine: u32) -> Self {
        Self { machine, shards: RwLock::new(FxHashMap::default()) }
    }

    /// Register a tensor shard with initial data (`n_local * dim`).
    pub fn register(&self, name: &str, data: Vec<f32>, dim: usize) {
        assert_eq!(data.len() % dim.max(1), 0);
        self.shards.write().unwrap().insert(
            name.to_string(),
            Arc::new(Shard { data: RwLock::new(data), dim }),
        );
    }

    fn shard(&self, name: &str) -> Arc<Shard> {
        self.shards
            .read()
            .unwrap()
            .get(name)
            .unwrap_or_else(|| panic!("tensor {name:?} not registered"))
            .clone()
    }

    /// Copy rows `locals` into `out` (len = locals.len() * dim).
    pub fn read_rows(&self, name: &str, locals: &[u32], out: &mut [f32]) {
        let shard = self.shard(name);
        let dim = shard.dim;
        let data = shard.data.read().unwrap();
        for (i, &l) in locals.iter().enumerate() {
            let src = &data[l as usize * dim..(l as usize + 1) * dim];
            out[i * dim..(i + 1) * dim].copy_from_slice(src);
        }
    }

    /// Copy row `locals[i]` straight into `out[slots[i]*dim..]` — the
    /// scatter variant [`KvClient::pull`] uses to skip the intermediate
    /// response buffer (§Perf: one copy per row instead of two).
    pub fn read_rows_scattered(
        &self,
        name: &str,
        locals: &[u32],
        slots: &[usize],
        out: &mut [f32],
    ) {
        let shard = self.shard(name);
        let dim = shard.dim;
        let data = shard.data.read().unwrap();
        for (&l, &slot) in locals.iter().zip(slots) {
            let src = &data[l as usize * dim..(l as usize + 1) * dim];
            out[slot * dim..(slot + 1) * dim].copy_from_slice(src);
        }
    }

    /// Row-sparse SGD update: `row[l] -= lr * grad[i]` for each local row.
    pub fn apply_grads(
        &self,
        name: &str,
        locals: &[u32],
        grads: &[f32],
        lr: f32,
    ) {
        let shard = self.shard(name);
        let dim = shard.dim;
        assert_eq!(grads.len(), locals.len() * dim);
        let mut data = shard.data.write().unwrap();
        for (i, &l) in locals.iter().enumerate() {
            let dst = &mut data[l as usize * dim..(l as usize + 1) * dim];
            for (d, g) in dst.iter_mut().zip(&grads[i * dim..(i + 1) * dim]) {
                *d -= lr * g;
            }
        }
    }

    pub fn dim_of(&self, name: &str) -> usize {
        self.shard(name).dim
    }
}

/// The whole distributed store: one server per machine + shared policy and
/// cost model. Clone-able handle ([`KvClient`]) per trainer.
pub struct KvCluster {
    pub servers: Vec<Arc<KvServer>>,
    pub cost: Arc<CostModel>,
    /// Emulate modeled link time with sleeps (wall-clock fidelity knob).
    pub emulate_network_time: bool,
}

impl KvCluster {
    pub fn new(n_machines: usize, cost: Arc<CostModel>) -> Arc<Self> {
        Arc::new(Self {
            servers: (0..n_machines as u32)
                .map(|m| Arc::new(KvServer::new(m)))
                .collect(),
            cost,
            emulate_network_time: false,
        })
    }

    pub fn with_emulated_network(
        n_machines: usize,
        cost: Arc<CostModel>,
    ) -> Arc<Self> {
        Arc::new(Self {
            servers: (0..n_machines as u32)
                .map(|m| Arc::new(KvServer::new(m)))
                .collect(),
            cost,
            emulate_network_time: true,
        })
    }

    /// Register a globally partitioned tensor: `rows[gid]` goes to
    /// `policy.owner(gid)`. `rows` is the full `n x dim` array.
    pub fn register_partitioned(
        &self,
        name: &str,
        rows: &[f32],
        dim: usize,
        policy: &dyn PartitionPolicy,
    ) {
        let n = rows.len() / dim.max(1);
        let mut per: Vec<Vec<f32>> = (0..policy.n_parts())
            .map(|p| Vec::with_capacity(policy.n_local(p as u32) * dim))
            .collect();
        // RangePolicy rows arrive in local order because ids are contiguous
        // per part; HashPolicy interleaves — local_of defines the layout.
        let mut locals: Vec<Vec<(u32, usize)>> =
            vec![Vec::new(); policy.n_parts()];
        for gid in 0..n as NodeId {
            locals[policy.owner(gid) as usize]
                .push((policy.local_of(gid), gid as usize));
        }
        for (p, l) in locals.iter_mut().enumerate() {
            l.sort_unstable_by_key(|e| e.0);
            for &(_, gid) in l.iter() {
                per[p].extend_from_slice(&rows[gid * dim..(gid + 1) * dim]);
            }
        }
        for (p, data) in per.into_iter().enumerate() {
            self.servers[p].register(name, data, dim);
        }
    }

    pub fn client(
        self: &Arc<Self>,
        machine: u32,
        policy: Arc<dyn PartitionPolicy>,
    ) -> KvClient {
        KvClient {
            cluster: Arc::clone(self),
            machine,
            policy,
            cache: None,
            pull_groups: Vec::new(),
            push_groups: Vec::new(),
        }
    }
}

/// Trainer-side handle: pulls/pushes with owner routing.
///
/// The per-owner grouping buffers are owned by the client and reused
/// across calls (§Perf: the mini-batch hot path performs zero steady-state
/// allocations here), which is why [`Self::pull`] and [`Self::push_grad`]
/// take `&mut self`. An optional [`FeatureCache`] serves repeated remote
/// rows from trainer memory.
pub struct KvClient {
    cluster: Arc<KvCluster>,
    pub machine: u32,
    policy: Arc<dyn PartitionPolicy>,
    cache: Option<FeatureCache>,
    /// Reusable per-owner (locals, out-slots) grouping scratch for `pull`.
    pull_groups: Vec<(Vec<u32>, Vec<usize>)>,
    /// Reusable per-owner (locals, grads) grouping scratch for `push_grad`.
    push_groups: Vec<(Vec<u32>, Vec<f32>)>,
}

impl KvClient {
    /// Attach a remote-row cache. Pulls of `cache.tensor()` consult it;
    /// all other tensors are unaffected.
    pub fn attach_cache(&mut self, cache: FeatureCache) {
        self.cache = Some(cache);
    }

    pub fn cache(&self) -> Option<&FeatureCache> {
        self.cache.as_ref()
    }

    /// Cumulative cache counters, if a cache is attached.
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// Cache counters accumulated since the last call (for metrics
    /// publication); `None` when no cache is attached.
    pub fn take_cache_delta(&mut self) -> Option<CacheStats> {
        self.cache.as_mut().map(|c| c.take_delta())
    }

    /// Pull rows for `ids` into `out` (len = ids.len() * dim). Local rows
    /// are a direct shared-memory copy; remote rows are served from the
    /// [`FeatureCache`] when possible, otherwise grouped per owner into
    /// one batched request each, with request+response bytes metered.
    /// Returns the number of rows actually *fetched* from remote machines
    /// (locality observability — cache hits do not count).
    pub fn pull(
        &mut self,
        name: &str,
        ids: &[NodeId],
        out: &mut [f32],
    ) -> usize {
        let dim = self.cluster.servers[self.machine as usize]
            .dim_of_or(name)
            .unwrap_or_else(|| self.remote_dim(name));
        assert!(out.len() >= ids.len() * dim);
        // group by owner, remembering destination slots (reused scratch)
        let nparts = self.policy.n_parts();
        let mut groups = std::mem::take(&mut self.pull_groups);
        if groups.len() != nparts {
            groups.resize_with(nparts, Default::default);
        }
        for g in groups.iter_mut() {
            g.0.clear();
            g.1.clear();
        }
        let use_cache = self
            .cache
            .as_ref()
            .is_some_and(|c| c.is_enabled() && c.tensor() == name);
        if use_cache {
            self.cache.as_mut().unwrap().ensure_dim(dim);
        }
        for (slot, &gid) in ids.iter().enumerate() {
            let owner = self.policy.owner(gid) as usize;
            if use_cache && owner as u32 != self.machine {
                let c = self.cache.as_mut().unwrap();
                if c.lookup(gid, &mut out[slot * dim..(slot + 1) * dim]) {
                    continue;
                }
            }
            groups[owner].0.push(self.policy.local_of(gid));
            groups[owner].1.push(slot);
        }
        let mut remote_rows = 0usize;
        for (owner, (locals, slots)) in groups.iter().enumerate() {
            if locals.is_empty() {
                continue;
            }
            let server = &self.cluster.servers[owner];
            if owner as u32 != self.machine {
                remote_rows += locals.len();
                let req_bytes = 16 + locals.len() as u64 * 4;
                let resp_bytes = 16 + (locals.len() * dim) as u64 * 4;
                self.cluster.cost.on_network(
                    self.machine,
                    owner as u32,
                    req_bytes,
                );
                self.cluster.cost.on_network(
                    owner as u32,
                    self.machine,
                    resp_bytes,
                );
                if self.cluster.emulate_network_time {
                    let secs = (req_bytes + resp_bytes) as f64
                        / self.cluster.cost.net_bytes_per_sec
                        + 2.0 * self.cluster.cost.net_latency_s;
                    spin_sleep(secs);
                }
            }
            // copy straight into the output slots (local and remote alike)
            server.read_rows_scattered(name, locals, slots, out);
            if use_cache && owner as u32 != self.machine {
                let c = self.cache.as_mut().unwrap();
                for &slot in slots.iter() {
                    c.insert(
                        ids[slot],
                        &out[slot * dim..(slot + 1) * dim],
                    );
                }
            }
        }
        self.pull_groups = groups;
        remote_rows
    }

    /// Push row gradients (sparse embedding update, §3.1 "sparse
    /// parameters"): routed to owners, applied as SGD on the server.
    pub fn push_grad(
        &mut self,
        name: &str,
        ids: &[NodeId],
        grads: &[f32],
        lr: f32,
    ) {
        // coherence: a sparse update through this client must not leave
        // stale cached copies behind
        if let Some(c) = self.cache.as_mut() {
            if c.tensor() == name {
                c.invalidate(ids);
            }
        }
        let dim = grads.len() / ids.len().max(1);
        let nparts = self.policy.n_parts();
        let mut groups = std::mem::take(&mut self.push_groups);
        if groups.len() != nparts {
            groups.resize_with(nparts, Default::default);
        }
        for g in groups.iter_mut() {
            g.0.clear();
            g.1.clear();
        }
        for (i, &gid) in ids.iter().enumerate() {
            let owner = self.policy.owner(gid) as usize;
            groups[owner].0.push(self.policy.local_of(gid));
            groups[owner]
                .1
                .extend_from_slice(&grads[i * dim..(i + 1) * dim]);
        }
        for (owner, (locals, g)) in groups.iter().enumerate() {
            if locals.is_empty() {
                continue;
            }
            if owner as u32 != self.machine {
                let bytes = 16 + (locals.len() * (1 + dim)) as u64 * 4;
                self.cluster.cost.on_network(
                    self.machine,
                    owner as u32,
                    bytes,
                );
            }
            self.cluster.servers[owner].apply_grads(name, locals, g, lr);
        }
        self.push_groups = groups;
    }

    fn remote_dim(&self, name: &str) -> usize {
        for s in &self.cluster.servers {
            if let Some(d) = s.dim_of_or(name) {
                return d;
            }
        }
        panic!("tensor {name:?} not registered anywhere");
    }
}

impl KvServer {
    fn dim_of_or(&self, name: &str) -> Option<usize> {
        self.shards.read().unwrap().get(name).map(|s| s.dim)
    }
}

/// Sleep `secs` with reasonable sub-millisecond accuracy.
fn spin_sleep(secs: f64) {
    if secs <= 0.0 {
        return;
    }
    let dur = std::time::Duration::from_secs_f64(secs);
    if dur > std::time::Duration::from_micros(200) {
        std::thread::sleep(dur);
    } else {
        let t = std::time::Instant::now();
        while t.elapsed() < dur {
            std::hint::spin_loop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::policy::{HashPolicy, RangePolicy};
    use crate::partition::NodeMap;

    fn rows(n: usize, dim: usize) -> Vec<f32> {
        (0..n * dim).map(|i| i as f32).collect()
    }

    fn range_cluster(
        dim: usize,
    ) -> (Arc<KvCluster>, Arc<dyn PartitionPolicy>, Vec<f32>) {
        // 3 machines owning [0,10), [10,25), [25,30)
        let nm = NodeMap { part_starts: vec![0, 10, 25, 30] };
        let policy: Arc<dyn PartitionPolicy> =
            Arc::new(RangePolicy::new(nm));
        let cost = Arc::new(CostModel::default());
        let cluster = KvCluster::new(3, cost);
        let data = rows(30, dim);
        cluster.register_partitioned("feat", &data, dim, policy.as_ref());
        (cluster, policy, data)
    }

    #[test]
    fn pull_returns_correct_rows_local_and_remote() {
        let dim = 4;
        let (cluster, policy, data) = range_cluster(dim);
        let mut client = cluster.client(1, policy);
        let ids: Vec<NodeId> = vec![12, 0, 29, 14]; // local, remote, remote, local
        let mut out = vec![0f32; ids.len() * dim];
        let remote = client.pull("feat", &ids, &mut out);
        assert_eq!(remote, 2);
        for (i, &gid) in ids.iter().enumerate() {
            assert_eq!(
                &out[i * dim..(i + 1) * dim],
                &data[gid as usize * dim..(gid as usize + 1) * dim],
                "row {gid}"
            );
        }
    }

    #[test]
    fn local_pull_is_free_remote_metered() {
        let dim = 8;
        let (cluster, policy, _) = range_cluster(dim);
        let mut client = cluster.client(0, policy);
        let mut out = vec![0f32; dim];
        client.pull("feat", &[3], &mut out);
        assert_eq!(cluster.cost.network_bytes(), 0);
        client.pull("feat", &[27], &mut out);
        assert!(cluster.cost.network_bytes() > 0);
    }

    #[test]
    fn push_grad_applies_sgd_on_owner() {
        let dim = 2;
        let (cluster, policy, data) = range_cluster(dim);
        let mut client = cluster.client(0, policy);
        let ids = vec![5 as NodeId, 20];
        let grads = vec![1.0f32, 1.0, 2.0, 2.0];
        client.push_grad("feat", &ids, &grads, 0.5);
        let mut out = vec![0f32; 2 * dim];
        client.pull("feat", &ids, &mut out);
        assert_eq!(out[0], data[10] - 0.5);
        assert_eq!(out[2], data[40] - 1.0);
    }

    #[test]
    fn hash_policy_roundtrip() {
        let dim = 3;
        let policy: Arc<dyn PartitionPolicy> =
            Arc::new(HashPolicy { nparts: 2, n_rows: 11 });
        let cost = Arc::new(CostModel::default());
        let cluster = KvCluster::new(2, cost);
        let data = rows(11, dim);
        cluster.register_partitioned("x", &data, dim, policy.as_ref());
        let mut client = cluster.client(0, policy);
        let ids: Vec<NodeId> = (0..11).collect();
        let mut out = vec![0f32; 11 * dim];
        client.pull("x", &ids, &mut out);
        assert_eq!(out, data);
    }

    /// Property: pull over random id multisets always equals the source.
    #[test]
    fn prop_pull_matches_source() {
        crate::util::proptest::forall(
            31,
            20,
            |r| {
                let k = 1 + r.usize_below(50);
                let ids: Vec<NodeId> =
                    (0..k).map(|_| r.below(30) as NodeId).collect();
                ids
            },
            |ids| {
                let dim = 4;
                let (cluster, policy, data) = range_cluster(dim);
                let mut client = cluster.client(2, policy);
                let mut out = vec![0f32; ids.len() * dim];
                client.pull("feat", ids, &mut out);
                for (i, &gid) in ids.iter().enumerate() {
                    let expect =
                        &data[gid as usize * dim..(gid as usize + 1) * dim];
                    if &out[i * dim..(i + 1) * dim] != expect {
                        return Err(format!("row {gid} mismatch"));
                    }
                }
                Ok(())
            },
        );
    }

    fn feat_cache(budget: usize) -> FeatureCache {
        use crate::kvstore::cache::CacheAdmission;
        FeatureCache::new("feat", budget, CacheAdmission::All, None)
    }

    #[test]
    fn cached_pull_is_byte_identical_and_skips_the_wire() {
        let dim = 4;
        let (cluster, policy, data) = range_cluster(dim);
        let mut client = cluster.client(1, policy);
        client.attach_cache(feat_cache(1 << 20));
        let ids: Vec<NodeId> = vec![12, 0, 29, 14, 0, 27];
        let mut cold = vec![0f32; ids.len() * dim];
        let fetched_cold = client.pull("feat", &ids, &mut cold);
        let bytes_after_cold = cluster.cost.network_bytes();
        assert!(fetched_cold > 0 && bytes_after_cold > 0);
        // warm pull: every remote row is cached → no new network bytes,
        // and the result matches the source byte for byte
        let mut warm = vec![0f32; ids.len() * dim];
        let fetched_warm = client.pull("feat", &ids, &mut warm);
        assert_eq!(fetched_warm, 0);
        assert_eq!(cluster.cost.network_bytes(), bytes_after_cold);
        assert_eq!(cold, warm);
        for (i, &gid) in ids.iter().enumerate() {
            assert_eq!(
                &warm[i * dim..(i + 1) * dim],
                &data[gid as usize * dim..(gid as usize + 1) * dim],
                "row {gid}"
            );
        }
        let s = client.cache_stats().unwrap();
        assert!(s.hit_rows > 0 && s.remote_bytes_saved > 0);
        assert!(s.hit_rate() > 0.0);
    }

    #[test]
    fn zero_budget_cache_degenerates_to_uncached() {
        let dim = 4;
        let (c1, policy, _) = range_cluster(dim);
        let (c2, policy2, _) = range_cluster(dim);
        let mut plain = c1.client(1, policy);
        let mut zeroed = c2.client(1, policy2);
        zeroed.attach_cache(feat_cache(0));
        let ids: Vec<NodeId> = vec![0, 12, 29, 0, 5];
        let mut a = vec![0f32; ids.len() * dim];
        let mut b = vec![0f32; ids.len() * dim];
        for _ in 0..2 {
            let ra = plain.pull("feat", &ids, &mut a);
            let rb = zeroed.pull("feat", &ids, &mut b);
            assert_eq!(ra, rb);
            assert_eq!(a, b);
        }
        assert_eq!(c1.cost.network_bytes(), c2.cost.network_bytes());
        let s = zeroed.cache_stats().unwrap();
        assert_eq!(s.hit_rows + s.miss_rows, 0);
    }

    #[test]
    fn push_grad_invalidates_cached_rows() {
        let dim = 2;
        let (cluster, policy, data) = range_cluster(dim);
        let mut client = cluster.client(0, policy);
        client.attach_cache(feat_cache(1 << 20));
        let ids = vec![20 as NodeId]; // remote for machine 0
        let mut out = vec![0f32; dim];
        client.pull("feat", &ids, &mut out); // populate cache
        let grads = vec![2.0f32, 2.0];
        client.push_grad("feat", &ids, &grads, 0.5);
        client.pull("feat", &ids, &mut out);
        assert_eq!(out[0], data[40] - 1.0, "stale cached row served");
    }

    #[test]
    fn repeated_pulls_reuse_scratch_capacity() {
        // grouping scratch survives across calls: nothing observable
        // changes, results stay correct over many mixed pulls
        let dim = 3;
        let (cluster, policy, data) = range_cluster(dim);
        let mut client = cluster.client(2, policy);
        let mut out = vec![0f32; 30 * dim];
        for round in 0..5 {
            let k = 5 + round * 5;
            let ids: Vec<NodeId> =
                (0..k).map(|i| ((i * 7 + round) % 30) as NodeId).collect();
            client.pull("feat", &ids, &mut out[..k * dim]);
            for (i, &gid) in ids.iter().enumerate() {
                assert_eq!(
                    &out[i * dim..(i + 1) * dim],
                    &data[gid as usize * dim..(gid as usize + 1) * dim]
                );
            }
        }
    }

    #[test]
    fn concurrent_pulls_are_safe() {
        let dim = 4;
        let (cluster, policy, data) = range_cluster(dim);
        let hs: Vec<_> = (0..3u32)
            .map(|m| {
                let mut c = cluster.client(m, policy.clone());
                let data = data.clone();
                std::thread::spawn(move || {
                    let mut out = vec![0f32; dim];
                    for gid in 0..30u32 {
                        c.pull("feat", &[gid], &mut out);
                        assert_eq!(
                            &out[..],
                            &data[gid as usize * dim..(gid as usize + 1) * dim]
                        );
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    }
}
