//! KvServer / KvClient: batched pull & sparse push with locality-aware
//! routing and full byte accounting.
//!
//! §Perf: remote per-owner pulls are dispatched **concurrently** (one
//! scoped thread per remote owner; the local shard is scattered on the
//! calling thread), so under `emulate_network_time` a pull's wall clock
//! is the max over owners instead of the sum. Remote rows stage through
//! a per-owner response buffer on that path (the wire's response framing)
//! and are scattered — and offered to the [`FeatureCache`], in owner
//! order, so cache state evolves exactly as in the serial loop — after
//! the join. Byte metering and returned bytes are identical with
//! concurrency on or off (test-enforced).

use std::sync::{Arc, Mutex, RwLock};

use rustc_hash::FxHashMap;

use crate::ft::{parse_replica_table, replica_table, FaultPlan, ReplicaSet};
use crate::graph::{GraphSchema, NodeId};
use crate::net::{CostModel, RpcError};

use super::cache::{CacheStats, FeatureCache, SharedFeatureCache};
use super::policy::PartitionPolicy;

/// View over the per-ntype feature tables of one deployment: tensor name
/// and row width per node type, plus the node→type lookup (empty = every
/// node is type 0). A homogeneous graph uses the trivial single-entry
/// view whose tensor name is the bare base name, so the typed pull path
/// degenerates to the classic one byte for byte — same code, trivial
/// schema.
#[derive(Clone)]
pub struct TypedFeatures {
    /// Base tensor name ("feat"); also what the [`FeatureCache`] binds.
    pub base: String,
    /// Per-ntype tensor names: `base` itself when homogeneous, else
    /// `base.<ntype-name>`.
    pub names: Vec<String>,
    /// Per-ntype row widths.
    pub dims: Vec<usize>,
    /// Node → ntype (new-ID order); empty = all type 0.
    pub node_type: Arc<Vec<u8>>,
}

impl TypedFeatures {
    pub fn homogeneous(base: &str, dim: usize) -> Self {
        Self {
            base: base.to_string(),
            names: vec![base.to_string()],
            dims: vec![dim],
            node_type: Arc::new(Vec::new()),
        }
    }

    /// Build the view a [`GraphSchema`] implies. `node_type` must be in
    /// the same (relabeled) ID space the KVStore is registered in.
    pub fn from_schema(
        base: &str,
        schema: &GraphSchema,
        node_type: Arc<Vec<u8>>,
    ) -> Self {
        if schema.n_ntypes() <= 1 {
            return Self::homogeneous(base, schema.max_feat_dim());
        }
        Self {
            base: base.to_string(),
            names: schema
                .ntypes
                .iter()
                .map(|t| format!("{base}.{}", t.name))
                .collect(),
            dims: schema.ntypes.iter().map(|t| t.feat_dim).collect(),
            node_type,
        }
    }

    pub fn n_ntypes(&self) -> usize {
        self.names.len()
    }

    pub fn is_single(&self) -> bool {
        self.names.len() == 1
    }

    #[inline]
    pub fn ntype_of(&self, gid: NodeId) -> u8 {
        if self.node_type.is_empty() {
            0
        } else {
            self.node_type[gid as usize]
        }
    }

    pub fn max_dim(&self) -> usize {
        self.dims.iter().copied().max().unwrap_or(0)
    }
}

/// One named tensor shard on a server: `n_local x dim`, row-major.
struct Shard {
    data: RwLock<Vec<f32>>,
    dim: usize,
}

/// Per-machine KV server: holds the local shard of every registered tensor.
pub struct KvServer {
    pub machine: u32,
    shards: RwLock<FxHashMap<String, Arc<Shard>>>,
}

impl KvServer {
    pub fn new(machine: u32) -> Self {
        Self { machine, shards: RwLock::new(FxHashMap::default()) }
    }

    /// Register a tensor shard with initial data (`n_local * dim`).
    pub fn register(&self, name: &str, data: Vec<f32>, dim: usize) {
        assert_eq!(data.len() % dim.max(1), 0);
        self.shards.write().unwrap().insert(
            name.to_string(),
            Arc::new(Shard { data: RwLock::new(data), dim }),
        );
    }

    /// The local shard of `name`, or the typed decode error a real
    /// server would send back for a request naming an unknown tensor.
    fn shard(&self, name: &str) -> Result<Arc<Shard>, RpcError> {
        self.shards.read().unwrap().get(name).cloned().ok_or_else(|| {
            RpcError::UnknownTensor {
                name: name.to_string(),
                machine: self.machine,
            }
        })
    }

    /// Snapshot every shard as `(name, dim, rows)`, name-sorted so the
    /// encoding — and therefore a checkpoint file — is deterministic.
    pub fn export_shards(&self) -> Vec<(String, usize, Vec<f32>)> {
        let shards = self.shards.read().unwrap();
        let mut out: Vec<(String, usize, Vec<f32>)> = shards
            .iter()
            .map(|(name, s)| {
                (name.clone(), s.dim, s.data.read().unwrap().clone())
            })
            .collect();
        out.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Overwrite (or create) one shard from a checkpoint snapshot.
    pub fn import_shard(&self, name: &str, dim: usize, data: Vec<f32>) {
        self.register(name, data, dim);
    }

    /// Copy rows `locals` into `out` (len = locals.len() * dim).
    pub fn read_rows(
        &self,
        name: &str,
        locals: &[u32],
        out: &mut [f32],
    ) -> Result<(), RpcError> {
        let shard = self.shard(name)?;
        let dim = shard.dim;
        let data = shard.data.read().unwrap();
        for (i, &l) in locals.iter().enumerate() {
            let src = &data[l as usize * dim..(l as usize + 1) * dim];
            out[i * dim..(i + 1) * dim].copy_from_slice(src);
        }
        Ok(())
    }

    /// Copy row `locals[i]` straight into
    /// `out[slots[i]*stride .. slots[i]*stride + dim]` — the scatter
    /// variant [`KvClient::pull`] uses to skip the intermediate response
    /// buffer (§Perf: one copy per row instead of two). `stride == dim`
    /// is the classic dense layout; typed pulls use a wider stride and
    /// leave the row tail to the caller.
    pub fn read_rows_scattered(
        &self,
        name: &str,
        locals: &[u32],
        slots: &[usize],
        out: &mut [f32],
        stride: usize,
    ) -> Result<(), RpcError> {
        let shard = self.shard(name)?;
        let dim = shard.dim;
        debug_assert!(stride >= dim);
        let data = shard.data.read().unwrap();
        for (&l, &slot) in locals.iter().zip(slots) {
            let src = &data[l as usize * dim..(l as usize + 1) * dim];
            out[slot * stride..slot * stride + dim].copy_from_slice(src);
        }
        Ok(())
    }

    /// Row-sparse SGD update: `row[l] -= lr * grad[i]` for each local row.
    pub fn apply_grads(
        &self,
        name: &str,
        locals: &[u32],
        grads: &[f32],
        lr: f32,
    ) -> Result<(), RpcError> {
        let shard = self.shard(name)?;
        let dim = shard.dim;
        assert_eq!(grads.len(), locals.len() * dim);
        let mut data = shard.data.write().unwrap();
        for (i, &l) in locals.iter().enumerate() {
            let dst = &mut data[l as usize * dim..(l as usize + 1) * dim];
            for (d, g) in dst.iter_mut().zip(&grads[i * dim..(i + 1) * dim]) {
                *d -= lr * g;
            }
        }
        Ok(())
    }

    pub fn dim_of(&self, name: &str) -> Result<usize, RpcError> {
        Ok(self.shard(name)?.dim)
    }
}

/// The whole distributed store: one server per machine + shared policy and
/// cost model. Clone-able handle ([`KvClient`]) per trainer.
pub struct KvCluster {
    pub servers: Vec<Arc<KvServer>>,
    pub cost: Arc<CostModel>,
    /// Emulate modeled link time with sleeps (wall-clock fidelity knob).
    pub emulate_network_time: bool,
    /// Dispatch per-owner remote pulls concurrently (max-over-owners wall
    /// clock under emulation). `false` restores the serial owner loop;
    /// bytes and results are identical either way.
    pub concurrent_fanout: bool,
    /// Injected-fault schedule shared by every client (including forks
    /// created before the plan was installed — they read this slot per
    /// request). `None` = fault-free.
    fault: Mutex<Option<Arc<FaultPlan>>>,
    /// Primary/backup replication state ([`ReplicaSet`]), installed by
    /// [`Self::enable_replication`]. `None` = unreplicated: a dead
    /// server surfaces as the PR-6 typed error instead of failing over.
    replicas: Mutex<Option<Arc<ReplicaSet>>>,
}

impl KvCluster {
    pub fn new(n_machines: usize, cost: Arc<CostModel>) -> Arc<Self> {
        Self::with_options(n_machines, cost, false, true)
    }

    pub fn with_emulated_network(
        n_machines: usize,
        cost: Arc<CostModel>,
    ) -> Arc<Self> {
        Self::with_options(n_machines, cost, true, true)
    }

    /// Full-knob constructor (`emulate_network_time`, `concurrent_fanout`).
    pub fn with_options(
        n_machines: usize,
        cost: Arc<CostModel>,
        emulate_network_time: bool,
        concurrent_fanout: bool,
    ) -> Arc<Self> {
        Arc::new(Self {
            servers: (0..n_machines as u32)
                .map(|m| Arc::new(KvServer::new(m)))
                .collect(),
            cost,
            emulate_network_time,
            concurrent_fanout,
            fault: Mutex::new(None),
            replicas: Mutex::new(None),
        })
    }

    /// Install an injected-fault schedule: every subsequent request from
    /// any client of this cluster is gated through `plan`.
    pub fn set_fault_plan(&self, plan: Arc<FaultPlan>) {
        *self.fault.lock().unwrap() = Some(plan);
    }

    /// The installed fault plan, if any.
    pub fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.fault.lock().unwrap().clone()
    }

    /// Materialize each machine's shards on its ring neighbor
    /// `(m + 1) % M` under [`replica_table`] names and install the
    /// [`ReplicaSet`] every client consults (docs/DESIGN.md §12).
    /// Covers the tensors registered *so far* — deploy calls this after
    /// registration. From here on, [`KvClient::push_grad`] writes
    /// through to primary and backup, and pulls fail over transparently
    /// once a primary exhausts its retry budget. Idempotent: a second
    /// call returns the installed set without copying again.
    pub fn enable_replication(&self) -> Arc<ReplicaSet> {
        if let Some(rs) = self.replica_set() {
            return rs;
        }
        let rs = Arc::new(ReplicaSet::new(self.servers.len()));
        for (m, server) in self.servers.iter().enumerate() {
            let standby = rs.replica_owner(m as u32) as usize;
            for (name, dim, data) in server.export_shards() {
                rs.add_replica_bytes((data.len() * 4) as u64);
                self.servers[standby].import_shard(
                    &replica_table(m as u32, &name),
                    dim,
                    data,
                );
            }
        }
        *self.replicas.lock().unwrap() = Some(Arc::clone(&rs));
        rs
    }

    /// The installed replication state, if any.
    pub fn replica_set(&self) -> Option<Arc<ReplicaSet>> {
        self.replicas.lock().unwrap().clone()
    }

    /// Restart path: rebuild machine `m`'s primary shards from its
    /// standby's replica tables — the authoritative copy while `m` was
    /// down (write-through kept updating it) — then flip routing back
    /// to the primary. Returns the bytes re-imported; the transfer is
    /// timed into the `pipeline.failover` decomposition as re-import.
    pub fn rejoin_server(&self, m: u32) -> u64 {
        let rs = self
            .replica_set()
            .expect("rejoin_server needs enable_replication first");
        let standby = rs.replica_owner(m) as usize;
        let t0 = std::time::Instant::now();
        let mut bytes = 0u64;
        for (name, dim, data) in self.servers[standby].export_shards() {
            if let Some((owner, base)) = parse_replica_table(&name) {
                if owner == m {
                    bytes += (data.len() * 4) as u64;
                    self.servers[m as usize].import_shard(base, dim, data);
                }
            }
        }
        rs.note_reimport(t0.elapsed());
        rs.add_replica_bytes(bytes);
        rs.mark_rejoined(m);
        bytes
    }

    /// Meter (and, under emulation, sleep for) one remote owner's pull
    /// round-trip of `n_rows` rows of width `dim`.
    fn meter_pull(&self, src: u32, owner: u32, n_rows: usize, dim: usize) {
        // sizes derive from the real framed encoding (net::payload,
        // regression-tested against the codec); name_len = 0 models an
        // interned tensor id, constant per request
        let req_bytes = crate::net::payload::kv_pull_req_bytes(0, n_rows);
        let resp_bytes = crate::net::payload::kv_pull_resp_bytes(n_rows, dim);
        self.cost.on_network(src, owner, req_bytes);
        self.cost.on_network(owner, src, resp_bytes);
        if self.emulate_network_time {
            let secs = (req_bytes + resp_bytes) as f64
                / self.cost.net_bytes_per_sec
                + 2.0 * self.cost.net_latency_s;
            // straggler emulation: a slow machine stretches every link
            // it terminates (docs/DESIGN.md §8)
            spin_sleep(secs * self.cost.pair_slowdown(src, owner));
        }
    }

    /// Register a globally partitioned tensor: `rows[gid]` goes to
    /// `policy.owner(gid)`. `rows` is the full `n x dim` array.
    pub fn register_partitioned(
        &self,
        name: &str,
        rows: &[f32],
        dim: usize,
        policy: &dyn PartitionPolicy,
    ) {
        let n = rows.len() / dim.max(1);
        let mut per: Vec<Vec<f32>> = (0..policy.n_parts())
            .map(|p| Vec::with_capacity(policy.n_local(p as u32) * dim))
            .collect();
        // RangePolicy rows arrive in local order because ids are contiguous
        // per part; HashPolicy interleaves — local_of defines the layout.
        let mut locals: Vec<Vec<(u32, usize)>> =
            vec![Vec::new(); policy.n_parts()];
        for gid in 0..n as NodeId {
            locals[policy.owner(gid) as usize]
                .push((policy.local_of(gid), gid as usize));
        }
        for (p, l) in locals.iter_mut().enumerate() {
            l.sort_unstable_by_key(|e| e.0);
            for &(_, gid) in l.iter() {
                per[p].extend_from_slice(&rows[gid * dim..(gid + 1) * dim]);
            }
        }
        for (p, data) in per.into_iter().enumerate() {
            self.servers[p].register(name, data, dim);
        }
    }

    /// Register the per-ntype feature tables a [`TypedFeatures`] view
    /// describes. `feats` is the uniform `n x src_dim` source matrix;
    /// ntype `t`'s table keeps the first `dims[t]` columns of the rows
    /// whose node is of type `t` (other rows stay zero and are never
    /// pulled through the typed path). The single-table view registers
    /// the source matrix as-is — byte-identical to the untyped layout.
    ///
    /// Capacity tradeoff: every table spans all `n` rows so the shared
    /// `RangePolicy` local ids work unchanged — at R ntypes that stores
    /// zero rows for the (R-1)/R of nodes not of each type. Compacting
    /// to per-ntype row indexes needs a typed local-id map threaded
    /// through the policy layer; deliberately out of scope here.
    pub fn register_typed(
        &self,
        tf: &TypedFeatures,
        feats: &[f32],
        src_dim: usize,
        policy: &dyn PartitionPolicy,
    ) {
        if tf.is_single() {
            assert_eq!(tf.dims[0], src_dim);
            self.register_partitioned(&tf.names[0], feats, src_dim, policy);
            return;
        }
        let n = feats.len() / src_dim.max(1);
        for (t, (name, &dim)) in
            tf.names.iter().zip(&tf.dims).enumerate()
        {
            assert!(dim <= src_dim, "ntype {name} dim {dim} > {src_dim}");
            let mut rows = vec![0f32; n * dim];
            for gid in 0..n {
                if tf.ntype_of(gid as NodeId) as usize == t {
                    rows[gid * dim..(gid + 1) * dim].copy_from_slice(
                        &feats[gid * src_dim..gid * src_dim + dim],
                    );
                }
            }
            self.register_partitioned(name, &rows, dim, policy);
        }
    }

    pub fn client(
        self: &Arc<Self>,
        machine: u32,
        policy: Arc<dyn PartitionPolicy>,
    ) -> KvClient {
        KvClient {
            cluster: Arc::clone(self),
            machine,
            policy,
            cache: None,
            pull_groups: Vec::new(),
            push_groups: Vec::new(),
            typed_groups: Vec::new(),
            slot_scratch: Vec::new(),
            pull_stage: Vec::new(),
            embedding_staleness: 0,
            stale_updates: 0,
            stale_ids: Vec::new(),
        }
    }
}

/// Trainer-side handle: pulls/pushes with owner routing.
///
/// The per-owner grouping buffers are owned by the client and reused
/// across calls (§Perf: the mini-batch hot path performs zero steady-state
/// allocations here), which is why [`Self::pull`] and [`Self::push_grad`]
/// take `&mut self`. An optional [`SharedFeatureCache`] serves repeated
/// remote rows from trainer memory; it stripes the byte budget across
/// `cache_shards` independently-locked [`FeatureCache`]s so that
/// [`Self::fork`]ed worker handles (and the background prefetcher)
/// share one budget and one working set without serializing on a
/// single lock.
pub struct KvClient {
    cluster: Arc<KvCluster>,
    pub machine: u32,
    policy: Arc<dyn PartitionPolicy>,
    cache: Option<Arc<SharedFeatureCache>>,
    /// Reusable per-owner (locals, id-indices) grouping scratch for
    /// `pull`/`pull_typed`.
    pull_groups: Vec<(Vec<u32>, Vec<usize>)>,
    /// Reusable per-owner (locals, grads) grouping scratch for `push_grad`.
    push_groups: Vec<(Vec<u32>, Vec<f32>)>,
    /// Reusable per-ntype (ids, out-slots) grouping scratch for
    /// `pull_typed`.
    typed_groups: Vec<(Vec<NodeId>, Vec<usize>)>,
    /// Reusable slot-mapping scratch for the typed scatter.
    slot_scratch: Vec<usize>,
    /// Reusable per-owner response staging buffers for the concurrent
    /// fan-out path (the wire's response framing; §Perf: capacity is
    /// retained across batches, keeping the hot path allocation-free).
    pull_stage: Vec<Vec<f32>>,
    /// Bounded-staleness window for learnable embeddings: `0` (strict,
    /// the default) invalidates cached rows on every `push_grad`, so
    /// reads are byte-identical to an uncached client; `K > 0` lets
    /// cached embedding rows lag the store by at most K sparse updates
    /// (the DistGNN-style accuracy-vs-speed knob).
    embedding_staleness: usize,
    /// Updates since the last staleness flush (strict mode leaves it 0).
    stale_updates: usize,
    /// Ids touched by updates since the last staleness flush.
    stale_ids: Vec<NodeId>,
}

impl KvClient {
    /// Attach a remote-row cache with a single stripe. Pulls of
    /// `cache.tensor()` consult it; all other tensors are unaffected.
    pub fn attach_cache(&mut self, cache: FeatureCache) {
        self.attach_cache_sharded(cache, 1);
    }

    /// Attach a remote-row cache striped `n_shards` ways: the budget is
    /// split evenly and rows route by `gid % n_shards`, so prefetch
    /// inserts and worker lookups on different stripes never contend.
    pub fn attach_cache_sharded(&mut self, cache: FeatureCache, n_shards: usize) {
        self.cache = Some(Arc::new(SharedFeatureCache::new(cache, n_shards)));
    }

    /// The shared cache handle, if any (what [`Self::fork`] propagates).
    pub fn shared_cache(&self) -> Option<Arc<SharedFeatureCache>> {
        self.cache.clone()
    }

    /// Bound the staleness of cached learnable-embedding rows: with
    /// `k == 0` (strict), every sparse update invalidates the cached
    /// copies it touched immediately; with `k > 0`, invalidations are
    /// batched and flushed every `k`-th update, so a cached row is
    /// never more than `k` updates behind the store.
    pub fn set_embedding_staleness(&mut self, k: usize) {
        self.embedding_staleness = k;
    }

    /// An independent handle over the same cluster for a sampling
    /// worker: same machine / policy / shared [`SharedFeatureCache`],
    /// private grouping scratch. Cache *contents* under N forks depend
    /// on which worker fetches a row first (hit/miss counters are
    /// schedule-dependent); returned bytes never do — the cache is
    /// value-transparent. The staleness window is inherited, but the
    /// pending-invalidation accumulator is per-handle (each fork flushes
    /// its own update stream).
    pub fn fork(&self) -> KvClient {
        KvClient {
            cluster: Arc::clone(&self.cluster),
            machine: self.machine,
            policy: self.policy.clone(),
            cache: self.cache.clone(),
            pull_groups: Vec::new(),
            push_groups: Vec::new(),
            typed_groups: Vec::new(),
            slot_scratch: Vec::new(),
            pull_stage: Vec::new(),
            embedding_staleness: self.embedding_staleness,
            stale_updates: 0,
            stale_ids: Vec::new(),
        }
    }

    /// Cumulative cache counters (summed over stripes), if a cache is
    /// attached.
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// Cache counters accumulated since the last call *on any fork of
    /// this client* (the delta cursor is shared cache state); `None`
    /// when no cache is attached.
    pub fn take_cache_delta(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.take_delta())
    }

    /// Pull rows for `ids` into `out` (len = ids.len() * dim). Local rows
    /// are a direct shared-memory copy; remote rows are served from the
    /// [`FeatureCache`] when possible, otherwise grouped per owner into
    /// one batched request each, with request+response bytes metered.
    /// Returns the number of rows actually *fetched* from remote machines
    /// (locality observability — cache hits do not count), or the typed
    /// RPC error an unknown tensor / injected outage produces (§8:
    /// errors propagate as values so the pipeline drains cleanly).
    pub fn pull(
        &mut self,
        name: &str,
        ids: &[NodeId],
        out: &mut [f32],
    ) -> Result<usize, RpcError> {
        let dim = match self.cluster.servers[self.machine as usize]
            .dim_of_or(name)
        {
            Some(d) => d,
            Option::None => self.remote_dim(name)?,
        };
        assert!(out.len() >= ids.len() * dim);
        let use_cache = self.cache_gate(name, &[dim]);
        self.pull_strided(name, dim, dim, 0, ids, None, out, use_cache)
    }

    /// Should a pull of `name` consult the [`FeatureCache`]? Centralized
    /// so every pull path gates — and binds the per-ntype dims — the
    /// same way.
    fn cache_gate(&mut self, name: &str, dims: &[usize]) -> bool {
        match &self.cache {
            Some(c) => {
                let on = c.is_enabled() && c.tensor() == name;
                if on {
                    c.ensure_dims(dims);
                }
                on
            }
            Option::None => false,
        }
    }

    /// Typed pull: row `ids[i]` comes from its node type's table (width
    /// `tf.dims[t]`) and lands at `out[slot * stride ..]`, with the row
    /// tail `dims[t]..stride` zeroed — callers only zero the padding
    /// rows beyond their real ids. The cache is consulted under
    /// `(ntype, id)` keys when it binds `tf.base`. Single-table views
    /// delegate to [`Self::pull`] — homogeneous graphs run the exact
    /// same path through their trivial schema.
    ///
    /// Wire modeling: each ntype's rows go out as that table's own
    /// per-owner batched request (a per-tensor KV protocol, like
    /// DistDGL's); a cross-table per-owner batch would amortize the
    /// request latency further but is not modeled.
    pub fn pull_typed(
        &mut self,
        tf: &TypedFeatures,
        ids: &[NodeId],
        out: &mut [f32],
        stride: usize,
    ) -> Result<usize, RpcError> {
        if tf.is_single() {
            let dim = tf.dims[0];
            if stride == dim {
                return self.pull(&tf.names[0], ids, out);
            }
            // wider batch rows than the table: strided single-table pull
            assert!(stride >= dim);
            assert!(out.len() >= ids.len() * stride);
            let use_cache = self.cache_gate(&tf.base, &[dim]);
            return self.pull_strided(
                &tf.names[0],
                dim,
                stride,
                0,
                ids,
                Option::None,
                out,
                use_cache,
            );
        }
        assert!(stride >= tf.max_dim());
        assert!(out.len() >= ids.len() * stride);
        let use_cache = self.cache_gate(&tf.base, &tf.dims);
        // bucket ids by ntype (reused scratch), then one strided
        // sub-pull per ntype against its own table
        let nt = tf.n_ntypes();
        let mut tg = std::mem::take(&mut self.typed_groups);
        if tg.len() != nt {
            tg.resize_with(nt, Default::default);
        }
        for g in tg.iter_mut() {
            g.0.clear();
            g.1.clear();
        }
        for (slot, &gid) in ids.iter().enumerate() {
            let t = tf.ntype_of(gid) as usize;
            tg[t].0.push(gid);
            tg[t].1.push(slot);
        }
        let mut remote_rows = 0usize;
        let mut err: Option<RpcError> = None;
        for (t, (tids, tslots)) in tg.iter().enumerate() {
            if tids.is_empty() {
                continue;
            }
            match self.pull_strided(
                &tf.names[t],
                tf.dims[t],
                stride,
                t as u8,
                tids,
                Some(tslots.as_slice()),
                out,
                use_cache,
            ) {
                Ok(r) => remote_rows += r,
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        self.typed_groups = tg;
        match err {
            Some(e) => Err(e),
            Option::None => Ok(remote_rows),
        }
    }

    /// Warm the cache with the remote rows a *future* batch will need —
    /// the demand-side entry point of the predictive prefetcher
    /// (`pipeline::prefetch`). Ids that are local, already cached, or
    /// claimed in-flight by another prefetch are skipped; the rest are
    /// pulled per owner with the usual wire metering and offered to the
    /// cache as prefetched rows (counted in `prefetch_issued`, and in
    /// `prefetch_wasted_bytes` if evicted or invalidated before a hit).
    /// With `pin` set (imminent batches), every remote row — fetched or
    /// already resident — is pinned so the CLOCK hand cannot evict it
    /// before its batch consumes it; `lookup` releases the pin.
    ///
    /// The invalidation epoch is captured before any wire traffic: if a
    /// `push_grad` flush lands mid-pull, the cache drops our stale
    /// inserts. Serving demand traffic stays byte-identical either way —
    /// the cache is value-transparent and prefetch consumes no batch
    /// randomness. Errors (injected outages) just mean rows stay cold;
    /// the demand path will fetch and surface them deterministically.
    pub fn prefetch_typed(
        &mut self,
        tf: &TypedFeatures,
        ids: &[NodeId],
        pin: bool,
    ) -> Result<usize, RpcError> {
        if !self.cache_gate(&tf.base, &tf.dims) {
            return Ok(0);
        }
        let cache = Arc::clone(self.cache.as_ref().unwrap());
        let epoch = cache.invalidation_epoch();
        // bucket remote, uncached, unclaimed ids by (ntype, owner)
        let nparts = self.policy.n_parts();
        let nt = tf.n_ntypes();
        let mut claimed: Vec<(u8, NodeId)> = Vec::new();
        let mut groups: Vec<Vec<(u32, NodeId)>> = vec![Vec::new(); nt * nparts];
        for &gid in ids {
            let owner = self.policy.owner(gid);
            if owner == self.machine {
                continue;
            }
            let t = tf.ntype_of(gid);
            if cache.contains(t, gid) {
                if pin {
                    cache.pin(t, gid);
                }
                continue;
            }
            if !cache.begin_inflight(t, gid) {
                continue; // another prefetch already has this row on the wire
            }
            claimed.push((t, gid));
            groups[t as usize * nparts + owner as usize]
                .push((self.policy.local_of(gid), gid));
        }
        let fault = self.cluster.fault_plan();
        let replicas = self.cluster.replica_set();
        let mut fetched = 0usize;
        let mut err: Option<RpcError> = None;
        let mut locals: Vec<u32> = Vec::new();
        let mut buf: Vec<f32> = Vec::new();
        'outer: for t in 0..nt {
            let dim = tf.dims[t];
            for owner in 0..nparts {
                let group = &groups[t * nparts + owner];
                if group.is_empty() {
                    continue;
                }
                let (srv, alias) = match route_kv_read(
                    fault.as_ref(),
                    replicas.as_ref(),
                    owner as u32,
                    &tf.names[t],
                ) {
                    Ok(r) => r,
                    Err(e) => {
                        err = Some(e);
                        break 'outer;
                    }
                };
                locals.clear();
                locals.extend(group.iter().map(|&(l, _)| l));
                buf.resize(locals.len() * dim, 0.0);
                if let Err(e) = self.cluster.servers[srv as usize]
                    .read_rows(
                        alias.as_deref().unwrap_or(&tf.names[t]),
                        &locals,
                        &mut buf,
                    )
                {
                    err = Some(e);
                    break 'outer;
                }
                if srv != self.machine {
                    self.cluster.meter_pull(
                        self.machine,
                        srv,
                        locals.len(),
                        dim,
                    );
                }
                for (i, &(_, gid)) in group.iter().enumerate() {
                    cache.insert_prefetched(
                        t as u8,
                        gid,
                        &buf[i * dim..(i + 1) * dim],
                        epoch,
                    );
                    if pin {
                        cache.pin(t as u8, gid);
                    }
                }
                fetched += locals.len();
            }
        }
        for &(t, gid) in &claimed {
            cache.end_inflight(t, gid);
        }
        match err {
            Some(e) => Err(e),
            Option::None => Ok(fetched),
        }
    }

    /// Shared pull core: rows of `name` (width `dim`) for `ids`, written
    /// at `slot * stride` where row `j`'s slot is `slots[j]` (`None` =
    /// `j`, the classic dense layout). Cache lookups/inserts are keyed
    /// `(ntype, id)`. On `Err` the output buffer contents are
    /// unspecified, but the client's reused scratch survives — the next
    /// call after a healed fault runs clean.
    #[allow(clippy::too_many_arguments)]
    fn pull_strided(
        &mut self,
        name: &str,
        dim: usize,
        stride: usize,
        ntype: u8,
        ids: &[NodeId],
        slots: Option<&[usize]>,
        out: &mut [f32],
        use_cache: bool,
    ) -> Result<usize, RpcError> {
        // strided rows: zero each row's dims..stride tail up front (one
        // cheap pass; prefixes are fully overwritten below), so callers
        // never pay a full-buffer memset (§Perf). No-op when stride==dim.
        if stride > dim {
            for (j, _) in ids.iter().enumerate() {
                let slot = slots.map_or(j, |s| s[j]);
                out[slot * stride + dim..(slot + 1) * stride].fill(0.0);
            }
        }
        // group by owner, remembering each id's index (reused scratch);
        // cache lookups lock only the stripe that owns each gid, so a
        // concurrent prefetch insert on another stripe never blocks us
        let nparts = self.policy.n_parts();
        let mut groups = std::mem::take(&mut self.pull_groups);
        let mut slot_scratch = std::mem::take(&mut self.slot_scratch);
        if groups.len() != nparts {
            groups.resize_with(nparts, Default::default);
        }
        for g in groups.iter_mut() {
            g.0.clear();
            g.1.clear();
        }
        {
            let cache = if use_cache {
                Some(self.cache.as_ref().unwrap().as_ref())
            } else {
                Option::None
            };
            for (j, &gid) in ids.iter().enumerate() {
                let slot = slots.map_or(j, |s| s[j]);
                let owner = self.policy.owner(gid) as usize;
                if owner as u32 != self.machine {
                    if let Some(c) = cache {
                        if c.lookup(
                            ntype,
                            gid,
                            &mut out[slot * stride..slot * stride + dim],
                        ) {
                            continue;
                        }
                    }
                }
                groups[owner].0.push(self.policy.local_of(gid));
                groups[owner].1.push(j);
            }
        }
        let machine = self.machine;
        let n_remote = groups
            .iter()
            .enumerate()
            .filter(|(o, g)| *o as u32 != machine && !g.0.is_empty())
            .count();
        let fault = self.cluster.fault_plan();
        let replicas = self.cluster.replica_set();
        let mut remote_rows = 0usize;
        let mut err: Option<RpcError> = None;
        if self.cluster.concurrent_fanout && n_remote >= 2 {
            // concurrent fan-out: one thread per remote owner stages its
            // response rows into the client's reused per-owner buffers
            // (metering + modeled link time inside the thread, so sleeps
            // overlap); the local shard scatters on the calling thread
            // in the meantime
            let cluster = &self.cluster;
            let mut stage = std::mem::take(&mut self.pull_stage);
            if stage.len() != nparts {
                stage.resize_with(nparts, Vec::new);
            }
            std::thread::scope(|sc| {
                let fault_ref = &fault;
                let replicas_ref = &replicas;
                let mut handles = Vec::with_capacity(n_remote);
                for (owner, (buf, (locals, _))) in
                    stage.iter_mut().zip(groups.iter()).enumerate()
                {
                    if owner as u32 == machine || locals.is_empty() {
                        continue;
                    }
                    handles.push(sc.spawn(
                        move || -> Result<(), RpcError> {
                            let (srv, alias) = route_kv_read(
                                fault_ref.as_ref(),
                                replicas_ref.as_ref(),
                                owner as u32,
                                name,
                            )?;
                            // rows are fully overwritten; stale contents
                            // of a longer previous response are never read
                            buf.resize(locals.len() * dim, 0.0);
                            cluster.servers[srv as usize].read_rows(
                                alias.as_deref().unwrap_or(name),
                                locals,
                                buf,
                            )?;
                            // a standby that happens to be the caller's
                            // own machine serves from local memory: no
                            // wire traffic to meter
                            if srv != machine {
                                cluster.meter_pull(
                                    machine,
                                    srv,
                                    locals.len(),
                                    dim,
                                );
                            }
                            Ok(())
                        },
                    ));
                }
                let (locals, idxs) = &groups[machine as usize];
                if !locals.is_empty() {
                    let slot_buf =
                        resolve_slots(idxs, slots, &mut slot_scratch);
                    if let Err(e) = cluster.servers[machine as usize]
                        .read_rows_scattered(
                            name, locals, slot_buf, out, stride,
                        )
                    {
                        err.get_or_insert(e);
                    }
                }
                for h in handles {
                    match h.join() {
                        Ok(Ok(())) => {}
                        Ok(Err(e)) => {
                            err.get_or_insert(e);
                        }
                        Err(_) => {
                            err.get_or_insert(RpcError::WorkerLost(
                                "kv fan-out",
                            ));
                        }
                    }
                }
            });
            if err.is_none() {
                // scatter staged rows and offer them to the cache in
                // owner order — the exact cache-state evolution of the
                // serial loop
                for (owner, (locals, idxs)) in groups.iter().enumerate() {
                    if owner as u32 == machine || locals.is_empty() {
                        continue;
                    }
                    let buf = &stage[owner];
                    remote_rows += locals.len();
                    let slot_buf =
                        resolve_slots(idxs, slots, &mut slot_scratch);
                    for (i, &slot) in slot_buf.iter().enumerate() {
                        out[slot * stride..slot * stride + dim]
                            .copy_from_slice(&buf[i * dim..(i + 1) * dim]);
                    }
                    if use_cache {
                        let c = self.cache.as_ref().unwrap();
                        for (&j, &slot) in idxs.iter().zip(slot_buf) {
                            c.insert(
                                ntype,
                                ids[j],
                                &out[slot * stride..slot * stride + dim],
                            );
                        }
                    }
                }
            }
            self.pull_stage = stage;
        } else {
            for (owner, (locals, idxs)) in groups.iter().enumerate() {
                if locals.is_empty() {
                    continue;
                }
                let mut server = &self.cluster.servers[owner];
                let mut alias: Option<String> = None;
                if owner as u32 != machine {
                    let (srv, a) = match route_kv_read(
                        fault.as_ref(),
                        replicas.as_ref(),
                        owner as u32,
                        name,
                    ) {
                        Ok(r) => r,
                        Err(e) => {
                            err = Some(e);
                            break;
                        }
                    };
                    server = &self.cluster.servers[srv as usize];
                    alias = a;
                    remote_rows += locals.len();
                    if srv != machine {
                        self.cluster.meter_pull(
                            machine,
                            srv,
                            locals.len(),
                            dim,
                        );
                    }
                }
                // copy straight into the output slots (local and remote
                // alike)
                let slot_buf = resolve_slots(idxs, slots, &mut slot_scratch);
                if let Err(e) = server.read_rows_scattered(
                    alias.as_deref().unwrap_or(name),
                    locals,
                    slot_buf,
                    out,
                    stride,
                ) {
                    err = Some(e);
                    break;
                }
                if use_cache && owner as u32 != machine {
                    let c = self.cache.as_ref().unwrap();
                    for (&j, &slot) in idxs.iter().zip(slot_buf) {
                        c.insert(
                            ntype,
                            ids[j],
                            &out[slot * stride..slot * stride + dim],
                        );
                    }
                }
            }
        }
        self.pull_groups = groups;
        self.slot_scratch = slot_scratch;
        match err {
            Some(e) => Err(e),
            Option::None => Ok(remote_rows),
        }
    }

    /// Push row gradients (sparse embedding update, §3.1 "sparse
    /// parameters"): routed to owners, applied as SGD on the server.
    /// On `Err` some owners may already have applied their rows — the
    /// recovery story is checkpoint rollback, not partial-push undo.
    pub fn push_grad(
        &mut self,
        name: &str,
        ids: &[NodeId],
        grads: &[f32],
        lr: f32,
    ) -> Result<(), RpcError> {
        // coherence: a sparse update through this client (or any fork
        // sharing its cache) must not leave stale cached copies behind —
        // covers() also matches the typed per-ntype tables (`base.<ntype>`).
        // Strict mode (staleness 0) invalidates right here; a bounded
        // window K > 0 accumulates touched ids and flushes every K-th
        // update, so cached rows lag the store by at most K updates.
        // Every flush also bumps the cache's invalidation epoch, which
        // kills any prefetch pull that was in flight across the update.
        if let Some(c) = &self.cache {
            if c.covers(name) {
                if self.embedding_staleness == 0 {
                    c.invalidate(ids);
                } else {
                    self.stale_ids.extend_from_slice(ids);
                    self.stale_updates += 1;
                    if self.stale_updates >= self.embedding_staleness {
                        let pending = std::mem::take(&mut self.stale_ids);
                        c.invalidate(&pending);
                        self.stale_updates = 0;
                    }
                }
            }
        }
        let dim = grads.len() / ids.len().max(1);
        let nparts = self.policy.n_parts();
        let mut groups = std::mem::take(&mut self.push_groups);
        if groups.len() != nparts {
            groups.resize_with(nparts, Default::default);
        }
        for g in groups.iter_mut() {
            g.0.clear();
            g.1.clear();
        }
        for (i, &gid) in ids.iter().enumerate() {
            let owner = self.policy.owner(gid) as usize;
            groups[owner].0.push(self.policy.local_of(gid));
            groups[owner]
                .1
                .extend_from_slice(&grads[i * dim..(i + 1) * dim]);
        }
        let fault = self.cluster.fault_plan();
        let replicas = self.cluster.replica_set();
        let mut err: Option<RpcError> = None;
        for (owner, (locals, g)) in groups.iter().enumerate() {
            if locals.is_empty() {
                continue;
            }
            // write-through protocol (docs/DESIGN.md §12): the update
            // lands on the primary AND its standby's replica table, so
            // the two copies stay byte-identical at every barrier. A
            // primary already failed over (or detected dead right here)
            // is skipped — its standby carries the authoritative rows
            // until rejoin re-imports them.
            let mut primary_up = true;
            if owner as u32 != self.machine {
                if replicas
                    .as_ref()
                    .is_some_and(|rs| rs.is_failed(owner as u32))
                {
                    primary_up = false;
                } else if let Some(f) = &fault {
                    if let Err(e) = f.admit_kv(owner as u32) {
                        match &replicas {
                            Some(rs) => {
                                rs.mark_failed(owner as u32);
                                primary_up = false;
                            }
                            Option::None => {
                                err = Some(e);
                                break;
                            }
                        }
                    }
                }
            }
            let bytes = crate::net::payload::kv_push_bytes(
                0, // interned tensor id, as in meter_pull
                locals.len(),
                dim,
            );
            if primary_up {
                if owner as u32 != self.machine {
                    self.cluster.cost.on_network(
                        self.machine,
                        owner as u32,
                        bytes,
                    );
                }
                if let Err(e) = self.cluster.servers[owner]
                    .apply_grads(name, locals, g, lr)
                {
                    err = Some(e);
                    break;
                }
            }
            if let Some(rs) = &replicas {
                let standby = rs.replica_owner(owner as u32);
                if standby != self.machine {
                    if let Some(f) = &fault {
                        if let Err(e) = f.admit_kv(standby) {
                            err = Some(e);
                            break;
                        }
                    }
                    self.cluster.cost.on_network(
                        self.machine,
                        standby,
                        bytes,
                    );
                }
                if let Err(e) = self.cluster.servers[standby as usize]
                    .apply_grads(
                        &replica_table(owner as u32, name),
                        locals,
                        g,
                        lr,
                    )
                {
                    err = Some(e);
                    break;
                }
            }
        }
        self.push_groups = groups;
        match err {
            Some(e) => Err(e),
            Option::None => Ok(()),
        }
    }

    fn remote_dim(&self, name: &str) -> Result<usize, RpcError> {
        for s in &self.cluster.servers {
            if let Some(d) = s.dim_of_or(name) {
                return Ok(d);
            }
        }
        Err(RpcError::UnknownTensor {
            name: name.to_string(),
            machine: self.machine,
        })
    }
}

impl KvServer {
    fn dim_of_or(&self, name: &str) -> Option<usize> {
        self.shards.read().unwrap().get(name).map(|s| s.dim)
    }
}

/// Gate one remote read against `owner` and resolve who serves it: the
/// primary when healthy, else the standby's [`replica_table`] copy once
/// `owner` is marked failed — or fails right here by exhausting its
/// retry budget. Returns `(server, alias)` where `alias = None` means
/// the primary serves the caller's own tensor name. Without a
/// [`ReplicaSet`] the admission error propagates unchanged (the PR-6
/// typed-error drain). A free function so the concurrent fan-out
/// threads can call it without borrowing the client.
///
/// Failover state is sticky routing memory: after the first detection,
/// requests stop paying the primary's retry budget and go straight to
/// the standby; only [`KvCluster::rejoin_server`] flips back. Detection
/// (the exhausted retry loop) and reroute (the standby's admission) are
/// timed separately into the `pipeline.failover` decomposition.
fn route_kv_read(
    fault: Option<&Arc<FaultPlan>>,
    replicas: Option<&Arc<ReplicaSet>>,
    owner: u32,
    name: &str,
) -> Result<(u32, Option<String>), RpcError> {
    if let Some(rs) = replicas {
        if rs.is_failed(owner) {
            let standby = rs.replica_owner(owner);
            if let Some(f) = fault {
                f.admit_kv(standby)?;
            }
            return Ok((standby, Some(replica_table(owner, name))));
        }
    }
    let Some(f) = fault else { return Ok((owner, None)) };
    let t0 = std::time::Instant::now();
    match f.admit_kv(owner) {
        Ok(()) => Ok((owner, None)),
        Err(e) => {
            let Some(rs) = replicas else { return Err(e) };
            rs.note_detect(t0.elapsed());
            rs.mark_failed(owner);
            let t1 = std::time::Instant::now();
            let standby = rs.replica_owner(owner);
            f.admit_kv(standby)?;
            rs.note_reroute(t1.elapsed());
            Ok((standby, Some(replica_table(owner, name))))
        }
    }
}

/// Map a per-owner group's id-indices to output slots: the identity when
/// the pull is dense (`slots == None`), else resolved through the
/// caller's slot table into the reused scratch.
fn resolve_slots<'a>(
    idxs: &'a [usize],
    slots: Option<&'a [usize]>,
    scratch: &'a mut Vec<usize>,
) -> &'a [usize] {
    match slots {
        Option::None => idxs,
        Some(s) => {
            scratch.clear();
            scratch.extend(idxs.iter().map(|&j| s[j]));
            scratch
        }
    }
}

/// Sleep `secs` with reasonable sub-millisecond accuracy.
fn spin_sleep(secs: f64) {
    if secs <= 0.0 {
        return;
    }
    let dur = std::time::Duration::from_secs_f64(secs);
    if dur > std::time::Duration::from_micros(200) {
        std::thread::sleep(dur);
    } else {
        let t = std::time::Instant::now();
        while t.elapsed() < dur {
            std::hint::spin_loop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::policy::{HashPolicy, RangePolicy};
    use crate::partition::NodeMap;

    fn rows(n: usize, dim: usize) -> Vec<f32> {
        (0..n * dim).map(|i| i as f32).collect()
    }

    fn range_cluster(
        dim: usize,
    ) -> (Arc<KvCluster>, Arc<dyn PartitionPolicy>, Vec<f32>) {
        // 3 machines owning [0,10), [10,25), [25,30)
        let nm = NodeMap { part_starts: vec![0, 10, 25, 30] };
        let policy: Arc<dyn PartitionPolicy> =
            Arc::new(RangePolicy::new(nm));
        let cost = Arc::new(CostModel::default());
        let cluster = KvCluster::new(3, cost);
        let data = rows(30, dim);
        cluster.register_partitioned("feat", &data, dim, policy.as_ref());
        (cluster, policy, data)
    }

    #[test]
    fn pull_returns_correct_rows_local_and_remote() {
        let dim = 4;
        let (cluster, policy, data) = range_cluster(dim);
        let mut client = cluster.client(1, policy);
        let ids: Vec<NodeId> = vec![12, 0, 29, 14]; // local, remote, remote, local
        let mut out = vec![0f32; ids.len() * dim];
        let remote = client.pull("feat", &ids, &mut out).unwrap();
        assert_eq!(remote, 2);
        for (i, &gid) in ids.iter().enumerate() {
            assert_eq!(
                &out[i * dim..(i + 1) * dim],
                &data[gid as usize * dim..(gid as usize + 1) * dim],
                "row {gid}"
            );
        }
    }

    #[test]
    fn local_pull_is_free_remote_metered() {
        let dim = 8;
        let (cluster, policy, _) = range_cluster(dim);
        let mut client = cluster.client(0, policy);
        let mut out = vec![0f32; dim];
        client.pull("feat", &[3], &mut out).unwrap();
        assert_eq!(cluster.cost.network_bytes(), 0);
        client.pull("feat", &[27], &mut out).unwrap();
        assert!(cluster.cost.network_bytes() > 0);
    }

    #[test]
    fn push_grad_applies_sgd_on_owner() {
        let dim = 2;
        let (cluster, policy, data) = range_cluster(dim);
        let mut client = cluster.client(0, policy);
        let ids = vec![5 as NodeId, 20];
        let grads = vec![1.0f32, 1.0, 2.0, 2.0];
        client.push_grad("feat", &ids, &grads, 0.5).unwrap();
        let mut out = vec![0f32; 2 * dim];
        client.pull("feat", &ids, &mut out).unwrap();
        assert_eq!(out[0], data[10] - 0.5);
        assert_eq!(out[2], data[40] - 1.0);
    }

    #[test]
    fn hash_policy_roundtrip() {
        let dim = 3;
        let policy: Arc<dyn PartitionPolicy> =
            Arc::new(HashPolicy { nparts: 2, n_rows: 11 });
        let cost = Arc::new(CostModel::default());
        let cluster = KvCluster::new(2, cost);
        let data = rows(11, dim);
        cluster.register_partitioned("x", &data, dim, policy.as_ref());
        let mut client = cluster.client(0, policy);
        let ids: Vec<NodeId> = (0..11).collect();
        let mut out = vec![0f32; 11 * dim];
        client.pull("x", &ids, &mut out).unwrap();
        assert_eq!(out, data);
    }

    /// Property: pull over random id multisets always equals the source.
    #[test]
    fn prop_pull_matches_source() {
        crate::util::proptest::forall(
            31,
            20,
            |r| {
                let k = 1 + r.usize_below(50);
                let ids: Vec<NodeId> =
                    (0..k).map(|_| r.below(30) as NodeId).collect();
                ids
            },
            |ids| {
                let dim = 4;
                let (cluster, policy, data) = range_cluster(dim);
                let mut client = cluster.client(2, policy);
                let mut out = vec![0f32; ids.len() * dim];
                client.pull("feat", ids, &mut out).unwrap();
                for (i, &gid) in ids.iter().enumerate() {
                    let expect =
                        &data[gid as usize * dim..(gid as usize + 1) * dim];
                    if &out[i * dim..(i + 1) * dim] != expect {
                        return Err(format!("row {gid} mismatch"));
                    }
                }
                Ok(())
            },
        );
    }

    fn feat_cache(budget: usize) -> FeatureCache {
        use crate::kvstore::cache::CacheAdmission;
        FeatureCache::new("feat", budget, CacheAdmission::All, None)
    }

    #[test]
    fn cached_pull_is_byte_identical_and_skips_the_wire() {
        let dim = 4;
        let (cluster, policy, data) = range_cluster(dim);
        let mut client = cluster.client(1, policy);
        client.attach_cache(feat_cache(1 << 20));
        let ids: Vec<NodeId> = vec![12, 0, 29, 14, 0, 27];
        let mut cold = vec![0f32; ids.len() * dim];
        let fetched_cold = client.pull("feat", &ids, &mut cold).unwrap();
        let bytes_after_cold = cluster.cost.network_bytes();
        assert!(fetched_cold > 0 && bytes_after_cold > 0);
        // warm pull: every remote row is cached → no new network bytes,
        // and the result matches the source byte for byte
        let mut warm = vec![0f32; ids.len() * dim];
        let fetched_warm = client.pull("feat", &ids, &mut warm).unwrap();
        assert_eq!(fetched_warm, 0);
        assert_eq!(cluster.cost.network_bytes(), bytes_after_cold);
        assert_eq!(cold, warm);
        for (i, &gid) in ids.iter().enumerate() {
            assert_eq!(
                &warm[i * dim..(i + 1) * dim],
                &data[gid as usize * dim..(gid as usize + 1) * dim],
                "row {gid}"
            );
        }
        let s = client.cache_stats().unwrap();
        assert!(s.hit_rows > 0 && s.remote_bytes_saved > 0);
        assert!(s.hit_rate() > 0.0);
    }

    #[test]
    fn zero_budget_cache_degenerates_to_uncached() {
        let dim = 4;
        let (c1, policy, _) = range_cluster(dim);
        let (c2, policy2, _) = range_cluster(dim);
        let mut plain = c1.client(1, policy);
        let mut zeroed = c2.client(1, policy2);
        zeroed.attach_cache(feat_cache(0));
        let ids: Vec<NodeId> = vec![0, 12, 29, 0, 5];
        let mut a = vec![0f32; ids.len() * dim];
        let mut b = vec![0f32; ids.len() * dim];
        for _ in 0..2 {
            let ra = plain.pull("feat", &ids, &mut a).unwrap();
            let rb = zeroed.pull("feat", &ids, &mut b).unwrap();
            assert_eq!(ra, rb);
            assert_eq!(a, b);
        }
        assert_eq!(c1.cost.network_bytes(), c2.cost.network_bytes());
        let s = zeroed.cache_stats().unwrap();
        assert_eq!(s.hit_rows + s.miss_rows, 0);
    }

    #[test]
    fn push_grad_invalidates_cached_rows() {
        let dim = 2;
        let (cluster, policy, data) = range_cluster(dim);
        let mut client = cluster.client(0, policy);
        client.attach_cache(feat_cache(1 << 20));
        let ids = vec![20 as NodeId]; // remote for machine 0
        let mut out = vec![0f32; dim];
        client.pull("feat", &ids, &mut out).unwrap(); // populate cache
        let grads = vec![2.0f32, 2.0];
        client.push_grad("feat", &ids, &grads, 0.5).unwrap();
        client.pull("feat", &ids, &mut out).unwrap();
        assert_eq!(out[0], data[40] - 1.0, "stale cached row served");
    }

    #[test]
    fn prefetch_warms_cache_and_demand_pull_hits_without_wire_traffic() {
        let dim = 4;
        let (cluster, policy, data) = range_cluster(dim);
        let mut client = cluster.client(1, policy);
        client.attach_cache(feat_cache(1 << 20));
        let tf = TypedFeatures::homogeneous("feat", dim);
        let ids: Vec<NodeId> = vec![0, 5, 27, 29, 12]; // 12 is local to m1
        let fetched = client.prefetch_typed(&tf, &ids, false).unwrap();
        assert_eq!(fetched, 4, "every remote row fetched exactly once");
        let bytes_after_prefetch = cluster.cost.network_bytes();
        assert!(bytes_after_prefetch > 0, "prefetch pulls are metered");
        // demand pull: served entirely from cache + local shard
        let mut out = vec![0f32; ids.len() * dim];
        let remote = client.pull("feat", &ids, &mut out).unwrap();
        assert_eq!(remote, 0);
        assert_eq!(cluster.cost.network_bytes(), bytes_after_prefetch);
        for (i, &gid) in ids.iter().enumerate() {
            assert_eq!(
                &out[i * dim..(i + 1) * dim],
                &data[gid as usize * dim..(gid as usize + 1) * dim],
                "row {gid}"
            );
        }
        let s = client.cache_stats().unwrap();
        assert_eq!(s.prefetch_issued, 4);
        assert_eq!(s.prefetch_hits, 4);
        assert_eq!(s.prefetch_wasted_bytes, 0);
        // re-prefetching the same frontier is free: everything resident
        let again = client.prefetch_typed(&tf, &ids, false).unwrap();
        assert_eq!(again, 0);
        assert_eq!(cluster.cost.network_bytes(), bytes_after_prefetch);
    }

    #[test]
    fn prefetch_pins_survive_pressure_and_demand_lookup_releases() {
        let dim = 4;
        let (cluster, policy, data) = range_cluster(dim);
        let mut client = cluster.client(1, policy);
        // room for ~2 rows: pressure enough that unpinned rows churn
        client.attach_cache(feat_cache(2 * (dim * 4 + 24)));
        let tf = TypedFeatures::homogeneous("feat", dim);
        let imminent: Vec<NodeId> = vec![27, 29];
        client.prefetch_typed(&tf, &imminent, true).unwrap();
        // a competing prefetch cannot evict the pinned imminent rows
        client.prefetch_typed(&tf, &[0, 5, 8], false).unwrap();
        let bytes_before = cluster.cost.network_bytes();
        let mut out = vec![0f32; imminent.len() * dim];
        let remote = client.pull("feat", &imminent, &mut out).unwrap();
        assert_eq!(remote, 0, "pinned rows were evicted pre-use");
        assert_eq!(cluster.cost.network_bytes(), bytes_before);
        for (i, &gid) in imminent.iter().enumerate() {
            assert_eq!(
                &out[i * dim..(i + 1) * dim],
                &data[gid as usize * dim..(gid as usize + 1) * dim]
            );
        }
        let s = client.cache_stats().unwrap();
        assert!(s.pinned_rows >= 2);
    }

    #[test]
    fn sharded_cache_is_byte_identical_to_single_stripe() {
        let dim = 4;
        let (c1, p1, data) = range_cluster(dim);
        let (c2, p2, _) = range_cluster(dim);
        let mut single = c1.client(1, p1);
        let mut striped = c2.client(1, p2);
        single.attach_cache(feat_cache(1 << 20));
        striped.attach_cache_sharded(feat_cache(1 << 20), 4);
        assert_eq!(striped.shared_cache().unwrap().n_shards(), 4);
        let ids: Vec<NodeId> = vec![0, 5, 27, 29, 12, 5, 0, 28];
        let mut a = vec![0f32; ids.len() * dim];
        let mut b = vec![0f32; ids.len() * dim];
        for _ in 0..3 {
            let ra = single.pull("feat", &ids, &mut a).unwrap();
            let rb = striped.pull("feat", &ids, &mut b).unwrap();
            assert_eq!(ra, rb, "stripe routing changed remote fetches");
            assert_eq!(a, b);
        }
        assert_eq!(c1.cost.network_bytes(), c2.cost.network_bytes());
        for (i, &gid) in ids.iter().enumerate() {
            assert_eq!(
                &b[i * dim..(i + 1) * dim],
                &data[gid as usize * dim..(gid as usize + 1) * dim]
            );
        }
        let ss = single.cache_stats().unwrap();
        let st = striped.cache_stats().unwrap();
        assert_eq!(ss.hit_rows, st.hit_rows);
        assert_eq!(ss.remote_bytes_saved, st.remote_bytes_saved);
    }

    #[test]
    fn embedding_staleness_window_bounds_cached_lag() {
        let dim = 2;
        let (cluster, policy, data) = range_cluster(dim);
        let mut client = cluster.client(0, policy);
        client.attach_cache(feat_cache(1 << 20));
        client.set_embedding_staleness(2);
        let ids = vec![20 as NodeId]; // remote for machine 0
        let base = data[40];
        let mut out = vec![0f32; dim];
        client.pull("feat", &ids, &mut out).unwrap(); // cache the row
        let grads = vec![2.0f32, 2.0];
        // update 1 of the window: the cached copy may legally lag
        client.push_grad("feat", &ids, &grads, 0.5).unwrap();
        client.pull("feat", &ids, &mut out).unwrap();
        assert_eq!(out[0], base, "within the window the stale row serves");
        // update 2 flushes the accumulated invalidations: fresh bytes
        client.push_grad("feat", &ids, &grads, 0.5).unwrap();
        client.pull("feat", &ids, &mut out).unwrap();
        assert_eq!(out[0], base - 2.0, "flush must expose both updates");
        // strict mode stays byte-exact (the PR-2 invariant, re-asserted)
        client.set_embedding_staleness(0);
        client.push_grad("feat", &ids, &grads, 0.5).unwrap();
        client.pull("feat", &ids, &mut out).unwrap();
        assert_eq!(out[0], base - 3.0);
    }

    #[test]
    fn prefetch_in_flight_across_update_is_dropped_as_stale() {
        // capture-epoch → update lands → insert_prefetched must not
        // publish the pre-update bytes (the store-level view of the
        // cache's epoch guard)
        let dim = 2;
        let (cluster, policy, data) = range_cluster(dim);
        let mut client = cluster.client(0, policy);
        client.attach_cache(feat_cache(1 << 20));
        let cache = client.shared_cache().unwrap();
        let ids = vec![20 as NodeId];
        let epoch = cache.invalidation_epoch();
        let old_row = vec![data[40], data[41]];
        client
            .push_grad("feat", &ids, &[2.0, 2.0], 0.5)
            .unwrap(); // bumps the epoch
        cache.ensure_dims(&[dim]);
        cache.insert_prefetched(0, 20, &old_row, epoch);
        let mut out = vec![0f32; dim];
        client.pull("feat", &ids, &mut out).unwrap();
        assert_eq!(out[0], data[40] - 1.0, "stale prefetch insert served");
    }

    #[test]
    fn repeated_pulls_reuse_scratch_capacity() {
        // grouping scratch survives across calls: nothing observable
        // changes, results stay correct over many mixed pulls
        let dim = 3;
        let (cluster, policy, data) = range_cluster(dim);
        let mut client = cluster.client(2, policy);
        let mut out = vec![0f32; 30 * dim];
        for round in 0..5 {
            let k = 5 + round * 5;
            let ids: Vec<NodeId> =
                (0..k).map(|i| ((i * 7 + round) % 30) as NodeId).collect();
            client.pull("feat", &ids, &mut out[..k * dim]).unwrap();
            for (i, &gid) in ids.iter().enumerate() {
                assert_eq!(
                    &out[i * dim..(i + 1) * dim],
                    &data[gid as usize * dim..(gid as usize + 1) * dim]
                );
            }
        }
    }

    #[test]
    fn concurrent_pull_is_byte_identical_to_serial() {
        let dim = 4;
        let nm = NodeMap { part_starts: vec![0, 10, 25, 30] };
        let policy: Arc<dyn PartitionPolicy> =
            Arc::new(RangePolicy::new(nm));
        let data = rows(30, dim);
        let conc = KvCluster::new(3, Arc::new(CostModel::default()));
        let serial = KvCluster::with_options(
            3,
            Arc::new(CostModel::default()),
            false,
            false,
        );
        assert!(conc.concurrent_fanout, "concurrency must be the default");
        conc.register_partitioned("feat", &data, dim, policy.as_ref());
        serial.register_partitioned("feat", &data, dim, policy.as_ref());
        let mut c1 = conc.client(1, policy.clone());
        let mut c2 = serial.client(1, policy);
        // both remote owners (0 and 2) + local rows + duplicates
        let ids: Vec<NodeId> = vec![0, 12, 29, 5, 26, 0, 14, 9];
        let mut a = vec![0f32; ids.len() * dim];
        let mut b = vec![0f32; ids.len() * dim];
        for round in 0..3 {
            let ra = c1.pull("feat", &ids, &mut a).unwrap();
            let rb = c2.pull("feat", &ids, &mut b).unwrap();
            assert_eq!(ra, rb, "round {round}");
            assert_eq!(a, b, "round {round}");
        }
        for (i, &gid) in ids.iter().enumerate() {
            assert_eq!(
                &a[i * dim..(i + 1) * dim],
                &data[gid as usize * dim..(gid as usize + 1) * dim],
                "row {gid}"
            );
        }
        assert_eq!(
            conc.cost.network_bytes(),
            serial.cost.network_bytes(),
            "modeled bytes must not depend on dispatch concurrency"
        );
        assert_eq!(conc.cost.network_msgs(), serial.cost.network_msgs());
    }

    /// Forked clients share one FeatureCache; under concurrent use the
    /// stats stay consistent: every remote lookup is a hit or a miss,
    /// and every miss is a fetched remote row.
    #[test]
    fn forked_clients_share_cache_and_stats_stay_consistent() {
        let dim = 4;
        let (cluster, policy, data) = range_cluster(dim);
        let mut base = cluster.client(1, policy);
        base.attach_cache(feat_cache(1 << 20));
        let ids: Vec<NodeId> = (0..30).collect();
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let mut c = base.fork();
                let ids = ids.clone();
                let data = data.clone();
                std::thread::spawn(move || {
                    let mut out = vec![0f32; ids.len() * dim];
                    let mut fetched = 0usize;
                    for _ in 0..4 {
                        fetched += c.pull("feat", &ids, &mut out).unwrap();
                    }
                    for (i, &gid) in ids.iter().enumerate() {
                        assert_eq!(
                            &out[i * dim..(i + 1) * dim],
                            &data[gid as usize * dim
                                ..(gid as usize + 1) * dim],
                            "row {gid}"
                        );
                    }
                    fetched
                })
            })
            .collect();
        let fetched: usize =
            handles.into_iter().map(|h| h.join().unwrap()).sum();
        let s = base.cache_stats().unwrap();
        // machine 1 owns [10, 25): rows 0..10 ∪ 25..30 are remote
        let remote_per_pass = 15u64;
        let passes = 2 * 4;
        assert_eq!(s.hit_rows + s.miss_rows, passes * remote_per_pass);
        assert_eq!(s.miss_rows as usize, fetched, "a miss that was never \
             fetched (or a fetch that was never counted as a miss)");
        // with a budget holding every row, only first touches miss — at
        // worst both workers race the same cold row once
        assert!(
            s.hit_rows >= (passes - 2) * remote_per_pass,
            "shared cache barely hit: {s:?}"
        );
    }

    /// 30 nodes over 3 machines, 2 ntypes: even ids type 0 (dim 4), odd
    /// ids type 1 (dim 2); stride 4 output rows.
    fn typed_cluster(
    ) -> (Arc<KvCluster>, Arc<dyn PartitionPolicy>, TypedFeatures, Vec<f32>)
    {
        let nm = NodeMap { part_starts: vec![0, 10, 25, 30] };
        let policy: Arc<dyn PartitionPolicy> =
            Arc::new(RangePolicy::new(nm));
        let cost = Arc::new(CostModel::default());
        let cluster = KvCluster::new(3, cost);
        let src = rows(30, 4);
        let node_type: Vec<u8> =
            (0..30).map(|g| (g % 2) as u8).collect();
        let tf = TypedFeatures {
            base: "feat".into(),
            names: vec!["feat.even".into(), "feat.odd".into()],
            dims: vec![4, 2],
            node_type: Arc::new(node_type),
        };
        cluster.register_typed(&tf, &src, 4, policy.as_ref());
        (cluster, policy, tf, src)
    }

    #[test]
    fn typed_pull_routes_rows_to_their_tables() {
        let (cluster, policy, tf, src) = typed_cluster();
        let mut client = cluster.client(1, policy);
        let ids: Vec<NodeId> = vec![12, 1, 29, 14, 0, 27];
        let stride = 4;
        let mut out = vec![f32::NAN; ids.len() * stride];
        let remote =
            client.pull_typed(&tf, &ids, &mut out, stride).unwrap();
        assert!(remote > 0);
        for (i, &gid) in ids.iter().enumerate() {
            let dim = tf.dims[tf.ntype_of(gid) as usize];
            assert_eq!(
                &out[i * stride..i * stride + dim],
                &src[gid as usize * 4..gid as usize * 4 + dim],
                "row {gid}"
            );
            // the tail beyond the typed dim is zeroed by the pull
            for &x in &out[i * stride + dim..(i + 1) * stride] {
                assert_eq!(x, 0.0, "row {gid} tail not zeroed");
            }
        }
    }

    #[test]
    fn typed_pull_cache_is_byte_identical_and_keyed_per_ntype() {
        let (cluster, policy, tf, _) = typed_cluster();
        let mut client = cluster.client(1, policy);
        client.attach_cache(feat_cache(1 << 20));
        let ids: Vec<NodeId> = vec![0, 1, 26, 29, 0, 27];
        let stride = 4;
        let mut cold = vec![0f32; ids.len() * stride];
        let fetched_cold =
            client.pull_typed(&tf, &ids, &mut cold, stride).unwrap();
        let bytes_cold = cluster.cost.network_bytes();
        assert!(fetched_cold > 0 && bytes_cold > 0);
        let mut warm = vec![0f32; ids.len() * stride];
        let fetched_warm =
            client.pull_typed(&tf, &ids, &mut warm, stride).unwrap();
        assert_eq!(fetched_warm, 0, "warm typed pull hit the wire");
        assert_eq!(cluster.cost.network_bytes(), bytes_cold);
        assert_eq!(cold, warm);
        let s = client.cache_stats().unwrap();
        assert!(s.hit_rows > 0);
    }

    #[test]
    fn push_to_typed_table_invalidates_typed_cache_rows() {
        // a sparse update on a per-ntype table must not leave a stale
        // (ntype, row) entry behind
        let (cluster, policy, tf, _) = typed_cluster();
        let mut client = cluster.client(1, policy);
        client.attach_cache(feat_cache(1 << 20));
        let ids: Vec<NodeId> = vec![27]; // odd -> ntype 1, remote for m1
        let stride = 4;
        let mut out = vec![0f32; stride];
        client
            .pull_typed(&tf, &ids, &mut out, stride)
            .unwrap(); // warm the cache
        let before = out[..2].to_vec();
        let grads = vec![3.0f32, 3.0];
        client.push_grad("feat.odd", &ids, &grads, 0.5).unwrap();
        client.pull_typed(&tf, &ids, &mut out, stride).unwrap();
        assert_eq!(out[0], before[0] - 1.5, "stale typed cached row served");
        assert_eq!(out[1], before[1] - 1.5);
    }

    #[test]
    fn homogeneous_typed_view_matches_plain_pull() {
        // the trivial single-table view must be byte- and meter-identical
        // to a plain named pull (same code path)
        let dim = 4;
        let (c1, p1, data) = range_cluster(dim);
        let (c2, p2, _) = range_cluster(dim);
        let tf = TypedFeatures::homogeneous("feat", dim);
        let mut plain = c1.client(1, p1);
        let mut typed = c2.client(1, p2);
        let ids: Vec<NodeId> = vec![12, 0, 29, 14, 0];
        let mut a = vec![0f32; ids.len() * dim];
        let mut b = vec![0f32; ids.len() * dim];
        let ra = plain.pull("feat", &ids, &mut a).unwrap();
        let rb = typed.pull_typed(&tf, &ids, &mut b, dim).unwrap();
        assert_eq!(ra, rb);
        assert_eq!(a, b);
        for (i, &gid) in ids.iter().enumerate() {
            assert_eq!(
                &a[i * dim..(i + 1) * dim],
                &data[gid as usize * dim..(gid as usize + 1) * dim]
            );
        }
        assert_eq!(c1.cost.network_bytes(), c2.cost.network_bytes());
    }

    #[test]
    fn unknown_tensor_is_a_typed_error_not_a_panic() {
        let dim = 4;
        let (cluster, policy, _) = range_cluster(dim);
        let mut client = cluster.client(1, policy);
        let mut out = vec![0f32; dim];
        let err = client.pull("nope", &[0], &mut out).unwrap_err();
        assert_eq!(
            err,
            RpcError::UnknownTensor { name: "nope".into(), machine: 1 }
        );
        // pushes surface the same decode error
        let err =
            client.push_grad("nope", &[0], &[0.0; 4], 0.1).unwrap_err();
        assert!(matches!(err, RpcError::UnknownTensor { .. }));
        // the client survives: a valid pull still works afterwards
        client.pull("feat", &[12], &mut out).unwrap();
    }

    #[test]
    fn transient_kv_outage_heals_through_retries() {
        use crate::ft::{FailWindow, FaultPlan};
        let dim = 4;
        let (cluster, policy, data) = range_cluster(dim);
        let mut plan = FaultPlan::new();
        plan.kv_outages = vec![FailWindow::transient(0, 0, 2)];
        plan.backoff = std::time::Duration::ZERO;
        let plan = Arc::new(plan);
        cluster.set_fault_plan(plan.clone());
        let mut client = cluster.client(1, policy);
        let ids: Vec<NodeId> = vec![0, 3]; // owner 0, remote for m1
        let mut out = vec![0f32; ids.len() * dim];
        let remote = client.pull("feat", &ids, &mut out).unwrap();
        assert_eq!(remote, 2);
        assert!(plan.retries() >= 2, "outage must have cost retries");
        for (i, &gid) in ids.iter().enumerate() {
            assert_eq!(
                &out[i * dim..(i + 1) * dim],
                &data[gid as usize * dim..(gid as usize + 1) * dim]
            );
        }
    }

    #[test]
    fn permanent_kv_outage_is_server_down_serial_and_concurrent() {
        use crate::ft::{FailWindow, FaultPlan};
        let dim = 4;
        for concurrent in [false, true] {
            let nm = NodeMap { part_starts: vec![0, 10, 25, 30] };
            let policy: Arc<dyn PartitionPolicy> =
                Arc::new(RangePolicy::new(nm));
            let cluster = KvCluster::with_options(
                3,
                Arc::new(CostModel::default()),
                false,
                concurrent,
            );
            cluster.register_partitioned(
                "feat",
                &rows(30, dim),
                dim,
                policy.as_ref(),
            );
            let mut plan = FaultPlan::new();
            plan.kv_outages = vec![FailWindow::permanent(0, 0)];
            plan.backoff = std::time::Duration::ZERO;
            cluster.set_fault_plan(Arc::new(plan));
            let mut client = cluster.client(1, policy);
            // both remote owners engaged so the concurrent path fans out
            let ids: Vec<NodeId> = vec![0, 27];
            let mut out = vec![0f32; ids.len() * dim];
            let err = client.pull("feat", &ids, &mut out).unwrap_err();
            assert_eq!(
                err,
                RpcError::ServerDown { machine: 0, role: "kv" },
                "concurrent={concurrent}"
            );
            // owner 2 is healthy: pulls avoiding machine 0 still succeed
            let n = client.pull("feat", &[27, 14], &mut out).unwrap();
            assert_eq!(n, 1, "concurrent={concurrent}");
        }
    }

    #[test]
    fn failover_serves_replica_rows_byte_identically() {
        use crate::ft::{FailWindow, FaultPlan};
        let dim = 4;
        for concurrent in [false, true] {
            let nm = NodeMap { part_starts: vec![0, 10, 25, 30] };
            let policy: Arc<dyn PartitionPolicy> =
                Arc::new(RangePolicy::new(nm));
            let data = rows(30, dim);
            let cluster = KvCluster::with_options(
                3,
                Arc::new(CostModel::default()),
                false,
                concurrent,
            );
            cluster.register_partitioned(
                "feat",
                &data,
                dim,
                policy.as_ref(),
            );
            let rs = cluster.enable_replication();
            assert!(rs.replica_bytes() > 0, "deploy copy is accounted");
            let mut plan = FaultPlan::new();
            plan.kv_outages = vec![FailWindow::permanent(0, 0)];
            plan.backoff = std::time::Duration::ZERO;
            cluster.set_fault_plan(Arc::new(plan));
            let mut client = cluster.client(1, policy);
            // both remote owners engaged; machine 0 is permanently dead,
            // its replica lives on machine 1 — the client's own machine
            let ids: Vec<NodeId> = vec![0, 27, 5, 12];
            let mut out = vec![0f32; ids.len() * dim];
            let remote = client.pull("feat", &ids, &mut out).unwrap();
            assert_eq!(remote, 3, "concurrent={concurrent}");
            for (i, &gid) in ids.iter().enumerate() {
                assert_eq!(
                    &out[i * dim..(i + 1) * dim],
                    &data[gid as usize * dim..(gid as usize + 1) * dim],
                    "row {gid} concurrent={concurrent}"
                );
            }
            assert!(rs.is_failed(0));
            assert_eq!(rs.failovers(), 1, "detection counts once");
            // routing memory: a second pull goes straight to the standby
            client.pull("feat", &ids, &mut out).unwrap();
            assert_eq!(rs.failovers(), 1, "concurrent={concurrent}");
        }
    }

    #[test]
    fn rejoin_reimports_updates_applied_during_the_outage() {
        use crate::ft::{FailWindow, FaultPlan};
        let dim = 2;
        let (cluster, policy, data) = range_cluster(dim);
        cluster.enable_replication();
        let rs = cluster.replica_set().unwrap();
        let mut client = cluster.client(1, policy);
        // healthy write-through: primary and replica both advance
        client
            .push_grad("feat", &[0, 20], &[1.0, 1.0, 1.0, 1.0], 0.5)
            .unwrap();
        // kill machine 0 and keep updating: only its replica advances
        let mut plan = FaultPlan::new();
        plan.kv_outages = vec![FailWindow::permanent(0, 0)];
        plan.backoff = std::time::Duration::ZERO;
        cluster.set_fault_plan(Arc::new(plan));
        client.push_grad("feat", &[0], &[1.0, 1.0], 0.5).unwrap();
        assert!(rs.is_failed(0), "dead primary detected on the push path");
        // reads during the outage serve the replica's fresh bytes
        let mut out = vec![0f32; dim];
        client.pull("feat", &[0], &mut out).unwrap();
        assert_eq!(out[0], data[0] - 1.0);
        // restart: re-import from the replica, flip back to the primary
        let bytes = cluster.rejoin_server(0);
        assert!(bytes > 0, "re-import transfers the shard");
        assert!(!rs.is_failed(0));
        assert_eq!(rs.rejoins(), 1);
        assert!(rs.reimport_time() > std::time::Duration::ZERO);
        // heal the wire; the primary serves the rows updated while dead
        cluster.set_fault_plan(Arc::new(FaultPlan::new()));
        client.pull("feat", &[0], &mut out).unwrap();
        assert_eq!(out[0], data[0] - 1.0, "primary missed outage updates");
    }

    #[test]
    fn prefetch_fails_over_and_demand_pull_stays_byte_identical() {
        use crate::ft::{FailWindow, FaultPlan};
        let dim = 4;
        let (cluster, policy, data) = range_cluster(dim);
        cluster.enable_replication();
        let mut plan = FaultPlan::new();
        plan.kv_outages = vec![FailWindow::permanent(0, 0)];
        plan.backoff = std::time::Duration::ZERO;
        cluster.set_fault_plan(Arc::new(plan));
        let mut client = cluster.client(2, policy);
        client.attach_cache(feat_cache(1 << 20));
        let tf = TypedFeatures::homogeneous("feat", dim);
        // rows 0 and 5 belong to the dead machine 0 (replica on 1),
        // row 12 to the healthy machine 1
        let ids: Vec<NodeId> = vec![0, 5, 12];
        let fetched = client.prefetch_typed(&tf, &ids, false).unwrap();
        assert_eq!(fetched, 3, "prefetch failed over instead of erroring");
        let bytes = cluster.cost.network_bytes();
        let mut out = vec![0f32; ids.len() * dim];
        let remote = client.pull("feat", &ids, &mut out).unwrap();
        assert_eq!(remote, 0, "demand pull must hit the warmed cache");
        assert_eq!(cluster.cost.network_bytes(), bytes);
        for (i, &gid) in ids.iter().enumerate() {
            assert_eq!(
                &out[i * dim..(i + 1) * dim],
                &data[gid as usize * dim..(gid as usize + 1) * dim],
                "row {gid}"
            );
        }
    }

    /// Property (docs/DESIGN.md §12): after any interleaving of sparse
    /// updates, failovers, and rejoins, the replicated cluster holds
    /// exactly the bytes of a fault-free twin driven by the same update
    /// stream — and every primary shard is byte-identical to its
    /// standby's replica table (the all-reduce-barrier consistency
    /// invariant; every dead primary rejoins before the check, as the
    /// barrier protocol requires).
    #[test]
    fn prop_replicas_match_a_fault_free_twin_after_any_interleaving() {
        crate::util::proptest::forall(
            97,
            12,
            |r| {
                let k = 1 + r.usize_below(12);
                (0..k)
                    .map(|_| {
                        (r.usize_below(4) as u8, r.below(30) as u32)
                    })
                    .collect::<Vec<_>>()
            },
            |ops| {
                let dim = 2;
                let (faulted, p1, _) = range_cluster(dim);
                let (twin, p2, _) = range_cluster(dim);
                faulted.enable_replication();
                let rs = faulted.replica_set().unwrap();
                let mut cf = faulted.client(1, p1);
                let mut ct = twin.client(1, p2);
                for &(kind, x) in ops {
                    match kind {
                        0 | 1 => {
                            let ids = vec![x as NodeId];
                            let g = vec![1.0f32; dim];
                            cf.push_grad("feat", &ids, &g, 0.1)
                                .map_err(|e| e.to_string())?;
                            ct.push_grad("feat", &ids, &g, 0.1)
                                .map_err(|e| e.to_string())?;
                        }
                        2 => {
                            rs.mark_failed(x % 3);
                        }
                        _ => {
                            if rs.is_failed(x % 3) {
                                faulted.rejoin_server(x % 3);
                            }
                        }
                    }
                }
                for m in 0..3 {
                    if rs.is_failed(m) {
                        faulted.rejoin_server(m);
                    }
                }
                for m in 0..3u32 {
                    let standby = rs.replica_owner(m) as usize;
                    for (name, d, want) in
                        twin.servers[m as usize].export_shards()
                    {
                        let locals: Vec<u32> =
                            (0..(want.len() / d) as u32).collect();
                        let mut got = vec![0f32; want.len()];
                        faulted.servers[m as usize]
                            .read_rows(&name, &locals, &mut got)
                            .map_err(|e| e.to_string())?;
                        if got != want {
                            return Err(format!(
                                "m{m} {name} diverged from the twin"
                            ));
                        }
                        let mut rep = vec![0f32; want.len()];
                        faulted.servers[standby]
                            .read_rows(
                                &replica_table(m, &name),
                                &locals,
                                &mut rep,
                            )
                            .map_err(|e| e.to_string())?;
                        if rep != want {
                            return Err(format!(
                                "m{m} {name} replica diverged"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn concurrent_pulls_are_safe() {
        let dim = 4;
        let (cluster, policy, data) = range_cluster(dim);
        let hs: Vec<_> = (0..3u32)
            .map(|m| {
                let mut c = cluster.client(m, policy.clone());
                let data = data.clone();
                std::thread::spawn(move || {
                    let mut out = vec![0f32; dim];
                    for gid in 0..30u32 {
                        c.pull("feat", &[gid], &mut out).unwrap();
                        assert_eq!(
                            &out[..],
                            &data[gid as usize * dim..(gid as usize + 1) * dim]
                        );
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    }
}
