//! Learnable sparse vertex embeddings (§3.1 "sparse parameters").
//!
//! Some GNN models learn an embedding per vertex; only the rows touched by
//! a mini-batch are updated. [`EmbeddingTable`] wraps a KVStore tensor with
//! deterministic initialization and the trainer-facing gather/update API.
//! Updates go through `KvClient::push_grad`, i.e. they are routed to the
//! owning machine and applied there (never broadcast — the KVStore *is*
//! the optimizer state for sparse params).
//!
//! Cache coherence: when the gathering client caches this table's rows,
//! `push_grad` is the invalidation point. In strict mode
//! (`embedding_staleness = 0`, the default) every update invalidates the
//! cached copies it touched before returning, so a gather after an
//! update is byte-identical to an uncached client. With a bounded window
//! `K > 0`, cached rows may serve values up to K sparse updates old —
//! the DistGNN-style accuracy-vs-speed knob; see
//! `KvClient::set_embedding_staleness`.

use std::sync::Arc;

use crate::graph::NodeId;
use crate::net::RpcError;
use crate::util::Rng;

use super::policy::PartitionPolicy;
use super::store::{KvClient, KvCluster};

pub struct EmbeddingTable {
    pub name: String,
    pub dim: usize,
    pub n_rows: usize,
}

impl EmbeddingTable {
    /// Create + register on the cluster with N(0, scale) init.
    pub fn create(
        cluster: &Arc<KvCluster>,
        policy: &dyn PartitionPolicy,
        name: &str,
        n_rows: usize,
        dim: usize,
        scale: f32,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n_rows * dim)
            .map(|_| rng.normal() as f32 * scale)
            .collect();
        cluster.register_partitioned(name, &data, dim, policy);
        Self { name: name.to_string(), dim, n_rows }
    }

    /// Gather rows for a mini-batch. Returns the remote-row count, or
    /// the RPC error of the underlying pull (injected outage, unknown
    /// tensor on a mis-deployed cluster).
    pub fn gather(
        &self,
        client: &mut KvClient,
        ids: &[NodeId],
        out: &mut [f32],
    ) -> Result<usize, RpcError> {
        client.pull(&self.name, ids, out)
    }

    /// Apply row-sparse SGD for the touched rows. Invalidates any cached
    /// copies on the client per its staleness window (strict `0`:
    /// immediately, before this returns).
    pub fn update(
        &self,
        client: &mut KvClient,
        ids: &[NodeId],
        grads: &[f32],
        lr: f32,
    ) -> Result<(), RpcError> {
        client.push_grad(&self.name, ids, grads, lr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::policy::RangePolicy;
    use crate::net::CostModel;
    use crate::partition::NodeMap;

    #[test]
    fn embedding_update_roundtrip() {
        let nm = NodeMap { part_starts: vec![0, 8, 16] };
        let policy: Arc<dyn PartitionPolicy> =
            Arc::new(RangePolicy::new(nm));
        let cluster = KvCluster::new(2, Arc::new(CostModel::default()));
        let emb = EmbeddingTable::create(
            &cluster,
            policy.as_ref(),
            "emb",
            16,
            4,
            0.1,
            7,
        );
        let mut client = cluster.client(0, policy);
        let ids = vec![2 as NodeId, 12];
        let mut before = vec![0f32; 2 * 4];
        emb.gather(&mut client, &ids, &mut before).unwrap();
        let grads = vec![1.0f32; 2 * 4];
        emb.update(&mut client, &ids, &grads, 0.25).unwrap();
        let mut after = vec![0f32; 2 * 4];
        emb.gather(&mut client, &ids, &mut after).unwrap();
        for (b, a) in before.iter().zip(&after) {
            assert!((b - 0.25 - a).abs() < 1e-6);
        }
    }

    #[test]
    fn strict_mode_update_invalidates_cached_rows_through_the_table() {
        // regression: the optimizer path (EmbeddingTable::update →
        // push_grad) must not leave stale cached copies behind — a
        // gather through a caching client sees every update immediately
        use crate::kvstore::{CacheAdmission, FeatureCache};
        let nm = NodeMap { part_starts: vec![0, 8, 16] };
        let policy: Arc<dyn PartitionPolicy> =
            Arc::new(RangePolicy::new(nm));
        let cluster = KvCluster::new(2, Arc::new(CostModel::default()));
        let emb = EmbeddingTable::create(
            &cluster,
            policy.as_ref(),
            "emb",
            16,
            4,
            0.1,
            7,
        );
        let mut client = cluster.client(0, policy);
        client.attach_cache(FeatureCache::new(
            "emb",
            1 << 20,
            CacheAdmission::All,
            None,
        ));
        let ids = vec![12 as NodeId]; // remote for machine 0 → cached
        let mut before = vec![0f32; 4];
        emb.gather(&mut client, &ids, &mut before).unwrap();
        for step in 1..=3 {
            let grads = vec![1.0f32; 4];
            emb.update(&mut client, &ids, &grads, 0.25).unwrap();
            let mut after = vec![0f32; 4];
            emb.gather(&mut client, &ids, &mut after).unwrap();
            for (b, a) in before.iter().zip(&after) {
                assert!(
                    (b - 0.25 * step as f32 - a).abs() < 1e-6,
                    "stale cached embedding row served at step {step}"
                );
            }
        }
        let s = client.cache_stats().unwrap();
        assert_eq!(s.hit_rows, 0, "every gather after an update re-fetched");
    }

    #[test]
    fn bounded_staleness_lags_then_converges_on_flush() {
        // embedding_staleness = 2: a gather between the two updates of a
        // window may serve the pre-window value; the flush exposes both
        use crate::kvstore::{CacheAdmission, FeatureCache};
        let nm = NodeMap { part_starts: vec![0, 8, 16] };
        let policy: Arc<dyn PartitionPolicy> =
            Arc::new(RangePolicy::new(nm));
        let cluster = KvCluster::new(2, Arc::new(CostModel::default()));
        let emb = EmbeddingTable::create(
            &cluster,
            policy.as_ref(),
            "emb",
            16,
            4,
            0.1,
            7,
        );
        let mut client = cluster.client(0, policy);
        client.attach_cache(FeatureCache::new(
            "emb",
            1 << 20,
            CacheAdmission::All,
            None,
        ));
        client.set_embedding_staleness(2);
        let ids = vec![12 as NodeId];
        let mut base = vec![0f32; 4];
        emb.gather(&mut client, &ids, &mut base).unwrap();
        let grads = vec![1.0f32; 4];
        emb.update(&mut client, &ids, &grads, 0.25).unwrap();
        let mut mid = vec![0f32; 4];
        emb.gather(&mut client, &ids, &mut mid).unwrap();
        assert_eq!(mid, base, "within the window the cached row serves");
        emb.update(&mut client, &ids, &grads, 0.25).unwrap();
        let mut fresh = vec![0f32; 4];
        emb.gather(&mut client, &ids, &mut fresh).unwrap();
        for (b, f) in base.iter().zip(&fresh) {
            assert!(
                (b - 0.5 - f).abs() < 1e-6,
                "flush must expose the full window's updates"
            );
        }
    }

    #[test]
    fn init_is_deterministic() {
        let nm = NodeMap { part_starts: vec![0, 16] };
        let policy: Arc<dyn PartitionPolicy> =
            Arc::new(RangePolicy::new(nm));
        let c1 = KvCluster::new(1, Arc::new(CostModel::default()));
        let c2 = KvCluster::new(1, Arc::new(CostModel::default()));
        let e1 =
            EmbeddingTable::create(&c1, policy.as_ref(), "e", 16, 3, 0.1, 9);
        let e2 =
            EmbeddingTable::create(&c2, policy.as_ref(), "e", 16, 3, 0.1, 9);
        let ids: Vec<NodeId> = (0..16).collect();
        let mut a = vec![0f32; 16 * 3];
        let mut b = vec![0f32; 16 * 3];
        e1.gather(&mut c1.client(0, policy.clone()), &ids, &mut a)
            .unwrap();
        e2.gather(&mut c2.client(0, policy.clone()), &ids, &mut b)
            .unwrap();
        assert_eq!(a, b);
    }
}
