//! Trainer-side cache of **remote** feature rows (§Perf).
//!
//! DistDGL-style mini-batch training spends most of its network budget
//! re-pulling the same boundary-vertex features epoch after epoch: the
//! frontier of consecutive mini-batches overlaps heavily, and min-edge-cut
//! partitioning concentrates the remote accesses on a small set of
//! high-degree boundary vertices. [`FeatureCache`] keeps those rows in
//! trainer memory so [`KvClient::pull`](super::KvClient::pull) serves them
//! without touching the wire:
//!
//! - **Scope** — one cache per trainer per tensor group (normally the
//!   `"feat"` feature tables). Rows are keyed by **(ntype, row id)**: a
//!   heterogeneous graph's per-ntype tables share one budget, and the
//!   homogeneous case is the trivial single-ntype key (byte-identical to
//!   an untyped cache). Local rows are never cached (shared memory is
//!   already free); only rows whose owner is a different machine enter
//!   the cache.
//! - **Admission** — [`CacheAdmission::All`] admits every fetched remote
//!   row; [`CacheAdmission::Degree`] admits only vertices of degree ≥ a
//!   threshold, prioritizing the high-degree boundary vertices that
//!   dominate repeat traffic (MassiveGNN/DistGNN's observation).
//! - **Eviction** — CLOCK (second-chance): a hit sets the slot's
//!   reference bit; the rotating hand evicts the first unreferenced slot.
//!   Rows **pinned** by the predictive prefetcher (needed by an imminent
//!   batch, docs/DESIGN.md §10) are skipped outright; the sweep is
//!   bounded so an all-pinned cache refuses the insert instead of
//!   spinning. Row storage is a single flat `Vec<f32>` (slot `i` at
//!   `i*dim`), so a full cache never reallocates.
//! - **Budget** — a byte budget caps `capacity = budget / (row bytes +
//!   bookkeeping)`. A budget of 0 disables the cache entirely (the pull
//!   path degenerates to the uncached behavior, byte for byte).
//! - **Coherence** — the cache is meant for immutable tensors (input
//!   features). `KvClient::push_grad` on the cached tensor invalidates
//!   the touched rows, so a pull after a sparse update through the *same*
//!   client is never stale (in strict mode; the bounded-staleness
//!   embedding knob relaxes exactly this — see
//!   [`KvClient::set_embedding_staleness`](super::KvClient::set_embedding_staleness)).
//!   Cross-client writes are not tracked.
//!
//! Correctness bar (tested): cached and uncached pulls return
//! byte-identical rows, and all randomness is untouched — the cache never
//! consumes RNG state. Prefetched rows are copies of the same immutable
//! tensor rows a demand pull would fetch, so warming the cache ahead of
//! demand cannot change a single served byte.
//!
//! **Thread-safety audit (worker pool + prefetcher).** A bare
//! [`FeatureCache`] is plain single-threaded state — no interior
//! mutability, no lock on the hit path. When a trainer runs N sampling
//! workers and/or the predictive prefetcher, the forked
//! [`KvClient`](super::KvClient)s share one [`SharedFeatureCache`]: the
//! budget is striped across `cache_shards` independent
//! `Mutex<FeatureCache>` stripes routed by row id, so prefetch inserts on
//! one stripe never serialize against worker lookups on another.
//! Invariants that span fields (map ↔ slots ↔ data ↔ stats) live entirely
//! inside one stripe and are only ever observed consistent under its
//! lock. Under sharing, *which* worker's pull is counted as the miss for
//! a cold row is schedule-dependent — two workers can race the same cold
//! row and both miss — but `hit_rows + miss_rows` still equals the total
//! remote lookups and every miss is a fetched row (test:
//! `forked_clients_share_cache_and_stats_stay_consistent`), and served
//! bytes are identical in every interleaving because entries are
//! immutable copies of immutable tensor rows.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};
use rustc_hash::{FxHashMap, FxHashSet};

use crate::graph::NodeId;

/// Which fetched remote rows are worth keeping.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CacheAdmission {
    /// Admit every remote row.
    All,
    /// Admit rows with vertex degree ≥ the threshold. `None` = auto:
    /// resolved to the dataset mean degree at deploy time
    /// ([`Cluster::deploy`](crate::cluster::Cluster) wires the degree
    /// table). Without a degree table the policy admits everything.
    Degree(Option<u32>),
}

impl CacheAdmission {
    /// Parse the `cache_admission` config value: `all`, `degree`, or
    /// `degree:<min>`.
    pub fn parse(v: &str) -> Result<Self> {
        Ok(match v {
            "all" => Self::All,
            "degree" => Self::Degree(None),
            _ => match v.strip_prefix("degree:") {
                Some(min) => Self::Degree(Some(min.parse()?)),
                None => {
                    bail!("cache_admission must be all|degree|degree:<min>")
                }
            },
        })
    }
}

/// Monotonic counters; deltas feed `cache.*` [`Metrics`] counters.
///
/// [`Metrics`]: crate::metrics::Metrics
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Remote rows served from trainer memory.
    pub hit_rows: u64,
    /// Remote rows that had to be fetched over the network.
    pub miss_rows: u64,
    /// Rows displaced by the CLOCK hand.
    pub evicted_rows: u64,
    /// Fetched rows the admission policy declined to keep (or that found
    /// every slot pinned).
    pub rejected_rows: u64,
    /// Response payload bytes that never crossed the wire (`hit_rows *
    /// dim * 4`).
    pub remote_bytes_saved: u64,
    /// Rows fetched ahead of demand by the predictive prefetcher.
    pub prefetch_issued: u64,
    /// Demand lookups served by a row the prefetcher fetched (each
    /// prefetched row counts at most once — its first demand hit).
    pub prefetch_hits: u64,
    /// Payload bytes of prefetched rows evicted or invalidated before
    /// any demand hit (prefetch that paid wire cost for nothing).
    pub prefetch_wasted_bytes: u64,
    /// Pin events on resident rows (imminent-batch protection from the
    /// CLOCK hand; each demand hit releases one pin).
    pub pinned_rows: u64,
}

impl CacheStats {
    /// Hits / (hits + misses); 0 when the cache saw no remote rows.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hit_rows + self.miss_rows;
        if total == 0 {
            0.0
        } else {
            self.hit_rows as f64 / total as f64
        }
    }

    fn minus(&self, o: &CacheStats) -> CacheStats {
        CacheStats {
            hit_rows: self.hit_rows - o.hit_rows,
            miss_rows: self.miss_rows - o.miss_rows,
            evicted_rows: self.evicted_rows - o.evicted_rows,
            rejected_rows: self.rejected_rows - o.rejected_rows,
            remote_bytes_saved: self.remote_bytes_saved
                - o.remote_bytes_saved,
            prefetch_issued: self.prefetch_issued - o.prefetch_issued,
            prefetch_hits: self.prefetch_hits - o.prefetch_hits,
            prefetch_wasted_bytes: self.prefetch_wasted_bytes
                - o.prefetch_wasted_bytes,
            pinned_rows: self.pinned_rows - o.pinned_rows,
        }
    }

    fn plus(&self, o: &CacheStats) -> CacheStats {
        CacheStats {
            hit_rows: self.hit_rows + o.hit_rows,
            miss_rows: self.miss_rows + o.miss_rows,
            evicted_rows: self.evicted_rows + o.evicted_rows,
            rejected_rows: self.rejected_rows + o.rejected_rows,
            remote_bytes_saved: self.remote_bytes_saved
                + o.remote_bytes_saved,
            prefetch_issued: self.prefetch_issued + o.prefetch_issued,
            prefetch_hits: self.prefetch_hits + o.prefetch_hits,
            prefetch_wasted_bytes: self.prefetch_wasted_bytes
                + o.prefetch_wasted_bytes,
            pinned_rows: self.pinned_rows + o.pinned_rows,
        }
    }
}

/// Per-slot bookkeeping bytes charged against the budget on top of the
/// row payload (map entry + slot record, amortized).
const ROW_OVERHEAD_BYTES: usize = 24;

/// Composite cache key: (ntype, row id). Homogeneous tensors use ntype 0.
#[inline]
fn key(ntype: u8, gid: NodeId) -> u64 {
    ((ntype as u64) << 32) | gid as u64
}

/// Does `name` belong to the tensor group rooted at `base`? True for the
/// base name itself and for any per-ntype table `base.<ntype>` — writes
/// to either must invalidate. Shared by [`FeatureCache::covers`] and
/// [`SharedFeatureCache::covers`].
#[inline]
fn covers_name(base: &str, name: &str) -> bool {
    name == base
        || (name.len() > base.len() + 1
            && name.starts_with(base)
            && name.as_bytes()[base.len()] == b'.')
}

struct Slot {
    key: u64,
    /// CLOCK reference bit: set on hit, cleared by a passing hand.
    referenced: bool,
    /// Entered via the prefetcher and not yet demand-hit. Cleared by the
    /// first demand hit (counting `prefetch_hits`); still set at
    /// eviction/invalidation, the fetch was wasted wire traffic
    /// (`prefetch_wasted_bytes`).
    prefetched: bool,
    /// Outstanding pins: rows an imminent batch is known to need. The
    /// CLOCK hand skips pinned slots; each demand hit releases one pin.
    pins: u32,
}

/// See the module docs. Single-threaded by design: each trainer's
/// [`KvClient`](super::KvClient) owns its own cache (behind a
/// [`SharedFeatureCache`] stripe when workers/prefetcher share it), so no
/// locking sits inside the hit path itself.
pub struct FeatureCache {
    tensor: String,
    budget_bytes: usize,
    admission: CacheAdmission,
    degrees: Option<Arc<Vec<u32>>>,
    /// Per-ntype row widths; empty until the first pull binds them. A
    /// homogeneous tensor binds the single-entry `[dim]`.
    dims: Vec<usize>,
    /// Slot stride = max per-ntype dim (rows narrower than the stride
    /// only use their prefix). One arena keeps the flat-storage/CLOCK
    /// machinery identical to the untyped cache; the cost is that a
    /// narrow ntype's row occupies (and is charged) a full-width slot.
    /// Per-width arenas would pack more rows into the same budget on
    /// very skewed dim mixes — revisit if typed hit rates lag.
    slot_width: usize,
    /// Max rows under the byte budget (0 until `dims` is known).
    capacity: usize,
    map: FxHashMap<u64, u32>,
    slots: Vec<Slot>,
    /// Flat row storage: slot `i` occupies
    /// `data[i*slot_width..(i+1)*slot_width]`.
    data: Vec<f32>,
    /// Slots released by [`Self::invalidate`], reused before eviction.
    free: Vec<u32>,
    hand: usize,
    stats: CacheStats,
    reported: CacheStats,
}

impl FeatureCache {
    pub fn new(
        tensor: &str,
        budget_bytes: usize,
        admission: CacheAdmission,
        degrees: Option<Arc<Vec<u32>>>,
    ) -> Self {
        Self {
            tensor: tensor.to_string(),
            budget_bytes,
            admission,
            degrees,
            dims: Vec::new(),
            slot_width: 0,
            capacity: 0,
            map: FxHashMap::default(),
            slots: Vec::new(),
            data: Vec::new(),
            free: Vec::new(),
            hand: 0,
            stats: CacheStats::default(),
            reported: CacheStats::default(),
        }
    }

    /// Name of the cached tensor (only pulls of this tensor consult the
    /// cache).
    pub fn tensor(&self) -> &str {
        &self.tensor
    }

    /// Does `name` belong to this cache's tensor group? True for the
    /// base name itself and for any per-ntype table `base.<ntype>` —
    /// writes to either must invalidate.
    pub fn covers(&self, name: &str) -> bool {
        covers_name(&self.tensor, name)
    }

    /// False iff the byte budget is 0 (fully disabled, zero overhead).
    pub fn is_enabled(&self) -> bool {
        self.budget_bytes > 0
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Rows currently resident.
    pub fn rows(&self) -> usize {
        self.map.len()
    }

    /// Bytes charged against the budget (payload + bookkeeping).
    pub fn used_bytes(&self) -> usize {
        self.map.len() * (self.slot_width * 4 + ROW_OVERHEAD_BYTES)
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Counters accumulated since the previous `take_delta` call (for
    /// periodic publication into [`Metrics`](crate::metrics::Metrics)).
    pub fn take_delta(&mut self) -> CacheStats {
        let d = self.stats.minus(&self.reported);
        self.reported = self.stats;
        d
    }

    /// Bind the per-ntype row widths on first use and derive the row
    /// capacity from the byte budget.
    ///
    /// **Arena layout invariant** (protected by the assert below): the
    /// cache is one flat arena of equal-width slots, `slot_width =
    /// max(dims)`, so *any* ntype's row fits *any* slot and the CLOCK
    /// hand never needs to match widths when reusing a victim. That only
    /// holds if `dims` is bound exactly once: re-binding while rows are
    /// resident would silently reinterpret live slots under new widths
    /// (slot `i`'s payload starts at `i*slot_width`, and `lookup` copies
    /// the `dims[ntype]` prefix). A cache is therefore dedicated to one
    /// tensor group for its whole life; callers that need a different
    /// dim set build a new cache. The single-table case is just the
    /// one-entry `dims = [dim]` instance of the same path — there is
    /// deliberately no separate scalar entry point.
    pub fn ensure_dims(&mut self, dims: &[usize]) {
        if self.dims == dims {
            return;
        }
        assert!(
            self.dims.is_empty() && self.map.is_empty(),
            "FeatureCache for {:?} re-bound from dims {:?} to {:?}",
            self.tensor,
            self.dims,
            dims
        );
        assert!(!dims.is_empty());
        self.dims = dims.to_vec();
        self.slot_width = dims.iter().copied().max().unwrap_or(0).max(1);
        self.capacity =
            self.budget_bytes / (self.slot_width * 4 + ROW_OVERHEAD_BYTES);
    }

    /// Copy the cached row for `(ntype, gid)` into `out` (len =
    /// `dims[ntype]`) and mark it recently used. Counts a hit or a miss;
    /// a hit releases one pin and counts the row's first demand hit
    /// after a prefetch as a `prefetch_hit`.
    pub fn lookup(&mut self, ntype: u8, gid: NodeId, out: &mut [f32]) -> bool {
        match self.map.get(&key(ntype, gid)) {
            Some(&s) => {
                let d = self.dims[ntype as usize];
                let w = self.slot_width;
                let s = s as usize;
                out[..d].copy_from_slice(&self.data[s * w..s * w + d]);
                let slot = &mut self.slots[s];
                slot.referenced = true;
                if slot.prefetched {
                    slot.prefetched = false;
                    self.stats.prefetch_hits += 1;
                }
                if slot.pins > 0 {
                    slot.pins -= 1;
                }
                self.stats.hit_rows += 1;
                self.stats.remote_bytes_saved += (d * 4) as u64;
                true
            }
            None => {
                self.stats.miss_rows += 1;
                false
            }
        }
    }

    /// Is `(ntype, gid)` resident? A pure peek for prefetch dedup: no
    /// stats, no reference bit — it must not perturb hit accounting or
    /// CLOCK state.
    pub fn contains(&self, ntype: u8, gid: NodeId) -> bool {
        self.map.contains_key(&key(ntype, gid))
    }

    /// Offer a freshly fetched remote row of `(ntype, gid)`. Subject to
    /// admission; evicts via CLOCK when the budget is exhausted.
    pub fn insert(&mut self, ntype: u8, gid: NodeId, row: &[f32]) {
        self.insert_impl(ntype, gid, row, false);
    }

    /// [`Self::insert`] for a row the prefetcher fetched ahead of
    /// demand: counts `prefetch_issued` and flags the slot so its first
    /// demand hit (or its eviction without one) is attributed to the
    /// prefetcher.
    pub fn insert_prefetched(&mut self, ntype: u8, gid: NodeId, row: &[f32]) {
        self.stats.prefetch_issued += 1;
        self.insert_impl(ntype, gid, row, true);
    }

    fn insert_impl(
        &mut self,
        ntype: u8,
        gid: NodeId,
        row: &[f32],
        prefetched: bool,
    ) {
        let k = key(ntype, gid);
        if self.capacity == 0 || self.map.contains_key(&k) {
            return;
        }
        if !self.admit(gid) {
            self.stats.rejected_rows += 1;
            return;
        }
        let d = self.dims[ntype as usize];
        let w = self.slot_width;
        let slot = if let Some(s) = self.free.pop() {
            s
        } else if self.slots.len() < self.capacity {
            self.slots.push(Slot {
                key: k,
                referenced: false,
                prefetched: false,
                pins: 0,
            });
            self.data.resize(self.slots.len() * w, 0.0);
            (self.slots.len() - 1) as u32
        } else {
            match self.evict() {
                Some(s) => s,
                None => {
                    // every slot pinned for an imminent batch: refuse
                    // the insert rather than displace protected rows
                    self.stats.rejected_rows += 1;
                    return;
                }
            }
        };
        let i = slot as usize;
        self.slots[i] =
            Slot { key: k, referenced: false, prefetched, pins: 0 };
        self.data[i * w..i * w + d].copy_from_slice(&row[..d]);
        self.map.insert(k, slot);
    }

    /// Pin a *resident* row an imminent batch needs: the CLOCK hand will
    /// not evict it until a demand hit releases the pin. Returns whether
    /// the row was resident (pinning a non-resident row is a no-op — the
    /// prefetcher pins right after inserting).
    pub fn pin(&mut self, ntype: u8, gid: NodeId) -> bool {
        match self.map.get(&key(ntype, gid)) {
            Some(&s) => {
                let slot = &mut self.slots[s as usize];
                slot.pins += 1;
                slot.referenced = true;
                self.stats.pinned_rows += 1;
                true
            }
            None => false,
        }
    }

    /// Drop rows (sparse-update coherence: stale copies must not survive
    /// a `push_grad` on the cached tensor group). The writer does not
    /// know which ntype a row was cached under, so every bound ntype's
    /// key is dropped. Pins do not protect against invalidation —
    /// coherence outranks the prefetch hold.
    pub fn invalidate(&mut self, ids: &[NodeId]) {
        let n_ntypes = self.dims.len().max(1) as u8;
        for &gid in ids {
            for t in 0..n_ntypes {
                if let Some(s) = self.map.remove(&key(t, gid)) {
                    let slot = &mut self.slots[s as usize];
                    slot.referenced = false;
                    slot.pins = 0;
                    if slot.prefetched {
                        slot.prefetched = false;
                        self.stats.prefetch_wasted_bytes +=
                            (self.dims[t as usize] * 4) as u64;
                    }
                    self.free.push(s);
                }
            }
        }
    }

    fn admit(&self, gid: NodeId) -> bool {
        match self.admission {
            CacheAdmission::All => true,
            CacheAdmission::Degree(min) => match &self.degrees {
                Some(deg) => {
                    deg.get(gid as usize).copied().unwrap_or(0)
                        >= min.unwrap_or(0)
                }
                None => true,
            },
        }
    }

    /// CLOCK hand: clear reference bits until an unreferenced, unpinned
    /// victim is found. Only called with a full cache and an empty free
    /// list, so every slot is live; without pins the sweep terminates
    /// within two laps (first lap clears bits, second finds a victim).
    /// Pinned slots are skipped *without* clearing their bit, so the
    /// sweep is explicitly bounded to two laps — `None` means every slot
    /// is pinned and the caller must decline the insert.
    fn evict(&mut self) -> Option<u32> {
        for _ in 0..2 * self.slots.len() {
            let i = self.hand;
            self.hand = (self.hand + 1) % self.slots.len();
            let s = &mut self.slots[i];
            if s.pins > 0 {
                continue;
            }
            if s.referenced {
                s.referenced = false;
            } else {
                self.map.remove(&s.key);
                self.stats.evicted_rows += 1;
                if s.prefetched {
                    s.prefetched = false;
                    let t = (s.key >> 32) as usize;
                    self.stats.prefetch_wasted_bytes +=
                        (self.dims[t] * 4) as u64;
                }
                return Some(i as u32);
            }
        }
        None
    }
}

/// The cache handle every forked [`KvClient`](super::KvClient) (sampling
/// workers + the predictive prefetcher) shares: one byte budget striped
/// across `n_shards` independently locked [`FeatureCache`]s, routed by
/// row id, so prefetch inserts on one stripe never serialize against
/// demand lookups on another. `n_shards = 1` is semantically the old
/// single `Arc<Mutex<FeatureCache>>` (one lock, one arena).
///
/// Also owns the two pieces of cross-client prefetch coordination:
///
/// - the **in-flight set** — keys the prefetcher is currently pulling,
///   so overlapping lookahead windows never double-fetch a row;
/// - the **invalidation epoch** — bumped by every [`Self::invalidate`];
///   a prefetch captures the epoch before pulling and its insert is
///   dropped if an invalidation landed in between, so a stale pre-update
///   value can never overwrite coherence (strict-mode byte identity).
///
/// Routing by row id (not the full (ntype, id) key) keeps all of a
/// vertex's typed rows — and therefore a whole `invalidate([gid])` — on
/// one stripe.
pub struct SharedFeatureCache {
    shards: Vec<Mutex<FeatureCache>>,
    tensor: String,
    enabled: bool,
    inflight: Mutex<FxHashSet<u64>>,
    epoch: AtomicU64,
}

impl SharedFeatureCache {
    /// Stripe `proto`'s byte budget across `n_shards` (each stripe gets
    /// `budget / n`; a budget too small to give every stripe a slot just
    /// leaves some stripes disabled — correctness is unaffected because
    /// the cache is value-transparent).
    pub fn new(proto: FeatureCache, n_shards: usize) -> Self {
        let n = n_shards.max(1);
        let tensor = proto.tensor.clone();
        let enabled = proto.is_enabled();
        let per = proto.budget_bytes / n;
        let shards = (0..n)
            .map(|_| {
                Mutex::new(FeatureCache::new(
                    &tensor,
                    per,
                    proto.admission.clone(),
                    proto.degrees.clone(),
                ))
            })
            .collect();
        Self {
            shards,
            tensor,
            enabled,
            inflight: Mutex::new(FxHashSet::default()),
            epoch: AtomicU64::new(0),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn tensor(&self) -> &str {
        &self.tensor
    }

    pub fn covers(&self, name: &str) -> bool {
        covers_name(&self.tensor, name)
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    #[inline]
    fn shard(&self, gid: NodeId) -> &Mutex<FeatureCache> {
        &self.shards[gid as usize % self.shards.len()]
    }

    /// Bind row widths on every stripe (see
    /// [`FeatureCache::ensure_dims`] for the arena invariant).
    pub fn ensure_dims(&self, dims: &[usize]) {
        for s in &self.shards {
            s.lock().unwrap().ensure_dims(dims);
        }
    }

    pub fn lookup(&self, ntype: u8, gid: NodeId, out: &mut [f32]) -> bool {
        self.shard(gid).lock().unwrap().lookup(ntype, gid, out)
    }

    /// Non-counting residency peek (prefetch dedup).
    pub fn contains(&self, ntype: u8, gid: NodeId) -> bool {
        self.shard(gid).lock().unwrap().contains(ntype, gid)
    }

    pub fn insert(&self, ntype: u8, gid: NodeId, row: &[f32]) {
        self.shard(gid).lock().unwrap().insert(ntype, gid, row);
    }

    /// Insert a prefetched row, unless an invalidation has landed since
    /// the prefetcher captured `epoch` (the row's fetched value may
    /// predate a sparse update — dropping it preserves strict-mode
    /// coherence; the wasted fetch is still counted as issued).
    pub fn insert_prefetched(
        &self,
        ntype: u8,
        gid: NodeId,
        row: &[f32],
        epoch: u64,
    ) {
        let mut shard = self.shard(gid).lock().unwrap();
        if self.epoch.load(Ordering::Acquire) != epoch {
            // count the issued row (it did cross the wire) as
            // immediately wasted
            let d = shard.dims.get(ntype as usize).copied().unwrap_or(0);
            shard.stats.prefetch_issued += 1;
            shard.stats.prefetch_wasted_bytes += (d * 4) as u64;
            return;
        }
        shard.insert_prefetched(ntype, gid, row);
    }

    /// Pin a resident row for an imminent batch.
    pub fn pin(&self, ntype: u8, gid: NodeId) -> bool {
        self.shard(gid).lock().unwrap().pin(ntype, gid)
    }

    /// Invalidate rows on their stripes and bump the invalidation epoch
    /// so concurrent in-flight prefetches cannot resurrect stale values.
    pub fn invalidate(&self, ids: &[NodeId]) {
        self.epoch.fetch_add(1, Ordering::AcqRel);
        for &gid in ids {
            self.shard(gid).lock().unwrap().invalidate(&[gid]);
        }
    }

    /// The current invalidation epoch; capture before a prefetch pull,
    /// pass to [`Self::insert_prefetched`].
    pub fn invalidation_epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Claim `(ntype, gid)` for an in-flight prefetch pull. `false` =
    /// another pull already has it (skip — dedup against in-flight).
    pub fn begin_inflight(&self, ntype: u8, gid: NodeId) -> bool {
        self.inflight.lock().unwrap().insert(key(ntype, gid))
    }

    /// Release the in-flight claim (after the insert, or on error).
    pub fn end_inflight(&self, ntype: u8, gid: NodeId) {
        self.inflight.lock().unwrap().remove(&key(ntype, gid));
    }

    /// Aggregate counters across stripes.
    pub fn stats(&self) -> CacheStats {
        self.shards.iter().fold(CacheStats::default(), |acc, s| {
            acc.plus(&s.lock().unwrap().stats())
        })
    }

    /// Aggregate per-stripe deltas since the last call (each stripe's
    /// cursor advances under its own lock, so concurrent callers never
    /// double-count).
    pub fn take_delta(&self) -> CacheStats {
        self.shards.iter().fold(CacheStats::default(), |acc, s| {
            acc.plus(&s.lock().unwrap().take_delta())
        })
    }

    /// Rows resident across all stripes.
    pub fn rows(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().rows()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(gid: NodeId, dim: usize) -> Vec<f32> {
        (0..dim).map(|d| (gid as usize * dim + d) as f32).collect()
    }

    fn cache_for_rows(n_rows: usize, dim: usize) -> FeatureCache {
        let budget = n_rows * (dim * 4 + ROW_OVERHEAD_BYTES);
        let mut c =
            FeatureCache::new("feat", budget, CacheAdmission::All, None);
        c.ensure_dims(&[dim]);
        c
    }

    #[test]
    fn eviction_honors_byte_budget() {
        let dim = 4;
        let mut c = cache_for_rows(8, dim);
        let budget = c.budget_bytes();
        for gid in 0..100u32 {
            c.insert(0, gid, &row(gid, dim));
            assert!(c.used_bytes() <= budget, "over budget at gid {gid}");
        }
        assert_eq!(c.rows(), 8);
        assert_eq!(c.stats().evicted_rows, 92);
    }

    #[test]
    fn hits_return_inserted_bytes() {
        let dim = 6;
        let mut c = cache_for_rows(16, dim);
        for gid in [3u32, 9, 11] {
            c.insert(0, gid, &row(gid, dim));
        }
        let mut out = vec![0f32; dim];
        for gid in [9u32, 3, 11] {
            assert!(c.lookup(0, gid, &mut out));
            assert_eq!(out, row(gid, dim), "row {gid}");
        }
        assert!(!c.lookup(0, 999, &mut out));
        let s = c.stats();
        assert_eq!((s.hit_rows, s.miss_rows), (3, 1));
        assert_eq!(s.remote_bytes_saved, 3 * dim as u64 * 4);
    }

    #[test]
    fn clock_keeps_recently_referenced_rows() {
        let dim = 2;
        let mut c = cache_for_rows(2, dim);
        c.insert(0, 1, &row(1, dim));
        c.insert(0, 2, &row(2, dim));
        let mut out = vec![0f32; dim];
        assert!(c.lookup(0, 1, &mut out)); // reference row 1
        c.insert(0, 3, &row(3, dim)); // must evict the unreferenced row 2
        assert!(c.lookup(0, 1, &mut out), "referenced row was evicted");
        assert!(!c.lookup(0, 2, &mut out), "unreferenced row survived");
        assert!(c.lookup(0, 3, &mut out));
    }

    #[test]
    fn zero_budget_disables_everything() {
        let mut c =
            FeatureCache::new("feat", 0, CacheAdmission::All, None);
        c.ensure_dims(&[4]);
        assert!(!c.is_enabled());
        c.insert(0, 1, &row(1, 4));
        assert_eq!(c.rows(), 0);
        assert_eq!(c.stats(), CacheStats::default());
    }

    #[test]
    fn degree_admission_filters_low_degree_rows() {
        let dim = 2;
        let degrees = Arc::new(vec![1u32, 10, 2, 50]);
        let budget = 8 * (dim * 4 + ROW_OVERHEAD_BYTES);
        let mut c = FeatureCache::new(
            "feat",
            budget,
            CacheAdmission::Degree(Some(5)),
            Some(degrees),
        );
        c.ensure_dims(&[dim]);
        for gid in 0..4u32 {
            c.insert(0, gid, &row(gid, dim));
        }
        let mut out = vec![0f32; dim];
        assert!(!c.lookup(0, 0, &mut out)); // degree 1 < 5
        assert!(c.lookup(0, 1, &mut out)); // degree 10
        assert!(!c.lookup(0, 2, &mut out)); // degree 2
        assert!(c.lookup(0, 3, &mut out)); // degree 50
        assert_eq!(c.stats().rejected_rows, 2);
    }

    #[test]
    fn invalidate_releases_and_reuses_slots() {
        let dim = 3;
        let mut c = cache_for_rows(4, dim);
        for gid in 0..4u32 {
            c.insert(0, gid, &row(gid, dim));
        }
        c.invalidate(&[1, 2]);
        assert_eq!(c.rows(), 2);
        let mut out = vec![0f32; dim];
        assert!(!c.lookup(0, 1, &mut out));
        // freed slots are reused without evicting live rows
        c.insert(0, 10, &row(10, dim));
        c.insert(0, 11, &row(11, dim));
        assert_eq!(c.rows(), 4);
        assert_eq!(c.stats().evicted_rows, 0);
        assert!(c.lookup(0, 0, &mut out) && c.lookup(0, 3, &mut out));
    }

    #[test]
    fn take_delta_reports_increments_once() {
        let dim = 2;
        let mut c = cache_for_rows(4, dim);
        c.insert(0, 1, &row(1, dim));
        let mut out = vec![0f32; dim];
        c.lookup(0, 1, &mut out);
        let d1 = c.take_delta();
        assert_eq!(d1.hit_rows, 1);
        let d2 = c.take_delta();
        assert_eq!(d2, CacheStats::default());
        c.lookup(0, 1, &mut out);
        assert_eq!(c.take_delta().hit_rows, 1);
    }

    #[test]
    fn typed_keys_are_disjoint_and_use_their_own_dims() {
        // two ntypes sharing one budget: same row id under different
        // ntypes are distinct entries with their own row widths
        let dims = [4usize, 2];
        let budget = 8 * (4 * 4 + ROW_OVERHEAD_BYTES);
        let mut c =
            FeatureCache::new("feat", budget, CacheAdmission::All, None);
        c.ensure_dims(&dims);
        let wide = [1.0f32, 2.0, 3.0, 4.0];
        let narrow = [9.0f32, 8.0];
        c.insert(0, 5, &wide);
        c.insert(1, 5, &narrow);
        assert_eq!(c.rows(), 2);
        let mut out4 = [0f32; 4];
        let mut out2 = [0f32; 2];
        assert!(c.lookup(0, 5, &mut out4));
        assert_eq!(out4, wide);
        assert!(c.lookup(1, 5, &mut out2));
        assert_eq!(out2, narrow);
        // misses on the other ntype's ids
        assert!(!c.lookup(0, 6, &mut out4));
        assert!(!c.lookup(1, 6, &mut out2));
        // bytes saved respect per-ntype dims: 4*4 + 2*4
        assert_eq!(c.stats().remote_bytes_saved, (4 * 4 + 2 * 4) as u64);
    }

    #[test]
    fn admission_config_parses() {
        assert_eq!(CacheAdmission::parse("all").unwrap(), CacheAdmission::All);
        assert_eq!(
            CacheAdmission::parse("degree").unwrap(),
            CacheAdmission::Degree(None)
        );
        assert_eq!(
            CacheAdmission::parse("degree:12").unwrap(),
            CacheAdmission::Degree(Some(12))
        );
        assert!(CacheAdmission::parse("lru").is_err());
    }

    #[test]
    fn pinned_rows_survive_the_clock_hand() {
        let dim = 2;
        let mut c = cache_for_rows(2, dim);
        c.insert(0, 1, &row(1, dim));
        c.insert(0, 2, &row(2, dim));
        assert!(c.pin(0, 1));
        assert!(!c.pin(0, 99), "pinning a non-resident row is a no-op");
        // row 2 is unpinned+unreferenced: it must be the victim even
        // though the hand reaches (referenced, pinned) row 1 first
        c.insert(0, 3, &row(3, dim));
        let mut out = vec![0f32; dim];
        assert!(c.lookup(0, 1, &mut out), "pinned row was evicted");
        assert!(!c.lookup(0, 2, &mut out));
        assert!(c.lookup(0, 3, &mut out));
        assert_eq!(c.stats().pinned_rows, 1);
        // the demand hit released the pin: row 1 is now evictable
        c.insert(0, 4, &row(4, dim));
        c.insert(0, 5, &row(5, dim));
        assert_eq!(c.rows(), 2);
        assert!(!c.contains(0, 1), "released pin must not protect");
    }

    #[test]
    fn all_pinned_cache_rejects_inserts_and_terminates() {
        let dim = 2;
        let mut c = cache_for_rows(2, dim);
        c.insert(0, 1, &row(1, dim));
        c.insert(0, 2, &row(2, dim));
        assert!(c.pin(0, 1));
        assert!(c.pin(0, 2));
        // bounded sweep: no victim exists, the insert must be declined
        // (not spin) and counted
        c.insert(0, 3, &row(3, dim));
        assert!(!c.contains(0, 3));
        assert_eq!(c.rows(), 2);
        assert_eq!(c.stats().rejected_rows, 1);
        assert_eq!(c.stats().evicted_rows, 0);
    }

    #[test]
    fn prefetched_rows_count_hits_and_waste() {
        let dim = 4;
        let mut c = cache_for_rows(2, dim);
        c.insert_prefetched(0, 1, &row(1, dim));
        c.insert_prefetched(0, 2, &row(2, dim));
        assert_eq!(c.stats().prefetch_issued, 2);
        // first demand hit on row 1 is a prefetch hit; the second hit on
        // the same row is an ordinary hit
        let mut out = vec![0f32; dim];
        assert!(c.lookup(0, 1, &mut out));
        assert_eq!(out, row(1, dim));
        assert!(c.lookup(0, 1, &mut out));
        assert_eq!(c.stats().prefetch_hits, 1);
        // row 2 is evicted before any demand hit: its bytes were wasted
        c.insert(0, 3, &row(3, dim));
        let s = c.stats();
        assert_eq!(s.prefetch_wasted_bytes, (dim * 4) as u64);
        assert_eq!(s.prefetch_hits, 1);
    }

    #[test]
    fn invalidated_prefetch_counts_as_waste() {
        let dim = 3;
        let mut c = cache_for_rows(4, dim);
        c.insert_prefetched(0, 7, &row(7, dim));
        c.invalidate(&[7]);
        let s = c.stats();
        assert_eq!(s.prefetch_wasted_bytes, (dim * 4) as u64);
        assert_eq!(s.prefetch_hits, 0);
    }

    #[test]
    fn sharded_cache_serves_identical_bytes_and_aggregates_stats() {
        let dim = 4;
        let budget = 64 * (dim * 4 + ROW_OVERHEAD_BYTES);
        let proto =
            FeatureCache::new("feat", budget, CacheAdmission::All, None);
        let c = SharedFeatureCache::new(proto, 4);
        assert_eq!(c.n_shards(), 4);
        assert!(c.is_enabled());
        assert!(c.covers("feat") && c.covers("feat.1") && !c.covers("ft"));
        c.ensure_dims(&[dim]);
        for gid in 0..32u32 {
            c.insert(0, gid, &row(gid, dim));
        }
        let mut out = vec![0f32; dim];
        for gid in 0..32u32 {
            assert!(c.lookup(0, gid, &mut out), "row {gid}");
            assert_eq!(out, row(gid, dim));
        }
        assert!(!c.lookup(0, 500, &mut out));
        let s = c.stats();
        assert_eq!((s.hit_rows, s.miss_rows), (32, 1));
        assert_eq!(c.rows(), 32);
        // per-stripe delta cursors sum to the same aggregate exactly once
        let d = c.take_delta();
        assert_eq!((d.hit_rows, d.miss_rows), (32, 1));
        assert_eq!(c.take_delta(), CacheStats::default());
    }

    #[test]
    fn single_shard_matches_unsharded_semantics() {
        let dim = 2;
        let budget = 2 * (dim * 4 + ROW_OVERHEAD_BYTES);
        let proto =
            FeatureCache::new("feat", budget, CacheAdmission::All, None);
        let c = SharedFeatureCache::new(proto, 1);
        c.ensure_dims(&[dim]);
        c.insert(0, 1, &row(1, dim));
        c.insert(0, 2, &row(2, dim));
        let mut out = vec![0f32; dim];
        assert!(c.lookup(0, 1, &mut out)); // reference row 1
        c.insert(0, 3, &row(3, dim)); // CLOCK evicts row 2, as unsharded
        assert!(c.lookup(0, 1, &mut out));
        assert!(!c.contains(0, 2));
        assert!(c.lookup(0, 3, &mut out));
    }

    #[test]
    fn inflight_set_dedupes_concurrent_prefetches() {
        let proto =
            FeatureCache::new("feat", 1 << 16, CacheAdmission::All, None);
        let c = SharedFeatureCache::new(proto, 2);
        assert!(c.begin_inflight(0, 42));
        assert!(!c.begin_inflight(0, 42), "second claim must be refused");
        assert!(c.begin_inflight(1, 42), "ntypes claim independently");
        c.end_inflight(0, 42);
        assert!(c.begin_inflight(0, 42), "released claim is reclaimable");
    }

    #[test]
    fn invalidation_epoch_drops_stale_prefetch_inserts() {
        let dim = 2;
        let budget = 16 * (dim * 4 + ROW_OVERHEAD_BYTES);
        let proto =
            FeatureCache::new("feat", budget, CacheAdmission::All, None);
        let c = SharedFeatureCache::new(proto, 2);
        c.ensure_dims(&[dim]);
        let e = c.invalidation_epoch();
        // an invalidation lands while the prefetch pull is in flight:
        // the insert must be dropped (its value may predate the update)
        c.invalidate(&[1]);
        c.insert_prefetched(0, 1, &row(1, dim), e);
        assert!(!c.contains(0, 1), "stale prefetch insert survived");
        let s = c.stats();
        assert_eq!(s.prefetch_issued, 1);
        assert_eq!(s.prefetch_wasted_bytes, (dim * 4) as u64);
        // with a current epoch the insert lands normally
        let e2 = c.invalidation_epoch();
        c.insert_prefetched(0, 1, &row(1, dim), e2);
        assert!(c.contains(0, 1));
    }
}
