//! Trainer-side cache of **remote** feature rows (§Perf).
//!
//! DistDGL-style mini-batch training spends most of its network budget
//! re-pulling the same boundary-vertex features epoch after epoch: the
//! frontier of consecutive mini-batches overlaps heavily, and min-edge-cut
//! partitioning concentrates the remote accesses on a small set of
//! high-degree boundary vertices. [`FeatureCache`] keeps those rows in
//! trainer memory so [`KvClient::pull`](super::KvClient::pull) serves them
//! without touching the wire:
//!
//! - **Scope** — one cache per trainer per tensor group (normally the
//!   `"feat"` feature tables). Rows are keyed by **(ntype, row id)**: a
//!   heterogeneous graph's per-ntype tables share one budget, and the
//!   homogeneous case is the trivial single-ntype key (byte-identical to
//!   an untyped cache). Local rows are never cached (shared memory is
//!   already free); only rows whose owner is a different machine enter
//!   the cache.
//! - **Admission** — [`CacheAdmission::All`] admits every fetched remote
//!   row; [`CacheAdmission::Degree`] admits only vertices of degree ≥ a
//!   threshold, prioritizing the high-degree boundary vertices that
//!   dominate repeat traffic (MassiveGNN/DistGNN's observation).
//! - **Eviction** — CLOCK (second-chance): a hit sets the slot's
//!   reference bit; the rotating hand evicts the first unreferenced slot.
//!   Row storage is a single flat `Vec<f32>` (slot `i` at `i*dim`), so a
//!   full cache never reallocates.
//! - **Budget** — a byte budget caps `capacity = budget / (row bytes +
//!   bookkeeping)`. A budget of 0 disables the cache entirely (the pull
//!   path degenerates to the uncached behavior, byte for byte).
//! - **Coherence** — the cache is meant for immutable tensors (input
//!   features). `KvClient::push_grad` on the cached tensor invalidates
//!   the touched rows, so a pull after a sparse update through the *same*
//!   client is never stale. Cross-client writes are not tracked.
//!
//! Correctness bar (tested): cached and uncached pulls return
//! byte-identical rows, and all randomness is untouched — the cache never
//! consumes RNG state.
//!
//! **Thread-safety audit (worker pool).** The cache itself is plain
//! single-threaded state — no interior mutability, no lock on the hit
//! path. When a trainer runs N sampling workers, the forked
//! [`KvClient`](super::KvClient)s share one cache behind an
//! `Arc<Mutex<..>>` (one budget, one working set); the client locks it
//! once for a pull's whole lookup pass and once for the insert pass, so
//! invariants that span fields (map ↔ slots ↔ data ↔ stats) are only
//! ever observed consistent. Under sharing, *which* worker's pull is
//! counted as the miss for a cold row is schedule-dependent — two
//! workers can race the same cold row and both miss — but
//! `hit_rows + miss_rows` still equals the total remote lookups and
//! every miss is a fetched row (test:
//! `forked_clients_share_cache_and_stats_stay_consistent`), and served
//! bytes are identical in every interleaving because entries are
//! immutable copies of immutable tensor rows.

use std::sync::Arc;

use anyhow::{bail, Result};
use rustc_hash::FxHashMap;

use crate::graph::NodeId;

/// Which fetched remote rows are worth keeping.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CacheAdmission {
    /// Admit every remote row.
    All,
    /// Admit rows with vertex degree ≥ the threshold. `None` = auto:
    /// resolved to the dataset mean degree at deploy time
    /// ([`Cluster::deploy`](crate::cluster::Cluster) wires the degree
    /// table). Without a degree table the policy admits everything.
    Degree(Option<u32>),
}

impl CacheAdmission {
    /// Parse the `cache_admission` config value: `all`, `degree`, or
    /// `degree:<min>`.
    pub fn parse(v: &str) -> Result<Self> {
        Ok(match v {
            "all" => Self::All,
            "degree" => Self::Degree(None),
            _ => match v.strip_prefix("degree:") {
                Some(min) => Self::Degree(Some(min.parse()?)),
                None => {
                    bail!("cache_admission must be all|degree|degree:<min>")
                }
            },
        })
    }
}

/// Monotonic counters; deltas feed `cache.*` [`Metrics`] counters.
///
/// [`Metrics`]: crate::metrics::Metrics
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Remote rows served from trainer memory.
    pub hit_rows: u64,
    /// Remote rows that had to be fetched over the network.
    pub miss_rows: u64,
    /// Rows displaced by the CLOCK hand.
    pub evicted_rows: u64,
    /// Fetched rows the admission policy declined to keep.
    pub rejected_rows: u64,
    /// Response payload bytes that never crossed the wire (`hit_rows *
    /// dim * 4`).
    pub remote_bytes_saved: u64,
}

impl CacheStats {
    /// Hits / (hits + misses); 0 when the cache saw no remote rows.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hit_rows + self.miss_rows;
        if total == 0 {
            0.0
        } else {
            self.hit_rows as f64 / total as f64
        }
    }

    fn minus(&self, o: &CacheStats) -> CacheStats {
        CacheStats {
            hit_rows: self.hit_rows - o.hit_rows,
            miss_rows: self.miss_rows - o.miss_rows,
            evicted_rows: self.evicted_rows - o.evicted_rows,
            rejected_rows: self.rejected_rows - o.rejected_rows,
            remote_bytes_saved: self.remote_bytes_saved
                - o.remote_bytes_saved,
        }
    }
}

/// Per-slot bookkeeping bytes charged against the budget on top of the
/// row payload (map entry + slot record, amortized).
const ROW_OVERHEAD_BYTES: usize = 24;

/// Composite cache key: (ntype, row id). Homogeneous tensors use ntype 0.
#[inline]
fn key(ntype: u8, gid: NodeId) -> u64 {
    ((ntype as u64) << 32) | gid as u64
}

struct Slot {
    key: u64,
    /// CLOCK reference bit: set on hit, cleared by a passing hand.
    referenced: bool,
}

/// See the module docs. Single-threaded by design: each trainer's
/// [`KvClient`](super::KvClient) owns its own cache, so no locking sits on
/// the hit path.
pub struct FeatureCache {
    tensor: String,
    budget_bytes: usize,
    admission: CacheAdmission,
    degrees: Option<Arc<Vec<u32>>>,
    /// Per-ntype row widths; empty until the first pull binds them. A
    /// homogeneous tensor binds the single-entry `[dim]`.
    dims: Vec<usize>,
    /// Slot stride = max per-ntype dim (rows narrower than the stride
    /// only use their prefix). One arena keeps the flat-storage/CLOCK
    /// machinery identical to the untyped cache; the cost is that a
    /// narrow ntype's row occupies (and is charged) a full-width slot.
    /// Per-width arenas would pack more rows into the same budget on
    /// very skewed dim mixes — revisit if typed hit rates lag.
    slot_width: usize,
    /// Max rows under the byte budget (0 until `dims` is known).
    capacity: usize,
    map: FxHashMap<u64, u32>,
    slots: Vec<Slot>,
    /// Flat row storage: slot `i` occupies
    /// `data[i*slot_width..(i+1)*slot_width]`.
    data: Vec<f32>,
    /// Slots released by [`Self::invalidate`], reused before eviction.
    free: Vec<u32>,
    hand: usize,
    stats: CacheStats,
    reported: CacheStats,
}

impl FeatureCache {
    pub fn new(
        tensor: &str,
        budget_bytes: usize,
        admission: CacheAdmission,
        degrees: Option<Arc<Vec<u32>>>,
    ) -> Self {
        Self {
            tensor: tensor.to_string(),
            budget_bytes,
            admission,
            degrees,
            dims: Vec::new(),
            slot_width: 0,
            capacity: 0,
            map: FxHashMap::default(),
            slots: Vec::new(),
            data: Vec::new(),
            free: Vec::new(),
            hand: 0,
            stats: CacheStats::default(),
            reported: CacheStats::default(),
        }
    }

    /// Name of the cached tensor (only pulls of this tensor consult the
    /// cache).
    pub fn tensor(&self) -> &str {
        &self.tensor
    }

    /// Does `name` belong to this cache's tensor group? True for the
    /// base name itself and for any per-ntype table `base.<ntype>` —
    /// writes to either must invalidate.
    pub fn covers(&self, name: &str) -> bool {
        name == self.tensor
            || (name.len() > self.tensor.len() + 1
                && name.starts_with(&self.tensor)
                && name.as_bytes()[self.tensor.len()] == b'.')
    }

    /// False iff the byte budget is 0 (fully disabled, zero overhead).
    pub fn is_enabled(&self) -> bool {
        self.budget_bytes > 0
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Rows currently resident.
    pub fn rows(&self) -> usize {
        self.map.len()
    }

    /// Bytes charged against the budget (payload + bookkeeping).
    pub fn used_bytes(&self) -> usize {
        self.map.len() * (self.slot_width * 4 + ROW_OVERHEAD_BYTES)
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Counters accumulated since the previous `take_delta` call (for
    /// periodic publication into [`Metrics`](crate::metrics::Metrics)).
    pub fn take_delta(&mut self) -> CacheStats {
        let d = self.stats.minus(&self.reported);
        self.reported = self.stats;
        d
    }

    /// Bind the per-ntype row widths on first use and derive the row
    /// capacity from the byte budget (slots are `max(dims)` wide so any
    /// ntype's row fits any slot).
    pub fn ensure_dims(&mut self, dims: &[usize]) {
        if self.dims == dims {
            return;
        }
        assert!(
            self.dims.is_empty() && self.map.is_empty(),
            "FeatureCache for {:?} re-bound from dims {:?} to {:?}",
            self.tensor,
            self.dims,
            dims
        );
        assert!(!dims.is_empty());
        self.dims = dims.to_vec();
        self.slot_width = dims.iter().copied().max().unwrap_or(0).max(1);
        self.capacity =
            self.budget_bytes / (self.slot_width * 4 + ROW_OVERHEAD_BYTES);
    }

    /// Single-table convenience form of [`Self::ensure_dims`].
    pub fn ensure_dim(&mut self, dim: usize) {
        self.ensure_dims(&[dim]);
    }

    /// Copy the cached row for `(ntype, gid)` into `out` (len =
    /// `dims[ntype]`) and mark it recently used. Counts a hit or a miss.
    pub fn lookup(&mut self, ntype: u8, gid: NodeId, out: &mut [f32]) -> bool {
        match self.map.get(&key(ntype, gid)) {
            Some(&s) => {
                let d = self.dims[ntype as usize];
                let w = self.slot_width;
                let s = s as usize;
                out[..d].copy_from_slice(&self.data[s * w..s * w + d]);
                self.slots[s].referenced = true;
                self.stats.hit_rows += 1;
                self.stats.remote_bytes_saved += (d * 4) as u64;
                true
            }
            None => {
                self.stats.miss_rows += 1;
                false
            }
        }
    }

    /// Offer a freshly fetched remote row of `(ntype, gid)`. Subject to
    /// admission; evicts via CLOCK when the budget is exhausted.
    pub fn insert(&mut self, ntype: u8, gid: NodeId, row: &[f32]) {
        let k = key(ntype, gid);
        if self.capacity == 0 || self.map.contains_key(&k) {
            return;
        }
        if !self.admit(gid) {
            self.stats.rejected_rows += 1;
            return;
        }
        let d = self.dims[ntype as usize];
        let w = self.slot_width;
        let slot = if let Some(s) = self.free.pop() {
            s
        } else if self.slots.len() < self.capacity {
            self.slots.push(Slot { key: k, referenced: false });
            self.data.resize(self.slots.len() * w, 0.0);
            (self.slots.len() - 1) as u32
        } else {
            self.evict()
        };
        let i = slot as usize;
        self.slots[i] = Slot { key: k, referenced: false };
        self.data[i * w..i * w + d].copy_from_slice(&row[..d]);
        self.map.insert(k, slot);
    }

    /// Drop rows (sparse-update coherence: stale copies must not survive
    /// a `push_grad` on the cached tensor group). The writer does not
    /// know which ntype a row was cached under, so every bound ntype's
    /// key is dropped.
    pub fn invalidate(&mut self, ids: &[NodeId]) {
        let n_ntypes = self.dims.len().max(1) as u8;
        for &gid in ids {
            for t in 0..n_ntypes {
                if let Some(s) = self.map.remove(&key(t, gid)) {
                    self.slots[s as usize].referenced = false;
                    self.free.push(s);
                }
            }
        }
    }

    fn admit(&self, gid: NodeId) -> bool {
        match self.admission {
            CacheAdmission::All => true,
            CacheAdmission::Degree(min) => match &self.degrees {
                Some(deg) => {
                    deg.get(gid as usize).copied().unwrap_or(0)
                        >= min.unwrap_or(0)
                }
                None => true,
            },
        }
    }

    /// CLOCK hand: clear reference bits until an unreferenced victim is
    /// found. Only called with a full cache and an empty free list, so
    /// every slot is live and the sweep terminates within two laps.
    fn evict(&mut self) -> u32 {
        loop {
            let i = self.hand;
            self.hand = (self.hand + 1) % self.slots.len();
            let s = &mut self.slots[i];
            if s.referenced {
                s.referenced = false;
            } else {
                self.map.remove(&s.key);
                self.stats.evicted_rows += 1;
                return i as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(gid: NodeId, dim: usize) -> Vec<f32> {
        (0..dim).map(|d| (gid as usize * dim + d) as f32).collect()
    }

    fn cache_for_rows(n_rows: usize, dim: usize) -> FeatureCache {
        let budget = n_rows * (dim * 4 + ROW_OVERHEAD_BYTES);
        let mut c =
            FeatureCache::new("feat", budget, CacheAdmission::All, None);
        c.ensure_dim(dim);
        c
    }

    #[test]
    fn eviction_honors_byte_budget() {
        let dim = 4;
        let mut c = cache_for_rows(8, dim);
        let budget = c.budget_bytes();
        for gid in 0..100u32 {
            c.insert(0, gid, &row(gid, dim));
            assert!(c.used_bytes() <= budget, "over budget at gid {gid}");
        }
        assert_eq!(c.rows(), 8);
        assert_eq!(c.stats().evicted_rows, 92);
    }

    #[test]
    fn hits_return_inserted_bytes() {
        let dim = 6;
        let mut c = cache_for_rows(16, dim);
        for gid in [3u32, 9, 11] {
            c.insert(0, gid, &row(gid, dim));
        }
        let mut out = vec![0f32; dim];
        for gid in [9u32, 3, 11] {
            assert!(c.lookup(0, gid, &mut out));
            assert_eq!(out, row(gid, dim), "row {gid}");
        }
        assert!(!c.lookup(0, 999, &mut out));
        let s = c.stats();
        assert_eq!((s.hit_rows, s.miss_rows), (3, 1));
        assert_eq!(s.remote_bytes_saved, 3 * dim as u64 * 4);
    }

    #[test]
    fn clock_keeps_recently_referenced_rows() {
        let dim = 2;
        let mut c = cache_for_rows(2, dim);
        c.insert(0, 1, &row(1, dim));
        c.insert(0, 2, &row(2, dim));
        let mut out = vec![0f32; dim];
        assert!(c.lookup(0, 1, &mut out)); // reference row 1
        c.insert(0, 3, &row(3, dim)); // must evict the unreferenced row 2
        assert!(c.lookup(0, 1, &mut out), "referenced row was evicted");
        assert!(!c.lookup(0, 2, &mut out), "unreferenced row survived");
        assert!(c.lookup(0, 3, &mut out));
    }

    #[test]
    fn zero_budget_disables_everything() {
        let mut c =
            FeatureCache::new("feat", 0, CacheAdmission::All, None);
        c.ensure_dim(4);
        assert!(!c.is_enabled());
        c.insert(0, 1, &row(1, 4));
        assert_eq!(c.rows(), 0);
        assert_eq!(c.stats(), CacheStats::default());
    }

    #[test]
    fn degree_admission_filters_low_degree_rows() {
        let dim = 2;
        let degrees = Arc::new(vec![1u32, 10, 2, 50]);
        let budget = 8 * (dim * 4 + ROW_OVERHEAD_BYTES);
        let mut c = FeatureCache::new(
            "feat",
            budget,
            CacheAdmission::Degree(Some(5)),
            Some(degrees),
        );
        c.ensure_dim(dim);
        for gid in 0..4u32 {
            c.insert(0, gid, &row(gid, dim));
        }
        let mut out = vec![0f32; dim];
        assert!(!c.lookup(0, 0, &mut out)); // degree 1 < 5
        assert!(c.lookup(0, 1, &mut out)); // degree 10
        assert!(!c.lookup(0, 2, &mut out)); // degree 2
        assert!(c.lookup(0, 3, &mut out)); // degree 50
        assert_eq!(c.stats().rejected_rows, 2);
    }

    #[test]
    fn invalidate_releases_and_reuses_slots() {
        let dim = 3;
        let mut c = cache_for_rows(4, dim);
        for gid in 0..4u32 {
            c.insert(0, gid, &row(gid, dim));
        }
        c.invalidate(&[1, 2]);
        assert_eq!(c.rows(), 2);
        let mut out = vec![0f32; dim];
        assert!(!c.lookup(0, 1, &mut out));
        // freed slots are reused without evicting live rows
        c.insert(0, 10, &row(10, dim));
        c.insert(0, 11, &row(11, dim));
        assert_eq!(c.rows(), 4);
        assert_eq!(c.stats().evicted_rows, 0);
        assert!(c.lookup(0, 0, &mut out) && c.lookup(0, 3, &mut out));
    }

    #[test]
    fn take_delta_reports_increments_once() {
        let dim = 2;
        let mut c = cache_for_rows(4, dim);
        c.insert(0, 1, &row(1, dim));
        let mut out = vec![0f32; dim];
        c.lookup(0, 1, &mut out);
        let d1 = c.take_delta();
        assert_eq!(d1.hit_rows, 1);
        let d2 = c.take_delta();
        assert_eq!(d2, CacheStats::default());
        c.lookup(0, 1, &mut out);
        assert_eq!(c.take_delta().hit_rows, 1);
    }

    #[test]
    fn typed_keys_are_disjoint_and_use_their_own_dims() {
        // two ntypes sharing one budget: same row id under different
        // ntypes are distinct entries with their own row widths
        let dims = [4usize, 2];
        let budget = 8 * (4 * 4 + ROW_OVERHEAD_BYTES);
        let mut c =
            FeatureCache::new("feat", budget, CacheAdmission::All, None);
        c.ensure_dims(&dims);
        let wide = [1.0f32, 2.0, 3.0, 4.0];
        let narrow = [9.0f32, 8.0];
        c.insert(0, 5, &wide);
        c.insert(1, 5, &narrow);
        assert_eq!(c.rows(), 2);
        let mut out4 = [0f32; 4];
        let mut out2 = [0f32; 2];
        assert!(c.lookup(0, 5, &mut out4));
        assert_eq!(out4, wide);
        assert!(c.lookup(1, 5, &mut out2));
        assert_eq!(out2, narrow);
        // misses on the other ntype's ids
        assert!(!c.lookup(0, 6, &mut out4));
        assert!(!c.lookup(1, 6, &mut out2));
        // bytes saved respect per-ntype dims: 4*4 + 2*4
        assert_eq!(c.stats().remote_bytes_saved, (4 * 4 + 2 * 4) as u64);
    }

    #[test]
    fn admission_config_parses() {
        assert_eq!(CacheAdmission::parse("all").unwrap(), CacheAdmission::All);
        assert_eq!(
            CacheAdmission::parse("degree").unwrap(),
            CacheAdmission::Degree(None)
        );
        assert_eq!(
            CacheAdmission::parse("degree:12").unwrap(),
            CacheAdmission::Degree(Some(12))
        );
        assert!(CacheAdmission::parse("lru").is_err());
    }
}
