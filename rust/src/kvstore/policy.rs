//! Partition policies: map a global row id to its owning machine (§5.4
//! "flexible partition policies"). Vertex data of different types may use
//! different policies; the KVStore stores one policy per tensor name.

use crate::graph::NodeId;
use crate::partition::NodeMap;

pub trait PartitionPolicy: Send + Sync {
    fn owner(&self, key: NodeId) -> u32;
    /// Local row index on the owning machine.
    fn local_of(&self, key: NodeId) -> u32;
    fn n_parts(&self) -> usize;
    /// Number of rows owned by `part`.
    fn n_local(&self, part: u32) -> usize;
}

/// Contiguous-range ownership (the relabeled METIS partitions, §5.3).
pub struct RangePolicy {
    pub node_map: NodeMap,
}

impl RangePolicy {
    pub fn new(node_map: NodeMap) -> Self {
        Self { node_map }
    }
}

impl PartitionPolicy for RangePolicy {
    #[inline]
    fn owner(&self, key: NodeId) -> u32 {
        self.node_map.owner(key)
    }

    #[inline]
    fn local_of(&self, key: NodeId) -> u32 {
        self.node_map.local_of(key)
    }

    fn n_parts(&self) -> usize {
        self.node_map.nparts()
    }

    fn n_local(&self, part: u32) -> usize {
        self.node_map.n_core(part)
    }
}

/// Modulo-hash ownership (Euler-style random placement baseline).
pub struct HashPolicy {
    pub nparts: usize,
    pub n_rows: usize,
}

impl PartitionPolicy for HashPolicy {
    #[inline]
    fn owner(&self, key: NodeId) -> u32 {
        (key as usize % self.nparts) as u32
    }

    #[inline]
    fn local_of(&self, key: NodeId) -> u32 {
        (key as usize / self.nparts) as u32
    }

    fn n_parts(&self) -> usize {
        self.nparts
    }

    fn n_local(&self, part: u32) -> usize {
        let n = self.n_rows;
        let p = part as usize;
        n / self.nparts + usize::from(p < n % self.nparts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_policy_from_node_map() {
        let nm = NodeMap { part_starts: vec![0, 10, 25, 30] };
        let p = RangePolicy::new(nm);
        assert_eq!(p.n_parts(), 3);
        assert_eq!(p.owner(0), 0);
        assert_eq!(p.owner(9), 0);
        assert_eq!(p.owner(10), 1);
        assert_eq!(p.owner(29), 2);
        assert_eq!(p.local_of(12), 2);
        assert_eq!(p.n_local(1), 15);
    }

    #[test]
    fn hash_policy_covers_all_rows() {
        let p = HashPolicy { nparts: 3, n_rows: 10 };
        let mut per_part = vec![0usize; 3];
        for k in 0..10u32 {
            let o = p.owner(k) as usize;
            let l = p.local_of(k) as usize;
            assert!(l < p.n_local(o as u32), "k={k}");
            per_part[o] += 1;
        }
        assert_eq!(per_part, vec![4, 3, 3]);
        assert_eq!(
            per_part.iter().sum::<usize>(),
            (0..3).map(|i| p.n_local(i)).sum::<usize>()
        );
    }
}
