//! Distributed in-memory key-value store for vertex/edge data (§5.4).
//!
//! Features and learnable sparse embeddings are partitioned row-wise
//! across machines following the graph partitioning ([`RangePolicy`] over
//! the relabeled contiguous core ranges). Each machine hosts a
//! [`KvServer`]; trainers access it through a [`KvClient`] that
//!
//! - serves **local** rows through shared memory (a direct slice copy —
//!   the paper's "shared memory to minimize data copy" path), and
//! - groups **remote** rows per owner, fetching them in one batched
//!   request per machine while metering every byte on the cluster
//!   [`CostModel`](crate::net::CostModel) (and optionally emulating link
//!   time for wall-clock fidelity).
//!
//! `push_grad` implements the sparse-embedding update path: gradient rows
//! are routed to owners and applied as row-sparse SGD on the server.
//!
//! A trainer-side [`FeatureCache`] (see [`cache`]) sits in front of the
//! remote pull path: repeated boundary-vertex rows are served from trainer
//! memory with CLOCK eviction under a configurable byte budget, cutting
//! the dominant network cost of mini-batch generation.
//!
//! Heterogeneous graphs store **one feature table per node type**
//! ([`TypedFeatures`], docs/DESIGN.md §4) with independent row widths;
//! `KvClient::pull_typed` routes each row to its ntype's table and the
//! cache keys by `(ntype, row)`. Homogeneous graphs are the trivial
//! single-table view of the same machinery.

pub mod cache;
pub mod embedding;
pub mod policy;
pub mod store;

pub use cache::{CacheAdmission, CacheStats, FeatureCache, SharedFeatureCache};
pub use embedding::EmbeddingTable;
pub use policy::{HashPolicy, PartitionPolicy, RangePolicy};
pub use store::{KvClient, KvCluster, KvServer, TypedFeatures};
