//! Comparator systems from the paper's evaluation (§6):
//!
//! - **DistDGL (v1)** and **Euler** are *configurations* of this codebase
//!   (the paper's own framing: same training algorithm, different
//!   partitioning/parallelization/pipelining) — see
//!   `config::RunConfig::preset_distdgl_v1` / `preset_euler`.
//! - **ClusterGCN** ([`clustergcn`]) is a genuinely different training
//!   *algorithm* (partition-as-minibatch, cross-partition edges dropped)
//!   and is implemented here for the Fig 13 convergence comparison.
//! - **Full-graph training** ([`fullgraph`]) for the Fig 2 motivation
//!   experiment.

pub mod clustergcn;
pub mod fullgraph;

pub use clustergcn::ClusterGcnGen;
pub use fullgraph::FullGraphGen;
