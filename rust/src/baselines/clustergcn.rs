//! ClusterGCN baseline (Chiang et al., KDD'19) for the Fig 13 convergence
//! comparison.
//!
//! ClusterGCN partitions the graph into many small clusters (paper: 16,384
//! partitions of ogbn-papers100M) and trains on the *induced subgraph* of
//! a few randomly-chosen clusters per step: edges leaving the chosen
//! clusters are **dropped**, so neighbor aggregation is biased by the
//! partitioning — exactly the property DistDGLv2 avoids by always sampling
//! neighbors from the full graph (§6.3). We reuse the same padded block
//! layout so both trainers run the identical HLO.

use std::sync::Arc;

use rustc_hash::FxHashSet;

use crate::graph::{Dataset, NodeId, SplitTag};
use crate::partition::{
    metis_partition, PartitionConfig, VertexWeights,
};
use crate::runtime::executable::HostBatch;
use crate::sampler::compact::{to_block, ShapeSpec};
use crate::sampler::service::SampledNbrs;
use crate::util::Rng;

pub struct ClusterGcnGen {
    dataset: Arc<Dataset>,
    spec: ShapeSpec,
    /// cluster id per node.
    cluster_of: Vec<u32>,
    /// train nodes per cluster.
    cluster_train: Vec<Vec<NodeId>>,
    /// clusters drawn per mini-batch.
    clusters_per_batch: usize,
    rng: Rng,
}

impl ClusterGcnGen {
    pub fn new(
        dataset: Arc<Dataset>,
        spec: ShapeSpec,
        n_clusters: usize,
        clusters_per_batch: usize,
        seed: u64,
    ) -> Self {
        let vw = VertexWeights::uniform(dataset.n_nodes());
        let mut cfg = PartitionConfig::new(n_clusters);
        cfg.seed = seed;
        cfg.coarsen_to = (n_clusters * 8).max(256);
        let p = metis_partition(&dataset.graph, &vw, &cfg);
        let mut cluster_train: Vec<Vec<NodeId>> =
            vec![Vec::new(); n_clusters];
        for v in 0..dataset.n_nodes() {
            if dataset.split[v] == SplitTag::Train {
                cluster_train[p.assign[v] as usize].push(v as NodeId);
            }
        }
        Self {
            dataset,
            spec,
            cluster_of: p.assign,
            cluster_train,
            clusters_per_batch,
            rng: Rng::new(seed ^ 0xC6C),
        }
    }

    pub fn batches_per_epoch(&self) -> usize {
        (self.cluster_train.len() / self.clusters_per_batch).max(1)
    }

    /// One ClusterGCN step: union of q random clusters, in-cluster
    /// neighbors only.
    pub fn next(&mut self) -> HostBatch {
        let q = self.clusters_per_batch;
        let n_clusters = self.cluster_train.len();
        let mut chosen = FxHashSet::default();
        while chosen.len() < q.min(n_clusters) {
            chosen.insert(self.rng.below(n_clusters as u64) as u32);
        }
        // targets: train nodes of the chosen clusters, capped at batch
        let mut targets: Vec<NodeId> = Vec::new();
        for &c in &chosen {
            targets.extend(&self.cluster_train[c as usize]);
        }
        self.rng.shuffle(&mut targets);
        targets.truncate(self.spec.batch);
        if targets.is_empty() {
            // degenerate draw: fall back to any train node
            targets.push(
                self.cluster_train
                    .iter()
                    .flatten()
                    .next()
                    .copied()
                    .unwrap_or(0),
            );
        }

        // layer expansion with DROPPED cross-cluster edges
        let g = &self.dataset.graph;
        let l_total = self.spec.num_layers();
        let mut samples: Vec<(Vec<NodeId>, Vec<SampledNbrs>)> =
            Vec::with_capacity(l_total);
        let mut seeds = targets.clone();
        for l in (1..=l_total).rev() {
            let k = self.spec.fanouts[l - 1];
            let cap = self.spec.layer_nodes[l - 1];
            let mut layer: Vec<SampledNbrs> =
                Vec::with_capacity(seeds.len());
            let mut next: Vec<NodeId> = seeds.clone();
            let mut seen: FxHashSet<NodeId> =
                seeds.iter().copied().collect();
            for &s in &seeds {
                let nbrs: Vec<NodeId> = g
                    .neighbors(s)
                    .iter()
                    .copied()
                    .filter(|&v| {
                        chosen.contains(&self.cluster_of[v as usize])
                    })
                    .take(k)
                    .collect();
                // (no sampling beyond the in-cluster truncation); frontier
                // growth capped in to_block's drop order
                for &v in &nbrs {
                    if !seen.contains(&v) && next.len() < cap {
                        seen.insert(v);
                        next.push(v);
                    }
                }
                layer.push(SampledNbrs { nbrs, rels: Vec::new() });
            }
            samples.push((seeds, layer));
            seeds = next;
        }
        let block = to_block(&self.spec, &samples);

        // features + labels straight from the dataset (single machine)
        let n0 = self.spec.layer_nodes[0];
        let f = self.spec.feat_dim;
        let mut feats = vec![0f32; n0 * f];
        for (i, &v) in block.input_nodes.iter().enumerate().take(n0) {
            feats[i * f..(i + 1) * f]
                .copy_from_slice(self.dataset.feature(v));
        }
        let n_l = *self.spec.layer_nodes.last().unwrap();
        let mut labels = vec![0i32; n_l];
        let mut mask = vec![0f32; n_l];
        for (i, &v) in block.targets.iter().enumerate() {
            labels[i] = self.dataset.labels[v as usize] as i32;
            mask[i] = 1.0;
        }
        HostBatch {
            feats,
            layers: block.layers,
            labels,
            label_mask: mask,
            pair_mask: Vec::new(),
            targets: block.targets,
            input_nodes: block.input_nodes,
            remote_rows: 0,
            dropped_neighbors: block.dropped_neighbors,
        }
    }

    /// How many of a node set's graph edges survive the cluster restriction
    /// (observability: ClusterGCN's dropped-edge fraction).
    pub fn edge_retention(&self) -> f64 {
        let g = &self.dataset.graph;
        let mut kept = 0usize;
        let mut total = 0usize;
        for u in 0..g.n_nodes() as NodeId {
            for &v in g.neighbors(u) {
                total += 1;
                if self.cluster_of[u as usize]
                    == self.cluster_of[v as usize]
                {
                    kept += 1;
                }
            }
        }
        kept as f64 / total.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::sampler::compact::{ModelKind, TaskKind};

    fn gen() -> ClusterGcnGen {
        let d = Arc::new(DatasetSpec::new("cg", 1500, 6000).generate());
        let spec = ShapeSpec {
            name: "cg".into(),
            model: ModelKind::Sage,
            task: TaskKind::NodeClassification,
            batch: 64,
            fanouts: vec![4, 4],
            layer_nodes: vec![1024, 256, 64],
            feat_dim: d.feat_dim,
            num_classes: d.num_classes,
            num_rels: 1,
        };
        ClusterGcnGen::new(d, spec, 24, 2, 3)
    }

    #[test]
    fn batches_only_contain_in_cluster_edges() {
        let mut g = gen();
        let b = g.next();
        // every masked neighbor maps to a node in the chosen clusters —
        // verified indirectly: all referenced input nodes' clusters form a
        // set of at most clusters_per_batch ids (targets' clusters)
        let mut clusters: FxHashSet<u32> = FxHashSet::default();
        // reconstruct input node list is embedded in feats only; check via
        // dropped edges metric instead:
        assert!(b.targets.len() <= 64);
        for &t in &b.targets {
            clusters.insert(g.cluster_of[t as usize]);
        }
        assert!(clusters.len() <= 2);
    }

    #[test]
    fn clustergcn_drops_edges() {
        let g = gen();
        let retention = g.edge_retention();
        assert!(
            retention < 0.95,
            "clustering kept {retention} of edges — nothing dropped?"
        );
        assert!(retention > 0.2, "degenerate clustering: {retention}");
    }

    #[test]
    fn shapes_match_spec() {
        let mut g = gen();
        let b = g.next();
        assert_eq!(b.feats.len(), 1024 * g.spec.feat_dim);
        assert_eq!(b.labels.len(), 64);
        assert_eq!(b.layers.len(), 2);
    }
}
