//! Full-graph training baseline for the Fig 2 motivation experiment
//! (§3.2): one gradient update per pass over the *entire* training set
//! with full (un-sampled) neighborhoods, vs mini-batch training's many
//! updates per epoch. On large graphs this converges an order of
//! magnitude slower — the paper's argument for distributed mini-batch
//! training.

use std::sync::Arc;

use rustc_hash::FxHashSet;

use crate::graph::{Dataset, NodeId, SplitTag};
use crate::runtime::executable::HostBatch;
use crate::sampler::compact::{to_block, ShapeSpec};
use crate::sampler::service::SampledNbrs;

pub struct FullGraphGen {
    dataset: Arc<Dataset>,
    spec: ShapeSpec,
    train: Vec<NodeId>,
    cursor: usize,
}

impl FullGraphGen {
    pub fn new(dataset: Arc<Dataset>, spec: ShapeSpec) -> Self {
        let train = dataset.nodes_with(SplitTag::Train);
        Self { dataset, spec, train, cursor: 0 }
    }

    /// Steps per full pass (the train set may exceed the padded batch; the
    /// whole pass constitutes one "full-graph update" measurement unit).
    pub fn steps_per_pass(&self) -> usize {
        self.train.len().div_ceil(self.spec.batch).max(1)
    }

    /// Next full-neighborhood batch (deterministic order, no sampling:
    /// every neighbor up to the layer fanout cap is included).
    pub fn next(&mut self) -> HostBatch {
        let b = self.spec.batch;
        if self.cursor >= self.train.len() {
            self.cursor = 0;
        }
        let end = (self.cursor + b).min(self.train.len());
        let targets: Vec<NodeId> = self.train[self.cursor..end].to_vec();
        self.cursor = end;

        let g = &self.dataset.graph;
        let l_total = self.spec.num_layers();
        let mut samples: Vec<(Vec<NodeId>, Vec<SampledNbrs>)> =
            Vec::with_capacity(l_total);
        let mut seeds = targets.clone();
        for l in (1..=l_total).rev() {
            let k = self.spec.fanouts[l - 1];
            let cap = self.spec.layer_nodes[l - 1];
            let mut layer = Vec::with_capacity(seeds.len());
            let mut next = seeds.clone();
            let mut seen: FxHashSet<NodeId> =
                seeds.iter().copied().collect();
            for &s in &seeds {
                // full neighborhood, truncated only by the block width K
                let nbrs: Vec<NodeId> =
                    g.neighbors(s).iter().copied().take(k).collect();
                for &v in &nbrs {
                    if !seen.contains(&v) && next.len() < cap {
                        seen.insert(v);
                        next.push(v);
                    }
                }
                layer.push(SampledNbrs { nbrs, rels: Vec::new() });
            }
            samples.push((seeds, layer));
            seeds = next;
        }
        let block = to_block(&self.spec, &samples);

        let n0 = self.spec.layer_nodes[0];
        let f = self.spec.feat_dim;
        let mut feats = vec![0f32; n0 * f];
        for (i, &v) in block.input_nodes.iter().enumerate().take(n0) {
            feats[i * f..(i + 1) * f]
                .copy_from_slice(self.dataset.feature(v));
        }
        let n_l = *self.spec.layer_nodes.last().unwrap();
        let mut labels = vec![0i32; n_l];
        let mut mask = vec![0f32; n_l];
        for (i, &v) in block.targets.iter().enumerate() {
            labels[i] = self.dataset.labels[v as usize] as i32;
            mask[i] = 1.0;
        }
        HostBatch {
            feats,
            layers: block.layers,
            labels,
            label_mask: mask,
            pair_mask: Vec::new(),
            targets: block.targets,
            input_nodes: block.input_nodes,
            remote_rows: 0,
            dropped_neighbors: block.dropped_neighbors,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::sampler::compact::{ModelKind, TaskKind};

    fn gen() -> FullGraphGen {
        let d = Arc::new(DatasetSpec::new("fg", 1200, 4800).generate());
        let spec = ShapeSpec {
            name: "fg".into(),
            model: ModelKind::Sage,
            task: TaskKind::NodeClassification,
            batch: 64,
            fanouts: vec![8, 8],
            layer_nodes: vec![2048, 640, 64],
            feat_dim: d.feat_dim,
            num_classes: d.num_classes,
            num_rels: 1,
        };
        FullGraphGen::new(d, spec)
    }

    #[test]
    fn covers_train_set_in_one_pass() {
        let mut g = gen();
        let steps = g.steps_per_pass();
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..steps {
            seen.extend(g.next().targets.iter().copied());
        }
        let expect: std::collections::BTreeSet<_> =
            g.train.iter().copied().collect();
        assert_eq!(seen, expect);
    }

    #[test]
    fn includes_full_neighborhoods() {
        let mut g = gen();
        let b = g.next();
        // first target's neighbor count (capped by K=8) must be fully used
        let t = b.targets[0];
        let deg = g.dataset.graph.degree(t).min(8);
        let k = 8;
        let used = (0..k)
            .filter(|&kk| b.layers[1].nbr_mask[kk] > 0.0)
            .count();
        assert_eq!(used, deg);
    }
}
