//! Failure injection + straggler emulation (docs/DESIGN.md §8).
//!
//! A [`FaultPlan`] is an immutable description of the faults a run must
//! survive: KV/sampler server outages (by request index), transport
//! message drops and delays, and bounded retry/backoff policy. The plan
//! is shared (`Arc`) by every client it is installed on and keeps its
//! own atomic call counters, so an outage window like "requests 10..13
//! to machine 1 fail" is *transient*: each retry advances the counter
//! and eventually escapes the window, while `count = u64::MAX` models a
//! machine that never comes back and exhausts the retry budget into
//! [`RpcError::ServerDown`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::metrics::Metrics;
use crate::net::RpcError;

/// One injected outage: `machine` fails every request whose per-plan
/// call counter lands in `[after, after + count)`.
#[derive(Clone, Copy, Debug)]
pub struct FailWindow {
    pub machine: u32,
    pub after: u64,
    pub count: u64,
}

impl FailWindow {
    /// A machine that goes down at request `after` and never recovers.
    pub fn permanent(machine: u32, after: u64) -> Self {
        Self { machine, after, count: u64::MAX }
    }

    /// A machine that fails `count` requests starting at `after`, then
    /// answers again (a restarted server / healed link).
    pub fn transient(machine: u32, after: u64, count: u64) -> Self {
        Self { machine, after, count }
    }

    fn covers(&self, machine: u32, call: u64) -> bool {
        self.machine == machine
            && call >= self.after
            && call - self.after < self.count
    }
}

/// Injected-fault schedule + retry policy, shared by every RPC client
/// it is installed on (`Cluster::set_fault_plan`).
#[derive(Debug)]
pub struct FaultPlan {
    /// Outage windows over the KVStore request counter.
    pub kv_outages: Vec<FailWindow>,
    /// Outage windows over the sampler request counter.
    pub sampler_outages: Vec<FailWindow>,
    /// Drop every Nth transport message (0 = never drop).
    pub drop_every: u64,
    /// Added latency per transport message (straggler link).
    pub delay: Duration,
    /// Per-machine *compute* slowdown: every train step taken by a
    /// trainer on `machine` sleeps this long (an oversubscribed or
    /// thermally-throttled host). Unlike `delay`/CostModel link
    /// slowdowns — which are symmetric across a link — this perturbs
    /// one machine's step timings only, which is exactly the signal
    /// the coordinator's straggler demotion keys off.
    pub step_slowdowns: Vec<(u32, Duration)>,
    /// Failed requests are retried this many times before the caller
    /// sees [`RpcError::ServerDown`].
    pub max_retries: u32,
    /// Sleep between retries.
    pub backoff: Duration,
    kv_calls: AtomicU64,
    sampler_calls: AtomicU64,
    msg_calls: AtomicU64,
    retries: AtomicU64,
    kv_failures: AtomicU64,
    sampler_failures: AtomicU64,
    dropped_msgs: AtomicU64,
    delayed_msgs: AtomicU64,
    straggler_steps: AtomicU64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::new()
    }
}

impl FaultPlan {
    /// A fault-free plan with the default retry policy (3 retries,
    /// 1 ms backoff): installing it changes nothing until outage
    /// windows / drop / delay knobs are set.
    pub fn new() -> Self {
        Self {
            kv_outages: Vec::new(),
            sampler_outages: Vec::new(),
            drop_every: 0,
            delay: Duration::ZERO,
            step_slowdowns: Vec::new(),
            max_retries: 3,
            backoff: Duration::from_millis(1),
            kv_calls: AtomicU64::new(0),
            sampler_calls: AtomicU64::new(0),
            msg_calls: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            kv_failures: AtomicU64::new(0),
            sampler_failures: AtomicU64::new(0),
            dropped_msgs: AtomicU64::new(0),
            delayed_msgs: AtomicU64::new(0),
            straggler_steps: AtomicU64::new(0),
        }
    }

    fn fails(
        windows: &[FailWindow],
        calls: &AtomicU64,
        failures: &AtomicU64,
        machine: u32,
    ) -> bool {
        let c = calls.fetch_add(1, Ordering::Relaxed);
        if windows.iter().any(|w| w.covers(machine, c)) {
            failures.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    fn admit(
        &self,
        windows: &[FailWindow],
        calls: &AtomicU64,
        failures: &AtomicU64,
        machine: u32,
        role: &'static str,
    ) -> Result<(), RpcError> {
        if !Self::fails(windows, calls, failures, machine) {
            return Ok(());
        }
        for _ in 0..self.max_retries {
            self.retries.fetch_add(1, Ordering::Relaxed);
            if !self.backoff.is_zero() {
                std::thread::sleep(self.backoff);
            }
            if !Self::fails(windows, calls, failures, machine) {
                return Ok(());
            }
        }
        Err(RpcError::ServerDown { machine, role })
    }

    /// Gate one KVStore request to `machine`: advances the KV call
    /// counter (retries included, so transient windows heal) and
    /// returns `ServerDown` once the retry budget is spent.
    pub fn admit_kv(&self, machine: u32) -> Result<(), RpcError> {
        self.admit(
            &self.kv_outages,
            &self.kv_calls,
            &self.kv_failures,
            machine,
            "kv",
        )
    }

    /// Gate one sampler request to `machine` (same contract as
    /// [`Self::admit_kv`] over the sampler call counter).
    pub fn admit_sampler(&self, machine: u32) -> Result<(), RpcError> {
        self.admit(
            &self.sampler_outages,
            &self.sampler_calls,
            &self.sampler_failures,
            machine,
            "sampler",
        )
    }

    /// Gate one transport message: returns `false` when the message
    /// must be dropped, sleeping the injected per-message delay first.
    pub fn admit_message(&self) -> bool {
        let c = self.msg_calls.fetch_add(1, Ordering::Relaxed) + 1;
        if !self.delay.is_zero() {
            self.delayed_msgs.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.delay);
        }
        if self.drop_every > 0 && c % self.drop_every == 0 {
            self.dropped_msgs.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        true
    }

    /// Injected compute slowdown for one train step on `machine`
    /// (`Duration::ZERO` when the machine is healthy). The trainer
    /// sleeps this inside the step so the coordinator's heartbeat
    /// timings see it.
    pub fn step_delay(&self, machine: u32) -> Duration {
        let d: Duration = self
            .step_slowdowns
            .iter()
            .filter(|(m, _)| *m == machine)
            .map(|&(_, d)| d)
            .sum();
        if !d.is_zero() {
            self.straggler_steps.fetch_add(1, Ordering::Relaxed);
        }
        d
    }

    pub fn straggler_steps(&self) -> u64 {
        self.straggler_steps.load(Ordering::Relaxed)
    }

    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    pub fn kv_failures(&self) -> u64 {
        self.kv_failures.load(Ordering::Relaxed)
    }

    pub fn sampler_failures(&self) -> u64 {
        self.sampler_failures.load(Ordering::Relaxed)
    }

    pub fn dropped_msgs(&self) -> u64 {
        self.dropped_msgs.load(Ordering::Relaxed)
    }

    pub fn delayed_msgs(&self) -> u64 {
        self.delayed_msgs.load(Ordering::Relaxed)
    }

    /// Export the injection counters as `ft.*` metrics.
    pub fn publish(&self, m: &Metrics) {
        m.inc("ft.retries", self.retries());
        m.inc(
            "ft.injected_failures",
            self.kv_failures() + self.sampler_failures(),
        );
        m.inc("ft.dropped_msgs", self.dropped_msgs());
        m.inc("ft.delayed_msgs", self.delayed_msgs());
        m.inc("ft.straggler_steps", self.straggler_steps());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast(mut p: FaultPlan) -> FaultPlan {
        p.backoff = Duration::ZERO;
        p
    }

    #[test]
    fn transient_window_heals_within_the_retry_budget() {
        let mut p = fast(FaultPlan::new());
        p.kv_outages = vec![FailWindow::transient(1, 0, 2)];
        // calls 0 and 1 fail; retries advance the counter past the
        // window, so the request ultimately succeeds
        assert_eq!(p.admit_kv(1), Ok(()));
        assert_eq!(p.retries(), 2);
        assert_eq!(p.kv_failures(), 2);
        // later calls are clean
        assert_eq!(p.admit_kv(1), Ok(()));
        assert_eq!(p.retries(), 2);
    }

    #[test]
    fn permanent_outage_exhausts_retries_into_server_down() {
        let mut p = fast(FaultPlan::new());
        p.kv_outages = vec![FailWindow::permanent(0, 0)];
        assert_eq!(
            p.admit_kv(0),
            Err(RpcError::ServerDown { machine: 0, role: "kv" })
        );
        assert_eq!(p.retries(), 3);
        // other machines are unaffected
        assert_eq!(p.admit_kv(1), Ok(()));
    }

    #[test]
    fn sampler_and_kv_counters_are_independent() {
        let mut p = fast(FaultPlan::new());
        p.sampler_outages = vec![FailWindow::permanent(2, 0)];
        assert_eq!(p.admit_kv(2), Ok(()));
        assert_eq!(
            p.admit_sampler(2),
            Err(RpcError::ServerDown { machine: 2, role: "sampler" })
        );
    }

    #[test]
    fn drop_every_counts_and_drops() {
        let mut p = fast(FaultPlan::new());
        p.drop_every = 3;
        let delivered =
            (0..9).filter(|_| p.admit_message()).count();
        assert_eq!(delivered, 6);
        assert_eq!(p.dropped_msgs(), 3);
    }

    #[test]
    fn step_slowdown_hits_only_its_machine() {
        let mut p = fast(FaultPlan::new());
        p.step_slowdowns =
            vec![(1, Duration::from_millis(3))];
        assert_eq!(p.step_delay(0), Duration::ZERO);
        assert_eq!(p.step_delay(1), Duration::from_millis(3));
        assert_eq!(p.step_delay(1), Duration::from_millis(3));
        assert_eq!(p.straggler_steps(), 2);
    }

    #[test]
    fn publish_exports_ft_counters() {
        let mut p = fast(FaultPlan::new());
        p.kv_outages = vec![FailWindow::transient(0, 0, 1)];
        p.admit_kv(0).unwrap();
        let m = Metrics::new();
        p.publish(&m);
        assert_eq!(m.counter("ft.retries"), 1);
        assert_eq!(m.counter("ft.injected_failures"), 1);
    }
}
