//! Failure injection + straggler emulation (docs/DESIGN.md §8).
//!
//! A [`FaultPlan`] is an immutable description of the faults a run must
//! survive: KV/sampler server outages (by request index), transport
//! message drops, delays, asymmetric partitions and connection kills,
//! and bounded retry/backoff policy. The plan is shared (`Arc`) by
//! every client it is installed on and keeps its own atomic call
//! counters, so an outage window like "requests 10..13 to machine 1
//! fail" is *transient*: each retry advances the counter and eventually
//! escapes the window, while `count = u64::MAX` models a machine that
//! never comes back and exhausts the retry budget into
//! [`RpcError::ServerDown`].
//!
//! The window check itself ([`FaultPlan::inject`]) is shared by both
//! wire backends: the in-process admission loop (`admit_kv`) and the
//! real-socket `RpcClient` gate every attempt through the same counters,
//! so one plan reproduces identical injected-failure totals whichever
//! transport carries the run (regression-tested in `net::rpc`). The
//! message-level verdicts ([`FaultPlan::message_verdict`]) likewise
//! drive both the in-process fabric and the TCP chaos hook in
//! `net::tcp`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::metrics::Metrics;
use crate::net::retry::{with_retry, RetryPolicy};
use crate::net::RpcError;

/// One injected outage: `machine` fails every request whose per-plan
/// call counter lands in `[after, after + count)`.
#[derive(Clone, Copy, Debug)]
pub struct FailWindow {
    pub machine: u32,
    pub after: u64,
    pub count: u64,
}

impl FailWindow {
    /// A machine that goes down at request `after` and never recovers.
    pub fn permanent(machine: u32, after: u64) -> Self {
        Self { machine, after, count: u64::MAX }
    }

    /// A machine that fails `count` requests starting at `after`, then
    /// answers again (a restarted server / healed link).
    pub fn transient(machine: u32, after: u64, count: u64) -> Self {
        Self { machine, after, count }
    }

    fn covers(&self, machine: u32, call: u64) -> bool {
        self.machine == machine
            && call >= self.after
            && call - self.after < self.count
    }
}

/// What the transport must do with one cross-machine message (the
/// chaos verdict both backends obey).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MessageVerdict {
    /// Deliver normally.
    Deliver,
    /// Lost on the wire: never delivered, never metered.
    Drop,
    /// Deliver, then kill the underlying connection to the destination
    /// (a reset the next send must transparently re-dial through). The
    /// in-process fabric has no connections, so it delivers and only
    /// counts the kill — the counter totals stay backend-identical.
    DeliverThenKillConn,
}

/// Injected-fault schedule + retry policy, shared by every RPC client
/// it is installed on (`Cluster::set_fault_plan`).
#[derive(Debug)]
pub struct FaultPlan {
    /// Outage windows over the KVStore request counter.
    pub kv_outages: Vec<FailWindow>,
    /// Outage windows over the sampler request counter.
    pub sampler_outages: Vec<FailWindow>,
    /// Drop every Nth transport message (0 = never drop).
    pub drop_every: u64,
    /// Added latency per transport message (straggler link).
    pub delay: Duration,
    /// Kill the sender's connection after every Nth cross-machine
    /// message (0 = never). Only a real wire has connections to kill;
    /// see [`MessageVerdict::DeliverThenKillConn`].
    pub kill_conn_every: u64,
    /// Asymmetric partitions: every message from machine `.0` to
    /// machine `.1` is dropped (the reverse direction still flows
    /// unless listed separately).
    pub partitions: Vec<(u32, u32)>,
    /// Per-machine *compute* slowdown: every train step taken by a
    /// trainer on `machine` sleeps this long (an oversubscribed or
    /// thermally-throttled host). Unlike `delay`/CostModel link
    /// slowdowns — which are symmetric across a link — this perturbs
    /// one machine's step timings only, which is exactly the signal
    /// the coordinator's straggler demotion keys off.
    pub step_slowdowns: Vec<(u32, Duration)>,
    /// Failed requests are retried this many times before the caller
    /// sees [`RpcError::ServerDown`].
    pub max_retries: u32,
    /// Sleep between retries.
    pub backoff: Duration,
    kv_calls: AtomicU64,
    sampler_calls: AtomicU64,
    msg_calls: AtomicU64,
    retries: AtomicU64,
    kv_failures: AtomicU64,
    sampler_failures: AtomicU64,
    dropped_msgs: AtomicU64,
    delayed_msgs: AtomicU64,
    killed_conns: AtomicU64,
    straggler_steps: AtomicU64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::new()
    }
}

impl FaultPlan {
    /// A fault-free plan with the default retry policy (3 retries,
    /// 1 ms backoff): installing it changes nothing until outage
    /// windows / drop / delay knobs are set.
    pub fn new() -> Self {
        Self {
            kv_outages: Vec::new(),
            sampler_outages: Vec::new(),
            drop_every: 0,
            delay: Duration::ZERO,
            kill_conn_every: 0,
            partitions: Vec::new(),
            step_slowdowns: Vec::new(),
            max_retries: RetryPolicy::in_process().max_retries,
            backoff: RetryPolicy::in_process().backoff,
            kv_calls: AtomicU64::new(0),
            sampler_calls: AtomicU64::new(0),
            msg_calls: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            kv_failures: AtomicU64::new(0),
            sampler_failures: AtomicU64::new(0),
            dropped_msgs: AtomicU64::new(0),
            delayed_msgs: AtomicU64::new(0),
            killed_conns: AtomicU64::new(0),
            straggler_steps: AtomicU64::new(0),
        }
    }

    /// The plan's retry/backoff knobs as the shared [`RetryPolicy`].
    pub fn retry_policy(&self) -> RetryPolicy {
        RetryPolicy::new(self.max_retries, self.backoff)
    }

    /// The shared retries counter every retry loop (in-process admission
    /// and the wire `RpcClient`) feeds, so `ft.retries` totals are
    /// backend-independent.
    pub(crate) fn retries_counter(&self) -> &AtomicU64 {
        &self.retries
    }

    fn fails(
        windows: &[FailWindow],
        calls: &AtomicU64,
        failures: &AtomicU64,
        machine: u32,
    ) -> bool {
        let c = calls.fetch_add(1, Ordering::Relaxed);
        if windows.iter().any(|w| w.covers(machine, c)) {
            failures.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// One-shot injected-failure check for a single request attempt to
    /// `machine` in `role` (`"kv"` or `"sampler"`): advances the same
    /// call counter the in-process admission loop uses and returns
    /// `ServerDown` when an outage window covers the attempt. Both wire
    /// backends gate every attempt through this, which is what makes
    /// injected-failure totals identical across backends.
    pub fn inject(
        &self,
        role: &'static str,
        machine: u32,
    ) -> Result<(), RpcError> {
        let (windows, calls, failures) = match role {
            "kv" => (&self.kv_outages, &self.kv_calls, &self.kv_failures),
            _ => (
                &self.sampler_outages,
                &self.sampler_calls,
                &self.sampler_failures,
            ),
        };
        if Self::fails(windows, calls, failures, machine) {
            Err(RpcError::ServerDown { machine, role })
        } else {
            Ok(())
        }
    }

    fn admit(
        &self,
        role: &'static str,
        machine: u32,
    ) -> Result<(), RpcError> {
        with_retry(&self.retry_policy(), &self.retries, |_| {
            self.inject(role, machine)
        })
    }

    /// Gate one KVStore request to `machine`: advances the KV call
    /// counter (retries included, so transient windows heal) and
    /// returns `ServerDown` once the retry budget is spent.
    pub fn admit_kv(&self, machine: u32) -> Result<(), RpcError> {
        self.admit("kv", machine)
    }

    /// Gate one sampler request to `machine` (same contract as
    /// [`Self::admit_kv`] over the sampler call counter).
    pub fn admit_sampler(&self, machine: u32) -> Result<(), RpcError> {
        self.admit("sampler", machine)
    }

    /// Chaos verdict for one cross-machine message from machine `from`
    /// to machine `to`: sleeps the injected per-message delay, then
    /// applies (in order) asymmetric partitions, periodic drops, and
    /// periodic connection kills. Both wire backends route every
    /// cross-machine send through this.
    pub fn message_verdict(&self, from: u32, to: u32) -> MessageVerdict {
        let c = self.msg_calls.fetch_add(1, Ordering::Relaxed) + 1;
        if !self.delay.is_zero() {
            self.delayed_msgs.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.delay);
        }
        if self.partitions.iter().any(|&(a, b)| a == from && b == to) {
            self.dropped_msgs.fetch_add(1, Ordering::Relaxed);
            return MessageVerdict::Drop;
        }
        if self.drop_every > 0 && c % self.drop_every == 0 {
            self.dropped_msgs.fetch_add(1, Ordering::Relaxed);
            return MessageVerdict::Drop;
        }
        if self.kill_conn_every > 0 && c % self.kill_conn_every == 0 {
            self.killed_conns.fetch_add(1, Ordering::Relaxed);
            return MessageVerdict::DeliverThenKillConn;
        }
        MessageVerdict::Deliver
    }

    /// Gate one transport message without machine context (partitions
    /// never match): returns `false` when the message must be dropped,
    /// sleeping the injected per-message delay first.
    pub fn admit_message(&self) -> bool {
        self.message_verdict(u32::MAX, u32::MAX) != MessageVerdict::Drop
    }

    /// Injected compute slowdown for one train step on `machine`
    /// (`Duration::ZERO` when the machine is healthy). The trainer
    /// sleeps this inside the step so the coordinator's heartbeat
    /// timings see it.
    pub fn step_delay(&self, machine: u32) -> Duration {
        let d: Duration = self
            .step_slowdowns
            .iter()
            .filter(|(m, _)| *m == machine)
            .map(|&(_, d)| d)
            .sum();
        if !d.is_zero() {
            self.straggler_steps.fetch_add(1, Ordering::Relaxed);
        }
        d
    }

    pub fn straggler_steps(&self) -> u64 {
        self.straggler_steps.load(Ordering::Relaxed)
    }

    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    pub fn kv_failures(&self) -> u64 {
        self.kv_failures.load(Ordering::Relaxed)
    }

    pub fn sampler_failures(&self) -> u64 {
        self.sampler_failures.load(Ordering::Relaxed)
    }

    pub fn dropped_msgs(&self) -> u64 {
        self.dropped_msgs.load(Ordering::Relaxed)
    }

    pub fn delayed_msgs(&self) -> u64 {
        self.delayed_msgs.load(Ordering::Relaxed)
    }

    pub fn killed_conns(&self) -> u64 {
        self.killed_conns.load(Ordering::Relaxed)
    }

    /// Export the injection counters as `ft.*` metrics.
    pub fn publish(&self, m: &Metrics) {
        m.inc("ft.retries", self.retries());
        m.inc(
            "ft.injected_failures",
            self.kv_failures() + self.sampler_failures(),
        );
        m.inc("ft.dropped_msgs", self.dropped_msgs());
        m.inc("ft.delayed_msgs", self.delayed_msgs());
        m.inc("ft.killed_conns", self.killed_conns());
        m.inc("ft.straggler_steps", self.straggler_steps());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast(mut p: FaultPlan) -> FaultPlan {
        p.backoff = Duration::ZERO;
        p
    }

    #[test]
    fn transient_window_heals_within_the_retry_budget() {
        let mut p = fast(FaultPlan::new());
        p.kv_outages = vec![FailWindow::transient(1, 0, 2)];
        // calls 0 and 1 fail; retries advance the counter past the
        // window, so the request ultimately succeeds
        assert_eq!(p.admit_kv(1), Ok(()));
        assert_eq!(p.retries(), 2);
        assert_eq!(p.kv_failures(), 2);
        // later calls are clean
        assert_eq!(p.admit_kv(1), Ok(()));
        assert_eq!(p.retries(), 2);
    }

    #[test]
    fn permanent_outage_exhausts_retries_into_server_down() {
        let mut p = fast(FaultPlan::new());
        p.kv_outages = vec![FailWindow::permanent(0, 0)];
        assert_eq!(
            p.admit_kv(0),
            Err(RpcError::ServerDown { machine: 0, role: "kv" })
        );
        assert_eq!(p.retries(), 3);
        // other machines are unaffected
        assert_eq!(p.admit_kv(1), Ok(()));
    }

    #[test]
    fn sampler_and_kv_counters_are_independent() {
        let mut p = fast(FaultPlan::new());
        p.sampler_outages = vec![FailWindow::permanent(2, 0)];
        assert_eq!(p.admit_kv(2), Ok(()));
        assert_eq!(
            p.admit_sampler(2),
            Err(RpcError::ServerDown { machine: 2, role: "sampler" })
        );
    }

    #[test]
    fn drop_every_counts_and_drops() {
        let mut p = fast(FaultPlan::new());
        p.drop_every = 3;
        let delivered =
            (0..9).filter(|_| p.admit_message()).count();
        assert_eq!(delivered, 6);
        assert_eq!(p.dropped_msgs(), 3);
    }

    #[test]
    fn asymmetric_partition_drops_one_direction_only() {
        let mut p = fast(FaultPlan::new());
        p.partitions = vec![(0, 1)];
        for _ in 0..4 {
            assert_eq!(p.message_verdict(0, 1), MessageVerdict::Drop);
        }
        assert_eq!(p.message_verdict(1, 0), MessageVerdict::Deliver);
        assert_eq!(p.message_verdict(0, 2), MessageVerdict::Deliver);
        assert_eq!(p.dropped_msgs(), 4);
    }

    #[test]
    fn kill_conn_every_delivers_then_kills() {
        let mut p = fast(FaultPlan::new());
        p.kill_conn_every = 3;
        let verdicts: Vec<MessageVerdict> =
            (0..6).map(|_| p.message_verdict(0, 1)).collect();
        assert_eq!(
            verdicts,
            vec![
                MessageVerdict::Deliver,
                MessageVerdict::Deliver,
                MessageVerdict::DeliverThenKillConn,
                MessageVerdict::Deliver,
                MessageVerdict::Deliver,
                MessageVerdict::DeliverThenKillConn,
            ]
        );
        assert_eq!(p.killed_conns(), 2);
        assert_eq!(p.dropped_msgs(), 0, "killed messages still deliver");
    }

    #[test]
    fn inject_is_the_shared_one_shot_window_check() {
        let mut p = fast(FaultPlan::new());
        p.kv_outages = vec![FailWindow::transient(1, 0, 2)];
        // no internal retry loop: each call is exactly one attempt on
        // the same counter admit_kv advances
        assert!(p.inject("kv", 1).is_err());
        assert!(p.inject("kv", 1).is_err());
        assert_eq!(p.inject("kv", 1), Ok(()));
        assert_eq!(p.kv_failures(), 2);
        assert_eq!(p.retries(), 0, "inject never retries by itself");
    }

    #[test]
    fn step_slowdown_hits_only_its_machine() {
        let mut p = fast(FaultPlan::new());
        p.step_slowdowns =
            vec![(1, Duration::from_millis(3))];
        assert_eq!(p.step_delay(0), Duration::ZERO);
        assert_eq!(p.step_delay(1), Duration::from_millis(3));
        assert_eq!(p.step_delay(1), Duration::from_millis(3));
        assert_eq!(p.straggler_steps(), 2);
    }

    #[test]
    fn publish_exports_ft_counters() {
        let mut p = fast(FaultPlan::new());
        p.kv_outages = vec![FailWindow::transient(0, 0, 1)];
        p.admit_kv(0).unwrap();
        let m = Metrics::new();
        p.publish(&m);
        assert_eq!(m.counter("ft.retries"), 1);
        assert_eq!(m.counter("ft.injected_failures"), 1);
    }
}
