//! Checkpoint / exact-resume state (docs/DESIGN.md §8).
//!
//! A [`Checkpoint`] is everything needed to continue training with a
//! byte-identical stream: the run seed, the global step, the dense
//! model parameters (synchronized across ranks by the preceding
//! all-reduce, so one copy suffices), and every KVStore shard — feature
//! tables, labels, and learnable embeddings whose optimizer state
//! *lives* in the KVStore (`kvstore/embedding.rs`). Batch composition
//! has been a pure function of `(seed, global_step)` since PR 5, so no
//! sampler or scheduler state needs saving: restoring `(seed, step)`
//! and restarting the loaders at `step` replays the exact stream.
//!
//! The on-disk format follows `graph/bundle.rs`: magic + version, then
//! little-endian length-prefixed sections; foreign files and stale
//! versions are rejected with descriptive errors.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::kvstore::KvServer;

const MAGIC: u32 = 0xC8EC_4D17;
const VERSION: u32 = 0xFA00_0001;

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    write_u64(w, s.len() as u64)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let len = read_u64(r)? as usize;
    let mut b = vec![0u8; len];
    r.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let n = read_u64(r)? as usize;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

/// A full training snapshot: `(seed, step)` + model params + every
/// KVStore shard, name-sorted per server for a deterministic encoding.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub seed: u64,
    /// Global step the snapshot was taken *after*: resuming replays
    /// batches `step..`.
    pub step: u64,
    pub params: Vec<Vec<f32>>,
    /// Per KV server (machine order): `(tensor, dim, rows)`.
    pub shards: Vec<Vec<(String, usize, Vec<f32>)>>,
}

impl Checkpoint {
    /// The canonical file name the trainer writes at `step`.
    pub fn path_for(dir: &Path, step: u64) -> PathBuf {
        dir.join(format!("ckpt_{step:08}.ckpt"))
    }

    /// Snapshot the cluster: params + every server's shards.
    pub fn capture(
        seed: u64,
        step: u64,
        params: &[Vec<f32>],
        servers: &[Arc<KvServer>],
    ) -> Checkpoint {
        Checkpoint {
            seed,
            step,
            params: params.to_vec(),
            shards: servers.iter().map(|s| s.export_shards()).collect(),
        }
    }

    /// Write the restored shards back into a (re)deployed cluster's
    /// servers. The server count must match the snapshot's.
    pub fn restore(&self, servers: &[Arc<KvServer>]) -> Result<()> {
        ensure!(
            servers.len() == self.shards.len(),
            "checkpoint holds {} servers, cluster has {}",
            self.shards.len(),
            servers.len()
        );
        for (server, shards) in servers.iter().zip(&self.shards) {
            for (name, dim, data) in shards {
                server.import_shard(name, *dim, data.clone());
            }
        }
        Ok(())
    }

    /// Persist to `path`; returns the bytes written.
    pub fn save(&self, path: &Path) -> Result<u64> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(
            File::create(path)
                .with_context(|| format!("create {path:?}"))?,
        );
        write_u32(&mut w, MAGIC)?;
        write_u32(&mut w, VERSION)?;
        write_u64(&mut w, self.seed)?;
        write_u64(&mut w, self.step)?;
        write_u64(&mut w, self.params.len() as u64)?;
        for p in &self.params {
            write_f32s(&mut w, p)?;
        }
        write_u64(&mut w, self.shards.len() as u64)?;
        for server in &self.shards {
            write_u64(&mut w, server.len() as u64)?;
            for (name, dim, data) in server {
                write_str(&mut w, name)?;
                write_u64(&mut w, *dim as u64)?;
                write_f32s(&mut w, data)?;
            }
        }
        w.flush()?;
        Ok(std::fs::metadata(path)?.len())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let magic = read_u32(&mut r)?;
        if magic != MAGIC {
            bail!("bad checkpoint magic in {path:?}");
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!(
                "unsupported checkpoint version {version:#010x} in \
                 {path:?} ({VERSION:#010x} expected)"
            );
        }
        let seed = read_u64(&mut r)?;
        let step = read_u64(&mut r)?;
        let n_params = read_u64(&mut r)? as usize;
        let mut params = Vec::with_capacity(n_params);
        for _ in 0..n_params {
            params.push(read_f32s(&mut r)?);
        }
        let n_servers = read_u64(&mut r)? as usize;
        let mut shards = Vec::with_capacity(n_servers);
        for _ in 0..n_servers {
            let n_tensors = read_u64(&mut r)? as usize;
            let mut server = Vec::with_capacity(n_tensors);
            for _ in 0..n_tensors {
                let name = read_str(&mut r)?;
                let dim = read_u64(&mut r)? as usize;
                let data = read_f32s(&mut r)?;
                server.push((name, dim, data));
            }
            shards.push(server);
        }
        Ok(Checkpoint { seed, step, params, shards })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::NodeId;
    use crate::kvstore::{EmbeddingTable, KvCluster, RangePolicy};
    use crate::net::CostModel;
    use crate::partition::NodeMap;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("ddgl_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn checkpoint_roundtrips_byte_identically() {
        let ck = Checkpoint {
            seed: 7,
            step: 42,
            params: vec![vec![1.0, -2.5, 3.25], vec![0.0; 5]],
            shards: vec![
                vec![
                    ("emb".into(), 2, vec![0.5f32; 8]),
                    ("feat".into(), 3, vec![1.5f32; 9]),
                ],
                vec![("feat".into(), 3, vec![-1.0f32; 6])],
            ],
        };
        let p = tmp("rt.ckpt");
        let bytes = ck.save(&p).unwrap();
        assert!(bytes > 0);
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage_and_stale_versions() {
        let p = tmp("junk.ckpt");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        let err = Checkpoint::load(&p).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
        // right magic, wrong version
        let mut bytes = MAGIC.to_le_bytes().to_vec();
        bytes.extend_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 16]);
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn restore_rewinds_mutated_embedding_rows() {
        // the shard snapshot must do real work: mutate an embedding,
        // checkpoint, mutate again, restore — reads must rewind to the
        // snapshot (this is the path a resumed run takes for learnable
        // embeddings whose optimizer state lives in the KVStore)
        let nm = NodeMap { part_starts: vec![0, 8, 16] };
        let policy: Arc<RangePolicy> = Arc::new(RangePolicy::new(nm));
        let cluster = KvCluster::new(2, Arc::new(CostModel::default()));
        let emb = EmbeddingTable::create(
            &cluster, policy.as_ref(), "emb", 16, 4, 0.1, 7,
        );
        let mut client = cluster.client(0, policy.clone());
        let ids: Vec<NodeId> = vec![2, 12];
        let grads = vec![1.0f32; 2 * 4];
        emb.update(&mut client, &ids, &grads, 0.25).unwrap();

        let ck = Checkpoint::capture(7, 1, &[], &cluster.servers);
        let mut at_ckpt = vec![0f32; 2 * 4];
        emb.gather(&mut client, &ids, &mut at_ckpt).unwrap();

        emb.update(&mut client, &ids, &grads, 0.25).unwrap(); // diverge
        let mut diverged = vec![0f32; 2 * 4];
        emb.gather(&mut client, &ids, &mut diverged).unwrap();
        assert_ne!(at_ckpt, diverged);

        ck.restore(&cluster.servers).unwrap();
        let mut restored = vec![0f32; 2 * 4];
        emb.gather(&mut client, &ids, &mut restored).unwrap();
        assert_eq!(at_ckpt, restored, "restore must rewind the shard");
    }

    #[test]
    fn restore_rejects_server_count_mismatch() {
        let ck = Checkpoint {
            seed: 1,
            step: 0,
            params: vec![],
            shards: vec![vec![]],
        };
        let cluster = KvCluster::new(2, Arc::new(CostModel::default()));
        assert!(ck.restore(&cluster.servers).is_err());
    }
}
