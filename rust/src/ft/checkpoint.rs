//! Checkpoint / exact-resume state (docs/DESIGN.md §8).
//!
//! A [`Checkpoint`] is everything needed to continue training with a
//! byte-identical stream: the run seed, the global step, the dense
//! model parameters (synchronized across ranks by the preceding
//! all-reduce, so one copy suffices), and every KVStore shard — feature
//! tables, labels, and learnable embeddings whose optimizer state
//! *lives* in the KVStore (`kvstore/embedding.rs`). Batch composition
//! has been a pure function of `(seed, global_step)` since PR 5, so no
//! sampler or scheduler state needs saving: restoring `(seed, step)`
//! and restarting the loaders at `step` replays the exact stream.
//!
//! The on-disk format follows `graph/bundle.rs`: magic + version, then
//! little-endian length-prefixed sections; foreign files and stale
//! versions are rejected with descriptive errors.
//!
//! Version 2 (this header) adds the stateful-optimizer payload —
//! momentum coefficient + velocity tensors (identical across ranks, so
//! one copy suffices; see `trainer::apply_momentum`) — and the elastic
//! [`MembershipView`] the snapshot was taken under, so a resumed run
//! knows which trainer grid produced it. Version-1 files predate
//! optimizer state and are rejected: silently resuming them would drop
//! velocity and break the byte-identity contract.
//!
//! Writes are atomic: the encoder streams into `<path>.tmp` and only a
//! final `rename` publishes the checkpoint, so a crash mid-write can
//! never leave a truncated file that poisons `resume_from`.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::MembershipView;
use crate::kvstore::KvServer;

const MAGIC: u32 = 0xC8EC_4D17;
const VERSION: u32 = 0xFA00_0002;

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    write_u64(w, s.len() as u64)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let len = read_u64(r)? as usize;
    let mut b = vec![0u8; len];
    r.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let n = read_u64(r)? as usize;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

/// A full training snapshot: `(seed, step)` + model params + every
/// KVStore shard, name-sorted per server for a deterministic encoding.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub seed: u64,
    /// Global step the snapshot was taken *after*: resuming replays
    /// batches `step..`.
    pub step: u64,
    pub params: Vec<Vec<f32>>,
    /// Per KV server (machine order): `(tensor, dim, rows)`.
    pub shards: Vec<Vec<(String, usize, Vec<f32>)>>,
    /// Momentum coefficient the run trained with (0.0 = plain SGD,
    /// `velocity` empty).
    pub momentum: f32,
    /// Momentum velocity per parameter tensor. Synchronized params in,
    /// synchronized mean gradient in — so velocity is identical across
    /// ranks and one copy restores every rank.
    pub velocity: Vec<Vec<f32>>,
    /// Membership epoch the snapshot was taken under (None for
    /// fixed-membership runs).
    pub membership: Option<MembershipView>,
}

impl Checkpoint {
    /// The canonical file name the trainer writes at `step`.
    pub fn path_for(dir: &Path, step: u64) -> PathBuf {
        dir.join(format!("ckpt_{step:08}.ckpt"))
    }

    /// Snapshot the cluster: params + every server's shards.
    pub fn capture(
        seed: u64,
        step: u64,
        params: &[Vec<f32>],
        servers: &[Arc<KvServer>],
    ) -> Checkpoint {
        Checkpoint {
            seed,
            step,
            params: params.to_vec(),
            shards: servers.iter().map(|s| s.export_shards()).collect(),
            momentum: 0.0,
            velocity: Vec::new(),
            membership: None,
        }
    }

    /// Attach momentum-SGD state (coefficient + per-tensor velocity).
    pub fn with_optimizer(
        mut self,
        momentum: f32,
        velocity: Vec<Vec<f32>>,
    ) -> Self {
        self.momentum = momentum;
        self.velocity = velocity;
        self
    }

    /// Record the membership epoch the snapshot was taken under.
    pub fn with_membership(mut self, view: MembershipView) -> Self {
        self.membership = Some(view);
        self
    }

    /// Write the restored shards back into a (re)deployed cluster's
    /// servers. The server count must match the snapshot's.
    pub fn restore(&self, servers: &[Arc<KvServer>]) -> Result<()> {
        ensure!(
            servers.len() == self.shards.len(),
            "checkpoint holds {} servers, cluster has {}",
            self.shards.len(),
            servers.len()
        );
        for (server, shards) in servers.iter().zip(&self.shards) {
            for (name, dim, data) in shards {
                server.import_shard(name, *dim, data.clone());
            }
        }
        Ok(())
    }

    /// Persist to `path`; returns the bytes written. The write is
    /// crash-safe: everything streams into `<path>.tmp` and a final
    /// atomic rename publishes it, so `resume_from` never sees a
    /// truncated file.
    pub fn save(&self, path: &Path) -> Result<u64> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = tmp_path(path);
        let mut w = BufWriter::new(
            File::create(&tmp)
                .with_context(|| format!("create {tmp:?}"))?,
        );
        write_u32(&mut w, MAGIC)?;
        write_u32(&mut w, VERSION)?;
        write_u64(&mut w, self.seed)?;
        write_u64(&mut w, self.step)?;
        write_u64(&mut w, self.params.len() as u64)?;
        for p in &self.params {
            write_f32s(&mut w, p)?;
        }
        write_u64(&mut w, self.shards.len() as u64)?;
        for server in &self.shards {
            write_u64(&mut w, server.len() as u64)?;
            for (name, dim, data) in server {
                write_str(&mut w, name)?;
                write_u64(&mut w, *dim as u64)?;
                write_f32s(&mut w, data)?;
            }
        }
        // v2 sections: optimizer state + membership record
        write_u32(&mut w, self.momentum.to_bits())?;
        write_u64(&mut w, self.velocity.len() as u64)?;
        for v in &self.velocity {
            write_f32s(&mut w, v)?;
        }
        match &self.membership {
            None => write_u32(&mut w, 0)?,
            Some(view) => {
                write_u32(&mut w, 1)?;
                write_u64(&mut w, view.epoch)?;
                write_u64(&mut w, view.per_machine as u64)?;
                write_u64(&mut w, view.machines.len() as u64)?;
                for &m in &view.machines {
                    write_u32(&mut w, m)?;
                }
            }
        }
        let f = w
            .into_inner()
            .map_err(|e| anyhow::anyhow!("flush {tmp:?}: {e}"))?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publish {tmp:?} -> {path:?}"))?;
        Ok(std::fs::metadata(path)?.len())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let magic = read_u32(&mut r)?;
        if magic != MAGIC {
            bail!("bad checkpoint magic in {path:?}");
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!(
                "unsupported checkpoint version {version:#010x} in \
                 {path:?} ({VERSION:#010x} expected)"
            );
        }
        let seed = read_u64(&mut r)?;
        let step = read_u64(&mut r)?;
        let n_params = read_u64(&mut r)? as usize;
        let mut params = Vec::with_capacity(n_params);
        for _ in 0..n_params {
            params.push(read_f32s(&mut r)?);
        }
        let n_servers = read_u64(&mut r)? as usize;
        let mut shards = Vec::with_capacity(n_servers);
        for _ in 0..n_servers {
            let n_tensors = read_u64(&mut r)? as usize;
            let mut server = Vec::with_capacity(n_tensors);
            for _ in 0..n_tensors {
                let name = read_str(&mut r)?;
                let dim = read_u64(&mut r)? as usize;
                let data = read_f32s(&mut r)?;
                server.push((name, dim, data));
            }
            shards.push(server);
        }
        let momentum = f32::from_bits(read_u32(&mut r)?);
        let n_vel = read_u64(&mut r)? as usize;
        let mut velocity = Vec::with_capacity(n_vel);
        for _ in 0..n_vel {
            velocity.push(read_f32s(&mut r)?);
        }
        let membership = match read_u32(&mut r)? {
            0 => None,
            1 => {
                let epoch = read_u64(&mut r)?;
                let per_machine = read_u64(&mut r)? as usize;
                let n_m = read_u64(&mut r)? as usize;
                let mut machines = Vec::with_capacity(n_m);
                for _ in 0..n_m {
                    machines.push(read_u32(&mut r)?);
                }
                Some(MembershipView { epoch, machines, per_machine })
            }
            x => bail!("bad membership flag {x} in {path:?}"),
        };
        Ok(Checkpoint {
            seed,
            step,
            params,
            shards,
            momentum,
            velocity,
            membership,
        })
    }

    /// Delete all but the newest `keep` checkpoints in `dir` (plus any
    /// orphaned `.tmp` from a crashed writer). `keep == 0` disables
    /// pruning. Returns how many files were removed.
    pub fn prune(dir: &Path, keep: usize) -> Result<usize> {
        if keep == 0 || !dir.exists() {
            return Ok(0);
        }
        let mut ckpts: Vec<PathBuf> = Vec::new();
        let mut removed = 0usize;
        for entry in std::fs::read_dir(dir)? {
            let p = entry?.path();
            let name = match p.file_name().and_then(|n| n.to_str()) {
                Some(n) => n,
                None => continue,
            };
            if name.starts_with("ckpt_") && name.ends_with(".ckpt.tmp")
            {
                std::fs::remove_file(&p)?;
                removed += 1;
            } else if name.starts_with("ckpt_")
                && name.ends_with(".ckpt")
            {
                ckpts.push(p);
            }
        }
        // zero-padded step numbers: name order == step order
        ckpts.sort();
        let n = ckpts.len();
        for p in ckpts.into_iter().take(n.saturating_sub(keep)) {
            std::fs::remove_file(&p)?;
            removed += 1;
        }
        Ok(removed)
    }
}

/// `<path>.tmp` sibling used for the atomic write.
fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::NodeId;
    use crate::kvstore::{EmbeddingTable, KvCluster, RangePolicy};
    use crate::net::CostModel;
    use crate::partition::NodeMap;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("ddgl_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn checkpoint_roundtrips_byte_identically() {
        let ck = Checkpoint {
            seed: 7,
            step: 42,
            params: vec![vec![1.0, -2.5, 3.25], vec![0.0; 5]],
            shards: vec![
                vec![
                    ("emb".into(), 2, vec![0.5f32; 8]),
                    ("feat".into(), 3, vec![1.5f32; 9]),
                ],
                vec![("feat".into(), 3, vec![-1.0f32; 6])],
            ],
            momentum: 0.9,
            velocity: vec![vec![0.125, -0.25, 0.5], vec![1.0; 5]],
            membership: Some(MembershipView {
                epoch: 3,
                machines: vec![0, 2],
                per_machine: 2,
            }),
        };
        let p = tmp("rt.ckpt");
        let bytes = ck.save(&p).unwrap();
        assert!(bytes > 0);
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn plain_sgd_checkpoint_roundtrips_without_optimizer_state() {
        // capture() defaults: momentum 0, no velocity, no membership
        let ck = Checkpoint::capture(3, 5, &[vec![1.0f32; 4]], &[]);
        assert_eq!(ck.momentum, 0.0);
        assert!(ck.velocity.is_empty());
        assert!(ck.membership.is_none());
        let p = tmp("plain.ckpt");
        ck.save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap(), ck);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage_and_stale_versions() {
        let p = tmp("junk.ckpt");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        let err = Checkpoint::load(&p).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
        // right magic, wrong version
        let mut bytes = MAGIC.to_le_bytes().to_vec();
        bytes.extend_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 16]);
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_pre_momentum_v1_checkpoints_descriptively() {
        // a PR 6 era file: right magic, version 1 header — it has no
        // optimizer-state sections, so silently accepting it would
        // resume with dropped velocity and break byte-identity
        let p = tmp("v1.ckpt");
        let mut bytes = MAGIC.to_le_bytes().to_vec();
        bytes.extend_from_slice(&0xFA00_0001u32.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 24]); // seed/step/empty sections
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("0xfa000001"), "{err}");
        assert!(err.contains("0xfa000002 expected"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn save_is_atomic_and_leaves_no_tmp_file() {
        let ck = Checkpoint::capture(1, 2, &[vec![1.0f32]], &[]);
        let p = tmp("atomic.ckpt");
        ck.save(&p).unwrap();
        assert!(p.exists());
        assert!(
            !tmp_path(&p).exists(),
            "tmp file must be renamed away"
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn prune_keeps_the_newest_n_and_sweeps_orphaned_tmps() {
        let dir = std::env::temp_dir().join("ddgl_ckpt_prune_test");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let ck = Checkpoint::capture(1, 0, &[], &[]);
        for step in [2u64, 4, 8, 16] {
            ck.save(&Checkpoint::path_for(&dir, step)).unwrap();
        }
        // a crashed writer's leftover
        let orphan = dir.join("ckpt_00000099.ckpt.tmp");
        std::fs::write(&orphan, b"partial").unwrap();
        // keep = 0 disables pruning entirely
        assert_eq!(Checkpoint::prune(&dir, 0).unwrap(), 0);
        assert!(Checkpoint::path_for(&dir, 2).exists());
        let removed = Checkpoint::prune(&dir, 2).unwrap();
        assert_eq!(removed, 3); // steps 2, 4 + the orphan
        assert!(!Checkpoint::path_for(&dir, 2).exists());
        assert!(!Checkpoint::path_for(&dir, 4).exists());
        assert!(Checkpoint::path_for(&dir, 8).exists());
        assert!(Checkpoint::path_for(&dir, 16).exists());
        assert!(!orphan.exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_rewinds_mutated_embedding_rows() {
        // the shard snapshot must do real work: mutate an embedding,
        // checkpoint, mutate again, restore — reads must rewind to the
        // snapshot (this is the path a resumed run takes for learnable
        // embeddings whose optimizer state lives in the KVStore)
        let nm = NodeMap { part_starts: vec![0, 8, 16] };
        let policy: Arc<RangePolicy> = Arc::new(RangePolicy::new(nm));
        let cluster = KvCluster::new(2, Arc::new(CostModel::default()));
        let emb = EmbeddingTable::create(
            &cluster, policy.as_ref(), "emb", 16, 4, 0.1, 7,
        );
        let mut client = cluster.client(0, policy.clone());
        let ids: Vec<NodeId> = vec![2, 12];
        let grads = vec![1.0f32; 2 * 4];
        emb.update(&mut client, &ids, &grads, 0.25).unwrap();

        let ck = Checkpoint::capture(7, 1, &[], &cluster.servers);
        let mut at_ckpt = vec![0f32; 2 * 4];
        emb.gather(&mut client, &ids, &mut at_ckpt).unwrap();

        emb.update(&mut client, &ids, &grads, 0.25).unwrap(); // diverge
        let mut diverged = vec![0f32; 2 * 4];
        emb.gather(&mut client, &ids, &mut diverged).unwrap();
        assert_ne!(at_ckpt, diverged);

        ck.restore(&cluster.servers).unwrap();
        let mut restored = vec![0f32; 2 * 4];
        emb.gather(&mut client, &ids, &mut restored).unwrap();
        assert_eq!(at_ckpt, restored, "restore must rewind the shard");
    }

    #[test]
    fn restore_rejects_server_count_mismatch() {
        let ck = Checkpoint {
            seed: 1,
            step: 0,
            params: vec![],
            shards: vec![vec![]],
            momentum: 0.0,
            velocity: vec![],
            membership: None,
        };
        let cluster = KvCluster::new(2, Arc::new(CostModel::default()));
        assert!(ck.restore(&cluster.servers).is_err());
    }
}
