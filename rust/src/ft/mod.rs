//! Fault tolerance: checkpoint/exact-resume + failure injection
//! (docs/DESIGN.md §8).
//!
//! Production trainers get preempted; without this layer any failure
//! loses the run (the gap the distributed-GNN survey flags for the
//! whole DistDGL generation). Two halves:
//!
//! - [`Checkpoint`] — snapshot `(seed, step)`, model params, and every
//!   KVStore shard; because batch composition is a pure function of
//!   `(seed, global_step)`, restoring the snapshot and restarting the
//!   loaders at `step` (`DistNodeDataLoader::builder().start_at(step)`)
//!   replays a byte-identical stream (test-enforced across modes,
//!   worker counts, cache on/off, hetero + homogeneous).
//! - [`FaultPlan`] — injected KV/sampler outages, transport message
//!   drop/delay/partition and connection kills, and per-machine
//!   slowdown factors
//!   ([`CostModel::set_slowdown`](crate::net::CostModel::set_slowdown)),
//!   with bounded retry/backoff on the RPC paths surfacing
//!   [`RpcError`](crate::net::RpcError) instead of panics so the
//!   pipeline drains cleanly on unrecoverable failure.
//! - [`ReplicaSet`] — primary/backup KV shard replication with
//!   transparent failover and server rejoin (docs/DESIGN.md §12),
//!   turning an unrecoverable `ServerDown` into an invisible reroute.

pub mod checkpoint;
pub mod fault;
pub mod replica;

pub use checkpoint::Checkpoint;
pub use fault::{FailWindow, FaultPlan, MessageVerdict};
pub use replica::{parse_replica_table, replica_table, ReplicaSet};
