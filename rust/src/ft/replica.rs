//! Primary/backup KV shard replication state (docs/DESIGN.md §12).
//!
//! Placement follows the parameter-server lineage (Li et al.): the
//! shards owned by machine `m` are also materialized on machine
//! `(m + 1) % M` under the [`replica_table`] namespace, so any single
//! KV-server loss leaves every row reachable. A [`ReplicaSet`] is the
//! cluster-wide failover state machine the clients consult:
//!
//! * **up** (default) — reads go to the primary; embedding updates
//!   write through to primary *and* replica, keeping them
//!   byte-identical at every all-reduce barrier (test-enforced).
//! * **failed** — a client exhausted the bounded retry budget against
//!   the primary ([`RpcError::ServerDown`](crate::net::RpcError) /
//!   `ConnectionLost`) and flipped the machine via [`mark_failed`];
//!   all subsequent reads reroute to the replica owner. Because the
//!   replica holds identical bytes, the batch stream — and therefore
//!   losses and final params — is unchanged (the centerpiece
//!   invariant of this layer).
//! * back to **up** — a restarted server re-imports its shards from
//!   the peer replica ([`KvCluster::rejoin_server`]) and
//!   [`mark_rejoined`] flips routing back to the primary.
//!
//! The set keeps the `ft.failovers` / `ft.rejoins` / `ft.replica_bytes`
//! counters and decomposed failover timings (detect / reroute /
//! re-import, summed into the `pipeline.failover` timer) that
//! `TrainReport` and `benches/failover.rs` report.
//!
//! [`mark_failed`]: ReplicaSet::mark_failed
//! [`mark_rejoined`]: ReplicaSet::mark_rejoined
//! [`KvCluster::rejoin_server`]: crate::kvstore::KvCluster::rejoin_server

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

use crate::metrics::Metrics;

/// Name of the backup copy of primary `owner`'s tensor `name`, as
/// registered on the replica owner. The prefix keeps backups disjoint
/// from the peer's own same-named shards (every machine registers a
/// "feat"/"label" shard of its own).
pub fn replica_table(owner: u32, name: &str) -> String {
    format!("replica{owner}::{name}")
}

/// The primary name a [`replica_table`] entry backs up, with its
/// primary owner — `None` for ordinary (non-replica) tables.
pub fn parse_replica_table(name: &str) -> Option<(u32, &str)> {
    let rest = name.strip_prefix("replica")?;
    let (owner, base) = rest.split_once("::")?;
    Some((owner.parse().ok()?, base))
}

/// Cluster-wide replication + failover state, shared (`Arc`) by every
/// KV client once [`KvCluster::enable_replication`] has materialized
/// the backups.
///
/// [`KvCluster::enable_replication`]: crate::kvstore::KvCluster::enable_replication
#[derive(Debug)]
pub struct ReplicaSet {
    /// `failed[m]` — primary `m` is considered down; reads reroute.
    failed: Vec<AtomicBool>,
    failovers: AtomicU64,
    rejoins: AtomicU64,
    replica_bytes: AtomicU64,
    detect_nanos: AtomicU64,
    reroute_nanos: AtomicU64,
    reimport_nanos: AtomicU64,
}

impl ReplicaSet {
    pub fn new(n_machines: usize) -> Self {
        assert!(
            n_machines >= 2,
            "replication needs a distinct peer per machine"
        );
        Self {
            failed: (0..n_machines).map(|_| AtomicBool::new(false)).collect(),
            failovers: AtomicU64::new(0),
            rejoins: AtomicU64::new(0),
            replica_bytes: AtomicU64::new(0),
            detect_nanos: AtomicU64::new(0),
            reroute_nanos: AtomicU64::new(0),
            reimport_nanos: AtomicU64::new(0),
        }
    }

    pub fn n_machines(&self) -> usize {
        self.failed.len()
    }

    /// The machine holding the backup of `m`'s shards: `(m + 1) % M`.
    pub fn replica_owner(&self, m: u32) -> u32 {
        ((m as usize + 1) % self.failed.len()) as u32
    }

    /// Whether reads of `m`'s shards currently reroute to the replica.
    pub fn is_failed(&self, m: u32) -> bool {
        self.failed[m as usize].load(Ordering::Acquire)
    }

    /// Flip primary `m` to failed. Returns `true` for the caller that
    /// actually performed the transition (counted once as a failover,
    /// however many clients observe the dead server concurrently).
    pub fn mark_failed(&self, m: u32) -> bool {
        let first = !self.failed[m as usize].swap(true, Ordering::AcqRel);
        if first {
            self.failovers.fetch_add(1, Ordering::Relaxed);
        }
        first
    }

    /// Flip primary `m` back to up (after its shards were re-imported).
    pub fn mark_rejoined(&self, m: u32) {
        if self.failed[m as usize].swap(false, Ordering::AcqRel) {
            self.rejoins.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Account bytes materialized into replica tables (deploy copy and
    /// rejoin re-import both count — it is the replication traffic).
    pub fn add_replica_bytes(&self, bytes: u64) {
        self.replica_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Time spent discovering a primary was down (the exhausted retry
    /// loop against it).
    pub fn note_detect(&self, d: Duration) {
        self.detect_nanos
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Time spent re-issuing rerouted reads against the replica owner.
    pub fn note_reroute(&self, d: Duration) {
        self.reroute_nanos
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Time spent re-importing shards from the peer replica on rejoin.
    pub fn note_reimport(&self, d: Duration) {
        self.reimport_nanos
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn failovers(&self) -> u64 {
        self.failovers.load(Ordering::Relaxed)
    }

    pub fn rejoins(&self) -> u64 {
        self.rejoins.load(Ordering::Relaxed)
    }

    pub fn replica_bytes(&self) -> u64 {
        self.replica_bytes.load(Ordering::Relaxed)
    }

    pub fn detect_time(&self) -> Duration {
        Duration::from_nanos(self.detect_nanos.load(Ordering::Relaxed))
    }

    pub fn reroute_time(&self) -> Duration {
        Duration::from_nanos(self.reroute_nanos.load(Ordering::Relaxed))
    }

    pub fn reimport_time(&self) -> Duration {
        Duration::from_nanos(self.reimport_nanos.load(Ordering::Relaxed))
    }

    /// Export the replication counters as `ft.*` metrics plus the
    /// aggregate `pipeline.failover` timer (detect + reroute +
    /// re-import; `benches/failover.rs` reports the decomposition).
    pub fn publish(&self, m: &Metrics) {
        m.inc("ft.failovers", self.failovers());
        m.inc("ft.rejoins", self.rejoins());
        m.inc("ft.replica_bytes", self.replica_bytes());
        m.add_time(
            "pipeline.failover",
            self.detect_time() + self.reroute_time() + self.reimport_time(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replica_tables_round_trip_and_stay_disjoint() {
        let name = replica_table(2, "feat.paper");
        assert_eq!(name, "replica2::feat.paper");
        assert_eq!(parse_replica_table(&name), Some((2, "feat.paper")));
        // ordinary tables are not replicas
        assert_eq!(parse_replica_table("feat"), None);
        assert_eq!(parse_replica_table("replicaX::feat"), None);
        // a replica of a replica-looking base name still round-trips
        // on the FIRST separator (owner is the outer prefix)
        assert_eq!(
            parse_replica_table("replica0::replica1::feat"),
            Some((0, "replica1::feat"))
        );
    }

    #[test]
    fn placement_is_the_next_ring_neighbor() {
        let r = ReplicaSet::new(3);
        assert_eq!(r.replica_owner(0), 1);
        assert_eq!(r.replica_owner(1), 2);
        assert_eq!(r.replica_owner(2), 0);
    }

    #[test]
    fn failover_counts_once_across_concurrent_observers() {
        let r = ReplicaSet::new(2);
        assert!(!r.is_failed(0));
        assert!(r.mark_failed(0), "first observer performs the flip");
        assert!(!r.mark_failed(0), "later observers see it done");
        assert!(r.is_failed(0));
        assert_eq!(r.failovers(), 1);
        // the other machine is independent
        assert!(!r.is_failed(1));
    }

    #[test]
    fn rejoin_flips_back_and_counts() {
        let r = ReplicaSet::new(2);
        r.mark_rejoined(0); // rejoining an up machine is a no-op
        assert_eq!(r.rejoins(), 0);
        r.mark_failed(0);
        r.mark_rejoined(0);
        assert!(!r.is_failed(0));
        assert_eq!(r.rejoins(), 1);
        // a second failure of the same machine is a new failover
        assert!(r.mark_failed(0));
        assert_eq!(r.failovers(), 2);
    }

    #[test]
    fn publish_exports_counters_and_the_failover_timer() {
        let r = ReplicaSet::new(2);
        r.mark_failed(1);
        r.add_replica_bytes(4096);
        r.note_detect(Duration::from_millis(2));
        r.note_reroute(Duration::from_millis(1));
        r.note_reimport(Duration::from_millis(4));
        let m = Metrics::new();
        r.publish(&m);
        assert_eq!(m.counter("ft.failovers"), 1);
        assert_eq!(m.counter("ft.rejoins"), 0);
        assert_eq!(m.counter("ft.replica_bytes"), 4096);
        assert_eq!(
            m.total_time("pipeline.failover"),
            Duration::from_millis(7)
        );
    }

    #[test]
    #[should_panic(expected = "distinct peer")]
    fn single_machine_replication_is_rejected() {
        let _ = ReplicaSet::new(1);
    }
}
