//! BatchGen: stages 1–4 of the pipeline for one trainer — schedule
//! targets, sample multi-hop neighbors through the distributed sampler,
//! compact to the padded block layout, and pull features/labels from the
//! KVStore into a ready-to-transfer [`HostBatch`].
//!
//! Every batch is addressed by its **global index** `g` (epoch = `g /
//! batches_per_epoch`, idx = `g % batches_per_epoch`), and all per-batch
//! randomness — the epoch permutation, negative tails, and the sampler
//! stream — is a pure function of `(seed, epoch, idx)` via
//! [`Rng::for_path`]. That is what lets the pipeline's worker pool hand
//! batch indices to N workers ([`BatchGen::fork_worker`]) and reassemble
//! a stream that is byte-identical for any worker count.
//!
//! §Perf: the hot path is allocation-free across batches — the KvClient
//! grouping scratch, the sampler's per-owner split, and the label staging
//! buffer are all reused, and finished [`HostBatch`]es can be recycled
//! through a [`BatchPool`] so the big `n0 * feat_dim` feature buffer keeps
//! its capacity from batch to batch. Locality counters
//! (`kv.remote_rows`, `sampler.dropped_neighbors`, `cache.*`, `pool.*`)
//! and the per-stage timers (`pipeline.schedule`/`sample`/`pull`/
//! `compact`) are metered into the attached [`Metrics`] every batch.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::graph::{FanoutPlan, NodeId};
use crate::kvstore::{KvClient, TypedFeatures};
use crate::metrics::Metrics;
use crate::net::RpcError;
use crate::runtime::executable::HostBatch;
use crate::sampler::compact::{to_block, ShapeSpec, TaskKind};
use crate::sampler::{BatchScheduler, DistNeighborSampler, Target};
use crate::util::Rng;

/// Stream lanes under the run seed (see [`Rng::for_path`]).
const LANE_SAMPLE: u64 = 0x5A;
const LANE_EVAL: u64 = 0xE7;

#[derive(Default)]
struct PoolInner {
    slots: Vec<HostBatch>,
    cap: usize,
    metrics: Option<Arc<Metrics>>,
}

/// Recycling pool for spent [`HostBatch`]es. Clone-able and shared: the
/// worker pool's generators and the consumer all hold clones of one pool;
/// [`BatchPool::put`] returns a batch once the device is done with it and
/// [`BatchGen::materialize_with`] reuses the allocations. A batch that is
/// never returned is simply dropped — pooling is an optimization, never a
/// correctness requirement. Effectiveness is observable through the
/// `pool.hit` / `pool.miss` / `pool.dropped` counters (metered once a
/// [`Metrics`] sink is attached, which [`Pipeline::start`] does).
///
/// [`Pipeline::start`]: crate::pipeline::Pipeline::start
#[derive(Clone)]
pub struct BatchPool {
    inner: Arc<Mutex<PoolInner>>,
}

impl Default for BatchPool {
    fn default() -> Self {
        Self::with_capacity(4)
    }
}

impl BatchPool {
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            inner: Arc::new(Mutex::new(PoolInner {
                slots: Vec::new(),
                cap,
                metrics: None,
            })),
        }
    }

    /// Raise the slot cap to at least `min_cap` (never shrinks). The
    /// pipeline sizes the default pool to `num_workers +
    /// cpu_prefetch_depth` so recycling keeps up with N producers.
    pub fn ensure_cap(&self, min_cap: usize) {
        let mut p = self.inner.lock().unwrap();
        p.cap = p.cap.max(min_cap);
    }

    pub fn cap(&self) -> usize {
        self.inner.lock().unwrap().cap
    }

    /// Meter `pool.*` counters into `metrics` from now on (all clones
    /// share the sink).
    pub fn attach_metrics(&self, metrics: Arc<Metrics>) {
        self.inner.lock().unwrap().metrics = Some(metrics);
    }

    /// Return a spent batch for reuse (dropped if the pool is full).
    pub fn put(&self, b: HostBatch) {
        let mut p = self.inner.lock().unwrap();
        if p.slots.len() < p.cap {
            p.slots.push(b);
        } else if let Some(m) = &p.metrics {
            m.inc("pool.dropped", 1);
        }
    }

    /// Take a recycled batch, or a fresh default one.
    pub fn take(&self) -> HostBatch {
        let mut p = self.inner.lock().unwrap();
        match p.slots.pop() {
            Some(b) => {
                if let Some(m) = &p.metrics {
                    m.inc("pool.hit", 1);
                }
                b
            }
            Option::None => {
                if let Some(m) = &p.metrics {
                    m.inc("pool.miss", 1);
                }
                HostBatch::default()
            }
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

pub struct BatchGen {
    pub spec: ShapeSpec,
    pub scheduler: BatchScheduler,
    pub sampler: Arc<DistNeighborSampler>,
    pub kv: KvClient,
    /// Run seed: every batch's sampler stream is
    /// [`BatchGen::batch_rng`]`(seed, epoch, idx)` — no mutable RNG state
    /// survives across batches.
    pub seed: u64,
    /// Sequential cursor (global batch index) for [`Self::next`].
    pub pos: u64,
    /// Per-call counter for the independent eval stream of
    /// [`Self::materialize_nodes`].
    pub eval_pos: u64,
    /// Per-layer, per-etype fanout schedule (uniform for homogeneous
    /// graphs; per-layer totals equal `spec.fanouts`).
    pub plan: FanoutPlan,
    /// Per-ntype feature-table view (the trivial single-table view for
    /// homogeneous graphs).
    pub features: TypedFeatures,
    /// Name of the label tensor (dim-1 f32 rows); empty = no labels (lp).
    pub label_name: String,
    /// Sink for per-batch locality/cache counters (the pipeline installs
    /// its shared instance at start).
    pub metrics: Arc<Metrics>,
    /// Precomputed `sampler.etype_edges.<r>` metric keys (§Perf: no
    /// per-batch `format!` on the hot path); see [`etype_metric_keys`].
    pub etype_keys: Vec<String>,
    /// Spent-batch recycling (see [`BatchPool`]).
    pub pool: BatchPool,
    /// Reusable staging buffer for label-row pulls.
    pub label_scratch: Vec<f32>,
    /// Reusable node-id buffer for [`Self::prefetch_batch`] frontiers.
    pub frontier_scratch: Vec<NodeId>,
}

impl BatchGen {
    pub fn batches_per_epoch(&self) -> usize {
        self.scheduler.batches_per_epoch()
    }

    /// The sampler stream for batch `(epoch, idx)` of a run seeded
    /// `seed` — a pure function of its arguments (the worker-pool
    /// invariant; see the module docs).
    pub fn batch_rng(seed: u64, epoch: u64, idx: usize) -> Rng {
        Rng::for_path(seed, &[epoch, idx as u64, LANE_SAMPLE])
    }

    /// Produce one fully materialized mini-batch (stages 1–4) of the
    /// sequential stream. Panics on RPC failure — fault-tolerant
    /// drivers use [`Self::try_next`].
    pub fn next(&mut self) -> HostBatch {
        self.try_next().expect("mini-batch generation failed")
    }

    /// Fallible [`Self::next`]: injected outages / decode errors on the
    /// sampler or KVStore path surface as [`RpcError`] values.
    pub fn try_next(&mut self) -> Result<HostBatch, RpcError> {
        let g = self.pos;
        self.pos += 1;
        self.try_batch_at(g)
    }

    /// Produce global batch `g` (epoch `g / batches_per_epoch`, index
    /// `g % batches_per_epoch`). Pure in `(seed, g)` for a fixed
    /// deployment: workers claim disjoint `g`s and the reassembled
    /// stream is identical for any worker count. Panics on RPC failure
    /// — fault-tolerant drivers use [`Self::try_batch_at`].
    pub fn batch_at(&mut self, g: u64) -> HostBatch {
        self.try_batch_at(g).expect("mini-batch generation failed")
    }

    /// Fallible [`Self::batch_at`]. Purity in `(seed, g)` holds across
    /// failures: a batch retried after a healed fault is byte-identical
    /// to the one an undisturbed run produces.
    pub fn try_batch_at(&mut self, g: u64) -> Result<HostBatch, RpcError> {
        let bpe = self.batches_per_epoch().max(1) as u64;
        let (epoch, idx) = (g / bpe, (g % bpe) as usize);
        // stage 1: schedule
        let t = Instant::now();
        let target = self.scheduler.batch_at(epoch, idx);
        self.metrics.add_time("pipeline.schedule", t.elapsed());
        let mut rng = Self::batch_rng(self.seed, epoch, idx);
        self.materialize_with(&mut rng, &target)
    }

    /// Hand a finished batch back for buffer reuse.
    pub fn recycle(&mut self, b: HostBatch) {
        self.pool.put(b);
    }

    /// Warm the feature cache with global batch `g`'s remote layer-0
    /// frontier — the lookahead step of the predictive prefetcher
    /// ([`crate::pipeline::prefetch`]). Re-derives the batch's pure
    /// `(seed, epoch, idx)` schedule + sampler streams on this
    /// generator's private clones (no live RNG or cursor is touched),
    /// collects the sampled node set, and hands its remote part to
    /// [`KvClient::prefetch_typed`]; `pin` protects the rows of
    /// imminent batches from the CLOCK hand until demand consumes
    /// them. Returns the rows actually pulled ahead of demand.
    ///
    /// [`KvClient::prefetch_typed`]: crate::kvstore::KvClient::prefetch_typed
    pub fn prefetch_batch(
        &mut self,
        g: u64,
        pin: bool,
    ) -> Result<usize, RpcError> {
        let bpe = self.batches_per_epoch().max(1) as u64;
        let (epoch, idx) = (g / bpe, (g % bpe) as usize);
        let target = self.scheduler.batch_at(epoch, idx);
        let mut rng = Self::batch_rng(self.seed, epoch, idx);
        let flat = target.flat_nodes();
        let samples = self.sampler.sample_blocks(
            &flat,
            &self.plan,
            &self.spec.layer_nodes,
            &mut rng,
        )?;
        // the (undeduped) layer-0 frontier: seeds plus every sampled
        // neighbor of every layer — exactly the node set `to_block`
        // compacts into `input_nodes`. prefetch_typed dedupes against
        // the cache and in-flight pulls, so duplicates here are free.
        let mut frontier = std::mem::take(&mut self.frontier_scratch);
        frontier.clear();
        frontier.extend_from_slice(&flat);
        for (_, nbrs) in &samples {
            for s in nbrs {
                frontier.extend_from_slice(&s.nbrs);
            }
        }
        let fetched =
            self.kv.prefetch_typed(&self.features, &frontier, pin);
        self.frontier_scratch = frontier;
        fetched
    }

    /// Stages 2–4 for an explicit target set and sampler stream (shared
    /// by the train path, the eval path, and tests). On `Err` a pooled
    /// buffer may be dropped instead of recycled — pooling is an
    /// optimization, so this only costs a later `pool.miss`.
    pub fn materialize_with(
        &mut self,
        rng: &mut Rng,
        target: &Target,
    ) -> Result<HostBatch, RpcError> {
        let spec = &self.spec;
        // a plan whose layer totals exceed the spec's K would make
        // to_block truncate per-seed samples, silently dropping the
        // highest relations first — catch the misconfiguration here
        debug_assert!(
            (1..=self.plan.num_layers())
                .all(|l| self.plan.layer_total(l) == spec.fanouts[l - 1]),
            "fanout plan totals disagree with spec.fanouts"
        );
        let flat = target.flat_nodes();
        // stage 2: distributed neighbor sampling (≤ k_r per etype)
        let t = Instant::now();
        let samples = self.sampler.sample_blocks(
            &flat,
            &self.plan,
            &spec.layer_nodes,
            rng,
        )?;
        self.metrics.add_time("pipeline.sample", t.elapsed());
        // stage 4 (compaction; paper runs this on GPU, order is the same)
        let t = Instant::now();
        let block = to_block(spec, &samples);
        self.metrics.add_time("pipeline.compact", t.elapsed());

        // stage 3: CPU prefetch — features for the deduped input frontier
        // into a recycled buffer. §Perf: only the padding tail needs
        // zeroing here — the pull overwrites every real row's typed
        // prefix and zeroes its dims..stride tail itself.
        let t = Instant::now();
        let HostBatch {
            mut feats,
            mut labels,
            mut label_mask,
            mut pair_mask,
            ..
        } = self.pool.take();
        let n0 = spec.layer_nodes[0];
        let f = spec.feat_dim;
        let real = block.input_nodes.len().min(n0);
        feats.clear();
        feats.reserve(n0 * f);
        #[allow(clippy::uninit_vec)]
        unsafe {
            feats.set_len(n0 * f);
        }
        feats[real * f..].fill(0.0);
        let remote_rows = self.kv.pull_typed(
            &self.features,
            &block.input_nodes[..real],
            &mut feats[..real * f],
            f,
        )?;

        // labels / masks for the targets
        let n_l = *spec.layer_nodes.last().unwrap();
        let mut label_remote = 0usize;
        let (labels, label_mask, pair_mask) = match spec.task {
            TaskKind::NodeClassification => {
                self.label_scratch.clear();
                self.label_scratch.resize(block.targets.len(), 0.0);
                label_remote = self.kv.pull(
                    &self.label_name,
                    &block.targets,
                    &mut self.label_scratch,
                )?;
                labels.clear();
                labels.resize(n_l, 0);
                label_mask.clear();
                label_mask.resize(n_l, 0.0);
                for (i, &l) in self.label_scratch.iter().enumerate() {
                    labels[i] = l as i32;
                    label_mask[i] = 1.0;
                }
                pair_mask.clear();
                (labels, label_mask, pair_mask)
            }
            TaskKind::LinkPrediction => {
                let n_pairs = target.n_items();
                pair_mask.clear();
                pair_mask.resize(spec.batch, 0.0);
                for m in pair_mask.iter_mut().take(n_pairs) {
                    *m = 1.0;
                }
                labels.clear();
                label_mask.clear();
                (labels, label_mask, pair_mask)
            }
        };
        self.metrics.add_time("pipeline.pull", t.elapsed());

        // locality / cache observability (benchsuite + Table 2 reports)
        self.metrics
            .inc("kv.remote_rows", (remote_rows + label_remote) as u64);
        self.metrics.inc(
            "sampler.dropped_neighbors",
            block.dropped_neighbors as u64,
        );
        for (r, &c) in block.etype_edges.iter().enumerate() {
            if c > 0 {
                match self.etype_keys.get(r) {
                    Some(key) => self.metrics.inc(key, c),
                    // data rels beyond the spec's etypes (mis-matched
                    // variant): rare, allocate the key on demand
                    Option::None => self
                        .metrics
                        .inc(&format!("sampler.etype_edges.{r}"), c),
                }
            }
        }
        if let Some(d) = self.kv.take_cache_delta() {
            self.metrics.inc("cache.hit_rows", d.hit_rows);
            self.metrics.inc("cache.miss_rows", d.miss_rows);
            self.metrics.inc("cache.evicted_rows", d.evicted_rows);
            self.metrics
                .inc("cache.remote_bytes_saved", d.remote_bytes_saved);
            // prefetch observability: the delta cursor is shared cache
            // state, so the background prefetcher's traffic flows in
            // through whichever demand batch meters next
            self.metrics.inc("cache.prefetch_issued", d.prefetch_issued);
            self.metrics.inc("cache.prefetch_hits", d.prefetch_hits);
            self.metrics
                .inc("cache.prefetch_wasted_bytes", d.prefetch_wasted_bytes);
            self.metrics.inc("cache.pinned_rows", d.pinned_rows);
        }

        Ok(HostBatch {
            feats,
            layers: block.layers,
            labels,
            label_mask,
            pair_mask,
            targets: block.targets,
            input_nodes: block.input_nodes,
            remote_rows,
            dropped_neighbors: block.dropped_neighbors,
        })
    }

    /// Eval-batch generator over a fixed node list (validation/test).
    /// Each call draws from its own derived stream (`LANE_EVAL`), so
    /// interleaved eval batches never perturb the training stream.
    pub fn materialize_nodes(&mut self, nodes: &[NodeId]) -> HostBatch {
        let mut rng =
            Rng::for_path(self.seed, &[self.eval_pos, LANE_EVAL]);
        self.eval_pos += 1;
        self.materialize_with(&mut rng, &Target::Nodes(nodes.to_vec()))
            .expect("eval batch generation failed")
    }

    /// An independent sampling worker over the same batch stream: shares
    /// the deployment (sampler servers, KV servers, the [`BatchPool`],
    /// the [`FeatureCache`] and the metrics sink) but owns private
    /// scratch, so N forks materialize disjoint batch indices fully in
    /// parallel. `fork.batch_at(g) == self.batch_at(g)` byte for byte.
    ///
    /// [`FeatureCache`]: crate::kvstore::FeatureCache
    pub fn fork_worker(&self) -> BatchGen {
        BatchGen {
            spec: self.spec.clone(),
            scheduler: self.scheduler.clone(),
            sampler: Arc::new(self.sampler.fork()),
            kv: self.kv.fork(),
            seed: self.seed,
            pos: self.pos,
            eval_pos: 0,
            plan: self.plan.clone(),
            features: self.features.clone(),
            label_name: self.label_name.clone(),
            metrics: self.metrics.clone(),
            etype_keys: self.etype_keys.clone(),
            pool: self.pool.clone(),
            label_scratch: Vec::new(),
            frontier_scratch: Vec::new(),
        }
    }
}

/// The `sampler.etype_edges.<r>` counter names for `n_etypes` relations,
/// built once per [`BatchGen`] so the per-batch metering loop never
/// formats strings.
pub fn etype_metric_keys(n_etypes: usize) -> Vec<String> {
    (0..n_etypes)
        .map(|r| format!("sampler.etype_edges.{r}"))
        .collect()
}

/// Test-support constructors (tiny dataset; 1..n machines) and the
/// shared sampled-batch builder used by device/executable tests.
pub mod tests_support {
    use super::*;
    use crate::graph::{Dataset, DatasetSpec};
    use crate::kvstore::{
        CacheAdmission, FeatureCache, KvCluster, RangePolicy,
    };
    use crate::net::CostModel;
    use crate::partition::{
        build_partitions, metis_partition, relabel, NodeMap,
        PartitionConfig, Partitioning, VertexWeights,
    };
    use crate::sampler::compact::ModelKind;
    use crate::sampler::SamplerServer;

    /// Single-machine BatchGen over a generated graph: `n_train` targets,
    /// given batch size, 2 layers of fanout 3, small dims.
    pub fn tiny_gen(n_train: usize, batch: usize) -> BatchGen {
        tiny_gen_parts(n_train, batch, 1, 0)
    }

    /// Like [`tiny_gen`] but partitioned across `nparts` machines (trainer
    /// on machine 0) with a remote-feature cache of `cache_budget_bytes`
    /// (0 = uncached). Deterministic for fixed arguments.
    pub fn tiny_gen_parts(
        n_train: usize,
        batch: usize,
        nparts: usize,
        cache_budget_bytes: usize,
    ) -> BatchGen {
        let spec_d = DatasetSpec::new("tiny", 1000, 4000);
        tiny_gen_from(spec_d, n_train, batch, nparts, cache_budget_bytes)
    }

    /// Heterogeneous variant of [`tiny_gen_parts`]: 2 node types (dims
    /// 32/16), 3 edge types, RGCN-shaped blocks with per-etype fanouts.
    pub fn tiny_gen_hetero(
        n_train: usize,
        batch: usize,
        nparts: usize,
        cache_budget_bytes: usize,
    ) -> BatchGen {
        let mut spec_d = DatasetSpec::new("tiny-h", 1000, 4000);
        spec_d.num_rels = 3;
        spec_d.ntypes = vec![
            ("a".to_string(), 0.6, 1),
            ("b".to_string(), 0.4, 2),
        ];
        tiny_gen_from(spec_d, n_train, batch, nparts, cache_budget_bytes)
    }

    fn tiny_gen_from(
        spec_d: DatasetSpec,
        n_train: usize,
        batch: usize,
        nparts: usize,
        cache_budget_bytes: usize,
    ) -> BatchGen {
        let d = spec_d.generate();
        let n = d.n_nodes();
        let hetero = !d.schema.is_homogeneous();
        let p = if nparts == 1 {
            Partitioning { nparts: 1, assign: vec![0; n] }
        } else {
            let vw = VertexWeights::uniform(n);
            metis_partition(&d.graph, &vw, &PartitionConfig::new(nparts))
        };
        let r = relabel::relabel(&p);
        let d2 = relabel::relabel_dataset(&d, &r);
        let parts = build_partitions(&d2.graph, &r.node_map);
        let servers: Vec<Arc<SamplerServer>> = parts
            .into_iter()
            .enumerate()
            .map(|(m, pp)| {
                Arc::new(SamplerServer::new(m as u32, Arc::new(pp)))
            })
            .collect();
        let cost = Arc::new(CostModel::default());
        let node_map = Arc::new(NodeMap {
            part_starts: r.node_map.part_starts.clone(),
        });
        let sampler = Arc::new(DistNeighborSampler::new(
            0,
            servers,
            node_map.clone(),
            cost.clone(),
        ));
        let kv = KvCluster::new(nparts, cost);
        let policy = Arc::new(RangePolicy::new(NodeMap {
            part_starts: node_map.part_starts.clone(),
        }));
        // per-ntype feature tables + labels, in relabeled id order
        let features = TypedFeatures::from_schema(
            "feat",
            &d2.schema,
            Arc::new(d2.graph.node_type.clone()),
        );
        kv.register_typed(&features, &d2.feats, d2.feat_dim, policy.as_ref());
        let labels_f32: Vec<f32> =
            d2.labels.iter().map(|&l| l as f32).collect();
        kv.register_partitioned("label", &labels_f32, 1, policy.as_ref());
        let mut client = kv.client(0, policy);
        if cache_budget_bytes > 0 {
            client.attach_cache(FeatureCache::new(
                "feat",
                cache_budget_bytes,
                CacheAdmission::All,
                None,
            ));
        }

        let spec = ShapeSpec {
            name: spec_d.name.clone(),
            model: if hetero { ModelKind::Rgcn } else { ModelKind::Sage },
            task: TaskKind::NodeClassification,
            batch,
            fanouts: vec![3, 3],
            layer_nodes: vec![
                (batch * 16).next_multiple_of(128),
                (batch * 4).next_multiple_of(128),
                batch.next_multiple_of(128),
            ],
            feat_dim: d.feat_dim,
            num_classes: d.num_classes,
            num_rels: spec_d.num_rels,
        };
        let plan = FanoutPlan::from_schema(&d2.schema, &spec.fanouts);
        let etype_keys = etype_metric_keys(spec.num_rels);
        let train: Vec<NodeId> = (0..n_train as NodeId).collect();
        BatchGen {
            spec,
            scheduler: BatchScheduler::for_nodes(train, batch, 3),
            sampler,
            kv: client,
            seed: 11,
            pos: 0,
            eval_pos: 0,
            plan,
            features,
            label_name: "label".into(),
            metrics: Arc::new(Metrics::new()),
            etype_keys,
            pool: BatchPool::default(),
            label_scratch: Vec::new(),
            frontier_scratch: Vec::new(),
        }
    }

    /// Build a [`HostBatch`] whose block structure comes from *real*
    /// neighbor sampling over a generated graph (single machine), with
    /// random features/labels. This is the batch source for device /
    /// executable tests — relation ids are the sampled ones, never
    /// synthesized (the old `rand_batch` fabricated them from an RNG,
    /// which silently trained RGCN on noise relations).
    pub fn sampled_batch(
        spec: &crate::runtime::manifest::VariantSpec,
        seed: u64,
    ) -> HostBatch {
        sampled_shape_batch(&spec.shape_spec(), seed)
    }

    /// [`sampled_batch`] for a bare [`ShapeSpec`].
    pub fn sampled_shape_batch(shape: &ShapeSpec, seed: u64) -> HostBatch {
        let mut dspec = DatasetSpec::new("dev-sampled", 4000, 16_000);
        dspec.num_rels = shape.num_rels;
        dspec.seed = seed ^ 0x5EED;
        let d: Dataset = dspec.generate();
        let n = d.n_nodes();
        let p = Partitioning { nparts: 1, assign: vec![0; n] };
        let r = relabel::relabel(&p);
        let d2 = relabel::relabel_dataset(&d, &r);
        let parts = build_partitions(&d2.graph, &r.node_map);
        let servers: Vec<Arc<SamplerServer>> = parts
            .into_iter()
            .map(|pp| Arc::new(SamplerServer::new(0, Arc::new(pp))))
            .collect();
        let sampler = DistNeighborSampler::new(
            0,
            servers,
            Arc::new(r.node_map),
            Arc::new(CostModel::default()),
        );
        let mut rng = Rng::new(seed);
        let targets: Vec<NodeId> =
            (0..shape.batch.min(n) as NodeId).collect();
        let plan = FanoutPlan::from_schema(&d2.schema, &shape.fanouts);
        let samples = sampler
            .sample_blocks(&targets, &plan, &shape.layer_nodes, &mut rng)
            .expect("single-machine sampling cannot fail");
        let block = to_block(shape, &samples);
        let n0 = shape.layer_nodes[0];
        let f = shape.feat_dim;
        let nl = *shape.layer_nodes.last().unwrap();
        HostBatch {
            feats: (0..n0 * f).map(|_| rng.normal() as f32).collect(),
            layers: block.layers,
            labels: (0..nl)
                .map(|_| {
                    rng.below(shape.num_classes.max(1) as u64) as i32
                })
                .collect(),
            label_mask: vec![1.0; nl],
            pair_mask: vec![1.0; shape.batch],
            targets: block.targets,
            input_nodes: block.input_nodes,
            remote_rows: 0,
            dropped_neighbors: block.dropped_neighbors,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::{tiny_gen, tiny_gen_hetero, tiny_gen_parts};
    use super::*;

    #[test]
    fn batch_has_consistent_shapes() {
        let mut gen = tiny_gen(64, 16);
        let b = gen.next();
        let spec = &gen.spec;
        assert_eq!(b.feats.len(), spec.layer_nodes[0] * spec.feat_dim);
        assert_eq!(b.layers.len(), 2);
        assert_eq!(b.targets.len(), 16);
        assert_eq!(b.labels.len(), *spec.layer_nodes.last().unwrap());
        // label mask marks exactly the real targets
        let real: f32 = b.label_mask.iter().sum();
        assert_eq!(real as usize, 16);
    }

    #[test]
    fn features_match_source_rows() {
        let mut gen = tiny_gen(64, 16);
        let b = gen.next();
        // targets occupy the first slots of the final layer; their features
        // flow from input_nodes — verify the first input row is non-zero
        // (generated features are dense gaussians, all-zero would mean a
        // broken pull)
        let f = gen.spec.feat_dim;
        let nz = b.feats[..f].iter().filter(|&&x| x != 0.0).count();
        assert!(nz > f / 2);
    }

    #[test]
    fn epoch_covers_all_train_nodes() {
        let mut gen = tiny_gen(64, 16);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..gen.batches_per_epoch() {
            let b = gen.next();
            seen.extend(b.targets.iter().copied());
        }
        assert_eq!(seen.len(), 64);
    }

    fn batch_fields(b: &HostBatch) -> (Vec<f32>, Vec<i32>, Vec<u32>) {
        (b.feats.clone(), b.labels.clone(), b.targets.clone())
    }

    #[test]
    fn cached_gen_is_byte_identical_to_uncached() {
        // same seeds, 2 machines; one gen caches remote features, the
        // other doesn't — every batch must match byte for byte, and the
        // cache must actually get hits across two epochs
        let mut plain = tiny_gen_parts(128, 16, 2, 0);
        let mut cached = tiny_gen_parts(128, 16, 2, 8 << 20);
        let steps = 2 * plain.batches_per_epoch();
        let mut total_fetched_plain = 0usize;
        let mut total_fetched_cached = 0usize;
        for step in 0..steps {
            let a = plain.next();
            let b = cached.next();
            assert_eq!(batch_fields(&a), batch_fields(&b), "step {step}");
            assert_eq!(a.label_mask, b.label_mask, "step {step}");
            total_fetched_plain += a.remote_rows;
            total_fetched_cached += b.remote_rows;
        }
        let stats = cached.kv.cache_stats().unwrap();
        assert!(stats.hit_rows > 0, "cache never hit: {stats:?}");
        assert!(
            total_fetched_cached < total_fetched_plain,
            "cache did not reduce remote fetches \
             ({total_fetched_cached} vs {total_fetched_plain})"
        );
    }

    /// The tentpole invariant at the generator level: running the
    /// lookahead (`prefetch_batch`) ahead of demand changes no batch
    /// byte, while the demand pulls hit the prefetched rows.
    #[test]
    fn prefetched_gen_is_byte_identical_and_demand_hits() {
        let mut plain = tiny_gen_parts(128, 16, 2, 0);
        let mut pre = tiny_gen_parts(128, 16, 2, 8 << 20);
        let mut look = pre.fork_worker(); // the prefetcher's private fork
        let steps = plain.batches_per_epoch();
        for g in 0..steps as u64 {
            look.prefetch_batch(g, g == 0).unwrap();
        }
        for step in 0..steps {
            let a = plain.next();
            let b = pre.next();
            assert_eq!(batch_fields(&a), batch_fields(&b), "step {step}");
            assert_eq!(a.label_mask, b.label_mask, "step {step}");
        }
        let s = pre.kv.cache_stats().unwrap();
        assert!(s.prefetch_issued > 0, "lookahead never pulled: {s:?}");
        assert!(s.prefetch_hits > 0, "prefetched rows never hit: {s:?}");
        assert!(
            s.pinned_rows > 0,
            "imminent-batch rows were never pinned: {s:?}"
        );
        // the demand epoch re-fetched nothing the lookahead staged
        assert!(s.hit_rows >= s.prefetch_hits);
    }

    #[test]
    fn epoch_covers_all_train_nodes_with_cache_enabled() {
        let mut gen = tiny_gen_parts(64, 16, 2, 8 << 20);
        for _epoch in 0..2 {
            let mut seen = std::collections::BTreeSet::new();
            for _ in 0..gen.batches_per_epoch() {
                let b = gen.next();
                seen.extend(b.targets.iter().copied());
            }
            assert_eq!(seen.len(), 64);
        }
    }

    #[test]
    fn recycled_batches_are_byte_identical() {
        // recycling returned buffers must not change any produced batch
        let mut fresh = tiny_gen(64, 16);
        let mut pooled = tiny_gen(64, 16);
        for step in 0..8 {
            let a = fresh.next();
            let b = pooled.next();
            assert_eq!(batch_fields(&a), batch_fields(&b), "step {step}");
            assert_eq!(a.label_mask, b.label_mask, "step {step}");
            assert_eq!(a.pair_mask, b.pair_mask, "step {step}");
            pooled.recycle(b); // buffers reused by the next batch
        }
        assert!(!pooled.pool.is_empty());
    }

    /// Regression for the old `rand_batch` bug: the relation ids a batch
    /// carries must be exactly the ones the sampler drew, never
    /// synthesized. Re-runs the sampler with a cloned RNG and compares
    /// every real edge slot of every layer.
    #[test]
    fn batch_rel_ids_equal_sampled_rels() {
        let mut gen = tiny_gen_hetero(64, 16, 1, 0);
        // batch (epoch 0, idx 0): re-derive its pure-function stream to
        // probe what the sampler drew
        let target = gen.scheduler.batch_at(0, 0);
        let flat = target.flat_nodes();
        let mut probe_rng = BatchGen::batch_rng(gen.seed, 0, 0);
        let samples = gen
            .sampler
            .sample_blocks(
                &flat,
                &gen.plan,
                &gen.spec.layer_nodes,
                &mut probe_rng,
            )
            .unwrap();
        let batch = gen.next();
        let l_total = gen.spec.fanouts.len();
        let mut real_edges = 0usize;
        let mut nonzero_rels = 0usize;
        for (j, (_, nbrs)) in samples.iter().enumerate() {
            let l = l_total - j; // samples are outermost-first
            let lb = &batch.layers[l - 1];
            let k = gen.spec.fanouts[l - 1];
            for (i, s) in nbrs.iter().enumerate() {
                for kk in 0..s.nbrs.len().min(k) {
                    if lb.nbr_mask[i * k + kk] > 0.0 {
                        assert_eq!(
                            lb.rel[i * k + kk],
                            s.rels[kk] as i32,
                            "layer {l} row {i} slot {kk}"
                        );
                        real_edges += 1;
                        if s.rels[kk] > 0 {
                            nonzero_rels += 1;
                        }
                    }
                }
            }
        }
        assert!(real_edges > 0, "no real edges sampled");
        assert!(nonzero_rels > 0, "degenerate test: only rel-0 edges");
    }

    #[test]
    fn hetero_batch_respects_typed_tables_and_fanouts() {
        let mut gen = tiny_gen_hetero(64, 16, 1, 0);
        let b = gen.next();
        // typed run flows through per-ntype tables…
        assert_eq!(gen.features.names.len(), 2);
        assert!(gen.features.names[0].starts_with("feat."));
        // …and meters per-etype sampled-edge counts
        let mut etype_total = 0u64;
        for r in 0..gen.spec.num_rels {
            etype_total +=
                gen.metrics.counter(&format!("sampler.etype_edges.{r}"));
        }
        assert!(etype_total > 0, "no per-etype counters metered");
        // per-etype fanout caps hold per row in every layer
        for (l, lb) in b.layers.iter().enumerate() {
            let k = gen.spec.fanouts[l];
            let caps = gen.plan.layer(l + 1);
            let n_rows = lb.self_idx.len();
            for i in 0..n_rows {
                let mut counts = vec![0usize; gen.spec.num_rels];
                for kk in 0..k {
                    if lb.nbr_mask[i * k + kk] > 0.0 {
                        counts[lb.rel[i * k + kk] as usize] += 1;
                    }
                }
                for (r, &c) in counts.iter().enumerate() {
                    assert!(
                        c <= caps[r],
                        "layer {} row {i}: rel {r} has {c} > {}",
                        l + 1,
                        caps[r]
                    );
                }
            }
        }
    }

    #[test]
    fn hetero_cached_gen_is_byte_identical_to_uncached() {
        let mut plain = tiny_gen_hetero(128, 16, 2, 0);
        let mut cached = tiny_gen_hetero(128, 16, 2, 8 << 20);
        let steps = 2 * plain.batches_per_epoch();
        for step in 0..steps {
            let a = plain.next();
            let b = cached.next();
            assert_eq!(batch_fields(&a), batch_fields(&b), "step {step}");
            assert_eq!(a.label_mask, b.label_mask, "step {step}");
        }
        let stats = cached.kv.cache_stats().unwrap();
        assert!(stats.hit_rows > 0, "typed cache never hit: {stats:?}");
    }

    #[test]
    fn gen_meters_locality_counters() {
        let mut gen = tiny_gen_parts(64, 16, 2, 8 << 20);
        for _ in 0..2 * gen.batches_per_epoch() {
            let b = gen.next();
            gen.recycle(b);
        }
        let m = &gen.metrics;
        assert!(m.counter("kv.remote_rows") > 0);
        assert!(
            m.counter("cache.hit_rows") > 0,
            "warm epochs should hit the cache"
        );
        assert_eq!(
            m.counter("cache.hit_rows") + m.counter("cache.miss_rows"),
            m.counter("kv.remote_rows") + m.counter("cache.hit_rows"),
            "every miss is a fetched remote row"
        );
    }

    /// The worker-pool invariant at the generator level: forked workers
    /// materializing global batch indices in a scrambled order reproduce
    /// the sequential stream byte for byte (multi-partition, so remote
    /// sampling and pulls are on the path).
    #[test]
    fn forked_workers_reproduce_the_sequential_stream() {
        let mut seq = tiny_gen_parts(96, 16, 2, 0);
        let forks = [seq.fork_worker(), seq.fork_worker()];
        let n = 2 * seq.batches_per_epoch();
        let stream: Vec<HostBatch> =
            (0..n).map(|_| seq.next()).collect();
        let mut order: Vec<usize> = (0..n).collect();
        Rng::new(3).shuffle(&mut order);
        for (i, g) in order.into_iter().enumerate() {
            let mut w = forks[i % forks.len()].fork_worker();
            let b = w.batch_at(g as u64);
            assert_eq!(b, stream[g], "batch {g} diverged in a fork");
        }
    }

    #[test]
    fn eval_batches_do_not_perturb_the_training_stream() {
        let mut plain = tiny_gen(64, 16);
        let mut interleaved = tiny_gen(64, 16);
        for step in 0..4 {
            let a = plain.next();
            // eval between training batches draws from its own lane
            let _ = interleaved.materialize_nodes(&[1, 2, 3]);
            let b = interleaved.next();
            assert_eq!(a, b, "step {step}: eval perturbed the stream");
        }
    }

    #[test]
    fn pool_counters_meter_hits_misses_and_drops() {
        let metrics = Arc::new(Metrics::new());
        let pool = BatchPool::with_capacity(2);
        pool.attach_metrics(metrics.clone());
        pool.ensure_cap(1); // never shrinks
        assert_eq!(pool.cap(), 2);
        let a = pool.take(); // miss (empty)
        let b = pool.take(); // miss
        pool.put(a);
        pool.put(b);
        pool.put(HostBatch::default()); // over cap: dropped
        assert_eq!(metrics.counter("pool.miss"), 2);
        assert_eq!(metrics.counter("pool.dropped"), 1);
        let _ = pool.take(); // hit
        assert_eq!(metrics.counter("pool.hit"), 1);
        assert_eq!(pool.len(), 1);
    }

    #[test]
    fn per_stage_timers_are_metered() {
        let mut gen = tiny_gen_parts(64, 16, 2, 0);
        let metrics = Arc::new(Metrics::new());
        gen.metrics = metrics.clone();
        for _ in 0..2 {
            let _ = gen.next();
        }
        for stage in [
            "pipeline.schedule",
            "pipeline.sample",
            "pipeline.pull",
            "pipeline.compact",
        ] {
            assert!(
                metrics.total_time(stage) > std::time::Duration::ZERO,
                "{stage} never metered"
            );
        }
    }
}
