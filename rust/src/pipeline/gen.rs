//! BatchGen: stages 1–4 of the pipeline for one trainer — schedule
//! targets, sample multi-hop neighbors through the distributed sampler,
//! compact to the padded block layout, and pull features/labels from the
//! KVStore into a ready-to-transfer [`HostBatch`].

use std::sync::Arc;

use crate::graph::NodeId;
use crate::kvstore::KvClient;
use crate::runtime::executable::HostBatch;
use crate::sampler::compact::{to_block, ShapeSpec, TaskKind};
use crate::sampler::{BatchScheduler, DistNeighborSampler, Target};
use crate::util::Rng;

pub struct BatchGen {
    pub spec: ShapeSpec,
    pub scheduler: BatchScheduler,
    pub sampler: Arc<DistNeighborSampler>,
    pub kv: KvClient,
    pub rng: Rng,
    /// Name of the feature tensor in the KVStore.
    pub feat_name: String,
    /// Name of the label tensor (dim-1 f32 rows); empty = no labels (lp).
    pub label_name: String,
}

impl BatchGen {
    pub fn batches_per_epoch(&self) -> usize {
        self.scheduler.batches_per_epoch()
    }

    /// Produce one fully materialized mini-batch (stages 1–4).
    pub fn next(&mut self) -> HostBatch {
        // stage 1: schedule
        let target = self.scheduler.next_batch();
        self.materialize(&target)
    }

    /// Stages 2–4 for an explicit target set (shared by train/eval paths).
    pub fn materialize(&mut self, target: &Target) -> HostBatch {
        let spec = &self.spec;
        let flat = target.flat_nodes();
        // stage 2: distributed neighbor sampling
        let samples = self.sampler.sample_blocks(
            &flat,
            &spec.fanouts,
            &spec.layer_nodes,
            &mut self.rng,
        );
        // stage 4 (compaction; paper runs this on GPU, order is the same)
        let block = to_block(spec, &samples);

        // stage 3: CPU prefetch — features for the deduped input frontier.
        // §Perf: only the padding tail needs zeroing; the real rows are
        // fully overwritten by the pull below.
        let n0 = spec.layer_nodes[0];
        let f = spec.feat_dim;
        let real = block.input_nodes.len().min(n0);
        let mut feats: Vec<f32> = Vec::with_capacity(n0 * f);
        #[allow(clippy::uninit_vec)]
        unsafe {
            feats.set_len(n0 * f);
        }
        feats[real * f..].fill(0.0);
        let remote_rows = self.kv.pull(
            &self.feat_name,
            &block.input_nodes[..real],
            &mut feats[..real * f],
        );

        // labels / masks for the targets
        let n_l = *spec.layer_nodes.last().unwrap();
        let (labels, label_mask, pair_mask) = match spec.task {
            TaskKind::NodeClassification => {
                let mut lab_rows = vec![0f32; block.targets.len()];
                self.kv.pull(
                    &self.label_name,
                    &block.targets,
                    &mut lab_rows,
                );
                let mut labels = vec![0i32; n_l];
                let mut mask = vec![0f32; n_l];
                for (i, &l) in lab_rows.iter().enumerate() {
                    labels[i] = l as i32;
                    mask[i] = 1.0;
                }
                (labels, mask, Vec::new())
            }
            TaskKind::LinkPrediction => {
                let n_pairs = target.n_items();
                let mut pm = vec![0f32; spec.batch];
                for m in pm.iter_mut().take(n_pairs) {
                    *m = 1.0;
                }
                (Vec::new(), Vec::new(), pm)
            }
        };

        HostBatch {
            feats,
            layers: block.layers,
            labels,
            label_mask,
            pair_mask,
            targets: block.targets,
            remote_rows,
            dropped_neighbors: block.dropped_neighbors,
        }
    }

    /// Eval-batch generator over a fixed node list (validation/test).
    pub fn materialize_nodes(&mut self, nodes: &[NodeId]) -> HostBatch {
        self.materialize(&Target::Nodes(nodes.to_vec()))
    }
}

/// Test-support constructors (single machine, tiny dataset).
pub mod tests_support {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::kvstore::{KvCluster, RangePolicy};
    use crate::net::CostModel;
    use crate::partition::{build_partitions, NodeMap, Partitioning};
    use crate::sampler::compact::ModelKind;
    use crate::sampler::SamplerServer;

    /// Single-machine BatchGen over a generated graph: `n_train` targets,
    /// given batch size, 2 layers of fanout 3, small dims.
    pub fn tiny_gen(n_train: usize, batch: usize) -> BatchGen {
        let spec_d = DatasetSpec::new("tiny", 1000, 4000);
        let d = spec_d.generate();
        let n = d.n_nodes();
        let p = Partitioning { nparts: 1, assign: vec![0; n] };
        let r = crate::partition::relabel::relabel(&p);
        let g = crate::partition::relabel::relabel_graph(&d.graph, &r);
        let parts = build_partitions(&g, &r.node_map);
        let servers: Vec<Arc<SamplerServer>> = parts
            .into_iter()
            .map(|pp| Arc::new(SamplerServer::new(0, Arc::new(pp))))
            .collect();
        let cost = Arc::new(CostModel::default());
        let node_map = Arc::new(NodeMap {
            part_starts: r.node_map.part_starts.clone(),
        });
        let sampler = Arc::new(DistNeighborSampler::new(
            0,
            servers,
            node_map.clone(),
            cost.clone(),
        ));
        let kv = KvCluster::new(1, cost);
        let policy = Arc::new(RangePolicy::new(NodeMap {
            part_starts: node_map.part_starts.clone(),
        }));
        kv.register_partitioned("feat", &d.feats, d.feat_dim, policy.as_ref());
        let labels_f32: Vec<f32> =
            d.labels.iter().map(|&l| l as f32).collect();
        kv.register_partitioned("label", &labels_f32, 1, policy.as_ref());
        let client = kv.client(0, policy);

        let spec = ShapeSpec {
            name: "tiny".into(),
            model: ModelKind::Sage,
            task: TaskKind::NodeClassification,
            batch,
            fanouts: vec![3, 3],
            layer_nodes: vec![
                (batch * 16).next_multiple_of(128),
                (batch * 4).next_multiple_of(128),
                batch.next_multiple_of(128),
            ],
            feat_dim: d.feat_dim,
            num_classes: d.num_classes,
            num_rels: 1,
        };
        let train: Vec<NodeId> = (0..n_train as NodeId).collect();
        BatchGen {
            spec,
            scheduler: BatchScheduler::for_nodes(train, batch, 3),
            sampler,
            kv: client,
            rng: Rng::new(11),
            feat_name: "feat".into(),
            label_name: "label".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::tiny_gen;

    #[test]
    fn batch_has_consistent_shapes() {
        let mut gen = tiny_gen(64, 16);
        let b = gen.next();
        let spec = &gen.spec;
        assert_eq!(b.feats.len(), spec.layer_nodes[0] * spec.feat_dim);
        assert_eq!(b.layers.len(), 2);
        assert_eq!(b.targets.len(), 16);
        assert_eq!(b.labels.len(), *spec.layer_nodes.last().unwrap());
        // label mask marks exactly the real targets
        let real: f32 = b.label_mask.iter().sum();
        assert_eq!(real as usize, 16);
    }

    #[test]
    fn features_match_source_rows() {
        let mut gen = tiny_gen(64, 16);
        let b = gen.next();
        // targets occupy the first slots of the final layer; their features
        // flow from input_nodes — verify the first input row is non-zero
        // (generated features are dense gaussians, all-zero would mean a
        // broken pull)
        let f = gen.spec.feat_dim;
        let nz = b.feats[..f].iter().filter(|&&x| x != 0.0).count();
        assert!(nz > f / 2);
    }

    #[test]
    fn epoch_covers_all_train_nodes() {
        let mut gen = tiny_gen(64, 16);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..gen.batches_per_epoch() {
            let b = gen.next();
            seen.extend(b.targets.iter().copied());
        }
        assert_eq!(seen.len(), 64);
    }
}
