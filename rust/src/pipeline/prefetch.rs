//! Predictive prefetcher: warm the [`FeatureCache`] with *future*
//! batches' remote rows before demand (ROADMAP item 2, the MassiveGNN
//! direction).
//!
//! Since PR 5 every batch is a pure function of `(seed, epoch, idx)`:
//! the scheduler's target set and the sampler's neighbor draws for
//! global batch `g` can be recomputed by anyone holding a fork of the
//! deployment, without consuming any live randomness. The prefetcher
//! exploits exactly that: a background thread owns a
//! [`BatchGen`] fork and walks a lookahead frontier over the window
//! `[cursor, cursor + depth)`, where `cursor` tracks the demand side's
//! next batch index ([`PrefetchCtl::advance_to`], bumped by the
//! sampling workers as they claim indices). For each lookahead batch it
//! re-derives the schedule + sampler stream, materializes the remote
//! part of the layer-0 frontier, and pulls it per owner into the shared
//! cache ([`KvClient::prefetch_typed`]) — deduped against cache
//! contents and in-flight prefetches, admission-scored like any insert,
//! and metered as `cache.prefetch_*`.
//!
//! Rows belonging to *imminent* batches (the next two demand indices)
//! are pinned so the CLOCK hand cannot evict them between prefetch and
//! use; the demand-side `lookup` releases the pin. Everything else is
//! ordinary cache traffic the CLOCK hand may reclaim.
//!
//! Correctness: the prefetcher never touches the batch stream — it
//! holds its own scheduler clone and sampler fork, so the demand side's
//! batches are byte-identical with prefetch on or off (test-enforced at
//! the loader and e2e levels). In strict embedding mode the cache is
//! also value-transparent, so losses and params are unchanged. RPC
//! errors (injected outages) are swallowed here: a failed prefetch just
//! leaves rows cold for the demand path to fetch — and to surface the
//! error deterministically, if it persists.
//!
//! [`FeatureCache`]: crate::kvstore::FeatureCache
//! [`KvClient::prefetch_typed`]: crate::kvstore::KvClient::prefetch_typed

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::BatchGen;

/// How far ahead of `cursor` a row must be needed to count as
/// *imminent* (and get pinned): the batch in flight plus the next one.
const PIN_WINDOW: u64 = 2;

/// Parking nap when the frontier has caught up with the window.
const IDLE_NAP: Duration = Duration::from_micros(200);

/// Shared demand cursor + stop flag between the pipeline and its
/// prefetch thread. Lock-free: the demand side only ever publishes a
/// monotonically increasing cursor.
pub struct PrefetchCtl {
    /// The demand side's next unclaimed global batch index.
    cursor: AtomicU64,
    stop: AtomicBool,
}

impl PrefetchCtl {
    pub fn new(start: u64) -> Arc<Self> {
        Arc::new(Self {
            cursor: AtomicU64::new(start),
            stop: AtomicBool::new(false),
        })
    }

    /// Publish demand progress: the next demand batch index is at least
    /// `g`. Monotonic (`fetch_max`), so out-of-order worker claims are
    /// harmless.
    pub fn advance_to(&self, g: u64) {
        self.cursor.fetch_max(g, Ordering::AcqRel);
    }

    pub fn cursor(&self) -> u64 {
        self.cursor.load(Ordering::Acquire)
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::Release);
    }

    fn stopped(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }
}

/// Handle over the background lookahead thread. Dropping the owning
/// [`Pipeline`] stops and joins it ([`Prefetcher::shutdown`]).
///
/// [`Pipeline`]: crate::pipeline::Pipeline
pub struct Prefetcher {
    ctl: Arc<PrefetchCtl>,
    handle: Option<JoinHandle<()>>,
}

impl Prefetcher {
    /// Launch the lookahead thread over a private [`BatchGen`] fork.
    /// `depth` must be ≥ 1 (the pipeline gates depth 0 off entirely).
    pub fn spawn(mut gen: BatchGen, depth: usize, start: u64) -> Prefetcher {
        assert!(depth >= 1);
        let ctl = PrefetchCtl::new(start);
        let tctl = ctl.clone();
        let handle = std::thread::Builder::new()
            .name("prefetch".into())
            .spawn(move || {
                let mut frontier = start;
                while !tctl.stopped() {
                    let cursor = tctl.cursor();
                    // never prefetch behind demand — those rows are
                    // being fetched (or already were) by the workers
                    if frontier < cursor {
                        frontier = cursor;
                    }
                    if frontier >= cursor.saturating_add(depth as u64) {
                        std::thread::sleep(IDLE_NAP);
                        continue;
                    }
                    let g = frontier;
                    frontier += 1;
                    let pin = g < cursor.saturating_add(PIN_WINDOW);
                    let t = Instant::now();
                    // errors leave rows cold; the demand path fetches
                    // them and surfaces persistent faults itself
                    let _ = gen.prefetch_batch(g, pin);
                    gen.metrics.add_time("pipeline.prefetch", t.elapsed());
                }
            })
            .expect("spawn prefetch thread");
        Prefetcher { ctl, handle: Some(handle) }
    }

    /// The shared cursor, for the demand side to publish progress.
    pub fn ctl(&self) -> Arc<PrefetchCtl> {
        self.ctl.clone()
    }

    /// [`PrefetchCtl::advance_to`] without cloning the handle (the
    /// Sync-mode per-batch path).
    pub fn advance_to(&self, g: u64) {
        self.ctl.advance_to(g);
    }

    /// Raise stop and join the thread (idempotent).
    pub fn shutdown(&mut self) {
        self.ctl.stop();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::gen::tests_support::tiny_gen_parts;

    #[test]
    fn prefetcher_warms_the_cache_ahead_of_demand() {
        let gen = tiny_gen_parts(128, 16, 2, 8 << 20);
        let mut demand = gen.fork_worker();
        let mut pf = Prefetcher::spawn(gen, 4, 0);
        // wait for the lookahead window [0, 4) to be materialized
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let s = demand.kv.cache_stats().unwrap();
            if s.prefetch_issued > 0 || Instant::now() > deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let issued = demand.kv.cache_stats().unwrap().prefetch_issued;
        assert!(issued > 0, "prefetcher never issued a pull");
        // demand now consumes batch 0: its remote rows are resident
        let b = demand.batch_at(0);
        assert!(
            demand.kv.cache_stats().unwrap().prefetch_hits > 0,
            "prefetched rows never hit"
        );
        assert!(!b.input_nodes.is_empty());
        pf.shutdown();
        pf.shutdown(); // idempotent
    }

    #[test]
    fn cursor_advances_monotonically() {
        let ctl = PrefetchCtl::new(3);
        ctl.advance_to(7);
        ctl.advance_to(5); // stale worker claim: ignored
        assert_eq!(ctl.cursor(), 7);
    }
}
