//! Asynchronous mini-batch generation pipeline (§5.5, Figure 7).
//!
//! Stages: (1) mini-batch scheduling → (2) distributed neighbor sampling →
//! (3) CPU prefetch (feature pull from the KVStore) → (4) subgraph
//! compaction → (5) GPU prefetch (bounded hand-off to the training
//! thread). Stages 1–4 run in a dedicated *sampling thread* per trainer;
//! the hand-off queue depth models the paper's "only one mini-batch ahead
//! of time on the GPU" memory constraint, while the sampling thread itself
//! works `cpu_prefetch_depth` batches ahead.
//!
//! Modes reproduce the Fig 14 ablation:
//! - [`PipelineMode::Sync`]: everything inline in the training thread
//!   (DistDGL-v1 behaviour).
//! - [`PipelineMode::Async`]: sampling thread overlaps with training, but
//!   *pauses at epoch boundaries* (pipeline refill cost each epoch).
//! - [`PipelineMode::AsyncNonstop`]: the paper's non-stop pipeline — the
//!   sampling thread free-runs across epochs.

pub mod gen;

pub use gen::{BatchGen, BatchPool};

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;

use crate::metrics::Metrics;
use crate::runtime::executable::HostBatch;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineMode {
    Sync,
    Async,
    AsyncNonstop,
}

#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub mode: PipelineMode,
    /// Mini-batches the sampling thread may run ahead (stage 1-4 depth).
    pub cpu_prefetch_depth: usize,
    /// Mini-batches staged for the device (stage 5 depth; paper: 1).
    pub gpu_prefetch_depth: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            mode: PipelineMode::AsyncNonstop,
            cpu_prefetch_depth: 4,
            gpu_prefetch_depth: 1,
        }
    }
}

enum Ctl {
    /// Produce `n` more batches (Async mode: one epoch's worth at a time).
    Produce(usize),
    Stop,
}

/// Trainer-facing handle: `next()` yields the next ready mini-batch.
pub struct Pipeline {
    mode: PipelineMode,
    // async modes
    rx: Option<Receiver<HostBatch>>,
    ctl: Option<SyncSender<Ctl>>,
    pending: usize,
    epoch_len: usize,
    // sync mode
    gen: Option<BatchGen>,
    metrics: Arc<Metrics>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Pipeline {
    /// Launch (or inline) the pipeline for one trainer.
    pub fn start(
        mut gen: BatchGen,
        cfg: &PipelineConfig,
        metrics: Arc<Metrics>,
    ) -> Pipeline {
        // per-batch locality/cache counters land in the shared instance
        gen.metrics = metrics.clone();
        let epoch_len = gen.batches_per_epoch();
        match cfg.mode {
            PipelineMode::Sync => Pipeline {
                mode: cfg.mode,
                rx: None,
                ctl: None,
                pending: 0,
                epoch_len,
                gen: Some(gen),
                metrics,
                handle: None,
            },
            PipelineMode::Async | PipelineMode::AsyncNonstop => {
                let (tx, rx) = sync_channel::<HostBatch>(
                    cfg.cpu_prefetch_depth + cfg.gpu_prefetch_depth,
                );
                let (ctl_tx, ctl_rx) = sync_channel::<Ctl>(8);
                let nonstop = cfg.mode == PipelineMode::AsyncNonstop;
                let thread_metrics = metrics.clone();
                let handle = std::thread::Builder::new()
                    .name("sampling".into())
                    .spawn(move || {
                        let metrics = thread_metrics;
                        if nonstop {
                            // free-running: produce until the receiver drops
                            loop {
                                let b = metrics
                                    .time("pipeline.sample", || gen.next());
                                metrics.inc("pipeline.batches", 1);
                                if tx.send(b).is_err() {
                                    return;
                                }
                            }
                        }
                        // stop-at-epoch mode: wait for Produce(n) grants
                        while let Ok(Ctl::Produce(n)) = ctl_rx.recv() {
                            for _ in 0..n {
                                let b = metrics
                                    .time("pipeline.sample", || gen.next());
                                metrics.inc("pipeline.batches", 1);
                                if tx.send(b).is_err() {
                                    return;
                                }
                            }
                        }
                    })
                    .expect("spawn sampling thread");
                Pipeline {
                    mode: cfg.mode,
                    rx: Some(rx),
                    ctl: Some(ctl_tx),
                    pending: 0,
                    epoch_len,
                    gen: None,
                    metrics,
                    handle: Some(handle),
                }
            }
        }
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.epoch_len
    }

    /// Fetch the next mini-batch (blocking).
    pub fn next(&mut self) -> HostBatch {
        match self.mode {
            PipelineMode::Sync => {
                let gen = self.gen.as_mut().unwrap();
                let m = &self.metrics;
                m.inc("pipeline.batches", 1);
                m.time("pipeline.sample", || gen.next())
            }
            PipelineMode::AsyncNonstop => self
                .rx
                .as_ref()
                .unwrap()
                .recv()
                .expect("sampling thread died"),
            PipelineMode::Async => {
                if self.pending == 0 {
                    // epoch boundary: grant the next epoch (pipeline must
                    // refill from empty — the startup overhead the
                    // non-stop mode removes)
                    self.ctl
                        .as_ref()
                        .unwrap()
                        .send(Ctl::Produce(self.epoch_len))
                        .expect("sampling thread died");
                    self.pending = self.epoch_len;
                }
                self.pending -= 1;
                self.rx
                    .as_ref()
                    .unwrap()
                    .recv()
                    .expect("sampling thread died")
            }
        }
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        if let Some(ctl) = &self.ctl {
            let _ = ctl.try_send(Ctl::Stop);
        }
        self.rx.take(); // unblocks a sender stuck on a full queue
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::gen::tests_support::tiny_gen;

    fn run_mode(mode: PipelineMode) -> Vec<usize> {
        let gen = tiny_gen(64, 16); // 64 train nodes, batch 16
        let cfg = PipelineConfig { mode, ..Default::default() };
        let metrics = Arc::new(Metrics::new());
        let mut p = Pipeline::start(gen, &cfg, metrics);
        let epoch = p.batches_per_epoch();
        assert_eq!(epoch, 4);
        (0..2 * epoch).map(|_| p.next().targets.len()).collect()
    }

    #[test]
    fn all_modes_deliver_every_batch() {
        for mode in [
            PipelineMode::Sync,
            PipelineMode::Async,
            PipelineMode::AsyncNonstop,
        ] {
            let sizes = run_mode(mode);
            assert_eq!(sizes.len(), 8, "{mode:?}");
            assert!(sizes.iter().all(|&s| s == 16), "{mode:?}: {sizes:?}");
        }
    }

    #[test]
    fn async_pipeline_overlaps_production() {
        // the sampling thread should have batches ready before next() is
        // called: after a short sleep the queue must already be full
        let gen = tiny_gen(256, 16);
        let cfg = PipelineConfig {
            mode: PipelineMode::AsyncNonstop,
            cpu_prefetch_depth: 4,
            gpu_prefetch_depth: 1,
        };
        let metrics = Arc::new(Metrics::new());
        let mut p = Pipeline::start(gen, &cfg, metrics.clone());
        std::thread::sleep(std::time::Duration::from_millis(300));
        assert!(metrics.counter("pipeline.batches") >= 4);
        let t = std::time::Instant::now();
        let _ = p.next();
        assert!(
            t.elapsed() < std::time::Duration::from_millis(50),
            "first batch was not prefetched"
        );
    }

    #[test]
    fn pipeline_meters_locality_and_cache_counters() {
        use crate::pipeline::gen::tests_support::tiny_gen_parts;
        // 2 machines + a cache: the shared metrics must pick up the
        // per-batch kv/cache counters from the sampling thread
        let gen = tiny_gen_parts(64, 16, 2, 8 << 20);
        let cfg = PipelineConfig {
            mode: PipelineMode::Sync,
            ..Default::default()
        };
        let metrics = Arc::new(Metrics::new());
        let mut p = Pipeline::start(gen, &cfg, metrics.clone());
        for _ in 0..2 * p.batches_per_epoch() {
            let _ = p.next();
        }
        assert!(metrics.counter("kv.remote_rows") > 0);
        assert!(metrics.counter("cache.hit_rows") > 0);
        let _ = metrics.counter("sampler.dropped_neighbors"); // present
        assert!(metrics.report().contains("cache.hit_rows"));
    }

    #[test]
    fn dropping_pipeline_stops_thread() {
        let gen = tiny_gen(64, 16);
        let cfg = PipelineConfig::default();
        let p = Pipeline::start(gen, &cfg, Arc::new(Metrics::new()));
        drop(p); // must not hang
    }
}
