//! Asynchronous mini-batch generation pipeline (§5.5, Figure 7).
//!
//! Stages: (1) mini-batch scheduling → (2) distributed neighbor sampling →
//! (3) CPU prefetch (feature pull from the KVStore) → (4) subgraph
//! compaction → (5) GPU prefetch (bounded hand-off to the training
//! thread). Stages 1–4 run in a pool of `num_workers` *sampling workers*
//! per trainer (DistDGL runs multiple sampling processes per trainer for
//! the same reason — remote round-trips hide behind each other): workers
//! claim global batch indices from a shared cursor, materialize them
//! independently (every batch's randomness is a pure function of
//! `(seed, epoch, idx)` — see [`gen`]), and deliver through an in-order
//! reassembly buffer ahead of the bounded stage-5 queue. The emitted
//! stream is **byte-identical for any worker count** (test-enforced).
//! The stage-5 queue depth models the paper's "only one mini-batch ahead
//! of time on the GPU" memory constraint, while the workers together run
//! `cpu_prefetch_depth` batches ahead.
//!
//! Modes reproduce the Fig 14 ablation:
//! - [`PipelineMode::Sync`]: everything inline in the training thread
//!   (DistDGL-v1 behaviour).
//! - [`PipelineMode::Async`]: sampling workers overlap with training, but
//!   *pause at epoch boundaries* (pipeline refill cost each epoch) — the
//!   trainer grants one epoch's worth of batch indices at a time.
//! - [`PipelineMode::AsyncNonstop`]: the paper's non-stop pipeline — the
//!   workers free-run across epochs, bounded only by the queue depths.
//!
//! Shutdown is explicit for every mode and worker count: dropping the
//! [`Pipeline`] raises a stop flag (waking any worker parked on the
//! grant condvar), closes the hand-off queue (waking any worker parked
//! on a full queue), and joins every thread.
//!
//! Fault tolerance (docs/DESIGN.md §8): the hand-off queues carry
//! `Result<HostBatch, RpcError>`. A worker that hits an unrecoverable
//! RPC failure forwards the typed error in stream order, raises stop,
//! and exits; the trainer sees `Err` from [`Pipeline::next`] and the
//! whole pool drains cleanly instead of panicking. [`Pipeline::start_at`]
//! resumes the stream at an arbitrary global batch index — because
//! batch `g` is a pure function of `(seed, g)`, a resumed pipeline is
//! byte-identical to an undisturbed one (test-enforced).

pub mod gen;
pub mod prefetch;

pub use gen::{BatchGen, BatchPool};
pub use prefetch::{PrefetchCtl, Prefetcher};

use std::collections::BTreeMap;
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};

use crate::metrics::Metrics;
use crate::net::RpcError;
use crate::runtime::executable::HostBatch;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineMode {
    Sync,
    Async,
    AsyncNonstop,
}

#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub mode: PipelineMode,
    /// Mini-batches the sampling workers may run ahead (stage 1-4 depth).
    pub cpu_prefetch_depth: usize,
    /// Mini-batches staged for the device (stage 5 depth; paper: 1).
    pub gpu_prefetch_depth: usize,
    /// Sampling workers per trainer (stage 1-4 parallelism; ≥ 1). The
    /// batch stream is byte-identical for any value — this is purely a
    /// throughput knob.
    pub num_workers: usize,
    /// Lookahead batches whose remote rows the predictive prefetcher
    /// pulls into the feature cache ahead of demand (see
    /// [`prefetch`]); `0` (default) disables the prefetch thread. The
    /// batch stream is byte-identical for any value — like
    /// `num_workers`, purely a throughput knob.
    pub prefetch_depth: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            mode: PipelineMode::AsyncNonstop,
            cpu_prefetch_depth: 4,
            gpu_prefetch_depth: 1,
            num_workers: 1,
            prefetch_depth: 0,
        }
    }
}

/// Worker-pool control plane: the shared batch-index cursor, the grant
/// watermark (Async mode produces one epoch per grant; non-stop is an
/// unbounded grant), the emitted watermark (bounds run-ahead: claims
/// stay within `max_ahead` of what has been delivered in order, so one
/// slow batch can never let the other workers buffer arbitrarily many
/// materialized batches in the reassembly stash), and the stop flag —
/// one mutex, one condvar.
struct WorkerCtl {
    state: Mutex<CtlState>,
    cv: Condvar,
    /// Max claimed-but-not-yet-emitted batches (`cpu_prefetch_depth` of
    /// run-ahead + one in-hand batch per worker).
    max_ahead: u64,
}

struct CtlState {
    /// Next unclaimed global batch index.
    next: u64,
    /// Claims are allowed while `next < granted`.
    granted: u64,
    /// Batches delivered in order to the stage-5 queue so far.
    emitted: u64,
    stop: bool,
}

impl WorkerCtl {
    fn new(start: u64, granted: u64, max_ahead: u64) -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new(CtlState {
                next: start,
                granted,
                emitted: start,
                stop: false,
            }),
            cv: Condvar::new(),
            max_ahead,
        })
    }

    /// Claim the next batch index, parking until one is granted and
    /// within the run-ahead window. `None` once the pipeline is
    /// stopping.
    fn claim(&self) -> Option<u64> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.stop {
                return None;
            }
            if st.next < st.granted
                && st.next < st.emitted.saturating_add(self.max_ahead)
            {
                let g = st.next;
                st.next += 1;
                return Some(g);
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Allow `n` more batches to be claimed (Async epoch grant).
    fn grant(&self, n: usize) {
        let mut st = self.state.lock().unwrap();
        st.granted = st.granted.saturating_add(n as u64);
        self.cv.notify_all();
    }

    /// One more batch left the reassembly stage in order — widen the
    /// claim window.
    fn on_emitted(&self) {
        let mut st = self.state.lock().unwrap();
        st.emitted += 1;
        self.cv.notify_all();
    }

    fn stop(&self) {
        self.state.lock().unwrap().stop = true;
        self.cv.notify_all();
    }
}

/// Trainer-facing handle: `next()` yields the next ready mini-batch.
pub struct Pipeline {
    mode: PipelineMode,
    // async modes
    rx: Option<Receiver<Result<HostBatch, RpcError>>>,
    ctl: Option<Arc<WorkerCtl>>,
    pending: usize,
    /// Size of the next Async grant: a partial epoch right after
    /// `start_at` (so grants realign with epoch boundaries), a full
    /// epoch from then on.
    next_grant: usize,
    epoch_len: usize,
    // sync mode
    gen: Option<BatchGen>,
    metrics: Arc<Metrics>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Background lookahead thread (`prefetch_depth > 0`); stopped and
    /// joined on drop.
    prefetcher: Option<Prefetcher>,
}

impl Pipeline {
    /// Launch (or inline) the pipeline for one trainer.
    pub fn start(
        gen: BatchGen,
        cfg: &PipelineConfig,
        metrics: Arc<Metrics>,
    ) -> Pipeline {
        Self::start_at(gen, cfg, metrics, 0)
    }

    /// Launch the pipeline with the stream cursor at global batch
    /// `start` — the exact-resume entry point (docs/DESIGN.md §8).
    /// `start_at(k)` then `next()` yields precisely the batches a fresh
    /// pipeline yields after `k` `next()` calls.
    pub fn start_at(
        mut gen: BatchGen,
        cfg: &PipelineConfig,
        metrics: Arc<Metrics>,
        start: u64,
    ) -> Pipeline {
        // per-batch locality/cache/pool counters land in the shared
        // instance; the recycling pool must hold one spare per producer
        // plus the prefetch run-ahead to keep recycling effective
        gen.metrics = metrics.clone();
        gen.pool.attach_metrics(metrics.clone());
        let n_workers = cfg.num_workers.max(1);
        gen.pool.ensure_cap(n_workers + cfg.cpu_prefetch_depth);
        let epoch_len = gen.batches_per_epoch();
        gen.pos = start;
        // lookahead thread: a private BatchGen fork walks the window
        // [demand cursor, cursor + prefetch_depth), warming the shared
        // feature cache; the demand side publishes its cursor below
        let prefetcher = (cfg.prefetch_depth > 0).then(|| {
            Prefetcher::spawn(gen.fork_worker(), cfg.prefetch_depth, start)
        });
        let pctl = prefetcher.as_ref().map(|p| p.ctl());
        // Async grants realign with epoch boundaries: finish the
        // partial epoch `start` lands in, then grant whole epochs
        let first_grant =
            epoch_len - (start as usize) % epoch_len.max(1);
        match cfg.mode {
            PipelineMode::Sync => Pipeline {
                mode: cfg.mode,
                rx: None,
                ctl: None,
                pending: 0,
                next_grant: epoch_len,
                epoch_len,
                gen: Some(gen),
                metrics,
                handles: Vec::new(),
                prefetcher,
            },
            PipelineMode::Async | PipelineMode::AsyncNonstop => {
                let nonstop = cfg.mode == PipelineMode::AsyncNonstop;
                let ctl = WorkerCtl::new(
                    start,
                    if nonstop { u64::MAX } else { start },
                    (cfg.cpu_prefetch_depth + n_workers) as u64,
                );
                let mut handles = Vec::with_capacity(n_workers + 1);
                let rx = if n_workers == 1 {
                    // single worker: claims come out in order, no
                    // reassembly needed — one queue of the full depth
                    let (tx, rx) =
                        sync_channel::<Result<HostBatch, RpcError>>(
                            (cfg.cpu_prefetch_depth
                                + cfg.gpu_prefetch_depth)
                                .max(1),
                        );
                    let ctl = ctl.clone();
                    let metrics = metrics.clone();
                    let pctl = pctl.clone();
                    handles.push(
                        std::thread::Builder::new()
                            .name("sampling".into())
                            .spawn(move || {
                                while let Some(g) = ctl.claim() {
                                    if let Some(p) = &pctl {
                                        p.advance_to(g + 1);
                                    }
                                    match gen.try_batch_at(g) {
                                        Ok(b) => {
                                            metrics.inc(
                                                "pipeline.batches",
                                                1,
                                            );
                                            if tx.send(Ok(b)).is_err() {
                                                return;
                                            }
                                            ctl.on_emitted();
                                        }
                                        Err(e) => {
                                            // unrecoverable: forward the
                                            // typed error, stop the pool
                                            let _ = tx.send(Err(e));
                                            ctl.stop();
                                            return;
                                        }
                                    }
                                }
                            })
                            .expect("spawn sampling worker"),
                    );
                    rx
                } else {
                    // worker pool: (index, batch) pairs flow to a
                    // reassembly thread that restores stream order ahead
                    // of the bounded stage-5 queue
                    let (wtx, wrx) = sync_channel::<(
                        u64,
                        Result<HostBatch, RpcError>,
                    )>(
                        cfg.cpu_prefetch_depth.max(1)
                    );
                    let (tx, rx) =
                        sync_channel::<Result<HostBatch, RpcError>>(
                            cfg.gpu_prefetch_depth.max(1),
                        );
                    let mut gens = Vec::with_capacity(n_workers);
                    for _ in 1..n_workers {
                        gens.push(gen.fork_worker());
                    }
                    gens.push(gen);
                    for (w, mut g) in gens.into_iter().enumerate() {
                        let ctl = ctl.clone();
                        let metrics = metrics.clone();
                        let wtx = wtx.clone();
                        let pctl = pctl.clone();
                        handles.push(
                            std::thread::Builder::new()
                                .name(format!("sampling-{w}"))
                                .spawn(move || {
                                    while let Some(idx) = ctl.claim() {
                                        if let Some(p) = &pctl {
                                            p.advance_to(idx + 1);
                                        }
                                        match g.try_batch_at(idx) {
                                            Ok(b) => {
                                                metrics.inc(
                                                    "pipeline.batches",
                                                    1,
                                                );
                                                if wtx
                                                    .send((idx, Ok(b)))
                                                    .is_err()
                                                {
                                                    return;
                                                }
                                            }
                                            Err(e) => {
                                                let _ = wtx
                                                    .send((idx, Err(e)));
                                                ctl.stop();
                                                return;
                                            }
                                        }
                                    }
                                })
                                .expect("spawn sampling worker"),
                        );
                    }
                    drop(wtx); // emitter exits once every worker is gone
                    let emit_ctl = ctl.clone();
                    handles.push(
                        std::thread::Builder::new()
                            .name("reassembly".into())
                            .spawn(move || {
                                let ctl = emit_ctl;
                                // the stash never exceeds the ctl's
                                // run-ahead window: claims stall until
                                // `emitted` catches up
                                let mut expected = start;
                                let mut stash: BTreeMap<
                                    u64,
                                    Result<HostBatch, RpcError>,
                                > = BTreeMap::new();
                                while let Ok((idx, b)) = wrx.recv() {
                                    stash.insert(idx, b);
                                    while let Some(b) =
                                        stash.remove(&expected)
                                    {
                                        if tx.send(b).is_err() {
                                            return;
                                        }
                                        expected += 1;
                                        ctl.on_emitted();
                                    }
                                }
                                // workers stopped: flush the in-order tail
                                while let Some(b) = stash.remove(&expected)
                                {
                                    if tx.send(b).is_err() {
                                        return;
                                    }
                                    expected += 1;
                                    ctl.on_emitted();
                                }
                            })
                            .expect("spawn reassembly thread"),
                    );
                    rx
                };
                Pipeline {
                    mode: cfg.mode,
                    rx: Some(rx),
                    ctl: Some(ctl),
                    pending: 0,
                    next_grant: first_grant,
                    epoch_len,
                    gen: None,
                    metrics,
                    handles,
                    prefetcher,
                }
            }
        }
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.epoch_len
    }

    /// Fetch the next mini-batch (blocking). `Err` means an
    /// unrecoverable RPC failure (retries exhausted); the worker pool
    /// has already stopped and [`Drop`] will join it cleanly.
    pub fn next(&mut self) -> Result<HostBatch, RpcError> {
        match self.mode {
            PipelineMode::Sync => {
                let gen = self.gen.as_mut().unwrap();
                if let Some(p) = &self.prefetcher {
                    // publish demand progress: gen.pos is the index
                    // try_next is about to materialize
                    p.advance_to(gen.pos);
                }
                let b = gen.try_next()?;
                self.metrics.inc("pipeline.batches", 1);
                Ok(b)
            }
            PipelineMode::AsyncNonstop => {
                self.rx.as_ref().unwrap().recv().unwrap_or(Err(
                    RpcError::WorkerLost("sampling pipeline"),
                ))
            }
            PipelineMode::Async => {
                if self.pending == 0 {
                    // epoch boundary: grant the next epoch (pipeline must
                    // refill from empty — the startup overhead the
                    // non-stop mode removes)
                    let n = self.next_grant;
                    self.ctl.as_ref().unwrap().grant(n);
                    self.pending = n;
                    self.next_grant = self.epoch_len;
                }
                self.pending -= 1;
                self.rx.as_ref().unwrap().recv().unwrap_or(Err(
                    RpcError::WorkerLost("sampling pipeline"),
                ))
            }
        }
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        // explicit shutdown, any mode / worker count: raise stop (wakes
        // claim-parked workers), close the hand-off queue (wakes workers
        // parked on a full queue), then join everything
        if let Some(p) = &mut self.prefetcher {
            p.shutdown();
        }
        if let Some(ctl) = &self.ctl {
            ctl.stop();
        }
        self.rx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::gen::tests_support::{tiny_gen, tiny_gen_parts};

    fn run_mode(mode: PipelineMode, num_workers: usize) -> Vec<usize> {
        let gen = tiny_gen(64, 16); // 64 train nodes, batch 16
        let cfg =
            PipelineConfig { mode, num_workers, ..Default::default() };
        let metrics = Arc::new(Metrics::new());
        let mut p = Pipeline::start(gen, &cfg, metrics);
        let epoch = p.batches_per_epoch();
        assert_eq!(epoch, 4);
        (0..2 * epoch)
            .map(|_| p.next().unwrap().targets.len())
            .collect()
    }

    #[test]
    fn all_modes_deliver_every_batch_at_any_worker_count() {
        for mode in [
            PipelineMode::Sync,
            PipelineMode::Async,
            PipelineMode::AsyncNonstop,
        ] {
            for workers in [1, 3] {
                let sizes = run_mode(mode, workers);
                assert_eq!(sizes.len(), 8, "{mode:?} x{workers}");
                assert!(
                    sizes.iter().all(|&s| s == 16),
                    "{mode:?} x{workers}: {sizes:?}"
                );
            }
        }
    }

    /// The tentpole invariant at the pipeline level: the delivered stream
    /// is byte-identical for any worker count, in every async mode.
    #[test]
    fn worker_pool_streams_identical_batches() {
        for mode in [PipelineMode::Async, PipelineMode::AsyncNonstop] {
            let mk = |workers: usize| {
                let gen = tiny_gen_parts(96, 16, 2, 0);
                let cfg = PipelineConfig {
                    mode,
                    num_workers: workers,
                    ..Default::default()
                };
                Pipeline::start(gen, &cfg, Arc::new(Metrics::new()))
            };
            let mut one = mk(1);
            let mut four = mk(4);
            for step in 0..2 * one.batches_per_epoch() + 3 {
                assert_eq!(
                    one.next().unwrap(),
                    four.next().unwrap(),
                    "{mode:?}: stream diverged at step {step}"
                );
            }
        }
    }

    #[test]
    fn async_pipeline_overlaps_production() {
        // the sampling workers should have batches ready before next()
        // is called: after a short sleep the queue must already be full
        for workers in [1, 2] {
            let gen = tiny_gen(256, 16);
            let cfg = PipelineConfig {
                mode: PipelineMode::AsyncNonstop,
                cpu_prefetch_depth: 4,
                gpu_prefetch_depth: 1,
                num_workers: workers,
                prefetch_depth: 0,
            };
            let metrics = Arc::new(Metrics::new());
            let mut p = Pipeline::start(gen, &cfg, metrics.clone());
            std::thread::sleep(std::time::Duration::from_millis(300));
            assert!(metrics.counter("pipeline.batches") >= 4);
            let t = std::time::Instant::now();
            let _ = p.next().unwrap();
            assert!(
                t.elapsed() < std::time::Duration::from_millis(50),
                "first batch was not prefetched (x{workers})"
            );
        }
    }

    #[test]
    fn pipeline_meters_locality_and_cache_counters() {
        // 2 machines + a cache: the shared metrics must pick up the
        // per-batch kv/cache counters from the sampling thread
        let gen = tiny_gen_parts(64, 16, 2, 8 << 20);
        let cfg = PipelineConfig {
            mode: PipelineMode::Sync,
            ..Default::default()
        };
        let metrics = Arc::new(Metrics::new());
        let mut p = Pipeline::start(gen, &cfg, metrics.clone());
        for _ in 0..2 * p.batches_per_epoch() {
            let _ = p.next().unwrap();
        }
        assert!(metrics.counter("kv.remote_rows") > 0);
        assert!(metrics.counter("cache.hit_rows") > 0);
        let _ = metrics.counter("sampler.dropped_neighbors"); // present
        assert!(metrics.report().contains("cache.hit_rows"));
    }

    #[test]
    fn per_stage_timers_flow_through_the_pipeline() {
        let gen = tiny_gen(64, 16);
        let cfg = PipelineConfig::default();
        let metrics = Arc::new(Metrics::new());
        let mut p = Pipeline::start(gen, &cfg, metrics.clone());
        for _ in 0..p.batches_per_epoch() {
            let _ = p.next().unwrap();
        }
        for stage in [
            "pipeline.schedule",
            "pipeline.sample",
            "pipeline.pull",
            "pipeline.compact",
        ] {
            assert!(
                metrics.total_time(stage) > std::time::Duration::ZERO,
                "{stage} not metered through the async pipeline"
            );
        }
    }

    /// Shutdown must be prompt for every mode and worker count, even
    /// dropped mid-epoch with the hand-off queue full and workers parked
    /// on it (the old control-plane bug: `AsyncNonstop` never read its
    /// ctl channel, shutdown relied on the queue teardown alone).
    #[test]
    fn dropping_pipeline_mid_epoch_stops_all_workers() {
        for mode in [
            PipelineMode::Sync,
            PipelineMode::Async,
            PipelineMode::AsyncNonstop,
        ] {
            for workers in [1, 4] {
                let gen = tiny_gen(256, 16);
                let cfg = PipelineConfig {
                    mode,
                    num_workers: workers,
                    ..Default::default()
                };
                let metrics = Arc::new(Metrics::new());
                let mut p = Pipeline::start(gen, &cfg, metrics);
                // consume one batch so async modes are mid-epoch, then
                // give the workers time to fill every queue
                let _ = p.next().unwrap();
                if mode != PipelineMode::Sync {
                    std::thread::sleep(
                        std::time::Duration::from_millis(100),
                    );
                }
                drop(p); // must not hang (joins every thread)
            }
        }
    }

    #[test]
    fn dropping_pipeline_stops_thread() {
        let gen = tiny_gen(64, 16);
        let cfg = PipelineConfig::default();
        let p = Pipeline::start(gen, &cfg, Arc::new(Metrics::new()));
        drop(p); // must not hang
    }

    #[test]
    fn async_mode_produces_only_granted_epochs() {
        // stop-at-epoch: without a grant (no next() call), workers must
        // not produce anything
        let gen = tiny_gen(64, 16);
        let cfg = PipelineConfig {
            mode: PipelineMode::Async,
            num_workers: 2,
            ..Default::default()
        };
        let metrics = Arc::new(Metrics::new());
        let mut p = Pipeline::start(gen, &cfg, metrics.clone());
        std::thread::sleep(std::time::Duration::from_millis(100));
        assert_eq!(
            metrics.counter("pipeline.batches"),
            0,
            "Async workers produced without a grant"
        );
        let epoch = p.batches_per_epoch();
        for _ in 0..epoch {
            let _ = p.next().unwrap();
        }
        // exactly one epoch granted → at most one epoch produced
        std::thread::sleep(std::time::Duration::from_millis(100));
        assert_eq!(metrics.counter("pipeline.batches"), epoch as u64);
    }

    /// Exact resume at the pipeline level (docs/DESIGN.md §8):
    /// `start_at(k)` must continue the stream precisely where a straight
    /// run left off — every mode, multiple worker counts, across the
    /// next epoch boundary (which exercises the partial Async grant).
    #[test]
    fn start_at_resumes_the_exact_stream() {
        for mode in [
            PipelineMode::Sync,
            PipelineMode::Async,
            PipelineMode::AsyncNonstop,
        ] {
            for workers in [1, 4] {
                let cfg = PipelineConfig {
                    mode,
                    num_workers: workers,
                    ..Default::default()
                };
                let k = 7u64; // mid-epoch (epoch_len = 6)
                let mut straight = Pipeline::start(
                    tiny_gen_parts(96, 16, 2, 0),
                    &cfg,
                    Arc::new(Metrics::new()),
                );
                for _ in 0..k {
                    let _ = straight.next().unwrap();
                }
                let mut resumed = Pipeline::start_at(
                    tiny_gen_parts(96, 16, 2, 0),
                    &cfg,
                    Arc::new(Metrics::new()),
                    k,
                );
                for step in 0..9 {
                    assert_eq!(
                        straight.next().unwrap(),
                        resumed.next().unwrap(),
                        "{mode:?} x{workers}: resumed stream diverged \
                         at step {step} past batch {k}"
                    );
                }
            }
        }
    }

    /// Prefetch byte-identity at the pipeline level: a prefetching
    /// pipeline (cache + lookahead thread) must deliver the exact
    /// stream of an uncached, unprefetched one — every mode — while
    /// the lookahead demonstrably issues pulls ahead of demand.
    #[test]
    fn prefetching_pipeline_streams_identical_batches() {
        for mode in [
            PipelineMode::Sync,
            PipelineMode::Async,
            PipelineMode::AsyncNonstop,
        ] {
            let base_cfg = PipelineConfig {
                mode,
                ..Default::default()
            };
            let pre_cfg = PipelineConfig {
                mode,
                prefetch_depth: 8,
                ..Default::default()
            };
            let mut plain = Pipeline::start(
                tiny_gen_parts(96, 16, 2, 0),
                &base_cfg,
                Arc::new(Metrics::new()),
            );
            let metrics = Arc::new(Metrics::new());
            let mut pre = Pipeline::start(
                tiny_gen_parts(96, 16, 2, 8 << 20),
                &pre_cfg,
                metrics.clone(),
            );
            for step in 0..2 * plain.batches_per_epoch() {
                assert_eq!(
                    plain.next().unwrap(),
                    pre.next().unwrap(),
                    "{mode:?}: prefetch changed the stream at step {step}"
                );
            }
            drop(pre); // joins the lookahead thread
            assert!(
                metrics.counter("cache.prefetch_issued") > 0,
                "{mode:?}: the lookahead thread never pulled"
            );
        }
    }

    /// Drop-mid-epoch with the lookahead thread actively prefetching:
    /// shutdown must stop and join the prefetcher promptly for every
    /// mode and worker count (satellite: drain test with prefetch in
    /// flight).
    #[test]
    fn dropping_pipeline_with_prefetch_in_flight_joins_cleanly() {
        for mode in [
            PipelineMode::Sync,
            PipelineMode::Async,
            PipelineMode::AsyncNonstop,
        ] {
            for workers in [1, 4] {
                let gen = tiny_gen_parts(256, 16, 2, 8 << 20);
                let cfg = PipelineConfig {
                    mode,
                    num_workers: workers,
                    prefetch_depth: 8,
                    ..Default::default()
                };
                let metrics = Arc::new(Metrics::new());
                let mut p = Pipeline::start(gen, &cfg, metrics);
                let _ = p.next().unwrap(); // mid-epoch, window open
                drop(p); // must join workers AND the prefetch thread
            }
        }
    }

    /// Satellite 2 (extends `dropping_pipeline_mid_epoch_stops_all_
    /// workers`): an *injected server failure* mid-epoch must surface
    /// as the typed error — not a panic — and the pool must drain
    /// cleanly on drop, for every mode and worker count.
    #[test]
    fn injected_failure_mid_epoch_drains_cleanly_in_every_mode() {
        use crate::ft::{FailWindow, FaultPlan};
        for mode in [
            PipelineMode::Sync,
            PipelineMode::Async,
            PipelineMode::AsyncNonstop,
        ] {
            for workers in [1, 4] {
                let gen = tiny_gen_parts(96, 16, 2, 0);
                let mut plan = FaultPlan::new();
                // machine 1's sampler dies after a few admitted RPCs:
                // the first batches succeed, then one fails mid-epoch
                plan.sampler_outages.push(FailWindow::permanent(1, 6));
                plan.backoff = std::time::Duration::ZERO;
                gen.sampler.set_fault_plan(Arc::new(plan));
                let cfg = PipelineConfig {
                    mode,
                    num_workers: workers,
                    ..Default::default()
                };
                let mut p = Pipeline::start(
                    gen,
                    &cfg,
                    Arc::new(Metrics::new()),
                );
                let mut saw_err = false;
                for _ in 0..4 * p.batches_per_epoch() {
                    match p.next() {
                        Ok(_) => {}
                        Err(e) => {
                            assert_eq!(
                                e,
                                RpcError::ServerDown {
                                    machine: 1,
                                    role: "sampler"
                                },
                                "{mode:?} x{workers}"
                            );
                            saw_err = true;
                            break;
                        }
                    }
                }
                assert!(
                    saw_err,
                    "{mode:?} x{workers}: injected outage never surfaced"
                );
                drop(p); // must join every worker without hanging
            }
        }
    }
}
