//! Distributed mini-batch sampling (§5.5.1).
//!
//! Vertex-wise neighbor sampling (the GraphSAGE algorithm the paper
//! optimizes): each seed samples ≤ K neighbors independently, recursively
//! per layer — and on typed graphs ≤ k_r neighbors *per relation r*, per
//! the [`FanoutPlan`](crate::graph::FanoutPlan) derived from the
//! [`GraphSchema`](crate::graph::GraphSchema) (homogeneous graphs use the
//! trivial 1-etype plan through the same code path). The trainer-side
//! [`DistNeighborSampler`] dispatches seed batches to owning machines
//! ([`SamplerServer`]s answer from their physical partition via the halo
//! closure — no server-to-server traffic), stitches frontiers, and
//! [`compact`] re-maps the sampled subgraph into the dense padded block
//! layout the AOT'd HLO expects (`to_block`), with relation-segmented
//! sections when the data is typed.

pub mod compact;
pub mod distributed;
pub mod neighbor;
pub mod schedule;
pub mod service;

pub use compact::{Block, LayerBlock};
pub use distributed::DistNeighborSampler;
pub use schedule::{BatchScheduler, Target};
pub use service::SamplerServer;
