//! Trainer-side distributed neighbor sampling: dispatch each layer's seed
//! set to owning machines, stitch the per-seed results back in order
//! (§5.5.1). Local seeds hit the local server through shared memory; remote
//! requests are batched per machine and metered.
//!
//! §Perf: per-owner requests are dispatched **concurrently** (scoped
//! threads, one per remote owner, the local shard on the calling thread)
//! so under `emulate_network_time` a layer's wall clock is the max over
//! owners instead of the sum. Each owner's RNG stream is derived up front
//! in owner order — the exact derivation the serial loop performs — so
//! sampled neighborhoods are bit-identical with concurrency on or off
//! (test-enforced).

use std::sync::{Arc, Mutex};

use rustc_hash::FxHashMap;

use crate::ft::FaultPlan;
use crate::graph::{FanoutPlan, NodeId};
use crate::net::{CostModel, RpcError};
use crate::partition::NodeMap;
use crate::util::Rng;

use super::service::{SampledNbrs, SamplerServer};

/// Reusable per-call buffers (§Perf: the per-layer grouping pass used to
/// allocate `nparts` vectors per call; now it reuses these across the
/// whole run). Behind a mutex only to keep the sampler `Sync` — each
/// trainer owns its own sampler, so the lock is uncontended.
#[derive(Default)]
struct SamplerScratch {
    /// Per-owner (seeds, original slots) grouping for `sample_layer`.
    groups: Vec<(Vec<NodeId>, Vec<usize>)>,
    /// Frontier dedup set for `sample_blocks`.
    seen: FxHashMap<NodeId, ()>,
}

pub struct DistNeighborSampler {
    pub machine: u32,
    servers: Vec<Arc<SamplerServer>>,
    node_map: Arc<NodeMap>,
    cost: Arc<CostModel>,
    pub emulate_network_time: bool,
    /// Dispatch per-owner requests concurrently (wall clock = max over
    /// owners under emulation). `false` restores the serial loop — byte
    /// metering and sampled neighborhoods are identical either way.
    pub concurrent_fanout: bool,
    scratch: Mutex<SamplerScratch>,
    /// Injected-fault schedule gating remote requests ([`fork`]ed
    /// handles share the installed plan). `None` = fault-free.
    ///
    /// [`fork`]: Self::fork
    fault: Mutex<Option<Arc<FaultPlan>>>,
}

impl DistNeighborSampler {
    pub fn new(
        machine: u32,
        servers: Vec<Arc<SamplerServer>>,
        node_map: Arc<NodeMap>,
        cost: Arc<CostModel>,
    ) -> Self {
        Self {
            machine,
            servers,
            node_map,
            cost,
            emulate_network_time: false,
            concurrent_fanout: true,
            scratch: Mutex::new(SamplerScratch::default()),
            fault: Mutex::new(None),
        }
    }

    /// Gate every subsequent remote sampling request through `plan`.
    pub fn set_fault_plan(&self, plan: Arc<FaultPlan>) {
        *self.fault.lock().unwrap() = Some(plan);
    }

    fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.fault.lock().unwrap().clone()
    }

    /// An independent handle over the same deployment for a sampling
    /// worker: shares the servers / node map / cost model, owns private
    /// scratch (the scratch mutex never contends across workers).
    pub fn fork(&self) -> Self {
        Self {
            machine: self.machine,
            servers: self.servers.clone(),
            node_map: self.node_map.clone(),
            cost: self.cost.clone(),
            emulate_network_time: self.emulate_network_time,
            concurrent_fanout: self.concurrent_fanout,
            scratch: Mutex::new(SamplerScratch::default()),
            fault: Mutex::new(self.fault.lock().unwrap().clone()),
        }
    }

    /// Meter (and, under emulation, sleep for) one remote owner's
    /// request/response round-trip.
    fn meter_remote(
        &self,
        owner: u32,
        n_seeds: usize,
        n_fanouts: usize,
        res: &[SampledNbrs],
    ) {
        let edges: usize = res.iter().map(|r| r.nbrs.len()).sum();
        let (req, resp) =
            SamplerServer::wire_cost(n_seeds, n_fanouts, edges);
        self.cost.on_network(self.machine, owner, req);
        self.cost.on_network(owner, self.machine, resp);
        if self.emulate_network_time {
            let secs = (req + resp) as f64 / self.cost.net_bytes_per_sec
                + 2.0 * self.cost.net_latency_s;
            // straggler emulation (docs/DESIGN.md §8)
            let secs =
                secs * self.cost.pair_slowdown(self.machine, owner);
            std::thread::sleep(std::time::Duration::from_secs_f64(secs));
        }
    }

    /// Sample one layer for `seeds` with per-etype fanouts (`&[k]` is the
    /// classic uniform sampler); result[i] belongs to seeds[i]. Remote
    /// requests are gated through the installed [`FaultPlan`] (if any):
    /// an unrecoverable injected outage surfaces as
    /// [`RpcError::ServerDown`] with the RNG stream fully consumed, so a
    /// retried batch after recovery samples the same neighborhoods.
    pub fn sample_layer(
        &self,
        seeds: &[NodeId],
        fanouts: &[usize],
        rng: &mut Rng,
    ) -> Result<Vec<SampledNbrs>, RpcError> {
        let nparts = self.servers.len();
        if nparts == 1 {
            // single machine: shared memory, nothing to inject
            return Ok(
                self.servers[0].sample_neighbors(seeds, fanouts, rng)
            );
        }
        // §Perf fast path: locality-aware splits make all-local seed sets
        // the common case — skip the grouping pass and its allocations.
        // (RNG stream matches the general path's owner-split derivation.)
        if seeds
            .iter()
            .all(|&s| self.node_map.owner(s) == self.machine)
        {
            let mut sub = rng.split(self.machine as u64);
            return Ok(self.servers[self.machine as usize]
                .sample_neighbors(seeds, fanouts, &mut sub));
        }
        // group seeds by owner, remembering original slots (reused
        // scratch, taken out of the lock so the dispatch below never
        // holds it)
        let mut groups = {
            let mut scratch = self.scratch.lock().unwrap();
            std::mem::take(&mut scratch.groups)
        };
        if groups.len() != nparts {
            groups.resize_with(nparts, Default::default);
        }
        for g in groups.iter_mut() {
            g.0.clear();
            g.1.clear();
        }
        for (slot, &s) in seeds.iter().enumerate() {
            let owner = self.node_map.owner(s) as usize;
            groups[owner].0.push(s);
            groups[owner].1.push(slot);
        }
        // derive every non-empty owner's independent stream up front, in
        // owner order — exactly the derivation the serial loop performs,
        // so results are bit-identical regardless of dispatch concurrency
        let mut subs: Vec<Option<Rng>> = groups
            .iter()
            .enumerate()
            .map(|(owner, (group, _))| {
                (!group.is_empty()).then(|| rng.split(owner as u64))
            })
            .collect();
        let n_remote = groups
            .iter()
            .enumerate()
            .filter(|(o, g)| *o as u32 != self.machine && !g.0.is_empty())
            .count();
        let fault = self.fault_plan();
        let mut results: Vec<Option<Vec<SampledNbrs>>> =
            (0..nparts).map(|_| None).collect();
        let mut err: Option<RpcError> = None;
        if self.concurrent_fanout && n_remote >= 2 {
            // concurrent fan-out: one thread per remote owner, the local
            // shard on the calling thread (overlapping the round-trips)
            std::thread::scope(|sc| {
                let fault_ref = &fault;
                let mut handles = Vec::with_capacity(n_remote);
                for (owner, sub) in subs.iter_mut().enumerate() {
                    if owner as u32 == self.machine {
                        continue;
                    }
                    let Some(sub) = sub.take() else { continue };
                    let group = &groups[owner].0;
                    handles.push((
                        owner,
                        sc.spawn(
                            move || -> Result<Vec<SampledNbrs>, RpcError> {
                                if let Some(f) = fault_ref {
                                    f.admit_sampler(owner as u32)?;
                                }
                                let mut sub = sub;
                                let res = self.servers[owner]
                                    .sample_neighbors(
                                        group, fanouts, &mut sub,
                                    );
                                self.meter_remote(
                                    owner as u32,
                                    group.len(),
                                    fanouts.len(),
                                    &res,
                                );
                                Ok(res)
                            },
                        ),
                    ));
                }
                let m = self.machine as usize;
                if let Some(mut sub) = subs[m].take() {
                    results[m] = Some(self.servers[m].sample_neighbors(
                        &groups[m].0,
                        fanouts,
                        &mut sub,
                    ));
                }
                for (owner, h) in handles {
                    match h.join() {
                        Ok(Ok(res)) => results[owner] = Some(res),
                        Ok(Err(e)) => {
                            err.get_or_insert(e);
                        }
                        Err(_) => {
                            err.get_or_insert(RpcError::WorkerLost(
                                "sampler fan-out",
                            ));
                        }
                    }
                }
            });
        } else {
            for (owner, sub) in subs.iter_mut().enumerate() {
                let Some(mut sub) = sub.take() else { continue };
                if owner as u32 != self.machine {
                    if let Some(f) = &fault {
                        if let Err(e) = f.admit_sampler(owner as u32) {
                            err = Some(e);
                            break;
                        }
                    }
                }
                let res = self.servers[owner].sample_neighbors(
                    &groups[owner].0,
                    fanouts,
                    &mut sub,
                );
                if owner as u32 != self.machine {
                    self.meter_remote(
                        owner as u32,
                        groups[owner].0.len(),
                        fanouts.len(),
                        &res,
                    );
                }
                results[owner] = Some(res);
            }
        }
        // stitch per-seed results back into request slot order
        let mut out: Vec<SampledNbrs> =
            vec![SampledNbrs::default(); seeds.len()];
        for (owner, res) in results.into_iter().enumerate() {
            let Some(res) = res else { continue };
            for (r, &slot) in res.into_iter().zip(&groups[owner].1) {
                out[slot] = r;
            }
        }
        self.scratch.lock().unwrap().groups = groups;
        match err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    /// Multi-layer expansion: returns per-layer (seeds, per-seed samples),
    /// outermost (targets, layer L) first. Each layer samples ≤ k_r
    /// neighbors per etype per the [`FanoutPlan`] (a uniform plan is the
    /// classic schedule). Each layer's frontier is the seed set ∪
    /// newly-sampled neighbors, deduped in seed-first order and **capped**
    /// at `layer_caps[l-1]` (= the block's padded node budget) using
    /// exactly the drop order `compact::to_block` applies, so the two stay
    /// in lock-step when a budget fills up.
    pub fn sample_blocks(
        &self,
        targets: &[NodeId],
        plan: &FanoutPlan,
        layer_caps: &[usize], // layer_nodes [n0, ..., nL]
        rng: &mut Rng,
    ) -> Result<Vec<(Vec<NodeId>, Vec<SampledNbrs>)>, RpcError> {
        let l_total = plan.num_layers();
        assert_eq!(layer_caps.len(), l_total + 1);
        let mut layers = Vec::with_capacity(l_total);
        let mut seeds: Vec<NodeId> = targets.to_vec();
        for j in 0..l_total {
            let fanouts = plan.layer(l_total - j); // layer L first
            let cap = layer_caps[l_total - 1 - j];
            let samples = self.sample_layer(&seeds, fanouts, rng)?;
            let mut next = seeds.clone();
            // dedup set comes from scratch (cleared, capacity retained)
            let mut scratch = self.scratch.lock().unwrap();
            let seen = &mut scratch.seen;
            seen.clear();
            seen.extend(seeds.iter().map(|&s| (s, ())));
            for s in &samples {
                for &n in &s.nbrs {
                    if seen.contains_key(&n) {
                        continue;
                    }
                    if next.len() >= cap {
                        continue; // budget exhausted: to_block masks it out
                    }
                    seen.insert(n, ());
                    next.push(n);
                }
            }
            drop(scratch);
            layers.push((seeds, samples));
            seeds = next;
        }
        Ok(layers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::partition::{
        build_partitions, metis_partition, relabel, PartitionConfig,
        VertexWeights,
    };

    fn setup(
        nparts: usize,
    ) -> (crate::graph::Graph, Arc<NodeMap>, Vec<Arc<SamplerServer>>, Arc<CostModel>)
    {
        let spec = DatasetSpec::new("ds", 1000, 4000);
        let d = spec.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        let p =
            metis_partition(&d.graph, &vw, &PartitionConfig::new(nparts));
        let r = relabel::relabel(&p);
        let g = relabel::relabel_graph(&d.graph, &r);
        let parts = build_partitions(&g, &r.node_map);
        let servers: Vec<Arc<SamplerServer>> = parts
            .into_iter()
            .enumerate()
            .map(|(m, p)| Arc::new(SamplerServer::new(m as u32, Arc::new(p))))
            .collect();
        let cost = Arc::new(CostModel::default());
        (g, Arc::new(r.node_map), servers, cost)
    }

    #[test]
    fn stitched_results_align_with_seeds() {
        let (g, nm, servers, cost) = setup(3);
        let s = DistNeighborSampler::new(0, servers, nm, cost);
        let seeds: Vec<NodeId> = vec![5, 500, 900, 17, 333];
        let res = s.sample_layer(&seeds, &[4], &mut Rng::new(9)).unwrap();
        assert_eq!(res.len(), seeds.len());
        for (seed, r) in seeds.iter().zip(&res) {
            for &n in &r.nbrs {
                assert!(g.neighbors(*seed).contains(&n));
            }
        }
    }

    #[test]
    fn remote_requests_metered_local_not() {
        let (_, nm, servers, cost) = setup(2);
        let s = DistNeighborSampler::new(0, servers, nm.clone(), cost.clone());
        // all-local seeds
        let local: Vec<NodeId> =
            (0..10).map(|l| nm.global_of(0, l)).collect();
        s.sample_layer(&local, &[3], &mut Rng::new(1)).unwrap();
        assert_eq!(cost.network_bytes(), 0);
        // all-remote seeds
        let remote: Vec<NodeId> =
            (0..10).map(|l| nm.global_of(1, l)).collect();
        s.sample_layer(&remote, &[3], &mut Rng::new(1)).unwrap();
        assert!(cost.network_bytes() > 0);
    }

    #[test]
    fn multilayer_frontier_includes_seeds() {
        let (_, nm, servers, cost) = setup(2);
        let s = DistNeighborSampler::new(0, servers, nm, cost);
        let targets: Vec<NodeId> = vec![1, 2, 3, 4];
        let layers = s
            .sample_blocks(
                &targets,
                &FanoutPlan::uniform(&[5, 5]),
                &[4096, 512, 64],
                &mut Rng::new(2),
            )
            .unwrap();
        assert_eq!(layers.len(), 2);
        // layer 0 (outermost) seeds are the targets
        assert_eq!(layers[0].0, targets);
        // the next layer's seeds start with the previous seeds
        assert_eq!(&layers[1].0[..targets.len()], &targets[..]);
        // every sampled neighbor of layer 0 appears in layer 1's seeds
        for s0 in &layers[0].1 {
            for n in &s0.nbrs {
                assert!(layers[1].0.contains(n));
            }
        }
    }

    #[test]
    fn deterministic_given_rng_seed() {
        let (_, nm, servers, cost) = setup(2);
        let s = DistNeighborSampler::new(0, servers, nm, cost);
        let targets: Vec<NodeId> = vec![10, 20, 30];
        let plan = FanoutPlan::uniform(&[4, 4]);
        let a = s
            .sample_blocks(&targets, &plan, &[1024, 128, 16], &mut Rng::new(7))
            .unwrap();
        let b = s
            .sample_blocks(&targets, &plan, &[1024, 128, 16], &mut Rng::new(7))
            .unwrap();
        for (la, lb) in a.iter().zip(&b) {
            assert_eq!(la.0, lb.0);
            for (x, y) in la.1.iter().zip(&lb.1) {
                assert_eq!(x.nbrs, y.nbrs);
            }
        }
    }

    /// The fan-out invariant: concurrent dispatch is bit-identical to the
    /// serial loop — same neighborhoods, same rels, same modeled bytes —
    /// across many seeds with ≥3 partitions (so several remote threads
    /// really contend).
    #[test]
    fn concurrent_fanout_is_bit_identical_to_serial() {
        let (_, nm, servers, _) = setup(4);
        let serial_cost = Arc::new(CostModel::default());
        let conc_cost = Arc::new(CostModel::default());
        let mut serial = DistNeighborSampler::new(
            0,
            servers.clone(),
            nm.clone(),
            serial_cost.clone(),
        );
        serial.concurrent_fanout = false;
        let conc =
            DistNeighborSampler::new(0, servers, nm, conc_cost.clone());
        assert!(conc.concurrent_fanout, "concurrency must be the default");
        for seed in 0..20u64 {
            let seeds: Vec<NodeId> = (0..300u32)
                .map(|i| (i * 31 + seed as NodeId * 7) % 1000)
                .collect();
            let a = serial
                .sample_layer(&seeds, &[5], &mut Rng::new(seed))
                .unwrap();
            let b = conc
                .sample_layer(&seeds, &[5], &mut Rng::new(seed))
                .unwrap();
            assert_eq!(a.len(), b.len());
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.nbrs, y.nbrs, "seed {seed} slot {i}");
                assert_eq!(x.rels, y.rels, "seed {seed} slot {i}");
            }
            // multi-layer expansion stays in lock-step too
            let plan = FanoutPlan::uniform(&[4, 3]);
            let caps = [2048usize, 256, 64];
            let la = serial
                .sample_blocks(
                    &seeds[..40],
                    &plan,
                    &caps,
                    &mut Rng::new(seed ^ 0xA5),
                )
                .unwrap();
            let lb = conc
                .sample_blocks(
                    &seeds[..40],
                    &plan,
                    &caps,
                    &mut Rng::new(seed ^ 0xA5),
                )
                .unwrap();
            for (x, y) in la.iter().zip(&lb) {
                assert_eq!(x.0, y.0, "seed {seed}");
                for (sx, sy) in x.1.iter().zip(&y.1) {
                    assert_eq!(sx.nbrs, sy.nbrs, "seed {seed}");
                }
            }
        }
        assert_eq!(
            serial_cost.network_bytes(),
            conc_cost.network_bytes(),
            "modeled bytes must not depend on dispatch concurrency"
        );
        assert_eq!(serial_cost.network_msgs(), conc_cost.network_msgs());
    }

    /// Repeated concurrent runs under thread-scheduling noise return the
    /// same result every time (no hidden ordering dependence).
    #[test]
    fn concurrent_fanout_is_stable_across_runs() {
        let (_, nm, servers, cost) = setup(3);
        let s = DistNeighborSampler::new(0, servers, nm, cost);
        let seeds: Vec<NodeId> = (0..500u32).map(|i| (i * 13) % 1000).collect();
        let baseline =
            s.sample_layer(&seeds, &[4], &mut Rng::new(42)).unwrap();
        for run in 0..10 {
            let again =
                s.sample_layer(&seeds, &[4], &mut Rng::new(42)).unwrap();
            for (i, (x, y)) in baseline.iter().zip(&again).enumerate() {
                assert_eq!(x.nbrs, y.nbrs, "run {run} slot {i}");
            }
        }
    }

    #[test]
    fn fork_samples_identically() {
        let (_, nm, servers, cost) = setup(3);
        let s = DistNeighborSampler::new(0, servers, nm, cost);
        let f = s.fork();
        let seeds: Vec<NodeId> = vec![5, 500, 900, 17, 333];
        let a = s.sample_layer(&seeds, &[4], &mut Rng::new(9)).unwrap();
        let b = f.sample_layer(&seeds, &[4], &mut Rng::new(9)).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.nbrs, y.nbrs);
            assert_eq!(x.rels, y.rels);
        }
    }

    #[test]
    fn transient_sampler_outage_heals_and_stays_deterministic() {
        use crate::ft::{FailWindow, FaultPlan};
        let (_, nm, servers, cost) = setup(2);
        let clean =
            DistNeighborSampler::new(0, servers.clone(), nm.clone(), cost);
        let faulty = DistNeighborSampler::new(
            0,
            servers,
            nm.clone(),
            Arc::new(CostModel::default()),
        );
        let mut plan = FaultPlan::new();
        plan.sampler_outages = vec![FailWindow::transient(1, 0, 2)];
        plan.backoff = std::time::Duration::ZERO;
        let plan = Arc::new(plan);
        faulty.set_fault_plan(plan.clone());
        let remote: Vec<NodeId> =
            (0..10).map(|l| nm.global_of(1, l)).collect();
        let a = clean
            .sample_layer(&remote, &[3], &mut Rng::new(5))
            .unwrap();
        let b = faulty
            .sample_layer(&remote, &[3], &mut Rng::new(5))
            .unwrap();
        assert!(plan.retries() >= 2, "outage must have cost retries");
        // retries must not perturb the sampled stream
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.nbrs, y.nbrs);
        }
    }

    #[test]
    fn permanent_sampler_outage_is_server_down_both_dispatch_modes() {
        use crate::ft::{FailWindow, FaultPlan};
        for concurrent in [false, true] {
            let (_, nm, servers, cost) = setup(3);
            let mut s = DistNeighborSampler::new(0, servers, nm, cost);
            s.concurrent_fanout = concurrent;
            let mut plan = FaultPlan::new();
            plan.sampler_outages = vec![FailWindow::permanent(1, 0)];
            plan.backoff = std::time::Duration::ZERO;
            s.set_fault_plan(Arc::new(plan));
            // wide seed set touches every partition → machine 1 is hit
            let seeds: Vec<NodeId> = (0..1000).step_by(3).collect();
            let err = s
                .sample_layer(&seeds, &[4], &mut Rng::new(11))
                .unwrap_err();
            assert_eq!(
                err,
                RpcError::ServerDown { machine: 1, role: "sampler" },
                "concurrent={concurrent}"
            );
            // a fork shares the plan: multi-layer expansion fails too,
            // as a value, not a panic
            let f = s.fork();
            let got = f.sample_blocks(
                &seeds[..20],
                &FanoutPlan::uniform(&[4, 4]),
                &[2048, 256, 32],
                &mut Rng::new(11),
            );
            assert!(matches!(
                got,
                Err(RpcError::ServerDown { machine: 1, role: "sampler" })
            ));
        }
    }

    #[test]
    fn hetero_plan_caps_each_etype_across_machines() {
        // typed dataset over 2 machines: every seed's sample respects the
        // per-etype budget regardless of which server answered
        let mut spec = DatasetSpec::new("dh", 1000, 6000);
        spec.num_rels = 3;
        let d = spec.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        let p = metis_partition(&d.graph, &vw, &PartitionConfig::new(2));
        let r = relabel::relabel(&p);
        let g = relabel::relabel_graph(&d.graph, &r);
        let parts = build_partitions(&g, &r.node_map);
        let servers: Vec<Arc<SamplerServer>> = parts
            .into_iter()
            .enumerate()
            .map(|(m, p)| Arc::new(SamplerServer::new(m as u32, Arc::new(p))))
            .collect();
        let cost = Arc::new(CostModel::default());
        let s = DistNeighborSampler::new(
            0,
            servers,
            Arc::new(r.node_map),
            cost,
        );
        let seeds: Vec<NodeId> = (0..400).step_by(7).collect();
        let fanouts = [2usize, 2, 1];
        let res =
            s.sample_layer(&seeds, &fanouts, &mut Rng::new(3)).unwrap();
        assert_eq!(res.len(), seeds.len());
        for (seed, sn) in seeds.iter().zip(&res) {
            assert_eq!(sn.rels.len(), sn.nbrs.len());
            let mut counts = [0usize; 3];
            for &rel in &sn.rels {
                counts[rel as usize] += 1;
            }
            for (rel, &c) in counts.iter().enumerate() {
                assert!(c <= fanouts[rel], "seed {seed} rel {rel}: {c}");
            }
            for &n in &sn.nbrs {
                assert!(g.neighbors(*seed).contains(&n));
            }
        }
    }
}
