//! Mini-batch scheduling (pipeline stage 1, §5.5): per-epoch shuffling of
//! the trainer's assigned training items and target construction for both
//! tasks — node classification (seed nodes) and link prediction (positive
//! edges + uniform negative tails, rows laid out [heads | tails | negs]).
//!
//! The schedule is a **pure function of `(seed, epoch, batch_idx)`**: the
//! epoch permutation and each batch's negative tails are derived with
//! [`Rng::for_path`] instead of a sequential mutable RNG stream, so any
//! sampling worker can compute any batch independently
//! ([`BatchScheduler::batch_at`]) and the emitted stream is identical for
//! every worker count. The classic sequential
//! [`BatchScheduler::next_batch`] is a thin cursor over the same function.

use std::sync::Arc;

use crate::graph::NodeId;
use crate::util::Rng;

/// Stream lanes under the scheduler seed (see [`Rng::for_path`]).
const LANE_SHUFFLE: u64 = 0x5C;
const LANE_NEG: u64 = 0x4E;

/// Targets of one mini-batch, ready for multi-layer sampling.
#[derive(Clone, Debug)]
pub enum Target {
    /// Node classification: seed vertices.
    Nodes(Vec<NodeId>),
    /// Link prediction: (heads, tails, negative tails), equal lengths.
    Edges {
        heads: Vec<NodeId>,
        tails: Vec<NodeId>,
        negs: Vec<NodeId>,
    },
}

impl Target {
    /// Flat node list in the layer-L slot order the block contract expects.
    pub fn flat_nodes(&self) -> Vec<NodeId> {
        match self {
            Target::Nodes(v) => v.clone(),
            Target::Edges { heads, tails, negs } => {
                let mut v =
                    Vec::with_capacity(heads.len() + tails.len() + negs.len());
                v.extend_from_slice(heads);
                v.extend_from_slice(tails);
                v.extend_from_slice(negs);
                v
            }
        }
    }

    pub fn n_items(&self) -> usize {
        match self {
            Target::Nodes(v) => v.len(),
            Target::Edges { heads, .. } => heads.len(),
        }
    }
}

/// Per-trainer epoch scheduler over its assigned training items.
///
/// Clone-able: the item lists are shared (`Arc`), and all schedule state
/// is derived on demand from `(seed, epoch, batch_idx)`, so every clone
/// yields the exact same batches — a worker pool hands each worker a
/// clone and coordinates only on *which* global batch index to produce.
#[derive(Clone)]
pub struct BatchScheduler {
    /// Node-classification: assigned train vertices. Link-prediction:
    /// assigned (head, tail) edges.
    items_nodes: Arc<Vec<NodeId>>,
    items_edges: Arc<Vec<(NodeId, NodeId)>>,
    pub batch_size: usize,
    /// Negative-sampling id range (all graph vertices).
    pub n_nodes_total: u64,
    seed: u64,
    /// Re-permute the item order at each epoch boundary (training
    /// default). `false` keeps the given item order every epoch
    /// (evaluation / offline inference).
    shuffle: bool,
    /// Skip the short trailing batch of each epoch (DGL's `drop_last`).
    /// Only effective while at least one full batch exists — a seed set
    /// smaller than `batch_size` still yields its single short batch.
    drop_last: bool,
    /// Sequential cursor for [`Self::next_batch`] (global batch index).
    pos: u64,
    /// Cached permutation for `cached_epoch` (pure recomputation — kept
    /// only to avoid re-shuffling on every `batch_at` of the same epoch).
    cached_epoch: u64,
    order: Vec<u32>,
}

impl BatchScheduler {
    pub fn for_nodes(items: Vec<NodeId>, batch_size: usize, seed: u64) -> Self {
        Self::for_nodes_opts(items, batch_size, seed, true, false)
    }

    /// [`Self::for_nodes`] with explicit `shuffle` / `drop_last` behavior
    /// (the data-loader knobs; the defaults reproduce the classic
    /// training stream byte for byte).
    pub fn for_nodes_opts(
        items: Vec<NodeId>,
        batch_size: usize,
        seed: u64,
        shuffle: bool,
        drop_last: bool,
    ) -> Self {
        let mut s = Self {
            items_nodes: Arc::new(items),
            items_edges: Arc::new(Vec::new()),
            batch_size,
            n_nodes_total: 0,
            seed,
            shuffle,
            drop_last,
            pos: 0,
            cached_epoch: 0,
            order: Vec::new(),
        };
        s.order = s.epoch_order(0);
        s
    }

    pub fn for_edges(
        items: Vec<(NodeId, NodeId)>,
        batch_size: usize,
        n_nodes_total: u64,
        seed: u64,
    ) -> Self {
        Self::for_edges_opts(items, batch_size, n_nodes_total, seed, true, false)
    }

    /// [`Self::for_edges`] with explicit `shuffle` / `drop_last` behavior.
    pub fn for_edges_opts(
        items: Vec<(NodeId, NodeId)>,
        batch_size: usize,
        n_nodes_total: u64,
        seed: u64,
        shuffle: bool,
        drop_last: bool,
    ) -> Self {
        let mut s = Self {
            items_nodes: Arc::new(Vec::new()),
            items_edges: Arc::new(items),
            batch_size,
            n_nodes_total,
            seed,
            shuffle,
            drop_last,
            pos: 0,
            cached_epoch: 0,
            order: Vec::new(),
        };
        s.order = s.epoch_order(0);
        s
    }

    pub fn n_items(&self) -> usize {
        if self.items_nodes.is_empty() {
            self.items_edges.len()
        } else {
            self.items_nodes.len()
        }
    }

    /// Batches per epoch: the last short batch is included unless
    /// `drop_last` is set (and a full batch exists at all).
    pub fn batches_per_epoch(&self) -> usize {
        let n = self.n_items();
        if self.drop_last && n >= self.batch_size {
            n / self.batch_size
        } else {
            n.div_ceil(self.batch_size)
        }
    }

    /// The item permutation of `epoch` — a pure function of
    /// `(seed, epoch)`.
    fn epoch_order(&self, epoch: u64) -> Vec<u32> {
        let mut order: Vec<u32> = (0..self.n_items() as u32).collect();
        if self.shuffle {
            Rng::for_path(self.seed, &[epoch, LANE_SHUFFLE])
                .shuffle(&mut order);
        }
        order
    }

    fn ensure_epoch(&mut self, epoch: u64) {
        if self.cached_epoch != epoch || self.order.len() != self.n_items() {
            self.order = self.epoch_order(epoch);
            self.cached_epoch = epoch;
        }
    }

    /// Mini-batch `idx` of `epoch` — a pure function of
    /// `(seed, epoch, idx)`; `idx` must be `< batches_per_epoch()`.
    /// `&mut self` only maintains the cached permutation.
    pub fn batch_at(&mut self, epoch: u64, idx: usize) -> Target {
        debug_assert!(idx < self.batches_per_epoch());
        self.ensure_epoch(epoch);
        let lo = idx * self.batch_size;
        let hi = (lo + self.batch_size).min(self.order.len());
        let idxs = &self.order[lo..hi];
        if !self.items_nodes.is_empty() {
            Target::Nodes(
                idxs.iter()
                    .map(|&i| self.items_nodes[i as usize])
                    .collect(),
            )
        } else {
            // negative tails come from this batch's own derived stream,
            // never from shared mutable state
            let mut rng = Rng::for_path(
                self.seed,
                &[epoch, idx as u64, LANE_NEG],
            );
            let mut heads = Vec::with_capacity(idxs.len());
            let mut tails = Vec::with_capacity(idxs.len());
            let mut negs = Vec::with_capacity(idxs.len());
            for &i in idxs {
                let (h, t) = self.items_edges[i as usize];
                heads.push(h);
                tails.push(t);
                negs.push(rng.below(self.n_nodes_total) as NodeId);
            }
            Target::Edges { heads, tails, negs }
        }
    }

    /// Next mini-batch of the sequential stream; wraps to a fresh
    /// (re-shuffled unless `shuffle` is off) epoch at the boundary,
    /// skipping the short tail batch when `drop_last` is set. Identical
    /// to walking [`Self::batch_at`] in `(epoch, idx)` order.
    pub fn next_batch(&mut self) -> Target {
        let bpe = self.batches_per_epoch().max(1) as u64;
        let (epoch, idx) = (self.pos / bpe, (self.pos % bpe) as usize);
        self.pos += 1;
        self.batch_at(epoch, idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_items_once_per_epoch() {
        let items: Vec<NodeId> = (0..100).collect();
        let mut s = BatchScheduler::for_nodes(items, 32, 1);
        let mut seen = Vec::new();
        for _ in 0..s.batches_per_epoch() {
            if let Target::Nodes(v) = s.next_batch() {
                seen.extend(v);
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn epochs_reshuffle() {
        let items: Vec<NodeId> = (0..64).collect();
        let mut s = BatchScheduler::for_nodes(items, 64, 2);
        let Target::Nodes(a) = s.next_batch() else { panic!() };
        let Target::Nodes(b) = s.next_batch() else { panic!() };
        assert_ne!(a, b, "two epochs produced identical order");
        let mut bs = b.clone();
        bs.sort_unstable();
        assert_eq!(bs, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn edge_batches_have_aligned_triples() {
        let edges: Vec<(NodeId, NodeId)> =
            (0..50).map(|i| (i, i + 100)).collect();
        let mut s = BatchScheduler::for_edges(edges, 16, 1000, 3);
        let Target::Edges { heads, tails, negs } = s.next_batch() else {
            panic!()
        };
        assert_eq!(heads.len(), 16);
        assert_eq!(tails.len(), 16);
        assert_eq!(negs.len(), 16);
        for (h, t) in heads.iter().zip(&tails) {
            assert_eq!(*t, *h + 100);
        }
        assert!(negs.iter().all(|&n| (n as u64) < 1000));
    }

    #[test]
    fn no_shuffle_keeps_item_order_every_epoch() {
        let items: Vec<NodeId> = (0..40).collect();
        let mut s = BatchScheduler::for_nodes_opts(items, 16, 9, false, false);
        for _epoch in 0..2 {
            let mut seen = Vec::new();
            for _ in 0..s.batches_per_epoch() {
                let Target::Nodes(v) = s.next_batch() else { panic!() };
                seen.extend(v);
            }
            assert_eq!(seen, (0..40).collect::<Vec<_>>());
        }
    }

    #[test]
    fn drop_last_skips_the_short_tail() {
        let items: Vec<NodeId> = (0..65).collect();
        let mut s = BatchScheduler::for_nodes_opts(items, 16, 4, true, true);
        assert_eq!(s.batches_per_epoch(), 4); // floor(65/16), not ceil
        for _ in 0..3 * s.batches_per_epoch() {
            let Target::Nodes(v) = s.next_batch() else { panic!() };
            assert_eq!(v.len(), 16, "drop_last yielded a short batch");
        }
    }

    #[test]
    fn drop_last_with_tiny_seed_set_still_yields_batches() {
        // fewer items than batch_size: drop_last would starve the loader,
        // so the single short batch is kept
        let items: Vec<NodeId> = (0..5).collect();
        let mut s = BatchScheduler::for_nodes_opts(items, 16, 4, true, true);
        assert_eq!(s.batches_per_epoch(), 1);
        let Target::Nodes(v) = s.next_batch() else { panic!() };
        assert_eq!(v.len(), 5);
    }

    #[test]
    fn default_constructors_match_opted_defaults() {
        // the classic constructors must produce the byte-identical stream
        // of the explicit (shuffle=true, drop_last=false) form
        let a: Vec<NodeId> = (0..50).collect();
        let mut s1 = BatchScheduler::for_nodes(a.clone(), 16, 7);
        let mut s2 = BatchScheduler::for_nodes_opts(a, 16, 7, true, false);
        for _ in 0..2 * s1.batches_per_epoch() {
            let Target::Nodes(x) = s1.next_batch() else { panic!() };
            let Target::Nodes(y) = s2.next_batch() else { panic!() };
            assert_eq!(x, y);
        }
    }

    #[test]
    fn flat_nodes_layout_for_lp() {
        // the absolute [heads | tails | negs] row order is what to_block
        // and the lp pair masks assume — assert it directly, not through
        // flat_nodes-vs-flat_nodes comparisons
        let t = Target::Edges {
            heads: vec![1, 2],
            tails: vec![3, 4],
            negs: vec![5, 6],
        };
        assert_eq!(t.flat_nodes(), vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(t.n_items(), 2);
    }

    /// The worker-pool invariant at the scheduler level: random access by
    /// global batch index — in any order, from any clone — reproduces the
    /// sequential stream exactly, for both tasks.
    #[test]
    fn batch_at_matches_sequential_stream_in_any_order() {
        let nodes = BatchScheduler::for_nodes((0..70).collect(), 16, 11);
        let edges = BatchScheduler::for_edges(
            (0..70).map(|i| (i, i + 1)).collect(),
            16,
            500,
            11,
        );
        for mut seq in [nodes, edges] {
            let bpe = seq.batches_per_epoch() as u64;
            let mut ra = seq.clone();
            let stream: Vec<Target> =
                (0..3 * bpe).map(|_| seq.next_batch()).collect();
            // visit global indices in a scrambled order, as workers would
            let mut gs: Vec<u64> = (0..3 * bpe).collect();
            Rng::new(5).shuffle(&mut gs);
            for g in gs {
                let t = ra.batch_at(g / bpe, (g % bpe) as usize);
                assert_eq!(
                    t.flat_nodes(),
                    stream[g as usize].flat_nodes(),
                    "batch {g} diverged from the sequential stream"
                );
            }
        }
    }
}
