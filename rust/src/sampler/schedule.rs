//! Mini-batch scheduling (pipeline stage 1, §5.5): per-epoch shuffling of
//! the trainer's assigned training items and target construction for both
//! tasks — node classification (seed nodes) and link prediction (positive
//! edges + uniform negative tails, rows laid out [heads | tails | negs]).

use crate::graph::NodeId;
use crate::util::Rng;

/// Targets of one mini-batch, ready for multi-layer sampling.
#[derive(Clone, Debug)]
pub enum Target {
    /// Node classification: seed vertices.
    Nodes(Vec<NodeId>),
    /// Link prediction: (heads, tails, negative tails), equal lengths.
    Edges {
        heads: Vec<NodeId>,
        tails: Vec<NodeId>,
        negs: Vec<NodeId>,
    },
}

impl Target {
    /// Flat node list in the layer-L slot order the block contract expects.
    pub fn flat_nodes(&self) -> Vec<NodeId> {
        match self {
            Target::Nodes(v) => v.clone(),
            Target::Edges { heads, tails, negs } => {
                let mut v =
                    Vec::with_capacity(heads.len() + tails.len() + negs.len());
                v.extend_from_slice(heads);
                v.extend_from_slice(tails);
                v.extend_from_slice(negs);
                v
            }
        }
    }

    pub fn n_items(&self) -> usize {
        match self {
            Target::Nodes(v) => v.len(),
            Target::Edges { heads, .. } => heads.len(),
        }
    }
}

/// Per-trainer epoch scheduler over its assigned training items.
pub struct BatchScheduler {
    /// Node-classification: assigned train vertices. Link-prediction:
    /// assigned (head, tail) edges.
    items_nodes: Vec<NodeId>,
    items_edges: Vec<(NodeId, NodeId)>,
    pub batch_size: usize,
    /// Negative-sampling id range (all graph vertices).
    pub n_nodes_total: u64,
    rng: Rng,
    cursor: usize,
    order: Vec<u32>,
    /// Re-permute the item order at each epoch boundary (training
    /// default). `false` keeps the given item order every epoch
    /// (evaluation / offline inference).
    shuffle: bool,
    /// Skip the short trailing batch of each epoch (DGL's `drop_last`).
    /// Only effective while at least one full batch exists — a seed set
    /// smaller than `batch_size` still yields its single short batch.
    drop_last: bool,
}

impl BatchScheduler {
    pub fn for_nodes(items: Vec<NodeId>, batch_size: usize, seed: u64) -> Self {
        Self::for_nodes_opts(items, batch_size, seed, true, false)
    }

    /// [`Self::for_nodes`] with explicit `shuffle` / `drop_last` behavior
    /// (the data-loader knobs; the defaults reproduce the classic
    /// training stream byte for byte).
    pub fn for_nodes_opts(
        items: Vec<NodeId>,
        batch_size: usize,
        seed: u64,
        shuffle: bool,
        drop_last: bool,
    ) -> Self {
        let n = items.len();
        let mut s = Self {
            items_nodes: items,
            items_edges: Vec::new(),
            batch_size,
            n_nodes_total: 0,
            rng: Rng::new(seed),
            cursor: 0,
            order: (0..n as u32).collect(),
            shuffle,
            drop_last,
        };
        s.reshuffle();
        s
    }

    pub fn for_edges(
        items: Vec<(NodeId, NodeId)>,
        batch_size: usize,
        n_nodes_total: u64,
        seed: u64,
    ) -> Self {
        Self::for_edges_opts(items, batch_size, n_nodes_total, seed, true, false)
    }

    /// [`Self::for_edges`] with explicit `shuffle` / `drop_last` behavior.
    pub fn for_edges_opts(
        items: Vec<(NodeId, NodeId)>,
        batch_size: usize,
        n_nodes_total: u64,
        seed: u64,
        shuffle: bool,
        drop_last: bool,
    ) -> Self {
        let n = items.len();
        let mut s = Self {
            items_nodes: Vec::new(),
            items_edges: items,
            batch_size,
            n_nodes_total,
            rng: Rng::new(seed),
            cursor: 0,
            order: (0..n as u32).collect(),
            shuffle,
            drop_last,
        };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        if self.shuffle {
            self.rng.shuffle(&mut self.order);
        }
        self.cursor = 0;
    }

    pub fn n_items(&self) -> usize {
        self.order.len()
    }

    /// Batches per epoch: the last short batch is included unless
    /// `drop_last` is set (and a full batch exists at all).
    pub fn batches_per_epoch(&self) -> usize {
        let n = self.n_items();
        if self.drop_last && n >= self.batch_size {
            n / self.batch_size
        } else {
            n.div_ceil(self.batch_size)
        }
    }

    /// Next mini-batch; wraps to a fresh (re-shuffled unless `shuffle`
    /// is off) epoch at the boundary, skipping the short tail batch when
    /// `drop_last` is set.
    pub fn next_batch(&mut self) -> Target {
        // drop_last: a partial tail (fewer than batch_size items left,
        // with at least one full batch in the epoch) wraps early
        let need = if self.drop_last && self.order.len() >= self.batch_size {
            self.batch_size
        } else {
            1
        };
        if self.cursor + need > self.order.len() {
            self.reshuffle();
        }
        let end = (self.cursor + self.batch_size).min(self.order.len());
        let idxs = &self.order[self.cursor..end];
        self.cursor = end;
        if !self.items_nodes.is_empty() {
            Target::Nodes(
                idxs.iter()
                    .map(|&i| self.items_nodes[i as usize])
                    .collect(),
            )
        } else {
            let mut heads = Vec::with_capacity(idxs.len());
            let mut tails = Vec::with_capacity(idxs.len());
            let mut negs = Vec::with_capacity(idxs.len());
            for &i in idxs {
                let (h, t) = self.items_edges[i as usize];
                heads.push(h);
                tails.push(t);
                negs.push(self.rng.below(self.n_nodes_total) as NodeId);
            }
            Target::Edges { heads, tails, negs }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_items_once_per_epoch() {
        let items: Vec<NodeId> = (0..100).collect();
        let mut s = BatchScheduler::for_nodes(items, 32, 1);
        let mut seen = Vec::new();
        for _ in 0..s.batches_per_epoch() {
            if let Target::Nodes(v) = s.next_batch() {
                seen.extend(v);
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn epochs_reshuffle() {
        let items: Vec<NodeId> = (0..64).collect();
        let mut s = BatchScheduler::for_nodes(items, 64, 2);
        let Target::Nodes(a) = s.next_batch() else { panic!() };
        let Target::Nodes(b) = s.next_batch() else { panic!() };
        assert_ne!(a, b, "two epochs produced identical order");
        let mut bs = b.clone();
        bs.sort_unstable();
        assert_eq!(bs, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn edge_batches_have_aligned_triples() {
        let edges: Vec<(NodeId, NodeId)> =
            (0..50).map(|i| (i, i + 100)).collect();
        let mut s = BatchScheduler::for_edges(edges, 16, 1000, 3);
        let Target::Edges { heads, tails, negs } = s.next_batch() else {
            panic!()
        };
        assert_eq!(heads.len(), 16);
        assert_eq!(tails.len(), 16);
        assert_eq!(negs.len(), 16);
        for (h, t) in heads.iter().zip(&tails) {
            assert_eq!(*t, *h + 100);
        }
        assert!(negs.iter().all(|&n| (n as u64) < 1000));
    }

    #[test]
    fn no_shuffle_keeps_item_order_every_epoch() {
        let items: Vec<NodeId> = (0..40).collect();
        let mut s = BatchScheduler::for_nodes_opts(items, 16, 9, false, false);
        for _epoch in 0..2 {
            let mut seen = Vec::new();
            for _ in 0..s.batches_per_epoch() {
                let Target::Nodes(v) = s.next_batch() else { panic!() };
                seen.extend(v);
            }
            assert_eq!(seen, (0..40).collect::<Vec<_>>());
        }
    }

    #[test]
    fn drop_last_skips_the_short_tail() {
        let items: Vec<NodeId> = (0..65).collect();
        let mut s = BatchScheduler::for_nodes_opts(items, 16, 4, true, true);
        assert_eq!(s.batches_per_epoch(), 4); // floor(65/16), not ceil
        for _ in 0..3 * s.batches_per_epoch() {
            let Target::Nodes(v) = s.next_batch() else { panic!() };
            assert_eq!(v.len(), 16, "drop_last yielded a short batch");
        }
    }

    #[test]
    fn drop_last_with_tiny_seed_set_still_yields_batches() {
        // fewer items than batch_size: drop_last would starve the loader,
        // so the single short batch is kept
        let items: Vec<NodeId> = (0..5).collect();
        let mut s = BatchScheduler::for_nodes_opts(items, 16, 4, true, true);
        assert_eq!(s.batches_per_epoch(), 1);
        let Target::Nodes(v) = s.next_batch() else { panic!() };
        assert_eq!(v.len(), 5);
    }

    #[test]
    fn default_constructors_match_opted_defaults() {
        // the classic constructors must produce the byte-identical stream
        // of the explicit (shuffle=true, drop_last=false) form
        let a: Vec<NodeId> = (0..50).collect();
        let mut s1 = BatchScheduler::for_nodes(a.clone(), 16, 7);
        let mut s2 = BatchScheduler::for_nodes_opts(a, 16, 7, true, false);
        for _ in 0..2 * s1.batches_per_epoch() {
            let Target::Nodes(x) = s1.next_batch() else { panic!() };
            let Target::Nodes(y) = s2.next_batch() else { panic!() };
            assert_eq!(x, y);
        }
    }

    #[test]
    fn flat_nodes_layout_for_lp() {
        let t = Target::Edges {
            heads: vec![1, 2],
            tails: vec![3, 4],
            negs: vec![5, 6],
        };
        assert_eq!(t.flat_nodes(), vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(t.n_items(), 2);
    }
}
