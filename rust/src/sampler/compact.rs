//! Subgraph compaction (`to_block`, §5.5.1): re-map a sampled multi-layer
//! subgraph from global IDs to the dense, padded block layout the AOT'd
//! HLO expects (DESIGN.md §5). The paper moves this step to the GPU in the
//! training thread; here it runs in the pipeline's compact stage and is a
//! profiled hot path (§Perf).

use rustc_hash::FxHashMap;

use crate::graph::NodeId;

use super::service::SampledNbrs;

/// Model family of a shape spec (mirrors python ShapeConfig).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Sage,
    Gat,
    Rgcn,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    NodeClassification,
    LinkPrediction,
}

/// Static shapes of one AOT variant (parsed from artifacts/manifest.json).
#[derive(Clone, Debug)]
pub struct ShapeSpec {
    pub name: String,
    pub model: ModelKind,
    pub task: TaskKind,
    pub batch: usize,
    /// K per layer, input side first (fanouts[l-1] = layer l's K).
    pub fanouts: Vec<usize>,
    /// Padded node-array length per layer, `[n0, ..., nL]`.
    pub layer_nodes: Vec<usize>,
    pub feat_dim: usize,
    pub num_classes: usize,
    pub num_rels: usize,
}

impl ShapeSpec {
    pub fn num_layers(&self) -> usize {
        self.fanouts.len()
    }
}

/// One layer's padded index arrays (layer l: dst array length `n_l`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerBlock {
    /// `i32[n_l]` — position of dst node i in the layer-(l-1) node array.
    pub self_idx: Vec<i32>,
    /// `i32[n_l * K]` — neighbor positions, row-major.
    pub nbr_idx: Vec<i32>,
    /// `f32[n_l * K]` — 1.0 real neighbor / 0.0 padding.
    pub nbr_mask: Vec<f32>,
    /// `i32[n_l * K]` — the *sampled* relation id per edge slot (RGCN
    /// variants only; this is what the executable's `rel_l` input ships).
    pub rel: Vec<i32>,
    /// Relation-segmented CSR of the real (mask = 1) edges — one segment
    /// per etype, built for RGCN-shaped specs over typed data (other
    /// models skip the construction cost; the per-etype counts in
    /// [`Block::etype_edges`] are kept for every typed run): etype `r`'s
    /// edges are `(seg_dst[j], seg_src[j])` for
    /// `j in seg_ptr[r] as usize .. seg_ptr[r + 1] as usize`, where
    /// `seg_dst` indexes this layer's dst rows and `seg_src` the
    /// layer-(l-1) node array. Host-side observability + future per-etype
    /// kernels; not part of the device payload (the dense `rel` is).
    pub seg_ptr: Vec<u32>,
    pub seg_dst: Vec<i32>,
    pub seg_src: Vec<i32>,
}

/// A compacted mini-batch structure: everything the HLO needs except the
/// feature rows (filled by the prefetch stages) and labels.
#[derive(Clone, Debug)]
pub struct Block {
    /// Real (un-padded) input node globals, in layer-0 slot order.
    pub input_nodes: Vec<NodeId>,
    /// Real target node globals (layer-L slots `0..targets.len()`).
    pub targets: Vec<NodeId>,
    /// Per-layer index arrays, layer 1 (input side) first.
    pub layers: Vec<LayerBlock>,
    /// Neighbors that had to be dropped because a layer's node budget
    /// (`layer_nodes[l]`) was exhausted — observability for cap tuning.
    pub dropped_neighbors: usize,
    /// Kept (mask = 1) edges per etype, summed across layers; empty when
    /// the sampled data is homogeneous. Feeds the `sampler.etype_edges.*`
    /// metrics and the bench locality summary.
    pub etype_edges: Vec<u64>,
}

/// Build the padded block from multi-layer samples.
///
/// `samples[j]` is (seeds, per-seed neighbors) for layer `L-j` (outermost
/// first), exactly as produced by `DistNeighborSampler::sample_blocks`.
pub fn to_block(
    spec: &ShapeSpec,
    samples: &[(Vec<NodeId>, Vec<SampledNbrs>)],
) -> Block {
    let l_total = spec.num_layers();
    assert_eq!(samples.len(), l_total);
    let targets = samples[0].0.clone();
    assert!(
        targets.len() <= spec.layer_nodes[l_total],
        "targets {} exceed layer cap {}",
        targets.len(),
        spec.layer_nodes[l_total]
    );

    // typed data? (homogeneous samples carry no rels and skip all
    // segment work — the trivial-schema path is byte-identical).
    // §Perf: the per-layer relation bound is tracked while edges are
    // collected — no extra pass over the sampled edge set.
    let data_rels = samples
        .iter()
        .any(|(_, nbrs)| nbrs.iter().any(|s| !s.rels.is_empty()));
    // per-etype counters are cheap and kept for every typed run; the
    // CSR segments only matter to the relation-aware (RGCN) executable
    // path, so other models skip their per-batch construction cost
    let build_seg = data_rels && spec.model == ModelKind::Rgcn;
    // pre-sized to the spec's etypes so never-sampled trailing relations
    // still show up as explicit zero counts (grows on demand if the data
    // carries rels beyond the spec)
    let mut etype_edges: Vec<u64> = if data_rels {
        vec![0; spec.num_rels.max(1)]
    } else {
        Vec::new()
    };

    let mut layers_rev: Vec<LayerBlock> = Vec::with_capacity(l_total);
    let mut dropped = 0usize;
    // (rel, dst row, src pos) of kept edges — reused per layer
    let mut kept: Vec<(u8, i32, i32)> = Vec::new();

    // node array of the current dst layer (real entries only) + its index
    let mut dst_nodes: Vec<NodeId> = targets.clone();
    for (j, (seeds, nbrs)) in samples.iter().enumerate() {
        let l = l_total - j; // layer number
        let k = spec.fanouts[l - 1];
        let n_l = spec.layer_nodes[l];
        let n_prev_cap = spec.layer_nodes[l - 1];
        assert_eq!(seeds, &dst_nodes, "layer {l} seed mismatch");

        // build the src node array: dst nodes first (self slots), then new
        // unique neighbors up to the cap
        let mut src_nodes: Vec<NodeId> = dst_nodes.clone();
        let mut index: FxHashMap<NodeId, i32> = FxHashMap::default();
        index.reserve(src_nodes.len() * 2);
        for (i, &n) in src_nodes.iter().enumerate() {
            index.insert(n, i as i32);
        }
        let mut self_idx = vec![0i32; n_l];
        let mut nbr_idx = vec![0i32; n_l * k];
        let mut nbr_mask = vec![0f32; n_l * k];
        let mut rel = if spec.model == ModelKind::Rgcn {
            vec![0i32; n_l * k]
        } else {
            Vec::new()
        };
        kept.clear();
        let mut layer_max_rel = 0u8;

        for (i, s) in nbrs.iter().enumerate() {
            self_idx[i] = index[&dst_nodes[i]];
            for (kk, &n) in s.nbrs.iter().enumerate().take(k) {
                let pos = match index.get(&n) {
                    Some(&p) => p,
                    Option::None => {
                        if src_nodes.len() < n_prev_cap {
                            let p = src_nodes.len() as i32;
                            src_nodes.push(n);
                            index.insert(n, p);
                            p
                        } else {
                            dropped += 1;
                            continue; // budget exhausted: drop neighbor
                        }
                    }
                };
                nbr_idx[i * k + kk] = pos;
                nbr_mask[i * k + kk] = 1.0;
                let r = s.rels.get(kk).copied().unwrap_or(0);
                if !rel.is_empty() {
                    rel[i * k + kk] = r as i32;
                }
                if data_rels {
                    let ri = r as usize;
                    if etype_edges.len() <= ri {
                        etype_edges.resize(ri + 1, 0);
                    }
                    etype_edges[ri] += 1;
                    if build_seg {
                        kept.push((r, i as i32, pos));
                        layer_max_rel = layer_max_rel.max(r);
                    }
                }
            }
        }

        // relation-segmented CSR of this layer's kept edges; the segment
        // count covers the schema's etypes and anything observed beyond
        // them (a mis-matched variant must not index out of bounds)
        let (seg_ptr, seg_dst, seg_src) = if build_seg {
            let n_rels =
                spec.num_rels.max(1).max(layer_max_rel as usize + 1);
            let mut ptr = vec![0u32; n_rels + 1];
            for &(r, _, _) in &kept {
                ptr[r as usize + 1] += 1;
            }
            for r in 0..n_rels {
                ptr[r + 1] += ptr[r];
            }
            let mut cursor = ptr.clone();
            let mut dst = vec![0i32; kept.len()];
            let mut src = vec![0i32; kept.len()];
            for &(r, d, s_pos) in &kept {
                let c = cursor[r as usize] as usize;
                dst[c] = d;
                src[c] = s_pos;
                cursor[r as usize] += 1;
            }
            (ptr, dst, src)
        } else {
            (Vec::new(), Vec::new(), Vec::new())
        };

        layers_rev.push(LayerBlock {
            self_idx,
            nbr_idx,
            nbr_mask,
            rel,
            seg_ptr,
            seg_dst,
            seg_src,
        });
        dst_nodes = src_nodes;
    }

    layers_rev.reverse(); // layer 1 first
    Block {
        input_nodes: dst_nodes,
        targets,
        layers: layers_rev,
        dropped_neighbors: dropped,
        etype_edges,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(batch: usize, fanouts: Vec<usize>, caps: Vec<usize>) -> ShapeSpec {
        ShapeSpec {
            name: "t".into(),
            model: ModelKind::Sage,
            task: TaskKind::NodeClassification,
            batch,
            fanouts,
            layer_nodes: caps,
            feat_dim: 4,
            num_classes: 3,
            num_rels: 1,
        }
    }

    /// Hand-built 2-layer sample: targets [10, 20]; layer-2 neighbors
    /// 10→{20,30}, 20→{40}; layer-1 seeds then [10,20,30,40] with
    /// neighbors 10→{30}, 20→{}, 30→{50}, 40→{10}.
    fn hand_samples() -> Vec<(Vec<NodeId>, Vec<SampledNbrs>)> {
        vec![
            (
                vec![10, 20],
                vec![
                    SampledNbrs { nbrs: vec![20, 30], rels: vec![] },
                    SampledNbrs { nbrs: vec![40], rels: vec![] },
                ],
            ),
            (
                vec![10, 20, 30, 40],
                vec![
                    SampledNbrs { nbrs: vec![30], rels: vec![] },
                    SampledNbrs { nbrs: vec![], rels: vec![] },
                    SampledNbrs { nbrs: vec![50], rels: vec![] },
                    SampledNbrs { nbrs: vec![10], rels: vec![] },
                ],
            ),
        ]
    }

    #[test]
    fn block_structure_matches_hand_computation() {
        let sp = spec(2, vec![2, 2], vec![8, 8, 4]);
        // samples outermost-first: layer 2 then layer 1
        let b = to_block(&sp, &hand_samples());
        assert_eq!(b.targets, vec![10, 20]);
        // layer 2 (index 1): src array was [10,20] then +30, +40
        let l2 = &b.layers[1];
        assert_eq!(&l2.self_idx[..2], &[0, 1]);
        assert_eq!(&l2.nbr_idx[..2], &[1, 2]); // 10 -> [20(1), 30(2)]
        assert_eq!(&l2.nbr_mask[..2], &[1.0, 1.0]);
        assert_eq!(l2.nbr_idx[2], 3); // 20 -> [40(3)]
        assert_eq!(l2.nbr_mask[3], 0.0); // padding
        // layer 1: seeds [10,20,30,40], new node 50 → input_nodes
        assert_eq!(b.input_nodes, vec![10, 20, 30, 40, 50]);
        let l1 = &b.layers[0];
        assert_eq!(&l1.self_idx[..4], &[0, 1, 2, 3]);
        assert_eq!(l1.nbr_idx[0], 2); // 10 -> 30
        assert_eq!(l1.nbr_idx[2 * 2], 4); // 30 -> 50 (new slot 4)
        assert_eq!(l1.nbr_idx[3 * 2], 0); // 40 -> 10 (slot 0)
        assert_eq!(b.dropped_neighbors, 0);
    }

    #[test]
    fn cap_exhaustion_drops_and_masks() {
        let sp = spec(2, vec![2, 2], vec![4, 8, 4]); // n0 cap = 4 (tight)
        let b = to_block(&sp, &hand_samples());
        // layer-1 src array would need 5 nodes; node 50 must be dropped
        assert_eq!(b.input_nodes.len(), 4);
        assert_eq!(b.dropped_neighbors, 1);
        let l1 = &b.layers[0];
        assert_eq!(l1.nbr_mask[2 * 2], 0.0); // 30 -> 50 masked out
    }

    /// Typed hand-built samples: dense rel slots and the per-etype CSR
    /// must both reflect exactly the sampled relation ids.
    #[test]
    fn rel_segments_match_sampled_rels() {
        let mut sp = spec(2, vec![2, 2], vec![8, 8, 4]);
        sp.model = ModelKind::Rgcn;
        sp.num_rels = 3;
        let samples = vec![
            (
                vec![10, 20],
                vec![
                    SampledNbrs { nbrs: vec![20, 30], rels: vec![2, 0] },
                    SampledNbrs { nbrs: vec![40], rels: vec![1] },
                ],
            ),
            (
                vec![10, 20, 30, 40],
                vec![
                    SampledNbrs { nbrs: vec![30], rels: vec![1] },
                    SampledNbrs { nbrs: vec![], rels: vec![] },
                    SampledNbrs { nbrs: vec![50], rels: vec![0] },
                    SampledNbrs { nbrs: vec![10], rels: vec![2] },
                ],
            ),
        ];
        let b = to_block(&sp, &samples);
        // dense rel (what the RGCN executable receives): layer 2
        let l2 = &b.layers[1];
        assert_eq!(&l2.rel[..2], &[2, 0]); // 10 -> 20(rel 2), 30(rel 0)
        assert_eq!(l2.rel[2], 1); // 20 -> 40(rel 1)
        // per-etype CSR segments of layer 2: rel counts 1/1/1
        assert_eq!(l2.seg_ptr, vec![0, 1, 2, 3]);
        // rel-0 edge is (dst row 0, src pos of 30 = 2)
        assert_eq!((l2.seg_dst[0], l2.seg_src[0]), (0, 2));
        // rel-1 edge is (dst row 1, src pos of 40 = 3)
        assert_eq!((l2.seg_dst[1], l2.seg_src[1]), (1, 3));
        // rel-2 edge is (dst row 0, src pos of 20 = 1)
        assert_eq!((l2.seg_dst[2], l2.seg_src[2]), (0, 1));
        // totals across both layers: rels {0: 2, 1: 2, 2: 2}
        assert_eq!(b.etype_edges, vec![2, 2, 2]);
        // every seg edge agrees with the dense arrays
        for lb in &b.layers {
            let k = 2;
            for r in 0..3usize {
                for j in lb.seg_ptr[r] as usize..lb.seg_ptr[r + 1] as usize {
                    let (d, s) = (lb.seg_dst[j] as usize, lb.seg_src[j]);
                    let row = &lb.nbr_idx[d * k..(d + 1) * k];
                    let hit = row
                        .iter()
                        .enumerate()
                        .any(|(kk, &p)| {
                            p == s
                                && lb.nbr_mask[d * k + kk] > 0.0
                                && lb.rel[d * k + kk] == r as i32
                        });
                    assert!(hit, "seg edge (r={r}, dst={d}, src={s})");
                }
            }
        }
    }

    #[test]
    fn homogeneous_samples_build_no_segments() {
        let sp = spec(2, vec![2, 2], vec![8, 8, 4]);
        let b = to_block(&sp, &hand_samples());
        assert!(b.etype_edges.is_empty());
        for lb in &b.layers {
            assert!(lb.seg_ptr.is_empty());
            assert!(lb.seg_dst.is_empty() && lb.seg_src.is_empty());
        }
    }

    #[test]
    fn padded_rows_have_zero_mask() {
        let sp = spec(2, vec![2, 2], vec![16, 8, 4]);
        let b = to_block(&sp, &hand_samples());
        let l2 = &b.layers[1];
        // rows 2..4 of layer 2 are padding
        for i in 2..4 {
            assert_eq!(l2.self_idx[i], 0);
            for kk in 0..2 {
                assert_eq!(l2.nbr_mask[i * 2 + kk], 0.0);
            }
        }
    }

    /// Property: every (i, k) with mask 1 maps through nbr_idx to exactly
    /// the sampled neighbor, and self_idx maps to the node itself.
    #[test]
    fn prop_compaction_preserves_adjacency() {
        use crate::graph::DatasetSpec;
        use crate::partition::{
            build_partitions, metis_partition, relabel, PartitionConfig,
            VertexWeights,
        };
        use crate::sampler::{DistNeighborSampler, SamplerServer};
        use std::sync::Arc;

        let spec_d = DatasetSpec::new("cp", 600, 2400);
        let d = spec_d.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        let p = metis_partition(&d.graph, &vw, &PartitionConfig::new(2));
        let r = relabel::relabel(&p);
        let g = relabel::relabel_graph(&d.graph, &r);
        let parts = build_partitions(&g, &r.node_map);
        let servers: Vec<Arc<SamplerServer>> = parts
            .into_iter()
            .enumerate()
            .map(|(m, pp)| {
                Arc::new(SamplerServer::new(m as u32, Arc::new(pp)))
            })
            .collect();
        let cost = Arc::new(crate::net::CostModel::default());
        let sampler = DistNeighborSampler::new(
            0,
            servers,
            Arc::new(r.node_map),
            cost,
        );

        crate::util::proptest::forall(
            41,
            10,
            |rng| {
                let t: Vec<NodeId> = (0..8)
                    .map(|_| rng.below(600) as NodeId)
                    .collect::<std::collections::BTreeSet<_>>()
                    .into_iter()
                    .collect();
                (t, rng.next_u64())
            },
            |(targets, seed)| {
                let sp = ShapeSpec {
                    name: "p".into(),
                    model: ModelKind::Sage,
                    task: TaskKind::NodeClassification,
                    batch: targets.len(),
                    fanouts: vec![3, 3],
                    layer_nodes: vec![256, 64, 16],
                    feat_dim: 4,
                    num_classes: 2,
                    num_rels: 1,
                };
                let mut rng = crate::util::Rng::new(*seed);
                let samples = sampler
                    .sample_blocks(
                        targets,
                        &crate::graph::FanoutPlan::uniform(&sp.fanouts),
                        &sp.layer_nodes,
                        &mut rng,
                    )
                    .unwrap();
                let b = to_block(&sp, &samples);
                // check layer L (last LayerBlock) against samples[0]
                let l_total = sp.num_layers();
                for (j, (seeds, nbrs)) in samples.iter().enumerate() {
                    let l = l_total - j;
                    let lb = &b.layers[l - 1];
                    let k = sp.fanouts[l - 1];
                    // node array of layer l-1:
                    let prev: &[NodeId] = if l == 1 {
                        &b.input_nodes
                    } else {
                        &samples[j + 1].0
                    };
                    for (i, s) in nbrs.iter().enumerate() {
                        if prev[lb.self_idx[i] as usize] != seeds[i] {
                            return Err(format!(
                                "self_idx broken at layer {l} row {i}"
                            ));
                        }
                        for kk in 0..k {
                            if lb.nbr_mask[i * k + kk] > 0.0 {
                                let mapped =
                                    prev[lb.nbr_idx[i * k + kk] as usize];
                                if !s.nbrs.contains(&mapped) {
                                    return Err(format!(
                                        "nbr_idx maps to non-sampled node \
                                         at layer {l} row {i}"
                                    ));
                                }
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
