//! Per-machine sampler server: answers "sample ≤K neighbors of these seed
//! vertices" against the machine's physical partition. Thanks to the halo
//! closure (§5.3, Figure 6) every core vertex's full adjacency is local,
//! so servers never talk to each other — only trainers issue requests.

use std::sync::Arc;

use crate::graph::NodeId;
use crate::partition::PhysPartition;
use crate::util::Rng;

use super::neighbor::sample_k;

/// One sampled edge set for a seed: neighbor globals + relation types.
#[derive(Clone, Debug, Default)]
pub struct SampledNbrs {
    pub nbrs: Vec<NodeId>,
    pub rels: Vec<u8>,
}

pub struct SamplerServer {
    pub machine: u32,
    part: Arc<PhysPartition>,
}

impl SamplerServer {
    pub fn new(machine: u32, part: Arc<PhysPartition>) -> Self {
        Self { machine, part }
    }

    pub fn partition(&self) -> &Arc<PhysPartition> {
        &self.part
    }

    /// Sample for a batch of seeds (all must be core vertices here).
    /// Deterministic in `rng`.
    pub fn sample_neighbors(
        &self,
        seeds: &[NodeId],
        fanout: usize,
        rng: &mut Rng,
    ) -> Vec<SampledNbrs> {
        let mut out = Vec::with_capacity(seeds.len());
        let mut buf: Vec<NodeId> = Vec::with_capacity(fanout);
        let mut pos: Vec<u32> = Vec::with_capacity(fanout);
        let has_rel = !self.part.graph.rel.is_empty();
        for &seed in seeds {
            let local = self
                .part
                .local_of(seed)
                .unwrap_or_else(|| panic!("seed {seed} not on machine {}", self.machine));
            assert!(
                self.part.is_core_local(local),
                "seed {seed} is a halo vertex on machine {}",
                self.machine
            );
            let nbrs_local = self.part.graph.neighbors(local);
            sample_k(nbrs_local, fanout, rng, &mut buf, Some(&mut pos));
            let nbrs: Vec<NodeId> = buf
                .iter()
                .map(|&l| self.part.global_of(l))
                .collect();
            let rels: Vec<u8> = if has_rel {
                let all = self.part.graph.rel_of(local);
                pos.iter().map(|&p| all[p as usize]).collect()
            } else {
                Vec::new()
            };
            out.push(SampledNbrs { nbrs, rels });
        }
        out
    }

    /// Estimated request/response wire size for cost metering.
    pub fn wire_cost(seeds: usize, sampled_edges: usize) -> (u64, u64) {
        let req = 16 + seeds as u64 * 4;
        let resp = 16 + sampled_edges as u64 * 5; // 4B nbr + 1B rel
        (req, resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::partition::{
        build_partitions, metis_partition, relabel, PartitionConfig,
        VertexWeights,
    };

    fn setup() -> (crate::graph::Graph, Vec<Arc<PhysPartition>>) {
        let spec = DatasetSpec::new("ss", 800, 3200);
        let d = spec.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        let p = metis_partition(&d.graph, &vw, &PartitionConfig::new(2));
        let r = relabel::relabel(&p);
        let g = relabel::relabel_graph(&d.graph, &r);
        let parts = build_partitions(&g, &r.node_map)
            .into_iter()
            .map(Arc::new)
            .collect();
        (g, parts)
    }

    #[test]
    fn sampled_edges_exist_in_graph() {
        let (g, parts) = setup();
        let server = SamplerServer::new(0, parts[0].clone());
        let seeds: Vec<NodeId> = (0..parts[0].n_core.min(50) as u32)
            .map(|l| parts[0].global_of(l))
            .collect();
        let mut rng = Rng::new(5);
        let res = server.sample_neighbors(&seeds, 5, &mut rng);
        assert_eq!(res.len(), seeds.len());
        for (seed, s) in seeds.iter().zip(&res) {
            assert!(s.nbrs.len() <= 5);
            for &n in &s.nbrs {
                assert!(
                    g.neighbors(*seed).contains(&n),
                    "edge ({seed},{n}) not in graph"
                );
            }
        }
    }

    #[test]
    fn fanout_respected_and_degree_capped() {
        let (g, parts) = setup();
        let server = SamplerServer::new(0, parts[0].clone());
        let mut rng = Rng::new(6);
        for l in 0..parts[0].n_core.min(100) as u32 {
            let gid = parts[0].global_of(l);
            let res = server.sample_neighbors(&[gid], 3, &mut rng);
            let deg = g.degree(gid);
            assert_eq!(res[0].nbrs.len(), deg.min(3));
        }
    }

    #[test]
    #[should_panic(expected = "not on machine")]
    fn foreign_seed_panics() {
        let (_, parts) = setup();
        let server = SamplerServer::new(0, parts[0].clone());
        // a core of partition 1 that is not a halo of partition 0
        let p1 = &parts[1];
        let foreign = (0..p1.n_core as u32)
            .map(|l| p1.global_of(l))
            .find(|&g| parts[0].local_of(g).is_none())
            .expect("some vertex of p1 not known to p0");
        server.sample_neighbors(&[foreign], 3, &mut Rng::new(1));
    }
}
