//! Per-machine sampler server: answers "sample ≤K neighbors of these seed
//! vertices" against the machine's physical partition. Thanks to the halo
//! closure (§5.3, Figure 6) every core vertex's full adjacency is local,
//! so servers never talk to each other — only trainers issue requests.

use std::sync::Arc;

use crate::graph::NodeId;
use crate::partition::PhysPartition;
use crate::util::Rng;

use super::neighbor::sample_k_per_rel;

/// One sampled edge set for a seed: neighbor globals + relation types.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SampledNbrs {
    pub nbrs: Vec<NodeId>,
    pub rels: Vec<u8>,
}

pub struct SamplerServer {
    pub machine: u32,
    part: Arc<PhysPartition>,
}

impl SamplerServer {
    pub fn new(machine: u32, part: Arc<PhysPartition>) -> Self {
        Self { machine, part }
    }

    pub fn partition(&self) -> &Arc<PhysPartition> {
        &self.part
    }

    /// Sample for a batch of seeds (all must be core vertices here),
    /// taking up to `fanouts[r]` neighbors per etype `r` — a one-element
    /// `fanouts` is the classic uniform sampler (the homogeneous path is
    /// the trivial 1-etype schema, not a separate branch). Deterministic
    /// in `rng`.
    pub fn sample_neighbors(
        &self,
        seeds: &[NodeId],
        fanouts: &[usize],
        rng: &mut Rng,
    ) -> Vec<SampledNbrs> {
        let k_total: usize = fanouts.iter().sum();
        let mut out = Vec::with_capacity(seeds.len());
        let mut buf: Vec<NodeId> = Vec::with_capacity(k_total);
        let mut pos: Vec<u32> = Vec::with_capacity(k_total);
        let mut buckets: Vec<Vec<u32>> = Vec::new();
        let mut sel: Vec<NodeId> = Vec::new();
        let has_rel = !self.part.graph.rel.is_empty();
        for &seed in seeds {
            let local = self
                .part
                .local_of(seed)
                .unwrap_or_else(|| panic!("seed {seed} not on machine {}", self.machine));
            assert!(
                self.part.is_core_local(local),
                "seed {seed} is a halo vertex on machine {}",
                self.machine
            );
            let nbrs_local = self.part.graph.neighbors(local);
            let rels_local = self.part.graph.rel_of(local);
            sample_k_per_rel(
                nbrs_local,
                rels_local,
                fanouts,
                rng,
                &mut buf,
                Some(&mut pos),
                &mut buckets,
                &mut sel,
            );
            let nbrs: Vec<NodeId> = buf
                .iter()
                .map(|&l| self.part.global_of(l))
                .collect();
            let rels: Vec<u8> = if has_rel {
                let all = self.part.graph.rel_of(local);
                pos.iter().map(|&p| all[p as usize]).collect()
            } else {
                Vec::new()
            };
            out.push(SampledNbrs { nbrs, rels });
        }
        out
    }

    /// Request/response wire size for cost metering, derived from the
    /// real framed encoding (`net::payload::sampler_*_bytes`, which are
    /// regression-tested against the actual codec) — the emulated meter
    /// and a TCP socket charge the same bytes for the same RPC.
    /// `fanouts` is the per-relation fanout count riding in the request.
    pub fn wire_cost(
        seeds: usize,
        fanouts: usize,
        sampled_edges: usize,
    ) -> (u64, u64) {
        let req = crate::net::payload::sampler_req_bytes(seeds, fanouts);
        let resp = crate::net::payload::sampler_resp_bytes(
            seeds,
            sampled_edges,
        );
        (req, resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::partition::{
        build_partitions, metis_partition, relabel, PartitionConfig,
        VertexWeights,
    };

    fn setup() -> (crate::graph::Graph, Vec<Arc<PhysPartition>>) {
        let spec = DatasetSpec::new("ss", 800, 3200);
        let d = spec.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        let p = metis_partition(&d.graph, &vw, &PartitionConfig::new(2));
        let r = relabel::relabel(&p);
        let g = relabel::relabel_graph(&d.graph, &r);
        let parts = build_partitions(&g, &r.node_map)
            .into_iter()
            .map(Arc::new)
            .collect();
        (g, parts)
    }

    #[test]
    fn sampled_edges_exist_in_graph() {
        let (g, parts) = setup();
        let server = SamplerServer::new(0, parts[0].clone());
        let seeds: Vec<NodeId> = (0..parts[0].n_core.min(50) as u32)
            .map(|l| parts[0].global_of(l))
            .collect();
        let mut rng = Rng::new(5);
        let res = server.sample_neighbors(&seeds, &[5], &mut rng);
        assert_eq!(res.len(), seeds.len());
        for (seed, s) in seeds.iter().zip(&res) {
            assert!(s.nbrs.len() <= 5);
            for &n in &s.nbrs {
                assert!(
                    g.neighbors(*seed).contains(&n),
                    "edge ({seed},{n}) not in graph"
                );
            }
        }
    }

    #[test]
    fn fanout_respected_and_degree_capped() {
        let (g, parts) = setup();
        let server = SamplerServer::new(0, parts[0].clone());
        let mut rng = Rng::new(6);
        for l in 0..parts[0].n_core.min(100) as u32 {
            let gid = parts[0].global_of(l);
            let res = server.sample_neighbors(&[gid], &[3], &mut rng);
            let deg = g.degree(gid);
            assert_eq!(res[0].nbrs.len(), deg.min(3));
        }
    }

    #[test]
    #[should_panic(expected = "not on machine")]
    fn foreign_seed_panics() {
        let (_, parts) = setup();
        let server = SamplerServer::new(0, parts[0].clone());
        // a core of partition 1 that is not a halo of partition 0
        let p1 = &parts[1];
        let foreign = (0..p1.n_core as u32)
            .map(|l| p1.global_of(l))
            .find(|&g| parts[0].local_of(g).is_none())
            .expect("some vertex of p1 not known to p0");
        server.sample_neighbors(&[foreign], &[3], &mut Rng::new(1));
    }

    #[test]
    fn per_etype_fanouts_cap_each_relation() {
        // typed graph on one machine: per-rel budgets hold per seed and
        // the reported rels match the partition's edge types
        let mut spec = DatasetSpec::new("st", 600, 3600);
        spec.num_rels = 3;
        let d = spec.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        let p = metis_partition(&d.graph, &vw, &PartitionConfig::new(1));
        let r = relabel::relabel(&p);
        let g = relabel::relabel_graph(&d.graph, &r);
        let part = Arc::new(
            build_partitions(&g, &r.node_map).into_iter().next().unwrap(),
        );
        let server = SamplerServer::new(0, part.clone());
        let fanouts = [2usize, 1, 1];
        let mut rng = Rng::new(8);
        let seeds: Vec<NodeId> = (0..200u32).collect();
        let res = server.sample_neighbors(&seeds, &fanouts, &mut rng);
        for (seed, s) in seeds.iter().zip(&res) {
            assert_eq!(s.rels.len(), s.nbrs.len());
            let mut counts = [0usize; 3];
            for &rel in &s.rels {
                counts[rel as usize] += 1;
            }
            for (rel, &c) in counts.iter().enumerate() {
                assert!(
                    c <= fanouts[rel],
                    "seed {seed}: rel {rel} sampled {c} > {}",
                    fanouts[rel]
                );
            }
            // every reported rel matches the actual edge type
            for (&n, &rel) in s.nbrs.iter().zip(&s.rels) {
                let local = part.local_of(*seed).unwrap();
                let nbrs = part.graph.neighbors(local);
                let rels = part.graph.rel_of(local);
                let found = nbrs
                    .iter()
                    .zip(rels)
                    .any(|(&l, &rl)| part.global_of(l) == n && rl == rel);
                assert!(found, "({seed},{n}) rel {rel} not in adjacency");
            }
        }
    }
}
