//! Core sampling primitive: pick ≤ K neighbors of one vertex from a CSR
//! slice, uniformly without replacement.

use crate::graph::NodeId;
use crate::util::Rng;

/// Sample up to `k` distinct neighbors into `out` (cleared first). Returns
/// the edge positions sampled (for relation lookup) via `pos_out` when
/// provided. When `deg <= k` all neighbors are taken (no RNG draw).
pub fn sample_k(
    nbrs: &[NodeId],
    k: usize,
    rng: &mut Rng,
    out: &mut Vec<NodeId>,
    mut pos_out: Option<&mut Vec<u32>>,
) {
    out.clear();
    if let Some(p) = pos_out.as_deref_mut() {
        p.clear();
    }
    let deg = nbrs.len();
    if deg == 0 {
        return;
    }
    if deg <= k {
        out.extend_from_slice(nbrs);
        if let Some(p) = pos_out.as_deref_mut() {
            p.extend(0..deg as u32);
        }
        return;
    }
    // §Perf: fanouts are small (≤ 32 in every paper config), so rejection
    // sampling with a stack-resident linear dedup beats the hash-set based
    // Floyd sampler by avoiding any allocation in this innermost loop
    // (called once per seed per layer).
    if k <= 32 {
        let mut picked = [0u32; 32];
        let mut cnt = 0usize;
        while cnt < k {
            let idx = rng.usize_below(deg) as u32;
            if picked[..cnt].contains(&idx) {
                continue;
            }
            picked[cnt] = idx;
            cnt += 1;
            out.push(nbrs[idx as usize]);
            if let Some(p) = pos_out.as_deref_mut() {
                p.push(idx);
            }
        }
        return;
    }
    // large-k fallback: Floyd's algorithm
    for idx in rng.sample_distinct(deg, k) {
        out.push(nbrs[idx]);
        if let Some(p) = pos_out.as_deref_mut() {
            p.push(idx as u32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn takes_all_when_degree_small() {
        let nbrs = vec![1, 2, 3];
        let mut out = Vec::new();
        sample_k(&nbrs, 5, &mut Rng::new(1), &mut out, None);
        assert_eq!(out, nbrs);
    }

    #[test]
    fn samples_distinct_subset() {
        let nbrs: Vec<NodeId> = (0..100).collect();
        let mut out = Vec::new();
        let mut pos = Vec::new();
        sample_k(&nbrs, 10, &mut Rng::new(2), &mut out, Some(&mut pos));
        assert_eq!(out.len(), 10);
        assert_eq!(pos.len(), 10);
        let set: std::collections::HashSet<_> = out.iter().collect();
        assert_eq!(set.len(), 10);
        for (o, p) in out.iter().zip(&pos) {
            assert_eq!(*o, nbrs[*p as usize]);
        }
    }

    #[test]
    fn empty_adjacency_yields_empty() {
        let mut out = vec![9, 9];
        sample_k(&[], 4, &mut Rng::new(3), &mut out, None);
        assert!(out.is_empty());
    }

    #[test]
    fn roughly_uniform_over_many_draws() {
        let nbrs: Vec<NodeId> = (0..20).collect();
        let mut counts = [0usize; 20];
        let mut rng = Rng::new(4);
        let mut out = Vec::new();
        for _ in 0..10_000 {
            sample_k(&nbrs, 5, &mut rng, &mut out, None);
            for &v in &out {
                counts[v as usize] += 1;
            }
        }
        // each neighbor expected 2500 times
        for &c in &counts {
            assert!((2_100..2_900).contains(&c), "{counts:?}");
        }
    }
}
