//! Core sampling primitive: pick ≤ K neighbors of one vertex from a CSR
//! slice, uniformly without replacement.

use crate::graph::NodeId;
use crate::util::Rng;

/// Sample up to `k` distinct neighbors into `out` (cleared first). Returns
/// the edge positions sampled (for relation lookup) via `pos_out` when
/// provided. When `deg <= k` all neighbors are taken (no RNG draw).
pub fn sample_k(
    nbrs: &[NodeId],
    k: usize,
    rng: &mut Rng,
    out: &mut Vec<NodeId>,
    mut pos_out: Option<&mut Vec<u32>>,
) {
    out.clear();
    if let Some(p) = pos_out.as_deref_mut() {
        p.clear();
    }
    let deg = nbrs.len();
    if deg == 0 {
        return;
    }
    if deg <= k {
        out.extend_from_slice(nbrs);
        if let Some(p) = pos_out.as_deref_mut() {
            p.extend(0..deg as u32);
        }
        return;
    }
    // §Perf: fanouts are small (≤ 32 in every paper config), so rejection
    // sampling with a stack-resident linear dedup beats the hash-set based
    // Floyd sampler by avoiding any allocation in this innermost loop
    // (called once per seed per layer).
    if k <= 32 {
        let mut picked = [0u32; 32];
        let mut cnt = 0usize;
        while cnt < k {
            let idx = rng.usize_below(deg) as u32;
            if picked[..cnt].contains(&idx) {
                continue;
            }
            picked[cnt] = idx;
            cnt += 1;
            out.push(nbrs[idx as usize]);
            if let Some(p) = pos_out.as_deref_mut() {
                p.push(idx);
            }
        }
        return;
    }
    // large-k fallback: Floyd's algorithm
    for idx in rng.sample_distinct(deg, k) {
        out.push(nbrs[idx]);
        if let Some(p) = pos_out.as_deref_mut() {
            p.push(idx as u32);
        }
    }
}

/// Relation-aware sampling: pick up to `fanouts[r]` distinct neighbors
/// *per edge type r*, appending rel-0 picks first, then rel-1, etc.
/// `rels` is the adjacency-aligned relation array ([`Graph::rel_of`]);
/// edges whose rel exceeds the plan are skipped.
///
/// A single-etype plan (or a graph without a rel array) is *exactly*
/// [`sample_k`] — the homogeneous case is the trivial 1-etype schema
/// flowing through this same entry point, with an identical RNG stream.
///
/// `bucket_scratch`/`sel_scratch` are caller-owned buffers reused across
/// seeds (§Perf: no allocation in the per-seed loop once warm).
///
/// [`Graph::rel_of`]: crate::graph::Graph::rel_of
#[allow(clippy::too_many_arguments)]
pub fn sample_k_per_rel(
    nbrs: &[NodeId],
    rels: &[u8],
    fanouts: &[usize],
    rng: &mut Rng,
    out: &mut Vec<NodeId>,
    mut pos_out: Option<&mut Vec<u32>>,
    bucket_scratch: &mut Vec<Vec<u32>>,
    sel_scratch: &mut Vec<NodeId>,
) {
    if fanouts.len() <= 1 || rels.is_empty() {
        // single-etype plan, or a graph without a rel array driven by a
        // multi-etype plan: sample the full layer budget uniformly (for
        // one etype the sum IS that etype's fanout, so the homogeneous
        // stream is untouched)
        let k: usize = fanouts.iter().sum();
        sample_k(nbrs, k, rng, out, pos_out);
        return;
    }
    out.clear();
    if let Some(p) = pos_out.as_deref_mut() {
        p.clear();
    }
    if bucket_scratch.len() < fanouts.len() {
        bucket_scratch.resize_with(fanouts.len(), Vec::new);
    }
    for b in bucket_scratch.iter_mut() {
        b.clear();
    }
    debug_assert_eq!(rels.len(), nbrs.len());
    for (i, &r) in rels.iter().enumerate() {
        if (r as usize) < fanouts.len() {
            bucket_scratch[r as usize].push(i as u32);
        }
    }
    for (r, &k) in fanouts.iter().enumerate() {
        let bucket = &bucket_scratch[r];
        if bucket.is_empty() || k == 0 {
            continue;
        }
        // sample edge *positions* of this relation, then map back
        sample_k(bucket, k, rng, sel_scratch, None);
        for &pos in sel_scratch.iter() {
            out.push(nbrs[pos as usize]);
            if let Some(p) = pos_out.as_deref_mut() {
                p.push(pos);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn takes_all_when_degree_small() {
        let nbrs = vec![1, 2, 3];
        let mut out = Vec::new();
        sample_k(&nbrs, 5, &mut Rng::new(1), &mut out, None);
        assert_eq!(out, nbrs);
    }

    #[test]
    fn samples_distinct_subset() {
        let nbrs: Vec<NodeId> = (0..100).collect();
        let mut out = Vec::new();
        let mut pos = Vec::new();
        sample_k(&nbrs, 10, &mut Rng::new(2), &mut out, Some(&mut pos));
        assert_eq!(out.len(), 10);
        assert_eq!(pos.len(), 10);
        let set: std::collections::HashSet<_> = out.iter().collect();
        assert_eq!(set.len(), 10);
        for (o, p) in out.iter().zip(&pos) {
            assert_eq!(*o, nbrs[*p as usize]);
        }
    }

    #[test]
    fn empty_adjacency_yields_empty() {
        let mut out = vec![9, 9];
        sample_k(&[], 4, &mut Rng::new(3), &mut out, None);
        assert!(out.is_empty());
    }

    // ---- relation-aware sampling ----------------------------------------

    fn per_rel(
        nbrs: &[NodeId],
        rels: &[u8],
        fanouts: &[usize],
        seed: u64,
    ) -> (Vec<NodeId>, Vec<u32>) {
        let mut out = Vec::new();
        let mut pos = Vec::new();
        let mut buckets = Vec::new();
        let mut sel = Vec::new();
        sample_k_per_rel(
            nbrs,
            rels,
            fanouts,
            &mut Rng::new(seed),
            &mut out,
            Some(&mut pos),
            &mut buckets,
            &mut sel,
        );
        (out, pos)
    }

    #[test]
    fn per_rel_respects_per_etype_caps() {
        // 12 neighbors: rels cycle 0,1,2
        let nbrs: Vec<NodeId> = (0..12).collect();
        let rels: Vec<u8> = (0..12).map(|i| (i % 3) as u8).collect();
        let (out, pos) = per_rel(&nbrs, &rels, &[2, 1, 1], 5);
        assert_eq!(out.len(), 4);
        let mut counts = [0usize; 3];
        for &p in &pos {
            counts[rels[p as usize] as usize] += 1;
        }
        assert_eq!(counts, [2, 1, 1]);
        // pos_out aligned and distinct
        let set: std::collections::HashSet<_> = pos.iter().collect();
        assert_eq!(set.len(), pos.len());
        for (o, p) in out.iter().zip(&pos) {
            assert_eq!(*o, nbrs[*p as usize]);
        }
    }

    #[test]
    fn per_rel_single_etype_plan_matches_sample_k() {
        // the trivial 1-etype schema must reproduce sample_k bit for bit
        let nbrs: Vec<NodeId> = (0..50).collect();
        let rels = vec![0u8; 50];
        let (out_a, pos_a) = per_rel(&nbrs, &rels, &[7], 9);
        let mut out_b = Vec::new();
        let mut pos_b = Vec::new();
        sample_k(&nbrs, 7, &mut Rng::new(9), &mut out_b, Some(&mut pos_b));
        assert_eq!(out_a, out_b);
        assert_eq!(pos_a, pos_b);
    }

    #[test]
    fn per_rel_missing_relation_yields_fewer() {
        // no rel-1 edges at all: only the rel-0 and rel-2 budgets fill
        let nbrs: Vec<NodeId> = (0..10).collect();
        let rels: Vec<u8> = (0..10).map(|i| if i < 5 { 0 } else { 2 }).collect();
        let (out, pos) = per_rel(&nbrs, &rels, &[2, 3, 2], 1);
        assert_eq!(out.len(), 4);
        for &p in &pos {
            assert_ne!(rels[p as usize], 1);
        }
    }

    // ---- large-k Floyd fallback (k > 32) --------------------------------

    #[test]
    fn large_k_samples_are_distinct_and_aligned() {
        let nbrs: Vec<NodeId> = (100..300).collect(); // deg 200
        for k in [33usize, 48, 64, 100] {
            let mut out = Vec::new();
            let mut pos = Vec::new();
            sample_k(&nbrs, k, &mut Rng::new(7), &mut out, Some(&mut pos));
            assert_eq!(out.len(), k, "k={k}");
            assert_eq!(pos.len(), k, "k={k}");
            let set: std::collections::HashSet<_> = out.iter().collect();
            assert_eq!(set.len(), k, "duplicates at k={k}");
            for (o, p) in out.iter().zip(&pos) {
                assert_eq!(*o, nbrs[*p as usize], "pos_out misaligned k={k}");
                assert!((*p as usize) < nbrs.len());
            }
        }
    }

    #[test]
    fn large_k_is_deterministic_in_seed() {
        let nbrs: Vec<NodeId> = (0..500).collect();
        let sample = |seed: u64| {
            let mut out = Vec::new();
            let mut pos = Vec::new();
            sample_k(&nbrs, 77, &mut Rng::new(seed), &mut out, Some(&mut pos));
            (out, pos)
        };
        assert_eq!(sample(11), sample(11));
        assert_ne!(sample(11).0, sample(12).0);
    }

    #[test]
    fn large_k_degree_at_most_k_takes_all() {
        // deg <= k path must bypass the Floyd fallback entirely
        let nbrs: Vec<NodeId> = (0..40).collect();
        let mut out = Vec::new();
        let mut pos = Vec::new();
        sample_k(&nbrs, 64, &mut Rng::new(3), &mut out, Some(&mut pos));
        assert_eq!(out, nbrs);
        assert_eq!(pos, (0..40).collect::<Vec<u32>>());
    }

    #[test]
    fn roughly_uniform_over_many_draws() {
        let nbrs: Vec<NodeId> = (0..20).collect();
        let mut counts = [0usize; 20];
        let mut rng = Rng::new(4);
        let mut out = Vec::new();
        for _ in 0..10_000 {
            sample_k(&nbrs, 5, &mut rng, &mut out, None);
            for &v in &out {
                counts[v as usize] += 1;
            }
        }
        // each neighbor expected 2500 times
        for &c in &counts {
            assert!((2_100..2_900).contains(&c), "{counts:?}");
        }
    }
}
