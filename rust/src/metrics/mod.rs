//! Lightweight metrics: named counters + stage timers used by the trainer,
//! pipeline, and benches to attribute time (Table 2 / §Perf breakdowns).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Thread-safe named counters + duration accumulators.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    timers: BTreeMap<String, (Duration, u64)>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        let mut m = self.inner.lock().unwrap();
        *m.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn add_time(&self, name: &str, d: Duration) {
        let mut m = self.inner.lock().unwrap();
        let e = m
            .timers
            .entry(name.to_string())
            .or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Time a closure and attribute it to `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add_time(name, t.elapsed());
        out
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Snapshot every counter under a dotted prefix (e.g. `"cache."` →
    /// the FeatureCache group), sorted by name.
    pub fn counters_with_prefix(
        &self,
        prefix: &str,
    ) -> Vec<(String, u64)> {
        self.inner
            .lock()
            .unwrap()
            .counters
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(k, &v)| (k.clone(), v))
            .collect()
    }

    pub fn total_time(&self, name: &str) -> Duration {
        self.inner
            .lock()
            .unwrap()
            .timers
            .get(name)
            .map(|e| e.0)
            .unwrap_or(Duration::ZERO)
    }

    /// Human-readable dump (sorted by name).
    pub fn report(&self) -> String {
        let m = self.inner.lock().unwrap();
        let mut s = String::new();
        for (k, v) in &m.counters {
            s.push_str(&format!("{k:<40} {v}\n"));
        }
        for (k, (d, n)) in &m.timers {
            s.push_str(&format!(
                "{k:<40} {:?} total, {n} samples, {:?} avg\n",
                d,
                d.checked_div(*n as u32).unwrap_or(Duration::ZERO)
            ));
        }
        s
    }

    pub fn reset(&self) {
        let mut m = self.inner.lock().unwrap();
        m.counters.clear();
        m.timers.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_timers() {
        let m = Metrics::new();
        m.inc("batches", 3);
        m.inc("batches", 2);
        assert_eq!(m.counter("batches"), 5);
        let out = m.time("work", || 42);
        assert_eq!(out, 42);
        assert!(m.total_time("work") > Duration::ZERO);
        assert!(m.report().contains("batches"));
        m.reset();
        assert_eq!(m.counter("batches"), 0);
    }

    #[test]
    fn prefix_snapshot_selects_group() {
        let m = Metrics::new();
        m.inc("cache.hit_rows", 7);
        m.inc("cache.miss_rows", 3);
        m.inc("kv.remote_rows", 11);
        let cache = m.counters_with_prefix("cache.");
        assert_eq!(
            cache,
            vec![
                ("cache.hit_rows".to_string(), 7),
                ("cache.miss_rows".to_string(), 3),
            ]
        );
    }

    #[test]
    fn concurrent_increments() {
        let m = std::sync::Arc::new(Metrics::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.inc("x", 1);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.counter("x"), 4000);
    }
}
