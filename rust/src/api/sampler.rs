//! [`NeighborSampler`]: the sampling strategy as a value object.

use anyhow::{ensure, Result};

use crate::graph::{FanoutPlan, GraphSchema};
use crate::runtime::manifest::VariantSpec;

/// Per-layer neighbor-sampling fanouts, optionally split per edge type —
/// DGL's `NeighborSampler([k1, k2, ...])` value object. Replaces raw
/// fanout/plan plumbing in user code: the loader builder turns it into
/// the [`FanoutPlan`] the distributed sampler executes.
///
/// The compiled HLO fixes each layer's padded width to the variant's
/// fanouts, so a sampler attached to a loader must match its variant
/// ([`Self::validate_for`]); per-etype *weights* only redistribute each
/// layer's K across relations and are free to vary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NeighborSampler {
    fanouts: Vec<usize>,
    /// Per-etype share of each layer's K; `None` = the schema's weights
    /// (or the cluster's `etype_fanouts` override).
    etype_weights: Option<Vec<usize>>,
}

impl NeighborSampler {
    /// Uniform sampler: `fanouts[l-1]` neighbors per seed at layer `l`
    /// (input side first, like the variant specs).
    pub fn new(fanouts: Vec<usize>) -> Self {
        Self { fanouts, etype_weights: None }
    }

    /// The sampler a compiled variant was lowered for.
    pub fn from_variant(vspec: &VariantSpec) -> Self {
        Self::new(vspec.fanouts.clone())
    }

    /// Split each layer's K across edge types proportionally to
    /// `weights` (one entry per schema etype) instead of the schema's
    /// own fanout weights.
    pub fn with_etype_weights(mut self, weights: Vec<usize>) -> Self {
        self.etype_weights = Some(weights);
        self
    }

    pub fn fanouts(&self) -> &[usize] {
        &self.fanouts
    }

    pub fn num_layers(&self) -> usize {
        self.fanouts.len()
    }

    pub fn etype_weights(&self) -> Option<&[usize]> {
        self.etype_weights.as_deref()
    }

    /// The per-layer per-etype plan this sampler executes under `schema`.
    pub fn plan(&self, schema: &GraphSchema) -> FanoutPlan {
        match &self.etype_weights {
            Some(w) => FanoutPlan::from_weights(w, &self.fanouts),
            None => FanoutPlan::from_schema(schema, &self.fanouts),
        }
    }

    /// Check this sampler is executable for a compiled variant under a
    /// deployed schema: layer fanouts must equal the variant's (the HLO's
    /// padded widths are lowered from them) and any per-etype weights
    /// must cover the schema with at least one nonzero entry.
    pub fn validate_for(
        &self,
        vspec: &VariantSpec,
        schema: &GraphSchema,
    ) -> Result<()> {
        ensure!(
            self.fanouts == vspec.fanouts,
            "sampler fanouts {:?} do not match variant {:?} (compiled for \
             {:?}); the AOT shapes fix the per-layer widths",
            self.fanouts,
            vspec.name,
            vspec.fanouts
        );
        if let Some(w) = &self.etype_weights {
            ensure!(
                w.len() == schema.n_etypes(),
                "etype weights have {} entries, schema has {} etypes",
                w.len(),
                schema.n_etypes()
            );
            ensure!(
                w.iter().any(|&x| x > 0),
                "etype weights must have at least one nonzero entry"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeTypeSpec, GraphSchema};
    use crate::sampler::compact::{ModelKind, TaskKind};

    fn vspec(fanouts: Vec<usize>) -> VariantSpec {
        VariantSpec {
            name: "t".into(),
            model: ModelKind::Sage,
            task: TaskKind::NodeClassification,
            batch: 16,
            fanouts,
            layer_nodes: vec![512, 128, 128],
            feat_dim: 8,
            num_classes: 4,
            num_heads: 1,
            num_rels: 1,
            param_shapes: Vec::new(),
            train_inputs: Vec::new(),
            eval_inputs: Vec::new(),
            train_hlo: String::new(),
            eval_hlo: String::new(),
            params_bin: String::new(),
        }
    }

    #[test]
    fn plan_preserves_layer_totals() {
        let mut schema = GraphSchema::homogeneous(8);
        schema.etypes = vec![
            EdgeTypeSpec { name: "a".into(), fanout_weight: 2 },
            EdgeTypeSpec { name: "b".into(), fanout_weight: 1 },
        ];
        let s = NeighborSampler::new(vec![6, 3]);
        let p = s.plan(&schema);
        assert_eq!(p.layer_total(1), 6);
        assert_eq!(p.layer_total(2), 3);
        assert_eq!(p.layer(1).len(), 2);
        // explicit weights override the schema's
        let sw = NeighborSampler::new(vec![6, 3])
            .with_etype_weights(vec![1, 1]);
        assert_eq!(sw.plan(&schema).layer(1), &[3, 3]);
    }

    #[test]
    fn validation_pins_fanouts_to_the_variant() {
        let v = vspec(vec![5, 5]);
        let schema = GraphSchema::homogeneous(8);
        NeighborSampler::from_variant(&v)
            .validate_for(&v, &schema)
            .unwrap();
        assert!(NeighborSampler::new(vec![5, 4])
            .validate_for(&v, &schema)
            .is_err());
        // weights must cover the schema's etypes
        assert!(NeighborSampler::from_variant(&v)
            .with_etype_weights(vec![1, 1])
            .validate_for(&v, &schema)
            .is_err());
        assert!(NeighborSampler::from_variant(&v)
            .with_etype_weights(vec![0])
            .validate_for(&v, &schema)
            .is_err());
        NeighborSampler::from_variant(&v)
            .with_etype_weights(vec![3])
            .validate_for(&v, &schema)
            .unwrap();
    }
}
