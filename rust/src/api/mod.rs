//! DGL-shaped public API: custom training loops over the async pipeline.
//!
//! DistDGLv2's usability claim is that distributed training needs "almost
//! no code modification" relative to single-machine DGL (arxiv 2112.15345
//! §4): the user keeps their own training loop and swaps the graph handle
//! and data loader for distributed ones. This module is that surface for
//! the Rust reproduction (docs/DESIGN.md §7):
//!
//! - [`DistGraph`] — a cheap handle over a deployed
//!   [`Cluster`](crate::cluster::Cluster): typed node/edge counts, the
//!   [`GraphSchema`](crate::graph::GraphSchema), feature pulls through the
//!   distributed KVStore ([`DistGraph::ndata`]), and the per-trainer
//!   train/val/test splits.
//! - [`NeighborSampler`] — the sampling strategy as a value object:
//!   per-layer fanouts, optionally split per edge type.
//! - [`DistNodeDataLoader`] — a builder-constructed iterator over
//!   mini-batches. It owns the 5-stage asynchronous pipeline
//!   ([`Pipeline`](crate::pipeline::Pipeline)/[`BatchGen`](crate::pipeline::BatchGen))
//!   internally, supports `batch_size` / `shuffle` / `drop_last` / `seed`,
//!   and yields recyclable [`HostBatch`](crate::runtime::executable::HostBatch)es
//!   whose buffers flow back through the
//!   [`BatchPool`](crate::pipeline::BatchPool) (the §Perf allocation-free
//!   hot path). Seed sets cover the train/valid/test splits plus any
//!   explicit node list for offline inference ([`Seeds`]).
//!
//! [`trainer::train`](crate::trainer::train) is a thin client of this API;
//! `examples/custom_loop.rs` is the hand-written equivalent (explicit
//! device step + all-reduce + an inference pass). Under identical seeds
//! the loader's batch stream is byte-identical to the pre-refactor
//! trainer-internal pipeline — test-enforced in [`loader`].

pub mod graph;
pub mod loader;
pub mod sampler;

pub use graph::DistGraph;
pub use loader::{DistNodeDataLoader, DistNodeDataLoaderBuilder, Seeds};
pub use sampler::NeighborSampler;
