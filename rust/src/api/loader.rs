//! [`DistNodeDataLoader`]: the DGL-style mini-batch iterator that owns
//! the 5-stage asynchronous pipeline.

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::graph::NodeId;
use crate::metrics::Metrics;
use crate::net::RpcError;
use crate::pipeline::{BatchGen, BatchPool, Pipeline, PipelineConfig};
use crate::runtime::executable::HostBatch;
use crate::runtime::manifest::VariantSpec;
use crate::sampler::compact::TaskKind;
use crate::sampler::BatchScheduler;

use super::{DistGraph, NeighborSampler};

/// Which seed nodes a loader iterates — the deployment's splits, or an
/// arbitrary node list (offline inference over any vertex set).
#[derive(Clone, Debug)]
pub enum Seeds {
    /// This rank's slice of the training split (§5.6.1 locality-aware).
    Train,
    /// The global validation split.
    Val,
    /// The global test split.
    Test,
    /// An explicit seed list (offline inference; deduplication and order
    /// are the caller's choice).
    Nodes(Vec<NodeId>),
}

/// Builder for [`DistNodeDataLoader`] — DGL's
/// `DistNodeDataLoader(g, nids, sampler, batch_size=.., shuffle=..,
/// drop_last=..)` shape. Defaults reproduce the classic training stream
/// byte for byte: `Seeds::Train`, the variant's own batch size and
/// fanouts, `shuffle = true`, `drop_last = false`, the non-stop pipeline.
pub struct DistNodeDataLoaderBuilder<'a> {
    graph: &'a DistGraph<'a>,
    vspec: &'a VariantSpec,
    seeds: Seeds,
    sampler: Option<NeighborSampler>,
    rank: usize,
    machine: Option<u32>,
    batch_size: Option<usize>,
    shuffle: bool,
    drop_last: bool,
    seed: u64,
    start_at: u64,
    pipeline: PipelineConfig,
    prefetch_depth: Option<usize>,
    metrics: Option<Arc<Metrics>>,
}

impl<'a> DistNodeDataLoaderBuilder<'a> {
    /// Iterate this seed set instead of the training split.
    pub fn seeds(mut self, seeds: Seeds) -> Self {
        self.seeds = seeds;
        self
    }

    /// Trainer rank: selects the training-split slice, the machine whose
    /// KVStore/sampler the loader talks to, and the remote-feature cache
    /// affinity. Default 0.
    pub fn rank(mut self, rank: usize) -> Self {
        self.rank = rank;
        self
    }

    /// Anchor the loader on an explicit machine instead of deriving one
    /// from [`Self::rank`] — the elastic-membership path (docs/DESIGN.md
    /// §9), where (machine, seed set) come from a membership re-split
    /// rather than the deploy-time trainer grid, and the logical rank
    /// may exceed the deployed trainer count after a grow. Requires
    /// [`Seeds::Nodes`] (the deployment's rank-sliced splits are
    /// meaningless off-grid). With the same seed set, seed, and knobs,
    /// the stream is byte-identical to the rank-derived loader on that
    /// machine (test-enforced).
    pub fn machine(mut self, machine: u32) -> Self {
        self.machine = Some(machine);
        self
    }

    /// Sampling strategy; default: the variant's own fanouts under the
    /// deployed schema (see [`NeighborSampler::validate_for`]).
    pub fn sampler(mut self, sampler: NeighborSampler) -> Self {
        self.sampler = Some(sampler);
        self
    }

    /// Seeds per mini-batch; default (and maximum) is the variant's
    /// compiled batch size — smaller batches ride in the same padded
    /// layout, like the evaluation path always has.
    pub fn batch_size(mut self, batch_size: usize) -> Self {
        self.batch_size = Some(batch_size);
        self
    }

    /// Re-permute the seed order every epoch (default `true`; turn off
    /// for inference so batches chunk the seed list in order).
    pub fn shuffle(mut self, shuffle: bool) -> Self {
        self.shuffle = shuffle;
        self
    }

    /// Skip each epoch's short tail batch (default `false`).
    pub fn drop_last(mut self, drop_last: bool) -> Self {
        self.drop_last = drop_last;
        self
    }

    /// RNG seed for shuffling and neighbor sampling; a fixed seed makes
    /// the full batch stream reproducible byte for byte.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Resume the stream at global batch `start` (counted from the
    /// first batch of a fresh loader) — the exact-resume entry point
    /// (docs/DESIGN.md §8): a loader built with `.start_at(k)` yields
    /// precisely what a fresh loader with the same seed yields after
    /// `k` batches. Default 0 (a fresh stream).
    pub fn start_at(mut self, start: u64) -> Self {
        self.start_at = start;
        self
    }

    /// Pipeline execution mode/depths (default: the paper's non-stop
    /// asynchronous pipeline).
    pub fn pipeline(mut self, cfg: PipelineConfig) -> Self {
        self.pipeline = cfg;
        self
    }

    /// Sampling workers for this loader's pipeline (DGL's
    /// `num_workers`); the batch stream is byte-identical for any value.
    /// Shorthand for setting [`PipelineConfig::num_workers`].
    pub fn num_workers(mut self, num_workers: usize) -> Self {
        self.pipeline.num_workers = num_workers.max(1);
        self
    }

    /// Lookahead window for the predictive prefetcher (docs/DESIGN.md
    /// §10): a background thread re-derives the next `depth` batches'
    /// remote frontiers and warms the shared feature cache ahead of
    /// demand. `0` disables it. Unset, the deployment-wide
    /// [`ClusterSpec::prefetch_depth`] applies; calling this (even with
    /// `0`) overrides the deployment default for this loader. The batch
    /// stream is byte-identical for any value — purely a throughput
    /// knob, like [`Self::num_workers`].
    ///
    /// [`ClusterSpec::prefetch_depth`]: crate::cluster::ClusterSpec::prefetch_depth
    pub fn prefetch_depth(mut self, depth: usize) -> Self {
        self.prefetch_depth = Some(depth);
        self
    }

    /// Share a metrics sink across loaders (per-batch locality/cache
    /// counters land here); default: a fresh private instance.
    pub fn metrics(mut self, metrics: Arc<Metrics>) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// Assemble the generator, launch (or inline) the pipeline, and hand
    /// back the loader.
    pub fn build(self) -> Result<DistNodeDataLoader> {
        let cluster = self.graph.cluster();
        let shape = self.vspec.shape_spec();
        if let Some(machine) = self.machine {
            ensure!(
                (machine as usize) < cluster.spec.n_machines,
                "machine {} out of range ({} machines deployed)",
                machine,
                cluster.spec.n_machines
            );
            ensure!(
                matches!(self.seeds, Seeds::Nodes(_)),
                "a machine-anchored loader needs an explicit seed set \
                 (Seeds::Nodes) — the deployment's splits are sliced by \
                 rank, not by machine"
            );
        } else {
            ensure!(
                self.rank < cluster.n_trainers(),
                "rank {} out of range ({} trainers deployed)",
                self.rank,
                cluster.n_trainers()
            );
        }
        let sampler = self
            .sampler
            .unwrap_or_else(|| NeighborSampler::from_variant(self.vspec));
        sampler.validate_for(self.vspec, &cluster.schema)?;
        let batch_size = self.batch_size.unwrap_or(shape.batch);
        ensure!(batch_size > 0, "batch_size must be positive");
        ensure!(
            batch_size <= shape.batch,
            "batch_size {} exceeds the variant's compiled batch {} (the \
             padded block layout cannot grow)",
            batch_size,
            shape.batch
        );

        // the generator the monolithic trainer used, verbatim — the
        // default-configured loader must stream byte-identical batches
        let mut gen: BatchGen = if let Some(machine) = self.machine {
            let items = match self.seeds {
                // cloned, not moved: the scheduler rebuild below (a
                // Seeds::Nodes loader is never `default_schedule`)
                // consumes `self.seeds` again
                Seeds::Nodes(ref v) => v.clone(),
                _ => unreachable!("checked above"),
            };
            cluster.batch_gen_on(machine, items, self.vspec, self.seed)
        } else {
            cluster.batch_gen(
                self.rank,
                self.vspec,
                &self.vspec.name,
                self.seed,
            )
        };
        let default_schedule = matches!(self.seeds, Seeds::Train)
            && batch_size == shape.batch
            && self.shuffle
            && !self.drop_last;
        if !default_schedule {
            gen.scheduler = match (shape.task, self.seeds) {
                (TaskKind::LinkPrediction, Seeds::Train) => {
                    BatchScheduler::for_edges_opts(
                        cluster.lp_edges(self.rank, self.seed),
                        batch_size,
                        cluster.n_nodes as u64,
                        self.seed,
                        self.shuffle,
                        self.drop_last,
                    )
                }
                // non-train seeds always iterate plain nodes — for an lp
                // variant that is the embedding-inference path
                (_, seeds) => {
                    let items: Vec<NodeId> = match seeds {
                        Seeds::Train => cluster.train_sets[self.rank].clone(),
                        Seeds::Val => cluster.val_nodes.clone(),
                        Seeds::Test => cluster.test_nodes.clone(),
                        // moved, not cloned — inference seed lists can
                        // be large
                        Seeds::Nodes(v) => v,
                    };
                    BatchScheduler::for_nodes_opts(
                        items,
                        batch_size,
                        self.seed,
                        self.shuffle,
                        self.drop_last,
                    )
                }
            };
        }
        if sampler.etype_weights().is_some() {
            gen.plan = sampler.plan(&cluster.schema);
        }
        let n_seeds = gen.scheduler.n_items();
        ensure!(n_seeds > 0, "empty seed set");
        let epoch_len = gen.batches_per_epoch();
        let pool = gen.pool.clone();
        let metrics = self
            .metrics
            .unwrap_or_else(|| Arc::new(Metrics::new()));
        // builder override > PipelineConfig > deployment-wide default
        let mut pcfg = self.pipeline;
        if let Some(depth) = self.prefetch_depth {
            pcfg.prefetch_depth = depth;
        } else if pcfg.prefetch_depth == 0 {
            pcfg.prefetch_depth = cluster.spec.prefetch_depth;
        }
        let pipeline = Pipeline::start_at(
            gen,
            &pcfg,
            metrics.clone(),
            self.start_at,
        );
        Ok(DistNodeDataLoader {
            pipeline,
            pool,
            metrics,
            epoch_len,
            // epoch accounting continues where the resumed stream is
            pos: (self.start_at % epoch_len.max(1) as u64) as usize,
            batch_size,
            n_seeds,
        })
    }
}

/// Iterator-style mini-batch loader over the deployed cluster — DGL's
/// `DistNodeDataLoader`. One loader serves one consumer (a trainer rank
/// or an inference pass); it owns the asynchronous sampling pipeline and
/// recycles spent batches through its [`BatchPool`].
///
/// Two consumption styles:
///
/// - **per-epoch iteration** — `for batch in &mut loader { .. }` yields
///   exactly [`len`](Self::len) batches, then the loader re-arms for the
///   next epoch (the idiomatic DGL loop);
/// - **endless stream** — [`next_batch`](Self::next_batch) for
///   step-counted loops like the built-in trainer.
///
/// Return finished batches via [`recycle`](Self::recycle) (or a
/// [`pool`](Self::pool) handle from inside a `for` loop) so the big
/// feature buffers keep their capacity from batch to batch.
pub struct DistNodeDataLoader {
    pipeline: Pipeline,
    pool: BatchPool,
    metrics: Arc<Metrics>,
    epoch_len: usize,
    pos: usize,
    batch_size: usize,
    n_seeds: usize,
}

impl DistNodeDataLoader {
    /// Start building a loader for `graph` that feeds `vspec`-shaped
    /// batches.
    pub fn builder<'a>(
        graph: &'a DistGraph<'a>,
        vspec: &'a VariantSpec,
    ) -> DistNodeDataLoaderBuilder<'a> {
        DistNodeDataLoaderBuilder {
            graph,
            vspec,
            seeds: Seeds::Train,
            sampler: None,
            rank: 0,
            machine: None,
            batch_size: None,
            shuffle: true,
            drop_last: false,
            seed: 7,
            start_at: 0,
            pipeline: PipelineConfig::default(),
            prefetch_depth: None,
            metrics: None,
        }
    }

    /// Mini-batches per epoch (after `drop_last`).
    pub fn len(&self) -> usize {
        self.epoch_len
    }

    pub fn is_empty(&self) -> bool {
        self.epoch_len == 0
    }

    /// Seeds this loader iterates per epoch.
    pub fn n_seeds(&self) -> usize {
        self.n_seeds
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Next mini-batch as an endless stream (wraps epochs silently) —
    /// the step-counted-loop style. Blocks until the pipeline has one
    /// ready. Panics on an unrecoverable RPC failure; fault-tolerant
    /// drivers use [`Self::try_next_batch`].
    pub fn next_batch(&mut self) -> HostBatch {
        self.try_next_batch().expect("mini-batch pipeline failed")
    }

    /// Fallible [`Self::next_batch`]: an unrecoverable RPC failure (a
    /// server outage with retries exhausted — injected or real)
    /// surfaces as a typed [`RpcError`]; the sampling workers have
    /// already drained cleanly and drop joins them (docs/DESIGN.md §8).
    pub fn try_next_batch(&mut self) -> Result<HostBatch, RpcError> {
        if self.pos >= self.epoch_len {
            self.pos = 0;
        }
        self.pos += 1;
        self.pipeline.next()
    }

    /// Hand a finished batch back for buffer reuse (never required for
    /// correctness — an unreturned batch is simply dropped).
    pub fn recycle(&self, batch: HostBatch) {
        self.pool.put(batch);
    }

    /// A clonable handle to the recycling pool, for returning batches
    /// from inside a `for` loop (which holds `&mut self`) or another
    /// thread.
    pub fn pool(&self) -> BatchPool {
        self.pool.clone()
    }

    /// The metrics sink receiving this loader's per-batch counters
    /// (`kv.remote_rows`, `cache.*`, `sampler.*`, `pipeline.*`).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }
}

impl Iterator for DistNodeDataLoader {
    type Item = HostBatch;

    /// Yields [`len`](Self::len) batches, then `None` once — after which
    /// the loader is re-armed for the next epoch. A pipeline failure
    /// also ends the epoch (cleanly, no panic); use
    /// [`try_next_batch`](DistNodeDataLoader::try_next_batch) to
    /// observe the error itself.
    fn next(&mut self) -> Option<HostBatch> {
        if self.pos >= self.epoch_len {
            self.pos = 0;
            return None;
        }
        self.pos += 1;
        self.pipeline.next().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterSpec};
    use crate::graph::DatasetSpec;
    use crate::pipeline::PipelineMode;
    use crate::runtime::manifest::artifacts_dir;
    use crate::sampler::compact::ModelKind;

    fn dev_vspec(
        model: ModelKind,
        batch: usize,
        feat_dim: usize,
        num_rels: usize,
    ) -> VariantSpec {
        VariantSpec {
            name: "loader-dev".into(),
            model,
            task: TaskKind::NodeClassification,
            batch,
            fanouts: vec![3, 3],
            layer_nodes: vec![
                (batch * 16).next_multiple_of(128),
                (batch * 4).next_multiple_of(128),
                batch.next_multiple_of(128),
            ],
            feat_dim,
            num_classes: 16,
            num_heads: 1,
            num_rels,
            param_shapes: Vec::new(),
            train_inputs: Vec::new(),
            eval_inputs: Vec::new(),
            train_hlo: String::new(),
            eval_hlo: String::new(),
            params_bin: String::new(),
        }
    }

    fn homo_cluster(cache_budget: usize) -> (Cluster, VariantSpec) {
        let mut dspec = DatasetSpec::new("loader-t", 1500, 6000);
        dspec.train_frac = 0.2;
        let d = dspec.generate();
        let mut spec = ClusterSpec::new(2, 1);
        spec.cache_budget_bytes = cache_budget;
        let c = Cluster::deploy(&d, spec, artifacts_dir()).unwrap();
        let v = dev_vspec(ModelKind::Sage, 16, d.feat_dim, 1);
        (c, v)
    }

    fn hetero_cluster(cache_budget: usize) -> (Cluster, VariantSpec) {
        let mut dspec =
            DatasetSpec::new("loader-h", 2000, 8000).with_mag_types();
        dspec.train_frac = 0.3;
        let d = dspec.generate();
        let mut spec = ClusterSpec::new(2, 1);
        spec.cache_budget_bytes = cache_budget;
        let c = Cluster::deploy(&d, spec, artifacts_dir()).unwrap();
        let v = dev_vspec(
            ModelKind::Rgcn,
            16,
            d.schema.max_feat_dim(),
            d.schema.n_etypes(),
        );
        (c, v)
    }

    fn sync_cfg() -> PipelineConfig {
        PipelineConfig { mode: PipelineMode::Sync, ..Default::default() }
    }

    fn default_loader(
        g: &DistGraph<'_>,
        v: &VariantSpec,
        seed: u64,
        mode: PipelineMode,
    ) -> DistNodeDataLoader {
        DistNodeDataLoader::builder(g, v)
            .seed(seed)
            .pipeline(PipelineConfig { mode, ..Default::default() })
            .build()
            .unwrap()
    }

    /// The acceptance gate: a default-configured loader streams batches
    /// byte-identical to the legacy trainer-internal path (the raw
    /// `Cluster::batch_gen` stream the pre-refactor `trainer::train` fed
    /// through its private pipeline), across two epochs.
    #[test]
    fn loader_stream_is_byte_identical_to_legacy_pipeline() {
        let (c, v) = homo_cluster(64 << 20);
        let g = DistGraph::new(&c);
        let seed = 5u64;
        let mut legacy = c.batch_gen(0, &v, &v.name, seed);
        let mut loader =
            default_loader(&g, &v, seed, PipelineMode::Sync);
        assert_eq!(loader.len(), legacy.batches_per_epoch());
        for step in 0..2 * loader.len() {
            assert_eq!(
                legacy.next(),
                loader.next_batch(),
                "stream diverged at step {step}"
            );
        }
    }

    /// Same acceptance through the *asynchronous* pipeline: thread
    /// hand-off must not reorder or alter the stream.
    #[test]
    fn async_loader_streams_the_same_bytes() {
        let (c, v) = homo_cluster(64 << 20);
        let g = DistGraph::new(&c);
        let mut legacy = c.batch_gen(0, &v, &v.name, 9);
        let mut loader =
            default_loader(&g, &v, 9, PipelineMode::AsyncNonstop);
        for step in 0..loader.len() + 2 {
            assert_eq!(
                legacy.next(),
                loader.next_batch(),
                "async stream diverged at step {step}"
            );
        }
    }

    #[test]
    fn same_seed_same_stream_different_seed_differs() {
        let (c, v) = homo_cluster(64 << 20);
        let g = DistGraph::new(&c);
        let mut a = default_loader(&g, &v, 11, PipelineMode::Sync);
        let mut b = default_loader(&g, &v, 11, PipelineMode::Sync);
        for _ in 0..a.len() {
            assert_eq!(a.next_batch(), b.next_batch());
        }
        let mut d = default_loader(&g, &v, 12, PipelineMode::Sync);
        let mut a2 = default_loader(&g, &v, 11, PipelineMode::Sync);
        assert_ne!(
            a2.next_batch().targets,
            d.next_batch().targets,
            "seed must change the shuffle"
        );
    }

    /// The payload must be byte-identical with the cache on and off; the
    /// `remote_rows` locality counter is the one field *allowed* to
    /// differ (hits replace fetches), so it is stripped before comparing.
    fn strip_locality(mut b: HostBatch) -> HostBatch {
        b.remote_rows = 0;
        b
    }

    #[test]
    fn cache_on_and_off_stream_identical_bytes() {
        for hetero in [false, true] {
            let ((c0, v), (c1, _)) = if hetero {
                (hetero_cluster(0), hetero_cluster(64 << 20))
            } else {
                (homo_cluster(0), homo_cluster(64 << 20))
            };
            let g0 = DistGraph::new(&c0);
            let g1 = DistGraph::new(&c1);
            let mut off = default_loader(&g0, &v, 3, PipelineMode::Sync);
            let mut on = default_loader(&g1, &v, 3, PipelineMode::Sync);
            for step in 0..2 * off.len() {
                assert_eq!(
                    strip_locality(off.next_batch()),
                    strip_locality(on.next_batch()),
                    "hetero={hetero} diverged at step {step}"
                );
            }
            assert!(
                on.metrics().counter("cache.hit_rows") > 0,
                "hetero={hetero}: warm epochs should hit the cache"
            );
        }
    }

    /// The tentpole acceptance gate: identical `HostBatch` streams for
    /// `num_workers` ∈ {1, 4} — hetero + homogeneous, cache off and on,
    /// all three pipeline modes. `remote_rows` is stripped because with
    /// a shared cache the hit/miss attribution of a row depends on which
    /// worker touched it first; the payload bytes never do.
    #[test]
    fn worker_count_never_changes_the_stream() {
        for hetero in [false, true] {
            for cache in [0usize, 64 << 20] {
                let ((c1, v), (c4, _)) = if hetero {
                    (hetero_cluster(cache), hetero_cluster(cache))
                } else {
                    (homo_cluster(cache), homo_cluster(cache))
                };
                let g1 = DistGraph::new(&c1);
                let g4 = DistGraph::new(&c4);
                for mode in [
                    PipelineMode::Sync,
                    PipelineMode::Async,
                    PipelineMode::AsyncNonstop,
                ] {
                    let mut one = default_loader(&g1, &v, 13, mode);
                    let mut four = DistNodeDataLoader::builder(&g4, &v)
                        .seed(13)
                        .pipeline(PipelineConfig {
                            mode,
                            ..Default::default()
                        })
                        .num_workers(4)
                        .build()
                        .unwrap();
                    for step in 0..2 * one.len() + 1 {
                        assert_eq!(
                            strip_locality(one.next_batch()),
                            strip_locality(four.next_batch()),
                            "hetero={hetero} cache={cache} {mode:?} \
                             step {step}"
                        );
                    }
                }
            }
        }
    }

    /// The fault-tolerance acceptance gate (docs/DESIGN.md §8): a
    /// loader built with `.start_at(k)` must stream byte-identical
    /// batches to a fresh loader after `k` batches — hetero and
    /// homogeneous, cache off and on, all three pipeline modes, worker
    /// counts 1 and 4, with `k` landing mid-second-epoch so resume
    /// crosses a reshuffle boundary.
    #[test]
    fn start_at_resumes_byte_identically_across_the_matrix() {
        for hetero in [false, true] {
            for cache in [0usize, 64 << 20] {
                let ((ca, v), (cb, _)) = if hetero {
                    (hetero_cluster(cache), hetero_cluster(cache))
                } else {
                    (homo_cluster(cache), homo_cluster(cache))
                };
                let ga = DistGraph::new(&ca);
                let gb = DistGraph::new(&cb);
                for mode in [
                    PipelineMode::Sync,
                    PipelineMode::Async,
                    PipelineMode::AsyncNonstop,
                ] {
                    for workers in [1usize, 4] {
                        let cfg = PipelineConfig {
                            mode,
                            ..Default::default()
                        };
                        let mut straight =
                            DistNodeDataLoader::builder(&ga, &v)
                                .seed(19)
                                .pipeline(cfg.clone())
                                .num_workers(workers)
                                .build()
                                .unwrap();
                        let k = straight.len() as u64 + 3;
                        for _ in 0..k {
                            let _ = straight.next_batch();
                        }
                        let mut resumed =
                            DistNodeDataLoader::builder(&gb, &v)
                                .seed(19)
                                .pipeline(cfg)
                                .num_workers(workers)
                                .start_at(k)
                                .build()
                                .unwrap();
                        for step in 0..straight.len() + 2 {
                            assert_eq!(
                                strip_locality(straight.next_batch()),
                                strip_locality(resumed.next_batch()),
                                "hetero={hetero} cache={cache} {mode:?} \
                                 x{workers}: resumed stream diverged at \
                                 step {step} past batch {k}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// A machine-anchored loader fed this rank's own seed slice must
    /// stream byte-identical batches to the rank-derived loader — the
    /// bridge the elastic trainer crosses when it rebuilds loaders from
    /// a membership re-split (docs/DESIGN.md §9).
    #[test]
    fn elastic_machine_override_streams_the_rank_path_bytes() {
        let (c, v) = homo_cluster(0);
        let g = DistGraph::new(&c);
        for rank in 0..c.n_trainers() {
            let m = c.machine_of_trainer(rank);
            let mut by_rank = DistNodeDataLoader::builder(&g, &v)
                .rank(rank)
                .seed(29 ^ (rank as u64) << 17)
                .pipeline(sync_cfg())
                .build()
                .unwrap();
            let mut by_machine = DistNodeDataLoader::builder(&g, &v)
                .machine(m)
                .seeds(Seeds::Nodes(c.train_sets[rank].clone()))
                .seed(29 ^ (rank as u64) << 17)
                .pipeline(sync_cfg())
                .build()
                .unwrap();
            assert_eq!(by_rank.len(), by_machine.len());
            for step in 0..2 * by_rank.len() {
                assert_eq!(
                    by_rank.next_batch(),
                    by_machine.next_batch(),
                    "rank {rank} diverged at step {step}"
                );
            }
        }
        // a machine override without an explicit seed set is rejected,
        // as is an out-of-range machine
        assert!(DistNodeDataLoader::builder(&g, &v)
            .machine(0)
            .build()
            .is_err());
        assert!(DistNodeDataLoader::builder(&g, &v)
            .machine(9)
            .seeds(Seeds::Nodes(vec![1, 2, 3]))
            .build()
            .is_err());
    }

    /// The shrink ≡ fresh-resume contract at the loader layer: a (2,2)
    /// deployment re-split for one trainer per machine and resumed at
    /// batch `k` must stream byte-identical batches to a fresh (2,1)
    /// deployment's rank loaders resumed at the same `k` — hetero and
    /// homogeneous, sampling workers 1 and 4, with `k` mid-second-epoch
    /// so the resume crosses a reshuffle boundary.
    #[test]
    fn elastic_shrink_resplit_matches_a_fresh_smaller_deploy() {
        for hetero in [false, true] {
            let (mk_big, v): (Cluster, VariantSpec) = {
                let (spec_d, vv) = if hetero {
                    let mut dspec = DatasetSpec::new("loader-h", 2000, 8000)
                        .with_mag_types();
                    dspec.train_frac = 0.3;
                    let d = dspec.generate();
                    let vv = dev_vspec(
                        ModelKind::Rgcn,
                        16,
                        d.schema.max_feat_dim(),
                        d.schema.n_etypes(),
                    );
                    (d, vv)
                } else {
                    let mut dspec = DatasetSpec::new("loader-t", 1500, 6000);
                    dspec.train_frac = 0.2;
                    let d = dspec.generate();
                    let vv = dev_vspec(ModelKind::Sage, 16, d.feat_dim, 1);
                    (d, vv)
                };
                let mut spec = ClusterSpec::new(2, 2);
                spec.cache_budget_bytes = 0;
                (Cluster::deploy(&spec_d, spec, artifacts_dir()).unwrap(), vv)
            };
            let small = {
                let d = if hetero {
                    let mut dspec = DatasetSpec::new("loader-h", 2000, 8000)
                        .with_mag_types();
                    dspec.train_frac = 0.3;
                    dspec.generate()
                } else {
                    let mut dspec = DatasetSpec::new("loader-t", 1500, 6000);
                    dspec.train_frac = 0.2;
                    dspec.generate()
                };
                let mut spec = ClusterSpec::new(2, 1);
                spec.cache_budget_bytes = 0;
                Cluster::deploy(&d, spec, artifacts_dir()).unwrap()
            };
            let big = mk_big;
            // the re-split for machines {0,1} x 1 trainer IS the fresh
            // deployment's split
            let sets = big.train_sets_for(&[0, 1], 1);
            assert_eq!(sets, small.train_sets, "hetero={hetero}");
            let gbig = DistGraph::new(&big);
            let gsmall = DistGraph::new(&small);
            for workers in [1usize, 4] {
                for r in 0..2usize {
                    let seed = 19 ^ (r as u64) << 17;
                    let mut fresh = DistNodeDataLoader::builder(&gsmall, &v)
                        .rank(r)
                        .seed(seed)
                        .num_workers(workers)
                        .build()
                        .unwrap();
                    let k = fresh.len() as u64 + 2;
                    let mut shrunk = DistNodeDataLoader::builder(&gbig, &v)
                        .machine(r as u32)
                        .seeds(Seeds::Nodes(sets[r].clone()))
                        .seed(seed)
                        .start_at(k)
                        .num_workers(workers)
                        .build()
                        .unwrap();
                    for _ in 0..k {
                        let _ = fresh.next_batch();
                    }
                    for step in 0..fresh.len() + 2 {
                        assert_eq!(
                            strip_locality(fresh.next_batch()),
                            strip_locality(shrunk.next_batch()),
                            "hetero={hetero} x{workers} rank {r}: \
                             shrunk stream diverged at step {step}"
                        );
                    }
                }
            }
        }
    }

    /// An unrecoverable injected outage must surface from
    /// `try_next_batch` as the typed error — no panic — after which the
    /// loader drops cleanly (satellite 2 at the API layer).
    #[test]
    fn injected_outage_surfaces_as_typed_error_and_drains() {
        use crate::ft::{FailWindow, FaultPlan};
        let (c, v) = homo_cluster(0);
        let g = DistGraph::new(&c);
        let mut plan = FaultPlan::new();
        // machine 1's KV server dies for good after 4 admitted RPCs
        plan.kv_outages.push(FailWindow::permanent(1, 4));
        plan.backoff = std::time::Duration::ZERO;
        c.set_fault_plan(Arc::new(plan));
        let mut loader = DistNodeDataLoader::builder(&g, &v)
            .num_workers(2)
            .build()
            .unwrap();
        let mut saw = Option::None;
        for _ in 0..4 * loader.len() {
            match loader.try_next_batch() {
                Ok(b) => loader.recycle(b),
                Err(e) => {
                    saw = Some(e);
                    break;
                }
            }
        }
        assert_eq!(
            saw,
            Some(crate::net::RpcError::ServerDown {
                machine: 1,
                role: "kv"
            }),
            "outage never surfaced as a typed error"
        );
        drop(loader); // joins the drained worker pool without hanging
    }

    /// Serial vs concurrent per-owner RPC fan-out: identical batches
    /// (including `remote_rows` — no cache here) and identical modeled
    /// network bytes, on a 3-machine deployment so several remote owners
    /// are in flight at once.
    #[test]
    fn serial_and_concurrent_rpc_stream_identical_bytes() {
        let mk = |concurrent: bool| {
            let mut dspec = DatasetSpec::new("loader-rpc", 1500, 6000);
            dspec.train_frac = 0.2;
            let d = dspec.generate();
            let mut spec = ClusterSpec::new(3, 1);
            spec.cache_budget_bytes = 0;
            spec.concurrent_rpc = concurrent;
            let c = Cluster::deploy(&d, spec, artifacts_dir()).unwrap();
            let v = dev_vspec(ModelKind::Sage, 16, d.feat_dim, 1);
            (c, v)
        };
        let (cs, v) = mk(false);
        let (cc, _) = mk(true);
        let gs = DistGraph::new(&cs);
        let gc = DistGraph::new(&cc);
        let mut serial = default_loader(&gs, &v, 23, PipelineMode::Sync);
        let mut conc = default_loader(&gc, &v, 23, PipelineMode::Sync);
        for step in 0..2 * serial.len() {
            assert_eq!(
                serial.next_batch(),
                conc.next_batch(),
                "fan-out strategy changed the stream at step {step}"
            );
        }
        assert_eq!(
            cs.cost.network_bytes(),
            cc.cost.network_bytes(),
            "fan-out strategy changed the modeled bytes"
        );
    }

    /// Recycling through the shared pool under a real worker pool must
    /// not change any produced batch (workers reuse returned buffers).
    #[test]
    fn worker_pool_with_recycling_streams_identical_bytes() {
        let (c, v) = homo_cluster(0);
        let g = DistGraph::new(&c);
        let mut fresh = default_loader(&g, &v, 17, PipelineMode::Sync);
        let mut pooled = DistNodeDataLoader::builder(&g, &v)
            .seed(17)
            .num_workers(3)
            .build()
            .unwrap();
        for step in 0..3 * fresh.len() {
            let a = fresh.next_batch();
            let b = pooled.next_batch();
            assert_eq!(a, b, "step {step}");
            pooled.recycle(b);
        }
        assert!(
            pooled.metrics().counter("pool.hit") > 0,
            "workers never reused a recycled batch"
        );
    }

    #[test]
    fn hetero_loader_matches_legacy_and_meters_etypes() {
        let (c, v) = hetero_cluster(64 << 20);
        let g = DistGraph::new(&c);
        let mut legacy = c.batch_gen(0, &v, &v.name, 21);
        let mut loader = default_loader(&g, &v, 21, PipelineMode::Sync);
        for step in 0..2 * loader.len() {
            assert_eq!(
                legacy.next(),
                loader.next_batch(),
                "hetero stream diverged at step {step}"
            );
        }
        let mut typed = 0u64;
        for r in 0..v.num_rels {
            typed += loader
                .metrics()
                .counter(&format!("sampler.etype_edges.{r}"));
        }
        assert!(typed > 0, "no per-etype counters metered");
    }

    #[test]
    fn etype_weight_override_redirects_the_fanout() {
        let (c, v) = hetero_cluster(0);
        let g = DistGraph::new(&c);
        let mut w = vec![0usize; v.num_rels];
        w[0] = 1; // all of each layer's K to relation 0
        let mut loader = DistNodeDataLoader::builder(&g, &v)
            .sampler(
                NeighborSampler::from_variant(&v).with_etype_weights(w),
            )
            .pipeline(sync_cfg())
            .build()
            .unwrap();
        for _ in 0..loader.len() {
            let b = loader.next_batch();
            loader.recycle(b);
        }
        assert!(
            loader.metrics().counter("sampler.etype_edges.0") > 0,
            "weighted relation never sampled"
        );
        for r in 1..v.num_rels {
            assert_eq!(
                loader
                    .metrics()
                    .counter(&format!("sampler.etype_edges.{r}")),
                0,
                "zero-weighted relation {r} was sampled"
            );
        }
    }

    #[test]
    fn no_shuffle_chunks_the_seed_list_in_order() {
        let (c, v) = homo_cluster(0);
        let g = DistGraph::new(&c);
        let nodes: Vec<NodeId> = (100..165).collect();
        let mut loader = DistNodeDataLoader::builder(&g, &v)
            .seeds(Seeds::Nodes(nodes.clone()))
            .shuffle(false)
            .pipeline(sync_cfg())
            .build()
            .unwrap();
        assert_eq!(loader.n_seeds(), 65);
        assert_eq!(loader.len(), 5); // ceil(65 / 16)
        for _epoch in 0..2 {
            let mut seen = Vec::new();
            for _ in 0..loader.len() {
                seen.extend(loader.next_batch().targets);
            }
            assert_eq!(seen, nodes, "inference order must be preserved");
        }
    }

    #[test]
    fn drop_last_trims_len_and_keeps_batches_full() {
        let (c, v) = homo_cluster(0);
        let g = DistGraph::new(&c);
        let nodes: Vec<NodeId> = (0..65).collect();
        let mut loader = DistNodeDataLoader::builder(&g, &v)
            .seeds(Seeds::Nodes(nodes))
            .drop_last(true)
            .pipeline(sync_cfg())
            .build()
            .unwrap();
        assert_eq!(loader.len(), 4); // floor(65 / 16)
        for _ in 0..2 * loader.len() {
            assert_eq!(loader.next_batch().targets.len(), 16);
        }
    }

    #[test]
    fn builder_rejects_bad_configurations() {
        let (c, v) = homo_cluster(0);
        let g = DistGraph::new(&c);
        // batch larger than the compiled layout
        assert!(DistNodeDataLoader::builder(&g, &v)
            .batch_size(v.batch + 1)
            .build()
            .is_err());
        // empty seed set
        assert!(DistNodeDataLoader::builder(&g, &v)
            .seeds(Seeds::Nodes(Vec::new()))
            .build()
            .is_err());
        // out-of-range rank
        assert!(DistNodeDataLoader::builder(&g, &v)
            .rank(99)
            .build()
            .is_err());
        // mismatched sampler fanouts
        assert!(DistNodeDataLoader::builder(&g, &v)
            .sampler(NeighborSampler::new(vec![9]))
            .build()
            .is_err());
    }

    #[test]
    fn iterator_yields_one_epoch_then_rearms() {
        let (c, v) = homo_cluster(0);
        let g = DistGraph::new(&c);
        let mut loader = DistNodeDataLoader::builder(&g, &v)
            .seeds(Seeds::Val)
            .shuffle(false)
            .pipeline(sync_cfg())
            .build()
            .unwrap();
        let expect = c.val_nodes.len().div_ceil(16);
        assert_eq!(loader.len(), expect);
        for _epoch in 0..2 {
            let mut n = 0usize;
            let mut seen = std::collections::BTreeSet::new();
            let pool = loader.pool();
            for batch in &mut loader {
                n += 1;
                seen.extend(batch.targets.iter().copied());
                pool.put(batch); // recycling from inside the loop
            }
            assert_eq!(n, expect, "epoch must end after len() batches");
            assert_eq!(seen.len(), c.val_nodes.len());
        }
        assert!(!loader.pool().is_empty(), "recycled batches not pooled");
    }

    #[test]
    fn recycling_does_not_change_the_stream() {
        let (c, v) = homo_cluster(0);
        let g = DistGraph::new(&c);
        let mut fresh = default_loader(&g, &v, 17, PipelineMode::Sync);
        let mut pooled = default_loader(&g, &v, 17, PipelineMode::Sync);
        for step in 0..2 * fresh.len() {
            let a = fresh.next_batch();
            let b = pooled.next_batch();
            assert_eq!(a, b, "step {step}");
            pooled.recycle(b);
        }
    }

    #[test]
    fn lp_variant_trains_through_the_loader() {
        let (c, _) = homo_cluster(0);
        let g = DistGraph::new(&c);
        let mut v = dev_vspec(ModelKind::Sage, 16, 32, 1);
        v.task = TaskKind::LinkPrediction;
        // default (Train) seeds keep the legacy edge scheduler…
        let mut legacy = c.batch_gen(0, &v, &v.name, 31);
        let mut loader = default_loader(&g, &v, 31, PipelineMode::Sync);
        for step in 0..loader.len() {
            assert_eq!(
                legacy.next(),
                loader.next_batch(),
                "lp stream diverged at step {step}"
            );
        }
        // …and non-default options rebuild it deterministically
        let mut a = DistNodeDataLoader::builder(&g, &v)
            .drop_last(true)
            .seed(31)
            .pipeline(sync_cfg())
            .build()
            .unwrap();
        let mut b = DistNodeDataLoader::builder(&g, &v)
            .drop_last(true)
            .seed(31)
            .pipeline(sync_cfg())
            .build()
            .unwrap();
        for _ in 0..a.len() {
            let ba = a.next_batch();
            assert_eq!(ba.pair_mask.iter().sum::<f32>(), 16.0);
            assert_eq!(ba, b.next_batch());
        }
    }

    /// The prefetch tentpole's acceptance gate at the API layer: the
    /// batch stream is byte-identical with the predictive prefetcher
    /// off and on — lookahead depth {2, 8} × all three pipeline modes ×
    /// sampling workers {1, 4} × cache admission {all, degree} — and in
    /// every cell the prefetcher actually issued pulls (the gate is not
    /// vacuous). `remote_rows` is stripped as usual: prefetch turns
    /// demand fetches into hits, never changes payload bytes.
    #[test]
    fn prefetch_never_changes_the_stream_across_the_matrix() {
        use crate::kvstore::CacheAdmission;
        let mk = |admission: &CacheAdmission| {
            let mut dspec = DatasetSpec::new("loader-pf", 1500, 6000);
            dspec.train_frac = 0.2;
            let d = dspec.generate();
            let mut spec = ClusterSpec::new(2, 1);
            spec.cache_budget_bytes = 32 << 20;
            spec.cache_admission = admission.clone();
            let c = Cluster::deploy(&d, spec, artifacts_dir()).unwrap();
            let v = dev_vspec(ModelKind::Sage, 16, d.feat_dim, 1);
            (c, v)
        };
        for admission in
            [CacheAdmission::All, CacheAdmission::Degree(Option::None)]
        {
            let (c0, v) = mk(&admission);
            let g0 = DistGraph::new(&c0);
            let mut base = DistNodeDataLoader::builder(&g0, &v)
                .seed(37)
                .prefetch_depth(0)
                .pipeline(sync_cfg())
                .build()
                .unwrap();
            let expect: Vec<HostBatch> = (0..2 * base.len())
                .map(|_| strip_locality(base.next_batch()))
                .collect();
            for depth in [2usize, 8] {
                for mode in [
                    PipelineMode::Sync,
                    PipelineMode::Async,
                    PipelineMode::AsyncNonstop,
                ] {
                    for workers in [1usize, 4] {
                        let (c1, _) = mk(&admission);
                        let g1 = DistGraph::new(&c1);
                        let mut on = DistNodeDataLoader::builder(&g1, &v)
                            .seed(37)
                            .prefetch_depth(depth)
                            .pipeline(PipelineConfig {
                                mode,
                                ..Default::default()
                            })
                            .num_workers(workers)
                            .build()
                            .unwrap();
                        let m = on.metrics().clone();
                        for (step, want) in expect.iter().enumerate() {
                            assert_eq!(
                                *want,
                                strip_locality(on.next_batch()),
                                "{admission:?} depth={depth} {mode:?} \
                                 x{workers} diverged at step {step}"
                            );
                        }
                        drop(on);
                        assert!(
                            m.counter("cache.prefetch_issued") > 0,
                            "{admission:?} depth={depth} {mode:?} \
                             x{workers}: prefetcher never issued a pull"
                        );
                    }
                }
            }
        }
    }

    /// Loader half of the §11 multi-process equivalence invariant: a
    /// machine process that deploys its *own* cluster replica from the
    /// same config (what every `examples/launch.rs` process does) sees
    /// exactly the batch stream the shared single-process deployment
    /// produces for its ranks, across two epochs. With the ring
    /// all-reduce equivalence (`tcp_ring_matches_in_process_ring`),
    /// this is why crossing OS-process boundaries cannot perturb
    /// training.
    #[test]
    fn replicated_deployments_stream_identical_batches() {
        let mut dspec = DatasetSpec::new("launch-eq", 1500, 6000);
        dspec.train_frac = 0.2;
        let d = dspec.generate();
        let spec = ClusterSpec::new(2, 1);
        let shared =
            Cluster::deploy(&d, spec.clone(), artifacts_dir()).unwrap();
        let v = dev_vspec(ModelKind::Sage, 16, d.feat_dim, 1);
        for rank in 0..2usize {
            // a separate "process": regenerate and redeploy from the
            // same RunConfig-derived specs
            let replica = Cluster::deploy(
                &dspec.generate(),
                spec.clone(),
                artifacts_dir(),
            )
            .unwrap();
            let g_shared = DistGraph::new(&shared);
            let g_replica = DistGraph::new(&replica);
            let seed = 7u64 ^ ((rank as u64) << 17);
            let mut a = DistNodeDataLoader::builder(&g_shared, &v)
                .rank(rank)
                .seed(seed)
                .pipeline(sync_cfg())
                .build()
                .unwrap();
            let mut b = DistNodeDataLoader::builder(&g_replica, &v)
                .rank(rank)
                .seed(seed)
                .pipeline(sync_cfg())
                .build()
                .unwrap();
            for epoch in 0..2 {
                let ea: Vec<HostBatch> = (&mut a).collect();
                let eb: Vec<HostBatch> = (&mut b).collect();
                assert!(!ea.is_empty());
                assert_eq!(
                    ea, eb,
                    "rank {rank} epoch {epoch}: replica deployment \
                     diverged from the shared one"
                );
            }
        }
    }
}
