//! [`DistGraph`]: the DGL-style graph handle over a deployed cluster.

use std::sync::OnceLock;

use crate::cluster::Cluster;
use crate::graph::{GraphSchema, NodeId};

/// A cheap, read-only handle over a deployed [`Cluster`] exposing the
/// DGL `DistGraph` surface: typed counts, schema, feature pulls through
/// the distributed KVStore, and the training-set splits. Construction is
/// O(1); the per-type count tables behind [`Self::num_nodes`] /
/// [`Self::num_edges`] are built lazily on first use (one pass over the
/// partitions), so handles created only to feed data loaders — the
/// built-in trainer's case — never pay the scan.
pub struct DistGraph<'a> {
    cluster: &'a Cluster,
    /// Nodes per ntype (index = schema ntype id), built on first query.
    ntype_counts: OnceLock<Vec<usize>>,
    /// Stored (directed) edges per etype (index = schema etype id),
    /// built on first query.
    etype_counts: OnceLock<Vec<u64>>,
}

impl<'a> DistGraph<'a> {
    pub fn new(cluster: &'a Cluster) -> Self {
        Self {
            cluster,
            ntype_counts: OnceLock::new(),
            etype_counts: OnceLock::new(),
        }
    }

    fn ntype_counts(&self) -> &[usize] {
        self.ntype_counts.get_or_init(|| {
            let mut counts = vec![0usize; self.schema().n_ntypes()];
            if self.cluster.features.node_type.is_empty() {
                counts[0] = self.cluster.n_nodes;
            } else {
                for &t in self.cluster.features.node_type.iter() {
                    counts[t as usize] += 1;
                }
            }
            counts
        })
    }

    fn etype_counts(&self) -> &[u64] {
        self.etype_counts.get_or_init(|| {
            // every core vertex's full adjacency (with rels) is local to
            // its owner partition, so summing core rows covers each
            // stored edge exactly once
            let mut counts = vec![0u64; self.schema().n_etypes()];
            for p in &self.cluster.partitions {
                for l in 0..p.n_core as NodeId {
                    let rels = p.graph.rel_of(l);
                    if rels.is_empty() {
                        counts[0] += p.graph.degree(l) as u64;
                    } else {
                        for &r in rels {
                            counts[r as usize] += 1;
                        }
                    }
                }
            }
            counts
        })
    }

    /// The deployed cluster behind this handle.
    pub fn cluster(&self) -> &Cluster {
        self.cluster
    }

    /// The dataset's typed schema (trivial for homogeneous graphs).
    pub fn schema(&self) -> &GraphSchema {
        &self.cluster.schema
    }

    /// Nodes of one type, by schema name (homogeneous graphs: `"node"`).
    /// Panics on an unknown ntype name, like DGL's keyed access.
    pub fn num_nodes(&self, ntype: &str) -> usize {
        self.ntype_counts()[self.ntype_id(ntype)]
    }

    /// Total nodes across every type.
    pub fn num_nodes_total(&self) -> usize {
        self.cluster.n_nodes
    }

    /// Stored (directed) edges of one type, by schema name (homogeneous
    /// graphs: `"edge"`). Panics on an unknown etype name.
    pub fn num_edges(&self, etype: &str) -> u64 {
        self.etype_counts()[self.etype_id(etype)]
    }

    /// Total stored (directed) edges across every type.
    pub fn num_edges_total(&self) -> u64 {
        self.cluster.n_edges as u64
    }

    /// Schema id of an ntype name.
    pub fn ntype_id(&self, ntype: &str) -> usize {
        self.schema()
            .ntypes
            .iter()
            .position(|t| t.name == ntype)
            .unwrap_or_else(|| {
                panic!(
                    "unknown ntype {ntype:?}; schema has {:?}",
                    self.schema()
                        .ntypes
                        .iter()
                        .map(|t| t.name.as_str())
                        .collect::<Vec<_>>()
                )
            })
    }

    /// Schema id of an etype name.
    pub fn etype_id(&self, etype: &str) -> usize {
        self.schema()
            .etypes
            .iter()
            .position(|t| t.name == etype)
            .unwrap_or_else(|| {
                panic!(
                    "unknown etype {etype:?}; schema has {:?}",
                    self.schema()
                        .etypes
                        .iter()
                        .map(|t| t.name.as_str())
                        .collect::<Vec<_>>()
                )
            })
    }

    /// Node type ids (all zero for homogeneous graphs) of the given nodes.
    pub fn ntypes_of(&self, nodes: &[NodeId]) -> Vec<u8> {
        nodes
            .iter()
            .map(|&v| self.cluster.features.ntype_of(v))
            .collect()
    }

    /// Row width of [`Self::ndata`] pulls: the widest per-ntype feature
    /// dim (narrower types are zero-padded on the right, exactly like
    /// mini-batch feature rows).
    pub fn ndata_dim(&self) -> usize {
        self.schema().max_feat_dim()
    }

    /// Pull feature rows for arbitrary nodes through the distributed
    /// KVStore — DGL's `g.ndata["feat"][nids]`. Returns row-major
    /// `nodes.len() x ndata_dim()` with each row's typed prefix filled
    /// from its ntype's table via
    /// [`pull_typed`](crate::kvstore::KvClient::pull_typed); remote rows
    /// are metered on the cluster cost model like any trainer pull.
    pub fn ndata(&self, nodes: &[NodeId]) -> Vec<f32> {
        let dim = self.ndata_dim();
        let mut out = vec![0f32; nodes.len() * dim];
        let mut kv = self
            .cluster
            .kv
            .client(0, self.cluster.policy.clone());
        kv.pull_typed(&self.cluster.features, nodes, &mut out, dim)
            .expect("feature tables registered at deploy");
        out
    }

    /// Host-side labels of the given nodes (accuracy computation in
    /// custom loops).
    pub fn node_labels(&self, nodes: &[NodeId]) -> Vec<u16> {
        nodes
            .iter()
            .map(|&v| self.cluster.labels[v as usize])
            .collect()
    }

    pub fn num_classes(&self) -> usize {
        self.cluster.num_classes
    }

    /// Trainers in the deployment (ranks `0..n_trainers()`).
    pub fn n_trainers(&self) -> usize {
        self.cluster.n_trainers()
    }

    /// This rank's slice of the training set (the §5.6.1 locality-aware
    /// split; all ranks hold equally many items).
    pub fn train_idx(&self, rank: usize) -> &[NodeId] {
        &self.cluster.train_sets[rank]
    }

    pub fn val_idx(&self) -> &[NodeId] {
        &self.cluster.val_nodes
    }

    pub fn test_idx(&self) -> &[NodeId] {
        &self.cluster.test_nodes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::graph::DatasetSpec;
    use crate::runtime::manifest::artifacts_dir;

    fn homo_graph_cluster() -> Cluster {
        let d = DatasetSpec::new("api-g", 1500, 6000).generate();
        Cluster::deploy(&d, ClusterSpec::new(2, 2), artifacts_dir()).unwrap()
    }

    fn hetero_cluster() -> Cluster {
        let mut dspec =
            DatasetSpec::new("api-h", 2000, 8000).with_mag_types();
        dspec.train_frac = 0.3;
        let d = dspec.generate();
        Cluster::deploy(&d, ClusterSpec::new(2, 1), artifacts_dir()).unwrap()
    }

    #[test]
    fn homogeneous_counts_cover_the_graph() {
        let c = homo_graph_cluster();
        let g = DistGraph::new(&c);
        assert_eq!(g.num_nodes("node"), c.n_nodes);
        assert_eq!(g.num_edges("edge"), c.n_edges as u64);
        assert_eq!(g.num_nodes_total(), c.n_nodes);
        assert_eq!(g.num_edges_total(), c.n_edges as u64);
    }

    #[test]
    fn typed_counts_partition_the_totals() {
        let c = hetero_cluster();
        let g = DistGraph::new(&c);
        let schema = g.schema().clone();
        assert_eq!(schema.n_ntypes(), 3);
        let n_sum: usize = schema
            .ntypes
            .iter()
            .map(|t| g.num_nodes(&t.name))
            .sum();
        assert_eq!(n_sum, c.n_nodes);
        let e_sum: u64 = schema
            .etypes
            .iter()
            .map(|t| g.num_edges(&t.name))
            .sum();
        assert_eq!(e_sum, c.n_edges as u64);
        // papers dominate a mag-shaped graph
        assert!(g.num_nodes(&schema.ntypes[0].name) > c.n_nodes / 3);
    }

    #[test]
    #[should_panic(expected = "unknown ntype")]
    fn unknown_ntype_panics_with_the_vocabulary() {
        let c = homo_graph_cluster();
        DistGraph::new(&c).num_nodes("paper");
    }

    #[test]
    fn ndata_pulls_match_batch_feature_rows() {
        let c = homo_graph_cluster();
        let g = DistGraph::new(&c);
        let nodes: Vec<NodeId> = c.train_sets[0][..8].to_vec();
        let rows = g.ndata(&nodes);
        assert_eq!(rows.len(), nodes.len() * g.ndata_dim());
        // dense gaussian features: every pulled row must be non-zero
        for (i, row) in rows.chunks(g.ndata_dim()).enumerate() {
            assert!(
                row.iter().any(|&x| x != 0.0),
                "row {i} (node {}) came back empty",
                nodes[i]
            );
        }
        // deterministic (same KVStore contents)
        assert_eq!(rows, g.ndata(&nodes));
    }

    #[test]
    fn splits_are_exposed_per_rank() {
        let c = homo_graph_cluster();
        let g = DistGraph::new(&c);
        assert_eq!(g.n_trainers(), 4);
        let len0 = g.train_idx(0).len();
        assert!(len0 > 0);
        for r in 1..g.n_trainers() {
            assert_eq!(g.train_idx(r).len(), len0);
        }
        assert!(!g.val_idx().is_empty());
        assert_eq!(
            g.node_labels(&g.train_idx(0)[..4]).len(),
            4
        );
    }
}
