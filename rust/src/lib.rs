//! # DistDGLv2 — distributed hybrid CPU/GPU GNN training
//!
//! A from-scratch reproduction of *"Distributed Hybrid CPU and GPU training
//! for Graph Neural Networks on Billion-Scale Graphs"* (Zheng et al., 2021)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the coordinator: graph storage, multilevel
//!   multi-constraint partitioning, the distributed KV store, vertex-wise
//!   distributed neighbor sampling, the 5-stage asynchronous mini-batch
//!   generation pipeline, and synchronous data-parallel SGD across a
//!   simulated multi-machine cluster.
//! - **Layer 2 (python/compile/model.py)** — GraphSAGE / GAT / RGCN
//!   forward+backward+SGD traced by JAX and AOT-lowered to HLO text.
//! - **Layer 1 (python/compile/kernels/)** — Pallas kernels for the
//!   neighbor-aggregation hot-spots, verified against pure-jnp oracles.
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! compute once; [`runtime`] loads the HLO via the PJRT C API and the rest
//! of the system is pure Rust.
//!
//! Start with the DGL-shaped public surface in [`api`]:
//! [`api::DistGraph`] over a deployed [`cluster::Cluster`], and
//! [`api::DistNodeDataLoader`] for mini-batches — any loop can drain it
//! (`examples/custom_loop.rs` shows a hand-written train + inference
//! loop). [`trainer::train`] is the built-in synchronous-SGD driver, a
//! thin client of the same API; `examples/quickstart.rs` is the smallest
//! end-to-end run. The DGL → rust_pallas correspondence table lives in
//! docs/DESIGN.md §7.

pub mod api;
pub mod baselines;
pub mod benchsuite;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod ft;
pub mod graph;
pub mod kvstore;
pub mod metrics;
pub mod net;
pub mod partition;
pub mod pipeline;
pub mod runtime;
pub mod sampler;
pub mod trainer;
pub mod util;
