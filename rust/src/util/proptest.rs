//! In-tree property-testing helper (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, check)` runs `check` against `cases` random
//! inputs produced by `gen`; on failure it reports the failing case index +
//! seed so the exact input can be replayed deterministically.

use super::rng::Rng;

/// Run `check` on `cases` generated inputs; panics with replay info on the
/// first failure. `check` returns `Err(msg)` (or panics) to signal failure.
pub fn forall<T, G, C>(seed: u64, cases: usize, mut gen: G, mut check: C)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed (seed={seed}, case={case}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_on_true_property() {
        forall(
            1,
            50,
            |r| (r.below(100), r.below(100)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("addition not commutative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_with_replay_info() {
        forall(
            2,
            50,
            |r| r.below(10),
            |&x| if x < 5 { Ok(()) } else { Err(format!("x={x} >= 5")) },
        );
    }
}
