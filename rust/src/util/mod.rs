//! Small self-contained utilities (offline environment: no rand/serde/
//! criterion crates, so the pieces we need live here, tested).

pub mod bench;
pub mod json;
pub mod proptest;
pub mod rng;

pub use rng::Rng;
