//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Bench binaries (`cargo bench`, harness = false) use [`BenchRunner`] to
//! warm up, sample wall-clock times, and print a stable `name: median ±
//! spread` line plus machine-readable rows the EXPERIMENTS.md tables are
//! generated from.

use std::time::{Duration, Instant};

/// One measured series (e.g. "epoch time, 8 GPUs").
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters: usize,
}

impl Sample {
    pub fn secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

pub struct BenchRunner {
    pub warmup: usize,
    pub iters: usize,
    pub results: Vec<Sample>,
}

impl Default for BenchRunner {
    fn default() -> Self {
        Self { warmup: 1, iters: 5, results: Vec::new() }
    }
}

impl BenchRunner {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Self { warmup, iters, results: Vec::new() }
    }

    /// Time `f` (called once per iteration); returns the median duration.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Sample {
        for _ in 0..self.warmup {
            f();
        }
        let mut times: Vec<Duration> = (0..self.iters.max(1))
            .map(|_| {
                let t = Instant::now();
                f();
                t.elapsed()
            })
            .collect();
        times.sort();
        let s = Sample {
            name: name.to_string(),
            median: times[times.len() / 2],
            min: times[0],
            max: times[times.len() - 1],
            iters: self.iters,
        };
        println!(
            "{:<48} {:>10.3?} (min {:.3?}, max {:.3?}, n={})",
            s.name, s.median, s.min, s.max, s.iters
        );
        self.results.push(s.clone());
        s
    }

    /// Record an externally measured value (e.g. modeled time).
    pub fn record(&mut self, name: &str, d: Duration) -> Sample {
        let s = Sample {
            name: name.to_string(),
            median: d,
            min: d,
            max: d,
            iters: 1,
        };
        println!("{:<48} {:>10.3?} (recorded)", s.name, s.median);
        self.results.push(s.clone());
        s
    }

    /// Print a ratio table `rows[i] vs base` (the "speedup over X" the paper
    /// reports in its figures).
    pub fn speedup_table(&self, title: &str, base: &str) {
        let base_s = match self.results.iter().find(|s| s.name == base) {
            Some(s) => s.secs(),
            None => return,
        };
        println!("\n== {title} (speedup over {base}) ==");
        for s in &self.results {
            println!("{:<48} {:>8.2}x", s.name, base_s / s.secs());
        }
    }
}

/// Format a `f64` seconds value the way the tables print it.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_sample() {
        let mut r = BenchRunner::new(0, 3);
        let s = r.bench("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min <= s.median && s.median <= s.max);
        assert_eq!(r.results.len(), 1);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
        assert_eq!(fmt_secs(2.5e-5), "25.0us");
    }
}
