//! Minimal JSON parser for artifacts/manifest.json (no serde offline).
//!
//! Supports the full JSON grammar we emit from python: objects, arrays,
//! strings (with escapes), numbers, booleans, null. Not intended as a
//! general-purpose library; inputs are trusted build artifacts.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // collect the raw UTF-8 byte run
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| {
            anyhow!("bad number {txt:?} at byte {start}: {e}")
        })?))
    }
}

/// Minimal JSON writer (for run reports / EXPERIMENTS.md data dumps).
pub fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => {
                        out.push_str(&format!("\\u{:04x}", c as u32))
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(&Json::Str(k.clone()), out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{
          "block": 128,
          "variants": {
            "sage_nc_dev": {
              "fanouts": [5, 5],
              "layer_nodes": [1920, 512, 128],
              "train_hlo": "sage_nc_dev.train.hlo.txt",
              "inputs": [{"name": "feats", "shape": [1920, 32], "dtype": "f32"}]
            }
          }
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("block").unwrap().as_usize().unwrap(), 128);
        let v = j.get("variants").unwrap().get("sage_nc_dev").unwrap();
        assert_eq!(v.get("fanouts").unwrap().usize_arr().unwrap(), vec![5, 5]);
        assert_eq!(
            v.get("train_hlo").unwrap().as_str().unwrap(),
            "sage_nc_dev.train.hlo.txt"
        );
        let inputs = v.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].get("name").unwrap().as_str().unwrap(), "feats");
    }

    #[test]
    fn parses_scalars_and_escapes() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":false}}"#;
        let j = Json::parse(doc).unwrap();
        let mut s = String::new();
        write_json(&j, &mut s);
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
