//! Deterministic, splittable PRNG (xoshiro256**) used everywhere randomness
//! is needed: graph generation, neighbor sampling, negative sampling,
//! property tests. Seeded runs are fully reproducible across machines.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let mut s = [next(), next(), next(), next()];
        if s.iter().all(|&x| x == 0) {
            s[0] = 1; // the all-zero state is invalid
        }
        Rng { s }
    }

    /// Derive an independent stream (e.g. per machine / per trainer).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Pure-function stream derivation: the generator for a hierarchical
    /// coordinate `path` under `seed` (e.g. `[epoch, batch_idx, lane]`).
    /// Unlike a sequential [`Self::split`] chain threaded through mutable
    /// state, this depends on *nothing but its arguments* — any worker
    /// can reconstruct the stream for any coordinate independently, which
    /// is what makes the parallel mini-batch pipeline order-free.
    pub fn for_path(seed: u64, path: &[u64]) -> Rng {
        let mut r = Rng::new(seed ^ 0x5851_F42D_4C95_7F2D);
        for &p in path {
            r = r.split(p);
        }
        r
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, bound) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct items from `0..n` (floyd's algorithm when k << n,
    /// shuffle otherwise). Order is unspecified.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        // Floyd's: for j in n-k..n pick t in [0..=j]; if taken, use j.
        let mut set = rustc_hash::FxHashSet::default();
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.usize_below(j + 1);
            let v = if set.insert(t) { t } else { j };
            if v != t {
                set.insert(v);
            }
            out.push(v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn sample_distinct_correct() {
        let mut r = Rng::new(9);
        for (n, k) in [(10, 10), (100, 3), (50, 25), (1, 1), (5, 0)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(1);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn for_path_is_pure_and_coordinates_are_independent() {
        // same (seed, path) → same stream, regardless of construction order
        let mut a = Rng::for_path(9, &[3, 7, 1]);
        let mut b = Rng::for_path(9, &[3, 7, 1]);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // neighboring coordinates and prefixes are distinct streams
        let first = |mut r: Rng| r.next_u64();
        let base = first(Rng::for_path(9, &[3, 7, 1]));
        assert_ne!(base, first(Rng::for_path(9, &[3, 7, 2])));
        assert_ne!(base, first(Rng::for_path(9, &[3, 8, 1])));
        assert_ne!(base, first(Rng::for_path(9, &[3, 7])));
        assert_ne!(base, first(Rng::for_path(10, &[3, 7, 1])));
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Rng::new(11);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
