//! Cluster deployment (§5.1, Figure 5): partition the input graph, build
//! per-machine physical partitions, register features/labels in the
//! distributed KVStore, launch sampler servers, and split the training
//! set across trainers — everything `trainer::train` needs to run.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::ft::FaultPlan;
use crate::graph::{Dataset, FanoutPlan, GraphSchema, NodeId, SplitTag};
use crate::kvstore::{
    CacheAdmission, FeatureCache, KvCluster, RangePolicy, TypedFeatures,
};
use crate::metrics::Metrics;
use crate::net::CostModel;
use crate::partition::{
    build_partitions, hierarchical, metis_partition, random, relabel,
    NodeMap, PartitionConfig, Partitioning, PhysPartition, VertexWeights,
};
use crate::pipeline::{BatchGen, BatchPool};
use crate::runtime::manifest::VariantSpec;
use crate::sampler::compact::{ModelKind, TaskKind};
use crate::sampler::{BatchScheduler, DistNeighborSampler, SamplerServer};
use crate::trainer::{split_training_set_for, DeviceHandle};
use crate::util::Rng;

/// Which first-level partitioner to deploy with (Fig 14 ablation knobs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partitioner {
    /// Multilevel min-cut with multi-constraint balancing (the paper).
    Metis,
    /// Euler-style random placement.
    Random,
}

#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub n_machines: usize,
    pub trainers_per_machine: usize,
    pub partitioner: Partitioner,
    /// Balance train/val/test counts during partitioning (§5.3.2).
    pub multi_constraint: bool,
    /// Second-level (per-GPU) partitioning for the training-set split.
    pub two_level: bool,
    /// Sleep for modeled link time on remote pulls (wall-clock fidelity).
    pub emulate_network_time: bool,
    /// Dispatch per-owner sampler/KV requests concurrently (wall clock =
    /// max over owners under emulation; default). `false` restores the
    /// serial owner loops — results and modeled bytes are identical
    /// either way (test-enforced), so this is purely a perf/ablation
    /// knob (`concurrent_rpc` config key).
    pub concurrent_rpc: bool,
    /// Per-trainer remote-feature cache budget (bytes); 0 disables the
    /// [`FeatureCache`] entirely (see `docs/PERF.md`).
    pub cache_budget_bytes: usize,
    /// Which fetched remote rows the cache keeps.
    pub cache_admission: CacheAdmission,
    /// Lock stripes the per-trainer cache is split into (≥ 1): prefetch
    /// inserts and worker lookups on different stripes never contend.
    pub cache_shards: usize,
    /// Lookahead batches the predictive prefetcher pulls ahead of
    /// demand (`pipeline::prefetch`); 0 disables it.
    pub prefetch_depth: usize,
    /// Bounded-staleness window for learnable embeddings: cached rows
    /// may lag the store by at most this many sparse updates. 0
    /// (strict, default) is byte-identical to an uncached client.
    pub embedding_staleness: usize,
    /// Per-etype fanout weights overriding the schema's (each layer's K
    /// is split proportionally; see [`FanoutPlan`]). Empty = use the
    /// schema weights; must have one entry per etype otherwise.
    pub etype_fanouts: Vec<usize>,
    /// Primary/backup KV shard replication (docs/DESIGN.md §12): deploy
    /// materializes each machine's shards on its ring neighbor, embedding
    /// updates write through to both copies, and pulls fail over
    /// transparently when a server dies. Off by default — a dead server
    /// then surfaces as the §8 typed error instead (`replicate_kv` key).
    pub replicate_kv: bool,
    pub seed: u64,
}

impl ClusterSpec {
    pub fn new(n_machines: usize, trainers_per_machine: usize) -> Self {
        Self {
            n_machines,
            trainers_per_machine,
            partitioner: Partitioner::Metis,
            multi_constraint: true,
            two_level: true,
            emulate_network_time: false,
            concurrent_rpc: true,
            cache_budget_bytes: 64 << 20,
            cache_admission: CacheAdmission::All,
            cache_shards: 1,
            prefetch_depth: 0,
            embedding_staleness: 0,
            etype_fanouts: Vec::new(),
            replicate_kv: false,
            seed: 13,
        }
    }
}

/// Preprocessing timings + partition quality (Table 2 / Fig 14 inputs).
#[derive(Clone, Debug, Default)]
pub struct DeployStats {
    pub partition_secs: f64,
    pub build_secs: f64,
    pub load_secs: f64,
    pub edge_cut: usize,
    pub imbalance: f32,
}

pub struct Cluster {
    pub spec: ClusterSpec,
    pub artifacts: PathBuf,
    /// The dataset's typed schema (trivial for homogeneous graphs).
    pub schema: Arc<GraphSchema>,
    /// Per-ntype feature-table view shared by every trainer's BatchGen.
    pub features: TypedFeatures,
    pub cost: Arc<CostModel>,
    pub node_map: Arc<NodeMap>,
    pub kv: Arc<KvCluster>,
    pub policy: Arc<RangePolicy>,
    pub sampler_servers: Vec<Arc<SamplerServer>>,
    pub partitions: Vec<Arc<PhysPartition>>,
    /// Per-trainer training items (node ids; lp derives edges from these).
    pub train_sets: Vec<Vec<NodeId>>,
    /// The full (unsplit) training set in new-ID space — the input every
    /// membership re-split draws from ([`Self::train_sets_for`]), kept so
    /// elastic reconfiguration can recompute shares for any surviving
    /// machine subset without redeploying.
    pub train_ids: Vec<NodeId>,
    pub val_nodes: Vec<NodeId>,
    pub test_nodes: Vec<NodeId>,
    /// Per-node degree in new-ID order (drives degree-aware cache
    /// admission).
    pub degrees: Arc<Vec<u32>>,
    /// Labels in new-ID order (host copy for accuracy computation).
    pub labels: Arc<Vec<u16>>,
    pub num_classes: usize,
    pub n_nodes: usize,
    pub n_edges: usize,
    pub stats: DeployStats,
    /// Injected failure/straggler schedule (docs/DESIGN.md §8); applied
    /// to the KV fabric immediately and to every sampler built by
    /// [`Self::batch_gen`] afterwards.
    fault: Mutex<Option<Arc<FaultPlan>>>,
}

impl Cluster {
    /// Partition + deploy a dataset. `artifacts` points at the AOT output
    /// directory (HLO + manifest).
    pub fn deploy(
        dataset: &Dataset,
        spec: ClusterSpec,
        artifacts: PathBuf,
    ) -> Result<Cluster> {
        let n = dataset.n_nodes();
        let schema = Arc::new(dataset.schema.clone());
        // the cluster boundary is where type arrays must conform to the
        // schema — everything downstream indexes by rel/ntype unchecked
        dataset.graph.validate_schema(&schema)?;
        anyhow::ensure!(
            spec.etype_fanouts.is_empty()
                || spec.etype_fanouts.len() == schema.n_etypes(),
            "etype_fanouts has {} entries, schema has {} etypes",
            spec.etype_fanouts.len(),
            schema.n_etypes()
        );
        anyhow::ensure!(
            spec.etype_fanouts.is_empty()
                || spec.etype_fanouts.iter().any(|&w| w > 0),
            "etype_fanouts must have at least one nonzero weight"
        );
        let t_part = Instant::now();
        let partitioning: Partitioning = match spec.partitioner {
            Partitioner::Metis => {
                let vw = if spec.multi_constraint {
                    VertexWeights::for_training(
                        n,
                        &dataset.split,
                        &dataset.graph.node_type,
                        schema.n_ntypes(),
                    )
                } else {
                    VertexWeights::uniform(n)
                };
                let mut cfg = PartitionConfig::new(spec.n_machines);
                cfg.seed = spec.seed;
                metis_partition(&dataset.graph, &vw, &cfg)
            }
            Partitioner::Random => {
                random::random_partition(n, spec.n_machines, spec.seed)
            }
        };
        let edge_cut = partitioning.edge_cut(&dataset.graph);
        let imbalance =
            partitioning.imbalance(&VertexWeights::uniform(n));
        let partition_secs = t_part.elapsed().as_secs_f64();

        // relabel + physical partitions
        let t_build = Instant::now();
        let r = relabel::relabel(&partitioning);
        let d2 = relabel::relabel_dataset(dataset, &r);
        let node_map = Arc::new(r.node_map);
        let partitions: Vec<Arc<PhysPartition>> =
            build_partitions(&d2.graph, &node_map)
                .into_iter()
                .map(Arc::new)
                .collect();
        let sampler_servers: Vec<Arc<SamplerServer>> = partitions
            .iter()
            .enumerate()
            .map(|(m, p)| Arc::new(SamplerServer::new(m as u32, p.clone())))
            .collect();
        // degree table (new-ID space) for degree-aware cache admission:
        // every core vertex has its full adjacency on its owner partition
        let mut degrees = vec![0u32; n];
        for p in &partitions {
            for l in 0..p.n_core as u32 {
                degrees[p.global_of(l) as usize] =
                    p.graph.degree(l) as u32;
            }
        }
        let build_secs = t_build.elapsed().as_secs_f64();

        // KVStore: features + labels partitioned by the range policy
        let t_load = Instant::now();
        let cost = Arc::new(CostModel::default());
        let kv = KvCluster::with_options(
            spec.n_machines,
            cost.clone(),
            spec.emulate_network_time,
            spec.concurrent_rpc,
        );
        let policy = Arc::new(RangePolicy::new(NodeMap {
            part_starts: node_map.part_starts.clone(),
        }));
        // one feature table per ntype (the homogeneous case registers the
        // single "feat" table, byte-identical to the untyped layout)
        let features = TypedFeatures::from_schema(
            "feat",
            &schema,
            Arc::new(d2.graph.node_type.clone()),
        );
        kv.register_typed(&features, &d2.feats, d2.feat_dim, policy.as_ref());
        let labels_f32: Vec<f32> =
            d2.labels.iter().map(|&l| l as f32).collect();
        kv.register_partitioned("label", &labels_f32, 1, policy.as_ref());
        if spec.replicate_kv {
            // after registration, so every table gets a backup copy
            kv.enable_replication();
        }
        let load_secs = t_load.elapsed().as_secs_f64();

        // training-set split (§5.6.1): derived from the full membership
        // via the same pure function elastic reconfiguration re-invokes
        // on every membership change ([`Self::train_sets_for`]) — the
        // deploy split IS the full-membership split, by construction
        let train: Vec<NodeId> = d2.nodes_with(SplitTag::Train);
        let mut cluster = Cluster {
            spec,
            artifacts,
            schema,
            features,
            cost,
            node_map,
            kv,
            policy,
            sampler_servers,
            partitions,
            degrees: Arc::new(degrees),
            train_sets: Vec::new(),
            train_ids: train,
            val_nodes: d2.nodes_with(SplitTag::Val),
            test_nodes: d2.nodes_with(SplitTag::Test),
            labels: Arc::new(d2.labels.clone()),
            num_classes: d2.num_classes,
            n_nodes: n,
            n_edges: d2.graph.n_edges(),
            stats: DeployStats {
                partition_secs,
                build_secs,
                load_secs,
                edge_cut,
                imbalance,
            },
            fault: Mutex::new(None),
        };
        let all: Vec<u32> =
            (0..cluster.spec.n_machines as u32).collect();
        cluster.train_sets = cluster
            .train_sets_for(&all, cluster.spec.trainers_per_machine);
        Ok(cluster)
    }

    /// Re-split the full training set for an arbitrary surviving machine
    /// membership (elastic reconfiguration, docs/DESIGN.md §9). Pure in
    /// `(machines, per_machine)` given the deployed graph: every
    /// survivor recomputes its share independently and agrees
    /// byte-for-byte, and for the full machine list this reproduces the
    /// deploy split exactly (deploy calls it). Equalizes counts to the
    /// minimum, as synchronous SGD requires identical batch counts.
    pub fn train_sets_for(
        &self,
        machines: &[u32],
        per_machine: usize,
    ) -> Vec<Vec<NodeId>> {
        let machine_sets = split_training_set_for(
            self.train_ids.clone(),
            &self.node_map,
            machines,
            1,
        );
        let mut sets: Vec<Vec<NodeId>> = Vec::new();
        for (i, set) in machine_sets.into_iter().enumerate() {
            let m = machines[i] as usize;
            sets.extend(split_within_machine(
                set,
                &self.partitions[m],
                per_machine,
                self.spec.two_level,
                self.spec.seed ^ m as u64,
            ));
        }
        // synchronous SGD: equalize counts exactly (trim to min)
        let min_len = sets.iter().map(|s| s.len()).min().unwrap_or(0);
        for s in sets.iter_mut() {
            s.truncate(min_len);
        }
        sets
    }

    /// Install a fault-injection / straggler plan cluster-wide: the
    /// KVStore fabric picks it up immediately; samplers built by later
    /// [`Self::batch_gen`] calls (i.e. later loaders) inherit it.
    pub fn set_fault_plan(&self, plan: Arc<FaultPlan>) {
        self.kv.set_fault_plan(plan.clone());
        *self.fault.lock().unwrap() = Some(plan);
    }

    /// The installed fault plan, if any (for reporting its counters).
    pub fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.fault.lock().unwrap().clone()
    }

    pub fn n_trainers(&self) -> usize {
        self.spec.n_machines * self.spec.trainers_per_machine
    }

    /// Fraction of the graph's undirected edges cut by the partitioning,
    /// derived in one place so every report agrees: `stats.edge_cut`
    /// counts each cut pair once, while `n_edges` counts both stored
    /// directions of the symmetrized graph, so the denominator is
    /// `n_edges / 2` undirected pairs.
    pub fn edge_cut_frac(&self) -> f64 {
        self.stats.edge_cut as f64 / (self.n_edges as f64 / 2.0).max(1.0)
    }

    /// Build one trainer's remote-feature cache per the spec knobs;
    /// `None` when `cache_budget_bytes == 0`. The auto degree-admission
    /// threshold resolves to the dataset mean degree.
    pub fn make_feature_cache(&self) -> Option<FeatureCache> {
        if self.spec.cache_budget_bytes == 0 {
            return None;
        }
        let admission = match self.spec.cache_admission {
            CacheAdmission::Degree(None) => {
                let mean =
                    (self.n_edges / self.n_nodes.max(1)).max(1) as u32;
                CacheAdmission::Degree(Some(mean))
            }
            ref a => a.clone(),
        };
        Some(FeatureCache::new(
            "feat",
            self.spec.cache_budget_bytes,
            admission,
            Some(self.degrees.clone()),
        ))
    }

    pub fn machine_of_trainer(&self, t: usize) -> u32 {
        (t / self.spec.trainers_per_machine) as u32
    }

    pub fn batches_per_epoch(&self, batch: usize, _seed: u64) -> usize {
        self.train_sets
            .first()
            .map(|s| s.len().div_ceil(batch).max(1))
            .unwrap_or(1)
    }

    /// Build the mini-batch generator for one trainer.
    pub fn batch_gen(
        &self,
        trainer: usize,
        vspec: &VariantSpec,
        _variant: &str,
        seed: u64,
    ) -> BatchGen {
        self.batch_gen_on(
            self.machine_of_trainer(trainer),
            self.train_sets[trainer].clone(),
            vspec,
            seed,
        )
    }

    /// Build a mini-batch generator anchored on an explicit machine over
    /// an explicit item set — the elastic path, where the (machine,
    /// items) pair comes from a membership re-split rather than the
    /// deploy-time trainer grid. [`Self::batch_gen`] is the deploy-grid
    /// special case.
    pub fn batch_gen_on(
        &self,
        machine: u32,
        items: Vec<NodeId>,
        vspec: &VariantSpec,
        seed: u64,
    ) -> BatchGen {
        let shape = vspec.shape_spec();
        // an RGCN variant compiled for fewer relations than the schema
        // declares would silently zero the out-of-range relations'
        // messages in the one-hot aggregation — refuse the mismatch at
        // the same boundary that validates etype_fanouts
        assert!(
            shape.model != ModelKind::Rgcn
                || shape.num_rels >= self.schema.n_etypes(),
            "variant {:?} compiled for {} relations but the schema \
             declares {} etypes — regenerate artifacts or align the \
             dataset's num_rels",
            shape.name,
            shape.num_rels,
            self.schema.n_etypes()
        );
        let mut sampler = DistNeighborSampler::new(
            machine,
            self.sampler_servers.clone(),
            self.node_map.clone(),
            self.cost.clone(),
        );
        sampler.emulate_network_time = self.spec.emulate_network_time;
        sampler.concurrent_fanout = self.spec.concurrent_rpc;
        if let Some(plan) = self.fault.lock().unwrap().clone() {
            sampler.set_fault_plan(plan);
        }
        let scheduler = match shape.task {
            TaskKind::NodeClassification => BatchScheduler::for_nodes(
                items,
                shape.batch,
                seed,
            ),
            TaskKind::LinkPrediction => BatchScheduler::for_edges(
                self.lp_edges_on(machine, &items, seed),
                shape.batch,
                self.n_nodes as u64,
                seed,
            ),
        };
        let mut kv = self.kv.client(machine, self.policy.clone());
        if let Some(cache) = self.make_feature_cache() {
            kv.attach_cache_sharded(cache, self.spec.cache_shards.max(1));
        }
        kv.set_embedding_staleness(self.spec.embedding_staleness);
        let plan = self.fanout_plan(&shape.fanouts);
        let etype_keys =
            crate::pipeline::gen::etype_metric_keys(self.schema.n_etypes());
        BatchGen {
            spec: shape,
            scheduler,
            sampler: Arc::new(sampler),
            kv,
            seed: seed ^ 0xBA7C4,
            pos: 0,
            eval_pos: 0,
            plan,
            features: self.features.clone(),
            label_name: "label".into(),
            metrics: Arc::new(Metrics::new()),
            etype_keys,
            pool: BatchPool::default(),
            label_scratch: Vec::new(),
            frontier_scratch: Vec::new(),
        }
    }

    /// Link-prediction training items for one trainer: one positive edge
    /// per assigned node (its first sampled neighbor; remote or isolated
    /// items become self-pairs, masked later). Deterministic in `seed` —
    /// shared by [`Self::batch_gen`] and the `api` data-loader builder so
    /// both construct byte-identical schedulers.
    pub fn lp_edges(&self, trainer: usize, seed: u64) -> Vec<(NodeId, NodeId)> {
        self.lp_edges_on(
            self.machine_of_trainer(trainer),
            &self.train_sets[trainer],
            seed,
        )
    }

    /// [`Self::lp_edges`] over an explicit (machine, items) pair — the
    /// elastic counterpart, same determinism contract.
    pub fn lp_edges_on(
        &self,
        machine: u32,
        items: &[NodeId],
        seed: u64,
    ) -> Vec<(NodeId, NodeId)> {
        let mut rng = Rng::new(seed ^ 0xE18E5);
        let part = &self.partitions[machine as usize];
        let mut edges = Vec::with_capacity(items.len());
        for &v in items {
            if let Some(local) = part.local_of(v) {
                if part.is_core_local(local) {
                    let nbrs = part.graph.neighbors(local);
                    if !nbrs.is_empty() {
                        let pick = nbrs[rng.usize_below(nbrs.len())];
                        edges.push((v, part.global_of(pick)));
                        continue;
                    }
                }
            }
            // remote or isolated item: self-pair (masked later)
            edges.push((v, v));
        }
        edges
    }

    /// The per-layer per-etype fanout schedule: each layer's K split by
    /// the `etype_fanouts` override, or the schema's weights.
    pub fn fanout_plan(&self, fanouts: &[usize]) -> FanoutPlan {
        if self.spec.etype_fanouts.is_empty() {
            FanoutPlan::from_schema(&self.schema, fanouts)
        } else {
            FanoutPlan::from_weights(&self.spec.etype_fanouts, fanouts)
        }
    }

    /// Validation accuracy of `params` over (a sample of) the val set.
    pub fn evaluate(
        &self,
        device: &DeviceHandle,
        vspec: &VariantSpec,
        params: &[Vec<f32>],
        seed: u64,
    ) -> Result<f64> {
        if vspec.task != TaskKind::NodeClassification
            || self.val_nodes.is_empty()
            || params.is_empty()
        {
            return Ok(f64::NAN);
        }
        let mut gen = self.batch_gen(0, vspec, &vspec.name, seed);
        let batch_size = vspec.batch;
        let max_nodes = self.val_nodes.len().min(8 * batch_size);
        let mut correct = 0usize;
        let mut total = 0usize;
        let c = vspec.num_classes;
        for chunk in self.val_nodes[..max_nodes].chunks(batch_size) {
            let hb = gen.materialize_nodes(chunk);
            let logits = device.eval(params, hb.clone())?;
            for (i, &gid) in hb.targets.iter().enumerate() {
                let row = &logits[i * c..(i + 1) * c];
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j as u16)
                    .unwrap();
                if argmax == self.labels[gid as usize] {
                    correct += 1;
                }
                total += 1;
            }
            gen.recycle(hb); // reuse the feature buffer next chunk
        }
        Ok(correct as f64 / total.max(1) as f64)
    }
}

/// Split one machine's training items across its trainers: 2-level uses
/// the hierarchical partitioner for intra-batch locality; 1-level takes
/// contiguous chunks.
fn split_within_machine(
    set: Vec<NodeId>,
    part: &Arc<PhysPartition>,
    per_machine: usize,
    two_level: bool,
    seed: u64,
) -> Vec<Vec<NodeId>> {
    if per_machine <= 1 {
        return vec![set];
    }
    if !two_level {
        // contiguous equal chunks
        let n = set.len();
        let base = n / per_machine;
        let rem = n % per_machine;
        let mut out = Vec::with_capacity(per_machine);
        let mut off = 0;
        for t in 0..per_machine {
            let len = base + usize::from(t < rem);
            out.push(set[off..off + len].to_vec());
            off += len;
        }
        return out;
    }
    // 2-level: locality-aware buckets over the core subgraph
    let mut mask = vec![false; part.n_core];
    let mut remote: Vec<NodeId> = Vec::new();
    for &v in &set {
        match part.local_of(v) {
            Some(l) if part.is_core_local(l) => mask[l as usize] = true,
            _ => remote.push(v),
        }
    }
    let buckets = hierarchical::split_cores(part, &mask, per_machine, seed);
    let mut out: Vec<Vec<NodeId>> = vec![Vec::new(); per_machine];
    for (local, &b) in buckets.iter().enumerate() {
        if mask[local] {
            out[b as usize].push(part.global_of(local as u32));
        }
    }
    // spill remote items round-robin (balanced, per §5.6.1)
    for (i, v) in remote.into_iter().enumerate() {
        out[i % per_machine].push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::runtime::manifest::artifacts_dir;

    fn small_cluster(machines: usize, trainers: usize) -> Cluster {
        let d = DatasetSpec::new("cl", 1500, 6000).generate();
        Cluster::deploy(
            &d,
            ClusterSpec::new(machines, trainers),
            artifacts_dir(),
        )
        .unwrap()
    }

    #[test]
    fn deploy_builds_consistent_components() {
        let c = small_cluster(2, 2);
        assert_eq!(c.sampler_servers.len(), 2);
        assert_eq!(c.train_sets.len(), 4);
        let lens: Vec<usize> =
            c.train_sets.iter().map(|s| s.len()).collect();
        assert!(lens.iter().all(|&l| l == lens[0]), "{lens:?}");
        assert!(lens[0] > 0);
        assert!(c.stats.edge_cut > 0);
    }

    #[test]
    fn elastic_membership_resplit_matches_a_fresh_smaller_deploy() {
        // the shrink ≡ fresh-resume foundation: partitioning depends
        // only on n_machines, so a (2,2) cluster re-split for one
        // trainer per machine must reproduce a fresh (2,1) deploy's
        // train sets byte-for-byte
        let d = DatasetSpec::new("cl", 1500, 6000).generate();
        let big = Cluster::deploy(
            &d,
            ClusterSpec::new(2, 2),
            artifacts_dir(),
        )
        .unwrap();
        let small = Cluster::deploy(
            &d,
            ClusterSpec::new(2, 1),
            artifacts_dir(),
        )
        .unwrap();
        assert_eq!(big.train_sets_for(&[0, 1], 1), small.train_sets);
        // full membership reproduces the deploy split exactly
        assert_eq!(big.train_sets_for(&[0, 1], 2), big.train_sets);
        // demoting machine 0 keeps the split total and balanced on the
        // survivor, drawing from the full stored training set
        let solo = big.train_sets_for(&[1], 2);
        assert_eq!(solo.len(), 2);
        assert_eq!(solo[0].len(), solo[1].len());
        assert!(solo[0].len() * 2 > big.train_ids.len() - 2);
    }

    #[test]
    fn training_items_are_mostly_local() {
        let c = small_cluster(2, 2);
        let mut local = 0usize;
        let mut total = 0usize;
        for (t, set) in c.train_sets.iter().enumerate() {
            let m = c.machine_of_trainer(t);
            for &v in set {
                total += 1;
                if c.node_map.owner(v) == m {
                    local += 1;
                }
            }
        }
        let frac = local as f64 / total as f64;
        assert!(frac > 0.6, "locality {frac}");
    }

    #[test]
    fn batch_gen_produces_valid_batches() {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let c = small_cluster(2, 1);
        let m = crate::runtime::Manifest::load(&artifacts_dir()).unwrap();
        let v = m.variant("sage_nc_dev").unwrap();
        let mut gen = c.batch_gen(0, v, "sage_nc_dev", 5);
        let b = gen.next();
        assert_eq!(b.feats.len(), v.layer_nodes[0] * v.feat_dim);
        assert_eq!(b.layers.len(), 2);
        assert!(!b.targets.is_empty());
    }

    #[test]
    fn degree_table_covers_every_vertex() {
        let c = small_cluster(2, 1);
        assert_eq!(c.degrees.len(), c.n_nodes);
        let total: u64 = c.degrees.iter().map(|&d| d as u64).sum();
        assert_eq!(total as usize, c.n_edges, "degree sum != edge count");
        // spot-check against the owning partition's adjacency
        for p in &c.partitions {
            for l in (0..p.n_core as u32).step_by(97) {
                assert_eq!(
                    c.degrees[p.global_of(l) as usize] as usize,
                    p.graph.degree(l)
                );
            }
        }
    }

    #[test]
    fn feature_cache_factory_follows_spec() {
        let mut spec = ClusterSpec::new(2, 1);
        spec.cache_budget_bytes = 0;
        let d = DatasetSpec::new("cc", 1500, 6000).generate();
        let c = Cluster::deploy(&d, spec, artifacts_dir()).unwrap();
        assert!(c.make_feature_cache().is_none());

        let mut spec2 = ClusterSpec::new(2, 1);
        spec2.cache_admission =
            crate::kvstore::CacheAdmission::Degree(None);
        let c2 = Cluster::deploy(&d, spec2, artifacts_dir()).unwrap();
        let cache = c2.make_feature_cache().expect("default budget > 0");
        assert!(cache.is_enabled());
        assert_eq!(cache.tensor(), "feat");
    }

    #[test]
    fn hetero_deploy_builds_typed_tables_and_plan() {
        let mut dspec = DatasetSpec::paper_table1("mag-lsc", 100_000);
        dspec.train_frac = 0.4; // enough labeled papers at this scale
        let d = dspec.generate();
        let c = Cluster::deploy(
            &d,
            ClusterSpec::new(2, 1),
            artifacts_dir(),
        )
        .unwrap();
        assert_eq!(c.schema.n_ntypes(), 3);
        assert_eq!(c.features.names.len(), 3);
        assert!(c.features.names[0].starts_with("feat."));
        assert_eq!(c.features.dims[0], d.feat_dim);
        assert!(c.features.dims[1] < d.feat_dim);
        // per-etype split of a fanout-5 layer over 4 equal-weight etypes
        let plan = c.fanout_plan(&[5, 5]);
        assert_eq!(plan.layer(1).iter().sum::<usize>(), 5);
        assert_eq!(plan.layer(1).len(), 4);
        // all training items are papers (ntype 0)
        for set in &c.train_sets {
            for &v in set {
                assert_eq!(
                    c.features.ntype_of(v),
                    0,
                    "non-paper training item {v}"
                );
            }
        }
    }

    #[test]
    fn etype_fanout_override_must_match_schema() {
        let d = DatasetSpec::new("ov", 1500, 6000).generate();
        let mut spec = ClusterSpec::new(2, 1);
        spec.etype_fanouts = vec![2, 1]; // 2 entries, 1 etype
        assert!(Cluster::deploy(&d, spec, artifacts_dir()).is_err());
        let mut spec2 = ClusterSpec::new(2, 1);
        spec2.etype_fanouts = vec![0]; // all-zero weights rejected
        assert!(Cluster::deploy(&d, spec2, artifacts_dir()).is_err());
    }

    #[test]
    fn edge_cut_frac_is_a_true_pair_fraction() {
        // edge_cut counts undirected cut pairs once; n_edges counts both
        // stored directions — the fraction must land in (0, 1] and agree
        // with the pairwise derivation (regression for the old examples'
        // ad-hoc `/ n_edges * 2.0` prints, now derived in one place)
        let c = small_cluster(4, 1);
        let f = c.edge_cut_frac();
        assert!(f > 0.0 && f <= 1.0, "edge cut fraction {f}");
        let pairs = c.n_edges as f64 / 2.0;
        assert!((f - c.stats.edge_cut as f64 / pairs).abs() < 1e-12);
    }

    #[test]
    fn lp_edges_are_deterministic_and_anchored() {
        let c = small_cluster(2, 1);
        let a = c.lp_edges(0, 42);
        let b = c.lp_edges(0, 42);
        assert_eq!(a, b, "same seed must derive the same positive edges");
        assert_eq!(a.len(), c.train_sets[0].len());
        for (h, _) in &a {
            assert!(c.train_sets[0].contains(h));
        }
        assert_ne!(a, c.lp_edges(0, 43), "seed must matter");
    }

    #[test]
    fn random_partitioner_has_worse_cut() {
        let d = DatasetSpec::new("rc", 2000, 8000).generate();
        let mut s1 = ClusterSpec::new(4, 1);
        s1.partitioner = Partitioner::Metis;
        let mut s2 = ClusterSpec::new(4, 1);
        s2.partitioner = Partitioner::Random;
        let c1 = Cluster::deploy(&d, s1, artifacts_dir()).unwrap();
        let c2 = Cluster::deploy(&d, s2, artifacts_dir()).unwrap();
        assert!(
            (c1.stats.edge_cut as f64) < 0.8 * c2.stats.edge_cut as f64,
            "metis {} vs random {}",
            c1.stats.edge_cut,
            c2.stats.edge_cut
        );
    }
}
