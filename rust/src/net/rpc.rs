//! Request/response RPC over any [`Transport`] backend: a tag-matched
//! client plus serve loops wrapping the existing [`KvServer`] and
//! [`SamplerServer`] (docs/DESIGN.md §11).
//!
//! The in-process hot path keeps calling servers through shared memory
//! with modeled wire costs — that is the simulated fabric's whole point.
//! This module is the *real-wire* path: every request and response is
//! explicitly serialized ([`payload`]) and the equivalence tests below
//! prove a pull or a sampling round over RPC returns exactly what the
//! direct call returns, over both the in-process and TCP backends.
//!
//! Failure policy mirrors `ft` (§8): server-side errors travel as typed
//! [`RpcError`] values inside responses; transport failures and recv
//! timeouts become [`RpcError::ConnectionLost`] after the shared
//! bounded retry/backoff loop ([`crate::net::retry`]) — never a panic,
//! never an `unwrap` on a socket. An installed [`FaultPlan`] gates
//! every attempt through the same outage windows the in-process
//! admission uses, so one plan injects identical failure totals over
//! either backend (regression-tested below).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::payload::{
    decode_kv_request, decode_kv_response, decode_sampler_request,
    decode_sampler_response, encode_kv_request, encode_kv_response,
    encode_sampler_request, encode_sampler_response, KvRequest,
    KvResponse, SamplerRequest, SamplerResponse,
};
use super::retry::{with_retry, RetryPolicy};
use super::{Endpoint, Port, PortKind, RpcError};
use crate::ft::FaultPlan;
use crate::kvstore::KvServer;
use crate::sampler::service::SampledNbrs;
use crate::sampler::SamplerServer;
use crate::util::Rng;

/// How often serve loops wake to check their shutdown flag.
const SERVE_TICK: Duration = Duration::from_millis(100);

/// Tag-matched request/response client over one [`Endpoint`]. Requests
/// carry a fresh tag; responses echo it, so stale frames from timed-out
/// attempts are discarded instead of mis-delivered.
pub struct RpcClient {
    ep: Endpoint,
    next_tag: u64,
    /// Per-attempt response wait before the attempt is abandoned.
    pub timeout: Duration,
    /// Resend attempts after the first (bounded retry, as in `ft`).
    pub retries: u32,
    /// Sleep between attempts.
    pub backoff: Duration,
    /// Injected-outage plan: when set, every attempt is gated through
    /// the plan's outage windows (same counters as in-process
    /// admission) and retries feed the plan's shared `ft.retries`.
    fault: Option<Arc<FaultPlan>>,
    /// Retries taken when no plan is installed.
    own_retries: AtomicU64,
}

impl RpcClient {
    pub fn new(ep: Endpoint) -> Self {
        let policy = RetryPolicy::wire();
        Self {
            ep,
            next_tag: 1,
            timeout: Duration::from_secs(10),
            retries: policy.max_retries,
            backoff: policy.backoff,
            fault: None,
            own_retries: AtomicU64::new(0),
        }
    }

    pub fn endpoint(&self) -> &Endpoint {
        &self.ep
    }

    /// Gate every subsequent call through `plan`'s outage windows and
    /// feed its shared retries counter (the chaos/injection hook for
    /// real-wire clients — same `FaultPlan`, same totals as in-process).
    pub fn set_fault_plan(&mut self, plan: Arc<FaultPlan>) {
        self.fault = Some(plan);
    }

    /// Retries this client has taken (the plan's counter when one is
    /// installed, the client-local one otherwise).
    pub fn retries_taken(&self) -> u64 {
        match &self.fault {
            Some(f) => f.retries(),
            None => self.own_retries.load(Ordering::Relaxed),
        }
    }

    /// One round-trip to `dst` with the shared bounded retry/backoff
    /// loop. Transport errors and response timeouts surface as
    /// [`RpcError::ConnectionLost`] once the attempts are exhausted;
    /// injected outages surface as [`RpcError::ServerDown`], exactly as
    /// on the in-process path.
    pub fn call(
        &mut self,
        dst: u32,
        port: Port,
        payload: Vec<u8>,
    ) -> Result<Vec<u8>, RpcError> {
        let machine = self.ep.transport.machine_of(dst);
        let role: Option<&'static str> = match port.kind() {
            PortKind::KvStore => Some("kv"),
            PortKind::Sampler => Some("sampler"),
            _ => None,
        };
        let policy = RetryPolicy::new(self.retries, self.backoff);
        let plan = self.fault.clone();
        let timeout = self.timeout;
        let Self { ep, next_tag, own_retries, .. } = self;
        let counter: &AtomicU64 = match &plan {
            Some(f) => f.retries_counter(),
            None => own_retries,
        };
        with_retry(&policy, counter, |attempt| {
            if let (Some(f), Some(role)) = (plan.as_ref(), role) {
                f.inject(role, machine)?;
            }
            let tag = *next_tag;
            *next_tag += 1;
            ep.send(dst, port, tag, payload.clone())?;
            let deadline = Instant::now() + timeout;
            loop {
                let now = Instant::now();
                if now >= deadline {
                    return Err(RpcError::ConnectionLost {
                        peer: dst,
                        detail: format!(
                            "no response within {timeout:?} (attempt {})",
                            attempt + 1
                        ),
                    });
                }
                match ep.recv_kind(port.kind(), Some(deadline - now)) {
                    Some(m) if m.tag == tag => return Ok(m.payload),
                    Some(_) => continue, // stale reply from a retry
                    None if ep.is_closed() => {
                        return Err(RpcError::ConnectionLost {
                            peer: dst,
                            detail: "transport shut down".into(),
                        });
                    }
                    None => continue, // spurious timeout; loop re-checks
                }
            }
        })
    }

    fn lost(&self, dst: u32, what: impl std::fmt::Display) -> RpcError {
        RpcError::ConnectionLost { peer: dst, detail: what.to_string() }
    }

    /// Batched feature pull over the wire; returns `(dim, rows)`.
    /// Equivalent to `KvServer::read_rows` on the owner (test-enforced).
    pub fn kv_pull(
        &mut self,
        dst: u32,
        name: &str,
        locals: &[u32],
    ) -> Result<(usize, Vec<f32>), RpcError> {
        let req = KvRequest::Pull {
            name: name.to_string(),
            locals: locals.to_vec(),
        };
        let raw =
            self.call(dst, Port::KvStore, encode_kv_request(&req))?;
        match decode_kv_response(&raw)
            .map_err(|e| self.lost(dst, format!("bad kv response: {e}")))?
        {
            KvResponse::Rows { dim, data } => Ok((dim as usize, data)),
            KvResponse::Err(e) => Err(e),
            other => {
                Err(self.lost(dst, format!("unexpected reply {other:?}")))
            }
        }
    }

    /// Typed pull of one ntype table; returns `(ntype, dim, rows)`.
    pub fn kv_pull_typed(
        &mut self,
        dst: u32,
        name: &str,
        ntype: u8,
        locals: &[u32],
    ) -> Result<(u8, usize, Vec<f32>), RpcError> {
        let req = KvRequest::PullTyped {
            name: name.to_string(),
            ntype,
            locals: locals.to_vec(),
        };
        let raw =
            self.call(dst, Port::KvStore, encode_kv_request(&req))?;
        match decode_kv_response(&raw)
            .map_err(|e| self.lost(dst, format!("bad kv response: {e}")))?
        {
            KvResponse::TypedRows { ntype, dim, data } => {
                Ok((ntype, dim as usize, data))
            }
            KvResponse::Err(e) => Err(e),
            other => {
                Err(self.lost(dst, format!("unexpected reply {other:?}")))
            }
        }
    }

    /// Row-sparse gradient push over the wire.
    pub fn kv_push(
        &mut self,
        dst: u32,
        name: &str,
        locals: &[u32],
        grads: &[f32],
        lr: f32,
    ) -> Result<(), RpcError> {
        let req = KvRequest::Push {
            name: name.to_string(),
            locals: locals.to_vec(),
            grads: grads.to_vec(),
            lr,
        };
        let raw =
            self.call(dst, Port::KvStore, encode_kv_request(&req))?;
        match decode_kv_response(&raw)
            .map_err(|e| self.lost(dst, format!("bad kv response: {e}")))?
        {
            KvResponse::Ok => Ok(()),
            KvResponse::Err(e) => Err(e),
            other => {
                Err(self.lost(dst, format!("unexpected reply {other:?}")))
            }
        }
    }

    /// Remote neighbor sampling; deterministic in `rng_seed`, so the
    /// result matches a local `sample_neighbors` with the same seed —
    /// batch composition stays a pure function of `(seed, epoch, batch)`
    /// across process boundaries.
    pub fn sample(
        &mut self,
        dst: u32,
        seeds: &[u32],
        fanouts: &[usize],
        rng_seed: u64,
    ) -> Result<Vec<SampledNbrs>, RpcError> {
        let req = SamplerRequest {
            seeds: seeds.to_vec(),
            fanouts: fanouts.iter().map(|&f| f as u32).collect(),
            rng_seed,
        };
        let raw =
            self.call(dst, Port::Sampler, encode_sampler_request(&req))?;
        match decode_sampler_response(&raw).map_err(|e| {
            self.lost(dst, format!("bad sampler response: {e}"))
        })? {
            SamplerResponse::Blocks(blocks) => Ok(blocks),
            SamplerResponse::Err(e) => Err(e),
        }
    }
}

fn handle_kv(server: &KvServer, req: KvRequest) -> KvResponse {
    match req {
        KvRequest::Pull { name, locals } => {
            match server.dim_of(&name) {
                Ok(dim) => {
                    let mut data = vec![0.0f32; locals.len() * dim];
                    match server.read_rows(&name, &locals, &mut data) {
                        Ok(()) => {
                            KvResponse::Rows { dim: dim as u32, data }
                        }
                        Err(e) => KvResponse::Err(e),
                    }
                }
                Err(e) => KvResponse::Err(e),
            }
        }
        KvRequest::PullTyped { name, ntype, locals } => {
            match server.dim_of(&name) {
                Ok(dim) => {
                    let mut data = vec![0.0f32; locals.len() * dim];
                    match server.read_rows(&name, &locals, &mut data) {
                        Ok(()) => KvResponse::TypedRows {
                            ntype,
                            dim: dim as u32,
                            data,
                        },
                        Err(e) => KvResponse::Err(e),
                    }
                }
                Err(e) => KvResponse::Err(e),
            }
        }
        KvRequest::Push { name, locals, grads, lr } => {
            match server.apply_grads(&name, &locals, &grads, lr) {
                Ok(()) => KvResponse::Ok,
                Err(e) => KvResponse::Err(e),
            }
        }
    }
}

/// Serve `server`'s shards on `ep` until `running` clears or the
/// transport shuts down. One reply per request, same tag, back to the
/// sender's endpoint.
pub fn serve_kv(
    ep: Endpoint,
    server: Arc<KvServer>,
    running: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        while running.load(Ordering::SeqCst) {
            let Some(msg) =
                ep.recv_kind(Port::KvStore.kind(), Some(SERVE_TICK))
            else {
                if ep.is_closed() {
                    return;
                }
                continue;
            };
            let resp = match decode_kv_request(&msg.payload) {
                Ok(req) => handle_kv(&server, req),
                Err(e) => KvResponse::Err(RpcError::ConnectionLost {
                    peer: msg.from,
                    detail: format!("undecodable kv request: {e}"),
                }),
            };
            let _ = ep.send(
                msg.from,
                Port::KvStore,
                msg.tag,
                encode_kv_response(&resp),
            );
        }
    })
}

/// Serve neighbor sampling on `ep` until `running` clears. The request
/// carries the RNG seed, so sampling is a pure function of the request —
/// byte-identical to a local call with the same seed.
pub fn serve_sampler(
    ep: Endpoint,
    server: Arc<SamplerServer>,
    running: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        while running.load(Ordering::SeqCst) {
            let Some(msg) =
                ep.recv_kind(Port::Sampler.kind(), Some(SERVE_TICK))
            else {
                if ep.is_closed() {
                    return;
                }
                continue;
            };
            let resp = match decode_sampler_request(&msg.payload) {
                Ok(req) => {
                    let fanouts: Vec<usize> =
                        req.fanouts.iter().map(|&f| f as usize).collect();
                    let mut rng = Rng::new(req.rng_seed);
                    SamplerResponse::Blocks(server.sample_neighbors(
                        &req.seeds,
                        &fanouts,
                        &mut rng,
                    ))
                }
                Err(e) => {
                    SamplerResponse::Err(RpcError::ConnectionLost {
                        peer: msg.from,
                        detail: format!("undecodable sampler request: {e}"),
                    })
                }
            };
            let _ = ep.send(
                msg.from,
                Port::Sampler,
                msg.tag,
                encode_sampler_response(&resp),
            );
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::tcp::{free_loopback_ports, tcp_transport, TcpConfig};
    use crate::net::{CostModel, Transport};

    fn kv_with_feat() -> Arc<KvServer> {
        let server = Arc::new(KvServer::new(1));
        let data: Vec<f32> = (0..40).map(|i| i as f32 * 0.5).collect();
        server.register("feat", data, 4);
        server
    }

    fn stop(flag: &Arc<AtomicBool>, h: JoinHandle<()>) {
        flag.store(false, Ordering::SeqCst);
        h.join().unwrap();
    }

    #[test]
    fn kv_pull_over_rpc_equals_direct_read() {
        let t = Transport::new(2, CostModel::default());
        let server = kv_with_feat();
        let running = Arc::new(AtomicBool::new(true));
        let h = serve_kv(t.endpoint(1), server.clone(), running.clone());
        let mut client = RpcClient::new(t.endpoint(0));
        let locals = vec![0u32, 3, 7, 2];
        let (dim, rows) = client.kv_pull(1, "feat", &locals).unwrap();
        assert_eq!(dim, 4);
        let mut direct = vec![0.0f32; locals.len() * 4];
        server.read_rows("feat", &locals, &mut direct).unwrap();
        assert_eq!(rows, direct, "RPC pull ≡ direct read");
        stop(&running, h);
    }

    #[test]
    fn kv_typed_pull_and_push_round_trip_over_rpc() {
        let t = Transport::new(2, CostModel::default());
        let server = kv_with_feat();
        let running = Arc::new(AtomicBool::new(true));
        let h = serve_kv(t.endpoint(1), server.clone(), running.clone());
        let mut client = RpcClient::new(t.endpoint(0));
        let (nt, dim, rows) =
            client.kv_pull_typed(1, "feat", 2, &[1, 5]).unwrap();
        assert_eq!((nt, dim), (2, 4));
        let mut direct = vec![0.0f32; 8];
        server.read_rows("feat", &[1, 5], &mut direct).unwrap();
        assert_eq!(rows, direct);
        // push a gradient, observe it through a fresh pull
        client
            .kv_push(1, "feat", &[1], &[1.0, 1.0, 1.0, 1.0], 0.5)
            .unwrap();
        let (_, after) = client.kv_pull(1, "feat", &[1]).unwrap();
        for (a, b) in after.iter().zip(&direct[..4]) {
            assert!((a - (b - 0.5)).abs() < 1e-6, "push applied: {a} {b}");
        }
        stop(&running, h);
    }

    #[test]
    fn kv_errors_travel_typed_over_the_wire() {
        let t = Transport::new(2, CostModel::default());
        let server = kv_with_feat();
        let running = Arc::new(AtomicBool::new(true));
        let h = serve_kv(t.endpoint(1), server, running.clone());
        let mut client = RpcClient::new(t.endpoint(0));
        let err = client.kv_pull(1, "nope", &[0]).unwrap_err();
        assert_eq!(
            err,
            RpcError::UnknownTensor { name: "nope".into(), machine: 1 }
        );
        stop(&running, h);
    }

    #[test]
    fn unserved_port_times_out_into_connection_lost_after_retries() {
        let t = Transport::new(2, CostModel::default());
        let _sink = t.endpoint(1); // claimed but never served
        let mut client = RpcClient::new(t.endpoint(0));
        client.timeout = Duration::from_millis(30);
        client.retries = 2;
        client.backoff = Duration::from_millis(5);
        let start = Instant::now();
        let err = client.kv_pull(1, "feat", &[0]).unwrap_err();
        match err {
            RpcError::ConnectionLost { peer, detail } => {
                assert_eq!(peer, 1);
                assert!(detail.contains("no response"), "{detail}");
            }
            other => panic!("expected ConnectionLost, got {other:?}"),
        }
        // 3 attempts × 30ms timeout (+ backoffs) — bounded, not hung
        assert!(start.elapsed() >= Duration::from_millis(90));
    }

    #[test]
    fn sampler_rpc_is_deterministic_and_equals_local_call() {
        use crate::graph::DatasetSpec;
        use crate::partition::{
            build_partitions, metis_partition, relabel, PartitionConfig,
            VertexWeights,
        };
        let spec = DatasetSpec::new("rpc", 400, 1600);
        let d = spec.generate();
        let vw = VertexWeights::uniform(d.n_nodes());
        let p = metis_partition(&d.graph, &vw, &PartitionConfig::new(2));
        let r = relabel::relabel(&p);
        let g = relabel::relabel_graph(&d.graph, &r);
        let parts: Vec<_> = build_partitions(&g, &r.node_map)
            .into_iter()
            .map(Arc::new)
            .collect();
        let server = Arc::new(SamplerServer::new(0, parts[0].clone()));
        let seeds: Vec<u32> = (0..parts[0].n_core.min(20) as u32)
            .map(|l| parts[0].global_of(l))
            .collect();
        let t = Transport::new(2, CostModel::default());
        let running = Arc::new(AtomicBool::new(true));
        let h =
            serve_sampler(t.endpoint(1), server.clone(), running.clone());
        let mut client = RpcClient::new(t.endpoint(0));
        let over_wire = client.sample(1, &seeds, &[5], 1234).unwrap();
        let again = client.sample(1, &seeds, &[5], 1234).unwrap();
        assert_eq!(over_wire, again, "same seed → same sample");
        let mut rng = Rng::new(1234);
        let local = server.sample_neighbors(&seeds, &[5], &mut rng);
        assert_eq!(over_wire, local, "RPC sampling ≡ local sampling");
        stop(&running, h);
    }

    #[test]
    fn fault_plan_injects_identical_totals_over_both_backends() {
        use crate::ft::{FailWindow, FaultPlan};
        use crate::metrics::Metrics;
        let mk = || {
            let mut p = FaultPlan::new();
            p.backoff = Duration::ZERO;
            p.kv_outages = vec![
                FailWindow::transient(1, 2, 3),
                FailWindow::transient(1, 7, 1),
            ];
            Arc::new(p)
        };
        // reference: the PR 6 in-process admission loop
        let inproc = mk();
        let inproc_results: Vec<bool> =
            (0..6).map(|_| inproc.admit_kv(1).is_ok()).collect();
        // same schedule gating a wire client attempt-by-attempt
        let wire = mk();
        let t = Transport::new(2, CostModel::default());
        let server = kv_with_feat();
        let running = Arc::new(AtomicBool::new(true));
        let h = serve_kv(t.endpoint(1), server, running.clone());
        let mut client = RpcClient::new(t.endpoint(0));
        client.backoff = Duration::ZERO;
        client.set_fault_plan(wire.clone());
        let wire_results: Vec<bool> = (0..6)
            .map(|_| client.kv_pull(1, "feat", &[0]).is_ok())
            .collect();
        // identical request outcomes AND identical injected totals:
        // the outage-window scope gap is closed
        assert_eq!(inproc_results, wire_results);
        assert_eq!(inproc.kv_failures(), wire.kv_failures());
        assert_eq!(inproc.retries(), wire.retries());
        assert_eq!(client.retries_taken(), wire.retries());
        let (m1, m2) = (Metrics::new(), Metrics::new());
        inproc.publish(&m1);
        wire.publish(&m2);
        assert_eq!(
            m1.counter("ft.injected_failures"),
            m2.counter("ft.injected_failures")
        );
        assert_eq!(m1.counter("ft.retries"), m2.counter("ft.retries"));
        stop(&running, h);
    }

    #[test]
    fn permanent_outage_over_the_wire_is_server_down() {
        use crate::ft::{FailWindow, FaultPlan};
        let mut p = FaultPlan::new();
        p.backoff = Duration::ZERO;
        p.kv_outages = vec![FailWindow::permanent(1, 0)];
        let plan = Arc::new(p);
        let t = Transport::new(2, CostModel::default());
        let server = kv_with_feat();
        let running = Arc::new(AtomicBool::new(true));
        let h = serve_kv(t.endpoint(1), server, running.clone());
        let mut client = RpcClient::new(t.endpoint(0));
        client.backoff = Duration::ZERO;
        client.set_fault_plan(plan.clone());
        assert_eq!(
            client.kv_pull(1, "feat", &[0]).unwrap_err(),
            RpcError::ServerDown { machine: 1, role: "kv" }
        );
        assert_eq!(plan.retries(), 3, "bounded budget, then typed error");
        stop(&running, h);
    }

    #[test]
    fn kv_pull_over_tcp_loopback_equals_direct_read() {
        let ports = free_loopback_ports(2).unwrap();
        let addrs: Vec<String> = ports
            .iter()
            .map(|p| format!("127.0.0.1:{p}"))
            .collect();
        let mk = |my_proc: usize| {
            let mut cfg = TcpConfig::localhost(my_proc, 2, 0);
            cfg.addrs = addrs.clone();
            tcp_transport(cfg, Arc::new(CostModel::default())).unwrap()
        };
        let t0 = mk(0);
        let t1 = mk(1);
        let server = kv_with_feat();
        let running = Arc::new(AtomicBool::new(true));
        let h = serve_kv(t1.endpoint(1), server.clone(), running.clone());
        let mut client = RpcClient::new(t0.endpoint(0));
        let locals = vec![2u32, 9, 4];
        let (dim, rows) = client.kv_pull(1, "feat", &locals).unwrap();
        let mut direct = vec![0.0f32; locals.len() * dim];
        server.read_rows("feat", &locals, &mut direct).unwrap();
        assert_eq!(rows, direct, "TCP pull ≡ direct read");
        // typed errors cross the real wire too
        let err = client.kv_pull(1, "nope", &[0]).unwrap_err();
        assert_eq!(
            err,
            RpcError::UnknownTensor { name: "nope".into(), machine: 1 }
        );
        stop(&running, h);
    }
}
