//! Explicit serialization for every RPC payload that crosses the wire.
//!
//! The in-process backend moves these values as in-memory structs and
//! only *meters* their size; the TCP backend actually encodes them. Both
//! views are kept consistent by construction: the `*_bytes` size helpers
//! used by the emulated [`CostModel`](crate::net::CostModel) metering are
//! defined next to each codec and regression-tested against the real
//! encoded length (frame header included), so a modeled byte count and a
//! socket byte count for the same RPC agree.
//!
//! Encoding is the little-endian, length-prefixed scheme of
//! [`wire::ByteWriter`]/[`wire::ByteReader`] — no serde in the
//! dependency set, and the format is pinned by [`wire::WIRE_VERSION`].

use super::wire::{self, ByteReader, ByteWriter, WireError};
use super::RpcError;
use crate::coordinator::{Decision, MembershipView};
use crate::sampler::service::SampledNbrs;

// ---------------------------------------------------------------------
// RpcError (carried inside error responses)
// ---------------------------------------------------------------------

/// Map a decoded role string back onto the `&'static str` vocabulary the
/// typed error carries in-process. Unknown roles (a newer peer) collapse
/// to `"remote"` rather than failing the decode.
fn intern_role(s: &str) -> &'static str {
    match s {
        "kv" => "kv",
        "sampler" => "sampler",
        "sampling pipeline" => "sampling pipeline",
        "sampler fan-out" => "sampler fan-out",
        "kv fan-out" => "kv fan-out",
        _ => "remote",
    }
}

pub fn encode_rpc_error(w: &mut ByteWriter, e: &RpcError) {
    match e {
        RpcError::UnknownTensor { name, machine } => {
            w.u8(0);
            w.str(name);
            w.u32(*machine);
        }
        RpcError::ServerDown { machine, role } => {
            w.u8(1);
            w.u32(*machine);
            w.str(role);
        }
        RpcError::WorkerLost(what) => {
            w.u8(2);
            w.str(what);
        }
        RpcError::ConnectionLost { peer, detail } => {
            w.u8(3);
            w.u32(*peer);
            w.str(detail);
        }
    }
}

pub fn decode_rpc_error(r: &mut ByteReader) -> Result<RpcError, WireError> {
    Ok(match r.u8()? {
        0 => RpcError::UnknownTensor { name: r.str()?, machine: r.u32()? },
        1 => RpcError::ServerDown {
            machine: r.u32()?,
            role: intern_role(&r.str()?),
        },
        2 => RpcError::WorkerLost(intern_role(&r.str()?)),
        3 => RpcError::ConnectionLost { peer: r.u32()?, detail: r.str()? },
        k => return Err(WireError::BadPortKind(k)),
    })
}

// ---------------------------------------------------------------------
// KV store protocol
// ---------------------------------------------------------------------

/// Requests served by [`crate::net::rpc::serve_kv`]. `locals` are
/// owner-local row indices (the caller already ran the partition policy,
/// same as the in-process pull path).
#[derive(Clone, Debug, PartialEq)]
pub enum KvRequest {
    /// Batched feature pull from one tensor.
    Pull { name: String, locals: Vec<u32> },
    /// Typed pull: one node type's table of a typed tensor family
    /// (`name` is the per-ntype table, `ntype` rides along so the
    /// response can be scattered without re-deriving types).
    PullTyped { name: String, ntype: u8, locals: Vec<u32> },
    /// Row-sparse gradient push (`grads.len() == locals.len() * dim`).
    Push { name: String, locals: Vec<u32>, grads: Vec<f32>, lr: f32 },
}

#[derive(Clone, Debug, PartialEq)]
pub enum KvResponse {
    /// Pull result: `data.len() == n_rows * dim`.
    Rows { dim: u32, data: Vec<f32> },
    /// Typed pull result.
    TypedRows { ntype: u8, dim: u32, data: Vec<f32> },
    /// Push acknowledged.
    Ok,
    /// Typed failure (unknown tensor, injected outage) — errors stay
    /// values across the wire exactly as they do in-process (§8).
    Err(RpcError),
}

pub fn encode_kv_request(q: &KvRequest) -> Vec<u8> {
    let mut w = ByteWriter::new();
    match q {
        KvRequest::Pull { name, locals } => {
            w.u8(0);
            w.str(name);
            w.u32s(locals);
        }
        KvRequest::PullTyped { name, ntype, locals } => {
            w.u8(1);
            w.str(name);
            w.u8(*ntype);
            w.u32s(locals);
        }
        KvRequest::Push { name, locals, grads, lr } => {
            w.u8(2);
            w.str(name);
            w.u32s(locals);
            w.f32s(grads);
            w.f32(*lr);
        }
    }
    w.finish()
}

pub fn decode_kv_request(buf: &[u8]) -> Result<KvRequest, WireError> {
    let mut r = ByteReader::new(buf);
    let q = match r.u8()? {
        0 => KvRequest::Pull { name: r.str()?, locals: r.u32s()? },
        1 => KvRequest::PullTyped {
            name: r.str()?,
            ntype: r.u8()?,
            locals: r.u32s()?,
        },
        2 => KvRequest::Push {
            name: r.str()?,
            locals: r.u32s()?,
            grads: r.f32s()?,
            lr: r.f32()?,
        },
        k => return Err(WireError::BadPortKind(k)),
    };
    r.expect_end()?;
    Ok(q)
}

pub fn encode_kv_response(p: &KvResponse) -> Vec<u8> {
    let mut w = ByteWriter::new();
    match p {
        KvResponse::Rows { dim, data } => {
            w.u8(0);
            w.u32(*dim);
            w.f32s(data);
        }
        KvResponse::TypedRows { ntype, dim, data } => {
            w.u8(1);
            w.u8(*ntype);
            w.u32(*dim);
            w.f32s(data);
        }
        KvResponse::Ok => w.u8(2),
        KvResponse::Err(e) => {
            w.u8(3);
            encode_rpc_error(&mut w, e);
        }
    }
    w.finish()
}

pub fn decode_kv_response(buf: &[u8]) -> Result<KvResponse, WireError> {
    let mut r = ByteReader::new(buf);
    let p = match r.u8()? {
        0 => KvResponse::Rows { dim: r.u32()?, data: r.f32s()? },
        1 => KvResponse::TypedRows {
            ntype: r.u8()?,
            dim: r.u32()?,
            data: r.f32s()?,
        },
        2 => KvResponse::Ok,
        3 => KvResponse::Err(decode_rpc_error(&mut r)?),
        k => return Err(WireError::BadPortKind(k)),
    };
    r.expect_end()?;
    Ok(p)
}

/// Framed size of a `Pull` request. The emulated metering passes
/// `name_len = 0` (modeling a name-interned protocol where the tensor id
/// is amortized); the codec tests pass the real name length and assert
/// exact agreement with `encode_kv_request`.
pub fn kv_pull_req_bytes(name_len: usize, n_rows: usize) -> u64 {
    (wire::FRAME_HEADER_BYTES + 1 + 2 + name_len + 4 + 4 * n_rows) as u64
}

/// Framed size of a `Rows` response.
pub fn kv_pull_resp_bytes(n_rows: usize, dim: usize) -> u64 {
    (wire::FRAME_HEADER_BYTES + 1 + 4 + 4 + 4 * n_rows * dim) as u64
}

/// Framed size of a `Push` request.
pub fn kv_push_bytes(name_len: usize, n_rows: usize, dim: usize) -> u64 {
    (wire::FRAME_HEADER_BYTES
        + 1
        + 2
        + name_len
        + 4
        + 4 * n_rows
        + 4
        + 4 * n_rows * dim
        + 4) as u64
}

// ---------------------------------------------------------------------
// Sampler protocol
// ---------------------------------------------------------------------

/// One frontier's sampling request against the owner of its seeds.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SamplerRequest {
    pub seeds: Vec<u32>,
    pub fanouts: Vec<u32>,
    /// Seed for the server-side `Rng` — sampling stays a pure function
    /// of `(seed, epoch, batch)` across process boundaries.
    pub rng_seed: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub enum SamplerResponse {
    /// Per-seed sampled neighborhoods (the "blocks" the pipeline builds
    /// CSR segments from).
    Blocks(Vec<SampledNbrs>),
    Err(RpcError),
}

pub fn encode_sampler_request(q: &SamplerRequest) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(20 + 4 * q.seeds.len());
    w.u32s(&q.seeds);
    w.u32s(&q.fanouts);
    w.u64(q.rng_seed);
    w.finish()
}

pub fn decode_sampler_request(
    buf: &[u8],
) -> Result<SamplerRequest, WireError> {
    let mut r = ByteReader::new(buf);
    let q = SamplerRequest {
        seeds: r.u32s()?,
        fanouts: r.u32s()?,
        rng_seed: r.u64()?,
    };
    r.expect_end()?;
    Ok(q)
}

pub fn encode_sampler_response(p: &SamplerResponse) -> Vec<u8> {
    let mut w = ByteWriter::new();
    match p {
        SamplerResponse::Blocks(blocks) => {
            w.u8(0);
            // columnar: offsets + flat neighbor/rel arrays (4B+1B per
            // edge, matching the modeled 5B/edge wire cost)
            w.u32(blocks.len() as u32);
            let mut off = 0u32;
            w.u32(off);
            for b in blocks {
                off += b.nbrs.len() as u32;
                w.u32(off);
            }
            for b in blocks {
                for &n in &b.nbrs {
                    w.u32(n);
                }
            }
            for b in blocks {
                debug_assert_eq!(b.rels.len(), b.nbrs.len());
                for &rel in &b.rels {
                    w.u8(rel);
                }
            }
        }
        SamplerResponse::Err(e) => {
            w.u8(1);
            encode_rpc_error(&mut w, e);
        }
    }
    w.finish()
}

pub fn decode_sampler_response(
    buf: &[u8],
) -> Result<SamplerResponse, WireError> {
    let mut r = ByteReader::new(buf);
    match r.u8()? {
        0 => {
            let n = r.u32()? as usize;
            let mut offsets = Vec::with_capacity(n + 1);
            for _ in 0..=n {
                offsets.push(r.u32()? as usize);
            }
            let total = *offsets.last().unwrap_or(&0);
            let mut nbrs = Vec::with_capacity(total);
            for _ in 0..total {
                nbrs.push(r.u32()?);
            }
            let mut rels = Vec::with_capacity(total);
            for _ in 0..total {
                rels.push(r.u8()?);
            }
            r.expect_end()?;
            let blocks = (0..n)
                .map(|i| SampledNbrs {
                    nbrs: nbrs[offsets[i]..offsets[i + 1]].to_vec(),
                    rels: rels[offsets[i]..offsets[i + 1]].to_vec(),
                })
                .collect();
            Ok(SamplerResponse::Blocks(blocks))
        }
        1 => {
            let e = decode_rpc_error(&mut r)?;
            r.expect_end()?;
            Ok(SamplerResponse::Err(e))
        }
        k => Err(WireError::BadPortKind(k)),
    }
}

/// Framed size of a sampling request (`seeds` + `fanouts` + rng seed).
pub fn sampler_req_bytes(n_seeds: usize, n_fanouts: usize) -> u64 {
    (wire::FRAME_HEADER_BYTES + 4 + 4 * n_seeds + 4 + 4 * n_fanouts + 8)
        as u64
}

/// Framed size of a blocks response: offsets column + 4B neighbor + 1B
/// relation per sampled edge.
pub fn sampler_resp_bytes(n_seeds: usize, n_edges: usize) -> u64 {
    (wire::FRAME_HEADER_BYTES + 1 + 4 + 4 * (n_seeds + 1) + 5 * n_edges)
        as u64
}

// ---------------------------------------------------------------------
// Coordinator / rendezvous protocol
// ---------------------------------------------------------------------

/// Everything the rendezvous service speaks over `Port::Control`
/// (docs/DESIGN.md §11). Requests flow client → server; `Welcome`,
/// `DecisionMsg` and `ShutdownAck` flow back.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CoordMsg {
    /// Join: ask for a machine id (`preferred == u32::MAX` lets the
    /// server assign the next free id in join order).
    Hello { preferred: u32 },
    /// Join reply: the assigned machine id + the initial view.
    Welcome { machine: u32, view: MembershipView },
    /// Rank arrived at the epoch-boundary barrier.
    BarrierArrive { rank: u32 },
    /// Barrier release: Continue, or Reconfigure carrying the resized
    /// membership view.
    DecisionMsg(Decision),
    /// Liveness + step-timing signal (fire-and-forget).
    Heartbeat { rank: u32, secs: f64 },
    /// Rank is unrecoverably broken; demote its machine at the boundary.
    FailureReport { rank: u32 },
    /// Clean goodbye from one machine process.
    Shutdown { machine: u32 },
    ShutdownAck,
    /// A restarted machine process reclaims its *previous* id
    /// (docs/DESIGN.md §12). Plain `Hello` cannot: the id is already in
    /// the server's used set and the fallback would hand out a fresh
    /// one. Replied to with `Welcome` carrying the reclaimed id.
    Rejoin { machine: u32 },
}

pub fn encode_view(w: &mut ByteWriter, v: &MembershipView) {
    w.u64(v.epoch);
    w.u32s(&v.machines);
    w.u32(v.per_machine as u32);
}

pub fn decode_view(r: &mut ByteReader) -> Result<MembershipView, WireError> {
    Ok(MembershipView {
        epoch: r.u64()?,
        machines: r.u32s()?,
        per_machine: r.u32()? as usize,
    })
}

pub fn encode_coord_msg(m: &CoordMsg) -> Vec<u8> {
    let mut w = ByteWriter::new();
    match m {
        CoordMsg::Hello { preferred } => {
            w.u8(0);
            w.u32(*preferred);
        }
        CoordMsg::Welcome { machine, view } => {
            w.u8(1);
            w.u32(*machine);
            encode_view(&mut w, view);
        }
        CoordMsg::BarrierArrive { rank } => {
            w.u8(2);
            w.u32(*rank);
        }
        CoordMsg::DecisionMsg(Decision::Continue) => w.u8(3),
        CoordMsg::DecisionMsg(Decision::Reconfigure(view)) => {
            w.u8(4);
            encode_view(&mut w, view);
        }
        CoordMsg::Heartbeat { rank, secs } => {
            w.u8(5);
            w.u32(*rank);
            w.f64(*secs);
        }
        CoordMsg::FailureReport { rank } => {
            w.u8(6);
            w.u32(*rank);
        }
        CoordMsg::Shutdown { machine } => {
            w.u8(7);
            w.u32(*machine);
        }
        CoordMsg::ShutdownAck => w.u8(8),
        CoordMsg::Rejoin { machine } => {
            w.u8(9);
            w.u32(*machine);
        }
    }
    w.finish()
}

pub fn decode_coord_msg(buf: &[u8]) -> Result<CoordMsg, WireError> {
    let mut r = ByteReader::new(buf);
    let m = match r.u8()? {
        0 => CoordMsg::Hello { preferred: r.u32()? },
        1 => CoordMsg::Welcome {
            machine: r.u32()?,
            view: decode_view(&mut r)?,
        },
        2 => CoordMsg::BarrierArrive { rank: r.u32()? },
        3 => CoordMsg::DecisionMsg(Decision::Continue),
        4 => CoordMsg::DecisionMsg(Decision::Reconfigure(decode_view(
            &mut r,
        )?)),
        5 => CoordMsg::Heartbeat { rank: r.u32()?, secs: r.f64()? },
        6 => CoordMsg::FailureReport { rank: r.u32()? },
        7 => CoordMsg::Shutdown { machine: r.u32()? },
        8 => CoordMsg::ShutdownAck,
        9 => CoordMsg::Rejoin { machine: r.u32()? },
        k => return Err(WireError::BadPortKind(k)),
    };
    r.expect_end()?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::FRAME_HEADER_BYTES;

    fn frame_len(payload: &[u8]) -> u64 {
        (FRAME_HEADER_BYTES + payload.len()) as u64
    }

    #[test]
    fn kv_pull_request_and_response_round_trip() {
        let q = KvRequest::Pull {
            name: "feat".into(),
            locals: vec![0, 7, 31, 2],
        };
        let buf = encode_kv_request(&q);
        assert_eq!(decode_kv_request(&buf).unwrap(), q);
        assert_eq!(kv_pull_req_bytes("feat".len(), 4), frame_len(&buf));
        let p = KvResponse::Rows {
            dim: 3,
            data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        };
        let buf = encode_kv_response(&p);
        assert_eq!(decode_kv_response(&buf).unwrap(), p);
        assert_eq!(kv_pull_resp_bytes(2, 3), frame_len(&buf));
    }

    #[test]
    fn kv_pull_typed_round_trips() {
        let q = KvRequest::PullTyped {
            name: "feat/paper".into(),
            ntype: 1,
            locals: vec![5, 6],
        };
        let buf = encode_kv_request(&q);
        assert_eq!(decode_kv_request(&buf).unwrap(), q);
        let p = KvResponse::TypedRows {
            ntype: 1,
            dim: 2,
            data: vec![0.5, -0.5, 1.5, -1.5],
        };
        let buf = encode_kv_response(&p);
        assert_eq!(decode_kv_response(&buf).unwrap(), p);
    }

    #[test]
    fn kv_push_round_trips_and_sizes_agree() {
        let q = KvRequest::Push {
            name: "emb".into(),
            locals: vec![1, 2, 3],
            grads: vec![0.1; 6],
            lr: 0.05,
        };
        let buf = encode_kv_request(&q);
        assert_eq!(decode_kv_request(&buf).unwrap(), q);
        assert_eq!(kv_push_bytes("emb".len(), 3, 2), frame_len(&buf));
        let ok = encode_kv_response(&KvResponse::Ok);
        assert_eq!(decode_kv_response(&ok).unwrap(), KvResponse::Ok);
    }

    #[test]
    fn kv_error_responses_round_trip_typed() {
        for e in [
            RpcError::UnknownTensor { name: "nope".into(), machine: 2 },
            RpcError::ServerDown { machine: 1, role: "kv" },
            RpcError::ServerDown { machine: 0, role: "sampler" },
            RpcError::WorkerLost("sampling pipeline"),
            RpcError::ConnectionLost {
                peer: 3,
                detail: "read failed: eof".into(),
            },
        ] {
            let buf = encode_kv_response(&KvResponse::Err(e.clone()));
            assert_eq!(
                decode_kv_response(&buf).unwrap(),
                KvResponse::Err(e)
            );
        }
    }

    #[test]
    fn sampler_frontier_request_round_trips() {
        let q = SamplerRequest {
            seeds: vec![10, 20, 30],
            fanouts: vec![5, 2],
            rng_seed: 0xfeed_f00d,
        };
        let buf = encode_sampler_request(&q);
        assert_eq!(decode_sampler_request(&buf).unwrap(), q);
        assert_eq!(sampler_req_bytes(3, 2), frame_len(&buf));
    }

    #[test]
    fn sampler_blocks_response_round_trips() {
        let blocks = vec![
            SampledNbrs { nbrs: vec![1, 2, 3], rels: vec![0, 1, 0] },
            SampledNbrs { nbrs: vec![], rels: vec![] },
            SampledNbrs { nbrs: vec![9], rels: vec![2] },
        ];
        let p = SamplerResponse::Blocks(blocks.clone());
        let buf = encode_sampler_response(&p);
        match decode_sampler_response(&buf).unwrap() {
            SamplerResponse::Blocks(got) => {
                assert_eq!(got.len(), blocks.len());
                for (g, want) in got.iter().zip(&blocks) {
                    assert_eq!(g.nbrs, want.nbrs);
                    assert_eq!(g.rels, want.rels);
                }
            }
            other => panic!("expected blocks, got {other:?}"),
        }
        assert_eq!(sampler_resp_bytes(3, 4), frame_len(&buf));
        let err = SamplerResponse::Err(RpcError::ServerDown {
            machine: 1,
            role: "sampler",
        });
        let buf = encode_sampler_response(&err);
        assert_eq!(decode_sampler_response(&buf).unwrap(), err);
    }

    #[test]
    fn coordinator_messages_round_trip() {
        let view = MembershipView {
            epoch: 3,
            machines: vec![0, 2, 5],
            per_machine: 2,
        };
        let msgs = [
            CoordMsg::Hello { preferred: u32::MAX },
            CoordMsg::Hello { preferred: 1 },
            CoordMsg::Welcome { machine: 2, view: view.clone() },
            CoordMsg::BarrierArrive { rank: 4 },
            CoordMsg::DecisionMsg(Decision::Continue),
            CoordMsg::Heartbeat { rank: 3, secs: 0.0125 },
            CoordMsg::FailureReport { rank: 1 },
            CoordMsg::Shutdown { machine: 2 },
            CoordMsg::ShutdownAck,
            CoordMsg::Rejoin { machine: 1 },
        ];
        for m in msgs {
            let buf = encode_coord_msg(&m);
            assert_eq!(decode_coord_msg(&buf).unwrap(), m);
        }
    }

    #[test]
    fn resize_decision_round_trips_the_new_view() {
        // a Reconfigure decision *is* the resize message: it carries the
        // full post-resize membership view
        let view = MembershipView {
            epoch: 7,
            machines: vec![0, 1, 2, 3],
            per_machine: 4,
        };
        let m = CoordMsg::DecisionMsg(Decision::Reconfigure(view.clone()));
        let buf = encode_coord_msg(&m);
        match decode_coord_msg(&buf).unwrap() {
            CoordMsg::DecisionMsg(Decision::Reconfigure(got)) => {
                assert_eq!(got, view);
                assert_eq!(got.world_size(), 16);
            }
            other => panic!("expected reconfigure, got {other:?}"),
        }
    }

    #[test]
    fn truncated_and_garbage_payloads_fail_typed() {
        let q = KvRequest::Pull { name: "feat".into(), locals: vec![1] };
        let buf = encode_kv_request(&q);
        assert!(decode_kv_request(&buf[..buf.len() - 2]).is_err());
        assert!(decode_kv_request(&[9, 0, 0]).is_err());
        assert!(decode_coord_msg(&[42]).is_err());
        // trailing garbage is rejected, not silently ignored
        let mut extra = encode_coord_msg(&CoordMsg::ShutdownAck);
        extra.push(0);
        assert!(decode_coord_msg(&extra).is_err());
    }
}
