//! In-process message transport between simulated machines.
//!
//! Each machine owns an [`Endpoint`]; `send(dst, msg)` enqueues into dst's
//! mailbox (unbounded ordered channel per sender-receiver pair collapses to
//! a single mpsc here) and meters bytes on the shared [`CostModel`].

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use super::model::CostModel;
use crate::ft::FaultPlan;

/// Machine-level service ports (which server on the machine gets the
/// message).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Port {
    KvStore,
    Sampler,
    Trainer(u32),
    Control,
}

/// One framed message. `payload` is an opaque byte vector; `bytes()` is
/// what the cost model charges (header + payload).
#[derive(Debug)]
pub struct Message {
    pub from: u32,
    pub port: Port,
    pub tag: u64,
    pub payload: Vec<u8>,
}

impl Message {
    pub fn wire_bytes(&self) -> u64 {
        24 + self.payload.len() as u64
    }
}

struct Mailbox {
    tx: Sender<Message>,
}

/// The cluster fabric: create once, then `endpoint(m)` per participant.
///
/// Endpoints need not be machines: e.g. the trainer all-reduce ring has one
/// endpoint per *trainer*, with `machine_of` mapping endpoints to machines
/// so only genuinely cross-machine traffic is metered.
pub struct Transport {
    mailboxes: Vec<Mailbox>,
    receivers: Mutex<Vec<Option<Receiver<Message>>>>,
    machine_of: Vec<u32>,
    pub cost: Arc<CostModel>,
    /// Injected message drop/delay schedule (docs/DESIGN.md §8).
    fault: Mutex<Option<Arc<FaultPlan>>>,
}

impl Transport {
    pub fn new(n_machines: usize, cost: CostModel) -> Arc<Self> {
        Self::with_mapping(
            (0..n_machines as u32).collect(),
            Arc::new(cost),
        )
    }

    /// `machine_of[e]` = machine hosting endpoint `e`.
    pub fn with_mapping(
        machine_of: Vec<u32>,
        cost: Arc<CostModel>,
    ) -> Arc<Self> {
        let n = machine_of.len();
        let mut mailboxes = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            mailboxes.push(Mailbox { tx });
            receivers.push(Some(rx));
        }
        Arc::new(Self {
            mailboxes,
            receivers: Mutex::new(receivers),
            machine_of,
            cost,
            fault: Mutex::new(None),
        })
    }

    /// Gate every subsequent cross-machine send through `plan`'s
    /// drop/delay schedule (local sends stay untouched — shared memory
    /// does not lose messages).
    pub fn set_fault_plan(&self, plan: Arc<FaultPlan>) {
        *self.fault.lock().unwrap() = Some(plan);
    }

    pub fn n_machines(&self) -> usize {
        self.mailboxes.len()
    }

    /// Claim machine `m`'s endpoint (receiver side). Each machine claims
    /// its endpoint exactly once, at deployment.
    pub fn endpoint(self: &Arc<Self>, machine: u32) -> Endpoint {
        let rx = self.receivers.lock().unwrap()[machine as usize]
            .take()
            .expect("endpoint already claimed");
        Endpoint { machine, transport: Arc::clone(self), rx }
    }

    /// Send `msg` to `dst`'s mailbox, charging the cost model when the
    /// message crosses a machine boundary. A cross-machine message may
    /// be delayed or silently dropped by an installed [`FaultPlan`] —
    /// exactly the loss model protocols above must tolerate.
    pub fn send(&self, src: u32, dst: u32, msg: Message) {
        let (sm, dm) =
            (self.machine_of[src as usize], self.machine_of[dst as usize]);
        if sm != dm {
            let plan = self.fault.lock().unwrap().clone();
            if let Some(f) = plan {
                if !f.admit_message() {
                    return; // lost on the wire: never metered, never seen
                }
            }
            self.cost.on_network(sm, dm, msg.wire_bytes());
        }
        // local sends are free (shared memory path, §5.4)
        self.mailboxes[dst as usize]
            .tx
            .send(msg)
            .expect("destination endpoint dropped");
    }
}

/// Receiving side for one machine.
pub struct Endpoint {
    pub machine: u32,
    pub transport: Arc<Transport>,
    rx: Receiver<Message>,
}

impl Endpoint {
    pub fn recv(&self) -> Option<Message> {
        self.rx.recv().ok()
    }

    pub fn try_recv(&self) -> Option<Message> {
        self.rx.try_recv().ok()
    }

    pub fn send(&self, dst: u32, port: Port, tag: u64, payload: Vec<u8>) {
        self.transport.send(
            self.machine,
            dst,
            Message { from: self.machine, port, tag, payload },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_arrive_in_order() {
        let t = Transport::new(2, CostModel::default());
        let e0 = t.endpoint(0);
        let e1 = t.endpoint(1);
        for i in 0..10u64 {
            e0.send(1, Port::KvStore, i, vec![i as u8]);
        }
        for i in 0..10u64 {
            let m = e1.recv().unwrap();
            assert_eq!(m.tag, i);
            assert_eq!(m.from, 0);
        }
    }

    #[test]
    fn remote_bytes_are_metered_local_are_not() {
        let t = Transport::new(2, CostModel::default());
        let e0 = t.endpoint(0);
        let _e1 = t.endpoint(1);
        e0.send(0, Port::Sampler, 0, vec![0; 100]); // local
        assert_eq!(t.cost.network_bytes(), 0);
        e0.send(1, Port::Sampler, 0, vec![0; 100]); // remote
        assert_eq!(t.cost.network_bytes(), 124);
    }

    #[test]
    #[should_panic(expected = "endpoint already claimed")]
    fn endpoint_claimed_once() {
        let t = Transport::new(1, CostModel::default());
        let _a = t.endpoint(0);
        let _b = t.endpoint(0);
    }

    #[test]
    fn fault_plan_drops_and_delays_cross_machine_messages() {
        use crate::ft::FaultPlan;
        let t = Transport::new(2, CostModel::default());
        let e0 = t.endpoint(0);
        let e1 = t.endpoint(1);
        let mut plan = FaultPlan::new();
        plan.drop_every = 2; // every 2nd cross-machine message vanishes
        plan.delay = std::time::Duration::from_micros(50);
        let plan = Arc::new(plan);
        t.set_fault_plan(plan.clone());
        for i in 0..6u64 {
            e0.send(1, Port::KvStore, i, vec![]);
        }
        let got: Vec<u64> =
            std::iter::from_fn(|| e1.try_recv().map(|m| m.tag)).collect();
        assert_eq!(got, vec![0, 2, 4], "odd-indexed sends dropped");
        assert_eq!(plan.dropped_msgs(), 3);
        assert_eq!(plan.delayed_msgs(), 6);
        // local sends bypass the wire and its faults entirely
        e1.send(1, Port::Control, 9, vec![]);
        assert_eq!(e1.try_recv().unwrap().tag, 9);
        assert_eq!(plan.dropped_msgs(), 3);
    }

    #[test]
    fn cross_thread_send() {
        let t = Transport::new(2, CostModel::default());
        let e0 = t.endpoint(0);
        let e1 = t.endpoint(1);
        let t2 = Arc::clone(&t);
        let h = std::thread::spawn(move || {
            let m = e1.recv().unwrap();
            assert_eq!(m.payload, vec![7]);
            t2.send(1, 0, Message {
                from: 1,
                port: Port::Control,
                tag: 99,
                payload: vec![8],
            });
        });
        e0.send(1, Port::Control, 1, vec![7]);
        let back = e0.recv().unwrap();
        assert_eq!(back.tag, 99);
        h.join().unwrap();
    }
}
