//! Message transport between machines, pluggable over process boundaries.
//!
//! The [`Transport`]/[`Endpoint`] surface is what every layer above (KV
//! pulls, sampler RPCs, the all-reduce ring, the coordinator) programs
//! against. Beneath it sits a [`TransportBackend`]:
//!
//! * [in-process](Transport::new) — the original simulated fabric: sends
//!   are enqueue operations on shared memory, cross-machine bytes are
//!   metered on the [`CostModel`], and an installed
//!   [`FaultPlan`](crate::ft::FaultPlan) may drop or delay them.
//! * [TCP](crate::net::tcp) — real sockets between OS processes with the
//!   length-framed, versioned encoding of [`crate::net::wire`].
//!
//! Both backends deliver into the same per-endpoint [`PortQueues`]
//! structure (one FIFO per [`PortKind`] plus a global arrival sequence),
//! so receive semantics — `recv` in arrival order, `recv_kind` filtered
//! by service — are identical regardless of what the wire is. That
//! equivalence is the backbone of the in-process ≡ multi-process
//! byte-identity tests (docs/DESIGN.md §11).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::model::CostModel;
use super::wire::FRAME_HEADER_BYTES;
use super::RpcError;
use crate::ft::FaultPlan;

/// Machine-level service ports (which server on the machine gets the
/// message).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Port {
    KvStore,
    Sampler,
    Trainer(u32),
    Control,
}

/// The four service queues every endpoint demuxes into. `Trainer(r)`
/// collapses to one kind: the ring protocol disambiguates senders by the
/// rank argument carried in the port, not by separate queues.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum PortKind {
    KvStore = 0,
    Sampler = 1,
    Trainer = 2,
    Control = 3,
}

pub(crate) const N_PORT_KINDS: usize = 4;

impl Port {
    pub fn kind(&self) -> PortKind {
        match self {
            Port::KvStore => PortKind::KvStore,
            Port::Sampler => PortKind::Sampler,
            Port::Trainer(_) => PortKind::Trainer,
            Port::Control => PortKind::Control,
        }
    }
}

/// One framed message. `payload` is an opaque byte vector; `wire_bytes()`
/// is what the cost model charges: the real frame-header size plus the
/// payload, kept in lockstep with the TCP encoding by using the same
/// [`FRAME_HEADER_BYTES`] constant (regression-tested in `net::wire`).
#[derive(Clone, Debug)]
pub struct Message {
    pub from: u32,
    pub port: Port,
    pub tag: u64,
    pub payload: Vec<u8>,
}

impl Message {
    pub fn wire_bytes(&self) -> u64 {
        (FRAME_HEADER_BYTES + self.payload.len()) as u64
    }
}

struct QueueState {
    /// One FIFO per [`PortKind`], each entry stamped with a global
    /// arrival sequence so `recv`-any preserves overall arrival order.
    queues: [VecDeque<(u64, Message)>; N_PORT_KINDS],
    next_seq: u64,
    closed: bool,
}

/// Per-endpoint receive demux shared by every backend: senders (local
/// enqueues or the TCP reader thread) push, the owning [`Endpoint`] pops.
pub struct PortQueues {
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl Default for PortQueues {
    fn default() -> Self {
        Self::new()
    }
}

impl PortQueues {
    pub fn new() -> Self {
        Self {
            state: Mutex::new(QueueState {
                queues: Default::default(),
                next_seq: 0,
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn push(&self, msg: Message) {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return; // endpoint shut down: drop, exactly like a dead socket
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.queues[msg.port.kind() as usize].push_back((seq, msg));
        self.cv.notify_all();
    }

    fn pop_locked(
        st: &mut QueueState,
        kind: Option<PortKind>,
    ) -> Option<Message> {
        match kind {
            Some(k) => {
                st.queues[k as usize].pop_front().map(|(_, m)| m)
            }
            None => {
                // arrival order: pop the lowest sequence across all kinds
                let idx = (0..N_PORT_KINDS)
                    .filter_map(|i| {
                        st.queues[i].front().map(|(seq, _)| (*seq, i))
                    })
                    .min()
                    .map(|(_, i)| i)?;
                st.queues[idx].pop_front().map(|(_, m)| m)
            }
        }
    }

    /// Pop a message (optionally only of `kind`), waiting up to `timeout`
    /// (or indefinitely when `None`). Returns `None` on timeout or when
    /// the queues are closed and drained.
    pub fn pop(
        &self,
        kind: Option<PortKind>,
        timeout: Option<Duration>,
    ) -> Option<Message> {
        let deadline = timeout.map(|d| Instant::now() + d);
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(m) = Self::pop_locked(&mut st, kind) {
                return Some(m);
            }
            if st.closed {
                return None;
            }
            match deadline {
                None => st = self.cv.wait(st).unwrap(),
                Some(dl) => {
                    let now = Instant::now();
                    if now >= dl {
                        return None;
                    }
                    let (guard, _) =
                        self.cv.wait_timeout(st, dl - now).unwrap();
                    st = guard;
                }
            }
        }
    }

    pub fn try_pop(&self, kind: Option<PortKind>) -> Option<Message> {
        let mut st = self.state.lock().unwrap();
        Self::pop_locked(&mut st, kind)
    }

    /// Wake all blocked receivers; subsequent pushes are dropped.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

/// What a wire implementation must provide. Everything above the trait
/// ([`Endpoint`], the RPC client/server loops, the all-reduce ring, the
/// rendezvous protocol) is backend-agnostic.
pub trait TransportBackend: Send + Sync {
    /// Deliver `msg` from endpoint `src` to endpoint `dst`. Errors are
    /// the typed RPC vocabulary — a TCP backend maps socket failures to
    /// [`RpcError::ConnectionLost`]; the in-process backend only fails
    /// after shutdown.
    fn send(&self, src: u32, dst: u32, msg: Message) -> Result<(), RpcError>;

    /// Receive queues for endpoint `ep`, or `None` when `ep` lives in a
    /// different OS process (TCP backend) and cannot be claimed here.
    fn queues(&self, ep: u32) -> Option<Arc<PortQueues>>;

    /// Total endpoints in the fabric (across all processes).
    fn n_endpoints(&self) -> usize;

    /// Machine hosting endpoint `ep` (endpoints need not be machines:
    /// the trainer ring has one endpoint per trainer).
    fn machine_of(&self, ep: u32) -> u32;

    /// Install a message drop/delay/partition/conn-kill schedule. Both
    /// shipped backends honor it: the emulated fabric drops/delays
    /// enqueues, the TCP backend additionally kills real sockets
    /// (test-only chaos hook, docs/DESIGN.md §12).
    fn set_fault_plan(&self, _plan: Arc<FaultPlan>) {}

    /// Release wire resources and wake all blocked receivers. Idempotent.
    fn shutdown(&self) {}
}

/// In-process backend: the original simulated fabric. Sends are shared
/// memory enqueues; cross-machine traffic is metered on the [`CostModel`]
/// and subject to an installed [`FaultPlan`]; local traffic is free.
struct InProcBackend {
    queues: Vec<Arc<PortQueues>>,
    machine_of: Vec<u32>,
    cost: Arc<CostModel>,
    fault: Mutex<Option<Arc<FaultPlan>>>,
}

impl TransportBackend for InProcBackend {
    fn send(&self, src: u32, dst: u32, msg: Message) -> Result<(), RpcError> {
        let (sm, dm) =
            (self.machine_of[src as usize], self.machine_of[dst as usize]);
        if sm != dm {
            let plan = self.fault.lock().unwrap().clone();
            if let Some(f) = plan {
                // shared chaos verdict (drops, delays, partitions); a
                // connection-kill verdict still delivers — there is no
                // socket here, only the counter advances (see
                // `MessageVerdict::DeliverThenKillConn`)
                if f.message_verdict(sm, dm)
                    == crate::ft::MessageVerdict::Drop
                {
                    return Ok(()); // lost on the wire: never metered
                }
            }
            self.cost.on_network(sm, dm, msg.wire_bytes());
        }
        // local sends are free (shared memory path, §5.4)
        self.queues[dst as usize].push(msg);
        Ok(())
    }

    fn queues(&self, ep: u32) -> Option<Arc<PortQueues>> {
        self.queues.get(ep as usize).map(Arc::clone)
    }

    fn n_endpoints(&self) -> usize {
        self.queues.len()
    }

    fn machine_of(&self, ep: u32) -> u32 {
        self.machine_of[ep as usize]
    }

    fn set_fault_plan(&self, plan: Arc<FaultPlan>) {
        *self.fault.lock().unwrap() = Some(plan);
    }

    fn shutdown(&self) {
        for q in &self.queues {
            q.close();
        }
    }
}

/// The cluster fabric: create once, then `endpoint(m)` per participant.
///
/// Endpoints need not be machines: e.g. the trainer all-reduce ring has one
/// endpoint per *trainer*, with `machine_of` mapping endpoints to machines
/// so only genuinely cross-machine traffic is metered.
pub struct Transport {
    backend: Box<dyn TransportBackend>,
    claimed: Mutex<Vec<bool>>,
    pub cost: Arc<CostModel>,
}

impl Transport {
    pub fn new(n_machines: usize, cost: CostModel) -> Arc<Self> {
        Self::with_mapping((0..n_machines as u32).collect(), Arc::new(cost))
    }

    /// `machine_of[e]` = machine hosting endpoint `e`.
    pub fn with_mapping(
        machine_of: Vec<u32>,
        cost: Arc<CostModel>,
    ) -> Arc<Self> {
        let n = machine_of.len();
        let backend = InProcBackend {
            queues: (0..n).map(|_| Arc::new(PortQueues::new())).collect(),
            machine_of,
            cost: Arc::clone(&cost),
            fault: Mutex::new(None),
        };
        Self::from_backend(Box::new(backend), cost)
    }

    /// Wrap an arbitrary backend (used by [`crate::net::tcp`]).
    pub fn from_backend(
        backend: Box<dyn TransportBackend>,
        cost: Arc<CostModel>,
    ) -> Arc<Self> {
        let n = backend.n_endpoints();
        Arc::new(Self {
            backend,
            claimed: Mutex::new(vec![false; n]),
            cost,
        })
    }

    /// Gate every subsequent cross-machine send through `plan`'s
    /// drop/delay/partition/conn-kill schedule (local sends stay
    /// untouched — shared memory does not lose messages). On the TCP
    /// backend this is the chaos hook: kills close real sockets.
    pub fn set_fault_plan(&self, plan: Arc<FaultPlan>) {
        self.backend.set_fault_plan(plan);
    }

    pub fn n_machines(&self) -> usize {
        self.backend.n_endpoints()
    }

    pub fn n_endpoints(&self) -> usize {
        self.backend.n_endpoints()
    }

    pub fn machine_of(&self, ep: u32) -> u32 {
        self.backend.machine_of(ep)
    }

    /// Whether endpoint `ep` is receivable in this process (always true
    /// in-process; the TCP backend hosts a subset).
    pub fn hosts_endpoint(&self, ep: u32) -> bool {
        self.backend.queues(ep).is_some()
    }

    /// Claim machine `m`'s endpoint (receiver side). Each machine claims
    /// its endpoint exactly once, at deployment.
    pub fn endpoint(self: &Arc<Self>, machine: u32) -> Endpoint {
        let queues = self
            .backend
            .queues(machine)
            .expect("endpoint not hosted by this process");
        let mut claimed = self.claimed.lock().unwrap();
        assert!(
            !claimed[machine as usize],
            "endpoint already claimed"
        );
        claimed[machine as usize] = true;
        Endpoint { machine, transport: Arc::clone(self), queues }
    }

    /// Send `msg` to `dst`'s mailbox, charging the cost model when the
    /// message crosses a machine boundary. A cross-machine message may
    /// be delayed or silently dropped by an installed [`FaultPlan`] —
    /// exactly the loss model protocols above must tolerate. On a real
    /// wire, socket failures surface as [`RpcError::ConnectionLost`].
    pub fn send(
        &self,
        src: u32,
        dst: u32,
        msg: Message,
    ) -> Result<(), RpcError> {
        self.backend.send(src, dst, msg)
    }

    /// Tear the fabric down: wake blocked receivers, close sockets.
    pub fn shutdown(&self) {
        self.backend.shutdown();
    }
}

impl Drop for Transport {
    fn drop(&mut self) {
        self.backend.shutdown();
    }
}

/// Receiving side for one machine.
pub struct Endpoint {
    pub machine: u32,
    pub transport: Arc<Transport>,
    queues: Arc<PortQueues>,
}

impl Endpoint {
    /// Block until the next message in arrival order (any port). `None`
    /// once the transport is shut down and the queues drained.
    pub fn recv(&self) -> Option<Message> {
        self.queues.pop(None, None)
    }

    pub fn try_recv(&self) -> Option<Message> {
        self.queues.try_pop(None)
    }

    /// Bounded-wait receive: `None` on timeout or shutdown.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Message> {
        self.queues.pop(None, Some(timeout))
    }

    /// Receive only messages for one service queue, leaving other ports'
    /// traffic untouched (the rendezvous client and the all-reduce ring
    /// share an endpoint without stealing each other's frames).
    pub fn recv_kind(
        &self,
        kind: PortKind,
        timeout: Option<Duration>,
    ) -> Option<Message> {
        self.queues.pop(Some(kind), timeout)
    }

    /// Whether the transport beneath this endpoint has been shut down
    /// (a `recv` returning `None` is then terminal, not a timeout).
    pub fn is_closed(&self) -> bool {
        self.queues.is_closed()
    }

    pub fn send(
        &self,
        dst: u32,
        port: Port,
        tag: u64,
        payload: Vec<u8>,
    ) -> Result<(), RpcError> {
        self.transport.send(
            self.machine,
            dst,
            Message { from: self.machine, port, tag, payload },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_arrive_in_order() {
        let t = Transport::new(2, CostModel::default());
        let e0 = t.endpoint(0);
        let e1 = t.endpoint(1);
        for i in 0..10u64 {
            e0.send(1, Port::KvStore, i, vec![i as u8]).unwrap();
        }
        for i in 0..10u64 {
            let m = e1.recv().unwrap();
            assert_eq!(m.tag, i);
            assert_eq!(m.from, 0);
        }
    }

    #[test]
    fn remote_bytes_are_metered_local_are_not() {
        let t = Transport::new(2, CostModel::default());
        let e0 = t.endpoint(0);
        let _e1 = t.endpoint(1);
        e0.send(0, Port::Sampler, 0, vec![0; 100]).unwrap(); // local
        assert_eq!(t.cost.network_bytes(), 0);
        e0.send(1, Port::Sampler, 0, vec![0; 100]).unwrap(); // remote
        // header size derives from the actual framed encoding — the
        // emulated meter and the TCP wire charge identical bytes.
        assert_eq!(
            t.cost.network_bytes(),
            (FRAME_HEADER_BYTES + 100) as u64
        );
    }

    #[test]
    #[should_panic(expected = "endpoint already claimed")]
    fn endpoint_claimed_once() {
        let t = Transport::new(1, CostModel::default());
        let _a = t.endpoint(0);
        let _b = t.endpoint(0);
    }

    #[test]
    fn fault_plan_drops_and_delays_cross_machine_messages() {
        use crate::ft::FaultPlan;
        let t = Transport::new(2, CostModel::default());
        let e0 = t.endpoint(0);
        let e1 = t.endpoint(1);
        let mut plan = FaultPlan::new();
        plan.drop_every = 2; // every 2nd cross-machine message vanishes
        plan.delay = std::time::Duration::from_micros(50);
        let plan = Arc::new(plan);
        t.set_fault_plan(plan.clone());
        for i in 0..6u64 {
            e0.send(1, Port::KvStore, i, vec![]).unwrap();
        }
        let got: Vec<u64> =
            std::iter::from_fn(|| e1.try_recv().map(|m| m.tag)).collect();
        assert_eq!(got, vec![0, 2, 4], "odd-indexed sends dropped");
        assert_eq!(plan.dropped_msgs(), 3);
        assert_eq!(plan.delayed_msgs(), 6);
        // local sends bypass the wire and its faults entirely
        e1.send(1, Port::Control, 9, vec![]).unwrap();
        assert_eq!(e1.try_recv().unwrap().tag, 9);
        assert_eq!(plan.dropped_msgs(), 3);
    }

    #[test]
    fn cross_thread_send() {
        let t = Transport::new(2, CostModel::default());
        let e0 = t.endpoint(0);
        let e1 = t.endpoint(1);
        let t2 = Arc::clone(&t);
        let h = std::thread::spawn(move || {
            let m = e1.recv().unwrap();
            assert_eq!(m.payload, vec![7]);
            t2.send(1, 0, Message {
                from: 1,
                port: Port::Control,
                tag: 99,
                payload: vec![8],
            })
            .unwrap();
        });
        e0.send(1, Port::Control, 1, vec![7]).unwrap();
        let back = e0.recv().unwrap();
        assert_eq!(back.tag, 99);
        h.join().unwrap();
    }

    #[test]
    fn recv_kind_filters_without_stealing_other_ports() {
        let t = Transport::new(2, CostModel::default());
        let e0 = t.endpoint(0);
        let e1 = t.endpoint(1);
        e0.send(1, Port::Control, 1, vec![]).unwrap();
        e0.send(1, Port::Trainer(0), 2, vec![]).unwrap();
        e0.send(1, Port::Control, 3, vec![]).unwrap();
        // trainer traffic first: control frames stay queued
        let m = e1.recv_kind(PortKind::Trainer, None).unwrap();
        assert_eq!(m.tag, 2);
        // recv-any still sees control frames in arrival order
        assert_eq!(e1.recv().unwrap().tag, 1);
        assert_eq!(e1.recv().unwrap().tag, 3);
    }

    #[test]
    fn recv_any_preserves_arrival_order_across_kinds() {
        let t = Transport::new(2, CostModel::default());
        let e0 = t.endpoint(0);
        let e1 = t.endpoint(1);
        e0.send(1, Port::KvStore, 10, vec![]).unwrap();
        e0.send(1, Port::Sampler, 11, vec![]).unwrap();
        e0.send(1, Port::Control, 12, vec![]).unwrap();
        e0.send(1, Port::KvStore, 13, vec![]).unwrap();
        let tags: Vec<u64> = (0..4).map(|_| e1.recv().unwrap().tag).collect();
        assert_eq!(tags, vec![10, 11, 12, 13]);
    }

    #[test]
    fn recv_timeout_returns_none_when_idle() {
        let t = Transport::new(1, CostModel::default());
        let e0 = t.endpoint(0);
        let start = std::time::Instant::now();
        assert!(e0.recv_timeout(Duration::from_millis(10)).is_none());
        assert!(start.elapsed() >= Duration::from_millis(10));
        assert!(e0
            .recv_kind(PortKind::Control, Some(Duration::from_millis(5)))
            .is_none());
    }

    #[test]
    fn shutdown_wakes_blocked_receivers() {
        let t = Transport::new(1, CostModel::default());
        let e0 = t.endpoint(0);
        let t2 = Arc::clone(&t);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            t2.shutdown();
        });
        assert!(e0.recv().is_none(), "recv unblocks with None on shutdown");
        h.join().unwrap();
    }
}
