//! Link cost model: every byte crossing a machine boundary (network) or
//! the host↔device boundary (PCIe) is metered, and converted to *modeled
//! time* under the paper testbed's link parameters (100 Gbps network,
//! PCIe 3.0 x16 ≈ 12 GB/s effective). Benches report modeled time next to
//! wall-clock so speedup *shapes* survive the hardware substitution
//! (DESIGN.md §2).

use std::sync::atomic::{AtomicU64, Ordering};

/// Thread-safe byte/time accounting for the whole cluster.
#[derive(Debug)]
pub struct CostModel {
    /// Effective network bandwidth, bytes/sec.
    pub net_bytes_per_sec: f64,
    /// Per-message network latency, seconds.
    pub net_latency_s: f64,
    /// Effective PCIe bandwidth (host→device), bytes/sec.
    pub pcie_bytes_per_sec: f64,

    net_bytes: AtomicU64,
    net_msgs: AtomicU64,
    pcie_bytes: AtomicU64,
    pcie_xfers: AtomicU64,
}

impl Default for CostModel {
    /// Paper testbed: 100 Gbps network (≈11 GB/s effective), PCIe 3.0 x16.
    fn default() -> Self {
        Self::new(11e9, 20e-6, 12e9)
    }
}

impl CostModel {
    pub fn new(
        net_bytes_per_sec: f64,
        net_latency_s: f64,
        pcie_bytes_per_sec: f64,
    ) -> Self {
        Self {
            net_bytes_per_sec,
            net_latency_s,
            pcie_bytes_per_sec,
            net_bytes: AtomicU64::new(0),
            net_msgs: AtomicU64::new(0),
            pcie_bytes: AtomicU64::new(0),
            pcie_xfers: AtomicU64::new(0),
        }
    }

    pub fn on_network(&self, _src: u32, _dst: u32, bytes: u64) {
        self.net_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.net_msgs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_pcie(&self, bytes: u64) {
        self.pcie_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.pcie_xfers.fetch_add(1, Ordering::Relaxed);
    }

    pub fn network_bytes(&self) -> u64 {
        self.net_bytes.load(Ordering::Relaxed)
    }

    pub fn network_msgs(&self) -> u64 {
        self.net_msgs.load(Ordering::Relaxed)
    }

    pub fn pcie_bytes_total(&self) -> u64 {
        self.pcie_bytes.load(Ordering::Relaxed)
    }

    /// Modeled network transfer time, assuming ideal pipelining across the
    /// measured interval (serialization + per-message latency).
    pub fn modeled_network_secs(&self) -> f64 {
        self.network_bytes() as f64 / self.net_bytes_per_sec
            + self.network_msgs() as f64 * self.net_latency_s
    }

    pub fn modeled_pcie_secs(&self) -> f64 {
        self.pcie_bytes_total() as f64 / self.pcie_bytes_per_sec
    }

    pub fn reset(&self) {
        self.net_bytes.store(0, Ordering::Relaxed);
        self.net_msgs.store(0, Ordering::Relaxed);
        self.pcie_bytes.store(0, Ordering::Relaxed);
        self.pcie_xfers.store(0, Ordering::Relaxed);
    }

    /// Snapshot for before/after deltas in benches.
    pub fn snapshot(&self) -> CostSnapshot {
        CostSnapshot {
            net_bytes: self.network_bytes(),
            net_msgs: self.network_msgs(),
            pcie_bytes: self.pcie_bytes_total(),
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct CostSnapshot {
    pub net_bytes: u64,
    pub net_msgs: u64,
    pub pcie_bytes: u64,
}

impl CostSnapshot {
    pub fn delta(&self, later: &CostSnapshot) -> CostSnapshot {
        CostSnapshot {
            net_bytes: later.net_bytes - self.net_bytes,
            net_msgs: later.net_msgs - self.net_msgs,
            pcie_bytes: later.pcie_bytes - self.pcie_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_accumulates() {
        let c = CostModel::default();
        c.on_network(0, 1, 1000);
        c.on_network(1, 0, 500);
        c.on_pcie(2048);
        assert_eq!(c.network_bytes(), 1500);
        assert_eq!(c.network_msgs(), 2);
        assert_eq!(c.pcie_bytes_total(), 2048);
    }

    #[test]
    fn modeled_time_scales_with_bytes() {
        let c = CostModel::new(1e9, 1e-5, 1e9);
        c.on_network(0, 1, 1_000_000_000);
        let t = c.modeled_network_secs();
        assert!((t - (1.0 + 1e-5)).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn snapshot_delta() {
        let c = CostModel::default();
        c.on_network(0, 1, 100);
        let s1 = c.snapshot();
        c.on_network(0, 1, 250);
        let d = s1.delta(&c.snapshot());
        assert_eq!(d.net_bytes, 250); // on_network takes raw wire bytes
    }
}
