//! Link cost model: every byte crossing a machine boundary (network) or
//! the host↔device boundary (PCIe) is metered, and converted to *modeled
//! time* under the paper testbed's link parameters (100 Gbps network,
//! PCIe 3.0 x16 ≈ 12 GB/s effective). Benches report modeled time next to
//! wall-clock so speedup *shapes* survive the hardware substitution
//! (DESIGN.md §2).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Thread-safe byte/time accounting for the whole cluster.
#[derive(Debug)]
pub struct CostModel {
    /// Effective network bandwidth, bytes/sec.
    pub net_bytes_per_sec: f64,
    /// Per-message network latency, seconds.
    pub net_latency_s: f64,
    /// Effective PCIe bandwidth (host→device), bytes/sec.
    pub pcie_bytes_per_sec: f64,

    net_bytes: AtomicU64,
    net_msgs: AtomicU64,
    pcie_bytes: AtomicU64,
    pcie_xfers: AtomicU64,
    /// Per-machine straggler factors (≥ 1.0 slows every link touching
    /// that machine); indexed by machine, missing entries mean 1.0.
    slowdown: Mutex<Vec<f64>>,
}

impl Default for CostModel {
    /// Paper testbed: 100 Gbps network (≈11 GB/s effective), PCIe 3.0 x16.
    fn default() -> Self {
        Self::new(11e9, 20e-6, 12e9)
    }
}

impl CostModel {
    pub fn new(
        net_bytes_per_sec: f64,
        net_latency_s: f64,
        pcie_bytes_per_sec: f64,
    ) -> Self {
        Self {
            net_bytes_per_sec,
            net_latency_s,
            pcie_bytes_per_sec,
            net_bytes: AtomicU64::new(0),
            net_msgs: AtomicU64::new(0),
            pcie_bytes: AtomicU64::new(0),
            pcie_xfers: AtomicU64::new(0),
            slowdown: Mutex::new(Vec::new()),
        }
    }

    /// Mark `machine` as a straggler: every emulated transfer touching
    /// it is stretched by `factor` (clamped to ≥ 1.0). Modeled bytes
    /// are unaffected — a slow machine moves the same data, later
    /// (docs/DESIGN.md §8).
    pub fn set_slowdown(&self, machine: u32, factor: f64) {
        let mut s = self.slowdown.lock().unwrap();
        if s.len() <= machine as usize {
            s.resize(machine as usize + 1, 1.0);
        }
        s[machine as usize] = factor.max(1.0);
    }

    /// The straggler factor of a link: the slower endpoint dominates.
    pub fn pair_slowdown(&self, src: u32, dst: u32) -> f64 {
        let s = self.slowdown.lock().unwrap();
        let of = |m: u32| s.get(m as usize).copied().unwrap_or(1.0);
        of(src).max(of(dst))
    }

    pub fn on_network(&self, _src: u32, _dst: u32, bytes: u64) {
        self.net_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.net_msgs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_pcie(&self, bytes: u64) {
        self.pcie_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.pcie_xfers.fetch_add(1, Ordering::Relaxed);
    }

    pub fn network_bytes(&self) -> u64 {
        self.net_bytes.load(Ordering::Relaxed)
    }

    pub fn network_msgs(&self) -> u64 {
        self.net_msgs.load(Ordering::Relaxed)
    }

    pub fn pcie_bytes_total(&self) -> u64 {
        self.pcie_bytes.load(Ordering::Relaxed)
    }

    /// Modeled network transfer time, assuming ideal pipelining across the
    /// measured interval (serialization + per-message latency).
    pub fn modeled_network_secs(&self) -> f64 {
        self.network_bytes() as f64 / self.net_bytes_per_sec
            + self.network_msgs() as f64 * self.net_latency_s
    }

    pub fn modeled_pcie_secs(&self) -> f64 {
        self.pcie_bytes_total() as f64 / self.pcie_bytes_per_sec
    }

    pub fn reset(&self) {
        self.net_bytes.store(0, Ordering::Relaxed);
        self.net_msgs.store(0, Ordering::Relaxed);
        self.pcie_bytes.store(0, Ordering::Relaxed);
        self.pcie_xfers.store(0, Ordering::Relaxed);
    }

    /// Snapshot for before/after deltas in benches.
    pub fn snapshot(&self) -> CostSnapshot {
        CostSnapshot {
            net_bytes: self.network_bytes(),
            net_msgs: self.network_msgs(),
            pcie_bytes: self.pcie_bytes_total(),
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct CostSnapshot {
    pub net_bytes: u64,
    pub net_msgs: u64,
    pub pcie_bytes: u64,
}

impl CostSnapshot {
    pub fn delta(&self, later: &CostSnapshot) -> CostSnapshot {
        CostSnapshot {
            net_bytes: later.net_bytes - self.net_bytes,
            net_msgs: later.net_msgs - self.net_msgs,
            pcie_bytes: later.pcie_bytes - self.pcie_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_accumulates() {
        let c = CostModel::default();
        c.on_network(0, 1, 1000);
        c.on_network(1, 0, 500);
        c.on_pcie(2048);
        assert_eq!(c.network_bytes(), 1500);
        assert_eq!(c.network_msgs(), 2);
        assert_eq!(c.pcie_bytes_total(), 2048);
    }

    #[test]
    fn modeled_time_scales_with_bytes() {
        let c = CostModel::new(1e9, 1e-5, 1e9);
        c.on_network(0, 1, 1_000_000_000);
        let t = c.modeled_network_secs();
        assert!((t - (1.0 + 1e-5)).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn slowdown_defaults_to_unity_and_takes_the_link_max() {
        let c = CostModel::default();
        assert_eq!(c.pair_slowdown(0, 1), 1.0);
        c.set_slowdown(2, 3.5);
        assert_eq!(c.pair_slowdown(0, 2), 3.5);
        assert_eq!(c.pair_slowdown(2, 0), 3.5);
        assert_eq!(c.pair_slowdown(0, 1), 1.0);
        // factors below 1.0 are clamped (no speedups by accident)
        c.set_slowdown(2, 0.1);
        assert_eq!(c.pair_slowdown(0, 2), 1.0);
    }

    #[test]
    fn snapshot_delta() {
        let c = CostModel::default();
        c.on_network(0, 1, 100);
        let s1 = c.snapshot();
        c.on_network(0, 1, 250);
        let d = s1.delta(&c.snapshot());
        assert_eq!(d.net_bytes, 250); // on_network takes raw wire bytes
    }
}
