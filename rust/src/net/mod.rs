//! Simulated cluster interconnect.
//!
//! All cross-machine traffic in the system flows through a [`Transport`]:
//! ordered per-destination channels plus a [`CostModel`] that meters every
//! byte. The protocol logic above (KVStore pulls, sampler RPCs, gradient
//! all-reduce) is identical to a real deployment; only the wire is an
//! in-process channel. Benches report both wall-clock and modeled network
//! time (paper testbed: 100 Gbps + PCIe 3.0 — DESIGN.md §2).

pub mod model;
pub mod transport;

pub use model::CostModel;
pub use transport::{Endpoint, Message, Transport};
