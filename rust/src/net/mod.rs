//! Cluster interconnect: pluggable transports under one RPC surface.
//!
//! All cross-machine traffic in the system flows through a [`Transport`]:
//! ordered per-destination queues plus a [`CostModel`] that meters every
//! byte. Two backends implement the wire (docs/DESIGN.md §11):
//!
//! * **in-process** ([`Transport::new`]) — the simulated fabric used by
//!   tests and single-process runs; only the wire is an in-memory queue,
//!   the protocol logic above is identical to a real deployment, and
//!   benches report modeled network time (paper testbed: 100 Gbps +
//!   PCIe 3.0 — DESIGN.md §2).
//! * **TCP** ([`tcp`]) — real sockets between OS processes, length-framed
//!   and versioned ([`wire`]), with every RPC payload explicitly
//!   serialized ([`payload`]) and request/response loops in [`rpc`].

pub mod model;
pub mod payload;
pub mod retry;
pub mod rpc;
pub mod tcp;
pub mod transport;
pub mod wire;

pub use model::CostModel;
pub use retry::{with_retry, RetryPolicy};
pub use transport::{Endpoint, Message, Port, PortKind, Transport};

/// Typed error for every RPC boundary in the system (KVStore pulls,
/// sampler requests, pipeline fan-out, socket transport). Injected faults
/// ([`crate::ft::FaultPlan`]), lost worker threads, and real connection
/// failures surface as values of this type through `Result` instead of
/// poisoning threads with panics, so the pipeline can drain cleanly and
/// the trainer can decide to resume from a checkpoint (docs/DESIGN.md §8).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RpcError {
    /// A request named a tensor the addressed server never registered.
    UnknownTensor { name: String, machine: u32 },
    /// A server stayed unreachable through the bounded retry loop.
    ServerDown { machine: u32, role: &'static str },
    /// A fan-out / pipeline worker thread died before replying.
    WorkerLost(&'static str),
    /// A transport-level failure: TCP connect/read/write error, a recv
    /// timeout waiting for a response, or a frame the peer's wire
    /// version makes undecodable. `peer` is the endpoint id the failure
    /// was observed against.
    ConnectionLost { peer: u32, detail: String },
}

impl std::fmt::Display for RpcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RpcError::UnknownTensor { name, machine } => write!(
                f,
                "tensor {name:?} not registered on machine {machine}"
            ),
            RpcError::ServerDown { machine, role } => write!(
                f,
                "{role} server on machine {machine} unreachable \
                 (retries exhausted)"
            ),
            RpcError::WorkerLost(what) => {
                write!(f, "{what} worker thread lost")
            }
            RpcError::ConnectionLost { peer, detail } => {
                write!(f, "connection to endpoint {peer} lost: {detail}")
            }
        }
    }
}

impl std::error::Error for RpcError {}
