//! Simulated cluster interconnect.
//!
//! All cross-machine traffic in the system flows through a [`Transport`]:
//! ordered per-destination channels plus a [`CostModel`] that meters every
//! byte. The protocol logic above (KVStore pulls, sampler RPCs, gradient
//! all-reduce) is identical to a real deployment; only the wire is an
//! in-process channel. Benches report both wall-clock and modeled network
//! time (paper testbed: 100 Gbps + PCIe 3.0 — DESIGN.md §2).

pub mod model;
pub mod transport;

pub use model::CostModel;
pub use transport::{Endpoint, Message, Transport};

/// Typed error for every RPC boundary in the system (KVStore pulls,
/// sampler requests, pipeline fan-out). Injected faults
/// ([`crate::ft::FaultPlan`]) and lost worker threads surface as values
/// of this type through `Result` instead of poisoning threads with
/// panics, so the pipeline can drain cleanly and the trainer can decide
/// to resume from a checkpoint (docs/DESIGN.md §8).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RpcError {
    /// A request named a tensor the addressed server never registered.
    UnknownTensor { name: String, machine: u32 },
    /// A server stayed unreachable through the bounded retry loop.
    ServerDown { machine: u32, role: &'static str },
    /// A fan-out / pipeline worker thread died before replying.
    WorkerLost(&'static str),
}

impl std::fmt::Display for RpcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RpcError::UnknownTensor { name, machine } => write!(
                f,
                "tensor {name:?} not registered on machine {machine}"
            ),
            RpcError::ServerDown { machine, role } => write!(
                f,
                "{role} server on machine {machine} unreachable \
                 (retries exhausted)"
            ),
            RpcError::WorkerLost(what) => {
                write!(f, "{what} worker thread lost")
            }
        }
    }
}

impl std::error::Error for RpcError {}
