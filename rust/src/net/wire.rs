//! Wire format for framed transport messages (docs/DESIGN.md §11).
//!
//! Every message that crosses a process boundary is one length-framed,
//! versioned frame:
//!
//! ```text
//! offset  size  field
//!      0     4  magic        "DG2\n" (0x0a324744 LE) — catches port clashes
//!      4     2  version      WIRE_VERSION; mismatches are rejected loudly
//!      6     1  port_kind    0=KvStore 1=Sampler 2=Trainer 3=Control
//!      7     1  pad          always 0
//!      8     4  src          sender endpoint id
//!     12     4  dst          destination endpoint id
//!     16     4  port_arg     Trainer rank for Port::Trainer, else 0
//!     20     8  tag          request/response correlation tag
//!     28     4  payload_len  bytes of payload that follow the header
//!     32     …  payload
//! ```
//!
//! The in-process backend never serializes, but its [`CostModel`] metering
//! charges exactly what this encoding would put on the wire:
//! [`Message::wire_bytes`] is defined as `FRAME_HEADER_BYTES + payload`,
//! so emulated and real byte counts agree by construction (one constant,
//! regression-tested against the actual encoder below).
//!
//! [`CostModel`]: crate::net::CostModel
//! [`Message::wire_bytes`]: crate::net::Message::wire_bytes

use std::io::{Read, Write};

use super::transport::{Message, Port, PortKind};

/// Frame magic: ASCII "DG2" + newline so a text protocol accidentally
/// pointed at our port fails the magic check immediately.
pub const WIRE_MAGIC: u32 = u32::from_le_bytes(*b"DG2\n");

/// Bump on any incompatible frame or payload layout change. Peers with a
/// different version are rejected with [`WireError::VersionMismatch`]
/// rather than silently mis-decoded.
pub const WIRE_VERSION: u16 = 1;

/// Size of the frame header preceding every payload. This is the single
/// source of truth for header overhead: the TCP encoder writes exactly
/// this many bytes and the emulated cost model charges exactly this many
/// bytes per message (`Message::wire_bytes`).
pub const FRAME_HEADER_BYTES: usize = 32;

/// Decode/IO failures on the framed wire.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// First four bytes were not [`WIRE_MAGIC`] — not our protocol.
    BadMagic(u32),
    /// Peer speaks a different wire version; refuse rather than guess.
    VersionMismatch { got: u16, want: u16 },
    /// `port_kind` byte outside the known range.
    BadPortKind(u8),
    /// Buffer ended before the header or declared payload completed.
    Truncated { need: usize, have: usize },
    /// Underlying socket error (message text of the `io::Error`).
    Io(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic(m) => {
                write!(f, "bad frame magic {m:#010x} (expected {WIRE_MAGIC:#010x})")
            }
            WireError::VersionMismatch { got, want } => write!(
                f,
                "wire version mismatch: peer sent v{got}, this build \
                 speaks v{want} — refusing to decode"
            ),
            WireError::BadPortKind(k) => write!(f, "unknown port kind {k}"),
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e.to_string())
    }
}

fn port_to_parts(p: Port) -> (u8, u32) {
    match p {
        Port::Trainer(rank) => (PortKind::Trainer as u8, rank),
        other => (other.kind() as u8, 0),
    }
}

fn port_from_parts(kind: u8, arg: u32) -> Result<Port, WireError> {
    match kind {
        0 => Ok(Port::KvStore),
        1 => Ok(Port::Sampler),
        2 => Ok(Port::Trainer(arg)),
        3 => Ok(Port::Control),
        k => Err(WireError::BadPortKind(k)),
    }
}

/// Serialize the frame header for `msg` addressed to endpoint `dst`.
pub fn encode_header(dst: u32, msg: &Message) -> [u8; FRAME_HEADER_BYTES] {
    let (kind, arg) = port_to_parts(msg.port);
    let mut h = [0u8; FRAME_HEADER_BYTES];
    h[0..4].copy_from_slice(&WIRE_MAGIC.to_le_bytes());
    h[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    h[6] = kind;
    h[7] = 0;
    h[8..12].copy_from_slice(&msg.from.to_le_bytes());
    h[12..16].copy_from_slice(&dst.to_le_bytes());
    h[16..20].copy_from_slice(&arg.to_le_bytes());
    h[20..28].copy_from_slice(&msg.tag.to_le_bytes());
    h[28..32].copy_from_slice(&(msg.payload.len() as u32).to_le_bytes());
    h
}

/// Serialize a complete frame (header + payload) into one buffer.
pub fn encode_frame(dst: u32, msg: &Message) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER_BYTES + msg.payload.len());
    out.extend_from_slice(&encode_header(dst, msg));
    out.extend_from_slice(&msg.payload);
    out
}

fn le_u16(b: &[u8]) -> u16 {
    u16::from_le_bytes([b[0], b[1]])
}
fn le_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}
fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Parse a header; returns `(dst, from, port, tag, payload_len)`.
pub fn decode_header(
    h: &[u8],
) -> Result<(u32, u32, Port, u64, usize), WireError> {
    if h.len() < FRAME_HEADER_BYTES {
        return Err(WireError::Truncated {
            need: FRAME_HEADER_BYTES,
            have: h.len(),
        });
    }
    let magic = le_u32(&h[0..4]);
    if magic != WIRE_MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = le_u16(&h[4..6]);
    if version != WIRE_VERSION {
        return Err(WireError::VersionMismatch {
            got: version,
            want: WIRE_VERSION,
        });
    }
    let port = port_from_parts(h[6], le_u32(&h[16..20]))?;
    let from = le_u32(&h[8..12]);
    let dst = le_u32(&h[12..16]);
    let tag = le_u64(&h[20..28]);
    let payload_len = le_u32(&h[28..32]) as usize;
    Ok((dst, from, port, tag, payload_len))
}

/// Decode a complete frame from `buf`; returns `(dst, message)`.
pub fn decode_frame(buf: &[u8]) -> Result<(u32, Message), WireError> {
    let (dst, from, port, tag, payload_len) = decode_header(buf)?;
    let need = FRAME_HEADER_BYTES + payload_len;
    if buf.len() < need {
        return Err(WireError::Truncated { need, have: buf.len() });
    }
    let payload =
        buf[FRAME_HEADER_BYTES..FRAME_HEADER_BYTES + payload_len].to_vec();
    Ok((dst, Message { from, port, tag, payload }))
}

/// Write one frame to a stream (header then payload, no extra copies of
/// the payload).
pub fn write_frame<W: Write>(
    w: &mut W,
    dst: u32,
    msg: &Message,
) -> Result<(), WireError> {
    w.write_all(&encode_header(dst, msg))?;
    w.write_all(&msg.payload)?;
    Ok(())
}

/// Read one frame from a stream. Blocks until a full frame arrives or
/// the stream errors/closes (EOF inside a frame is [`WireError::Io`]).
pub fn read_frame<R: Read>(r: &mut R) -> Result<(u32, Message), WireError> {
    let mut h = [0u8; FRAME_HEADER_BYTES];
    r.read_exact(&mut h)?;
    let (dst, from, port, tag, payload_len) = decode_header(&h)?;
    let mut payload = vec![0u8; payload_len];
    r.read_exact(&mut payload)?;
    Ok((dst, Message { from, port, tag, payload }))
}

/// Little-endian payload writer used by every RPC codec in
/// [`crate::net::payload`]. Hand-rolled (no serde in the dependency set)
/// and symmetric with [`ByteReader`].
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Self { buf: Vec::with_capacity(n) }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed (u16) UTF-8 string.
    pub fn str(&mut self, s: &str) {
        debug_assert!(s.len() <= u16::MAX as usize);
        self.u16(s.len() as u16);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed (u32) slice of u32s.
    pub fn u32s(&mut self, vs: &[u32]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.u32(v);
        }
    }

    /// Length-prefixed (u32) slice of u64s.
    pub fn u64s(&mut self, vs: &[u64]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.u64(v);
        }
    }

    /// Length-prefixed (u32) slice of f32s.
    pub fn f32s(&mut self, vs: &[f32]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.f32(v);
        }
    }

    /// Length-prefixed (u32) raw byte slice.
    pub fn bytes(&mut self, vs: &[u8]) {
        self.u32(vs.len() as u32);
        self.buf.extend_from_slice(vs);
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor-based reader mirroring [`ByteWriter`]. Every accessor returns
/// `Result` — a short or corrupt payload becomes a [`WireError::Truncated`],
/// never a panic.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.buf.len() {
            return Err(WireError::Truncated {
                need: self.pos + n,
                have: self.buf.len(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, WireError> {
        Ok(le_u16(self.take(2)?))
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(le_u32(self.take(4)?))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(le_u64(self.take(8)?))
    }

    pub fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String, WireError> {
        let n = self.u16()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|e| WireError::Io(format!("invalid utf-8 string: {e}")))
    }

    pub fn u32s(&mut self) -> Result<Vec<u32>, WireError> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(n.min(self.remaining() / 4));
        for _ in 0..n {
            out.push(self.u32()?);
        }
        Ok(out)
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>, WireError> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(n.min(self.remaining() / 8));
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(n.min(self.remaining() / 4));
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>, WireError> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Assert the payload was consumed exactly — catches codec drift.
    pub fn expect_end(&self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::Truncated {
                need: self.pos,
                have: self.buf.len(),
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(port: Port, tag: u64, payload: Vec<u8>) -> Message {
        Message { from: 3, port, tag, payload }
    }

    #[test]
    fn frame_round_trips_every_port() {
        for port in [
            Port::KvStore,
            Port::Sampler,
            Port::Trainer(0),
            Port::Trainer(41),
            Port::Control,
        ] {
            let m = msg(port, 0xdead_beef_cafe, vec![1, 2, 3, 4, 5]);
            let buf = encode_frame(7, &m);
            let (dst, back) = decode_frame(&buf).unwrap();
            assert_eq!(dst, 7);
            assert_eq!(back.from, 3);
            assert_eq!(back.port, port);
            assert_eq!(back.tag, 0xdead_beef_cafe);
            assert_eq!(back.payload, vec![1, 2, 3, 4, 5]);
        }
    }

    #[test]
    fn header_constant_matches_actual_encoding() {
        // Satellite: `Message::wire_bytes()` must charge exactly what the
        // framed encoding puts on the wire — derive, don't hardcode.
        for n in [0usize, 1, 100, 4096] {
            let m = msg(Port::KvStore, 9, vec![0xab; n]);
            let framed = encode_frame(0, &m);
            assert_eq!(framed.len(), FRAME_HEADER_BYTES + n);
            assert_eq!(m.wire_bytes(), framed.len() as u64);
        }
    }

    #[test]
    fn bumped_wire_version_is_rejected() {
        let m = msg(Port::Control, 1, vec![9]);
        let mut buf = encode_frame(0, &m);
        let bumped = WIRE_VERSION + 1;
        buf[4..6].copy_from_slice(&bumped.to_le_bytes());
        let err = decode_frame(&buf).unwrap_err();
        assert_eq!(
            err,
            WireError::VersionMismatch { got: bumped, want: WIRE_VERSION }
        );
        let text = err.to_string();
        assert!(text.contains("version mismatch"), "clear error: {text}");
        assert!(text.contains("v2") && text.contains("v1"), "{text}");
    }

    #[test]
    fn bad_magic_and_truncation_are_rejected() {
        let m = msg(Port::Sampler, 1, vec![1, 2, 3]);
        let buf = encode_frame(0, &m);
        let mut garbled = buf.clone();
        garbled[0] = b'X';
        assert!(matches!(
            decode_frame(&garbled),
            Err(WireError::BadMagic(_))
        ));
        assert!(matches!(
            decode_frame(&buf[..FRAME_HEADER_BYTES + 1]),
            Err(WireError::Truncated { .. })
        ));
        assert!(matches!(
            decode_frame(&buf[..10]),
            Err(WireError::Truncated { .. })
        ));
    }

    #[test]
    fn stream_read_write_round_trip() {
        let m = msg(Port::Trainer(2), 77, vec![5; 300]);
        let mut stream = Vec::new();
        write_frame(&mut stream, 4, &m).unwrap();
        write_frame(&mut stream, 5, &msg(Port::Control, 78, vec![])).unwrap();
        let mut cur = std::io::Cursor::new(stream);
        let (d0, m0) = read_frame(&mut cur).unwrap();
        let (d1, m1) = read_frame(&mut cur).unwrap();
        assert_eq!((d0, m0.tag, m0.payload.len()), (4, 77, 300));
        assert_eq!((d1, m1.tag, m1.port), (5, 78, Port::Control));
        // a third read hits clean EOF → Io error, not a panic
        assert!(matches!(read_frame(&mut cur), Err(WireError::Io(_))));
    }

    #[test]
    fn byte_writer_reader_round_trip() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u16(65535);
        w.u32(123_456);
        w.u64(1 << 40);
        w.f32(0.25);
        w.f64(-1.5);
        w.str("feat/paper");
        w.u32s(&[1, 2, 3]);
        w.u64s(&[9, 8]);
        w.f32s(&[1.0, 2.0]);
        w.bytes(&[0xaa, 0xbb]);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 65535);
        assert_eq!(r.u32().unwrap(), 123_456);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), 0.25);
        assert_eq!(r.f64().unwrap(), -1.5);
        assert_eq!(r.str().unwrap(), "feat/paper");
        assert_eq!(r.u32s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.u64s().unwrap(), vec![9, 8]);
        assert_eq!(r.f32s().unwrap(), vec![1.0, 2.0]);
        assert_eq!(r.bytes().unwrap(), vec![0xaa, 0xbb]);
        r.expect_end().unwrap();
        // over-read is an error, not a panic
        let mut r2 = ByteReader::new(&buf[..3]);
        assert!(r2.u32().is_err());
    }
}
