//! Real-socket transport backend: length-framed TCP between OS processes.
//!
//! Each process hosts a subset of the fabric's endpoints and binds one
//! listener. Outgoing connections are opened lazily per peer process and
//! re-established with bounded backoff; every accepted connection gets a
//! reader thread that decodes [`wire`] frames and routes them into the
//! destination endpoint's [`PortQueues`] — the same demux structure the
//! in-process backend delivers into, which is what makes the two
//! backends observably equivalent above the [`Transport`] surface
//! (docs/DESIGN.md §11).
//!
//! Failure policy: no socket path panics. Connect/read/write errors and
//! undecodable frames map to [`RpcError::ConnectionLost`]; a decode
//! error (bad magic, bumped wire version) kills that connection so a
//! confused peer cannot corrupt the stream, and the next send re-dials.
//!
//! Chaos hook (docs/DESIGN.md §12): an installed
//! [`FaultPlan`](crate::ft::FaultPlan) gates every cross-machine send
//! through the same [`message_verdict`](crate::ft::FaultPlan::message_verdict)
//! the emulated fabric consults — frame drops, delays, and asymmetric
//! partitions behave identically over real sockets, and the
//! connection-kill verdict additionally closes the live socket so the
//! reconnect path is exercised under injected resets. Test-only: real
//! deployments leave the plan unset.

use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::model::CostModel;
use super::transport::{
    Message, PortQueues, Transport, TransportBackend,
};
use super::wire;
use super::RpcError;
use crate::ft::{FaultPlan, MessageVerdict};

/// Static wiring for one process's view of the TCP fabric.
#[derive(Clone, Debug)]
pub struct TcpConfig {
    /// Index of this process in `addrs`.
    pub my_proc: usize,
    /// Listen address of every process, in process order.
    pub addrs: Vec<String>,
    /// `endpoint_proc[e]` = process hosting endpoint `e`.
    pub endpoint_proc: Vec<usize>,
    /// `machine_of[e]` = machine hosting endpoint `e` (for metering and
    /// rank math; endpoints need not be machines).
    pub machine_of: Vec<u32>,
    /// Dial attempts before a send fails with `ConnectionLost`.
    pub connect_retries: u32,
    /// Sleep between dial attempts (peers may still be starting up).
    pub connect_backoff: Duration,
}

impl TcpConfig {
    /// One endpoint per process on 127.0.0.1, ports `port_base..+n`.
    pub fn localhost(my_proc: usize, n_procs: usize, port_base: u16) -> Self {
        Self {
            my_proc,
            addrs: (0..n_procs)
                .map(|p| format!("127.0.0.1:{}", port_base + p as u16))
                .collect(),
            endpoint_proc: (0..n_procs).collect(),
            machine_of: (0..n_procs as u32).collect(),
            connect_retries: 40,
            connect_backoff: Duration::from_millis(250),
        }
    }

    /// Same process layout, but with `k` endpoints per process (endpoint
    /// `e` lives on process `e / k`, machine `e / k`). Used by the ring
    /// all-reduce where each process hosts its local trainers' endpoints.
    pub fn with_endpoints_per_proc(mut self, k: usize) -> Self {
        let n = self.addrs.len();
        self.endpoint_proc = (0..n * k).map(|e| e / k).collect();
        self.machine_of = (0..(n * k) as u32).map(|e| e / k as u32).collect();
        self
    }
}

struct TcpInner {
    cfg: TcpConfig,
    /// Receive demux for locally hosted endpoints (`None` = remote).
    queues: Vec<Option<Arc<PortQueues>>>,
    /// Write side of the lazily dialed per-peer-process connections.
    conns: Vec<Mutex<Option<TcpStream>>>,
    /// Clones of accepted sockets so shutdown can unblock readers.
    reader_socks: Mutex<Vec<TcpStream>>,
    running: AtomicBool,
    cost: Arc<CostModel>,
    /// Chaos schedule shared with the in-process backend (test-only).
    fault: Mutex<Option<Arc<FaultPlan>>>,
}

impl TcpInner {
    fn lost(&self, peer: u32, detail: impl Into<String>) -> RpcError {
        RpcError::ConnectionLost { peer, detail: detail.into() }
    }

    /// Dial `proc`'s listener with bounded retries — peers race through
    /// startup, so early sends wait for the far listener to appear.
    fn dial(&self, proc: usize, peer: u32) -> Result<TcpStream, RpcError> {
        let addr_s = &self.cfg.addrs[proc];
        let addr: SocketAddr = addr_s
            .parse()
            .map_err(|e| self.lost(peer, format!("bad addr {addr_s}: {e}")))?;
        let mut last = String::from("no attempt made");
        for attempt in 0..=self.cfg.connect_retries {
            if !self.running.load(Ordering::SeqCst) {
                return Err(self.lost(peer, "transport shut down"));
            }
            match TcpStream::connect_timeout(&addr, Duration::from_secs(2)) {
                Ok(s) => {
                    let _ = s.set_nodelay(true);
                    return Ok(s);
                }
                Err(e) => {
                    last = e.to_string();
                    if attempt < self.cfg.connect_retries {
                        std::thread::sleep(self.cfg.connect_backoff);
                    }
                }
            }
        }
        Err(self.lost(
            peer,
            format!(
                "connect to {addr_s} failed after {} attempts: {last}",
                self.cfg.connect_retries + 1
            ),
        ))
    }

    fn write_to_peer(
        &self,
        proc: usize,
        dst: u32,
        msg: &Message,
    ) -> Result<(), RpcError> {
        let mut guard = self.conns[proc].lock().unwrap();
        // one reconnect round: a stale connection (peer restarted, half
        // -closed socket) gets dropped and re-dialed before giving up.
        for fresh in [false, true] {
            if guard.is_none() {
                *guard = Some(self.dial(proc, dst)?);
            }
            let stream = guard.as_mut().expect("connection just established");
            match wire::write_frame(stream, dst, msg)
                .and_then(|()| stream.flush().map_err(wire::WireError::from))
            {
                Ok(()) => return Ok(()),
                Err(e) => {
                    *guard = None;
                    if fresh {
                        return Err(
                            self.lost(dst, format!("write failed: {e}"))
                        );
                    }
                }
            }
        }
        unreachable!("reconnect loop returns on second pass")
    }

    /// Close the cached connection to `proc` (chaos conn-kill): the
    /// peer's reader sees the reset and exits; the next send to `proc`
    /// re-dials — exactly the path a real connection reset exercises.
    fn kill_conn(&self, proc: usize) {
        if let Some(s) = self.conns[proc].lock().unwrap().take() {
            let _ = s.shutdown(Shutdown::Both);
        }
    }

    /// Frame pump for one accepted connection. Exits on EOF, socket
    /// error, shutdown, or the first undecodable frame (kill the
    /// connection rather than guess at stream alignment).
    fn run_reader(self: &Arc<Self>, mut stream: TcpStream) {
        while self.running.load(Ordering::SeqCst) {
            match wire::read_frame(&mut stream) {
                Ok((dst, msg)) => {
                    match self.queues.get(dst as usize) {
                        Some(Some(q)) => q.push(msg),
                        // misrouted frame: drop it, keep the connection
                        _ => {}
                    }
                }
                Err(_) => {
                    let _ = stream.shutdown(Shutdown::Both);
                    return;
                }
            }
        }
    }

    fn run_acceptor(self: Arc<Self>, listener: TcpListener) {
        while self.running.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nodelay(true);
                    if let Ok(clone) = stream.try_clone() {
                        self.reader_socks.lock().unwrap().push(clone);
                    }
                    let inner = Arc::clone(&self);
                    std::thread::spawn(move || inner.run_reader(stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => {
                    if !self.running.load(Ordering::SeqCst) {
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
    }
}

/// Backend wrapper handed to [`Transport::from_backend`].
pub struct TcpBackend {
    inner: Arc<TcpInner>,
}

impl TransportBackend for TcpBackend {
    fn send(&self, src: u32, dst: u32, msg: Message) -> Result<(), RpcError> {
        let inner = &self.inner;
        if !inner.running.load(Ordering::SeqCst) {
            return Err(inner.lost(dst, "transport shut down"));
        }
        let cfg = &inner.cfg;
        let (Some(&sp), Some(&dp)) = (
            cfg.endpoint_proc.get(src as usize),
            cfg.endpoint_proc.get(dst as usize),
        ) else {
            return Err(inner.lost(dst, "endpoint outside fabric"));
        };
        let (sm, dm) =
            (cfg.machine_of[src as usize], cfg.machine_of[dst as usize]);
        let mut kill_after = false;
        if sm != dm {
            // the same chaos verdict the emulated backend consults: a
            // dropped frame vanishes before the meter, like a frame
            // lost on the wire
            let plan = inner.fault.lock().unwrap().clone();
            if let Some(f) = plan {
                match f.message_verdict(sm, dm) {
                    MessageVerdict::Drop => return Ok(()),
                    MessageVerdict::DeliverThenKillConn => {
                        kill_after = true;
                    }
                    MessageVerdict::Deliver => {}
                }
            }
            // observability parity with the emulated backend: the meter
            // counts the same framed bytes the socket carries.
            inner.cost.on_network(sm, dm, msg.wire_bytes());
        }
        if dp == cfg.my_proc {
            match &inner.queues[dst as usize] {
                Some(q) => {
                    q.push(msg);
                    Ok(())
                }
                None => Err(inner.lost(dst, "local endpoint has no queue")),
            }
        } else {
            debug_assert_eq!(
                sp, cfg.my_proc,
                "sends originate from locally hosted endpoints"
            );
            let r = inner.write_to_peer(dp, dst, &msg);
            if kill_after && r.is_ok() {
                inner.kill_conn(dp);
            }
            r
        }
    }

    fn queues(&self, ep: u32) -> Option<Arc<PortQueues>> {
        self.inner.queues.get(ep as usize)?.as_ref().map(Arc::clone)
    }

    fn n_endpoints(&self) -> usize {
        self.inner.cfg.endpoint_proc.len()
    }

    fn machine_of(&self, ep: u32) -> u32 {
        self.inner.cfg.machine_of[ep as usize]
    }

    fn set_fault_plan(&self, plan: Arc<FaultPlan>) {
        *self.inner.fault.lock().unwrap() = Some(plan);
    }

    fn shutdown(&self) {
        let inner = &self.inner;
        if inner.running.swap(false, Ordering::SeqCst) {
            for q in inner.queues.iter().flatten() {
                q.close();
            }
            for conn in &inner.conns {
                if let Some(s) = conn.lock().unwrap().take() {
                    let _ = s.shutdown(Shutdown::Both);
                }
            }
            for s in inner.reader_socks.lock().unwrap().drain(..) {
                let _ = s.shutdown(Shutdown::Both);
            }
            // acceptor notices `running == false` on its next poll tick
        }
    }
}

impl Drop for TcpBackend {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Build a TCP-backed [`Transport`] for this process: binds the local
/// listener (with retries — the port may linger in TIME_WAIT from a
/// previous run), spawns the acceptor, and exposes exactly the same
/// `endpoint()`/`send()` surface as the in-process fabric.
pub fn tcp_transport(
    cfg: TcpConfig,
    cost: Arc<CostModel>,
) -> Result<Arc<Transport>, RpcError> {
    let n_eps = cfg.endpoint_proc.len();
    assert_eq!(cfg.machine_of.len(), n_eps, "machine_of/endpoint_proc");
    assert!(cfg.my_proc < cfg.addrs.len(), "my_proc out of range");
    let me = cfg.my_proc as u32;
    let bind_addr = cfg.addrs[cfg.my_proc].clone();
    let mut listener = None;
    let mut last = String::new();
    for attempt in 0..=cfg.connect_retries {
        match TcpListener::bind(&bind_addr) {
            Ok(l) => {
                listener = Some(l);
                break;
            }
            Err(e) => {
                last = e.to_string();
                if attempt < cfg.connect_retries {
                    std::thread::sleep(cfg.connect_backoff);
                }
            }
        }
    }
    let listener = listener.ok_or_else(|| RpcError::ConnectionLost {
        peer: me,
        detail: format!("bind {bind_addr} failed: {last}"),
    })?;
    // nonblocking accept + poll tick lets the acceptor observe shutdown
    // without a connect-to-self wakeup dance
    listener.set_nonblocking(true).map_err(|e| {
        RpcError::ConnectionLost {
            peer: me,
            detail: format!("set_nonblocking: {e}"),
        }
    })?;
    let queues = (0..n_eps)
        .map(|e| {
            (cfg.endpoint_proc[e] == cfg.my_proc)
                .then(|| Arc::new(PortQueues::new()))
        })
        .collect();
    let conns = (0..cfg.addrs.len()).map(|_| Mutex::new(None)).collect();
    let inner = Arc::new(TcpInner {
        cfg,
        queues,
        conns,
        reader_socks: Mutex::new(Vec::new()),
        running: AtomicBool::new(true),
        cost: Arc::clone(&cost),
        fault: Mutex::new(None),
    });
    let acceptor = Arc::clone(&inner);
    std::thread::spawn(move || acceptor.run_acceptor(listener));
    Ok(Transport::from_backend(Box::new(TcpBackend { inner }), cost))
}

/// Reserve `n` distinct loopback ports by binding ephemeral listeners,
/// recording their ports, then releasing them. Subject to the usual
/// rebind race, which is acceptable for tests and benches; real runs
/// pass an explicit `port_base` through the launcher config.
pub fn free_loopback_ports(n: usize) -> Result<Vec<u16>, RpcError> {
    let mut keep = Vec::with_capacity(n);
    let mut ports = Vec::with_capacity(n);
    for _ in 0..n {
        let l = TcpListener::bind("127.0.0.1:0").map_err(|e| {
            RpcError::ConnectionLost {
                peer: 0,
                detail: format!("ephemeral bind: {e}"),
            }
        })?;
        let port = l
            .local_addr()
            .map_err(|e| RpcError::ConnectionLost {
                peer: 0,
                detail: format!("local_addr: {e}"),
            })?
            .port();
        ports.push(port);
        keep.push(l);
    }
    Ok(ports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{Port, PortKind};

    fn pair(n_procs: usize) -> Vec<Arc<Transport>> {
        let ports = free_loopback_ports(n_procs).unwrap();
        (0..n_procs)
            .map(|p| {
                let mut cfg = TcpConfig::localhost(p, n_procs, 0);
                cfg.addrs = ports
                    .iter()
                    .map(|port| format!("127.0.0.1:{port}"))
                    .collect();
                cfg.connect_retries = 20;
                cfg.connect_backoff = Duration::from_millis(50);
                tcp_transport(cfg, Arc::new(CostModel::default()))
                    .unwrap()
            })
            .collect()
    }

    #[test]
    fn two_process_send_recv_both_directions() {
        let ts = pair(2);
        let e0 = ts[0].endpoint(0);
        let e1 = ts[1].endpoint(1);
        for i in 0..20u64 {
            e0.send(1, Port::KvStore, i, vec![i as u8; 64]).unwrap();
        }
        for i in 0..20u64 {
            let m = e1
                .recv_timeout(Duration::from_secs(10))
                .expect("frame arrives");
            assert_eq!((m.tag, m.from), (i, 0), "per-sender FIFO holds");
            assert_eq!(m.payload, vec![i as u8; 64]);
        }
        e1.send(0, Port::Trainer(1), 99, vec![7]).unwrap();
        let back = e0
            .recv_kind(PortKind::Trainer, Some(Duration::from_secs(10)))
            .expect("reply arrives");
        assert_eq!((back.tag, back.port), (99, Port::Trainer(1)));
        // cross-machine TCP traffic is metered identically to in-proc
        assert_eq!(
            ts[0].cost.network_bytes(),
            20 * (wire::FRAME_HEADER_BYTES as u64 + 64)
        );
    }

    #[test]
    fn local_fast_path_skips_the_socket() {
        let ports = free_loopback_ports(1).unwrap();
        let mut cfg = TcpConfig::localhost(0, 1, 0);
        cfg.addrs = vec![format!("127.0.0.1:{}", ports[0])];
        let cfg = cfg.with_endpoints_per_proc(2);
        let t =
            tcp_transport(cfg, Arc::new(CostModel::default())).unwrap();
        let a = t.endpoint(0);
        let b = t.endpoint(1);
        a.send(1, Port::Control, 5, vec![1, 2]).unwrap();
        let m = b.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(m.tag, 5);
        assert_eq!(t.cost.network_bytes(), 0, "same machine: not metered");
    }

    #[test]
    fn unreachable_peer_is_connection_lost_not_panic() {
        let ports = free_loopback_ports(2).unwrap();
        let mut cfg = TcpConfig::localhost(0, 2, 0);
        cfg.addrs = ports
            .iter()
            .map(|port| format!("127.0.0.1:{port}"))
            .collect();
        cfg.connect_retries = 1;
        cfg.connect_backoff = Duration::from_millis(10);
        // process 1 never starts: its port is free but nothing listens
        let t =
            tcp_transport(cfg, Arc::new(CostModel::default())).unwrap();
        let e0 = t.endpoint(0);
        let err = e0.send(1, Port::KvStore, 0, vec![]).unwrap_err();
        match err {
            RpcError::ConnectionLost { peer, detail } => {
                assert_eq!(peer, 1);
                assert!(detail.contains("connect"), "{detail}");
            }
            other => panic!("expected ConnectionLost, got {other:?}"),
        }
    }

    #[test]
    fn sender_may_start_before_listener() {
        let ports = free_loopback_ports(2).unwrap();
        let addrs: Vec<String> = ports
            .iter()
            .map(|port| format!("127.0.0.1:{port}"))
            .collect();
        let mut cfg0 = TcpConfig::localhost(0, 2, 0);
        cfg0.addrs = addrs.clone();
        let t0 =
            tcp_transport(cfg0, Arc::new(CostModel::default()))
                .unwrap();
        let e0 = t0.endpoint(0);
        let addrs1 = addrs.clone();
        let h = std::thread::spawn(move || {
            // the peer comes up late; the sender's dial loop must wait
            std::thread::sleep(Duration::from_millis(300));
            let mut cfg1 = TcpConfig::localhost(1, 2, 0);
            cfg1.addrs = addrs1;
            let t1 = tcp_transport(
                cfg1,
                Arc::new(CostModel::default()),
            )
            .unwrap();
            let e1 = t1.endpoint(1);
            e1.recv_timeout(Duration::from_secs(10)).map(|m| m.tag)
        });
        e0.send(1, Port::Control, 42, vec![]).unwrap();
        assert_eq!(h.join().unwrap(), Some(42));
    }

    #[test]
    fn bumped_version_frame_kills_connection_without_delivery() {
        let ts = pair(1);
        let e0 = ts[0].endpoint(0);
        let addr = {
            // rebuild the address from the transport's own config is not
            // exposed; send to self over the socket instead: dial the
            // listener directly like a confused foreign client would.
            // pair(1) bound an ephemeral port; recover it via a probe
            // frame from a raw socket is impossible without the port, so
            // construct the scenario explicitly:
            drop(e0);
            drop(ts);
            let ports = free_loopback_ports(1).unwrap();
            format!("127.0.0.1:{}", ports[0])
        };
        let mut cfg = TcpConfig::localhost(0, 1, 0);
        cfg.addrs = vec![addr.clone()];
        let t =
            tcp_transport(cfg, Arc::new(CostModel::default())).unwrap();
        let e = t.endpoint(0);
        // raw client: one frame with a bumped version, then a valid one
        // on the same connection — neither may be delivered, because the
        // reader must kill the stream at the first undecodable frame.
        let msg = Message {
            from: 9,
            port: Port::Control,
            tag: 1,
            payload: vec![],
        };
        let mut bad = wire::encode_frame(0, &msg);
        bad[4..6].copy_from_slice(&(wire::WIRE_VERSION + 1).to_le_bytes());
        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.write_all(&bad).unwrap();
        raw.write_all(&wire::encode_frame(0, &msg)).unwrap();
        raw.flush().unwrap();
        assert!(
            e.recv_timeout(Duration::from_millis(300)).is_none(),
            "nothing decoded from a version-mismatched stream"
        );
        // a fresh, well-versioned connection still works
        let mut raw2 = TcpStream::connect(&addr).unwrap();
        raw2.write_all(&wire::encode_frame(0, &msg)).unwrap();
        raw2.flush().unwrap();
        let got = e.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(got.tag, 1);
    }

    #[test]
    fn chaos_conn_kills_are_survived_transparently() {
        use crate::ft::FaultPlan;
        let ts = pair(2);
        let mut plan = FaultPlan::new();
        plan.kill_conn_every = 3; // reset the socket after every 3rd send
        let plan = Arc::new(plan);
        ts[0].set_fault_plan(plan.clone());
        let e0 = ts[0].endpoint(0);
        let e1 = ts[1].endpoint(1);
        for i in 0..10u64 {
            e0.send(1, Port::KvStore, i, vec![i as u8]).unwrap();
        }
        // every message arrives despite the injected resets: the killed
        // connection is re-dialed on the next send
        for i in 0..10u64 {
            let m = e1
                .recv_timeout(Duration::from_secs(10))
                .expect("delivered through resets");
            assert_eq!(m.tag, i, "per-sender order survives reconnects");
        }
        assert_eq!(plan.killed_conns(), 3);
        assert_eq!(plan.dropped_msgs(), 0);
    }

    #[test]
    fn chaos_drops_and_partitions_apply_over_real_sockets() {
        use crate::ft::FaultPlan;
        let ts = pair(2);
        let mut plan = FaultPlan::new();
        plan.partitions = vec![(0, 1)]; // 0→1 blocked; 1→0 flows
        let plan = Arc::new(plan);
        ts[0].set_fault_plan(plan.clone());
        ts[1].set_fault_plan(plan.clone());
        let e0 = ts[0].endpoint(0);
        let e1 = ts[1].endpoint(1);
        e0.send(1, Port::Control, 1, vec![]).unwrap();
        assert!(
            e1.recv_timeout(Duration::from_millis(200)).is_none(),
            "partitioned direction delivers nothing"
        );
        e1.send(0, Port::Control, 2, vec![]).unwrap();
        assert_eq!(
            e0.recv_timeout(Duration::from_secs(5)).map(|m| m.tag),
            Some(2),
            "reverse direction unaffected (asymmetric partition)"
        );
        assert_eq!(plan.dropped_msgs(), 1);
        // dropped frames are never metered — parity with the in-process
        // backend's loss model
        assert_eq!(ts[0].cost.network_bytes(), 0);
    }

    #[test]
    #[should_panic(expected = "endpoint not hosted by this process")]
    fn claiming_a_remote_endpoint_panics() {
        let ports = free_loopback_ports(2).unwrap();
        let mut cfg = TcpConfig::localhost(0, 2, 0);
        cfg.addrs = ports
            .iter()
            .map(|port| format!("127.0.0.1:{port}"))
            .collect();
        let t =
            tcp_transport(cfg, Arc::new(CostModel::default())).unwrap();
        let _ = t.endpoint(1);
    }

    #[test]
    fn shutdown_unblocks_recv_and_fails_send() {
        let ts = pair(2);
        let e0 = ts[0].endpoint(0);
        let t0 = Arc::clone(&ts[0]);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            t0.shutdown();
        });
        assert!(e0.recv().is_none());
        h.join().unwrap();
        assert!(matches!(
            e0.send(1, Port::Control, 0, vec![]),
            Err(RpcError::ConnectionLost { .. })
        ));
    }
}
