//! The single bounded retry/backoff policy every RPC path shares.
//!
//! PR 6 gave the in-process fault-injection layer a retry loop
//! (`FaultPlan::admit_kv`) and PR 9 gave the wire client another one
//! (`RpcClient::call`); the two had drifted into separate
//! counter/backoff implementations, so TrainReport retry totals meant
//! different things depending on the backend. Both now funnel through
//! [`with_retry`]: one loop, one policy shape, and one shared retries
//! counter (the installed `FaultPlan`'s, when there is one), so
//! `ft.retries` is comparable across the in-process fabric and TCP.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Bounded retry policy: `max_retries` re-attempts after the first, with
/// a fixed sleep between attempts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Re-attempts after the first try (so `max_retries + 1` attempts
    /// total).
    pub max_retries: u32,
    /// Sleep between attempts.
    pub backoff: Duration,
}

impl RetryPolicy {
    pub const fn new(max_retries: u32, backoff: Duration) -> Self {
        Self { max_retries, backoff }
    }

    /// The in-process default (what `FaultPlan::new` installs): retries
    /// are cheap shared-memory re-admissions, so back off only 1 ms.
    pub const fn in_process() -> Self {
        Self::new(3, Duration::from_millis(1))
    }

    /// The real-wire default (what `RpcClient::new` installs): a resend
    /// costs a round-trip, so back off longer between attempts.
    pub const fn wire() -> Self {
        Self::new(3, Duration::from_millis(50))
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self::in_process()
    }
}

/// Run `attempt` (called with the attempt index, 0-based) until it
/// succeeds or the policy's budget is spent, sleeping the backoff and
/// bumping `retries` before every re-attempt. Returns the first success
/// or the *last* error — intermediate failures are policy-internal.
pub fn with_retry<T, E>(
    policy: &RetryPolicy,
    retries: &AtomicU64,
    mut attempt: impl FnMut(u32) -> Result<T, E>,
) -> Result<T, E> {
    let mut last = attempt(0);
    let mut n = 0u32;
    while last.is_err() && n < policy.max_retries {
        n += 1;
        retries.fetch_add(1, Ordering::Relaxed);
        if !policy.backoff.is_zero() {
            std::thread::sleep(policy.backoff);
        }
        last = attempt(n);
    }
    last
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_success_makes_no_retries() {
        let c = AtomicU64::new(0);
        let r: Result<u32, ()> =
            with_retry(&RetryPolicy::new(3, Duration::ZERO), &c, |_| Ok(7));
        assert_eq!(r, Ok(7));
        assert_eq!(c.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn transient_failure_heals_and_counts_each_retry() {
        let c = AtomicU64::new(0);
        let r: Result<u32, &str> = with_retry(
            &RetryPolicy::new(3, Duration::ZERO),
            &c,
            |attempt| if attempt < 2 { Err("down") } else { Ok(attempt) },
        );
        assert_eq!(r, Ok(2));
        assert_eq!(c.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn exhausted_budget_returns_the_last_error() {
        let c = AtomicU64::new(0);
        let r: Result<(), u32> = with_retry(
            &RetryPolicy::new(2, Duration::ZERO),
            &c,
            |attempt| Err(attempt),
        );
        assert_eq!(r, Err(2), "last attempt's error surfaces");
        assert_eq!(c.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn zero_retries_means_exactly_one_attempt() {
        let c = AtomicU64::new(0);
        let mut calls = 0;
        let r: Result<(), ()> =
            with_retry(&RetryPolicy::new(0, Duration::ZERO), &c, |_| {
                calls += 1;
                Err(())
            });
        assert!(r.is_err());
        assert_eq!(calls, 1);
        assert_eq!(c.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn defaults_match_the_two_historical_call_sites() {
        assert_eq!(
            RetryPolicy::in_process(),
            RetryPolicy::new(3, Duration::from_millis(1))
        );
        assert_eq!(
            RetryPolicy::wire(),
            RetryPolicy::new(3, Duration::from_millis(50))
        );
        assert_eq!(RetryPolicy::default(), RetryPolicy::in_process());
    }
}
