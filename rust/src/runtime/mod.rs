//! PJRT runtime: load the AOT'd HLO artifacts (`artifacts/*.hlo.txt`,
//! lowered once by `python/compile/aot.py`) and execute them from the
//! training hot path. Python never runs here.
//!
//! - [`manifest`] parses `artifacts/manifest.json` (shapes, input order,
//!   param layout) — the contract between L2 and L3.
//! - [`executable`] wraps a compiled train/eval pair with typed input
//!   packing, on-host parameter state, and PCIe byte metering.
//! - [`cost`] models device time (T4 GPU / Xeon CPU rooflines) so benches
//!   can report the paper's GPU-vs-CPU comparisons from this CPU testbed.

pub mod cost;
pub mod executable;
pub mod manifest;

pub use cost::DeviceCostModel;
pub use executable::{ModelExecutable, RuntimeEnv};
pub use manifest::{Manifest, TensorSpec, VariantSpec};
