//! Compiled model variants: HLO text → PJRT executable → typed step calls.
//!
//! Mirrors /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile`. One [`ModelExecutable`]
//! holds the train/eval pair for a variant plus the (host-side) parameter
//! state; `train_step` packs a [`HostBatch`] into literals following the
//! manifest's input order, executes, and swaps in the updated parameters
//! returned by the fused-SGD HLO.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::net::CostModel;
use crate::sampler::compact::TaskKind;

use super::manifest::{Manifest, VariantSpec};

/// A fully materialized mini-batch on the host, ready for device transfer
/// (the output of the pipeline's compact stage).
///
/// In DGL terms a mini-batch is the `(input_nodes, output_nodes, blocks)`
/// triple a `DistNodeDataLoader` yields; [`HostBatch::unpack`] exposes
/// exactly that view (`targets` are the output/seed nodes, `layers` the
/// blocks), with the features/labels already pulled alongside.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HostBatch {
    /// Padded input features, `n0 * feat_dim`.
    pub feats: Vec<f32>,
    /// Per-layer index arrays (layer 1 first), from `compact::to_block`.
    pub layers: Vec<crate::sampler::compact::LayerBlock>,
    /// Node classification: labels + mask, length `nL`.
    pub labels: Vec<i32>,
    pub label_mask: Vec<f32>,
    /// Link prediction: pair mask, length `batch`.
    pub pair_mask: Vec<f32>,
    /// Real target globals (for accuracy computation on eval).
    pub targets: Vec<crate::graph::NodeId>,
    /// Real (un-padded) input-frontier globals in layer-0 slot order —
    /// DGL's `input_nodes`. Host-side (maps layer-0 rows, e.g. inference
    /// embeddings, back to global ids); not part of the device payload.
    pub input_nodes: Vec<crate::graph::NodeId>,
    /// Observability: remote feature rows + dropped neighbors.
    pub remote_rows: usize,
    pub dropped_neighbors: usize,
}

impl HostBatch {
    /// The DGL mini-batch triple: `(input_nodes, seeds, blocks)`.
    pub fn unpack(
        &self,
    ) -> (
        &[crate::graph::NodeId],
        &[crate::graph::NodeId],
        &[crate::sampler::compact::LayerBlock],
    ) {
        (&self.input_nodes, &self.targets, &self.layers)
    }

    /// The seed (output) nodes of this mini-batch — DGL's `output_nodes`.
    pub fn seeds(&self) -> &[crate::graph::NodeId] {
        &self.targets
    }

    /// The per-layer message-flow blocks, input side first.
    pub fn blocks(&self) -> &[crate::sampler::compact::LayerBlock] {
        &self.layers
    }

    /// Host→device payload size (what the GPU prefetcher moves, §5.5.2).
    /// The relation-segmented `seg_*` arrays and the `input_nodes` /
    /// `targets` id lists are host-side observability and are not
    /// shipped — the dense `rel` array is what the RGCN HLO consumes.
    pub fn h2d_bytes(&self) -> u64 {
        let mut b = self.feats.len() * 4
            + self.labels.len() * 4
            + self.label_mask.len() * 4
            + self.pair_mask.len() * 4;
        for l in &self.layers {
            b += l.self_idx.len() * 4
                + l.nbr_idx.len() * 4
                + l.nbr_mask.len() * 4
                + l.rel.len() * 4;
        }
        b as u64
    }
}

/// Shared PJRT client + manifest.
pub struct RuntimeEnv {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
}

impl RuntimeEnv {
    pub fn new(artifacts: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, manifest })
    }

    /// Compile a variant's train+eval executables and load initial params.
    pub fn load(&self, variant: &str) -> Result<ModelExecutable> {
        let spec = self.manifest.variant(variant)?.clone();
        let train_exe = self.compile_hlo(&spec.train_hlo)?;
        let eval_exe = self.compile_hlo(&spec.eval_hlo)?;
        let params = self.manifest.load_params(&spec)?;
        Ok(ModelExecutable {
            spec,
            train_exe,
            eval_exe,
            params,
            pcie: None,
            steps: 0,
        })
    }

    fn compile_hlo(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.manifest.dir.join(file);
        let path_str = path
            .to_str()
            .with_context(|| format!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))
    }
}

pub struct ModelExecutable {
    pub spec: VariantSpec,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
    /// Host-side dense parameter state (flat f32 per tensor).
    pub params: Vec<Vec<f32>>,
    /// When set, h2d/d2h transfers are metered as PCIe traffic.
    pub pcie: Option<Arc<CostModel>>,
    pub steps: u64,
}

fn f32_literal(data: &[f32], shape: &[usize]) -> xla::Literal {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )
    .expect("f32 literal")
}

fn i32_literal(data: &[i32], shape: &[usize]) -> xla::Literal {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        shape,
        bytes,
    )
    .expect("i32 literal")
}

impl ModelExecutable {
    /// Pack the non-param inputs in manifest order.
    fn pack_inputs(
        &self,
        batch: &HostBatch,
        lr: Option<f32>,
    ) -> Result<Vec<xla::Literal>> {
        let spec = &self.spec;
        let specs = if lr.is_some() {
            &spec.train_inputs
        } else {
            &spec.eval_inputs
        };
        let mut out = Vec::with_capacity(specs.len());
        for ts in specs {
            let lit = match ts.name.as_str() {
                "feats" => {
                    if batch.feats.len() != ts.elements() {
                        bail!(
                            "feats len {} != expected {}",
                            batch.feats.len(),
                            ts.elements()
                        );
                    }
                    f32_literal(&batch.feats, &ts.shape)
                }
                "labels" => i32_literal(&batch.labels, &ts.shape),
                "label_mask" => f32_literal(&batch.label_mask, &ts.shape),
                "pair_mask" => f32_literal(&batch.pair_mask, &ts.shape),
                "lr" => {
                    xla::Literal::scalar(lr.expect("lr for train input"))
                }
                name => {
                    // per-layer arrays: {self_idx,nbr_idx,nbr_mask,rel}_<l>
                    let (kind, l) = name
                        .rsplit_once('_')
                        .with_context(|| format!("bad input {name}"))?;
                    let l: usize = l.parse()?;
                    let lb = batch
                        .layers
                        .get(l - 1)
                        .with_context(|| format!("missing layer {l}"))?;
                    match kind {
                        "self_idx" => i32_literal(&lb.self_idx, &ts.shape),
                        "nbr_idx" => i32_literal(&lb.nbr_idx, &ts.shape),
                        "nbr_mask" => f32_literal(&lb.nbr_mask, &ts.shape),
                        "rel" => i32_literal(&lb.rel, &ts.shape),
                        _ => bail!("unknown input tensor {name}"),
                    }
                }
            };
            out.push(lit);
        }
        Ok(out)
    }

    /// One synchronous training step: returns the mini-batch loss. The
    /// fused-SGD HLO returns updated params, which replace `self.params`.
    pub fn train_step(&mut self, batch: &HostBatch, lr: f32) -> Result<f32> {
        let mut params = std::mem::take(&mut self.params);
        let r = self.train_step_with(&mut params, batch, lr);
        self.params = params;
        self.steps += 1;
        r
    }

    /// Stateless variant: update caller-owned parameters (used by the
    /// device executor to serve several trainer replicas with one
    /// compiled executable).
    pub fn train_step_with(
        &self,
        params: &mut [Vec<f32>],
        batch: &HostBatch,
        lr: f32,
    ) -> Result<f32> {
        if let Some(c) = &self.pcie {
            c.on_pcie(batch.h2d_bytes());
        }
        let mut args: Vec<xla::Literal> = params
            .iter()
            .zip(&self.spec.param_shapes)
            .map(|(p, s)| f32_literal(p, s))
            .collect();
        args.extend(self.pack_inputs(batch, Some(lr))?);
        let result = self
            .train_exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("train execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        let mut parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        if parts.len() != self.spec.n_params() + 1 {
            bail!(
                "expected {} outputs, got {}",
                self.spec.n_params() + 1,
                parts.len()
            );
        }
        let loss_lit = parts.pop().unwrap();
        let loss = loss_lit
            .get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("loss read: {e:?}"))?;
        for (slot, lit) in params.iter_mut().zip(parts) {
            lit.copy_raw_to::<f32>(slot)
                .map_err(|e| anyhow::anyhow!("param readback: {e:?}"))?;
        }
        Ok(loss)
    }

    /// Forward-only pass: returns logits (nc, `nL * classes`) or embeddings
    /// (lp, `nL * hidden`).
    pub fn eval_step(&self, batch: &HostBatch) -> Result<Vec<f32>> {
        self.eval_step_with(&self.params, batch)
    }

    /// Stateless eval with caller-owned parameters.
    pub fn eval_step_with(
        &self,
        params: &[Vec<f32>],
        batch: &HostBatch,
    ) -> Result<Vec<f32>> {
        if let Some(c) = &self.pcie {
            c.on_pcie(batch.h2d_bytes());
        }
        let mut args: Vec<xla::Literal> = params
            .iter()
            .zip(&self.spec.param_shapes)
            .map(|(p, s)| f32_literal(p, s))
            .collect();
        args.extend(self.pack_inputs(batch, None)?);
        let result = self
            .eval_exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("eval execute: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch: {e:?}"))?
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        let v = out
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("readback: {e:?}"))?;
        if let Some(c) = &self.pcie {
            c.on_pcie(v.len() as u64 * 4);
        }
        Ok(v)
    }

    /// Accuracy over the real target rows of an eval batch (nc task).
    pub fn accuracy(
        &self,
        logits: &[f32],
        labels: &[i32],
        n_real: usize,
    ) -> f64 {
        assert_eq!(self.spec.task, TaskKind::NodeClassification);
        let c = self.spec.num_classes;
        let mut correct = 0usize;
        for i in 0..n_real {
            let row = &logits[i * c..(i + 1) * c];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j as i32)
                .unwrap_or(-1);
            if argmax == labels[i] {
                correct += 1;
            }
        }
        correct as f64 / n_real.max(1) as f64
    }

    /// Replace parameter state (e.g. after all-reduce averaging).
    pub fn set_params(&mut self, params: Vec<Vec<f32>>) {
        assert_eq!(params.len(), self.params.len());
        self.params = params;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::gen::tests_support::sampled_batch;
    use crate::runtime::manifest::artifacts_dir;

    fn make_batch(spec: &VariantSpec, seed: u64) -> HostBatch {
        // real sampled block structure; rels are the sampled ones
        sampled_batch(spec, seed)
    }

    fn env() -> Option<RuntimeEnv> {
        RuntimeEnv::new(&artifacts_dir()).ok()
    }

    #[test]
    fn sage_train_step_decreases_loss() {
        let Some(env) = env() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut exe = env.load("sage_nc_dev").unwrap();
        let batch = make_batch(&exe.spec, 1);
        let mut losses = Vec::new();
        for _ in 0..6 {
            losses.push(exe.train_step(&batch, 0.5).unwrap());
        }
        assert!(
            losses.last().unwrap() < &losses[0],
            "loss did not decrease: {losses:?}"
        );
        assert!(losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn eval_returns_logit_matrix() {
        let Some(env) = env() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let exe = env.load("sage_nc_dev").unwrap();
        let batch = make_batch(&exe.spec, 2);
        let logits = exe.eval_step(&batch).unwrap();
        assert_eq!(
            logits.len(),
            exe.spec.layer_nodes.last().unwrap() * exe.spec.num_classes
        );
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn train_step_is_deterministic() {
        let Some(env) = env() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut a = env.load("sage_nc_dev").unwrap();
        let mut b = env.load("sage_nc_dev").unwrap();
        let batch = make_batch(&a.spec, 3);
        let la = a.train_step(&batch, 0.1).unwrap();
        let lb = b.train_step(&batch, 0.1).unwrap();
        assert_eq!(la, lb);
        assert_eq!(a.params, b.params);
    }

    #[test]
    fn pcie_metering_counts_batch_bytes() {
        let Some(env) = env() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut exe = env.load("sage_nc_dev").unwrap();
        let cost = Arc::new(CostModel::default());
        exe.pcie = Some(cost.clone());
        let batch = make_batch(&exe.spec, 4);
        exe.train_step(&batch, 0.1).unwrap();
        assert_eq!(cost.pcie_bytes_total(), batch.h2d_bytes());
    }
}
