//! artifacts/manifest.json — the L2↔L3 contract. Produced by
//! `python/compile/aot.py`; describes every lowered variant: static shapes,
//! flat input order, parameter layout, and artifact file names.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::sampler::compact::{ModelKind, ShapeSpec, TaskKind};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct VariantSpec {
    pub name: String,
    pub model: ModelKind,
    pub task: TaskKind,
    pub batch: usize,
    pub fanouts: Vec<usize>,
    pub layer_nodes: Vec<usize>,
    pub feat_dim: usize,
    pub num_classes: usize,
    pub num_heads: usize,
    pub num_rels: usize,
    pub param_shapes: Vec<Vec<usize>>,
    pub train_inputs: Vec<TensorSpec>,
    pub eval_inputs: Vec<TensorSpec>,
    pub train_hlo: String,
    pub eval_hlo: String,
    pub params_bin: String,
}

impl VariantSpec {
    pub fn shape_spec(&self) -> ShapeSpec {
        ShapeSpec {
            name: self.name.clone(),
            model: self.model,
            task: self.task,
            batch: self.batch,
            fanouts: self.fanouts.clone(),
            layer_nodes: self.layer_nodes.clone(),
            feat_dim: self.feat_dim,
            num_classes: self.num_classes,
            num_rels: self.num_rels,
        }
    }

    pub fn n_params(&self) -> usize {
        self.param_shapes.len()
    }

    pub fn param_elements(&self) -> usize {
        self.param_shapes
            .iter()
            .map(|s| s.iter().product::<usize>().max(1))
            .sum()
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub block: usize,
    pub variants: BTreeMap<String, VariantSpec>,
}

fn tensor_list(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t.get("name")?.as_str()?.to_string(),
                shape: t.get("shape")?.usize_arr()?,
                dtype: t.get("dtype")?.as_str()?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {path:?} — run `make artifacts` first")
        })?;
        let j = Json::parse(&text)?;
        let block = j.get("block")?.as_usize()?;
        let mut variants = BTreeMap::new();
        for (name, v) in j.get("variants")?.as_obj()? {
            let model = match v.get("model")?.as_str()? {
                "sage" => ModelKind::Sage,
                "gat" => ModelKind::Gat,
                "rgcn" => ModelKind::Rgcn,
                m => bail!("unknown model kind {m:?}"),
            };
            let task = match v.get("task")?.as_str()? {
                "nc" => TaskKind::NodeClassification,
                "lp" => TaskKind::LinkPrediction,
                t => bail!("unknown task {t:?}"),
            };
            let spec = VariantSpec {
                name: name.clone(),
                model,
                task,
                batch: v.get("batch")?.as_usize()?,
                fanouts: v.get("fanouts")?.usize_arr()?,
                layer_nodes: v.get("layer_nodes")?.usize_arr()?,
                feat_dim: v.get("feat_dim")?.as_usize()?,
                num_classes: v.get("num_classes")?.as_usize()?,
                num_heads: v.get("num_heads")?.as_usize()?,
                num_rels: v.get("num_rels")?.as_usize()?,
                param_shapes: v
                    .get("param_shapes")?
                    .as_arr()?
                    .iter()
                    .map(|s| s.usize_arr())
                    .collect::<Result<_>>()?,
                train_inputs: tensor_list(v.get("train_inputs")?)?,
                eval_inputs: tensor_list(v.get("eval_inputs")?)?,
                train_hlo: v.get("train_hlo")?.as_str()?.to_string(),
                eval_hlo: v.get("eval_hlo")?.as_str()?.to_string(),
                params_bin: v.get("params_bin")?.as_str()?.to_string(),
            };
            variants.insert(name.clone(), spec);
        }
        Ok(Manifest { dir: dir.to_path_buf(), block, variants })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.variants.get(name).with_context(|| {
            format!(
                "variant {name:?} not in manifest (have: {:?}) — \
                 run `make artifacts` / `make artifacts-extra`",
                self.variants.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Load the initial parameters for a variant (flat little-endian f32).
    pub fn load_params(&self, spec: &VariantSpec) -> Result<Vec<Vec<f32>>> {
        let path = self.dir.join(&spec.params_bin);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let total: usize = spec
            .param_shapes
            .iter()
            .map(|s| s.iter().product::<usize>().max(1))
            .sum();
        if floats.len() != total {
            bail!(
                "params.bin has {} floats, manifest expects {total}",
                floats.len()
            );
        }
        let mut out = Vec::with_capacity(spec.param_shapes.len());
        let mut off = 0usize;
        for s in &spec.param_shapes {
            let n: usize = s.iter().product::<usize>().max(1);
            out.push(floats[off..off + n].to_vec());
            off += n;
        }
        Ok(out)
    }
}

/// Default artifacts directory: `$DISTDGLV2_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("DISTDGLV2_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<Manifest> {
        let dir = artifacts_dir();
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = artifacts() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        assert_eq!(m.block, 128);
        let v = m.variant("sage_nc_dev").unwrap();
        assert_eq!(v.model, ModelKind::Sage);
        assert_eq!(v.fanouts, vec![5, 5]);
        assert_eq!(v.layer_nodes.len(), 3);
        // input order: feats, (self, nbr, mask) x layers, labels, mask, lr
        assert_eq!(v.train_inputs[0].name, "feats");
        assert_eq!(v.train_inputs.last().unwrap().name, "lr");
        // eval = structural prefix (no labels/label_mask/lr)
        assert_eq!(v.eval_inputs.len(), v.train_inputs.len() - 3);
        for (e, t) in v.eval_inputs.iter().zip(&v.train_inputs) {
            assert_eq!(e.name, t.name);
        }
    }

    #[test]
    fn params_roundtrip_shapes() {
        let Some(m) = artifacts() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let v = m.variant("sage_nc_dev").unwrap();
        let params = m.load_params(v).unwrap();
        assert_eq!(params.len(), v.param_shapes.len());
        for (p, s) in params.iter().zip(&v.param_shapes) {
            assert_eq!(p.len(), s.iter().product::<usize>().max(1));
        }
    }

    #[test]
    fn missing_variant_is_helpful_error() {
        let Some(m) = artifacts() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let err = m.variant("nonexistent").unwrap_err().to_string();
        assert!(err.contains("make artifacts"));
    }
}
