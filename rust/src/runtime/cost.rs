//! Device cost model: estimate per-step FLOPs and memory traffic from a
//! variant's static shapes, then model step time on the paper's devices
//! (NVIDIA T4) and on a CPU socket. Benches use this to reproduce the
//! paper's GPU-vs-CPU comparisons (Fig 10/11) from a CPU-only testbed:
//! the *measured* CPU wall-clock anchors the pipeline, and the modeled
//! device ratio scales mini-batch compute (DESIGN.md §2).

use crate::sampler::compact::ModelKind;

use super::manifest::VariantSpec;

/// A compute device's roofline parameters.
#[derive(Clone, Copy, Debug)]
pub struct DeviceCostModel {
    pub name: &'static str,
    /// Sustained f32 FLOP/s for dense ops.
    pub flops: f64,
    /// Sustained memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Fixed per-kernel-launch / per-step overhead, seconds.
    pub step_overhead: f64,
}

impl DeviceCostModel {
    /// NVIDIA T4 (paper's g4dn trainer GPU): 8.1 TFLOPs f32, 300 GB/s.
    pub fn t4() -> Self {
        Self {
            name: "T4",
            flops: 8.1e12 * 0.35, // sustained fraction for GNN workloads
            mem_bw: 300e9 * 0.6,
            step_overhead: 150e-6,
        }
    }

    /// One socket of the paper's r5dn CPU nodes (≈24 cores Skylake).
    pub fn xeon() -> Self {
        Self {
            name: "Xeon",
            flops: 1.5e12 * 0.25,
            mem_bw: 100e9 * 0.5,
            step_overhead: 30e-6,
        }
    }

    /// This testbed: a single CPU core driving XLA-CPU.
    pub fn local_core() -> Self {
        Self {
            name: "local",
            flops: 5e10,
            mem_bw: 2e10,
            step_overhead: 20e-6,
        }
    }

    /// Roofline step time for a variant (train = fwd + bwd ≈ 3x fwd work).
    pub fn step_secs(&self, spec: &VariantSpec, train: bool) -> f64 {
        let (flops, bytes) = step_cost(spec);
        let mult = if train { 3.0 } else { 1.0 };
        let t = (flops * mult / self.flops).max(bytes * mult / self.mem_bw);
        t + self.step_overhead
    }
}

/// (FLOPs, bytes) of one forward pass at a variant's padded shapes.
pub fn step_cost(spec: &VariantSpec) -> (f64, f64) {
    let n = &spec.layer_nodes;
    let mut flops = 0f64;
    let mut bytes = 0f64;
    let l_total = spec.fanouts.len();
    for l in 1..=l_total {
        let nl = n[l] as f64;
        let k = spec.fanouts[l - 1] as f64;
        let f_in = if l == 1 {
            spec.feat_dim as f64
        } else {
            spec.hidden_dim() as f64
        };
        let f_out = if l == l_total {
            spec.out_dim() as f64
        } else {
            spec.hidden_dim() as f64
        };
        // aggregation: gather + mean over K neighbors
        let agg_flops = nl * k * f_in * 2.0;
        let agg_bytes = nl * k * f_in * 4.0; // gathered rows (read)
        match spec.model {
            ModelKind::Sage => {
                flops += agg_flops + 2.0 * nl * f_in * f_out * 2.0;
                bytes += agg_bytes + 2.0 * f_in * f_out * 4.0 + nl * f_out * 4.0;
            }
            ModelKind::Gat => {
                // per-head projection of every src node + edge-softmax
                // (logits, max, exp, weighted sum per edge per head) +
                // head-merge output projection
                let h = spec.num_heads.max(1) as f64;
                let n_src = n[l - 1] as f64;
                flops += n_src * f_in * f_out * 2.0      // src projection
                    + nl * k * f_out * 6.0 * h.sqrt()    // edge softmax ops
                    + nl * f_out * f_out * 2.0           // head merge
                    + agg_flops;
                bytes += agg_bytes
                    + n_src * f_out * 4.0
                    + 2.0 * f_in * f_out * 4.0
                    + nl * f_out * 4.0;
            }
            ModelKind::Rgcn => {
                let r = spec.num_rels as f64;
                flops += agg_flops * r.min(2.0)
                    + nl * r * f_in * f_out * 2.0
                    + nl * f_in * f_out * 2.0;
                bytes += agg_bytes
                    + r * f_in * f_out * 4.0
                    + nl * f_out * 4.0;
            }
        }
    }
    // input feature read
    bytes += (n[0] * spec.feat_dim) as f64 * 4.0;
    (flops, bytes)
}

impl VariantSpec {
    /// Hidden width used by interior layers.
    pub fn hidden_dim(&self) -> usize {
        // param_shapes[0] is [f_in, f_out(hidden)] for sage/gat/rgcn-self
        self.param_shapes
            .first()
            .and_then(|s| s.last())
            .copied()
            .unwrap_or(self.feat_dim)
    }

    pub fn out_dim(&self) -> usize {
        if self.num_classes > 0 {
            self.num_classes
        } else {
            self.hidden_dim()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::compact::TaskKind;

    /// Paper-scale shapes (batch 1000, fanout 15/10/5, hidden 256): at
    /// this size compute dominates launch overhead, which is where the
    /// paper's GPU-vs-CPU comparison happens.
    fn spec(model: ModelKind) -> VariantSpec {
        VariantSpec {
            name: "x".into(),
            model,
            task: TaskKind::NodeClassification,
            batch: 1000,
            fanouts: vec![15, 10, 5],
            layer_nodes: vec![1081344, 67584, 6144, 1024],
            feat_dim: 100,
            num_classes: 47,
            num_heads: 2,
            num_rels: 3,
            param_shapes: vec![vec![100, 256], vec![100, 256], vec![256]],
            train_inputs: vec![],
            eval_inputs: vec![],
            train_hlo: String::new(),
            eval_hlo: String::new(),
            params_bin: String::new(),
        }
    }

    #[test]
    fn gpu_is_faster_than_cpu_and_train_costs_more() {
        let s = spec(ModelKind::Sage);
        let t4 = DeviceCostModel::t4();
        let cpu = DeviceCostModel::xeon();
        assert!(t4.step_secs(&s, true) < cpu.step_secs(&s, true));
        assert!(t4.step_secs(&s, true) > t4.step_secs(&s, false));
    }

    #[test]
    fn complex_models_cost_more() {
        let sage = spec(ModelKind::Sage);
        let gat = spec(ModelKind::Gat);
        let rgcn = spec(ModelKind::Rgcn);
        let (fs, _) = step_cost(&sage);
        let (fg, _) = step_cost(&gat);
        let (fr, _) = step_cost(&rgcn);
        assert!(fg > fs * 0.5, "gat {fg} vs sage {fs}");
        assert!(fr > fs, "rgcn {fr} vs sage {fs}");
    }

    #[test]
    fn gpu_speedup_grows_with_compute_density() {
        // paper: "the more complex the model, the higher the GPU speedup"
        let t4 = DeviceCostModel::t4();
        let cpu = DeviceCostModel::xeon();
        let sage = spec(ModelKind::Sage);
        let rgcn = spec(ModelKind::Rgcn);
        let sp_sage = cpu.step_secs(&sage, true) / t4.step_secs(&sage, true);
        let sp_rgcn = cpu.step_secs(&rgcn, true) / t4.step_secs(&rgcn, true);
        assert!(sp_rgcn >= sp_sage * 0.9, "{sp_sage} vs {sp_rgcn}");
    }
}
