//! Coordination layer (paper §L3): the `Coordinator` owns the elastic
//! membership view of the trainer fleet — rank assignment, the
//! epoch-boundary barrier, heartbeat-based health, straggler demotion,
//! and planned grow/shrink events — and publishes a new *membership
//! epoch* whenever the trainer set changes (docs/DESIGN.md §9).
//!
//! The design is deliberately decision-at-the-barrier: health signals
//! (heartbeats, failure reports, step timings) accumulate freely during
//! an epoch, but the membership only ever changes at the epoch-boundary
//! barrier where all surviving ranks rendezvous. That makes every
//! reconfiguration a clean cut: parameters are synchronized (the
//! all-reduce ran), pipelines can drain, rank 0 can checkpoint, and the
//! new view is a pure function of (old view, who is dead/slow, the
//! planned schedule) — never of arrival order.

pub mod rendezvous;

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

/// One immutable membership epoch: which machines participate and how
/// many trainer ranks each hosts. Ranks are machine-major —
/// rank `r` lives on `machines[r / per_machine]` — so the mapping is a
/// pure function of the view and never of join order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MembershipView {
    /// Monotonic membership epoch (bumped by every reconfiguration).
    pub epoch: u64,
    /// Participating machine ids, ascending.
    pub machines: Vec<u32>,
    /// Trainer ranks hosted per machine (uniform grid).
    pub per_machine: usize,
}

impl MembershipView {
    /// The full grid every run starts from: machines `0..n_machines`,
    /// each hosting `per_machine` ranks.
    pub fn initial(n_machines: usize, per_machine: usize) -> Self {
        Self {
            epoch: 0,
            machines: (0..n_machines as u32).collect(),
            per_machine: per_machine.max(1),
        }
    }

    pub fn world_size(&self) -> usize {
        self.machines.len() * self.per_machine
    }

    /// Machine hosting rank `r` (machine-major grid).
    pub fn machine_of(&self, rank: usize) -> u32 {
        self.machines[rank / self.per_machine]
    }

    /// Per-rank machine vector, as `AllReduceGroup::new` expects.
    pub fn machine_vec(&self) -> Vec<u32> {
        (0..self.world_size()).map(|r| self.machine_of(r)).collect()
    }

    /// The ranks hosted on `machine` under this view (empty when the
    /// machine is not a member) — what a rendezvous'd process trains.
    pub fn ranks_on(&self, machine: u32) -> Vec<usize> {
        (0..self.world_size())
            .filter(|&r| self.machine_of(r) == machine)
            .collect()
    }
}

/// A planned elastic resize: at cumulative epoch-boundary `boundary`,
/// change the world size to `world` trainers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResizeEvent {
    pub boundary: u64,
    pub world: usize,
}

/// Parse the config `elastic=E:W[,E:W...]` schedule (at the E-th epoch
/// boundary, resize to W trainers). Events are sorted by boundary;
/// duplicate boundaries are rejected.
pub fn parse_elastic_schedule(s: &str) -> Result<Vec<ResizeEvent>> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (b, w) = part.split_once(':').ok_or_else(|| {
            anyhow!("elastic event '{part}' is not of the form E:W")
        })?;
        let boundary: u64 = b.trim().parse().map_err(|_| {
            anyhow!("bad elastic boundary '{b}' (want a positive int)")
        })?;
        let world: usize = w.trim().parse().map_err(|_| {
            anyhow!("bad elastic world '{w}' (want a positive int)")
        })?;
        ensure!(boundary > 0, "elastic boundary must be >= 1 in '{part}'");
        ensure!(world > 0, "elastic world must be >= 1 in '{part}'");
        out.push(ResizeEvent { boundary, world });
    }
    out.sort_by_key(|e| e.boundary);
    for w in out.windows(2) {
        ensure!(
            w[0].boundary != w[1].boundary,
            "duplicate elastic boundary {}",
            w[0].boundary
        );
    }
    Ok(out)
}

/// Coordinator policy knobs (TrainConfig carries the same fields).
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// A rank that neither arrives at the barrier nor heartbeats for
    /// this long is declared dead and its machine demoted. Must exceed
    /// the slowest expected step.
    pub heartbeat_timeout: Duration,
    /// A machine is a straggler when its mean step time exceeds
    /// `straggler_factor ×` the fleet's (lower-)median machine.
    pub straggler_factor: f64,
    /// Consecutive straggling boundaries before demotion.
    pub straggler_patience: usize,
    /// Master switch for timing-based demotion (failure-based removal
    /// is always on).
    pub demote_stragglers: bool,
    /// Planned resize schedule, sorted by boundary.
    pub planned: Vec<ResizeEvent>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            heartbeat_timeout: Duration::from_secs(5),
            straggler_factor: 3.0,
            straggler_patience: 2,
            demote_stragglers: false,
            planned: Vec::new(),
        }
    }
}

/// What the barrier tells every arriving rank to do next.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Membership unchanged — run the next epoch as-is.
    Continue,
    /// Membership changed: drain, checkpoint (rank 0), re-split, and
    /// rebuild loaders + all-reduce group for this new view.
    Reconfigure(MembershipView),
}

#[derive(Clone, Copy, Debug, Default)]
struct Beat {
    last: Option<Instant>,
    secs: f64,
    n: u64,
}

struct CoState {
    view: MembershipView,
    /// Cumulative epoch boundaries decided (drives `planned` events).
    boundaries: u64,
    /// Barrier generation (one per completed boundary).
    generation: u64,
    gen_started: Instant,
    arrived: BTreeSet<usize>,
    decision: Decision,
    beats: BTreeMap<usize, Beat>,
    failed: BTreeSet<usize>,
    /// Consecutive straggling boundaries, per machine.
    strikes: BTreeMap<u32, u32>,
    demotions: u64,
    shutdown: bool,
}

/// Membership owner + epoch-boundary barrier. One per elastic run,
/// shared (`Arc`) by every trainer thread across all membership epochs.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    state: Mutex<CoState>,
    cv: Condvar,
}

impl Coordinator {
    pub fn new(view: MembershipView, cfg: CoordinatorConfig) -> Arc<Self> {
        Arc::new(Self {
            cfg,
            state: Mutex::new(CoState {
                view,
                boundaries: 0,
                generation: 0,
                gen_started: Instant::now(),
                arrived: BTreeSet::new(),
                decision: Decision::Continue,
                beats: BTreeMap::new(),
                failed: BTreeSet::new(),
                strikes: BTreeMap::new(),
                demotions: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
        })
    }

    /// Current membership view (the next round's, once a
    /// `Reconfigure` decision has been published).
    pub fn view(&self) -> MembershipView {
        self.state.lock().unwrap().view.clone()
    }

    /// Cumulative epoch boundaries decided so far.
    pub fn boundaries(&self) -> u64 {
        self.state.lock().unwrap().boundaries
    }

    /// Machines removed from the membership so far (dead + straggler).
    pub fn demotions(&self) -> u64 {
        self.state.lock().unwrap().demotions
    }

    /// Record one finished step for `rank` (`step_secs` wall time).
    /// Doubles as the liveness signal for `heartbeat_timeout`.
    pub fn heartbeat(&self, rank: usize, step_secs: f64) {
        let mut st = self.state.lock().unwrap();
        let b = st.beats.entry(rank).or_default();
        b.last = Some(Instant::now());
        b.secs += step_secs;
        b.n += 1;
    }

    /// Report `rank` unrecoverably failed (e.g. its feature server is
    /// gone). The rank keeps joining the barrier as a zombie so the
    /// ring all-reduce never deadlocks; its machine is demoted at the
    /// next boundary.
    pub fn report_failure(&self, rank: usize) {
        let mut st = self.state.lock().unwrap();
        st.failed.insert(rank);
        drop(st);
        self.cv.notify_all();
    }

    /// Release every current and future barrier waiter with
    /// `Continue` (clean end-of-run; no decision is ever made again).
    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }

    /// Barrier generation (bumped once per completed boundary). Lets a
    /// non-blocking driver detect "someone else completed my round".
    pub fn generation(&self) -> u64 {
        self.state.lock().unwrap().generation
    }

    /// Non-blocking barrier arrival: record that `rank` reached the
    /// epoch boundary and, if that completes the round (every rank of
    /// the view arrived or is dead), decide and publish. Returns the
    /// decision when this call completed the round, else `None` — the
    /// message-driven rendezvous server replies to all pending arrivals
    /// the moment one of these returns `Some`.
    pub fn arrive(&self, rank: usize) -> Option<Decision> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Some(Decision::Continue);
        }
        st.arrived.insert(rank);
        self.cv.notify_all();
        self.complete_round(&mut st)
    }

    /// Non-blocking health sweep: reap silent ranks and complete the
    /// in-progress round if the survivors have all arrived. `None` when
    /// no round is in progress or arrivals are still outstanding. The
    /// rendezvous server calls this on its receive-timeout tick so a
    /// crashed process cannot wedge the barrier.
    pub fn poll(&self) -> Option<Decision> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Some(Decision::Continue);
        }
        if st.arrived.is_empty() {
            // decision-at-the-barrier: never reconfigure mid-epoch
            return None;
        }
        self.complete_round(&mut st)
    }

    /// Shared completion step: reap, check the round, decide, advance
    /// the generation, wake blocking waiters.
    fn complete_round(&self, st: &mut CoState) -> Option<Decision> {
        self.reap_stale(st);
        if st.arrived.is_empty() || !Self::complete(st) {
            return None;
        }
        let d = self.decide(st);
        st.generation += 1;
        st.arrived.clear();
        self.cv.notify_all();
        Some(d)
    }

    /// Epoch-boundary barrier. Blocks until every rank of the current
    /// view has arrived (ranks silent longer than `heartbeat_timeout`
    /// are declared dead instead), then the last arriver decides
    /// Continue vs Reconfigure and all ranks return that decision.
    /// Implemented on the same [`Self::arrive`]/[`Self::poll`]
    /// primitives the transport-hosted rendezvous service drives.
    pub fn barrier(&self, rank: usize) -> Decision {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Decision::Continue;
        }
        let gen = st.generation;
        st.arrived.insert(rank);
        self.cv.notify_all();
        loop {
            if st.shutdown {
                return Decision::Continue;
            }
            if st.generation != gen {
                // someone else completed this generation
                return st.decision.clone();
            }
            if let Some(d) = self.complete_round(&mut st) {
                return d;
            }
            let (g, _) = self
                .cv
                .wait_timeout(st, self.cfg.heartbeat_timeout)
                .unwrap();
            st = g;
        }
    }

    /// Declare dead any rank that has neither arrived nor heartbeat
    /// within the timeout (measured from its last beat, or from the
    /// round start if it never reported).
    fn reap_stale(&self, st: &mut CoState) {
        let now = Instant::now();
        for r in 0..st.view.world_size() {
            if st.arrived.contains(&r) || st.failed.contains(&r) {
                continue;
            }
            let last = st
                .beats
                .get(&r)
                .and_then(|b| b.last)
                .unwrap_or(st.gen_started);
            if now.duration_since(last) > self.cfg.heartbeat_timeout {
                st.failed.insert(r);
            }
        }
    }

    fn complete(st: &CoState) -> bool {
        (0..st.view.world_size())
            .all(|r| st.arrived.contains(&r) || st.failed.contains(&r))
    }

    /// Compute the boundary decision: demote dead/straggling machines,
    /// apply any planned resize, publish the next view. Pure in
    /// (old view, failed set, timings, schedule) — survivor identity
    /// and arrival order never matter.
    fn decide(&self, st: &mut CoState) -> Decision {
        st.boundaries += 1;
        let old = st.view.clone();
        let mut demoted: BTreeSet<u32> = BTreeSet::new();
        for &r in &st.failed {
            demoted.insert(old.machine_of(r));
        }
        if self.cfg.demote_stragglers {
            self.mark_stragglers(st, &old, &mut demoted);
        }
        let mut machines: Vec<u32> = old
            .machines
            .iter()
            .copied()
            .filter(|m| !demoted.contains(m))
            .collect();
        if machines.is_empty() {
            // never demote the last machine standing: keep the old
            // view and hope the fault heals rather than abandon the run
            machines = old.machines.clone();
            demoted.clear();
        }
        let mut per = old.per_machine;
        if let Some(ev) = self
            .cfg
            .planned
            .iter()
            .find(|e| e.boundary == st.boundaries)
        {
            if ev.world >= machines.len() {
                per = (ev.world / machines.len()).max(1);
            } else {
                // shrinking below one rank per machine: keep the
                // first `world` machines (ascending ids — pure in the
                // view, not in who asked)
                machines.truncate(ev.world);
                per = 1;
            }
        }
        // reset per-round health for the next epoch
        st.failed.clear();
        st.beats.clear();
        st.gen_started = Instant::now();
        let changed = machines != old.machines || per != old.per_machine;
        st.decision = if changed {
            st.demotions += demoted.len() as u64;
            st.view = MembershipView {
                epoch: old.epoch + 1,
                machines,
                per_machine: per,
            };
            Decision::Reconfigure(st.view.clone())
        } else {
            Decision::Continue
        };
        st.decision.clone()
    }

    /// Strike machines whose mean step time exceeds
    /// `straggler_factor ×` the lower-median machine; demote after
    /// `straggler_patience` consecutive strikes (never below one
    /// machine). Requires a timing sample from every machine.
    fn mark_stragglers(
        &self,
        st: &mut CoState,
        old: &MembershipView,
        demoted: &mut BTreeSet<u32>,
    ) {
        let mut means: Vec<(u32, f64)> = Vec::new();
        for (i, &m) in old.machines.iter().enumerate() {
            let lo = i * old.per_machine;
            let mut sum = 0.0;
            let mut n = 0u64;
            for r in lo..lo + old.per_machine {
                if let Some(b) = st.beats.get(&r) {
                    if b.n > 0 {
                        sum += b.secs / b.n as f64;
                        n += 1;
                    }
                }
            }
            if n > 0 {
                means.push((m, sum / n as f64));
            }
        }
        if means.len() < old.machines.len() || means.len() < 2 {
            return;
        }
        let mut sorted: Vec<f64> = means.iter().map(|&(_, v)| v).collect();
        sorted.sort_by(f64::total_cmp);
        // lower median: with two machines this is the *fast* one, so a
        // single slow host is compared against its healthy peer
        let median = sorted[(sorted.len() - 1) / 2];
        for &(m, mean) in &means {
            if median > 0.0 && mean > self.cfg.straggler_factor * median {
                *st.strikes.entry(m).or_insert(0) += 1;
            } else {
                st.strikes.remove(&m);
            }
        }
        for &(m, _) in &means {
            let struck = st.strikes.get(&m).copied().unwrap_or(0)
                >= self.cfg.straggler_patience as u32;
            if struck
                && !demoted.contains(&m)
                && old.machines.len() - demoted.len() > 1
            {
                demoted.insert(m);
                st.strikes.remove(&m);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run one full barrier round on every rank of the current view.
    fn round(co: &Arc<Coordinator>) -> Vec<Decision> {
        let world = co.view().world_size();
        std::thread::scope(|s| {
            let hs: Vec<_> = (0..world)
                .map(|r| {
                    let co = co.clone();
                    s.spawn(move || co.barrier(r))
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn membership_view_maps_ranks_machine_major() {
        let v = MembershipView::initial(3, 2);
        assert_eq!(v.world_size(), 6);
        assert_eq!(v.machine_vec(), vec![0, 0, 1, 1, 2, 2]);
        let shrunk = MembershipView {
            epoch: 1,
            machines: vec![0, 2],
            per_machine: 1,
        };
        assert_eq!(shrunk.world_size(), 2);
        assert_eq!(shrunk.machine_of(1), 2);
    }

    #[test]
    fn elastic_schedule_parses_and_rejects_garbage() {
        let evs = parse_elastic_schedule("3:2, 1:4").unwrap();
        assert_eq!(
            evs,
            vec![
                ResizeEvent { boundary: 1, world: 4 },
                ResizeEvent { boundary: 3, world: 2 },
            ]
        );
        assert!(parse_elastic_schedule("").unwrap().is_empty());
        assert!(parse_elastic_schedule("nope").is_err());
        assert!(parse_elastic_schedule("0:2").is_err());
        assert!(parse_elastic_schedule("2:0").is_err());
        assert!(parse_elastic_schedule("1:2,1:3").is_err());
    }

    #[test]
    fn barrier_is_continue_for_a_healthy_round() {
        let co = Coordinator::new(
            MembershipView::initial(2, 1),
            CoordinatorConfig::default(),
        );
        co.heartbeat(0, 0.001);
        co.heartbeat(1, 0.001);
        let ds = round(&co);
        assert!(ds.iter().all(|d| *d == Decision::Continue));
        assert_eq!(co.boundaries(), 1);
        assert_eq!(co.view().epoch, 0);
    }

    #[test]
    fn planned_resize_reshapes_the_membership_at_its_boundary() {
        // grow 2 -> 4 at boundary 2
        let co = Coordinator::new(
            MembershipView::initial(2, 1),
            CoordinatorConfig {
                planned: vec![ResizeEvent { boundary: 2, world: 4 }],
                ..Default::default()
            },
        );
        assert!(round(&co).iter().all(|d| *d == Decision::Continue));
        let ds = round(&co);
        let want = MembershipView {
            epoch: 1,
            machines: vec![0, 1],
            per_machine: 2,
        };
        assert!(ds
            .iter()
            .all(|d| *d == Decision::Reconfigure(want.clone())));
        assert_eq!(co.view(), want);
        // shrink below one-per-machine: 2 machines -> world 1
        let co = Coordinator::new(
            MembershipView::initial(2, 1),
            CoordinatorConfig {
                planned: vec![ResizeEvent { boundary: 1, world: 1 }],
                ..Default::default()
            },
        );
        let ds = round(&co);
        let want = MembershipView {
            epoch: 1,
            machines: vec![0],
            per_machine: 1,
        };
        assert!(ds
            .iter()
            .all(|d| *d == Decision::Reconfigure(want.clone())));
        // no machine was *demoted* (planned resize, not a failure)
        assert_eq!(co.demotions(), 0);
    }

    #[test]
    fn dead_rank_demotes_its_machine() {
        let co = Coordinator::new(
            MembershipView::initial(2, 2),
            CoordinatorConfig::default(),
        );
        co.report_failure(3); // machine 1
        let ds = round(&co);
        let want = MembershipView {
            epoch: 1,
            machines: vec![0],
            per_machine: 2,
        };
        assert!(ds
            .iter()
            .all(|d| *d == Decision::Reconfigure(want.clone())));
        assert_eq!(co.demotions(), 1);
    }

    #[test]
    fn never_demotes_the_last_machine() {
        let co = Coordinator::new(
            MembershipView::initial(2, 1),
            CoordinatorConfig::default(),
        );
        co.report_failure(0);
        co.report_failure(1);
        let ds = round(&co);
        assert!(ds.iter().all(|d| *d == Decision::Continue));
        assert_eq!(co.demotions(), 0);
        assert_eq!(co.view().machines, vec![0, 1]);
    }

    #[test]
    fn straggler_demoted_after_patience_rounds() {
        let co = Coordinator::new(
            MembershipView::initial(2, 1),
            CoordinatorConfig {
                demote_stragglers: true,
                straggler_factor: 2.0,
                straggler_patience: 2,
                ..Default::default()
            },
        );
        // round 1: machine 1 is 20x slower -> first strike, no demotion
        co.heartbeat(0, 0.001);
        co.heartbeat(1, 0.020);
        assert!(round(&co).iter().all(|d| *d == Decision::Continue));
        // round 2: still slow -> second strike -> demoted
        co.heartbeat(0, 0.001);
        co.heartbeat(1, 0.020);
        let ds = round(&co);
        let want = MembershipView {
            epoch: 1,
            machines: vec![0],
            per_machine: 1,
        };
        assert!(ds
            .iter()
            .all(|d| *d == Decision::Reconfigure(want.clone())));
        assert_eq!(co.demotions(), 1);
    }

    #[test]
    fn straggler_strikes_reset_when_the_machine_recovers() {
        let co = Coordinator::new(
            MembershipView::initial(2, 1),
            CoordinatorConfig {
                demote_stragglers: true,
                straggler_factor: 2.0,
                straggler_patience: 2,
                ..Default::default()
            },
        );
        co.heartbeat(0, 0.001);
        co.heartbeat(1, 0.020); // strike 1
        round(&co);
        co.heartbeat(0, 0.001);
        co.heartbeat(1, 0.001); // recovered: strikes reset
        round(&co);
        co.heartbeat(0, 0.001);
        co.heartbeat(1, 0.020); // strike 1 again, not 2
        assert!(round(&co).iter().all(|d| *d == Decision::Continue));
        assert_eq!(co.demotions(), 0);
    }

    #[test]
    fn silent_rank_is_reaped_after_heartbeat_timeout() {
        let co = Coordinator::new(
            MembershipView::initial(2, 1),
            CoordinatorConfig {
                heartbeat_timeout: Duration::from_millis(30),
                ..Default::default()
            },
        );
        co.heartbeat(0, 0.001);
        // rank 1 never arrives and never beats
        let d = co.barrier(0);
        let want = MembershipView {
            epoch: 1,
            machines: vec![0],
            per_machine: 1,
        };
        assert_eq!(d, Decision::Reconfigure(want));
        assert_eq!(co.demotions(), 1);
    }

    #[test]
    fn shutdown_releases_barrier_waiters() {
        let co = Coordinator::new(
            MembershipView::initial(2, 1),
            CoordinatorConfig::default(),
        );
        std::thread::scope(|s| {
            let waiter = {
                let co = co.clone();
                s.spawn(move || co.barrier(0))
            };
            std::thread::sleep(Duration::from_millis(10));
            co.shutdown();
            assert_eq!(waiter.join().unwrap(), Decision::Continue);
        });
        // future barriers return immediately too
        assert_eq!(co.barrier(1), Decision::Continue);
    }

    #[test]
    fn ranks_on_maps_the_machine_major_grid() {
        let v = MembershipView::initial(3, 2);
        assert_eq!(v.ranks_on(0), vec![0, 1]);
        assert_eq!(v.ranks_on(2), vec![4, 5]);
        assert_eq!(v.ranks_on(7), Vec::<usize>::new());
        let shrunk = MembershipView {
            epoch: 1,
            machines: vec![0, 2],
            per_machine: 2,
        };
        assert_eq!(shrunk.ranks_on(2), vec![2, 3]);
        assert_eq!(shrunk.ranks_on(1), Vec::<usize>::new());
    }

    #[test]
    fn nonblocking_arrive_completes_the_round_like_barrier() {
        let co = Coordinator::new(
            MembershipView::initial(2, 1),
            CoordinatorConfig::default(),
        );
        assert_eq!(co.arrive(0), None, "round incomplete");
        assert_eq!(co.generation(), 0);
        assert_eq!(co.arrive(1), Some(Decision::Continue));
        assert_eq!(co.generation(), 1);
        assert_eq!(co.boundaries(), 1);
        // blocking waiters of the same round are released by an arrive
        let co2 = Coordinator::new(
            MembershipView::initial(2, 1),
            CoordinatorConfig::default(),
        );
        std::thread::scope(|s| {
            let waiter = {
                let co2 = co2.clone();
                s.spawn(move || co2.barrier(0))
            };
            std::thread::sleep(Duration::from_millis(10));
            assert_eq!(co2.arrive(1), Some(Decision::Continue));
            assert_eq!(waiter.join().unwrap(), Decision::Continue);
        });
    }

    #[test]
    fn poll_reaps_a_silent_rank_and_completes_the_round() {
        let co = Coordinator::new(
            MembershipView::initial(2, 1),
            CoordinatorConfig {
                heartbeat_timeout: Duration::from_millis(20),
                ..Default::default()
            },
        );
        // no round in progress: poll never invents a boundary
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(co.poll(), None);
        assert_eq!(co.boundaries(), 0);
        // rank 0 arrives; rank 1 goes silent past the timeout
        assert_eq!(co.arrive(0), None);
        std::thread::sleep(Duration::from_millis(30));
        let d = co.poll().expect("reap completes the round");
        let want = MembershipView {
            epoch: 1,
            machines: vec![0],
            per_machine: 1,
        };
        assert_eq!(d, Decision::Reconfigure(want));
        assert_eq!(co.demotions(), 1);
    }
}
