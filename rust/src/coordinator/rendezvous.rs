//! Transport-hosted rendezvous: the [`Coordinator`] re-hosted behind
//! [`Port::Control`] messages so membership spans OS processes
//! (docs/DESIGN.md §11).
//!
//! One process (elected by config: the one hosting the server endpoint)
//! runs a [`RendezvousServer`] wrapping the same in-process
//! [`Coordinator`] the single-process elastic trainer uses — rank
//! assignment, epoch-boundary barrier, heartbeat reaping, straggler
//! strikes, and planned resizes are byte-for-byte the same decision
//! logic; only the signal delivery changes from shared memory to
//! [`CoordMsg`] frames. Every machine process holds a
//! [`RendezvousClient`] mirroring the `Coordinator` API (`barrier`,
//! `heartbeat`, `report_failure`, `shutdown`) over the wire.
//!
//! Protocol (client → server unless noted):
//!   `Hello{preferred}` → `Welcome{machine, view}` — join + id assignment
//!   `Rejoin{machine}` → `Welcome{machine, view}` — a restarted process
//!       reclaims its previous id (docs/DESIGN.md §12); plain `Hello`
//!       would collide with the used-id set and get a fresh id
//!   `BarrierArrive{rank}` → `DecisionMsg(..)` — held until the round
//!       completes (all ranks arrived or were reaped), then answered
//!       all-at-once with the same decision
//!   `Heartbeat{rank, secs}`, `FailureReport{rank}` — fire-and-forget
//!   `Shutdown{machine}` → `ShutdownAck` — the server exits after every
//!       expected client said goodbye
//!
//! Works identically over the in-process and TCP backends — the tests
//! below run the same protocol over both.

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::Duration;

use super::{Coordinator, CoordinatorConfig, Decision, MembershipView};
use crate::net::payload::{decode_coord_msg, encode_coord_msg, CoordMsg};
use crate::net::{Endpoint, Port, PortKind, RpcError};

/// Serve-loop tick: how often the server reaps silent ranks when no
/// messages arrive. Derived from the heartbeat timeout so a crashed
/// process is declared dead on the same schedule as in-process runs.
fn tick_of(cfg: &CoordinatorConfig) -> Duration {
    (cfg.heartbeat_timeout / 4)
        .clamp(Duration::from_millis(10), Duration::from_millis(250))
}

/// The rendezvous service. Owns the server [`Endpoint`] and the wrapped
/// [`Coordinator`]; `run()` is the message loop (spawn it on a thread —
/// it exits after all `expect_clients` processes said `Shutdown`).
pub struct RendezvousServer {
    ep: Endpoint,
    co: Arc<Coordinator>,
    expect_clients: usize,
    tick: Duration,
}

impl RendezvousServer {
    pub fn new(
        ep: Endpoint,
        view: MembershipView,
        cfg: CoordinatorConfig,
        expect_clients: usize,
    ) -> Self {
        let tick = tick_of(&cfg);
        Self {
            ep,
            co: Coordinator::new(view, cfg),
            expect_clients,
            tick,
        }
    }

    /// The wrapped coordinator (observability: boundaries, demotions).
    pub fn coordinator(&self) -> Arc<Coordinator> {
        Arc::clone(&self.co)
    }

    fn reply(&self, to: u32, tag: u64, msg: &CoordMsg) {
        // a vanished peer is handled by reaping, not by the reply path
        let _ = self.ep.send(to, Port::Control, tag, encode_coord_msg(msg));
    }

    fn flush_pending(&self, pending: &mut Vec<(u32, u64)>, d: &Decision) {
        let msg = CoordMsg::DecisionMsg(d.clone());
        for (to, tag) in pending.drain(..) {
            self.reply(to, tag, &msg);
        }
    }

    /// Message loop. Returns the number of epoch boundaries decided.
    pub fn run(self) -> u64 {
        // barrier arrivals awaiting the round's decision: (endpoint, tag)
        let mut pending: Vec<(u32, u64)> = Vec::new();
        let mut used_ids: BTreeSet<u32> = BTreeSet::new();
        let mut byes: BTreeSet<u32> = BTreeSet::new();
        loop {
            let msg = self.ep.recv_kind(PortKind::Control, Some(self.tick));
            let Some(msg) = msg else {
                if self.ep.is_closed() {
                    // transport torn down under us: release any waiters
                    self.co.shutdown();
                    self.flush_pending(&mut pending, &Decision::Continue);
                    return self.co.boundaries();
                }
                // idle tick: reap silent ranks, maybe complete the round
                if let Some(d) = self.co.poll() {
                    self.flush_pending(&mut pending, &d);
                }
                continue;
            };
            let Ok(decoded) = decode_coord_msg(&msg.payload) else {
                continue; // garbled frame: drop it, the wire stays up
            };
            match decoded {
                CoordMsg::Hello { preferred } => {
                    let machine = if preferred != u32::MAX
                        && !used_ids.contains(&preferred)
                    {
                        preferred
                    } else {
                        // join order: smallest id not yet handed out
                        (0..).find(|m| !used_ids.contains(m)).unwrap()
                    };
                    used_ids.insert(machine);
                    self.reply(
                        msg.from,
                        msg.tag,
                        &CoordMsg::Welcome { machine, view: self.co.view() },
                    );
                }
                CoordMsg::Rejoin { machine } => {
                    // restart/rejoin: the id stays reserved for its
                    // owner, so reclaiming is just re-welcoming; the
                    // restarted process owes a fresh Shutdown goodbye
                    used_ids.insert(machine);
                    byes.remove(&msg.from);
                    self.reply(
                        msg.from,
                        msg.tag,
                        &CoordMsg::Welcome { machine, view: self.co.view() },
                    );
                }
                CoordMsg::BarrierArrive { rank } => {
                    pending.push((msg.from, msg.tag));
                    if let Some(d) = self.co.arrive(rank as usize) {
                        self.flush_pending(&mut pending, &d);
                    }
                }
                CoordMsg::Heartbeat { rank, secs } => {
                    self.co.heartbeat(rank as usize, secs);
                }
                CoordMsg::FailureReport { rank } => {
                    self.co.report_failure(rank as usize);
                    if let Some(d) = self.co.poll() {
                        self.flush_pending(&mut pending, &d);
                    }
                }
                CoordMsg::Shutdown { machine: _ } => {
                    self.reply(msg.from, msg.tag, &CoordMsg::ShutdownAck);
                    byes.insert(msg.from);
                    if byes.len() >= self.expect_clients {
                        self.co.shutdown();
                        self.flush_pending(
                            &mut pending,
                            &Decision::Continue,
                        );
                        return self.co.boundaries();
                    }
                }
                // server-to-client messages arriving here are protocol
                // misuse by a peer; ignore them
                CoordMsg::Welcome { .. }
                | CoordMsg::DecisionMsg(_)
                | CoordMsg::ShutdownAck => {}
            }
        }
    }
}

/// Per-process handle onto the rendezvous service, mirroring the
/// [`Coordinator`] API over the wire. Methods take `&mut self`: one
/// process drives its rendezvous from one thread (trainer ranks within
/// the process arrive together via [`Self::barrier_all`]).
pub struct RendezvousClient {
    ep: Endpoint,
    server: u32,
    machine: u32,
    view: MembershipView,
    next_tag: u64,
    /// How long to wait for the barrier decision before declaring the
    /// coordinator lost. Must exceed the slowest epoch (the decision
    /// only lands when every rank arrives).
    pub decision_timeout: Duration,
}

impl RendezvousClient {
    /// Join the rendezvous: send `Hello`, await `Welcome`, learn our
    /// machine id and the initial membership view. `preferred = None`
    /// lets the server assign ids in join order.
    pub fn join(
        ep: Endpoint,
        server: u32,
        preferred: Option<u32>,
        timeout: Duration,
    ) -> Result<Self, RpcError> {
        let mut c = Self {
            ep,
            server,
            machine: u32::MAX,
            view: MembershipView::initial(0, 1),
            next_tag: 1,
            decision_timeout: Duration::from_secs(600),
        };
        let hello = CoordMsg::Hello {
            preferred: preferred.unwrap_or(u32::MAX),
        };
        let tag = c.send(&hello)?;
        match c.await_reply(&[tag], timeout)? {
            CoordMsg::Welcome { machine, view } => {
                c.machine = machine;
                c.view = view;
                Ok(c)
            }
            other => Err(RpcError::ConnectionLost {
                peer: server,
                detail: format!("expected Welcome, got {other:?}"),
            }),
        }
    }

    /// Restart path (docs/DESIGN.md §12): reclaim `machine` after a
    /// process restart. A plain [`Self::join`] cannot — the id sits in
    /// the server's used set, so the fallback would hand out a fresh
    /// one and the world would believe a new machine appeared.
    pub fn rejoin(
        ep: Endpoint,
        server: u32,
        machine: u32,
        timeout: Duration,
    ) -> Result<Self, RpcError> {
        let mut c = Self {
            ep,
            server,
            machine,
            view: MembershipView::initial(0, 1),
            next_tag: 1,
            decision_timeout: Duration::from_secs(600),
        };
        let tag = c.send(&CoordMsg::Rejoin { machine })?;
        match c.await_reply(&[tag], timeout)? {
            CoordMsg::Welcome { machine: m, view } => {
                c.machine = m;
                c.view = view;
                Ok(c)
            }
            other => Err(RpcError::ConnectionLost {
                peer: server,
                detail: format!("expected Welcome, got {other:?}"),
            }),
        }
    }

    pub fn machine(&self) -> u32 {
        self.machine
    }

    /// Current membership view (updated by `Reconfigure` decisions).
    pub fn view(&self) -> &MembershipView {
        &self.view
    }

    /// The ranks this process trains under the current view.
    pub fn my_ranks(&self) -> Vec<usize> {
        self.view.ranks_on(self.machine)
    }

    fn send(&mut self, msg: &CoordMsg) -> Result<u64, RpcError> {
        let tag = self.next_tag;
        self.next_tag += 1;
        self.ep.send(
            self.server,
            Port::Control,
            tag,
            encode_coord_msg(msg),
        )?;
        Ok(tag)
    }

    /// Wait until every tag in `tags` has been answered; returns the
    /// last reply (barrier rounds answer all arrivals identically).
    /// Stale frames (earlier rounds) are discarded by tag.
    fn await_reply(
        &self,
        tags: &[u64],
        timeout: Duration,
    ) -> Result<CoordMsg, RpcError> {
        let deadline = std::time::Instant::now() + timeout;
        let mut waiting: BTreeSet<u64> = tags.iter().copied().collect();
        let mut last = None;
        while !waiting.is_empty() {
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(RpcError::ConnectionLost {
                    peer: self.server,
                    detail: format!(
                        "no rendezvous reply within {timeout:?}"
                    ),
                });
            }
            let msg = self
                .ep
                .recv_kind(PortKind::Control, Some(deadline - now));
            let Some(msg) = msg else {
                if self.ep.is_closed() {
                    return Err(RpcError::ConnectionLost {
                        peer: self.server,
                        detail: "transport shut down".into(),
                    });
                }
                continue;
            };
            if !waiting.remove(&msg.tag) {
                continue; // stale reply from an earlier round
            }
            match decode_coord_msg(&msg.payload) {
                Ok(m) => last = Some(m),
                Err(e) => {
                    return Err(RpcError::ConnectionLost {
                        peer: self.server,
                        detail: format!("undecodable reply: {e}"),
                    })
                }
            }
        }
        last.ok_or_else(|| RpcError::ConnectionLost {
            peer: self.server,
            detail: "no tags awaited".into(),
        })
    }

    /// Epoch-boundary barrier for every locally hosted rank at once.
    /// Sends all arrivals before blocking — two local ranks must never
    /// deadlock waiting on each other's un-sent arrival — then waits for
    /// the round's decision. A `Reconfigure` updates the local view.
    pub fn barrier_all(
        &mut self,
        ranks: &[usize],
    ) -> Result<Decision, RpcError> {
        let mut tags = Vec::with_capacity(ranks.len());
        for &r in ranks {
            tags.push(
                self.send(&CoordMsg::BarrierArrive { rank: r as u32 })?,
            );
        }
        let reply = self.await_reply(&tags, self.decision_timeout)?;
        match reply {
            CoordMsg::DecisionMsg(d) => {
                if let Decision::Reconfigure(v) = &d {
                    self.view = v.clone();
                }
                Ok(d)
            }
            other => Err(RpcError::ConnectionLost {
                peer: self.server,
                detail: format!("expected DecisionMsg, got {other:?}"),
            }),
        }
    }

    /// Single-rank barrier (the `Coordinator::barrier` shape).
    pub fn barrier(&mut self, rank: usize) -> Result<Decision, RpcError> {
        self.barrier_all(&[rank])
    }

    /// Record one finished step for `rank` (liveness + step timing).
    /// Fire-and-forget: a lost heartbeat only risks a reap, which the
    /// next heartbeat heals.
    pub fn heartbeat(
        &mut self,
        rank: usize,
        step_secs: f64,
    ) -> Result<(), RpcError> {
        self.send(&CoordMsg::Heartbeat {
            rank: rank as u32,
            secs: step_secs,
        })?;
        Ok(())
    }

    /// Report `rank` unrecoverably failed (fire-and-forget).
    pub fn report_failure(&mut self, rank: usize) -> Result<(), RpcError> {
        self.send(&CoordMsg::FailureReport { rank: rank as u32 })?;
        Ok(())
    }

    /// Clean goodbye: the server exits once every process said this.
    pub fn shutdown(&mut self) -> Result<(), RpcError> {
        let machine = self.machine;
        let tag = self.send(&CoordMsg::Shutdown { machine })?;
        match self.await_reply(&[tag], Duration::from_secs(30))? {
            CoordMsg::ShutdownAck => Ok(()),
            other => Err(RpcError::ConnectionLost {
                peer: self.server,
                detail: format!("expected ShutdownAck, got {other:?}"),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ResizeEvent;
    use crate::net::tcp::{free_loopback_ports, tcp_transport, TcpConfig};
    use crate::net::{CostModel, Transport};

    const JOIN_T: Duration = Duration::from_secs(20);

    /// Two machines × 1 rank through join → barrier → planned resize →
    /// shutdown, over any pair of client endpoints + a server endpoint.
    fn run_protocol(
        eps: Vec<Endpoint>,
        server_ep: Endpoint,
        server_id: u32,
    ) -> (u64, Vec<u32>) {
        let cfg = CoordinatorConfig {
            planned: vec![ResizeEvent { boundary: 2, world: 1 }],
            ..Default::default()
        };
        let server = RendezvousServer::new(
            server_ep,
            MembershipView::initial(2, 1),
            cfg,
            2,
        );
        let co = server.coordinator();
        let sh = std::thread::spawn(move || server.run());
        let hs: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                std::thread::spawn(move || {
                    let mut c = RendezvousClient::join(
                        ep, server_id, None, JOIN_T,
                    )
                    .expect("join");
                    let m = c.machine();
                    let ranks = c.my_ranks();
                    assert_eq!(ranks.len(), 1);
                    c.heartbeat(ranks[0], 0.001).unwrap();
                    // round 1: everyone healthy
                    let d1 = c.barrier_all(&ranks).unwrap();
                    assert_eq!(d1, Decision::Continue);
                    // round 2: planned shrink to world 1
                    let d2 = c.barrier_all(&ranks).unwrap();
                    match d2 {
                        Decision::Reconfigure(v) => {
                            assert_eq!(v.machines, vec![0]);
                            assert_eq!(v.world_size(), 1);
                            assert_eq!(c.view(), &v);
                        }
                        d => panic!("expected resize, got {d:?}"),
                    }
                    c.shutdown().unwrap();
                    m
                })
            })
            .collect();
        let machines: Vec<u32> =
            hs.into_iter().map(|h| h.join().unwrap()).collect();
        let boundaries = sh.join().unwrap();
        assert_eq!(boundaries, co.boundaries());
        (boundaries, machines)
    }

    #[test]
    fn rendezvous_over_in_process_transport() {
        // endpoints 0,1 = clients; 2 = server
        let t = Transport::new(3, CostModel::default());
        let eps = vec![t.endpoint(0), t.endpoint(1)];
        let (boundaries, mut machines) = run_protocol(eps, t.endpoint(2), 2);
        assert_eq!(boundaries, 2);
        machines.sort_unstable();
        assert_eq!(machines, vec![0, 1], "join-order id assignment");
    }

    #[test]
    fn rendezvous_over_tcp_loopback() {
        // two real processes' worth of sockets in one test: proc 0 hosts
        // client 0 + the server (endpoint 2), proc 1 hosts client 1
        let ports = free_loopback_ports(2).unwrap();
        let addrs: Vec<String> = ports
            .iter()
            .map(|p| format!("127.0.0.1:{p}"))
            .collect();
        let mk = |my_proc: usize| {
            let mut cfg = TcpConfig::localhost(my_proc, 2, 0);
            cfg.addrs = addrs.clone();
            cfg.endpoint_proc = vec![0, 1, 0];
            cfg.machine_of = vec![0, 1, 0];
            tcp_transport(cfg, Arc::new(CostModel::default())).unwrap()
        };
        let t0 = mk(0);
        let t1 = mk(1);
        let eps = vec![t0.endpoint(0), t1.endpoint(1)];
        let (boundaries, mut machines) = run_protocol(eps, t0.endpoint(2), 2);
        assert_eq!(boundaries, 2);
        machines.sort_unstable();
        assert_eq!(machines, vec![0, 1]);
    }

    #[test]
    fn preferred_ids_are_honored_and_collisions_fall_back() {
        let t = Transport::new(3, CostModel::default());
        let server = RendezvousServer::new(
            t.endpoint(2),
            MembershipView::initial(2, 1),
            CoordinatorConfig::default(),
            2,
        );
        let sh = std::thread::spawn(move || server.run());
        let mut c1 = RendezvousClient::join(
            t.endpoint(0),
            2,
            Some(1),
            JOIN_T,
        )
        .unwrap();
        assert_eq!(c1.machine(), 1, "preferred id granted");
        // second client asks for the taken id: falls back to join order
        let mut c0 = RendezvousClient::join(
            t.endpoint(1),
            2,
            Some(1),
            JOIN_T,
        )
        .unwrap();
        assert_eq!(c0.machine(), 0, "collision falls back to next free");
        c0.shutdown().unwrap();
        c1.shutdown().unwrap();
        sh.join().unwrap();
    }

    #[test]
    fn rejoin_reclaims_the_previous_machine_id() {
        let t = Transport::new(3, CostModel::default());
        let server = RendezvousServer::new(
            t.endpoint(2),
            MembershipView::initial(2, 1),
            CoordinatorConfig::default(),
            2,
        );
        let sh = std::thread::spawn(move || server.run());
        let mut c1 =
            RendezvousClient::join(t.endpoint(0), 2, Some(1), JOIN_T)
                .unwrap();
        assert_eq!(c1.machine(), 1);
        // the "restarted" process: a plain Hello for the taken id would
        // fall back to a fresh id, Rejoin asserts the identity instead
        let mut again =
            RendezvousClient::rejoin(t.endpoint(1), 2, 1, JOIN_T).unwrap();
        assert_eq!(again.machine(), 1, "rejoin reclaims the taken id");
        assert_eq!(again.view().machines, vec![0, 1]);
        again.shutdown().unwrap();
        c1.shutdown().unwrap();
        sh.join().unwrap();
    }

    #[test]
    fn server_reaps_a_vanished_process_and_releases_the_barrier() {
        let t = Transport::new(3, CostModel::default());
        let server = RendezvousServer::new(
            t.endpoint(2),
            MembershipView::initial(2, 1),
            CoordinatorConfig {
                heartbeat_timeout: Duration::from_millis(60),
                ..Default::default()
            },
            1, // only client 0 is expected to say goodbye
        );
        let sh = std::thread::spawn(move || server.run());
        let mut c0 =
            RendezvousClient::join(t.endpoint(0), 2, Some(0), JOIN_T)
                .unwrap();
        // machine 1 joined the view but its process never arrives: the
        // poll tick reaps rank 1 and answers the barrier with a shrink
        let d = c0.barrier(0).unwrap();
        match d {
            Decision::Reconfigure(v) => {
                assert_eq!(v.machines, vec![0]);
            }
            d => panic!("expected reap-shrink, got {d:?}"),
        }
        c0.shutdown().unwrap();
        sh.join().unwrap();
    }
}
